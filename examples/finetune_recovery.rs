//! Fine-tuning recovery (the paper's Table 1 "+N tokens" rows): convert
//! the base model at an aggressive compression, then fine-tune the
//! trainable-MLA form through the AOT train-step executable and watch the
//! held-out loss recover toward the original model. Logs the loss curve.
//!
//! Run: `cargo run --release --example finetune_recovery [-- steps]`

use anyhow::{Context, Result};
use std::path::Path;
use transmla::convert::{absorb_trainable, convert_model, ConvertOptions};
use transmla::corpus::Corpus;
use transmla::eval::{capture_calib, evaluate};
use transmla::model::{init_gqa, Params};
use transmla::runtime::Runtime;
use transmla::train::Trainer;
use transmla::util::Rng;

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(60);
    let rt = Runtime::new(Path::new("artifacts"))?;
    let cfg_name = "llama2tiny";
    let cfg = rt.manifest.configs.get(cfg_name).context("config")?.clone();

    let ckpt = Path::new("runs/llama2tiny_base.tnz");
    let gqa = if ckpt.exists() {
        Params::load(ckpt)?
    } else {
        eprintln!("[warn] no checkpoint - using random init");
        init_gqa(&cfg, 42)
    };

    let corpus = Corpus::synthetic(7, 2_000_000);
    let calib_exec = rt.load(&format!("{cfg_name}_calib"))?;
    let mut rng = Rng::new(0);
    let toks = corpus.sample_batch(8, cfg.max_seq, &mut rng);
    let calib = capture_calib(&calib_exec, &gqa, &toks, 1024)?;
    let batches: Vec<_> = corpus
        .val_batches(8, cfg.max_seq)
        .into_iter()
        .take(2)
        .collect();

    let base = evaluate(&rt.load(&format!("{cfg_name}_gqa_prefill"))?, &gqa, &batches)?;
    println!("original GQA loss {:.4}", base.loss);

    // The paper's hardest row: -92.97% KV cache.
    let rank = *rt
        .manifest
        .table1_ranks
        .get(cfg_name)
        .and_then(|r| r.last())
        .context("rank")?;
    let (train_p, absorbed, _) =
        convert_model(&gqa, &calib, &cfg, &ConvertOptions::transmla(rank))?;
    let eval_mla = |p: &Params| -> Result<f64> {
        let exec = rt.load(&format!("{cfg_name}_mla_prefill_r{rank}"))?;
        Ok(evaluate(&exec, p, &batches)?.loss)
    };
    let loss0 = eval_mla(&absorbed)?;
    println!(
        "converted (-{:.2}% KV) loss {:.4}  (degradation +{:.4})",
        cfg.compression(rank) * 100.0,
        loss0,
        loss0 - base.loss
    );

    // Fine-tune the trainable form; re-absorb and re-evaluate periodically.
    let exec = rt.load(&format!("{cfg_name}_mla_train_r{rank}"))?;
    let mut tr = Trainer::new(exec, train_p)?;
    let chunk = 20;
    let mut seen_tokens = 0usize;
    for round in 0..steps.div_ceil(chunk) {
        let n = chunk.min(steps - round * chunk);
        let rep = tr.run(&corpus, n, 5e-4, round as u64 + 10, 0, "recovery")?;
        seen_tokens += rep.tokens;
        let absorbed_ft = absorb_trainable(&tr.params, &cfg)?;
        let loss = eval_mla(&absorbed_ft)?;
        println!(
            "after {:>6} FT tokens: train {:.4}  heldout {:.4}  (gap to base {:+.4})",
            seen_tokens,
            rep.tail_loss(5),
            loss,
            loss - base.loss
        );
    }
    Ok(())
}
