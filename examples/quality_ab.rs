//! Quality A/B walkthrough (hermetic — no artifacts needed): one tiny
//! in-repo dataset scored across a GQA engine and its rank-8 MLA twin
//! through protocol-v2 routing — the TransMLA question "did conversion
//! hurt, and what did it buy" as one printed matrix.
//!
//!   1. generate reference outputs from a solo GQA engine (they become
//!      the dataset's `expected` values),
//!   2. host a `gqa` + `mla` registry on a local port,
//!   3. fan the dataset across both models with the qeval driver,
//!   4. build the per-model × per-scorer report with `--baseline gqa`
//!      semantics and print it (the MLA row carries the deltas).
//!
//! On the sim backend the MLA twin at the same seed reproduces the GQA
//! outputs exactly (the sim's state chain is cache-layout-independent),
//! so the printed exact-match delta is 0.0pp — the "quality recovered"
//! half of the paper's claim, in miniature.
//!
//! Run: `cargo run --release --example quality_ab`
//!
//! The same topology from the CLI:
//! `transmla eval --data d.jsonl --model gqa=arch=gqa \
//!      --model mla=arch=mla,rank=8 --baseline gqa \
//!      --exact --levenshtein 0.8`

use anyhow::Result;
use transmla::backend::SimBackend;
use transmla::config::{EngineConfig, EvalOpts};
use transmla::coordinator::{Engine, Request};
use transmla::qeval::{self, scorers};
use transmla::server::{self, EngineRegistry, RoutePolicy};

fn main() -> Result<()> {
    let addr = "127.0.0.1:7462";
    let prompts =
        ["the latent cache", "absorbed attention", "rank picks the", "kv bytes per token"];
    let max_new = 12;

    // 1. Reference outputs from a solo GQA engine.
    let mut reference = Engine::new(SimBackend::gqa(4), EngineConfig::default());
    let reqs: Vec<Request> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| Request::from_text(i as u64, p, max_new))
        .collect();
    let expected: Vec<String> =
        reference.generate(reqs)?.iter().map(|c| c.text()).collect();
    let pairs: Vec<(&str, &str)> =
        prompts.iter().zip(&expected).map(|(p, e)| (*p, e.as_str())).collect();
    let ds = qeval::Dataset::from_pairs(&pairs);

    // 2. The A/B pair behind one endpoint.
    let server_thread = std::thread::spawn(move || {
        let mut reg = EngineRegistry::new(RoutePolicy::Default("gqa".into()));
        reg.register("gqa", Engine::new(SimBackend::gqa(4), EngineConfig::default()))
            .unwrap();
        reg.register("mla", Engine::new(SimBackend::mla(4, 8), EngineConfig::default()))
            .unwrap();
        server::serve(&mut reg, addr).unwrap();
    });
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while server::client_line(addr, "{\"cmd\":\"ping\"}").is_err() {
        if std::time::Instant::now() > deadline {
            anyhow::bail!("server at {addr} never came up (port in use?)");
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }

    // 3. Fan every row to both models (bounded concurrency, protocol-v2
    //    routing), then 4. fold into the A/B matrix.
    let opts = EvalOpts { concurrency: 4, max_new, baseline: Some("gqa".into()) };
    let models = vec!["gqa".to_string(), "mla".to_string()];
    let run = qeval::run_eval(&ds, &models, addr, &opts)?;
    let scorers = scorers::from_flags(&[
        ("exact".to_string(), "true".to_string()),
        ("levenshtein".to_string(), "0.8".to_string()),
    ])?;
    let report = qeval::EvalReport::build("quality-ab", &ds, &scorers, &run, Some("gqa"))?;
    println!("{}", report.human());
    print!("\n{}", report.to_jsonl());

    server::client_shutdown(addr)?;
    server_thread.join().expect("server thread");
    Ok(())
}
