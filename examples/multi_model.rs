//! Multi-model serving walkthrough (hermetic — no artifacts needed):
//! one server fronting a GQA engine and its MLA-converted twin, the
//! paper's migration story as an operational A/B setup.
//!
//!   1. build a two-engine `EngineRegistry` (`gqa-base` + `mla`, the MLA
//!      one on the paged cache with chunked prefill),
//!   2. serve it on a local port,
//!   3. route requests to each model explicitly (protocol v2 `model`
//!      field) and once through the routing policy,
//!   4. list the hosted models and print per-engine stats.
//!
//! Run: `cargo run --release --example multi_model`
//!
//! The same topology from the CLI:
//! `transmla serve --backend sim --model gqa-base=layout=gqa \
//!      --model mla=layout=mla,cache=paged,policy=chunked:8`

use anyhow::Result;
use transmla::backend::SimBackend;
use transmla::config::{CacheKind, EngineConfig, PolicyKind};
use transmla::coordinator::Engine;
use transmla::json::Json;
use transmla::server::{self, EngineRegistry, RoutePolicy};

fn main() -> Result<()> {
    let addr = "127.0.0.1:7461";

    // 1. Two named engines behind one endpoint. Each has its own
    //    backend, cache store, and scheduling policy.
    let server_thread = std::thread::spawn(move || {
        let mut reg = EngineRegistry::new(RoutePolicy::Default("gqa-base".into()));
        reg.register(
            "gqa-base",
            Engine::new(SimBackend::gqa(8), EngineConfig::default()),
        )
        .unwrap();
        reg.register(
            "mla",
            Engine::new(
                SimBackend::mla(8, 8),
                EngineConfig {
                    cache: CacheKind::Paged { block_size: 16, n_blocks: None },
                    policy: PolicyKind::Chunked { chunk_tokens: 8 },
                    ..Default::default()
                },
            ),
        )
        .unwrap();
        // 2. The serving loop steps every non-idle engine each iteration.
        server::serve(&mut reg, addr).unwrap();
    });

    // Wait for the listener (bounded, so a failed bind surfaces instead
    // of spinning forever).
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while server::client_line(addr, "{\"cmd\":\"ping\"}").is_err() {
        if std::time::Instant::now() > deadline {
            anyhow::bail!("server at {addr} never came up (port in use?)");
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }

    // 3. Explicit routing: the same prompt through both models.
    let prompt = "the latent cache compresses ";
    for model in ["gqa-base", "mla"] {
        let resp = server::client_request_model(addr, prompt, 24, Some(model))?;
        println!(
            "[{}] {}{}",
            resp.get("model").and_then(Json::as_str).unwrap_or("?"),
            prompt,
            resp.get("text").and_then(Json::as_str).unwrap_or("")
        );
    }
    // No `model` field: the routing policy (default:gqa-base) decides.
    let routed = server::client_request(addr, prompt, 8)?;
    println!(
        "[routed -> {}] ok",
        routed.get("model").and_then(Json::as_str).unwrap_or("?")
    );

    // 4. Discover what the server hosts, then read per-engine stats.
    let models = server::client_models(addr)?;
    println!("models: {}", models.to_pretty());
    let stats = server::client_stats(addr)?;
    if let Some(engines) = stats.get("engines").and_then(Json::as_obj) {
        for (name, eng) in engines {
            println!(
                "[{name}] completed {} | decode {:.1} tok/s | cache `{}`",
                eng.get("counters")
                    .and_then(|c| c.get("completed"))
                    .and_then(Json::as_usize)
                    .unwrap_or(0),
                eng.get("decode_tok_per_s").and_then(Json::as_f64).unwrap_or(0.0),
                eng.get("cache")
                    .and_then(|c| c.get("kind"))
                    .and_then(Json::as_str)
                    .unwrap_or("?"),
            );
        }
    }

    server::client_shutdown(addr)?;
    server_thread.join().expect("server thread");
    Ok(())
}
