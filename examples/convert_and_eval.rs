//! Conversion-quality sweep: converts the base model at every exported
//! latent rank with both TransMLA and the MHA2MLA baseline and reports
//! held-out loss/perplexity — a compact, runnable slice of Table 1 and
//! Figure 3b.
//!
//! Run: `cargo run --release --example convert_and_eval`

use anyhow::{Context, Result};
use std::path::Path;
use transmla::convert::{convert_model, ConvertOptions, PcaMode};
use transmla::corpus::Corpus;
use transmla::eval::{capture_calib, evaluate};
use transmla::model::{init_gqa, Params};
use transmla::runtime::Runtime;
use transmla::util::Rng;

fn main() -> Result<()> {
    let rt = Runtime::new(Path::new("artifacts"))?;
    let cfg_name = "llama2tiny";
    let cfg = rt.manifest.configs.get(cfg_name).context("config")?.clone();

    let ckpt = Path::new("runs/llama2tiny_base.tnz");
    let gqa = if ckpt.exists() {
        Params::load(ckpt)?
    } else {
        eprintln!("[warn] no checkpoint - using random init");
        init_gqa(&cfg, 42)
    };

    let corpus = Corpus::synthetic(7, 2_000_000);
    let calib_exec = rt.load(&format!("{cfg_name}_calib"))?;
    let mut rng = Rng::new(0);
    let toks = corpus.sample_batch(8, cfg.max_seq, &mut rng);
    let calib = capture_calib(&calib_exec, &gqa, &toks, 1024)?;
    let batches: Vec<_> = corpus
        .val_batches(8, cfg.max_seq)
        .into_iter()
        .take(2)
        .collect();

    let base_exec = rt.load(&format!("{cfg_name}_gqa_prefill"))?;
    let base = evaluate(&base_exec, &gqa, &batches)?;
    println!("original GQA       : loss {:.4}  ppl {:.3}", base.loss, base.ppl);

    let ranks = rt.manifest.sweep_ranks.get(cfg_name).context("ranks")?;
    println!("\n method    | rank | KV kept | loss    | d-loss vs base");
    println!("-----------+------+---------+---------+---------------");
    for &r in ranks {
        for (label, opts) in [
            ("transmla", ConvertOptions::transmla(r)),
            ("mha2mla ", ConvertOptions::mha2mla(r)),
            ("w-pca   ", ConvertOptions {
                pca_mode: PcaMode::Weights,
                ..ConvertOptions::transmla(r)
            }),
        ] {
            let (_t, absorbed, _d) = convert_model(&gqa, &calib, &cfg, &opts)?;
            let exec = rt.load(&format!("{cfg_name}_mla_prefill_r{r}"))?;
            let ev = evaluate(&exec, &absorbed, &batches)?;
            println!(
                " {label} | {r:>4} | {:>6.2}% | {:.4} | +{:.4}",
                100.0 * (1.0 - cfg.compression(r)),
                ev.loss,
                ev.loss - base.loss
            );
        }
    }
    Ok(())
}
