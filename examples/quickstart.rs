//! Quickstart: the whole TransMLA story in one file.
//!
//!   1. load (or init) a GQA byte-LM,
//!   2. capture calibration activations through the AOT calib artifact,
//!   3. convert to absorbed MLA (RoRoPE + BKV + joint PCA + Absorb),
//!   4. generate text from both models and compare decode throughput.
//!
//! Run: `cargo run --release --example quickstart`
//! (expects `make artifacts` to have been run; uses runs/llama2tiny_base.tnz
//! if present, otherwise a random init.)

use anyhow::{Context, Result};
use std::path::Path;
use transmla::backend::SimBackend;
use transmla::config::EngineConfig;
use transmla::convert::{convert_model, ConvertOptions};
use transmla::coordinator::engine::Arch;
use transmla::coordinator::{Engine, ModelBundle, Request};
use transmla::corpus::Corpus;
use transmla::eval::capture_calib;
use transmla::model::{init_gqa, Params};
use transmla::runtime::Runtime;
use transmla::util::Rng;

fn main() -> Result<()> {
    let rt = match Runtime::new(Path::new("artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            // Bare checkout: show the serving loop hermetically instead.
            eprintln!("[quickstart] artifact runtime unavailable ({e:#})");
            eprintln!("[quickstart] demonstrating the engine over SimBackend");
            for (label, be) in [("GQA sim", SimBackend::gqa(8)), ("MLA sim", SimBackend::mla(8, 4))] {
                let mut engine = Engine::new(be, EngineConfig::default());
                let out = engine.generate(vec![Request::from_text(0, "the model ", 32)])?;
                println!(
                    "[{label}] {:5.1} tok/s | {} tokens generated",
                    engine.decode_throughput(),
                    out[0].tokens.len()
                );
            }
            return Ok(());
        }
    };
    let cfg_name = "llama2tiny";
    let cfg = rt.manifest.configs.get(cfg_name).context("config")?.clone();

    // 1. Base GQA model.
    let ckpt = Path::new("runs/llama2tiny_base.tnz");
    let gqa = if ckpt.exists() {
        println!("loading {}", ckpt.display());
        Params::load(ckpt)?
    } else {
        println!("no checkpoint found - using random init (train with `transmla train`)");
        init_gqa(&cfg, 42)
    };

    // 2. Calibration activations (the paper uses WikiText-2; we use a
    //    held-out slice of the synthetic corpus).
    let corpus = Corpus::synthetic(7, 500_000);
    let calib_exec = rt.load(&format!("{cfg_name}_calib"))?;
    let mut rng = Rng::new(0);
    let toks = corpus.sample_batch(8, cfg.max_seq, &mut rng);
    let calib = capture_calib(&calib_exec, &gqa, &toks, 1024)?;

    // 3. TransMLA conversion at the paper's -87.5% compression row.
    let rank = 32;
    let opts = ConvertOptions::transmla(rank);
    let (_train, absorbed, diag) = convert_model(&gqa, &calib, &cfg, &opts)?;
    println!(
        "converted to MLA r={rank}: KV cache -{:.2}%, per-layer alphas {:?}",
        cfg.compression(rank) * 100.0,
        diag.alphas
    );

    // 4. Serve the same prompt through both engines.
    let prompt = "the model compresses the kv cache ";
    for (label, arch, params) in [
        ("GQA ", Arch::Gqa, gqa.clone()),
        ("MLA ", Arch::Mla { rank }, absorbed),
    ] {
        let bundle = ModelBundle::load(&rt, cfg_name, arch, 8, params)?;
        let mut engine = Engine::with_bundle(bundle, EngineConfig::default());
        let out = engine.generate(vec![Request::from_text(0, prompt, 48)])?;
        println!(
            "[{label}] {:5.1} tok/s | {}{}",
            engine.decode_throughput(),
            prompt,
            out[0].text()
        );
    }
    Ok(())
}
