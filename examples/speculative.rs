//! Speculative decoding walkthrough (hermetic — no artifacts needed):
//! the propose/verify/rollback pipeline over the sim backend, the
//! paper's migration story as a speedup story — a low-rank MLA draft
//! proposing tokens its GQA parent verifies in one batched call.
//!
//!   1. run a plain serial-decode engine as the baseline,
//!   2. run the same requests under `speculative:4` with a same-seed
//!      MLA draft (the sim's state chain ignores layout, so the draft
//!      agrees on every greedy token — the perfect-agreement bound),
//!   3. show the completions are bit-identical while the target ran
//!      measurably fewer decode iterations,
//!   4. repeat with a differently-seeded draft to show graceful
//!      degradation: output still exact, acceptance rate just drops.
//!
//! Run: `cargo run --release --example speculative`
//!
//! The same topology from the CLI:
//! `transmla serve --backend sim --policy speculative:4 --draft mla:2`

use anyhow::Result;
use transmla::backend::{SimBackend, SimConfig};
use transmla::config::{EngineConfig, PolicyKind};
use transmla::coordinator::{Engine, Request};

fn requests() -> Vec<Request> {
    [
        "the latent cache compresses the heads",
        "speculation trades one verify call",
        "for several serial decode steps",
    ]
    .iter()
    .enumerate()
    .map(|(i, p)| Request::from_text(i as u64, p, 20))
    .collect()
}

fn spec_engine(draft: SimBackend) -> Result<Engine> {
    let mut e = Engine::new(
        SimBackend::gqa(4),
        EngineConfig {
            policy: PolicyKind::Speculative { k: 4 },
            ..Default::default()
        },
    );
    e.set_draft(Box::new(draft))?;
    Ok(e)
}

fn main() -> Result<()> {
    // 1. Baseline: plain serial decode, one target call per token.
    let mut plain = Engine::new(SimBackend::gqa(4), EngineConfig::default());
    let baseline = plain.generate(requests())?;
    let serial_steps = plain.metrics.counter("decode_steps");
    println!("serial decode: {serial_steps} target iterations");

    // 2. Speculative: a rank-2 MLA draft proposes up to 3 tokens per
    //    slot per iteration; the GQA target verifies the chain in ONE
    //    batched call and rolls back past the first mismatch.
    let mut spec = spec_engine(SimBackend::mla(4, 2))?;
    println!("draft attached: {}", spec.draft_name().unwrap_or("?"));
    let speculated = spec.generate(requests())?;

    // 3. Same tokens, fewer target iterations.
    for (a, b) in baseline.iter().zip(&speculated) {
        assert_eq!(a.tokens, b.tokens, "speculation must not change output");
    }
    let s = spec.spec_stats();
    println!(
        "speculative:4 (same-seed draft): {} target iterations \
         (acceptance {:.0}%, {:.2} tokens/step)",
        s.steps,
        s.acceptance_rate * 100.0,
        s.tokens_per_step,
    );
    assert!(s.steps < serial_steps);

    // 4. A draft that disagrees (different seed) still yields the exact
    //    serial output — rejected proposals are rolled back, the verify
    //    step's own sample always lands — it just accelerates less.
    let mismatched = SimBackend::new(SimConfig { seed: 99, ..SimConfig::mla(4, 2) })?;
    let mut degraded = spec_engine(mismatched)?;
    let tokens = degraded.generate(requests())?;
    for (a, b) in baseline.iter().zip(&tokens) {
        assert_eq!(a.tokens, b.tokens, "a bad draft must only cost speed");
    }
    let d = degraded.spec_stats();
    println!(
        "speculative:4 (mismatched draft): {} target iterations \
         (acceptance {:.0}%, {:.2} tokens/step) — output still exact",
        d.steps,
        d.acceptance_rate * 100.0,
        d.tokens_per_step,
    );
    Ok(())
}
