//! End-to-end serving driver (the repo's headline validation run):
//! loads the trained byte-LM, converts it to MLA at the paper's -92.97%
//! compression, then serves identical batched workloads through the GQA
//! and MLA engines at several context lengths, reporting per-arch decode
//! throughput, latency percentiles, and the measured speedup — the CPU
//! analogue of the paper's Figure 4 / Table 4. Results are recorded in
//! EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example serve_bench [-- ctx_list]`

use anyhow::{Context, Result};
use std::path::Path;
use transmla::config::EngineConfig;
use transmla::convert::{convert_model, ConvertOptions};
use transmla::coordinator::engine::Arch;
use transmla::coordinator::{Engine, ModelBundle, Request};
use transmla::corpus::Corpus;
use transmla::eval::capture_calib;
use transmla::model::{init_gqa, Params};
use transmla::runtime::Runtime;
use transmla::util::Rng;

fn main() -> Result<()> {
    let rt = Runtime::new(Path::new("artifacts"))?;
    let cfg_name = "llama2tiny";
    let cfg = rt.manifest.configs.get(cfg_name).context("config")?.clone();
    let contexts: Vec<usize> = std::env::args()
        .skip(1)
        .filter_map(|a| a.parse().ok())
        .collect();
    let contexts = if contexts.is_empty() {
        vec![128, 256, 512]
    } else {
        contexts
    };

    let ckpt = Path::new("runs/llama2tiny_base.tnz");
    let gqa = if ckpt.exists() {
        Params::load(ckpt)?
    } else {
        eprintln!("[warn] runs/llama2tiny_base.tnz missing - random init");
        init_gqa(&cfg, 42)
    };

    let corpus = Corpus::synthetic(7, 2_000_000);
    let calib_exec = rt.load(&format!("{cfg_name}_calib"))?;
    let mut rng = Rng::new(0);
    let toks = corpus.sample_batch(8, cfg.max_seq, &mut rng);
    let calib = capture_calib(&calib_exec, &gqa, &toks, 1024)?;

    let rank = *rt
        .manifest
        .table1_ranks
        .get(cfg_name)
        .and_then(|r| r.last())
        .context("rank")?;
    let (_t, mla, _d) = convert_model(&gqa, &calib, &cfg, &ConvertOptions::transmla(rank))?;
    println!(
        "serving {} | GQA {} f32/tok/layer vs MLA {} (-{:.2}%)",
        cfg_name,
        cfg.kv_per_token(),
        cfg.mla_kv_per_token(rank),
        cfg.compression(rank) * 100.0
    );
    println!("\n ctx  | arch | tok/s  | p50 lat | p95 lat | decode p50");
    println!("------+------+--------+---------+---------+-----------");

    for &ctx_len in &contexts {
        let mut speedup = (0.0f64, 0.0f64);
        for (label, arch, params) in [
            ("GQA", Arch::Gqa, gqa.clone()),
            ("MLA", Arch::Mla { rank }, mla.clone()),
        ] {
            let suffix = if ctx_len == cfg.max_seq {
                String::new()
            } else {
                format!("_t{ctx_len}")
            };
            let (pname, dname) = match arch {
                Arch::Gqa => (
                    format!("{cfg_name}_gqa_prefill"),
                    format!("{cfg_name}_gqa_decode_b8{suffix}"),
                ),
                Arch::Mla { rank } => (
                    format!("{cfg_name}_mla_prefill_r{rank}"),
                    format!("{cfg_name}_mla_decode_r{rank}_b8{suffix}"),
                ),
            };
            let bundle =
                ModelBundle::load_named(&rt, cfg_name, arch, 8, params, &pname, &dname)?;
            let mut engine = Engine::with_bundle(bundle, EngineConfig::default());
            // Paper protocol: input length == output length == ctx/2.
            let half = ctx_len / 2;
            let mut wl_rng = Rng::new(11);
            for i in 0..24 {
                let start = wl_rng.below(corpus.train.len() - half - 1);
                let prompt: Vec<i32> = corpus.train[start..start + half]
                    .iter()
                    .map(|&b| b as i32)
                    .collect();
                let mut req = Request::new(i, prompt, half);
                req.temperature = 0.7;
                engine.submit(req);
            }
            engine.run_to_completion()?;
            engine.slots_check()?;
            let tps = engine.decode_throughput();
            let lat = engine
                .take_completions()
                .iter()
                .map(|c| c.latency_s)
                .collect::<Vec<_>>();
            let lat = transmla::util::BenchStats::new(lat);
            let dec = engine.metrics.stats("decode_s").context("decode stats")?;
            println!(
                " {ctx_len:>4} | {label}  | {tps:>6.1} | {:>6.2}s | {:>6.2}s | {:>7.2}ms",
                lat.percentile(50.0),
                lat.percentile(95.0),
                dec.percentile(50.0) * 1e3,
            );
            if label == "GQA" {
                speedup.0 = tps;
            } else {
                speedup.1 = tps;
            }
        }
        println!(
            "      -> MLA speedup at ctx {ctx_len}: {:.2}x",
            speedup.1 / speedup.0.max(1e-9)
        );
    }
    Ok(())
}
