//! Bench: end-to-end serving throughput.
//!
//! Two tiers:
//!   * **hermetic** (always runs): the full engine loop over `SimBackend`
//!     for each scheduling policy and both cache layouts, the threaded
//!     worker mode vs the single-threaded sweep over TCP, the
//!     dual-stream prefill/decode overlap on vs off, and the open-loop
//!     traffic harness (seeded bursty trace → goodput under a TTFT SLO
//!     across a policy × cache × backpressure grid) — measures the L3
//!     overhead (scheduling, slot lifecycle, splicing, sampling,
//!     threading) with no artifacts required;
//!   * **artifact-backed** (when `make artifacts` + a real `xla` runtime
//!     are present): GQA vs absorbed-MLA — the measured-CPU counterpart
//!     of the paper's Figure 4 / Table 4.
//!
//! The hermetic results are persisted to `BENCH_serving.json` at the
//! repo root (commit it to record a perf trajectory point).

#[path = "harness/mod.rs"]
mod harness;

use harness::Bench;
use std::path::Path;
use transmla::backend::{SimBackend, SimConfig};
use transmla::config::{CacheKind, EngineConfig, PolicyKind, SloSpec};
use transmla::convert::{convert_model, Calib, ConvertOptions};
use transmla::coordinator::engine::Arch;
use transmla::coordinator::{Engine, ModelBundle, Request};
use transmla::corpus::Corpus;
use transmla::kvcache::QuantKind;
use transmla::model::init_gqa;
use transmla::runtime::Runtime;
use transmla::server::{self, EngineRegistry, RoutePolicy, ServeOpts};
use transmla::tensor::Tensor;
use transmla::util::Rng;
use transmla::workload::{self, ArrivalKind, ReportRow, Trace, TraceSpec};

fn sim_workload(b: &Bench, policy: PolicyKind, label: &str) {
    let n_req = if b.quick { 16 } else { 64 };
    let mean = b.run(&format!("sim_engine_{label}_{n_req}req"), || {
        let mut engine = Engine::new(
            SimBackend::new(SimConfig { capacity: 128, prefill_seq: 128, ..SimConfig::gqa(8) })
                .unwrap(),
            EngineConfig { policy, ..Default::default() },
        );
        for i in 0..n_req {
            engine.submit(Request::from_text(i, "the scheduler balances the memory budget", 24));
        }
        engine.run_to_completion().unwrap();
    });
    let toks = n_req as f64 * 24.0;
    b.report(&format!("sim_engine_{label}_tok_per_s"), toks / mean.max(1e-12), "tok/s");
}

/// One full serve cycle over loopback TCP: start a two-model server
/// with `workers` engine threads, fire a concurrent burst, shut down.
/// The step-rate comparison `workers: 0` (single-threaded sweep) vs
/// `workers: 2` (one thread per engine) is the tentpole measurement.
fn serving_workload(b: &Bench, addr: &'static str, workers: usize, label: &str) {
    let n_req = if b.quick { 8 } else { 24 };
    let max_new = 16usize;
    let mean = b.run(&format!("serve_{label}_{n_req}req"), || {
        let handle = std::thread::spawn(move || {
            let mut reg = EngineRegistry::new(RoutePolicy::RoundRobin);
            for name in ["a", "b"] {
                reg.register(
                    name,
                    Engine::new(
                        SimBackend::new(SimConfig {
                            capacity: 128,
                            prefill_seq: 128,
                            ..SimConfig::gqa(8)
                        })
                        .unwrap(),
                        EngineConfig::default(),
                    ),
                )
                .unwrap();
            }
            server::serve_with(&mut reg, addr, ServeOpts { workers, ..ServeOpts::default() })
                .unwrap();
        });
        // Wait for the listener, then hammer it.
        loop {
            if server::client_line(addr, "{\"cmd\":\"ping\"}").is_ok() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let clients: Vec<_> = (0..n_req)
            .map(|_| {
                std::thread::spawn(move || {
                    server::client_request(addr, "threaded serving workload", max_new)
                        .unwrap();
                })
            })
            .collect();
        for c in clients {
            c.join().unwrap();
        }
        server::client_shutdown(addr).unwrap();
        handle.join().unwrap();
    });
    let toks = (n_req * max_new) as f64;
    b.report(&format!("serve_{label}_tok_per_s"), toks / mean.max(1e-12), "tok/s");
}

/// Speculative decoding off vs on: a same-seed sim draft agrees with
/// the target on every greedy token, so this bounds the best case —
/// emitted tokens per target verify step approaches K while the output
/// stays bit-identical to serial decode.
fn speculative_workload(b: &Bench, k: Option<usize>, label: &str) {
    let n_req = if b.quick { 16 } else { 64 };
    let max_new = 24usize;
    let mut tokens_per_step = 0.0f64;
    let mean = b.run(&format!("sim_engine_{label}_{n_req}req"), || {
        let policy = match k {
            Some(k) => PolicyKind::Speculative { k },
            None => PolicyKind::AdmitFirst,
        };
        let mut engine = Engine::new(
            SimBackend::new(SimConfig { capacity: 128, prefill_seq: 128, ..SimConfig::gqa(8) })
                .unwrap(),
            EngineConfig { policy, ..Default::default() },
        );
        if k.is_some() {
            engine
                .set_draft(Box::new(
                    SimBackend::new(SimConfig {
                        capacity: 128,
                        prefill_seq: 128,
                        ..SimConfig::mla(8, 2)
                    })
                    .unwrap(),
                ))
                .unwrap();
        }
        for i in 0..n_req {
            engine.submit(Request::from_text(
                i as u64,
                "the draft proposes and the target verifies in one call",
                max_new,
            ));
        }
        engine.run_to_completion().unwrap();
        tokens_per_step = engine.spec_stats().tokens_per_step;
    });
    let toks = (n_req * max_new) as f64;
    b.report(&format!("sim_engine_{label}_tok_per_s"), toks / mean.max(1e-12), "tok/s");
    if k.is_some() {
        b.report(&format!("sim_engine_{label}_tok_per_step"), tokens_per_step, "tok/step");
    }
}

/// Chunked prefill with the decode batch on a second stream, vs the
/// serial schedule — same completions (bit-identical by construction),
/// different wall clock.
fn overlap_workload(b: &Bench, overlap: bool, label: &str) {
    let n_req = if b.quick { 12 } else { 48 };
    let max_new = 12usize;
    let prompt = "a long enough prompt that chunked prefill spans several engine \
                  iterations while the active batch keeps decoding";
    let mean = b.run(&format!("sim_engine_{label}_{n_req}req"), || {
        let mut engine = Engine::new(
            SimBackend::new(SimConfig { capacity: 256, prefill_seq: 256, ..SimConfig::gqa(8) })
                .unwrap(),
            EngineConfig {
                policy: PolicyKind::Chunked { chunk_tokens: 16 },
                cache: CacheKind::Paged { block_size: 16, n_blocks: None },
                overlap,
                ..Default::default()
            },
        );
        for i in 0..n_req {
            engine.submit(Request::from_text(i as u64, prompt, max_new));
        }
        engine.run_to_completion().unwrap();
    });
    let toks = (n_req * max_new) as f64;
    b.report(&format!("sim_engine_{label}_tok_per_s"), toks / mean.max(1e-12), "tok/s");
}

/// Quantized KV blocks vs fp32 at an EQUAL `--cache-blocks` byte budget
/// (16 fp32 worst-case blocks): the lossy pools convert the same bytes
/// into more blocks, so the same burst admits in fewer, wider waves.
/// Reports wall-clock throughput plus the first admission wave — the
/// concurrency the byte budget buys under each codec.
fn quant_workload(b: &Bench, quant: QuantKind, label: &str) {
    let n_req = if b.quick { 16 } else { 48 };
    let max_new = 12usize;
    let mut wave = 0usize;
    let mean = b.run(&format!("sim_engine_{label}_{n_req}req"), || {
        let mut engine = Engine::new(
            SimBackend::new(SimConfig { capacity: 128, prefill_seq: 128, ..SimConfig::gqa(16) })
                .unwrap(),
            EngineConfig {
                cache: CacheKind::Paged { block_size: 16, n_blocks: Some(16) },
                kv_quant: quant,
                ..Default::default()
            },
        );
        for i in 0..n_req {
            engine.submit(Request::from_text(
                i as u64,
                "quantized blocks stretch the byte budget",
                max_new,
            ));
        }
        engine.run_to_completion().unwrap();
        wave = engine.admission_log()[0].1.len();
    });
    let toks = (n_req as usize * max_new) as f64;
    b.report(&format!("sim_engine_{label}_tok_per_s"), toks / mean.max(1e-12), "tok/s");
    b.report(
        &format!("sim_engine_{label}_admit_wave"),
        wave as f64,
        "seq (first admission wave at equal byte budget)",
    );
}

/// The open-loop traffic harness end-to-end as a bench: one seeded
/// bursty trace replayed over loopback TCP against a policy × cache ×
/// backpressure server grid, reporting goodput under a TTFT SLO and
/// p95 TTFT — the same [`ReportRow`] rows `transmla workload` emits as
/// JSONL, here denominated into `BENCH_serving.json`.
fn traffic_workload(
    b: &Bench,
    addr: &'static str,
    label: &str,
    policy: PolicyKind,
    cache: CacheKind,
    max_pending: usize,
) {
    let spec = TraceSpec {
        seed: 42,
        arrivals: ArrivalKind::Bursty { burst: 6 },
        rate: if b.quick { 120.0 } else { 240.0 },
        duration_s: 0.5,
        max_new: 12,
        // Prompts sized for the sim engine's 128-token capacity.
        agent_prefix: "agent q: ".to_string(),
        agent_suffix: (4, 16),
        chat_len: (8, 64),
        ..TraceSpec::default()
    };
    let trace = Trace::generate(&spec).unwrap();
    let slo = SloSpec { ttft_ms: Some(100.0), tpot_ms: None };
    let n = trace.events.len();
    let mut row: Option<ReportRow> = None;
    b.run(&format!("workload_{label}_{n}req"), || {
        let handle = std::thread::spawn(move || {
            let e = Engine::new(
                SimBackend::new(SimConfig {
                    capacity: 128,
                    prefill_seq: 128,
                    ..SimConfig::gqa(8)
                })
                .unwrap(),
                EngineConfig { policy, cache, ..Default::default() },
            );
            let mut reg = EngineRegistry::single(e);
            server::serve_with(
                &mut reg,
                addr,
                ServeOpts { max_pending, ..ServeOpts::default() },
            )
            .unwrap();
        });
        loop {
            if server::client_line(addr, "{\"cmd\":\"ping\"}").is_ok() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let result = workload::replay(&trace, addr).unwrap();
        server::client_shutdown(addr).unwrap();
        handle.join().unwrap();
        let tags = [
            ("cache", format!("{cache:?}")),
            ("max_pending", max_pending.to_string()),
            ("policy", format!("{policy:?}")),
        ];
        row = Some(ReportRow::build(label, &tags, slo, &result));
    });
    let row = row.expect("at least one bench iteration");
    b.report(&format!("workload_{label}_goodput"), row.goodput_rps, "SLO-met req/s");
    if let Some(ttft) = &row.ttft {
        b.report(&format!("workload_{label}_ttft_p95_ms"), ttft.p95 * 1e3, "ms");
    }
    b.report(&format!("workload_{label}_shed"), row.shed as f64, "req shed");
}

fn main() {
    let b = Bench::new();

    // -- hermetic tier: policies + layouts over the sim backend ----------
    for (label, policy) in [
        ("admit_first", PolicyKind::AdmitFirst),
        ("decode_first", PolicyKind::DecodeFirst),
        ("hybrid4", PolicyKind::Hybrid { min_free: 4 }),
    ] {
        sim_workload(&b, policy, label);
    }
    for (label, sim) in [
        ("gqa_layout", SimConfig::gqa(8)),
        ("mla_r4_layout", SimConfig::mla(8, 4)),
    ] {
        b.run(&format!("sim_engine_{label}_32req"), || {
            let mut engine = Engine::new(
                SimBackend::new(sim.clone()).unwrap(),
                EngineConfig::default(),
            );
            for i in 0..32 {
                engine.submit(Request::from_text(i, "layout traffic", 16));
            }
            engine.run_to_completion().unwrap();
        });
    }

    // Threaded workers vs the single-threaded sweep, over real loopback
    // TCP (fixed ports; the listening socket never enters TIME_WAIT, so
    // back-to-back iterations rebind cleanly).
    serving_workload(&b, "127.0.0.1:18470", 0, "sweep");
    serving_workload(&b, "127.0.0.1:18471", 2, "workers2");

    // Dual-stream prefill/decode overlap on vs off (chunked policy).
    overlap_workload(&b, false, "chunked_serial");
    overlap_workload(&b, true, "chunked_overlap");

    // Speculative decoding off vs on at k in {2, 4} (same-seed draft:
    // the perfect-agreement upper bound on tokens per verify step).
    speculative_workload(&b, None, "spec_off");
    speculative_workload(&b, Some(2), "spec_k2");
    speculative_workload(&b, Some(4), "spec_k4");

    // Quantized KV blocks vs fp32 at an equal byte budget (the *_admit_
    // wave series is the headline: blocks bought per byte).
    quant_workload(&b, QuantKind::Off, "quant_off");
    quant_workload(&b, QuantKind::Int8, "quant_int8");
    quant_workload(&b, QuantKind::Fp8, "quant_fp8");

    // The open-loop traffic harness: one seeded bursty trace against a
    // policy × cache × backpressure grid — goodput under a 100ms TTFT
    // SLO is the denomination the workload report uses.
    traffic_workload(
        &b, "127.0.0.1:18472", "admit_fixed", PolicyKind::AdmitFirst,
        CacheKind::Fixed, 0,
    );
    traffic_workload(
        &b, "127.0.0.1:18473", "chunked8_paged", PolicyKind::Chunked { chunk_tokens: 8 },
        CacheKind::Paged { block_size: 16, n_blocks: None }, 0,
    );
    traffic_workload(
        &b, "127.0.0.1:18474", "admit_fixed_mp16", PolicyKind::AdmitFirst,
        CacheKind::Fixed, 16,
    );
    traffic_workload(
        &b, "127.0.0.1:18475", "chunked8_paged_mp16",
        PolicyKind::Chunked { chunk_tokens: 8 },
        CacheKind::Paged { block_size: 16, n_blocks: None }, 16,
    );

    // Persist the hermetic tier as the serving perf trajectory (the
    // artifact tier below is environment-dependent, so it stays out).
    b.write_json(
        "serving",
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serving.json"),
    );

    // -- artifact tier: the paper's Figure 4 / Table 4 measurement -------
    let rt = match Runtime::new(Path::new("artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("artifact tier skipped: {e:#} (run `make artifacts`)");
            return;
        }
    };
    let cfg_name = "llama2tiny";
    let cfg = rt.manifest.configs[cfg_name].clone();
    let gqa = init_gqa(&cfg, 0);
    let corpus = Corpus::synthetic(7, 500_000);

    // Random calibration is fine for a throughput bench.
    let mut rng = Rng::new(1);
    let calib = Calib {
        k_pre: (0..cfg.n_layers)
            .map(|_| Tensor::randn(&[512, cfg.kv_dim()], 1.0, &mut rng))
            .collect(),
        v_act: (0..cfg.n_layers)
            .map(|_| Tensor::randn(&[512, cfg.kv_dim()], 0.5, &mut rng))
            .collect(),
        q_pre: (0..cfg.n_layers)
            .map(|_| Tensor::randn(&[512, cfg.q_dim()], 1.0, &mut rng))
            .collect(),
    };
    let rank = 4;
    let (_t, mla, _d) =
        convert_model(&gqa, &calib, &cfg, &ConvertOptions::transmla(rank)).unwrap();

    for ctx in [128usize, 256, 512] {
        let suffix = if ctx == cfg.max_seq {
            String::new()
        } else {
            format!("_t{ctx}")
        };
        let mut tps = (0.0f64, 0.0f64);
        for (label, arch, params) in [
            ("gqa", Arch::Gqa, gqa.clone()),
            ("mla", Arch::Mla { rank }, mla.clone()),
        ] {
            let (pname, dname) = match arch {
                Arch::Gqa => (
                    format!("{cfg_name}_gqa_prefill"),
                    format!("{cfg_name}_gqa_decode_b8{suffix}"),
                ),
                Arch::Mla { rank } => (
                    format!("{cfg_name}_mla_prefill_r{rank}"),
                    format!("{cfg_name}_mla_decode_r{rank}_b8{suffix}"),
                ),
            };
            let bundle = ModelBundle::load_named(
                &rt, cfg_name, arch, 8, params.clone(), &pname, &dname,
            )
            .unwrap();
            let mut engine = Engine::with_bundle(bundle, EngineConfig::default());
            let half = ctx / 2;
            let mut wl = Rng::new(3);
            let n_req = if b.quick { 8 } else { 16 };
            for i in 0..n_req {
                let start = wl.below(corpus.train.len() - half - 1);
                let prompt: Vec<i32> = corpus.train[start..start + half]
                    .iter()
                    .map(|&x| x as i32)
                    .collect();
                engine.submit(Request::new(i, prompt, half));
            }
            engine.run_to_completion().unwrap();
            let t = engine.decode_throughput();
            b.report(&format!("table4_ctx{ctx}_{label}_decode"), t, "tok/s");
            if label == "gqa" {
                tps.0 = t;
            } else {
                tps.1 = t;
            }
        }
        b.report(
            &format!("table4_ctx{ctx}_speedup"),
            tps.1 / tps.0.max(1e-9),
            "x (fig4 shape: grows with ctx)",
        );
    }
}
