//! Bench: end-to-end serving throughput, GQA vs absorbed-MLA — the
//! measured-CPU counterpart of the paper's Figure 4 / Table 4 (the
//! analytical-GPU counterpart lives in `transmla exp table4`).
//!
//! Requires `make artifacts`. Uses a random-init model (throughput does
//! not depend on weight values).

#[path = "harness/mod.rs"]
mod harness;

use harness::Bench;
use std::path::Path;
use transmla::config::EngineConfig;
use transmla::convert::{convert_model, Calib, ConvertOptions};
use transmla::coordinator::engine::Arch;
use transmla::coordinator::{Engine, ModelBundle, Request};
use transmla::corpus::Corpus;
use transmla::model::init_gqa;
use transmla::runtime::Runtime;
use transmla::tensor::Tensor;
use transmla::util::Rng;

fn main() {
    let b = Bench::new();
    let rt = match Runtime::new(Path::new("artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping bench_serving: {e:#} (run `make artifacts`)");
            return;
        }
    };
    let cfg_name = "llama2tiny";
    let cfg = rt.manifest.configs[cfg_name].clone();
    let gqa = init_gqa(&cfg, 0);
    let corpus = Corpus::synthetic(7, 500_000);

    // Random calibration is fine for a throughput bench.
    let mut rng = Rng::new(1);
    let calib = Calib {
        k_pre: (0..cfg.n_layers)
            .map(|_| Tensor::randn(&[512, cfg.kv_dim()], 1.0, &mut rng))
            .collect(),
        v_act: (0..cfg.n_layers)
            .map(|_| Tensor::randn(&[512, cfg.kv_dim()], 0.5, &mut rng))
            .collect(),
        q_pre: (0..cfg.n_layers)
            .map(|_| Tensor::randn(&[512, cfg.q_dim()], 1.0, &mut rng))
            .collect(),
    };
    let rank = 4;
    let (_t, mla, _d) =
        convert_model(&gqa, &calib, &cfg, &ConvertOptions::transmla(rank)).unwrap();

    for ctx in [128usize, 256, 512] {
        let suffix = if ctx == cfg.max_seq {
            String::new()
        } else {
            format!("_t{ctx}")
        };
        let mut tps = (0.0f64, 0.0f64);
        for (label, arch, params) in [
            ("gqa", Arch::Gqa, gqa.clone()),
            ("mla", Arch::Mla { rank }, mla.clone()),
        ] {
            let (pname, dname) = match arch {
                Arch::Gqa => (
                    format!("{cfg_name}_gqa_prefill"),
                    format!("{cfg_name}_gqa_decode_b8{suffix}"),
                ),
                Arch::Mla { rank } => (
                    format!("{cfg_name}_mla_prefill_r{rank}"),
                    format!("{cfg_name}_mla_decode_r{rank}_b8{suffix}"),
                ),
            };
            let bundle = ModelBundle::load_named(
                &rt, cfg_name, arch, 8, params.clone(), &pname, &dname,
            )
            .unwrap();
            let mut engine = Engine::new(bundle, EngineConfig::default());
            let half = ctx / 2;
            let mut wl = Rng::new(3);
            let n_req = if b.quick { 8 } else { 16 };
            for i in 0..n_req {
                let start = wl.below(corpus.train.len() - half - 1);
                let prompt: Vec<i32> = corpus.train[start..start + half]
                    .iter()
                    .map(|&x| x as i32)
                    .collect();
                engine.submit(Request::new(i, prompt, half));
            }
            engine.run_to_completion().unwrap();
            let t = engine.decode_throughput();
            b.report(&format!("table4_ctx{ctx}_{label}_decode"), t, "tok/s");
            if label == "gqa" {
                tps.0 = t;
            } else {
                tps.1 = t;
            }
        }
        b.report(
            &format!("table4_ctx{ctx}_speedup"),
            tps.1 / tps.0.max(1e-9),
            "x (fig4 shape: grows with ctx)",
        );
    }
}
