//! Bench: pure-L3 coordinator paths that must never be the serving
//! bottleneck — slot allocation, cache splicing, sampling, metrics.

#[path = "harness/mod.rs"]
mod harness;

use harness::Bench;
use transmla::backend::SimBackend;
use transmla::config::EngineConfig;
use transmla::coordinator::sampling;
use transmla::coordinator::{Engine, Request};
use transmla::kvcache::{CacheLayout, KvCache, SlotAllocator};
use transmla::tensor::Tensor;
use transmla::util::Rng;

fn main() {
    let b = Bench::new();

    // Full admit -> decode -> complete loop over the hermetic backend:
    // the pure-L3 cost of one serving cycle (scheduler + sequence
    // manager + splice + sampling), no XLA in the path.
    b.run("sim_engine_full_loop_16req", || {
        let mut e = Engine::new(SimBackend::gqa(8), EngineConfig::default());
        for i in 0..16 {
            e.submit(Request::from_text(i, "coordinator hot path", 8));
        }
        e.run_to_completion().unwrap();
    });

    b.run("slot_alloc_release_1k_cycles", || {
        let mut a = SlotAllocator::new(8);
        for i in 0..1000u64 {
            let s = a.alloc(i).unwrap();
            a.release(s).unwrap();
        }
    });

    // Cache splice: move one prefill row into the pool (GQA vs MLA-r4
    // layouts — the byte ratio IS the paper's compression).
    let mut gqa_pool = KvCache::new(CacheLayout::Gqa { g: 8, d: 32 }, 4, 8, 512);
    let gqa_src = vec![
        Tensor::zeros(&[4, 8, 512, 8, 32]),
        Tensor::zeros(&[4, 8, 512, 8, 32]),
    ];
    b.run("splice_gqa_row (16 MiB pool)", || {
        gqa_pool.splice_from(&gqa_src, 3, 5).unwrap();
    });

    let mut mla_pool = KvCache::new(CacheLayout::Mla { r: 4, dr: 32 }, 4, 8, 512);
    let mla_src = vec![
        Tensor::zeros(&[4, 8, 512, 4]),
        Tensor::zeros(&[4, 8, 512, 32]),
    ];
    b.run("splice_mla_r4_row (1.1 MiB pool)", || {
        mla_pool.splice_from(&mla_src, 3, 5).unwrap();
    });

    let mut rng = Rng::new(0);
    let logits: Vec<f32> = (0..256).map(|_| rng.normal_f32(2.0)).collect();
    b.run("sample_greedy_v256_x1k", || {
        for _ in 0..1000 {
            std::hint::black_box(sampling::greedy(&logits));
        }
    });
    b.run("sample_temp0.7_v256_x1k", || {
        for _ in 0..1000 {
            std::hint::black_box(sampling::sample(&logits, 0.7, &mut rng));
        }
    });
}
