//! Shared bench harness (offline stand-in for criterion): warmup +
//! timed iterations + mean/p50/min reporting, with a `--quick` mode used
//! by `cargo bench` in CI-ish runs. Every measurement is also collected
//! so a bench can persist its run as a JSON trajectory file (see
//! [`Bench::write_json`]) — `BENCH_serving.json` at the repo root is the
//! first such trajectory.

use std::cell::RefCell;
use std::time::Instant;
use transmla::json::Json;

#[allow(dead_code)]
enum Entry {
    /// A timed workload: name + mean/p50/min seconds over n iterations.
    Timing { name: String, mean_s: f64, p50_s: f64, min_s: f64, n: usize },
    /// A derived metric (throughput, speedup, ...).
    Metric { name: String, value: f64, unit: String },
}

#[allow(dead_code)]
pub struct Bench {
    pub quick: bool,
    results: RefCell<Vec<Entry>>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

#[allow(dead_code)]
impl Bench {
    pub fn new() -> Self {
        let quick = std::env::args().any(|a| a == "--quick")
            || std::env::var("BENCH_QUICK").is_ok();
        Bench { quick, results: RefCell::new(Vec::new()) }
    }

    /// Run `f` with warmup and report. Returns mean seconds.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> f64 {
        let (warmup, iters) = if self.quick { (1, 3) } else { (2, 10) };
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let p50 = samples[samples.len() / 2];
        println!(
            "bench {name:<44} mean {:>9.3}ms  p50 {:>9.3}ms  min {:>9.3}ms  (n={})",
            mean * 1e3,
            p50 * 1e3,
            samples[0] * 1e3,
            samples.len()
        );
        self.results.borrow_mut().push(Entry::Timing {
            name: name.to_string(),
            mean_s: mean,
            p50_s: p50,
            min_s: samples[0],
            n: samples.len(),
        });
        mean
    }

    /// Report a derived throughput metric.
    pub fn report(&self, name: &str, value: f64, unit: &str) {
        println!("bench {name:<44} {value:>12.2} {unit}");
        self.results.borrow_mut().push(Entry::Metric {
            name: name.to_string(),
            value,
            unit: unit.to_string(),
        });
    }

    /// Persist everything measured so far as a JSON trajectory file:
    /// `{"bench": <name>, "quick": bool, "results": [...]}` where each
    /// result is either a timing (`mean_s`/`p50_s`/`min_s`/`n`) or a
    /// derived metric (`value`/`unit`). Overwrites `path`; commit the
    /// file to record a perf trajectory point.
    pub fn write_json(&self, bench: &str, path: &str) {
        let mut j = Json::obj();
        j.set("bench", Json::Str(bench.to_string()));
        j.set("quick", Json::Bool(self.quick));
        let results = self
            .results
            .borrow()
            .iter()
            .map(|e| {
                let mut r = Json::obj();
                match e {
                    Entry::Timing { name, mean_s, p50_s, min_s, n } => {
                        r.set("name", Json::Str(name.clone()));
                        r.set("mean_s", Json::Num(*mean_s));
                        r.set("p50_s", Json::Num(*p50_s));
                        r.set("min_s", Json::Num(*min_s));
                        r.set("n", Json::Num(*n as f64));
                    }
                    Entry::Metric { name, value, unit } => {
                        r.set("name", Json::Str(name.clone()));
                        r.set("value", Json::Num(*value));
                        r.set("unit", Json::Str(unit.clone()));
                    }
                }
                r
            })
            .collect();
        j.set("results", Json::Arr(results));
        match std::fs::write(path, j.to_string() + "\n") {
            Ok(()) => println!("bench results written to {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}
