//! Shared bench harness (offline stand-in for criterion): warmup +
//! timed iterations + mean/p50/min reporting, with a `--quick` mode used
//! by `cargo bench` in CI-ish runs.

use std::time::Instant;

#[allow(dead_code)]
pub struct Bench {
    pub quick: bool,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

#[allow(dead_code)]
impl Bench {
    pub fn new() -> Self {
        let quick = std::env::args().any(|a| a == "--quick")
            || std::env::var("BENCH_QUICK").is_ok();
        Bench { quick }
    }

    /// Run `f` with warmup and report. Returns mean seconds.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> f64 {
        let (warmup, iters) = if self.quick { (1, 3) } else { (2, 10) };
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let p50 = samples[samples.len() / 2];
        println!(
            "bench {name:<44} mean {:>9.3}ms  p50 {:>9.3}ms  min {:>9.3}ms  (n={})",
            mean * 1e3,
            p50 * 1e3,
            samples[0] * 1e3,
            samples.len()
        );
        mean
    }

    /// Report a derived throughput metric.
    pub fn report(&self, name: &str, value: f64, unit: &str) {
        println!("bench {name:<44} {value:>12.2} {unit}");
    }
}
