//! Bench: paged vs fixed KV-cache under a mixed-context workload at the
//! SAME total byte budget — the paged pool's concurrency and memory
//! utilisation advantage — plus the chunked-vs-monolithic prefill
//! decode-stall (the TPOT tail the StepPlan pipeline bounds) and the raw
//! block-allocator and block-table hot paths. Fully hermetic
//! (SimBackend; no artifacts).

#[path = "harness/mod.rs"]
mod harness;

use harness::Bench;
use transmla::backend::{SimBackend, SimConfig};
use transmla::config::{CacheKind, EngineConfig, PolicyKind};
use transmla::coordinator::{Engine, Request};
use transmla::kvcache::{BlockAllocator, CacheLayout, PagedKvCache};

/// Short + long prompts interleaved: the workload worst-case reservation
/// punishes.
fn submit_mixed(e: &mut Engine, n_req: u64) {
    for i in 0..n_req {
        if i % 4 == 0 {
            // Long: half the context.
            e.submit(Request::new(i, vec![65; 56], 48));
        } else {
            // Short: a few tokens.
            e.submit(Request::from_text(i, "short ask", 8));
        }
    }
}

fn main() {
    let b = Bench::new();
    let n_req = if b.quick { 16 } else { 64 };
    let capacity = 128usize;

    // Equal byte budgets: fixed 4 slots x 128 tokens == paged 32 blocks
    // of 16 tokens (x the same layout bytes/token). The paged engine gets
    // 8 slots — concurrency is bounded by blocks, not worst-case rows.
    let mut waves = (0usize, 0usize);
    for (label, batch, cache) in [
        ("fixed_b4", 4usize, CacheKind::Fixed),
        (
            "paged_b8_bs16",
            8usize,
            CacheKind::Paged { block_size: 16, n_blocks: Some(32) },
        ),
    ] {
        let mean = b.run(&format!("mixed_ctx_{label}_{n_req}req"), || {
            let mut e = Engine::new(
                SimBackend::new(SimConfig {
                    capacity,
                    prefill_seq: capacity,
                    ..SimConfig::gqa(batch)
                })
                .unwrap(),
                EngineConfig { cache, ..Default::default() },
            );
            submit_mixed(&mut e, n_req as u64);
            e.run_to_completion().unwrap();
        });
        let toks: f64 = (0..n_req).map(|i| if i % 4 == 0 { 48.0 } else { 8.0 }).sum();
        b.report(
            &format!("mixed_ctx_{label}_tok_per_s"),
            toks / mean.max(1e-12),
            "tok/s",
        );
        // First admission wave = concurrent sequences at equal bytes.
        let mut e = Engine::new(
            SimBackend::new(SimConfig {
                capacity,
                prefill_seq: capacity,
                ..SimConfig::gqa(batch)
            })
            .unwrap(),
            EngineConfig { cache, ..Default::default() },
        );
        submit_mixed(&mut e, n_req as u64);
        e.run_to_completion().unwrap();
        let wave = e.admission_log()[0].1.len();
        let cs = e.cache_stats();
        b.report(&format!("mixed_ctx_{label}_first_wave"), wave as f64, "seqs");
        b.report(
            &format!("mixed_ctx_{label}_pool_bytes"),
            cs.bytes_total as f64,
            "bytes (equal budgets)",
        );
        if label.starts_with("fixed") {
            waves.0 = wave;
        } else {
            waves.1 = wave;
        }
    }
    b.report(
        "mixed_ctx_paged_over_fixed_concurrency",
        waves.1 as f64 / waves.0.max(1) as f64,
        "x first-wave admissions at equal bytes",
    );

    // Chunked vs monolithic prefill: the TPOT stall a long admission
    // inflicts on active decodes. `decode_stall` is the max number of
    // prefill tokens processed between two consecutive decode steps —
    // one whole prompt under admit-first, one chunk under chunked:N.
    let stall_run = |policy: PolicyKind| -> (usize, usize) {
        let mut e = Engine::new(
            SimBackend::new(SimConfig {
                capacity: 128,
                prefill_seq: 128,
                ..SimConfig::gqa(4)
            })
            .unwrap(),
            EngineConfig { policy, ..Default::default() },
        );
        for i in 0..3 {
            e.submit(Request::from_text(i, "steady decode traffic", 40));
        }
        for _ in 0..5 {
            e.step().unwrap();
        }
        e.submit(Request::new(3, vec![65; 96], 8));
        let (mut max_gap, mut gap) = (0usize, 0usize);
        while !e.is_idle() {
            let pre = e.metrics.counter("prefill_tokens");
            let dec = e.metrics.counter("decode_steps");
            e.step().unwrap();
            gap += (e.metrics.counter("prefill_tokens") - pre) as usize;
            if e.metrics.counter("decode_steps") > dec {
                max_gap = max_gap.max(gap);
                gap = 0;
            }
        }
        (max_gap, e.metrics.counter("decode_steps") as usize)
    };
    for (label, policy) in [
        ("monolithic", PolicyKind::AdmitFirst),
        ("chunked_8", PolicyKind::Chunked { chunk_tokens: 8 }),
    ] {
        let mean = b.run(&format!("long_admit_{label}_wall"), || {
            stall_run(policy);
        });
        let (stall, steps) = stall_run(policy);
        b.report(
            &format!("long_admit_{label}_decode_stall"),
            stall as f64,
            "prefill tokens between decode steps (max)",
        );
        b.report(
            &format!("long_admit_{label}_decode_steps"),
            steps as f64,
            &format!("steps in {mean:.2e}s"),
        );
    }

    // Shared-prefix burst: same 16-block budget, a seed request caches
    // the common prefix, then 8 identical-prompt requests arrive. With
    // the prefix cache each needs 1 block beyond the shared 2, so the
    // whole burst admits at once (slot-capped) instead of blocks-capped —
    // admission concurrency and TTFT both move.
    let prefix_run = |prefix_on: bool| -> (usize, f64) {
        let mut e = Engine::new(
            SimBackend::new(SimConfig {
                capacity: 64,
                prefill_seq: 64,
                ..SimConfig::gqa(8)
            })
            .unwrap(),
            EngineConfig {
                cache: CacheKind::Paged { block_size: 8, n_blocks: Some(16) },
                prefix_cache: prefix_on,
                ..Default::default()
            },
        );
        let prompt: Vec<i32> = (0..17).map(|i| (i * 13 + 7) % 251).collect();
        e.submit(Request::new(100, prompt.clone(), 4));
        e.run_to_completion().unwrap();
        e.take_completions();
        for i in 0..8 {
            e.submit(Request::new(i, prompt.clone(), 4));
        }
        e.run_to_completion().unwrap();
        let wave = e.admission_log()[1].1.len();
        let comps = e.take_completions();
        let ttft = comps.iter().map(|c| c.ttft_s).sum::<f64>() / comps.len() as f64;
        (wave, ttft)
    };
    let mut waves = (0usize, 0usize);
    for (label, on) in [("off", false), ("on", true)] {
        let mean = b.run(&format!("shared_prefix_burst_prefix_{label}_wall"), || {
            prefix_run(on);
        });
        let (wave, ttft) = prefix_run(on);
        b.report(
            &format!("shared_prefix_burst_prefix_{label}_first_wave"),
            wave as f64,
            "seqs admitted in the burst wave (equal 16-block budget)",
        );
        b.report(
            &format!("shared_prefix_burst_prefix_{label}_mean_ttft"),
            ttft,
            &format!("s (wall {mean:.2e}s)"),
        );
        if on {
            waves.1 = wave;
        } else {
            waves.0 = wave;
        }
    }
    b.report(
        "shared_prefix_prefix_over_off_concurrency",
        waves.1 as f64 / waves.0.max(1) as f64,
        "x burst-wave admissions at equal blocks",
    );

    // Raw allocator hot path: alloc/release cycles through the free list.
    b.run("block_alloc_release_1k_cycles", || {
        let mut a = BlockAllocator::new(32);
        for _ in 0..1000 {
            let x = a.alloc().unwrap();
            a.release(x).unwrap();
        }
    });

    // Block-table row addressing: the per-token indirection decode pays.
    let mut pc = PagedKvCache::new(CacheLayout::Mla { r: 4, dr: 32 }, 4, 8, 16, 64).unwrap();
    pc.admit_slot(3, 256, 256).unwrap();
    b.run("paged_row_lookup_x4k", || {
        let mut acc = 0.0f32;
        for pos in 0..256 {
            for l in 0..4 {
                acc += pc.row(0, 3, l, pos).unwrap()[0];
                acc += pc.row(1, 3, l, pos).unwrap()[0];
            }
        }
        std::hint::black_box(acc);
    });
}
