//! Bench: the TransMLA conversion pipeline (RoRoPE rotation, per-layer
//! conversion, whole-model conversion incl. Absorb) — the offline cost a
//! model vendor pays once per model.

#[path = "harness/mod.rs"]
mod harness;

use harness::Bench;
use transmla::config::ModelConfig;
use transmla::convert::{convert_model, rorope_rotation, Calib, ConvertOptions};
use transmla::model::init_gqa;
use transmla::tensor::Tensor;
use transmla::util::Rng;

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "llama2tiny".into(),
        vocab: 256,
        d_model: 256,
        n_heads: 8,
        n_kv_groups: 8,
        head_dim: 32,
        n_layers: 4,
        d_ff: 768,
        max_seq: 512,
        rope_theta: 10000.0,
    }
}

fn fake_calib(cfg: &ModelConfig, n: usize) -> Calib {
    let mut rng = Rng::new(1);
    Calib {
        k_pre: (0..cfg.n_layers)
            .map(|_| Tensor::randn(&[n, cfg.kv_dim()], 1.0, &mut rng))
            .collect(),
        v_act: (0..cfg.n_layers)
            .map(|_| Tensor::randn(&[n, cfg.kv_dim()], 0.4, &mut rng))
            .collect(),
        q_pre: (0..cfg.n_layers)
            .map(|_| Tensor::randn(&[n, cfg.q_dim()], 1.0, &mut rng))
            .collect(),
    }
}

fn main() {
    let b = Bench::new();
    let cfg = cfg();
    let gqa = init_gqa(&cfg, 0);
    let calib = fake_calib(&cfg, 1024);

    for fold in [1usize, 4] {
        b.run(&format!("rorope_rotation_fold{fold}"), || {
            let _ = rorope_rotation(&calib.k_pre[0], &cfg, fold).unwrap();
        });
    }

    for r in [4usize, 32, 128] {
        b.run(&format!("convert_model_r{r}"), || {
            let _ =
                convert_model(&gqa, &calib, &cfg, &ConvertOptions::transmla(r))
                    .unwrap();
        });
    }

    b.run("convert_model_mha2mla_r32", || {
        let _ = convert_model(&gqa, &calib, &cfg, &ConvertOptions::mha2mla(32))
            .unwrap();
    });
}
