//! Bench: Table-1 pipeline costs — calibration capture, conversion at the
//! paper's three compression rows, and held-out evaluation through the
//! compiled prefill. (Quality numbers come from `transmla exp table1`;
//! this measures the machinery.)

#[path = "harness/mod.rs"]
mod harness;

use harness::Bench;
use std::path::Path;
use transmla::convert::{convert_model, ConvertOptions};
use transmla::corpus::Corpus;
use transmla::eval::{capture_calib, evaluate};
use transmla::model::init_gqa;
use transmla::runtime::Runtime;
use transmla::util::Rng;

fn main() {
    let b = Bench::new();
    let rt = match Runtime::new(Path::new("artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping bench_table1: {e:#} (run `make artifacts`)");
            return;
        }
    };
    let cfg = rt.manifest.configs["llama2tiny"].clone();
    let gqa = init_gqa(&cfg, 0);
    let corpus = Corpus::synthetic(7, 500_000);
    let mut rng = Rng::new(0);
    let toks = corpus.sample_batch(8, cfg.max_seq, &mut rng);

    let calib_exec = rt.load("llama2tiny_calib").unwrap();
    let mut calib = None;
    b.run("calib_capture_4096tok", || {
        calib = Some(capture_calib(&calib_exec, &gqa, &toks, 1024).unwrap());
    });
    let calib = calib.unwrap();

    for r in [128usize, 32, 4] {
        b.run(&format!("table1_convert_r{r}"), || {
            let _ = convert_model(&gqa, &calib, &cfg, &ConvertOptions::transmla(r))
                .unwrap();
        });
    }

    let batches: Vec<_> = corpus
        .val_batches(8, cfg.max_seq)
        .into_iter()
        .take(1)
        .collect();
    let exec = rt.load("llama2tiny_gqa_prefill").unwrap();
    b.run("heldout_eval_1batch_4096tok", || {
        let _ = evaluate(&exec, &gqa, &batches).unwrap();
    });
}
