//! Bench: the converter's numerical core (gram, Jacobi eigh, PCA) at the
//! problem sizes the llama2tiny conversion actually hits (g*d = 256,
//! joint space (2g-1)d = 480).

#[path = "harness/mod.rs"]
mod harness;

use harness::Bench;
use transmla::linalg::{eigh_desc, gram, pca_from_gram};
use transmla::tensor::Tensor;
use transmla::util::Rng;

fn main() {
    let b = Bench::new();
    let mut rng = Rng::new(0);

    for d in [16usize, 64, 256, 480] {
        let z = Tensor::randn(&[1024, d], 1.0, &mut rng);
        b.run(&format!("gram_{d}x{d}_n1024"), || {
            let _ = gram(&z);
        });
    }

    for d in [16usize, 64, 128, 480] {
        let z = Tensor::randn(&[256, d], 1.0, &mut rng);
        let c = gram(&z);
        b.run(&format!("jacobi_eigh_{d}"), || {
            let _ = eigh_desc(&c).unwrap();
        });
    }

    let z = Tensor::randn(&[1024, 480], 1.0, &mut rng);
    let c = gram(&z);
    b.run("pca_basis_480_r128", || {
        let _ = pca_from_gram(&c, 128).unwrap();
    });

    let a = Tensor::randn(&[256, 480], 1.0, &mut rng);
    let bm = Tensor::randn(&[480, 256], 1.0, &mut rng);
    b.run("matmul_256x480x256", || {
        let _ = a.matmul(&bm).unwrap();
    });
}
