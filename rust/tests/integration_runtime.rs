//! Integration: artifact loading + HLO execution + decode/prefill
//! consistency across the PJRT boundary. Requires `make artifacts` AND a
//! real `xla` runtime; on a bare checkout every test here skips cleanly
//! (the hermetic engine coverage lives in `integration_engine` /
//! `integration_server` over `SimBackend`).

use std::path::Path;
use transmla::corpus::Corpus;
use transmla::eval::evaluate;
use transmla::model::init_gqa;
use transmla::runtime::{Runtime, Value};
use transmla::util::Rng;

fn runtime() -> Option<Runtime> {
    match Runtime::new(Path::new("artifacts")) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping (artifact runtime unavailable): {e:#}");
            None
        }
    }
}

#[test]
fn manifest_has_expected_inventory() {
    let Some(rt) = runtime() else { return };
    for name in [
        "llama2tiny_gqa_prefill",
        "llama2tiny_gqa_decode_b1",
        "llama2tiny_gqa_decode_b8",
        "llama2tiny_gqa_decode_b8_t128",
        "llama2tiny_mla_decode_r4_b8_t256",
        "llama2tiny_gqa_train",
        "llama2tiny_calib",
        "llama2tiny_merged_prefill",
        "llama2tiny_mla_prefill_r128",
        "llama2tiny_mla_train_r4",
        "smoltiny_gqa_prefill",
    ] {
        assert!(rt.manifest.entries.contains_key(name), "{name} missing");
    }
}

#[test]
fn prefill_runs_and_loss_is_ln_v_at_random_init() {
    let Some(rt) = runtime() else { return };
    let cfg = rt.manifest.configs["llama2tiny"].clone();
    let params = init_gqa(&cfg, 0);
    let exec = rt.load("llama2tiny_gqa_prefill").unwrap();
    let corpus = Corpus::synthetic(3, 200_000);
    let batches = corpus.val_batches(8, cfg.max_seq);
    let ev = evaluate(&exec, &params, &batches[..1]).unwrap();
    assert!((ev.loss - (cfg.vocab as f64).ln()).abs() < 1.0, "{}", ev.loss);
    assert!(ev.ppl.is_finite());
}

#[test]
fn gqa_decode_matches_prefill_logits_through_hlo() {
    let Some(rt) = runtime() else { return };
    let cfg = rt.manifest.configs["llama2tiny"].clone();
    let params = init_gqa(&cfg, 7);
    let prefill = rt.load("llama2tiny_gqa_prefill").unwrap();
    let decode = rt.load("llama2tiny_gqa_decode_b8").unwrap();

    let corpus = Corpus::synthetic(5, 200_000);
    let mut rng = Rng::new(0);
    let t = cfg.max_seq;
    let tokens = corpus.sample_batch(8, t, &mut rng);

    let mut args = params.values();
    args.push(Value::i32_mat(tokens.clone(), &[8, t]));
    let outs = prefill.run(&args).unwrap();
    let (logits_p, kc, vc) = (&outs[0], &outs[1], &outs[2]);

    // Re-decode position `pos` for every row: feeding token[pos] with the
    // prefill cache (entries > pos are stale but masked) must reproduce
    // the prefill logits at that position.
    let pos = 37usize;
    let tok: Vec<i32> = (0..8).map(|b| tokens[b * t + pos]).collect();
    let pos_v: Vec<i32> = vec![pos as i32; 8];
    let mut dargs = params.values();
    dargs.push(Value::i32_vec(tok));
    dargs.push(Value::i32_vec(pos_v));
    dargs.push(Value::F32(kc.clone()));
    dargs.push(Value::F32(vc.clone()));
    let douts = decode.run(&dargs).unwrap();
    let logits_d = &douts[0];

    let v = cfg.vocab;
    let mut worst = 0.0f32;
    for b in 0..8 {
        for i in 0..v {
            let a = logits_p.data[(b * t + pos) * v + i];
            let c = logits_d.data[b * v + i];
            worst = worst.max((a - c).abs());
        }
    }
    assert!(worst < 2e-3, "decode/prefill divergence {worst}");
}

#[test]
fn train_step_executes_and_reduces_loss() {
    let Some(rt) = runtime() else { return };
    let cfg = rt.manifest.configs["llama2tiny"].clone();
    let exec = rt.load("llama2tiny_gqa_train").unwrap();
    let mut trainer =
        transmla::train::Trainer::new(exec, init_gqa(&cfg, 1)).unwrap();
    let corpus = Corpus::synthetic(9, 400_000);
    let rep = trainer.run(&corpus, 8, 2e-3, 4, 0, "test").unwrap();
    assert_eq!(rep.losses.len(), 8);
    let first = rep.losses[0];
    let last = rep.losses[7];
    assert!(last < first, "loss should drop: {first} -> {last}");
    assert!(first < 6.0 && first > 4.0, "ln(256)-ish start: {first}");
}

#[test]
fn value_roundtrip_shapes() {
    let Some(rt) = runtime() else { return };
    // i32 literal roundtrip through an upload.
    let v = Value::i32_mat(vec![1, 2, 3, 4, 5, 6], &[2, 3]);
    let (buf, _lit) = rt.upload_owned(&v).unwrap();
    let lit = buf.to_literal_sync().unwrap();
    let t = transmla::runtime::literal_to_tensor(&lit).unwrap();
    assert_eq!(t.shape, vec![2, 3]);
    assert_eq!(t.data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
}
