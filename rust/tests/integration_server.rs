//! Integration: the TCP JSONL server protocol v2 — multi-model routing,
//! the happy path, error paths (bad JSON, unknown cmd, missing prompt,
//! bad temperature, unknown model), the nested stats shape, and the
//! `models` command — hermetically over `SimBackend` (no artifacts, no
//! XLA runtime).
//!
//! The wire format asserted here is specified in `docs/PROTOCOL.md`; the
//! schema regression tests (`stats_schema_matches_protocol_md`,
//! `models_cmd_schema_matches_protocol_md`,
//! `unknown_request_fields_are_ignored`,
//! `v1_client_line_works_against_a_legacy_single_model_server`) keep
//! that document honest — adding or renaming a field means updating
//! both.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};
use transmla::backend::{BackendSpec, CacheStore, ExecBackend, PrefillOut, SimBackend};
use transmla::config::{CacheKind, EngineConfig, PolicyKind};
use transmla::coordinator::{Engine, Request};
use transmla::json::Json;
use transmla::server::{self, EngineRegistry, RoutePolicy, ServeOpts};
use transmla::tensor::Tensor;
use transmla::Result;

fn wait_for_ping(addr: &str) {
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        if let Ok(j) = server::client_line(addr, "{\"cmd\":\"ping\"}") {
            if j.get("pong").is_some() {
                return;
            }
        }
        assert!(Instant::now() < deadline, "server at {addr} never came up");
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Legacy single-model server: one engine registered as `default`.
fn start_server(addr: &'static str, policy: PolicyKind) -> JoinHandle<()> {
    let handle = std::thread::spawn(move || {
        let e = Engine::new(
            SimBackend::gqa(4),
            EngineConfig { policy, ..Default::default() },
        );
        let mut reg = EngineRegistry::single(e);
        server::serve(&mut reg, addr).unwrap();
    });
    wait_for_ping(addr);
    handle
}

/// Two-model server: a GQA engine and an MLA engine side by side.
fn start_multi_server(addr: &'static str, route: RoutePolicy) -> JoinHandle<()> {
    let handle = std::thread::spawn(move || {
        let mut reg = EngineRegistry::new(route);
        reg.register(
            "gqa-base",
            Engine::new(SimBackend::gqa(4), EngineConfig::default()),
        )
        .unwrap();
        reg.register(
            "mla",
            Engine::new(SimBackend::mla(4, 8), EngineConfig::default()),
        )
        .unwrap();
        server::serve(&mut reg, addr).unwrap();
    });
    wait_for_ping(addr);
    handle
}

fn err_text(j: &Json) -> String {
    j.get("error")
        .and_then(Json::as_str)
        .unwrap_or_else(|| panic!("expected an error reply, got {j:?}"))
        .to_string()
}

/// Per-engine stats object (v1 shape) for `name` out of a v2 snapshot.
fn engine_stats<'a>(stats: &'a Json, name: &str) -> &'a Json {
    stats
        .get("engines")
        .unwrap_or_else(|| panic!("stats missing `engines`: {stats:?}"))
        .get(name)
        .unwrap_or_else(|| panic!("stats missing engine `{name}`: {stats:?}"))
}

#[test]
fn request_stats_shutdown_roundtrip() {
    let addr = "127.0.0.1:18431";
    let handle = start_server(addr, PolicyKind::AdmitFirst);

    let resp = server::client_request(addr, "hello server", 4).unwrap();
    assert!(resp.get("text").is_some(), "{resp:?}");
    assert_eq!(resp.get("prompt_len").and_then(Json::as_usize), Some(12));
    assert_eq!(resp.get("model").and_then(Json::as_str), Some("default"));
    assert_eq!(resp.get("max_new").and_then(Json::as_usize), Some(4));
    assert!(resp.get("latency_s").is_some());
    assert!(resp.get("ttft_s").is_some());
    assert!(resp.get("tpot_s").is_some());

    let stats = server::client_stats(addr).unwrap();
    let eng = engine_stats(&stats, "default");
    assert_eq!(eng.get("policy").and_then(Json::as_str), Some("admit-first"));
    let counters = eng.get("counters").expect("counters object");
    assert_eq!(counters.get("completed").and_then(Json::as_usize), Some(1));
    assert_eq!(counters.get("requests").and_then(Json::as_usize), Some(1));
    // Percentile summaries are present for the latency series.
    for series in ["decode_s", "prefill_s", "latency_s", "queue_s"] {
        let s = eng
            .get(series)
            .unwrap_or_else(|| panic!("stats missing `{series}`: {eng:?}"));
        for key in ["p50", "p95", "p99", "mean", "n"] {
            assert!(s.get(key).is_some(), "`{series}` missing `{key}`");
        }
    }
    // Cache memory accounting rides along in every stats snapshot.
    let cache = eng.get("cache").expect("cache accounting object");
    assert_eq!(cache.get("kind").and_then(Json::as_str), Some("fixed"));
    let total = cache.get("bytes_total").and_then(Json::as_usize).unwrap();
    let in_use = cache.get("bytes_in_use").and_then(Json::as_usize).unwrap();
    assert!(total > 0 && in_use == total, "fixed pool is fully committed");
    // Registry-level facts live in the `server` object.
    let srv = stats.get("server").expect("server object");
    assert_eq!(srv.get("models").and_then(Json::as_usize), Some(1));
    assert_eq!(
        srv.get("routing").and_then(Json::as_str),
        Some("default:default")
    );
    assert_eq!(srv.get("pending").and_then(Json::as_usize), Some(0));

    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}

/// Backward compatibility: a v1 client line (no `model` field) against a
/// legacy single-model invocation gets a completion whose v1 fields are
/// all present with their v1 meanings (`id`, `text`, `prompt_len`,
/// `latency_s`, `queue_s`, `prefill_s`, `ttft_s`, `tpot_s`); v2 only
/// *adds* `model` and `max_new`.
#[test]
fn v1_client_line_works_against_a_legacy_single_model_server() {
    let addr = "127.0.0.1:18438";
    let handle = start_server(addr, PolicyKind::AdmitFirst);

    let resp = server::client_line(addr, "{\"prompt\":\"v1 client\",\"max_new\":3}").unwrap();
    for key in [
        "id", "text", "prompt_len", "latency_s", "queue_s", "prefill_s",
        "ttft_s", "tpot_s",
    ] {
        assert!(resp.get(key).is_some(), "v1 completion field `{key}`: {resp:?}");
    }
    assert_eq!(resp.get("prompt_len").and_then(Json::as_usize), Some(9));
    assert_eq!(resp.get("model").and_then(Json::as_str), Some("default"));
    assert_eq!(resp.get("max_new").and_then(Json::as_usize), Some(3));

    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}

#[test]
fn paged_server_reports_block_accounting() {
    let addr = "127.0.0.1:18434";
    let handle = std::thread::spawn(move || {
        let e = Engine::new(
            SimBackend::gqa(4),
            EngineConfig {
                cache: CacheKind::Paged { block_size: 16, n_blocks: None },
                ..Default::default()
            },
        );
        let mut reg = EngineRegistry::single(e);
        server::serve(&mut reg, addr).unwrap();
    });
    wait_for_ping(addr);

    let resp = server::client_request(addr, "page me", 4).unwrap();
    assert!(resp.get("text").is_some(), "{resp:?}");

    let stats = server::client_stats(addr).unwrap();
    let cache = engine_stats(&stats, "default")
        .get("cache")
        .expect("cache accounting object");
    assert_eq!(cache.get("kind").and_then(Json::as_str), Some("paged"));
    assert!(cache.get("blocks_total").and_then(Json::as_usize).unwrap() > 0);
    // All requests completed, so every block is back on the free list;
    // the pool's resident bytes stay at the configured budget.
    assert_eq!(cache.get("blocks_in_use").and_then(Json::as_usize), Some(0));
    let total = cache.get("bytes_total").and_then(Json::as_usize).unwrap();
    let worst = cache.get("bytes_worst_case").and_then(Json::as_usize).unwrap();
    assert_eq!(total, worst, "default paged pool matches the fixed budget");

    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}

#[test]
fn protocol_error_paths_answer_in_band() {
    let addr = "127.0.0.1:18432";
    let handle = start_server(addr, PolicyKind::Hybrid { min_free: 2 });

    let bad = server::client_line(addr, "{not json at all").unwrap();
    assert!(err_text(&bad).contains("bad json"), "{bad:?}");

    let unknown = server::client_line(addr, "{\"cmd\":\"frobnicate\"}").unwrap();
    assert!(err_text(&unknown).contains("unknown cmd"), "{unknown:?}");

    let missing = server::client_line(addr, "{\"max_new\": 4}").unwrap();
    assert!(err_text(&missing).contains("missing prompt"), "{missing:?}");

    let empty = server::client_line(addr, "{\"prompt\": \"\"}").unwrap();
    assert!(err_text(&empty).contains("missing prompt"), "{empty:?}");

    // Sampling params are validated in-band: a negative, overflowing
    // (1e999 -> inf), or non-numeric temperature never reaches an engine.
    for line in [
        "{\"prompt\":\"x\",\"temperature\":-0.5}",
        "{\"prompt\":\"x\",\"temperature\":1e999}",
        // Finite as f64 but saturates to inf in the engine's f32.
        "{\"prompt\":\"x\",\"temperature\":1e300}",
        "{\"prompt\":\"x\",\"temperature\":\"hot\"}",
    ] {
        let bad_t = server::client_line(addr, line).unwrap();
        assert!(err_text(&bad_t).contains("bad temperature"), "{line} -> {bad_t:?}");
    }
    // A valid in-range temperature still serves.
    let ok_t = server::client_line(
        addr,
        "{\"prompt\":\"warm\",\"max_new\":2,\"temperature\":0.7}",
    )
    .unwrap();
    assert!(ok_t.get("text").is_some(), "{ok_t:?}");

    // Model routing errors are in-band too.
    let bad_m = server::client_line(addr, "{\"prompt\":\"x\",\"model\":7}").unwrap();
    assert!(err_text(&bad_m).contains("bad model"), "{bad_m:?}");
    let unknown_m = server::client_line(addr, "{\"prompt\":\"x\",\"model\":\"nope\"}").unwrap();
    assert!(err_text(&unknown_m).contains("unknown model"), "{unknown_m:?}");

    // The connection survives an error line: errors are answered in-band,
    // then a valid request on the same socket still works.
    let mut stream = TcpStream::connect(addr).unwrap();
    writeln!(stream, "{{\"cmd\":\"nope\"}}").unwrap();
    writeln!(stream, "{{\"prompt\":\"still alive\",\"max_new\":2}}").unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(err_text(&Json::parse(line.trim()).unwrap()).contains("unknown cmd"));
    line.clear();
    reader.read_line(&mut line).unwrap();
    let ok = Json::parse(line.trim()).unwrap();
    assert!(ok.get("text").is_some(), "{ok:?}");

    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}

/// The server edge clamps `max_new` to the engine's remaining capacity
/// for the prompt (a hostile request cannot demand an unserveable
/// reservation) and echoes the effective value on the completion.
#[test]
fn max_new_is_clamped_to_capacity_and_echoed() {
    let addr = "127.0.0.1:18439";
    let handle = start_server(addr, PolicyKind::AdmitFirst);

    // SimBackend::gqa capacity is 64; a 10-byte prompt leaves room for
    // 64 - 10 + 1 = 55 tokens (the final one is write-free).
    let resp = server::client_request(addr, "ten bytes.", 1_000_000).unwrap();
    assert_eq!(resp.get("max_new").and_then(Json::as_usize), Some(55), "{resp:?}");
    assert!(resp.get("text").is_some(), "the clamped request still serves");

    // An in-range ask is untouched.
    let resp = server::client_request(addr, "ten bytes.", 7).unwrap();
    assert_eq!(resp.get("max_new").and_then(Json::as_usize), Some(7));

    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}

#[test]
fn chunked_server_reports_pipeline_queues_and_chunk_metrics() {
    let addr = "127.0.0.1:18435";
    let handle = start_server(addr, PolicyKind::Chunked { chunk_tokens: 4 });

    let resp = server::client_request(addr, "a prompt long enough to chunk", 4).unwrap();
    assert!(resp.get("text").is_some(), "{resp:?}");
    // The TTFT decomposition rides along on every completion.
    assert!(resp.get("queue_s").is_some());
    assert!(resp.get("prefill_s").is_some());

    let stats = server::client_stats(addr).unwrap();
    let eng = engine_stats(&stats, "default");
    assert_eq!(eng.get("policy").and_then(Json::as_str), Some("chunked"));
    // Queue depths of the StepPlan pipeline (drained by now, but present).
    for depth in ["queued", "prefilling", "decoding"] {
        assert_eq!(
            eng.get(depth).and_then(Json::as_usize),
            Some(0),
            "stats missing/nonzero `{depth}`: {eng:?}"
        );
    }
    // Chunk metrics: a 29-char prompt at chunk 4 takes several chunks.
    let counters = eng.get("counters").expect("counters");
    assert!(counters.get("prefill_chunks").and_then(Json::as_usize).unwrap() >= 8);
    let chunk_tokens = eng
        .get("chunk_tokens")
        .unwrap_or_else(|| panic!("stats missing `chunk_tokens`: {eng:?}"));
    assert!(chunk_tokens.get("p50").is_some());

    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}

/// The schema regression test referenced by docs/PROTOCOL.md: every
/// documented completion / stats / cache / prefix field is present on a
/// prefix-enabled paged server, including the v2 nesting (`engines` /
/// `server`) and the prefix-sharing counters.
#[test]
fn stats_schema_matches_protocol_md() {
    let addr = "127.0.0.1:18436";
    let handle = std::thread::spawn(move || {
        let e = Engine::new(
            SimBackend::gqa(4),
            EngineConfig {
                cache: CacheKind::Paged { block_size: 8, n_blocks: None },
                prefix_cache: true,
                ..Default::default()
            },
        );
        let mut reg = EngineRegistry::single(e);
        server::serve(&mut reg, addr).unwrap();
    });
    wait_for_ping(addr);

    // Two same-prefix requests: the second shares the first's cached
    // prefix blocks (requests are sequential, so the ordering is exact).
    let prompt = "the shared prefix lives here";
    let resp = server::client_request(addr, prompt, 4).unwrap();
    // docs/PROTOCOL.md "Completion reply" field list (v2 = v1 + model +
    // max_new).
    for key in [
        "id", "model", "text", "prompt_len", "max_new", "latency_s",
        "queue_s", "prefill_s", "ttft_s", "tpot_s",
    ] {
        assert!(resp.get(key).is_some(), "completion missing `{key}`: {resp:?}");
    }
    server::client_request(addr, prompt, 4).unwrap();

    let stats = server::client_stats(addr).unwrap();
    // docs/PROTOCOL.md "Stats reply" v2 top level: engines + server.
    for key in ["engines", "server"] {
        assert!(stats.get(key).is_some(), "stats missing `{key}`: {stats:?}");
    }
    let srv = stats.get("server").unwrap();
    for key in ["max_pending", "models", "pending", "routing", "shed", "uptime_s"] {
        assert!(srv.get(key).is_some(), "server missing `{key}`: {srv:?}");
    }
    // docs/PROTOCOL.md "shed object": exactly these keys, zeroed on a
    // server that never shed; max_pending 0 = unbounded (the default).
    assert_eq!(srv.get("max_pending").and_then(Json::as_usize), Some(0));
    let shed = srv.get("shed").unwrap();
    let shed_keys: Vec<&str> =
        shed.as_obj().unwrap().keys().map(String::as_str).collect();
    assert_eq!(shed_keys, ["count", "last_retry_after_ms"], "shed object schema");
    assert_eq!(shed.get("count").and_then(Json::as_usize), Some(0));
    // docs/PROTOCOL.md per-engine field list (the v1 stats shape,
    // unchanged — dashboards re-point to `engines.<name>`).
    let eng = engine_stats(&stats, "default");
    for key in [
        "counters", "policy", "decode_tok_per_s", "uptime_s", "queued",
        "prefilling", "decoding", "cache",
    ] {
        assert!(eng.get(key).is_some(), "stats missing `{key}`: {eng:?}");
    }
    // docs/PROTOCOL.md "spec object" field list: present on every stats
    // snapshot (all-zero when speculation is off, as here); the optional
    // `draft` name only appears once a draft model is attached.
    let spec = eng.get("spec").expect("spec object");
    for key in [
        "proposed", "accepted", "steps", "tokens", "acceptance_rate",
        "tokens_per_step",
    ] {
        assert!(spec.get(key).is_some(), "spec missing `{key}`: {spec:?}");
    }
    assert_eq!(spec.get("steps").and_then(Json::as_usize), Some(0));
    assert!(spec.get("draft").is_none(), "no draft attached: {spec:?}");
    let cache = eng.get("cache").unwrap();
    // docs/PROTOCOL.md "cache object" field list.
    for key in [
        "kind", "bytes_total", "bytes_in_use", "bytes_worst_case",
        "block_size", "blocks_total", "blocks_in_use", "blocks_reserved",
        "bytes_deduped", "quant",
    ] {
        assert!(cache.get(key).is_some(), "cache missing `{key}`: {cache:?}");
    }
    // docs/PROTOCOL.md "quant object" field list: always present; with
    // no codec configured (as here) it reports kind "off" at 1.0x.
    let quant = cache.get("quant").expect("quant object");
    for key in ["kind", "bytes_per_token", "bytes_per_token_fp32", "compression"] {
        assert!(quant.get(key).is_some(), "quant missing `{key}`: {quant:?}");
    }
    assert_eq!(quant.get("kind").and_then(Json::as_str), Some("off"));
    assert_eq!(quant.get("compression").and_then(Json::as_f64), Some(1.0));
    assert_eq!(
        quant.get("bytes_per_token").and_then(Json::as_usize),
        quant.get("bytes_per_token_fp32").and_then(Json::as_usize),
    );
    // docs/PROTOCOL.md "prefix object" field list (present only when the
    // prefix cache is enabled — which it is here).
    let prefix = cache.get("prefix").expect("prefix object when enabled");
    for key in [
        "lookups", "hits", "hit_rate", "blocks_shared", "tokens_shared",
        "blocks_cached", "evictions",
    ] {
        assert!(prefix.get(key).is_some(), "prefix missing `{key}`: {prefix:?}");
    }
    // And the second request actually hit the cached prefix.
    assert!(prefix.get("hits").and_then(Json::as_usize).unwrap() >= 1);
    let rate = prefix.get("hit_rate").and_then(Json::as_f64).unwrap();
    assert!(rate > 0.0 && rate <= 1.0, "hit rate {rate} out of range");
    assert!(
        prefix.get("blocks_cached").and_then(Json::as_usize).unwrap() > 0,
        "the prompt's full blocks stay cached"
    );

    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}

/// A speculative engine behind the server: greedy completions match a
/// plain solo engine bit-for-bit, and the `spec` stats object reports a
/// consistent acceptance rate plus the attached draft's name.
#[test]
fn speculative_server_serves_identically_and_reports_spec_stats() {
    let addr = "127.0.0.1:18445";
    let handle = std::thread::spawn(move || {
        let mut e = Engine::new(
            SimBackend::gqa(4),
            EngineConfig {
                policy: PolicyKind::Speculative { k: 4 },
                ..Default::default()
            },
        );
        // Same-seed sim draft: layout-independent state chain, so it
        // agrees with the target on every greedy token.
        e.set_draft(Box::new(SimBackend::mla(4, 2))).unwrap();
        let mut reg = EngineRegistry::single(e);
        server::serve(&mut reg, addr).unwrap();
    });
    wait_for_ping(addr);

    let prompt = "speculative serving path";
    let resp = server::client_request(addr, prompt, 8).unwrap();
    let text = resp.get("text").and_then(Json::as_str).unwrap().to_string();

    // Bit-identical to a plain (non-speculative) solo engine at temp 0.
    let mut solo = Engine::new(SimBackend::gqa(4), EngineConfig::default());
    let comps = solo.generate(vec![Request::from_text(0, prompt, 8)]).unwrap();
    assert_eq!(text, comps[0].text(), "speculative serving diverged");

    let stats = server::client_stats(addr).unwrap();
    let spec = engine_stats(&stats, "default").get("spec").expect("spec object");
    let proposed = spec.get("proposed").and_then(Json::as_usize).unwrap();
    let accepted = spec.get("accepted").and_then(Json::as_usize).unwrap();
    let steps = spec.get("steps").and_then(Json::as_usize).unwrap();
    let tokens = spec.get("tokens").and_then(Json::as_usize).unwrap();
    assert!(steps > 0 && proposed > 0, "{spec:?}");
    assert_eq!(accepted, proposed, "same-seed draft never misses: {spec:?}");
    let rate = spec.get("acceptance_rate").and_then(Json::as_f64).unwrap();
    assert_eq!(rate, 1.0, "{spec:?}");
    let tps = spec.get("tokens_per_step").and_then(Json::as_f64).unwrap();
    assert!((tps - tokens as f64 / steps as f64).abs() < 1e-9, "{spec:?}");
    assert!(tps > 1.0, "{spec:?}");
    assert!(
        spec.get("draft").and_then(Json::as_str).is_some(),
        "draft name rides along once attached: {spec:?}"
    );

    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}

/// docs/PROTOCOL.md "models" command: every hosted engine with its spec.
#[test]
fn models_cmd_schema_matches_protocol_md() {
    let addr = "127.0.0.1:18440";
    let handle = start_multi_server(addr, RoutePolicy::Default("gqa-base".to_string()));

    let resp = server::client_models(addr).unwrap();
    assert_eq!(
        resp.get("routing").and_then(Json::as_str),
        Some("default:gqa-base")
    );
    let models = resp.get("models").and_then(Json::as_arr).expect("models array");
    assert_eq!(models.len(), 2);
    for m in models {
        for key in [
            "name", "backend", "arch", "policy", "cache", "batch", "capacity",
            "max_prompt", "default",
        ] {
            assert!(m.get(key).is_some(), "model entry missing `{key}`: {m:?}");
        }
    }
    // Registration order is preserved; the default flag follows routing.
    assert_eq!(models[0].get("name").and_then(Json::as_str), Some("gqa-base"));
    assert_eq!(models[0].get("arch").and_then(Json::as_str), Some("gqa"));
    assert_eq!(models[0].get("default"), Some(&Json::Bool(true)));
    assert_eq!(models[1].get("name").and_then(Json::as_str), Some("mla"));
    assert_eq!(models[1].get("arch").and_then(Json::as_str), Some("mla"));
    assert_eq!(models[1].get("rank").and_then(Json::as_usize), Some(8));
    assert_eq!(models[1].get("default"), Some(&Json::Bool(false)));

    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}

/// docs/PROTOCOL.md: unknown fields on a request line are ignored
/// (forward compatibility); only unknown *commands* are errors.
#[test]
fn unknown_request_fields_are_ignored() {
    let addr = "127.0.0.1:18437";
    let handle = start_server(addr, PolicyKind::AdmitFirst);

    let resp = server::client_line(
        addr,
        "{\"prompt\":\"hi\",\"max_new\":2,\"stream\":true,\"n\":3}",
    )
    .unwrap();
    assert!(
        resp.get("text").is_some(),
        "unknown request fields must be ignored, got {resp:?}"
    );

    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}

#[test]
fn concurrent_clients_all_complete() {
    let addr = "127.0.0.1:18433";
    let handle = start_server(addr, PolicyKind::DecodeFirst);

    let clients: Vec<JoinHandle<usize>> = (0..6)
        .map(|i| {
            std::thread::spawn(move || {
                let resp =
                    server::client_request(addr, "concurrent load test", 2 + i % 3)
                        .unwrap();
                resp.get("text").and_then(Json::as_str).unwrap().len()
            })
        })
        .collect();
    for c in clients {
        assert!(c.join().unwrap() > 0);
    }

    let stats = server::client_stats(addr).unwrap();
    let counters = engine_stats(&stats, "default").get("counters").expect("counters");
    assert_eq!(counters.get("completed").and_then(Json::as_usize), Some(6));

    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}

/// The acceptance test for multi-model serving: one server hosting a GQA
/// engine and an MLA engine serves an interleaved concurrent burst.
/// Every reply's `model` matches its request's routing (id/model pairs
/// never cross), per-engine stats depths are disjoint and correct, and
/// each engine's completions are bit-identical to a single-engine run of
/// the same requests.
#[test]
fn multi_model_burst_routes_correctly_and_matches_single_engine_runs() {
    let addr = "127.0.0.1:18441";
    let handle = start_multi_server(addr, RoutePolicy::Default("gqa-base".to_string()));

    let prompts = [
        "alpha prompt one",
        "bravo prompt two!",
        "charlie prompt three",
        "delta prompt four??",
    ];
    let max_new = 6;

    // Interleaved concurrent burst: every prompt goes to BOTH models at
    // once, so both engines batch-serve while the other is busy.
    let mut clients = Vec::new();
    for (i, prompt) in prompts.iter().enumerate() {
        for model in ["gqa-base", "mla"] {
            let prompt = prompt.to_string();
            clients.push(std::thread::spawn(move || {
                let resp = server::client_request_model(
                    addr,
                    &prompt,
                    max_new + i % 2, // uneven budgets interleave completion order
                    Some(model),
                )
                .unwrap();
                (model, prompt, resp)
            }));
        }
    }
    let mut by_model: Vec<(String, String)> = Vec::new();
    for c in clients {
        let (model, prompt, resp) = c.join().unwrap();
        // The reply's model always matches the request's routing.
        assert_eq!(
            resp.get("model").and_then(Json::as_str),
            Some(model),
            "reply crossed models: {resp:?}"
        );
        let text = resp.get("text").and_then(Json::as_str).unwrap().to_string();
        by_model.push((format!("{model}:{prompt}"), text));
    }

    // Bit-identical to single-engine runs of the same requests: the sim
    // model is deterministic and greedy decoding ignores the RNG, so a
    // fresh solo engine reproduces each text exactly.
    for (arch, model) in [("gqa", "gqa-base"), ("mla", "mla")] {
        let mut solo = match arch {
            "gqa" => Engine::new(SimBackend::gqa(4), EngineConfig::default()),
            _ => Engine::new(SimBackend::mla(4, 8), EngineConfig::default()),
        };
        let reqs: Vec<Request> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| Request::from_text(i as u64, p, max_new + i % 2))
            .collect();
        let comps = solo.generate(reqs).unwrap();
        for (i, prompt) in prompts.iter().enumerate() {
            let served = by_model
                .iter()
                .find(|(k, _)| k == &format!("{model}:{prompt}"))
                .map(|(_, t)| t.clone())
                .unwrap_or_else(|| panic!("no reply for {model}:{prompt}"));
            assert_eq!(
                served,
                comps[i].text(),
                "{model} completion for `{prompt}` differs from a solo run"
            );
        }
    }

    // Per-engine stats are disjoint and correct: each engine saw exactly
    // its own four requests, and the pipelines drained.
    let stats = server::client_stats(addr).unwrap();
    for name in ["gqa-base", "mla"] {
        let eng = engine_stats(&stats, name);
        let counters = eng.get("counters").expect("counters");
        assert_eq!(
            counters.get("requests").and_then(Json::as_usize),
            Some(prompts.len()),
            "{name} requests"
        );
        assert_eq!(
            counters.get("completed").and_then(Json::as_usize),
            Some(prompts.len()),
            "{name} completed"
        );
        for depth in ["queued", "prefilling", "decoding"] {
            assert_eq!(eng.get(depth).and_then(Json::as_usize), Some(0), "{name} {depth}");
        }
    }
    assert_eq!(
        stats
            .get("server")
            .and_then(|s| s.get("pending"))
            .and_then(Json::as_usize),
        Some(0)
    );

    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}

/// Requests without a `model` field follow the registry's routing
/// policy: `default:<name>` pins them, `round-robin` rotates through
/// the engines in registration order.
#[test]
fn unrouted_requests_follow_the_routing_policy() {
    // default:<name> pins unrouted requests to that engine.
    let addr = "127.0.0.1:18442";
    let handle = start_multi_server(addr, RoutePolicy::Default("mla".to_string()));
    let resp = server::client_request(addr, "no model field", 3).unwrap();
    assert_eq!(resp.get("model").and_then(Json::as_str), Some("mla"), "{resp:?}");
    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();

    // round-robin alternates (requests sent sequentially, so the
    // rotation order is deterministic).
    let addr = "127.0.0.1:18443";
    let handle = start_multi_server(addr, RoutePolicy::RoundRobin);
    let picks: Vec<String> = (0..4)
        .map(|_| {
            server::client_request(addr, "rotate me", 2)
                .unwrap()
                .get("model")
                .and_then(Json::as_str)
                .unwrap()
                .to_string()
        })
        .collect();
    assert_eq!(picks, vec!["gqa-base", "mla", "gqa-base", "mla"]);
    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}

/// [`SimBackend`] with a fixed per-call service delay: a deterministic
/// "slow model" that keeps requests in flight long enough for a bounded
/// pending queue to fill under test.
struct SlowBackend {
    inner: SimBackend,
    delay: Duration,
}

impl ExecBackend for SlowBackend {
    fn spec(&self) -> &BackendSpec {
        self.inner.spec()
    }

    fn prefill(&mut self, tokens: &[i32], rows: usize) -> Result<PrefillOut> {
        std::thread::sleep(self.delay);
        self.inner.prefill(tokens, rows)
    }

    fn prefill_chunk(
        &mut self,
        tokens: &[i32],
        slot: usize,
        start_pos: usize,
        cache: &mut CacheStore,
    ) -> Result<Tensor> {
        std::thread::sleep(self.delay);
        self.inner.prefill_chunk(tokens, slot, start_pos, cache)
    }

    fn decode(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
        active: &[bool],
        cache: &mut CacheStore,
    ) -> Result<Tensor> {
        std::thread::sleep(self.delay);
        self.inner.decode(tokens, pos, active, cache)
    }
}

/// Admission backpressure (docs/PROTOCOL.md `overloaded`): with
/// `max_pending: 1` and a slow engine, a request arriving while one is
/// in flight gets the in-band shed reply with exactly the documented
/// keys, the `stats.server.shed` counter increments — and, the sibling
/// of the disconnect test above, the shed path leaves no pending-map
/// entry behind: `pending` returns to 0 and the server keeps serving.
#[test]
fn overloaded_requests_are_shed_in_band_without_leaking_pending() {
    let addr = "127.0.0.1:18446";
    let handle = std::thread::spawn(move || {
        let slow =
            SlowBackend { inner: SimBackend::gqa(4), delay: Duration::from_millis(5) };
        let mut reg = EngineRegistry::single(Engine::new(slow, EngineConfig::default()));
        server::serve_with(
            &mut reg,
            addr,
            ServeOpts { max_pending: 1, ..ServeOpts::default() },
        )
        .unwrap();
    });
    wait_for_ping(addr);

    // A long request holds the single admission slot for ~200ms (40
    // decode steps x 5ms)...
    let holder = std::thread::spawn(move || {
        server::client_request(addr, "hold the slot", 40).unwrap()
    });
    std::thread::sleep(Duration::from_millis(40));
    // ...so the next arrival finds the pending queue full and is shed.
    let resp = server::client_request(addr, "shed me", 2).unwrap();
    assert_eq!(
        resp.get("error").and_then(Json::as_str),
        Some("overloaded"),
        "{resp:?}"
    );
    let retry = resp.get("retry_after_ms").and_then(Json::as_f64).unwrap();
    assert!(retry >= 1.0, "{resp:?}");
    // The documented shed-reply schema is exactly these two keys.
    let keys: Vec<&str> = resp.as_obj().unwrap().keys().map(String::as_str).collect();
    assert_eq!(keys, ["error", "retry_after_ms"], "shed reply schema");

    assert!(holder.join().unwrap().get("text").is_some(), "held request completes");

    let stats = server::client_stats(addr).unwrap();
    let srv = stats.get("server").unwrap();
    assert_eq!(srv.get("max_pending").and_then(Json::as_usize), Some(1));
    let shed = srv.get("shed").unwrap();
    assert_eq!(shed.get("count").and_then(Json::as_usize), Some(1), "{shed:?}");
    assert!(
        shed.get("last_retry_after_ms").and_then(Json::as_f64).unwrap() >= 1.0,
        "{shed:?}"
    );
    assert_eq!(
        srv.get("pending").and_then(Json::as_usize),
        Some(0),
        "shed path leaked a pending entry"
    );

    // And the loop still serves after shedding.
    let ok = server::client_request(addr, "after the storm", 2).unwrap();
    assert!(ok.get("text").is_some(), "{ok:?}");

    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}

/// A client that disconnects mid-request must not wedge the engine loop
/// or leak its pending reply entry: the completion's send fails
/// silently, the entry is removed, and the server keeps serving.
#[test]
fn client_disconnect_mid_request_does_not_wedge_or_leak() {
    let addr = "127.0.0.1:18444";
    let handle = start_server(addr, PolicyKind::AdmitFirst);

    // Send a request and slam the connection before the reply arrives.
    {
        let mut stream = TcpStream::connect(addr).unwrap();
        writeln!(stream, "{{\"prompt\":\"abandon me\",\"max_new\":2}}").unwrap();
        stream.flush().unwrap();
        // Drop without reading: the reply channel's receiver dies with
        // the handler thread.
    }

    // The loop still serves: a well-behaved request completes normally.
    let resp = server::client_request(addr, "still serving", 8).unwrap();
    assert!(resp.get("text").is_some(), "{resp:?}");

    // Both requests complete (the abandoned one's delivery just fails
    // silently) and no pending entry is left behind. Poll briefly: the
    // abandoned request races the well-behaved one.
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let stats = server::client_stats(addr).unwrap();
        let completed = engine_stats(&stats, "default")
            .get("counters")
            .and_then(|c| c.get("completed"))
            .and_then(Json::as_usize)
            .unwrap_or(0);
        let pending = stats
            .get("server")
            .and_then(|s| s.get("pending"))
            .and_then(Json::as_usize)
            .unwrap();
        if completed == 2 && pending == 0 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "abandoned request wedged or leaked: completed {completed}, \
             pending {pending}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }

    // Shutdown still drains cleanly — the loop is not wedged.
    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}
