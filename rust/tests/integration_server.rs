//! Integration: the TCP JSONL server protocol — happy path, error paths
//! (bad JSON, unknown cmd, missing prompt), and the stats command —
//! hermetically over `SimBackend` (no artifacts, no XLA runtime).
//!
//! The wire format asserted here is specified in `docs/PROTOCOL.md`; the
//! schema regression tests (`stats_schema_matches_protocol_md`,
//! `unknown_request_fields_are_ignored`) keep that document honest —
//! adding or renaming a field means updating both.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};
use transmla::backend::SimBackend;
use transmla::config::{CacheKind, EngineConfig, PolicyKind};
use transmla::coordinator::Engine;
use transmla::json::Json;
use transmla::server;

fn start_server(addr: &'static str, policy: PolicyKind) -> JoinHandle<()> {
    let handle = std::thread::spawn(move || {
        let mut e = Engine::new(
            SimBackend::gqa(4),
            EngineConfig { policy, ..Default::default() },
        );
        server::serve(&mut e, addr).unwrap();
    });
    // Wait until the listener answers pings.
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        if let Ok(j) = server::client_line(addr, "{\"cmd\":\"ping\"}") {
            if j.get("pong").is_some() {
                return handle;
            }
        }
        assert!(Instant::now() < deadline, "server at {addr} never came up");
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn err_text(j: &Json) -> String {
    j.get("error")
        .and_then(Json::as_str)
        .unwrap_or_else(|| panic!("expected an error reply, got {j:?}"))
        .to_string()
}

#[test]
fn request_stats_shutdown_roundtrip() {
    let addr = "127.0.0.1:18431";
    let handle = start_server(addr, PolicyKind::AdmitFirst);

    let resp = server::client_request(addr, "hello server", 4).unwrap();
    assert!(resp.get("text").is_some(), "{resp:?}");
    assert_eq!(resp.get("prompt_len").and_then(Json::as_usize), Some(12));
    assert!(resp.get("latency_s").is_some());
    assert!(resp.get("ttft_s").is_some());
    assert!(resp.get("tpot_s").is_some());

    let stats = server::client_stats(addr).unwrap();
    assert_eq!(
        stats.get("policy").and_then(Json::as_str),
        Some("admit-first")
    );
    let counters = stats.get("counters").expect("counters object");
    assert_eq!(counters.get("completed").and_then(Json::as_usize), Some(1));
    assert_eq!(counters.get("requests").and_then(Json::as_usize), Some(1));
    // Percentile summaries are present for the latency series.
    for series in ["decode_s", "prefill_s", "latency_s", "queue_s"] {
        let s = stats
            .get(series)
            .unwrap_or_else(|| panic!("stats missing `{series}`: {stats:?}"));
        for key in ["p50", "p95", "p99", "mean", "n"] {
            assert!(s.get(key).is_some(), "`{series}` missing `{key}`");
        }
    }
    // Cache memory accounting rides along in every stats snapshot.
    let cache = stats.get("cache").expect("cache accounting object");
    assert_eq!(cache.get("kind").and_then(Json::as_str), Some("fixed"));
    let total = cache.get("bytes_total").and_then(Json::as_usize).unwrap();
    let in_use = cache.get("bytes_in_use").and_then(Json::as_usize).unwrap();
    assert!(total > 0 && in_use == total, "fixed pool is fully committed");

    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}

#[test]
fn paged_server_reports_block_accounting() {
    let addr = "127.0.0.1:18434";
    let handle = std::thread::spawn(move || {
        let mut e = Engine::new(
            SimBackend::gqa(4),
            EngineConfig {
                cache: CacheKind::Paged { block_size: 16, n_blocks: None },
                ..Default::default()
            },
        );
        server::serve(&mut e, addr).unwrap();
    });
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        if let Ok(j) = server::client_line(addr, "{\"cmd\":\"ping\"}") {
            if j.get("pong").is_some() {
                break;
            }
        }
        assert!(Instant::now() < deadline, "server at {addr} never came up");
        std::thread::sleep(Duration::from_millis(20));
    }

    let resp = server::client_request(addr, "page me", 4).unwrap();
    assert!(resp.get("text").is_some(), "{resp:?}");

    let stats = server::client_stats(addr).unwrap();
    let cache = stats.get("cache").expect("cache accounting object");
    assert_eq!(cache.get("kind").and_then(Json::as_str), Some("paged"));
    assert!(cache.get("blocks_total").and_then(Json::as_usize).unwrap() > 0);
    // All requests completed, so every block is back on the free list;
    // the pool's resident bytes stay at the configured budget.
    assert_eq!(cache.get("blocks_in_use").and_then(Json::as_usize), Some(0));
    let total = cache.get("bytes_total").and_then(Json::as_usize).unwrap();
    let worst = cache.get("bytes_worst_case").and_then(Json::as_usize).unwrap();
    assert_eq!(total, worst, "default paged pool matches the fixed budget");

    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}

#[test]
fn protocol_error_paths_answer_in_band() {
    let addr = "127.0.0.1:18432";
    let handle = start_server(addr, PolicyKind::Hybrid { min_free: 2 });

    let bad = server::client_line(addr, "{not json at all").unwrap();
    assert!(err_text(&bad).contains("bad json"), "{bad:?}");

    let unknown = server::client_line(addr, "{\"cmd\":\"frobnicate\"}").unwrap();
    assert!(err_text(&unknown).contains("unknown cmd"), "{unknown:?}");

    let missing = server::client_line(addr, "{\"max_new\": 4}").unwrap();
    assert!(err_text(&missing).contains("missing prompt"), "{missing:?}");

    let empty = server::client_line(addr, "{\"prompt\": \"\"}").unwrap();
    assert!(err_text(&empty).contains("missing prompt"), "{empty:?}");

    // The connection survives an error line: errors are answered in-band,
    // then a valid request on the same socket still works.
    let mut stream = TcpStream::connect(addr).unwrap();
    writeln!(stream, "{{\"cmd\":\"nope\"}}").unwrap();
    writeln!(stream, "{{\"prompt\":\"still alive\",\"max_new\":2}}").unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(err_text(&Json::parse(line.trim()).unwrap()).contains("unknown cmd"));
    line.clear();
    reader.read_line(&mut line).unwrap();
    let ok = Json::parse(line.trim()).unwrap();
    assert!(ok.get("text").is_some(), "{ok:?}");

    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}

#[test]
fn chunked_server_reports_pipeline_queues_and_chunk_metrics() {
    let addr = "127.0.0.1:18435";
    let handle = start_server(addr, PolicyKind::Chunked { chunk_tokens: 4 });

    let resp = server::client_request(addr, "a prompt long enough to chunk", 4).unwrap();
    assert!(resp.get("text").is_some(), "{resp:?}");
    // The TTFT decomposition rides along on every completion.
    assert!(resp.get("queue_s").is_some());
    assert!(resp.get("prefill_s").is_some());

    let stats = server::client_stats(addr).unwrap();
    assert_eq!(stats.get("policy").and_then(Json::as_str), Some("chunked"));
    // Queue depths of the StepPlan pipeline (drained by now, but present).
    for depth in ["queued", "prefilling", "decoding"] {
        assert_eq!(
            stats.get(depth).and_then(Json::as_usize),
            Some(0),
            "stats missing/nonzero `{depth}`: {stats:?}"
        );
    }
    // Chunk metrics: a 29-char prompt at chunk 4 takes several chunks.
    let counters = stats.get("counters").expect("counters");
    assert!(counters.get("prefill_chunks").and_then(Json::as_usize).unwrap() >= 8);
    let chunk_tokens = stats
        .get("chunk_tokens")
        .unwrap_or_else(|| panic!("stats missing `chunk_tokens`: {stats:?}"));
    assert!(chunk_tokens.get("p50").is_some());

    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}

/// The schema regression test referenced by docs/PROTOCOL.md: every
/// documented completion / stats / cache / prefix field is present on a
/// prefix-enabled paged server, including the prefix-sharing counters.
#[test]
fn stats_schema_matches_protocol_md() {
    let addr = "127.0.0.1:18436";
    let handle = std::thread::spawn(move || {
        let mut e = Engine::new(
            SimBackend::gqa(4),
            EngineConfig {
                cache: CacheKind::Paged { block_size: 8, n_blocks: None },
                prefix_cache: true,
                ..Default::default()
            },
        );
        server::serve(&mut e, addr).unwrap();
    });
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        if let Ok(j) = server::client_line(addr, "{\"cmd\":\"ping\"}") {
            if j.get("pong").is_some() {
                break;
            }
        }
        assert!(Instant::now() < deadline, "server at {addr} never came up");
        std::thread::sleep(Duration::from_millis(20));
    }

    // Two same-prefix requests: the second shares the first's cached
    // prefix blocks (requests are sequential, so the ordering is exact).
    let prompt = "the shared prefix lives here";
    let resp = server::client_request(addr, prompt, 4).unwrap();
    // docs/PROTOCOL.md "Completion reply" field list.
    for key in [
        "id", "text", "prompt_len", "latency_s", "queue_s", "prefill_s",
        "ttft_s", "tpot_s",
    ] {
        assert!(resp.get(key).is_some(), "completion missing `{key}`: {resp:?}");
    }
    server::client_request(addr, prompt, 4).unwrap();

    let stats = server::client_stats(addr).unwrap();
    // docs/PROTOCOL.md "Stats reply" top-level field list.
    for key in [
        "counters", "policy", "decode_tok_per_s", "uptime_s", "queued",
        "prefilling", "decoding", "cache",
    ] {
        assert!(stats.get(key).is_some(), "stats missing `{key}`: {stats:?}");
    }
    let cache = stats.get("cache").unwrap();
    // docs/PROTOCOL.md "cache object" field list.
    for key in [
        "kind", "bytes_total", "bytes_in_use", "bytes_worst_case",
        "block_size", "blocks_total", "blocks_in_use", "blocks_reserved",
        "bytes_deduped",
    ] {
        assert!(cache.get(key).is_some(), "cache missing `{key}`: {cache:?}");
    }
    // docs/PROTOCOL.md "prefix object" field list (present only when the
    // prefix cache is enabled — which it is here).
    let prefix = cache.get("prefix").expect("prefix object when enabled");
    for key in [
        "lookups", "hits", "hit_rate", "blocks_shared", "tokens_shared",
        "blocks_cached", "evictions",
    ] {
        assert!(prefix.get(key).is_some(), "prefix missing `{key}`: {prefix:?}");
    }
    // And the second request actually hit the cached prefix.
    assert!(prefix.get("hits").and_then(Json::as_usize).unwrap() >= 1);
    let rate = prefix.get("hit_rate").and_then(Json::as_f64).unwrap();
    assert!(rate > 0.0 && rate <= 1.0, "hit rate {rate} out of range");
    assert!(
        prefix.get("blocks_cached").and_then(Json::as_usize).unwrap() > 0,
        "the prompt's full blocks stay cached"
    );

    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}

/// docs/PROTOCOL.md: unknown fields on a request line are ignored
/// (forward compatibility); only unknown *commands* are errors.
#[test]
fn unknown_request_fields_are_ignored() {
    let addr = "127.0.0.1:18437";
    let handle = start_server(addr, PolicyKind::AdmitFirst);

    let resp = server::client_line(
        addr,
        "{\"prompt\":\"hi\",\"max_new\":2,\"stream\":true,\"n\":3}",
    )
    .unwrap();
    assert!(
        resp.get("text").is_some(),
        "unknown request fields must be ignored, got {resp:?}"
    );

    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}

#[test]
fn concurrent_clients_all_complete() {
    let addr = "127.0.0.1:18433";
    let handle = start_server(addr, PolicyKind::DecodeFirst);

    let clients: Vec<JoinHandle<usize>> = (0..6)
        .map(|i| {
            std::thread::spawn(move || {
                let resp =
                    server::client_request(addr, "concurrent load test", 2 + i % 3)
                        .unwrap();
                resp.get("text").and_then(Json::as_str).unwrap().len()
            })
        })
        .collect();
    for c in clients {
        assert!(c.join().unwrap() > 0);
    }

    let stats = server::client_stats(addr).unwrap();
    let counters = stats.get("counters").expect("counters");
    assert_eq!(counters.get("completed").and_then(Json::as_usize), Some(6));

    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}
