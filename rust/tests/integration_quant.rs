//! Acceptance tests for quantized KV blocks (`--kv-quant`): at an equal
//! `--cache-blocks` *byte* budget the int8 codec must admit >= 1.8x the
//! concurrent sequences of the fp32 pool — on both cache layouts — while
//! greedy completions stay bit-identical to fp32 on the sim geometry
//! (the sim's base-100 cache encoding is int8-exact; see
//! `kvcache::quant` and `backend::sim`).

use transmla::backend::{SimBackend, SimConfig};
use transmla::config::{CacheKind, EngineConfig, PolicyKind};
use transmla::coordinator::{Engine, Request};
use transmla::kvcache::QuantKind;

const CAPACITY: usize = 64;
const BLOCK_SIZE: usize = 16;
/// Byte budget: 4 fp32 worst-case blocks — exactly one full-capacity
/// sequence, the smallest legal pool, so the admission headroom below
/// comes purely from the codec.
const BUDGET_BLOCKS: usize = 4;
const N_REQS: u64 = 16;

fn quant_engine(mla: bool, quant: QuantKind, seed: u64) -> Engine {
    let base = if mla { SimConfig::mla(16, 4) } else { SimConfig::gqa(16) };
    Engine::new(
        SimBackend::new(SimConfig {
            capacity: CAPACITY,
            prefill_seq: CAPACITY,
            seed,
            ..base
        })
        .unwrap(),
        EngineConfig {
            cache: CacheKind::Paged {
                block_size: BLOCK_SIZE,
                n_blocks: Some(BUDGET_BLOCKS),
            },
            kv_quant: quant,
            seed,
            ..Default::default()
        },
    )
}

/// Distinct short prompts: 8 tokens + 8 new -> bounded demand 15 tokens
/// = one block per sequence, so the admission wave counts blocks.
fn burst() -> Vec<Request> {
    (0..N_REQS)
        .map(|i| {
            let prompt: Vec<i32> =
                (0..8).map(|j| ((i as i32 + 1) * 31 + j * 7) % 250).collect();
            Request::new(i, prompt, 8)
        })
        .collect()
}

/// Run the burst, returning (first admission wave, completions by id).
fn run_burst(e: &mut Engine) -> (usize, Vec<(u64, Vec<i32>)>) {
    for r in burst() {
        e.submit(r);
    }
    e.run_to_completion().unwrap();
    e.slots_check().unwrap();
    let mut comps: Vec<(u64, Vec<i32>)> = e
        .take_completions()
        .into_iter()
        .map(|c| (c.id, c.tokens))
        .collect();
    comps.sort_by_key(|(id, _)| *id);
    let wave = e.admission_log()[0].1.len();
    (wave, comps)
}

fn admission_ratio(mla: bool) -> f64 {
    let mut off = quant_engine(mla, QuantKind::Off, 7);
    let mut int8 = quant_engine(mla, QuantKind::Int8, 7);
    // Equal byte budget: the encoded pool may hold more blocks but never
    // more bytes than the fp32 pool it was budgeted against.
    let off_bytes = off.cache_stats().bytes_total;
    let int8_bytes = int8.cache_stats().bytes_total;
    assert!(
        int8_bytes <= off_bytes,
        "int8 pool overruns the byte budget: {int8_bytes} > {off_bytes}"
    );
    let (off_wave, off_comps) = run_burst(&mut off);
    let (int8_wave, int8_comps) = run_burst(&mut int8);
    assert_eq!(off_comps.len(), N_REQS as usize);
    assert_eq!(int8_comps.len(), N_REQS as usize);
    // Greedy completions are bit-identical: int8's per-row scale keeps
    // every base-100 cache digit exact on the sim geometry.
    assert_eq!(off_comps, int8_comps, "int8 must not change greedy output");
    assert!(off_wave > 0);
    int8_wave as f64 / off_wave as f64
}

#[test]
fn int8_admits_1_8x_sequences_at_equal_byte_budget_gqa() {
    let ratio = admission_ratio(false);
    // GQA(g=2,d=8): 128 -> 40 bytes/token/layer, 4 budget blocks -> 12
    // encoded blocks: a 3x admission wave.
    assert!(ratio >= 1.8, "GQA admission ratio {ratio} < 1.8");
}

#[test]
fn int8_admits_1_8x_sequences_at_equal_byte_budget_mla() {
    let ratio = admission_ratio(true);
    // MLA(r=4,dr=8): 96 -> 40 bytes/token (both layers), 4 budget blocks
    // -> 9 encoded blocks: a 2.25x admission wave.
    assert!(ratio >= 1.8, "MLA admission ratio {ratio} < 1.8");
}

#[test]
fn quant_stats_report_the_codec_and_compression() {
    let e = quant_engine(false, QuantKind::Int8, 0);
    let cs = e.cache_stats();
    let q = cs.quant;
    assert_eq!(q.kind, "int8");
    // GQA(2,8), L=2: fp32 2*16*4*2 = 256 B/token, int8 2*(16+4)*2 = 80.
    assert_eq!(q.bytes_per_token_fp32, 256);
    assert_eq!(q.bytes_per_token, 80);
    assert!((q.compression - 3.2).abs() < 1e-9, "{}", q.compression);
    // Worst case stays fp32-denominated so compression reads as savings.
    assert_eq!(cs.bytes_worst_case, 16 * CAPACITY * 256);

    let off = quant_engine(false, QuantKind::Off, 0);
    let q = off.cache_stats().quant;
    assert_eq!(q.kind, "off");
    assert_eq!(q.bytes_per_token, q.bytes_per_token_fp32);
    assert!((q.compression - 1.0).abs() < 1e-9);
}

/// fp8's ~6% relative error is too coarse for exact digit recovery, so
/// greedy parity with fp32 is NOT guaranteed (the row-level drift bound
/// is property-tested in `kvcache::quant`). What the engine contract does
/// guarantee: the full serving loop runs refcount-clean over fp8 blocks
/// and is deterministic — two identical runs produce identical tokens.
#[test]
fn fp8_runs_the_full_loop_deterministically() {
    let run = || {
        let mut e = quant_engine(true, QuantKind::Fp8, 11);
        let (wave, comps) = run_burst(&mut e);
        assert_eq!(e.cache_stats().quant.kind, "fp8");
        (wave, comps)
    };
    let (wave_a, comps_a) = run_burst(&mut quant_engine(true, QuantKind::Fp8, 11));
    let (wave_b, comps_b) = run();
    assert_eq!(comps_a.len(), N_REQS as usize);
    assert!(comps_a.iter().all(|(_, t)| t.len() == 8));
    assert_eq!(comps_a, comps_b, "fp8 decode must be deterministic");
    assert_eq!(wave_a, wave_b);
    // Same byte layout as int8 -> same >= 1.8x admission headroom.
    let off_wave = run_burst(&mut quant_engine(true, QuantKind::Off, 11)).0;
    assert!(wave_a as f64 / off_wave as f64 >= 1.8);
}

/// Quantized blocks compose with the chunked policy and prefix sharing:
/// a same-prefix burst over int8 blocks still dedupes (mid-prefill
/// registration included) and matches the fp32 engine's greedy output.
#[test]
fn int8_composes_with_prefix_sharing_and_chunked_prefill() {
    let build = |quant: QuantKind| {
        let mut e = Engine::new(
            SimBackend::new(SimConfig {
                capacity: CAPACITY,
                prefill_seq: CAPACITY,
                seed: 3,
                ..SimConfig::gqa(8)
            })
            .unwrap(),
            EngineConfig {
                policy: PolicyKind::Chunked { chunk_tokens: 8 },
                cache: CacheKind::Paged { block_size: 8, n_blocks: Some(16) },
                prefix_cache: true,
                kv_quant: quant,
                seed: 3,
                ..Default::default()
            },
        );
        // 20-token shared prompt: two full 8-token blocks of cacheable
        // prefix, landing across multiple chunks.
        let prompt: Vec<i32> = (0..20).map(|i| (i * 13 + 7) % 251).collect();
        for i in 0..6 {
            e.submit(Request::new(i, prompt.clone(), 4));
        }
        e.run_to_completion().unwrap();
        e.slots_check().unwrap();
        let mut comps: Vec<(u64, Vec<i32>)> = e
            .take_completions()
            .into_iter()
            .map(|c| (c.id, c.tokens))
            .collect();
        comps.sort_by_key(|(id, _)| *id);
        let stats = e.cache_stats();
        (comps, stats)
    };
    let (off_comps, _) = build(QuantKind::Off);
    let (int8_comps, int8_stats) = build(QuantKind::Int8);
    assert_eq!(off_comps, int8_comps, "sharing over int8 changed output");
    let ps = int8_stats.prefix.expect("prefix cache on");
    assert!(ps.hits > 0, "same-prefix burst must hit the index: {ps:?}");
    assert!(ps.blocks_shared > 0, "hits must map shared blocks: {ps:?}");
}
