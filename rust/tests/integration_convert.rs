//! Integration: the Rust conversion toolchain validated through the AOT
//! MLA artifacts (the same invariances the python suite proves against
//! the jax models, here proven against the compiled HLO). Requires
//! `make artifacts` + a real `xla` runtime; every test skips cleanly on
//! a bare checkout.

use std::path::Path;
use transmla::convert::{
    self, absorb_trainable, convert_model, merged_params_from, rorope_mask,
    rorope_rotation, ConvertOptions,
};
use transmla::corpus::Corpus;
use transmla::eval::{capture_calib, evaluate};
use transmla::model::init_gqa;
use transmla::runtime::Runtime;
use transmla::util::Rng;

struct Setup {
    rt: Runtime,
    cfg: transmla::config::ModelConfig,
    gqa: transmla::model::Params,
    calib: convert::Calib,
    batches: Vec<Vec<i32>>,
}

fn setup() -> Option<Setup> {
    let rt = match Runtime::new(Path::new("artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping (artifact runtime unavailable): {e:#}");
            return None;
        }
    };
    let cfg = rt.manifest.configs["llama2tiny"].clone();
    // Prefer the trained checkpoint (realistic activation statistics);
    // fall back to random init on a fresh clone.
    let ckpt = Path::new("runs/llama2tiny_base.tnz");
    let gqa = if ckpt.exists() {
        transmla::model::Params::load(ckpt).unwrap()
    } else {
        init_gqa(&cfg, 11)
    };
    let corpus = Corpus::synthetic(13, 400_000);
    let calib_exec = rt.load("llama2tiny_calib").unwrap();
    let mut rng = Rng::new(2);
    let toks = corpus.sample_batch(8, cfg.max_seq, &mut rng);
    let calib = capture_calib(&calib_exec, &gqa, &toks, 512).unwrap();
    let batches = corpus.val_batches(8, cfg.max_seq).into_iter().take(1).collect();
    Some(Setup { rt, cfg, gqa, calib, batches })
}

#[test]
fn merged_form_is_exact_through_hlo() {
    let Some(s) = setup() else { return };
    let gqa_exec = s.rt.load("llama2tiny_gqa_prefill").unwrap();
    let merged_exec = s.rt.load("llama2tiny_merged_prefill").unwrap();
    let base = evaluate(&gqa_exec, &s.gqa, &s.batches).unwrap();
    let merged = merged_params_from(&s.gqa, &s.cfg, None, None, None).unwrap();
    let m = evaluate(&merged_exec, &merged, &s.batches).unwrap();
    assert!(
        (base.loss - m.loss).abs() < 1e-4,
        "merged {} vs gqa {}",
        m.loss,
        base.loss
    );
}

#[test]
fn rorope_rotation_is_exact_through_hlo() {
    let Some(s) = setup() else { return };
    let gqa_exec = s.rt.load("llama2tiny_gqa_prefill").unwrap();
    let merged_exec = s.rt.load("llama2tiny_merged_prefill").unwrap();
    let base = evaluate(&gqa_exec, &s.gqa, &s.batches).unwrap();
    let rotations: Vec<_> = s
        .calib
        .k_pre
        .iter()
        .map(|k| rorope_rotation(k, &s.cfg, 1).unwrap().0)
        .collect();
    let merged =
        merged_params_from(&s.gqa, &s.cfg, Some(&rotations), None, None).unwrap();
    let m = evaluate(&merged_exec, &merged, &s.batches).unwrap();
    assert!(
        (base.loss - m.loss).abs() < 1e-3,
        "rotated {} vs gqa {} (Eq. 19 violated)",
        m.loss,
        base.loss
    );
}

#[test]
fn full_rank_conversion_matches_merged_masked_through_hlo() {
    let Some(s) = setup() else { return };
    // Full-rank latent: the ONLY approximation left is RoPE removal on
    // heads 1..g-1, identical to the merged model with a head-0 mask.
    let r_full = 192; // largest exported rank (< full 480, so compare trend)
    let (_, absorbed, _) =
        convert_model(&s.gqa, &s.calib, &s.cfg, &ConvertOptions::transmla(r_full))
            .unwrap();
    let mla_exec = s.rt.load("llama2tiny_mla_prefill_r192").unwrap();
    let ev_mla = evaluate(&mla_exec, &absorbed, &s.batches).unwrap();

    let rotations: Vec<_> = s
        .calib
        .k_pre
        .iter()
        .map(|k| rorope_rotation(k, &s.cfg, 1).unwrap().0)
        .collect();
    let mask = rorope_mask(&s.cfg, 1, 1);
    let merged = merged_params_from(
        &s.gqa, &s.cfg, Some(&rotations), None, Some(mask),
    )
    .unwrap();
    let merged_exec = s.rt.load("llama2tiny_merged_prefill").unwrap();
    let ev_merged = evaluate(&merged_exec, &merged, &s.batches).unwrap();

    // r=192 keeps the top 192 of 480 joint dims: close but not exact.
    assert!(
        (ev_mla.loss - ev_merged.loss).abs() < 0.15,
        "mla {} vs merged-masked {}",
        ev_mla.loss,
        ev_merged.loss
    );
}

#[test]
fn reabsorbed_trainable_matches_absorbed_through_hlo() {
    let Some(s) = setup() else { return };
    let (train_p, absorbed, _) =
        convert_model(&s.gqa, &s.calib, &s.cfg, &ConvertOptions::transmla(32))
            .unwrap();
    let re = absorb_trainable(&train_p, &s.cfg).unwrap();
    let exec = s.rt.load("llama2tiny_mla_prefill_r32").unwrap();
    let a = evaluate(&exec, &absorbed, &s.batches).unwrap();
    let b = evaluate(&exec, &re, &s.batches).unwrap();
    assert!((a.loss - b.loss).abs() < 1e-5, "{} vs {}", a.loss, b.loss);
}

#[test]
fn compression_error_monotone_in_rank_through_hlo() {
    let Some(s) = setup() else { return };
    let gqa_exec = s.rt.load("llama2tiny_gqa_prefill").unwrap();
    let base = evaluate(&gqa_exec, &s.gqa, &s.batches).unwrap();
    let mut errs = vec![];
    for r in [4usize, 64, 192] {
        let (_, absorbed, _) =
            convert_model(&s.gqa, &s.calib, &s.cfg, &ConvertOptions::transmla(r))
                .unwrap();
        let exec = s.rt.load(&format!("llama2tiny_mla_prefill_r{r}")).unwrap();
        let ev = evaluate(&exec, &absorbed, &s.batches).unwrap();
        errs.push(ev.loss - base.loss);
    }
    // On a trained model degradation shrinks monotonically with rank; on
    // a random-init fallback all degradations sit at noise level.
    let trained = Path::new("runs/llama2tiny_base.tnz").exists();
    if trained {
        // RoPE removal dominates the degradation; compression adds on
        // top of it at low rank. Allow noise between adjacent high ranks.
        assert!(
            errs[0] >= errs[1] - 1e-2 && errs[1] >= errs[2] - 5e-2,
            "degradation should shrink with rank: {errs:?}"
        );
    } else {
        assert!(
            errs.iter().all(|e| e.abs() < 0.05),
            "random-init degradation should be negligible: {errs:?}"
        );
    }
}

#[test]
fn mha2mla_baseline_runs_through_hlo() {
    let Some(s) = setup() else { return };
    let (_, absorbed, diag) =
        convert_model(&s.gqa, &s.calib, &s.cfg, &ConvertOptions::mha2mla(32))
            .unwrap();
    assert_eq!(diag.dr, s.cfg.head_dim);
    let exec = s.rt.load("llama2tiny_mla_prefill_r32").unwrap();
    let ev = evaluate(&exec, &absorbed, &s.batches).unwrap();
    assert!(ev.loss.is_finite());
}
