//! Integration: the quality harness end to end — the GQA↔MLA A/B the
//! subsystem exists for, the report's byte-reproducibility contract,
//! and the `transmla eval` CLI surface (the ISSUE's acceptance command
//! verbatim, dataset diagnostics included).
//!
//! Hermetic throughout: SimBackend engines over loopback TCP, fixed
//! ports in the 1849x range (18490 A/B, 18491/18492 CLI smoke; the
//! driver's own unit test owns 18499).

use std::time::{Duration, Instant};

use transmla::backend::{SimBackend, SimConfig};
use transmla::config::{EngineConfig, EvalOpts};
use transmla::coordinator::{Engine, Request};
use transmla::json::Json;
use transmla::qeval::{scorers, Dataset, EvalReport, EvalRun, ModelRun, RowOutcome};
use transmla::server::{self, EngineRegistry, RoutePolicy};

fn wait_ready(addr: &str) {
    let deadline = Instant::now() + Duration::from_secs(5);
    while server::client_line(addr, "{\"cmd\":\"ping\"}").is_err() {
        assert!(Instant::now() < deadline, "server at {addr} never came up");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn ab_scorers() -> Vec<Box<dyn scorers::Scorer>> {
    scorers::from_flags(&[
        ("exact".to_string(), "true".to_string()),
        ("levenshtein".to_string(), "0.8".to_string()),
    ])
    .unwrap()
}

/// The tentpole claim, pinned: a same-seed MLA twin scores *identically*
/// to its GQA baseline (the sim's token chain is cache-layout
/// independent), and the harness still detects a genuinely different
/// model (a seed-1 "degraded" engine) — so a 0.0pp delta is evidence of
/// parity, not of a scorer that passes everything.
#[test]
fn gqa_mla_ab_parity_and_degradation_detection() {
    let addr = "127.0.0.1:18490";
    let prompts =
        ["the latent cache", "absorbed attention", "rank picks the", "kv bytes per token"];
    let max_new = 8;

    // Reference outputs from a solo GQA engine (completions come back
    // id-sorted, so they align with the prompt order).
    let mut reference = Engine::new(SimBackend::gqa(4), EngineConfig::default());
    let reqs: Vec<Request> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| Request::from_text(i as u64, p, max_new))
        .collect();
    let expected: Vec<String> =
        reference.generate(reqs).unwrap().iter().map(|c| c.text()).collect();
    let pairs: Vec<(&str, &str)> =
        prompts.iter().zip(&expected).map(|(p, e)| (*p, e.as_str())).collect();
    let ds = Dataset::from_pairs(&pairs);

    let handle = std::thread::spawn(move || {
        let mut reg = EngineRegistry::new(RoutePolicy::Default("gqa".into()));
        reg.register("gqa", Engine::new(SimBackend::gqa(4), EngineConfig::default()))
            .unwrap();
        reg.register("mla", Engine::new(SimBackend::mla(4, 8), EngineConfig::default()))
            .unwrap();
        // Same arch as the baseline, different seed: a model whose
        // outputs genuinely differ, to prove the harness can see loss.
        let degraded =
            SimBackend::new(SimConfig { seed: 1, ..SimConfig::gqa(4) }).unwrap();
        reg.register("degraded", Engine::new(degraded, EngineConfig::default()))
            .unwrap();
        server::serve(&mut reg, addr).unwrap();
    });
    wait_ready(addr);

    let opts = EvalOpts { concurrency: 4, max_new, baseline: Some("gqa".into()) };
    let models: Vec<String> =
        ["gqa", "mla", "degraded"].iter().map(|s| s.to_string()).collect();
    let run = transmla::qeval::run_eval(&ds, &models, addr, &opts).unwrap();
    let run2 = transmla::qeval::run_eval(&ds, &models, addr, &opts).unwrap();
    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();

    let sc = ab_scorers();
    let rep = EvalReport::build("ab", &ds, &sc, &run, Some("gqa")).unwrap();
    assert_eq!(rep.models.len(), 3);
    let by_name = |name: &str| rep.models.iter().find(|m| m.model == name).unwrap();
    let (gqa, mla, deg) = (by_name("gqa"), by_name("mla"), by_name("degraded"));

    // Every row completed — transport and routing are clean.
    for m in [gqa, mla, deg] {
        assert_eq!((m.n, m.completed, m.errors), (4, 4, 0), "{}", m.model);
    }
    // Parity: the served GQA engine reproduces the reference outputs,
    // and the same-seed MLA twin matches them bit for bit.
    assert_eq!(gqa.cells[0].pass_rate(), 1.0, "gqa exact");
    assert_eq!(mla.cells[0].pass_rate(), 1.0, "mla exact");
    assert_eq!(mla.cells[1].pass_rate(), 1.0, "mla levenshtein");
    // Detection: the seed-1 engine does not.
    assert!(deg.cells[0].pass_rate() < 1.0, "degraded model must show loss");

    // The serialized delta says the same thing.
    let jsonl = rep.to_jsonl();
    let (meta, rows) = EvalReport::parse(&jsonl).unwrap();
    assert_eq!(meta.get("baseline").and_then(Json::as_str), Some("gqa"));
    let mla_row = rows
        .iter()
        .find(|r| r.get("model").and_then(Json::as_str) == Some("mla"))
        .unwrap();
    let d_exact = mla_row
        .get("delta")
        .and_then(|d| d.get("scores"))
        .and_then(|s| s.get("exact"))
        .and_then(|e| e.get("pass_rate"))
        .and_then(Json::as_f64)
        .unwrap();
    assert_eq!(d_exact, 0.0, "MLA conversion cost zero exact-match quality");

    // Determinism across runs: identical matrices (timings differ,
    // scores cannot — ScorerCell is PartialEq).
    let rep2 = EvalReport::build("ab", &ds, &sc, &run2, Some("gqa")).unwrap();
    for (a, b) in rep.models.iter().zip(&rep2.models) {
        assert_eq!(a.cells, b.cells, "run-to-run score drift in {}", a.model);
    }
}

/// Regression: the report serializers are byte-functions of their
/// inputs. A fully synthetic run (index-derived timings, no server, no
/// clock) must serialize to identical JSONL and HTML bytes every time.
#[test]
fn report_bytes_are_reproducible_over_a_synthetic_run() {
    let build = || {
        let ds = Dataset::from_pairs(&[("p0", "e0"), ("p1", "e1"), ("p2", "e2")]);
        let outcome = |i: usize| RowOutcome::Done {
            output: if i == 1 { "wrong".into() } else { format!("e{i}") },
            ttft_s: 0.010 + i as f64 * 0.001,
            tpot_s: 0.002,
            latency_s: 0.050 + i as f64 * 0.001,
            client_s: 0.055,
        };
        let run = EvalRun {
            models: vec![
                ModelRun { model: "gqa".into(), results: (0..3).map(|i| RowOutcome::Done {
                    output: format!("e{i}"),
                    ttft_s: 0.010,
                    tpot_s: 0.002,
                    latency_s: 0.050,
                    client_s: 0.055,
                }).collect() },
                ModelRun { model: "mla".into(), results: (0..3).map(outcome).collect() },
            ],
            wall_s: 0.5,
        };
        let rep = EvalReport::build("repro", &ds, &ab_scorers(), &run, Some("gqa")).unwrap();
        (rep.to_jsonl(), rep.render_html("transmla eval report"))
    };
    let (jsonl_a, html_a) = build();
    let (jsonl_b, html_b) = build();
    assert_eq!(jsonl_a, jsonl_b, "JSONL bytes drift");
    assert_eq!(html_a, html_b, "HTML bytes drift");
    // And the pinned shape: meta line + one line per model, delta on
    // the non-baseline row only.
    let (_, rows) = EvalReport::parse(&jsonl_a).unwrap();
    assert_eq!(rows.len(), 2);
    assert!(rows[0].get("delta").is_none());
    assert!(rows[1].get("delta").is_some());
    assert!(html_a.contains("(baseline)"));
    assert!(html_a.contains("pp)"), "delta annotation renders");
}

/// The ISSUE's acceptance command, verbatim flags included (the bare
/// `--exact` directly before `--levenshtein 0.8` exercises the
/// boolean-flag parse), against a dataset with every diagnostic case:
/// a clean row, a missing id, a duplicate id, a non-JSON line, and a
/// row with no `input`.
#[test]
fn cli_eval_smoke_with_diagnostics_and_reproducible_scores() {
    let dir = std::env::temp_dir().join("transmla_qeval_smoke");
    std::fs::create_dir_all(&dir).unwrap();
    let data = dir.join("ds.jsonl");
    std::fs::write(
        &data,
        "{\"id\": \"a\", \"input\": \"the latent\", \"expected\": \"x\"}\n\
         {\"input\": \"absorbed\", \"expected\": \"y\"}\n\
         {\"id\": \"a\", \"input\": \"rank picks\", \"expected\": \"z\"}\n\
         {not json\n\
         {\"id\": \"b\", \"expected\": \"no input\"}\n",
    )
    .unwrap();

    let run = |addr: &str, report: &std::path::Path, html: &std::path::Path| {
        let out = std::process::Command::new(env!("CARGO_BIN_EXE_transmla"))
            .args([
                "eval",
                "--data",
                data.to_str().unwrap(),
                "--model",
                "gqa=arch=gqa",
                "--model",
                "mla=arch=mla,rank=8",
                "--baseline",
                "gqa",
                "--exact",
                "--levenshtein",
                "0.8",
                "--batch",
                "4",
                "--max-new",
                "6",
                "--concurrency",
                "4",
                "--addr",
                addr,
                "--report",
                report.to_str().unwrap(),
                "--html",
                html.to_str().unwrap(),
            ])
            .output()
            .expect("spawn transmla eval");
        assert!(
            out.status.success(),
            "eval exited nonzero:\nstdout: {}\nstderr: {}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        let stderr = String::from_utf8_lossy(&out.stderr).into_owned();
        assert!(stderr.contains("bad JSON"), "malformed line surfaced on stderr");
        assert!(stderr.contains("missing string field `input`"));
    };

    let (r1, h1) = (dir.join("r1.jsonl"), dir.join("r1.html"));
    let (r2, h2) = (dir.join("r2.jsonl"), dir.join("r2.html"));
    run("127.0.0.1:18491", &r1, &h1);
    run("127.0.0.1:18492", &r2, &h2);

    let text1 = std::fs::read_to_string(&r1).unwrap();
    let (meta, rows) = EvalReport::parse(&text1).unwrap();
    let num = |k: &str| meta.get(k).and_then(Json::as_f64).unwrap() as usize;
    assert_eq!(num("n_rows"), 3, "3 usable rows");
    assert_eq!(num("malformed"), 2, "non-JSON line + missing-input line");
    assert_eq!(num("synthetic_ids"), 2, "missing id + repaired duplicate");
    assert_eq!(num("dup_ids"), 1);
    let model_names: Vec<&str> = meta
        .get("models")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .filter_map(Json::as_str)
        .collect();
    assert_eq!(model_names, ["gqa", "mla"]);
    assert_eq!(rows.len(), 2);
    for row in &rows {
        assert_eq!(row.get("completed").and_then(Json::as_f64), Some(3.0));
        assert_eq!(row.get("errors").and_then(Json::as_f64), Some(0.0), "zero transport errors");
        for sc in ["exact", "levenshtein"] {
            assert!(
                row.get("scores").and_then(|s| s.get(sc)).is_some(),
                "scorer `{sc}` missing from row"
            );
        }
    }
    assert!(rows[1].get("delta").is_some(), "non-baseline row carries delta");

    // The HTML is written and carries the baseline annotation.
    let html = std::fs::read_to_string(&h1).unwrap();
    assert!(html.contains("(baseline)"));

    // Scores are byte-identical across the two runs (wall time and
    // latency fields legitimately differ; graded quality cannot).
    let (_, rows2) = EvalReport::parse(&std::fs::read_to_string(&r2).unwrap()).unwrap();
    for (a, b) in rows.iter().zip(&rows2) {
        assert_eq!(
            a.get("scores").map(Json::to_string),
            b.get("scores").map(Json::to_string),
            "scores drift between identical CLI runs"
        );
    }
}
