//! Integration: the open-loop traffic harness (`src/workload/`) and
//! admission backpressure (`ServeOpts::max_pending`), end to end over
//! loopback TCP on the hermetic `SimBackend` — no artifacts, no XLA.
//!
//! Four claims are pinned here:
//!
//!   1. **Determinism** — same seed ⇒ byte-identical trace JSONL, and
//!      byte-identical report JSONL/HTML given identical outcomes, for
//!      all three arrival processes (`trace_and_report_bytes_are_...`).
//!   2. **Overload safety** — a 3×-sustainable bursty trace against a
//!      bounded pending queue never wedges the loop: every request gets
//!      exactly one reply, the observed pending depth never exceeds the
//!      bound, and the server's shed counter reconciles with the
//!      client-observed shed replies (`overload_never_wedges_...`).
//!   3. **Graceful degradation** — at 3× the sustainable rate, goodput
//!      with backpressure is at least the unbounded baseline's: shedding
//!      early beats queueing every request past its TTFT SLO
//!      (`backpressure_preserves_goodput_under_overload`).
//!   4. **CLI** — `transmla workload` self-hosts hermetically and emits
//!      a parseable report row (`workload_subcommand_smoke`).
//!
//! Ports 18480-18483 (see the allocation notes in the sibling tests).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};
use transmla::backend::{BackendSpec, CacheStore, ExecBackend, PrefillOut, SimBackend};
use transmla::config::{EngineConfig, SloSpec};
use transmla::coordinator::Engine;
use transmla::json::Json;
use transmla::server::{self, EngineRegistry, ServeOpts};
use transmla::tensor::Tensor;
use transmla::workload::{
    self, ArrivalKind, Outcome, ReportRow, RunOutcome, RunResult, Trace, TraceSpec,
};
use transmla::Result;

/// [`SimBackend`] with a fixed per-call service delay: a deterministic
/// service rate (the sim alone is far too fast for wall-clock queueing
/// to build), so "3× the sustainable rate" is a number we control.
struct SlowBackend {
    inner: SimBackend,
    delay: Duration,
}

impl SlowBackend {
    fn new(batch: usize, delay_ms: u64) -> SlowBackend {
        SlowBackend {
            inner: SimBackend::gqa(batch),
            delay: Duration::from_millis(delay_ms),
        }
    }
}

impl ExecBackend for SlowBackend {
    fn spec(&self) -> &BackendSpec {
        self.inner.spec()
    }

    fn prefill(&mut self, tokens: &[i32], rows: usize) -> Result<PrefillOut> {
        std::thread::sleep(self.delay);
        self.inner.prefill(tokens, rows)
    }

    fn prefill_chunk(
        &mut self,
        tokens: &[i32],
        slot: usize,
        start_pos: usize,
        cache: &mut CacheStore,
    ) -> Result<Tensor> {
        std::thread::sleep(self.delay);
        self.inner.prefill_chunk(tokens, slot, start_pos, cache)
    }

    fn decode(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
        active: &[bool],
        cache: &mut CacheStore,
    ) -> Result<Tensor> {
        std::thread::sleep(self.delay);
        self.inner.decode(tokens, pos, active, cache)
    }
}

fn wait_for_ping(addr: &str) {
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        if let Ok(j) = server::client_line(addr, "{\"cmd\":\"ping\"}") {
            if j.get("pong").is_some() {
                return;
            }
        }
        assert!(Instant::now() < deadline, "server at {addr} never came up");
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// One slow engine behind a (possibly bounded) serving loop.
fn start_slow_server(
    addr: &'static str,
    batch: usize,
    delay_ms: u64,
    max_pending: usize,
) -> JoinHandle<()> {
    let handle = std::thread::spawn(move || {
        let e = Engine::new(SlowBackend::new(batch, delay_ms), EngineConfig::default());
        let mut reg = EngineRegistry::single(e);
        server::serve_with(
            &mut reg,
            addr,
            ServeOpts { max_pending, ..ServeOpts::default() },
        )
        .unwrap();
    });
    wait_for_ping(addr);
    handle
}

fn server_shed_count(addr: &str) -> usize {
    server::client_stats(addr)
        .unwrap()
        .get("server")
        .and_then(|s| s.get("shed"))
        .and_then(|s| s.get("count"))
        .and_then(Json::as_usize)
        .unwrap()
}

fn server_pending(stats: &Json) -> usize {
    stats
        .get("server")
        .and_then(|s| s.get("pending"))
        .and_then(Json::as_usize)
        .unwrap()
}

/// The 3×-sustainable overload point used by tests 2 and 3. The slow
/// engine decodes a batch-4 step every 2ms → 2000 tokens/s; agent-only
/// traffic at max_new 16 costs ~8ms/request of decode plus prefill →
/// ~100 requests/s sustainable. 300/s for 0.3s is a 3× storm of ~90
/// requests.
fn overload_spec(seed: u64, arrivals: ArrivalKind) -> TraceSpec {
    TraceSpec {
        seed,
        arrivals,
        rate: 300.0,
        duration_s: 0.3,
        agent_frac: 1.0, // homogeneous decode budgets: max_new is exact
        max_new: 16,
        // Short prompts: the slow engine's capacity is 64 tokens, so
        // every prompt must fit with its full decode budget.
        agent_prefix: "agent q: ".to_string(),
        agent_suffix: (4, 12),
        ..TraceSpec::default()
    }
}

/// Deterministic synthetic outcomes derived purely from the trace (no
/// wall clock): what the report sees is then a pure function of the
/// seed, which is the only way "byte-identical report" can be pinned
/// without freezing real latencies.
fn synthetic_outcomes(trace: &Trace) -> RunResult {
    let outcomes = trace
        .events
        .iter()
        .enumerate()
        .map(|(i, e)| RunOutcome {
            index: i,
            tenant: e.tenant,
            at_s: e.at_s,
            outcome: if i % 5 == 4 {
                Outcome::Shed { retry_after_ms: 2.0 }
            } else {
                Outcome::Done {
                    ttft_s: 0.005 + (e.prompt.len() % 7) as f64 * 0.01,
                    tpot_s: 0.002 + (e.max_new % 3) as f64 * 0.001,
                    latency_s: 0.05 + e.at_s * 0.01,
                    queue_s: 0.001,
                    model: "default".to_string(),
                    client_s: 0.06,
                }
            },
        })
        .collect();
    RunResult { outcomes, wall_s: trace.spec.duration_s }
}

/// Satellite 1: same seed ⇒ byte-identical trace AND byte-identical
/// JSONL/HTML report, for every arrival process; a different seed
/// changes the trace bytes.
#[test]
fn trace_and_report_bytes_are_reproducible_for_all_arrival_kinds() {
    let slo = SloSpec { ttft_ms: Some(40.0), tpot_ms: Some(4.0) };
    for arrivals in [ArrivalKind::Poisson, ArrivalKind::Bursty { burst: 6 }, ArrivalKind::Ramp]
    {
        let spec = TraceSpec { seed: 11, arrivals, rate: 120.0, duration_s: 0.5, ..Default::default() };
        let (a, b) = (Trace::generate(&spec).unwrap(), Trace::generate(&spec).unwrap());
        assert_eq!(a.to_jsonl(), b.to_jsonl(), "{arrivals:?}: trace not byte-stable");
        let reseeded = Trace::generate(&TraceSpec { seed: 12, ..spec.clone() }).unwrap();
        assert_ne!(a.to_jsonl(), reseeded.to_jsonl(), "{arrivals:?}: seed ignored");

        let tags: &[(&str, String)] = &[("arrivals", arrivals.name())];
        let row_a = ReportRow::build("det", tags, slo, &synthetic_outcomes(&a));
        let row_b = ReportRow::build("det", tags, slo, &synthetic_outcomes(&b));
        assert_eq!(
            workload::to_jsonl(std::slice::from_ref(&row_a)),
            workload::to_jsonl(std::slice::from_ref(&row_b)),
            "{arrivals:?}: report JSONL not byte-stable"
        );
        assert_eq!(
            workload::render_html("t", std::slice::from_ref(&row_a)),
            workload::render_html("t", std::slice::from_ref(&row_b)),
            "{arrivals:?}: report HTML not byte-stable"
        );
        // And the row is substantive, not vacuously equal.
        assert!(row_a.n > 10, "{arrivals:?}: only {} events", row_a.n);
        assert!(row_a.completed > 0 && row_a.shed > 0);
        let line = workload::to_jsonl(std::slice::from_ref(&row_a));
        ReportRow::parse(line.trim()).unwrap();
    }
}

/// Satellite 2 (overload property): a 3× bursty storm against a bounded
/// queue. Every request gets exactly one reply, nothing wedges, the
/// sampled pending depth respects the bound, and the server's shed
/// counter reconciles with the client-observed shed replies.
#[test]
fn overload_never_wedges_and_every_request_gets_exactly_one_reply() {
    let addr = "127.0.0.1:18480";
    let max_pending = 4;
    let handle = start_slow_server(addr, 4, 2, max_pending);

    let trace = Trace::generate(&overload_spec(3, ArrivalKind::Bursty { burst: 8 })).unwrap();
    assert!(trace.events.len() > 30, "storm too small: {}", trace.events.len());

    // Sample the pending depth while the storm runs.
    let stop = Arc::new(AtomicBool::new(false));
    let poller = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut max_seen = 0;
            while !stop.load(Ordering::Relaxed) {
                if let Ok(stats) = server::client_stats(addr) {
                    max_seen = max_seen.max(server_pending(&stats));
                }
                std::thread::sleep(Duration::from_millis(3));
            }
            max_seen
        })
    };

    let result = workload::replay(&trace, addr).unwrap();
    stop.store(true, Ordering::Relaxed);
    let max_seen_pending = poller.join().unwrap();

    // Exactly one outcome per scheduled request, none of them transport
    // errors — overload is answered in-band, never by dropping sockets.
    assert_eq!(result.outcomes.len(), trace.events.len());
    assert_eq!(result.errors(), 0, "transport errors under overload");
    assert_eq!(result.completed() + result.shed(), trace.events.len());
    assert!(result.shed() > 0, "a 3× storm must shed at {max_pending} pending");
    assert!(result.completed() > 0, "backpressure must still admit work");
    // Shed replies carry a usable retry hint.
    for o in &result.outcomes {
        if let Outcome::Shed { retry_after_ms } = o.outcome {
            assert!(retry_after_ms >= 1.0, "vacuous retry_after_ms");
        }
    }

    // The bound held whenever we looked, and the books balance.
    assert!(
        max_seen_pending <= max_pending,
        "pending {max_seen_pending} exceeded --max-pending {max_pending}"
    );
    assert_eq!(
        server_shed_count(addr),
        result.shed(),
        "server shed counter disagrees with client-observed shed replies"
    );
    let stats = server::client_stats(addr).unwrap();
    assert_eq!(server_pending(&stats), 0, "pending entries leaked after drain");

    // Not wedged: the loop still serves and shuts down cleanly.
    let ok = server::client_request(addr, "post-storm", 2).unwrap();
    assert!(ok.get("text").is_some(), "{ok:?}");
    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}

/// The tentpole acceptance test: graceful degradation. Same 3× Poisson
/// storm against the same slow engine, once with a bounded queue and
/// once unbounded. With backpressure every admitted request is served
/// promptly (shed the rest); without it the queue grows for the whole
/// trace and the tail misses the TTFT SLO — so goodput with
/// backpressure must be at least the unbounded baseline's, while both
/// runs answer every single request.
#[test]
fn backpressure_preserves_goodput_under_overload() {
    let slo = SloSpec { ttft_ms: Some(150.0), tpot_ms: None };
    let trace = Trace::generate(&overload_spec(5, ArrivalKind::Poisson)).unwrap();

    let run = |addr: &'static str, max_pending: usize| -> ReportRow {
        let handle = start_slow_server(addr, 4, 2, max_pending);
        let result = workload::replay(&trace, addr).unwrap();
        server::client_shutdown(addr).unwrap();
        handle.join().unwrap();
        assert_eq!(
            result.completed() + result.shed() + result.errors(),
            trace.events.len(),
            "a request went unanswered (max_pending {max_pending})"
        );
        assert_eq!(result.errors(), 0, "transport errors (max_pending {max_pending})");
        let tags = [("max_pending", max_pending.to_string())];
        ReportRow::build("overload-3x", &tags, slo, &result)
    };

    let bounded = run("127.0.0.1:18481", 6);
    let unbounded = run("127.0.0.1:18482", 0);

    // The unbounded run admits everything...
    assert_eq!(unbounded.shed, 0);
    assert_eq!(unbounded.completed, trace.events.len());
    // ...while the bounded run sheds the excess instead of queueing it.
    assert!(bounded.shed > 0, "3× overload at 6 pending must shed");
    assert!(bounded.completed > 0);

    // Graceful degradation, the number the harness exists to produce:
    // shedding early preserves goodput that unbounded queueing destroys.
    assert!(
        bounded.goodput_rps >= unbounded.goodput_rps,
        "backpressure goodput {:.1}/s fell below the unbounded baseline \
         {:.1}/s (bounded: {}/{} SLO-met in {:.2}s; unbounded: {}/{} in {:.2}s)",
        bounded.goodput_rps,
        unbounded.goodput_rps,
        bounded.slo_met,
        bounded.completed,
        bounded.wall_s,
        unbounded.slo_met,
        unbounded.completed,
        unbounded.wall_s,
    );
    // And the baseline really did degrade: the unbounded tail blows the
    // TTFT SLO, which is what makes raw throughput the wrong metric.
    assert!(
        unbounded.slo_met < unbounded.completed,
        "unbounded queueing unexpectedly met the SLO for all {} completions \
         — the overload point is miscalibrated",
        unbounded.completed
    );
}

/// The `workload` subcommand self-hosts hermetically (sim backend by
/// default) and writes a parseable JSONL report row plus the HTML page
/// — the same invocation CI's smoke job runs.
#[test]
fn workload_subcommand_smoke() {
    let dir = std::env::temp_dir().join("transmla_workload_smoke");
    std::fs::create_dir_all(&dir).unwrap();
    let report = dir.join("report.jsonl");
    let html = dir.join("report.html");
    let trace_out = dir.join("trace.jsonl");
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_transmla"))
        .args([
            "workload",
            "--arrivals",
            "poisson",
            "--rate",
            "60",
            "--duration",
            "0.4",
            "--seed",
            "7",
            "--max-new",
            "8",
            "--addr",
            "127.0.0.1:18483",
            "--label",
            "smoke",
            "--report",
            report.to_str().unwrap(),
            "--html",
            html.to_str().unwrap(),
            "--trace-out",
            trace_out.to_str().unwrap(),
        ])
        .output()
        .expect("spawn transmla workload");
    assert!(
        out.status.success(),
        "workload exited nonzero:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );

    let line = std::fs::read_to_string(&report).unwrap();
    let row = ReportRow::parse(line.trim()).unwrap();
    assert_eq!(row.get("label").and_then(Json::as_str), Some("smoke"));
    assert!(row.get("goodput_rps").and_then(Json::as_f64).unwrap() > 0.0);
    assert_eq!(
        row.get("tags").and_then(|t| t.get("arrivals")).and_then(Json::as_str),
        Some("poisson")
    );

    let html_text = std::fs::read_to_string(&html).unwrap();
    assert!(html_text.contains("<table>") && html_text.contains("smoke"));

    // The emitted trace is the seed-7 trace, byte-for-byte.
    let spec = TraceSpec {
        seed: 7,
        rate: 60.0,
        duration_s: 0.4,
        max_new: 8,
        ..TraceSpec::default()
    };
    assert_eq!(
        std::fs::read_to_string(&trace_out).unwrap(),
        Trace::generate(&spec).unwrap().to_jsonl(),
        "CLI trace bytes differ from the library's for the same seed"
    );
}
