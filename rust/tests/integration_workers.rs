//! Integration: threaded engine workers (`ServeOpts { workers: N }`).
//!
//! The acceptance bar for PR 6's tentpole: completions must be
//! bit-identical between the worker mode and the single-threaded sweep
//! fallback (`workers: 0`) across scheduling policies × cache stores ×
//! layouts; shutdown must drain in-flight work without wedging or
//! leaking pending replies; and a randomized interleaved burst across
//! three models must survive the threading. Everything runs hermetically
//! over `SimBackend` — greedy decoding (temperature 0, the default) is
//! pure argmax with no RNG consumption, so per-request outputs are a
//! function of (prompt, model) alone and cannot depend on how requests
//! interleave across threads. (Temperature > 0 parity is pinned at the
//! engine level in `coordinator::engine`'s overlap tests, where
//! submission order is controlled.)

use std::collections::BTreeMap;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};
use transmla::backend::SimBackend;
use transmla::config::{CacheKind, EngineConfig, PolicyKind};
use transmla::coordinator::{Engine, Request};
use transmla::json::Json;
use transmla::server::{self, EngineRegistry, RoutePolicy, ServeOpts};

fn wait_for_ping(addr: &str) {
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        if let Ok(j) = server::client_line(addr, "{\"cmd\":\"ping\"}") {
            if j.get("pong").is_some() {
                return;
            }
        }
        assert!(Instant::now() < deadline, "server at {addr} never came up");
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// One GQA + one MLA engine sharing `cfg`, behind `workers` threads.
fn start_two_model_server(
    addr: &'static str,
    cfg: EngineConfig,
    workers: usize,
) -> JoinHandle<()> {
    let handle = std::thread::spawn(move || {
        let mut reg = EngineRegistry::new(RoutePolicy::Default("gqa-base".to_string()));
        reg.register("gqa-base", Engine::new(SimBackend::gqa(4), cfg.clone()))
            .unwrap();
        reg.register("mla", Engine::new(SimBackend::mla(4, 8), cfg))
            .unwrap();
        server::serve_with(&mut reg, addr, ServeOpts { workers, ..ServeOpts::default() }).unwrap();
    });
    wait_for_ping(addr);
    handle
}

/// Fire `prompts` at both models concurrently and collect
/// `model:prompt -> (text, max_new)`; then shut the server down.
fn burst(
    addr: &'static str,
    handle: JoinHandle<()>,
    prompts: &[&'static str],
) -> BTreeMap<String, (String, usize)> {
    let mut clients = Vec::new();
    for (i, prompt) in prompts.iter().enumerate() {
        for model in ["gqa-base", "mla"] {
            let prompt = *prompt;
            clients.push(std::thread::spawn(move || {
                let resp = server::client_request_model(
                    addr,
                    prompt,
                    4 + i % 3, // uneven budgets interleave completion order
                    Some(model),
                )
                .unwrap();
                (format!("{model}:{prompt}"), resp)
            }));
        }
    }
    let mut out = BTreeMap::new();
    for c in clients {
        let (key, resp) = c.join().unwrap();
        let text = resp
            .get("text")
            .and_then(Json::as_str)
            .unwrap_or_else(|| panic!("no text for {key}: {resp:?}"))
            .to_string();
        let max_new = resp.get("max_new").and_then(Json::as_usize).unwrap();
        out.insert(key, (text, max_new));
    }
    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
    out
}

/// The tentpole acceptance test: completions are bit-identical between
/// `--workers N` and the single-threaded sweep across
/// {admit-first, chunked:3} × {fixed, paged+prefix} × {GQA, MLA}
/// (both layouts serve side by side in every combination).
#[test]
fn threaded_completions_match_sweep_across_policies_and_caches() {
    let prompts: &[&'static str] = &[
        "alpha parity prompt",
        "bravo!",
        "charlie parity prompt three",
        "delta",
    ];
    // (sweep addr, worker addr) per combination — unique ports because
    // the test binary runs tests in parallel.
    let combos: &[(PolicyKind, bool, &'static str, &'static str)] = &[
        (PolicyKind::AdmitFirst, false, "127.0.0.1:18450", "127.0.0.1:18451"),
        (PolicyKind::AdmitFirst, true, "127.0.0.1:18452", "127.0.0.1:18453"),
        (
            PolicyKind::Chunked { chunk_tokens: 3 },
            false,
            "127.0.0.1:18454",
            "127.0.0.1:18455",
        ),
        (
            PolicyKind::Chunked { chunk_tokens: 3 },
            true,
            "127.0.0.1:18456",
            "127.0.0.1:18457",
        ),
    ];
    for &(policy, paged, sweep_addr, worker_addr) in combos {
        let cfg = EngineConfig {
            policy,
            cache: if paged {
                CacheKind::Paged { block_size: 8, n_blocks: None }
            } else {
                CacheKind::Fixed
            },
            prefix_cache: paged,
            ..Default::default()
        };
        let sweep = burst(
            sweep_addr,
            start_two_model_server(sweep_addr, cfg.clone(), 0),
            prompts,
        );
        let threaded = burst(
            worker_addr,
            start_two_model_server(worker_addr, cfg, 2),
            prompts,
        );
        assert_eq!(
            sweep, threaded,
            "completions diverged between sweep and workers \
             (policy {policy:?}, paged {paged})"
        );
        // And both match a fresh solo engine (greedy = order-independent).
        for (i, prompt) in prompts.iter().enumerate() {
            for (model, mk) in [
                ("gqa-base", SimBackend::gqa as fn(usize) -> SimBackend),
                ("mla", |b| SimBackend::mla(b, 8)),
            ] {
                let mut solo = Engine::new(
                    mk(4),
                    EngineConfig { policy, ..Default::default() },
                );
                let comps = solo
                    .generate(vec![Request::from_text(0, prompt, 4 + i % 3)])
                    .unwrap();
                assert_eq!(
                    threaded[&format!("{model}:{prompt}")].0,
                    comps[0].text(),
                    "{model} `{prompt}` differs from a solo run"
                );
            }
        }
    }
}

/// Shutdown with work in flight: every already-submitted request is
/// drained to a real completion (workers finish their sequences before
/// exiting), nothing wedges, and `serve_with` returns cleanly. Requests
/// arriving after shutdown get an in-band error rather than silence.
#[test]
fn worker_shutdown_drains_in_flight_requests() {
    let addr = "127.0.0.1:18458";
    let handle = start_two_model_server(
        addr,
        EngineConfig { policy: PolicyKind::Chunked { chunk_tokens: 2 }, ..Default::default() },
        2,
    );

    // Long-ish generations so shutdown lands while they are in flight.
    let clients: Vec<JoinHandle<Json>> = (0..6)
        .map(|i| {
            std::thread::spawn(move || {
                let model = if i % 2 == 0 { "gqa-base" } else { "mla" };
                server::client_request_model(
                    addr,
                    "a prompt that takes a while to prefill and decode",
                    12,
                    Some(model),
                )
                .unwrap()
            })
        })
        .collect();

    // Let the requests reach the engines, then pull the plug.
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let stats = server::client_stats(addr).unwrap();
        let requests: usize = ["gqa-base", "mla"]
            .iter()
            .filter_map(|n| {
                stats
                    .get("engines")?
                    .get(n)?
                    .get("counters")?
                    .get("requests")?
                    .as_usize()
            })
            .sum();
        if requests == 6 {
            break;
        }
        assert!(Instant::now() < deadline, "requests never reached the engines");
        std::thread::sleep(Duration::from_millis(5));
    }
    server::client_shutdown(addr).unwrap();

    // Every in-flight request still gets its completion — the workers
    // drain before exiting (no wedge, no pending leak, no error reply).
    for c in clients {
        let resp = c.join().unwrap();
        assert!(
            resp.get("text").is_some(),
            "in-flight request dropped at shutdown: {resp:?}"
        );
        assert_eq!(resp.get("max_new").and_then(Json::as_usize), Some(12));
    }
    // serve_with returned Ok — the engines were reattached and no worker
    // wedged or leaked.
    handle.join().unwrap();
}

/// Stress: three models with different policies/caches behind two
/// workers (one worker owns two engines), hammered by a deterministic
/// pseudo-random interleaving of concurrent requests. Every reply must
/// match a fresh solo-engine run of that single request (greedy decoding
/// is order-independent), routing must never cross models, and the
/// engines must drain completely.
#[test]
fn randomized_three_model_stress_under_workers() {
    let addr = "127.0.0.1:18459";
    let handle = std::thread::spawn(move || {
        let mut reg = EngineRegistry::new(RoutePolicy::RoundRobin);
        reg.register("plain", Engine::new(SimBackend::gqa(4), EngineConfig::default()))
            .unwrap();
        reg.register(
            "chunky",
            Engine::new(
                SimBackend::gqa(4),
                EngineConfig {
                    policy: PolicyKind::Chunked { chunk_tokens: 3 },
                    cache: CacheKind::Paged { block_size: 8, n_blocks: None },
                    prefix_cache: true,
                    weight: 2,
                    ..Default::default()
                },
            ),
        )
        .unwrap();
        reg.register("mla", Engine::new(SimBackend::mla(4, 8), EngineConfig::default()))
            .unwrap();
        server::serve_with(&mut reg, addr, ServeOpts { workers: 2, ..ServeOpts::default() })
            .unwrap();
    });
    wait_for_ping(addr);

    // Deterministic LCG so the "random" schedule is reproducible.
    let mut state = 0x2545F4914F6CDD1Du64;
    let mut rand = move |n: usize| {
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        ((state >> 33) as usize) % n
    };
    let prompts = [
        "shared prefix stress prompt variant one",
        "shared prefix stress prompt variant two",
        "a different short one",
        "x",
    ];
    let models = ["plain", "chunky", "mla"];
    let mut clients = Vec::new();
    for _ in 0..24 {
        let model = models[rand(3)];
        let prompt = prompts[rand(prompts.len())];
        let max_new = 1 + rand(6);
        clients.push(std::thread::spawn(move || {
            let resp =
                server::client_request_model(addr, prompt, max_new, Some(model)).unwrap();
            (model, prompt, max_new, resp)
        }));
    }

    let mut per_model = BTreeMap::new();
    for c in clients {
        let (model, prompt, max_new, resp) = c.join().unwrap();
        assert_eq!(
            resp.get("model").and_then(Json::as_str),
            Some(model),
            "reply crossed models: {resp:?}"
        );
        let text = resp.get("text").and_then(Json::as_str).unwrap().to_string();
        // Greedy decoding is a pure function of (prompt, model): a fresh
        // solo engine must reproduce the served text exactly, regardless
        // of how the threaded server batched and interleaved.
        let mut solo = match model {
            "mla" => Engine::new(SimBackend::mla(4, 8), EngineConfig::default()),
            _ => Engine::new(SimBackend::gqa(4), EngineConfig::default()),
        };
        let comps = solo
            .generate(vec![Request::from_text(0, prompt, max_new)])
            .unwrap();
        assert_eq!(text, comps[0].text(), "{model} `{prompt}` (max_new {max_new})");
        *per_model.entry(model).or_insert(0usize) += 1;
    }

    // Control commands work mid-mode: the worker-mode stats fan-out
    // assembles every engine, counters add up, and everything drained.
    let stats = server::client_stats(addr).unwrap();
    let mut completed = 0usize;
    for (model, served) in &per_model {
        let eng = stats
            .get("engines")
            .and_then(|e| e.get(model))
            .unwrap_or_else(|| panic!("stats missing engine `{model}`: {stats:?}"));
        let c = eng
            .get("counters")
            .and_then(|c| c.get("completed"))
            .and_then(Json::as_usize)
            .unwrap();
        assert_eq!(c, *served, "{model} completed");
        completed += c;
        for depth in ["queued", "prefilling", "decoding"] {
            assert_eq!(eng.get(depth).and_then(Json::as_usize), Some(0), "{model} {depth}");
        }
    }
    assert_eq!(completed, 24);
    assert_eq!(
        stats
            .get("server")
            .and_then(|s| s.get("pending"))
            .and_then(Json::as_usize),
        Some(0)
    );
    let m = server::client_models(addr).unwrap();
    assert_eq!(m.get("models").and_then(Json::as_arr).unwrap().len(), 3);
    assert_eq!(m.get("routing").and_then(Json::as_str), Some("round-robin"));

    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}

/// `workers` larger than the engine count is clamped (one worker per
/// engine) and still serves + shuts down cleanly.
#[test]
fn more_workers_than_engines_is_clamped_and_serves() {
    let addr = "127.0.0.1:18460";
    let handle = start_two_model_server(addr, EngineConfig::default(), 8);
    let resp = server::client_request_model(addr, "clamped workers", 3, Some("mla")).unwrap();
    assert_eq!(resp.get("model").and_then(Json::as_str), Some("mla"));
    assert!(resp.get("text").is_some(), "{resp:?}");
    server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}
