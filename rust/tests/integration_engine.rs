//! Integration: the serving engine end-to-end — continuous batching,
//! slot recycling, determinism, both cache layouts, and scheduling-policy
//! behaviour — hermetically over the deterministic `SimBackend`, so this
//! suite runs on a bare checkout with no `artifacts/` directory and no
//! XLA runtime. (The same engine over real PJRT executables is covered by
//! `integration_runtime` when artifacts are present.)

use transmla::backend::SimBackend;
use transmla::config::{EngineConfig, PolicyKind};
use transmla::coordinator::{Engine, Request};

fn engine(seed: u64) -> Engine {
    Engine::new(
        SimBackend::gqa(8),
        EngineConfig { seed, ..Default::default() },
    )
}

fn mla_engine(seed: u64, rank: usize) -> Engine {
    Engine::new(
        SimBackend::mla(8, rank),
        EngineConfig { seed, ..Default::default() },
    )
}

#[test]
fn generates_requested_token_counts() {
    let mut e = engine(0);
    let reqs = vec![
        Request::from_text(0, "hello world", 5),
        Request::from_text(1, "the quick brown fox", 9),
        Request::from_text(2, "a", 3),
    ];
    let comps = e.generate(reqs).unwrap();
    assert_eq!(comps.len(), 3);
    assert_eq!(comps[0].tokens.len(), 5);
    assert_eq!(comps[1].tokens.len(), 9);
    assert_eq!(comps[2].tokens.len(), 3);
    e.slots_check().unwrap();
    assert!(e.is_idle());
}

#[test]
fn full_loop_works_in_the_mla_latent_layout() {
    // Same admit -> decode -> complete loop over the compressed cache
    // layout (the paper's serving configuration).
    for rank in [4usize, 32] {
        let mut e = mla_engine(0, rank);
        let reqs: Vec<Request> = (0..12)
            .map(|i| Request::from_text(i, "the latent cache serves", 6))
            .collect();
        let comps = e.generate(reqs).unwrap();
        assert_eq!(comps.len(), 12);
        assert!(comps.iter().all(|c| c.tokens.len() == 6));
        e.slots_check().unwrap();
    }
}

#[test]
fn greedy_decode_is_deterministic_and_batch_invariant() {
    // The same prompt must yield the same greedy tokens whether it runs
    // alone or batched with other requests (slot isolation).
    let mut e1 = engine(1);
    let solo = e1
        .generate(vec![Request::from_text(0, "the model rotates", 8)])
        .unwrap();

    let mut e2 = engine(2);
    let mixed = e2
        .generate(vec![
            Request::from_text(0, "the model rotates", 8),
            Request::from_text(1, "completely different prompt here", 12),
            Request::from_text(2, "yet another one", 6),
        ])
        .unwrap();

    assert_eq!(solo[0].tokens, mixed[0].tokens, "slot cross-talk detected");

    // And a fresh engine with the same seed reproduces it exactly.
    let mut e3 = engine(1);
    let again = e3
        .generate(vec![Request::from_text(0, "the model rotates", 8)])
        .unwrap();
    assert_eq!(solo[0].tokens, again[0].tokens, "nondeterministic decode");
}

#[test]
fn more_requests_than_slots_recycles() {
    let mut e = engine(3);
    let reqs: Vec<Request> = (0..20)
        .map(|i| Request::from_text(i, "abcdefgh", 4))
        .collect();
    let comps = e.generate(reqs).unwrap();
    assert_eq!(comps.len(), 20);
    assert!(e.metrics.counter("completed") == 20);
    assert!(e.metrics.counter("decode_steps") > 0);
    e.slots_check().unwrap();
}

#[test]
fn throughput_counters_consistent() {
    let mut e = engine(4);
    let reqs: Vec<Request> = (0..8)
        .map(|i| Request::from_text(i, "some text prompt", 6))
        .collect();
    let comps = e.generate(reqs).unwrap();
    let generated: usize = comps.iter().map(|c| c.tokens.len()).sum();
    // first token comes from prefill; the rest from decode
    let decoded = e.metrics.counter("decode_tokens") as usize;
    assert_eq!(decoded, generated - comps.len());
    assert!(e.decode_throughput() > 0.0);
    // Per-request accounting flows into the metrics series.
    assert_eq!(e.metrics.summary("latency_s").unwrap().n, 8);
    assert_eq!(e.metrics.summary("ttft_s").unwrap().n, 8);
}

#[test]
fn empty_prompt_completes_instead_of_panicking() {
    // Regression for the `(plen - 1)` underflow in admission.
    let mut e = engine(5);
    let comps = e
        .generate(vec![
            Request::new(0, vec![], 4),
            Request::from_text(1, "nonempty", 4),
        ])
        .unwrap();
    assert_eq!(comps.len(), 2);
    assert_eq!(comps[0].prompt_len, 0);
    assert_eq!(comps[0].tokens.len(), 4);
    e.slots_check().unwrap();
}

#[test]
fn overlong_prompts_are_clamped_and_complete() {
    let mut e = engine(6);
    let cap = e.spec().capacity;
    let comps = e
        .generate(vec![Request::new(0, vec![65; cap * 2], 100)])
        .unwrap();
    assert_eq!(comps.len(), 1);
    assert!(!comps[0].tokens.is_empty());
    assert!(comps[0].tokens.len() <= cap);
    e.slots_check().unwrap();
}

// ---------------------------------------------------------------------------
// Scheduling policies: same scripted workload, observably different
// admission orderings, all reaching completion.
// ---------------------------------------------------------------------------

/// 2 slots; A is long, B and C are short. Returns (completion order,
/// admission trace as (active-at-admission, admitted ids)).
fn run_scripted(policy: PolicyKind) -> (Vec<u64>, Vec<(usize, Vec<u64>)>) {
    let mut e = Engine::new(
        SimBackend::gqa(2),
        EngineConfig { policy, ..Default::default() },
    );
    e.submit(Request::from_text(0, "aaaaaaaa", 8)); // A: long
    e.submit(Request::from_text(1, "bbbbbbbb", 2)); // B: short
    e.submit(Request::from_text(2, "cccccccc", 2)); // C: short
    e.run_to_completion().unwrap();
    e.slots_check().unwrap();
    let order: Vec<u64> = e.take_completions().iter().map(|c| c.id).collect();
    (order, e.admission_log().to_vec())
}

#[test]
fn admit_first_backfills_the_free_slot_immediately() {
    let (order, log) = run_scripted(PolicyKind::AdmitFirst);
    assert_eq!(order, vec![1, 2, 0], "C backfills B's slot and beats A");
    assert_eq!(log.len(), 2);
    assert_eq!(log[0].1, vec![0, 1]);
    // C was admitted while A was still decoding.
    assert_eq!(log[1], (1, vec![2]));
}

#[test]
fn decode_first_drains_the_batch_before_admitting() {
    let (order, log) = run_scripted(PolicyKind::DecodeFirst);
    assert_eq!(order, vec![1, 0, 2], "A finishes before C is admitted");
    assert_eq!(log.len(), 2);
    // C's admission waited for an empty batch.
    assert_eq!(log[1], (0, vec![2]));
}

#[test]
fn hybrid_threshold_controls_the_admission_ordering() {
    // min_free = 2: one free slot is not enough -> behaves like
    // decode-first on this workload.
    let (order, log) = run_scripted(PolicyKind::Hybrid { min_free: 2 });
    assert_eq!(order, vec![1, 0, 2]);
    assert_eq!(log[1], (0, vec![2]));

    // min_free = 1 degrades to admit-first.
    let (order, log) = run_scripted(PolicyKind::Hybrid { min_free: 1 });
    assert_eq!(order, vec![1, 2, 0]);
    assert_eq!(log[1], (1, vec![2]));
}

#[test]
fn all_policies_complete_a_bursty_workload() {
    for policy in [
        PolicyKind::AdmitFirst,
        PolicyKind::DecodeFirst,
        PolicyKind::Hybrid { min_free: 4 },
    ] {
        let mut e = Engine::new(
            SimBackend::gqa(8),
            EngineConfig { policy, ..Default::default() },
        );
        let reqs: Vec<Request> = (0..30)
            .map(|i| Request::from_text(i, "burst", 1 + (i as usize % 7)))
            .collect();
        let comps = e.generate(reqs).unwrap();
        assert_eq!(comps.len(), 30, "{policy:?} lost requests");
        assert!(e.is_idle());
        e.slots_check().unwrap();
    }
}
