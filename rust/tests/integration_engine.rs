//! Integration: the serving engine end-to-end — continuous batching,
//! slot recycling, determinism, both cache layouts, and scheduling-policy
//! behaviour — hermetically over the deterministic `SimBackend`, so this
//! suite runs on a bare checkout with no `artifacts/` directory and no
//! XLA runtime. (The same engine over real PJRT executables is covered by
//! `integration_runtime` when artifacts are present.)

use transmla::backend::{SimBackend, SimConfig};
use transmla::config::{CacheKind, EngineConfig, PolicyKind};
use transmla::coordinator::{Engine, Request, StepPlan};

fn engine(seed: u64) -> Engine {
    Engine::new(
        SimBackend::gqa(8),
        EngineConfig { seed, ..Default::default() },
    )
}

fn mla_engine(seed: u64, rank: usize) -> Engine {
    Engine::new(
        SimBackend::mla(8, rank),
        EngineConfig { seed, ..Default::default() },
    )
}

#[test]
fn generates_requested_token_counts() {
    let mut e = engine(0);
    let reqs = vec![
        Request::from_text(0, "hello world", 5),
        Request::from_text(1, "the quick brown fox", 9),
        Request::from_text(2, "a", 3),
    ];
    let comps = e.generate(reqs).unwrap();
    assert_eq!(comps.len(), 3);
    assert_eq!(comps[0].tokens.len(), 5);
    assert_eq!(comps[1].tokens.len(), 9);
    assert_eq!(comps[2].tokens.len(), 3);
    e.slots_check().unwrap();
    assert!(e.is_idle());
}

#[test]
fn full_loop_works_in_the_mla_latent_layout() {
    // Same admit -> decode -> complete loop over the compressed cache
    // layout (the paper's serving configuration).
    for rank in [4usize, 32] {
        let mut e = mla_engine(0, rank);
        let reqs: Vec<Request> = (0..12)
            .map(|i| Request::from_text(i, "the latent cache serves", 6))
            .collect();
        let comps = e.generate(reqs).unwrap();
        assert_eq!(comps.len(), 12);
        assert!(comps.iter().all(|c| c.tokens.len() == 6));
        e.slots_check().unwrap();
    }
}

#[test]
fn greedy_decode_is_deterministic_and_batch_invariant() {
    // The same prompt must yield the same greedy tokens whether it runs
    // alone or batched with other requests (slot isolation).
    let mut e1 = engine(1);
    let solo = e1
        .generate(vec![Request::from_text(0, "the model rotates", 8)])
        .unwrap();

    let mut e2 = engine(2);
    let mixed = e2
        .generate(vec![
            Request::from_text(0, "the model rotates", 8),
            Request::from_text(1, "completely different prompt here", 12),
            Request::from_text(2, "yet another one", 6),
        ])
        .unwrap();

    assert_eq!(solo[0].tokens, mixed[0].tokens, "slot cross-talk detected");

    // And a fresh engine with the same seed reproduces it exactly.
    let mut e3 = engine(1);
    let again = e3
        .generate(vec![Request::from_text(0, "the model rotates", 8)])
        .unwrap();
    assert_eq!(solo[0].tokens, again[0].tokens, "nondeterministic decode");
}

#[test]
fn more_requests_than_slots_recycles() {
    let mut e = engine(3);
    let reqs: Vec<Request> = (0..20)
        .map(|i| Request::from_text(i, "abcdefgh", 4))
        .collect();
    let comps = e.generate(reqs).unwrap();
    assert_eq!(comps.len(), 20);
    assert!(e.metrics.counter("completed") == 20);
    assert!(e.metrics.counter("decode_steps") > 0);
    e.slots_check().unwrap();
}

#[test]
fn throughput_counters_consistent() {
    let mut e = engine(4);
    let reqs: Vec<Request> = (0..8)
        .map(|i| Request::from_text(i, "some text prompt", 6))
        .collect();
    let comps = e.generate(reqs).unwrap();
    let generated: usize = comps.iter().map(|c| c.tokens.len()).sum();
    // first token comes from prefill; the rest from decode
    let decoded = e.metrics.counter("decode_tokens") as usize;
    assert_eq!(decoded, generated - comps.len());
    assert!(e.decode_throughput() > 0.0);
    // Per-request accounting flows into the metrics series.
    assert_eq!(e.metrics.summary("latency_s").unwrap().n, 8);
    assert_eq!(e.metrics.summary("ttft_s").unwrap().n, 8);
}

#[test]
fn empty_prompt_completes_instead_of_panicking() {
    // Regression for the `(plen - 1)` underflow in admission.
    let mut e = engine(5);
    let comps = e
        .generate(vec![
            Request::new(0, vec![], 4),
            Request::from_text(1, "nonempty", 4),
        ])
        .unwrap();
    assert_eq!(comps.len(), 2);
    assert_eq!(comps[0].prompt_len, 0);
    assert_eq!(comps[0].tokens.len(), 4);
    e.slots_check().unwrap();
}

#[test]
fn overlong_prompts_are_clamped_and_complete() {
    let mut e = engine(6);
    let cap = e.spec().capacity;
    let comps = e
        .generate(vec![Request::new(0, vec![65; cap * 2], 100)])
        .unwrap();
    assert_eq!(comps.len(), 1);
    assert!(!comps[0].tokens.is_empty());
    assert!(comps[0].tokens.len() <= cap);
    e.slots_check().unwrap();
}

// ---------------------------------------------------------------------------
// Scheduling policies: same scripted workload, observably different
// admission orderings, all reaching completion.
// ---------------------------------------------------------------------------

/// 2 slots; A is long, B and C are short. Returns (completion order,
/// admission trace as (active-at-admission, admitted ids)).
fn run_scripted_with_cache(
    policy: PolicyKind,
    cache: CacheKind,
) -> (Vec<u64>, Vec<(usize, Vec<u64>)>, Vec<Vec<i32>>) {
    let mut e = Engine::new(
        SimBackend::gqa(2),
        EngineConfig { policy, cache, ..Default::default() },
    );
    e.submit(Request::from_text(0, "aaaaaaaa", 8)); // A: long
    e.submit(Request::from_text(1, "bbbbbbbb", 2)); // B: short
    e.submit(Request::from_text(2, "cccccccc", 2)); // C: short
    e.run_to_completion().unwrap();
    e.slots_check().unwrap();
    let mut comps = e.take_completions();
    let order: Vec<u64> = comps.iter().map(|c| c.id).collect();
    comps.sort_by_key(|c| c.id);
    let tokens: Vec<Vec<i32>> = comps.into_iter().map(|c| c.tokens).collect();
    let log: Vec<(usize, Vec<u64>)> = e.admission_log().iter().cloned().collect();
    (order, log, tokens)
}

fn run_scripted(policy: PolicyKind) -> (Vec<u64>, Vec<(usize, Vec<u64>)>) {
    let (order, log, _) = run_scripted_with_cache(policy, CacheKind::Fixed);
    (order, log)
}

#[test]
fn admit_first_backfills_the_free_slot_immediately() {
    let (order, log) = run_scripted(PolicyKind::AdmitFirst);
    assert_eq!(order, vec![1, 2, 0], "C backfills B's slot and beats A");
    assert_eq!(log.len(), 2);
    assert_eq!(log[0].1, vec![0, 1]);
    // C was admitted while A was still decoding.
    assert_eq!(log[1], (1, vec![2]));
}

#[test]
fn decode_first_drains_the_batch_before_admitting() {
    let (order, log) = run_scripted(PolicyKind::DecodeFirst);
    assert_eq!(order, vec![1, 0, 2], "A finishes before C is admitted");
    assert_eq!(log.len(), 2);
    // C's admission waited for an empty batch.
    assert_eq!(log[1], (0, vec![2]));
}

#[test]
fn hybrid_threshold_controls_the_admission_ordering() {
    // min_free = 2: one free slot is not enough -> behaves like
    // decode-first on this workload.
    let (order, log) = run_scripted(PolicyKind::Hybrid { min_free: 2 });
    assert_eq!(order, vec![1, 0, 2]);
    assert_eq!(log[1], (0, vec![2]));

    // min_free = 1 degrades to admit-first.
    let (order, log) = run_scripted(PolicyKind::Hybrid { min_free: 1 });
    assert_eq!(order, vec![1, 2, 0]);
    assert_eq!(log[1], (1, vec![2]));
}

// ---------------------------------------------------------------------------
// Paged block cache: completion-identical to the fixed pool, and strictly
// more concurrency under the same byte budget on mixed-context workloads.
// ---------------------------------------------------------------------------

#[test]
fn paged_and_fixed_caches_are_completion_identical() {
    // Same scripted arrivals, every policy, both cache kinds: identical
    // completion order, admission trace, and token-for-token output.
    for policy in [
        PolicyKind::AdmitFirst,
        PolicyKind::DecodeFirst,
        PolicyKind::Hybrid { min_free: 2 },
        PolicyKind::Chunked { chunk_tokens: 4 },
    ] {
        let fixed = run_scripted_with_cache(policy, CacheKind::Fixed);
        let paged = run_scripted_with_cache(
            policy,
            CacheKind::Paged { block_size: 16, n_blocks: None },
        );
        assert_eq!(fixed.0, paged.0, "{policy:?}: completion order diverged");
        assert_eq!(fixed.1, paged.1, "{policy:?}: admission trace diverged");
        assert_eq!(fixed.2, paged.2, "{policy:?}: tokens diverged");
    }
}

// ---------------------------------------------------------------------------
// Chunked prefill: bit-identical to monolithic across policies and cache
// stores, and the overlap win — decode never stalls more than one chunk.
// ---------------------------------------------------------------------------

#[test]
fn chunked_prefill_is_completion_identical_to_monolithic() {
    // Same scripted workload, every chunk size, both cache stores: the
    // tokens of every completion must match the monolithic reference
    // bit-for-bit (the sim model is deterministic and batch-invariant,
    // so any divergence is a resume bug in the chunk path).
    for cache in [
        CacheKind::Fixed,
        CacheKind::Paged { block_size: 16, n_blocks: None },
    ] {
        let reference = run_scripted_with_cache(PolicyKind::AdmitFirst, cache).2;
        for monolithic in [PolicyKind::DecodeFirst, PolicyKind::Hybrid { min_free: 2 }] {
            assert_eq!(
                reference,
                run_scripted_with_cache(monolithic, cache).2,
                "{monolithic:?} over {cache:?} diverged from admit-first"
            );
        }
        for chunk in [1usize, 3, 64] {
            let got =
                run_scripted_with_cache(PolicyKind::Chunked { chunk_tokens: chunk }, cache).2;
            assert_eq!(
                reference, got,
                "chunked:{chunk} over {cache:?} diverged from monolithic"
            );
        }
    }
}

/// The acceptance scenario: three sequences are decoding when a long
/// prompt arrives. Under admit-first, the monolithic prefill stalls
/// every decode for the whole prompt; under chunked:N, decode keeps
/// stepping with at most one N-token chunk between steps — and the
/// completions stay bit-identical.
#[test]
fn chunked_prefill_overlaps_decode_and_bounds_the_stall() {
    let chunk = 8usize;
    let long_len = 96usize;
    let capacity = 128usize;
    let mk = |policy: PolicyKind| {
        Engine::new(
            SimBackend::new(SimConfig {
                capacity,
                prefill_seq: capacity,
                ..SimConfig::gqa(4)
            })
            .unwrap(),
            EngineConfig { policy, ..Default::default() },
        )
    };
    // Returns (max prefill tokens between consecutive decode steps,
    // completions sorted by id).
    let run = |mut e: Engine| -> (usize, Vec<(u64, Vec<i32>)>) {
        for i in 0..3 {
            e.submit(Request::from_text(i, "steady decode traffic", 40));
        }
        // Let the steady sequences admit and get a few decode steps in.
        for _ in 0..5 {
            e.step().unwrap();
        }
        e.submit(Request::new(3, vec![65; long_len], 8));
        let mut max_gap = 0usize;
        let mut gap = 0usize;
        while !e.is_idle() {
            let pre = e.metrics.counter("prefill_tokens");
            let dec = e.metrics.counter("decode_steps");
            e.step().unwrap();
            gap += (e.metrics.counter("prefill_tokens") - pre) as usize;
            if e.metrics.counter("decode_steps") > dec {
                max_gap = max_gap.max(gap);
                gap = 0;
            }
        }
        e.slots_check().unwrap();
        let mut comps = e.take_completions();
        comps.sort_by_key(|c| c.id);
        (max_gap, comps.into_iter().map(|c| (c.id, c.tokens)).collect())
    };

    let (mono_gap, mono) = run(mk(PolicyKind::AdmitFirst));
    let (chunk_gap, chunked) = run(mk(PolicyKind::Chunked { chunk_tokens: chunk }));
    assert!(
        mono_gap >= long_len,
        "monolithic stall must cover the whole long prompt (gap {mono_gap})"
    );
    assert!(
        chunk_gap <= chunk,
        "chunked decode gap {chunk_gap} exceeds one chunk ({chunk})"
    );
    assert!(
        chunk_gap < mono_gap,
        "chunked gap {chunk_gap} not strictly below monolithic {mono_gap}"
    );
    assert_eq!(mono.len(), 4);
    assert_eq!(
        mono, chunked,
        "chunked completions must be bit-identical to monolithic"
    );
}

#[test]
fn paged_hybrid_admits_like_fixed_when_blocks_are_plentiful() {
    // Regression: the block-aware scheduler view must not shrink below
    // hybrid's `min_free` threshold just because the queue is short —
    // only a genuine block shortage may defer admission.
    for cache in [
        CacheKind::Fixed,
        CacheKind::Paged { block_size: 16, n_blocks: None },
    ] {
        let mut e = Engine::new(
            SimBackend::gqa(3),
            EngineConfig {
                policy: PolicyKind::Hybrid { min_free: 2 },
                cache,
                ..Default::default()
            },
        );
        e.submit(Request::from_text(0, "long running seq", 8));
        assert_eq!(e.step().unwrap(), StepPlan::admit_monolithic(1));
        e.submit(Request::from_text(1, "late arrival", 2));
        // 1 active, 2 free slots, 1 queued, blocks plentiful: the hybrid
        // threshold is met, so both cache kinds admit immediately.
        assert_eq!(
            e.step().unwrap(),
            StepPlan::admit_monolithic(1),
            "{cache:?} deferred"
        );
        e.run_to_completion().unwrap();
        e.slots_check().unwrap();
    }
}

#[test]
fn paged_mla_layout_runs_the_full_loop() {
    let mut e = Engine::new(
        SimBackend::mla(8, 4),
        EngineConfig {
            cache: CacheKind::Paged { block_size: 8, n_blocks: None },
            ..Default::default()
        },
    );
    let reqs: Vec<Request> = (0..12)
        .map(|i| Request::from_text(i, "the latent cache pages", 6))
        .collect();
    let comps = e.generate(reqs).unwrap();
    assert_eq!(comps.len(), 12);
    assert!(comps.iter().all(|c| c.tokens.len() == 6));
    assert_eq!(e.cache_stats().blocks_in_use, 0);
    e.slots_check().unwrap();
}

/// The acceptance scenario: same total cache byte budget, mixed-context
/// workload of short prompts. The fixed pool reserves worst-case rows, so
/// its byte budget only buys 4 slots; the paged pool spends blocks on
/// actual demand and admits all 8 short requests concurrently.
#[test]
fn paged_admits_more_short_sequences_under_the_same_byte_budget() {
    let capacity = 64usize;
    let block_size = 16usize;
    // Fixed: 4 slots x 64 tokens reserved = 256 token-rows of budget.
    let mut fixed = Engine::new(
        SimBackend::new(SimConfig { capacity, prefill_seq: capacity, ..SimConfig::gqa(4) })
            .unwrap(),
        EngineConfig::default(),
    );
    // Paged: 8 slots over the SAME budget — 16 blocks x 16 tokens = 256.
    let mut paged = Engine::new(
        SimBackend::new(SimConfig { capacity, prefill_seq: capacity, ..SimConfig::gqa(8) })
            .unwrap(),
        EngineConfig {
            cache: CacheKind::Paged { block_size, n_blocks: Some(16) },
            ..Default::default()
        },
    );
    assert_eq!(
        fixed.cache_stats().bytes_total,
        paged.cache_stats().bytes_total,
        "the comparison is only fair at equal byte budgets"
    );

    // 8 short requests: prompt 8 + max_new 8 -> bounded demand 15 tokens
    // = 1 block each, where the fixed pool would reserve 64 tokens each.
    for e in [&mut fixed, &mut paged] {
        for i in 0..8 {
            e.submit(Request::from_text(i, "short ask", 8));
        }
        e.run_to_completion().unwrap();
        e.slots_check().unwrap();
    }
    let fixed_comps = fixed.take_completions();
    let paged_comps = paged.take_completions();
    assert_eq!(fixed_comps.len(), 8);
    assert_eq!(paged_comps.len(), 8);

    // First admission wave: the fixed pool is capped by its 4 worst-case
    // slots; the paged pool admits all 8 at once.
    let fixed_wave = fixed.admission_log()[0].1.len();
    let paged_wave = paged.admission_log()[0].1.len();
    assert_eq!(fixed_wave, 4, "fixed admits its slot count");
    assert_eq!(paged_wave, 8, "paged admits the whole burst");
    assert!(
        paged_wave > fixed_wave,
        "paged must admit strictly more concurrent sequences"
    );

    // And both engines produce the same tokens per request (the sim model
    // is batch-invariant, so concurrency does not change content).
    let mut f = fixed_comps;
    f.sort_by_key(|c| c.id);
    let mut p = paged_comps;
    p.sort_by_key(|c| c.id);
    for (a, b) in f.iter().zip(p.iter()) {
        assert_eq!(a.tokens, b.tokens, "request {} diverged", a.id);
    }
}

// ---------------------------------------------------------------------------
// Prefix sharing: a burst of same-prefix requests admits strictly more
// concurrent sequences than `--prefix-cache off` at an equal block budget,
// with bit-identical completions — both layouts, chunked and monolithic.
// ---------------------------------------------------------------------------

/// Seed one request to populate the prefix cache, then fire a burst of 8
/// identical-prompt requests. Returns (max concurrent sequences, first
/// burst admission wave, completions sorted by id, final cache stats).
fn prefix_burst(
    mla: bool,
    policy: PolicyKind,
    prefix_on: bool,
) -> (usize, usize, Vec<(u64, Vec<i32>)>, Engine) {
    let capacity = 64usize;
    let base = if mla { SimConfig::mla(8, 4) } else { SimConfig::gqa(8) };
    let mut e = Engine::new(
        SimBackend::new(SimConfig { capacity, prefill_seq: capacity, ..base }).unwrap(),
        EngineConfig {
            policy,
            cache: CacheKind::Paged { block_size: 8, n_blocks: Some(16) },
            prefix_cache: prefix_on,
            ..Default::default()
        },
    );
    // 17 tokens: two full 8-token blocks become cacheable prefix.
    let prompt: Vec<i32> = (0..17).map(|i| (i * 13 + 7) % 251).collect();
    e.submit(Request::new(100, prompt.clone(), 4));
    e.run_to_completion().unwrap();
    e.take_completions();
    for i in 0..8 {
        e.submit(Request::new(i, prompt.clone(), 4));
    }
    let mut max_active = 0;
    while !e.is_idle() {
        e.step().unwrap();
        max_active = max_active.max(e.n_active());
    }
    e.slots_check().unwrap();
    let wave = e
        .admission_log()
        .get(1)
        .map(|(_, ids)| ids.len())
        .unwrap_or(0);
    let mut comps = e.take_completions();
    comps.sort_by_key(|c| c.id);
    let comps = comps.into_iter().map(|c| (c.id, c.tokens)).collect();
    (max_active, wave, comps, e)
}

#[test]
fn prefix_sharing_admits_more_same_prefix_sequences_bit_identically() {
    // The acceptance scenario, over both cache layouts and both a
    // monolithic and the chunked policy: each burst request's bounded
    // demand is 3 blocks unshared but only 1 beyond the cached 2-block
    // prefix, so a 16-block pool admits the whole burst of 8 (slot-capped)
    // instead of 5 — and every completion matches the unshared run
    // token-for-token.
    for mla in [false, true] {
        for policy in [
            PolicyKind::AdmitFirst,
            PolicyKind::Chunked { chunk_tokens: 8 },
        ] {
            let (off_active, off_wave, off_comps, _) =
                prefix_burst(mla, policy, false);
            let (on_active, on_wave, on_comps, e) = prefix_burst(mla, policy, true);
            assert!(
                on_active > off_active,
                "{policy:?} mla={mla}: prefix cache must admit strictly more \
                 concurrent sequences ({on_active} vs {off_active})"
            );
            assert_eq!(
                on_active, 8,
                "{policy:?} mla={mla}: sharing should reach the slot cap"
            );
            assert!(
                on_wave > off_wave,
                "{policy:?} mla={mla}: first burst wave {on_wave} vs {off_wave}"
            );
            assert_eq!(
                on_comps, off_comps,
                "{policy:?} mla={mla}: completions must be bit-identical to \
                 the unshared run"
            );
            let cs = e.cache_stats();
            let ps = cs.prefix.expect("prefix stats present when enabled");
            assert!(ps.hits >= 8, "every burst request hits: {ps:?}");
            assert!(
                ps.tokens_shared >= 8 * 16,
                "two full blocks shared per burst request: {ps:?}"
            );
            assert_eq!(cs.blocks_in_use, ps.blocks_cached, "only cache remains");
            if matches!(policy, PolicyKind::Chunked { .. }) {
                assert!(
                    e.metrics.counter("prefix_tokens_skipped") >= 8 * 16,
                    "chunked prefill must skip the shared prefix outright"
                );
            }
            e.slots_check().unwrap();
        }
    }
}

#[test]
fn prefix_cache_evicts_under_pressure_and_stays_correct() {
    // Pool of 8 blocks: a seed caches 2 prefix blocks; a later request
    // needing 7 blocks must evict cached blocks (LRU) rather than being
    // refused — blocks-free admission accounts eviction headroom.
    let capacity = 64usize;
    let mut e = Engine::new(
        SimBackend::new(SimConfig { capacity, prefill_seq: capacity, ..SimConfig::gqa(8) })
            .unwrap(),
        EngineConfig {
            cache: CacheKind::Paged { block_size: 8, n_blocks: Some(8) },
            prefix_cache: true,
            ..Default::default()
        },
    );
    e.submit(Request::new(0, (0..17).collect(), 4));
    e.run_to_completion().unwrap();
    assert_eq!(e.cache_stats().prefix.unwrap().blocks_cached, 2);
    // 50-token prompt + 4 new -> bounded 53 tokens = 7 blocks > the 6
    // unreserved; admission evicts from the cache to fit.
    e.submit(Request::new(1, (100..150).collect(), 4));
    e.run_to_completion().unwrap();
    let comps = e.take_completions();
    assert_eq!(comps.len(), 2);
    assert!(comps.iter().all(|c| c.tokens.len() == 4));
    let ps = e.cache_stats().prefix.unwrap();
    assert!(ps.evictions >= 1, "eviction must have made room: {ps:?}");
    e.slots_check().unwrap();
}

#[test]
fn prefix_cache_on_fixed_store_is_a_construction_error() {
    let r = Engine::try_new(
        SimBackend::gqa(4),
        EngineConfig { prefix_cache: true, ..Default::default() },
    );
    assert!(r.is_err(), "prefix cache requires the paged store");
}

#[test]
fn all_policies_complete_a_bursty_workload() {
    for policy in [
        PolicyKind::AdmitFirst,
        PolicyKind::DecodeFirst,
        PolicyKind::Hybrid { min_free: 4 },
    ] {
        let mut e = Engine::new(
            SimBackend::gqa(8),
            EngineConfig { policy, ..Default::default() },
        );
        let reqs: Vec<Request> = (0..30)
            .map(|i| Request::from_text(i, "burst", 1 + (i as usize % 7)))
            .collect();
        let comps = e.generate(reqs).unwrap();
        assert_eq!(comps.len(), 30, "{policy:?} lost requests");
        assert!(e.is_idle());
        e.slots_check().unwrap();
    }
}

// ---------------------------------------------------------------------------
// Speculative decoding: the propose/verify/rollback pipeline emits
// bit-identical greedy output with measurably fewer target iterations.
// ---------------------------------------------------------------------------

/// Engine over `cache`, either plain admit-first (`k = None`) or
/// speculative at depth `k` with a same-seed draft of the *other*
/// layout attached. The sim's state chain depends only on tokens +
/// seed — never on layout or rank — so the cross-layout draft agrees
/// with the target on every greedy token: a perfect proposer.
fn spec_engine(mla: bool, cache: CacheKind, prefix: bool, k: Option<usize>) -> Engine {
    let base = if mla { SimConfig::mla(8, 4) } else { SimConfig::gqa(8) };
    let policy = match k {
        Some(k) => PolicyKind::Speculative { k },
        None => PolicyKind::AdmitFirst,
    };
    let mut e = Engine::new(
        SimBackend::new(SimConfig { capacity: 64, prefill_seq: 64, ..base }).unwrap(),
        EngineConfig { policy, cache, prefix_cache: prefix, ..Default::default() },
    );
    if k.is_some() {
        let draft_base = if mla { SimConfig::gqa(8) } else { SimConfig::mla(8, 2) };
        e.set_draft(Box::new(
            SimBackend::new(SimConfig { capacity: 64, prefill_seq: 64, ..draft_base })
                .unwrap(),
        ))
        .unwrap();
    }
    e
}

/// Mixed workload: plain prompts, a one-char prompt, an empty prompt,
/// and a shared-prefix pair (exercises rollback over shared blocks when
/// the paged + prefix-cache combination runs it).
fn spec_reqs() -> Vec<Request> {
    let shared: Vec<i32> = (0..20).map(|i| (i * 7 + 3) % 251).collect();
    vec![
        Request::from_text(0, "speculate on this prompt", 12),
        Request::from_text(1, "b", 7),
        Request::new(2, vec![], 5),
        Request::new(3, shared.clone(), 9),
        Request::new(4, shared, 6),
    ]
}

/// The acceptance criteria, end to end: at temperature 0, `speculative:K`
/// completions are bit-identical to plain decode across {fixed,
/// paged+prefix-cache} x {GQA, MLA}; the high-agreement draft yields
/// strictly fewer target decode iterations; and the reported acceptance
/// rate is consistent with the counted proposals and accepts.
#[test]
fn speculative_decode_is_bit_identical_with_fewer_target_iterations() {
    for mla in [false, true] {
        for (cache, prefix) in [
            (CacheKind::Fixed, false),
            (CacheKind::Paged { block_size: 8, n_blocks: None }, true),
        ] {
            let mut plain = spec_engine(mla, cache, prefix, None);
            let a = plain.generate(spec_reqs()).unwrap();
            let serial_steps = plain.metrics.counter("decode_steps");
            for k in [2usize, 4] {
                let mut spec = spec_engine(mla, cache, prefix, Some(k));
                let b = spec.generate(spec_reqs()).unwrap();
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(&b) {
                    assert_eq!(x.id, y.id);
                    assert_eq!(
                        x.tokens, y.tokens,
                        "mla={mla} {cache:?} k={k}: speculative output diverged"
                    );
                }
                let spec_steps = spec.metrics.counter("decode_steps");
                assert!(
                    spec_steps < serial_steps,
                    "mla={mla} {cache:?} k={k}: speculation must take fewer \
                     target iterations ({spec_steps} vs {serial_steps})"
                );
                let s = spec.spec_stats();
                assert_eq!(s.steps, spec_steps, "every decode step verified");
                assert_eq!(
                    s.accepted, s.proposed,
                    "the same-seed draft never misses"
                );
                assert_eq!(s.acceptance_rate, 1.0);
                assert_eq!(
                    s.tokens,
                    plain.metrics.counter("decode_tokens"),
                    "verify steps emit exactly the serial decode stream"
                );
                assert!(s.tokens_per_step > 1.0, "k={k}: {}", s.tokens_per_step);
                spec.slots_check().unwrap();
            }
        }
    }
}

/// Registry-level fairness: two co-hosted engines both make progress
/// every sweep — a long chunked prefill on one model cannot starve the
/// other model's short decodes — and each engine's completions are
/// bit-identical to running it alone.
#[test]
fn registry_steps_engines_fairly_and_preserves_per_engine_results() {
    use transmla::server::{EngineRegistry, RoutePolicy};

    let long_prompt = "x".repeat(60);
    let build_long = || {
        Engine::new(
            SimBackend::gqa(4),
            EngineConfig {
                policy: PolicyKind::Chunked { chunk_tokens: 4 },
                ..Default::default()
            },
        )
    };
    let build_short = || Engine::new(SimBackend::mla(4, 8), EngineConfig::default());
    let long_reqs = || vec![Request::from_text(0, &long_prompt, 4)]; // 15 prefill chunks
    let short_reqs = || {
        (0..3)
            .map(|i| Request::from_text(10 + i, "quick", 2))
            .collect::<Vec<_>>()
    };

    let mut reg = EngineRegistry::new(RoutePolicy::RoundRobin);
    reg.register("slow-prefill", build_long()).unwrap();
    reg.register("fast-decode", build_short()).unwrap();
    reg.validate().unwrap();
    for r in long_reqs() {
        reg.get_mut("slow-prefill").unwrap().submit(r);
    }
    for r in short_reqs() {
        reg.get_mut("fast-decode").unwrap().submit(r);
    }

    // The fair sweep: while the long prompt is still chunking through
    // prefill, the other engine must finish its whole workload — its
    // decodes are never starved by the co-hosted model.
    let mut fast_done_while_slow_prefilling = false;
    while !reg.is_idle() {
        reg.step_non_idle().unwrap();
        if reg.get("fast-decode").unwrap().is_idle()
            && !reg.get("slow-prefill").unwrap().is_idle()
        {
            fast_done_while_slow_prefilling = true;
        }
    }
    assert!(
        fast_done_while_slow_prefilling,
        "co-hosted engine was starved by the other model's long prefill"
    );

    let mut served = reg.take_completions();
    served.sort_by_key(|c| c.id);
    assert_eq!(served.len(), 4);
    assert!(served.iter().all(|c| !c.model.is_empty()));

    // Bit-parity with solo runs of the same engines and requests.
    let solo_long = build_long().generate(long_reqs()).unwrap();
    let solo_short = build_short().generate(short_reqs()).unwrap();
    let solo: Vec<_> = solo_long.into_iter().chain(solo_short).collect();
    for (s, r) in served.iter().zip(solo.iter()) {
        assert_eq!(s.id, r.id);
        assert_eq!(s.tokens, r.tokens, "registry run diverged for id {}", s.id);
    }
}
