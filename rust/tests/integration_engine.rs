//! Integration: the serving engine end-to-end (continuous batching,
//! slot recycling, determinism, server protocol) over the real PJRT
//! executables.

use std::path::Path;
use transmla::config::EngineConfig;
use transmla::coordinator::engine::Arch;
use transmla::coordinator::{Engine, ModelBundle, Request};
use transmla::model::init_gqa;
use transmla::runtime::Runtime;

fn engine(seed: u64) -> Engine {
    let rt = Runtime::new(Path::new("artifacts")).expect("make artifacts");
    let cfg = rt.manifest.configs["llama2tiny"].clone();
    let params = init_gqa(&cfg, 3);
    let bundle = ModelBundle::load(&rt, "llama2tiny", Arch::Gqa, 8, params).unwrap();
    Engine::new(bundle, EngineConfig { seed, ..Default::default() })
}

#[test]
fn generates_requested_token_counts() {
    let mut e = engine(0);
    let reqs = vec![
        Request::from_text(0, "hello world", 5),
        Request::from_text(1, "the quick brown fox", 9),
        Request::from_text(2, "a", 3),
    ];
    let comps = e.generate(reqs).unwrap();
    assert_eq!(comps.len(), 3);
    assert_eq!(comps[0].tokens.len(), 5);
    assert_eq!(comps[1].tokens.len(), 9);
    assert_eq!(comps[2].tokens.len(), 3);
    e.slots_check().unwrap();
    assert!(e.is_idle());
}

#[test]
fn greedy_decode_is_deterministic_and_batch_invariant() {
    // The same prompt must yield the same greedy tokens whether it runs
    // alone or batched with other requests (slot isolation).
    let mut e1 = engine(1);
    let solo = e1
        .generate(vec![Request::from_text(0, "the model rotates", 8)])
        .unwrap();

    let mut e2 = engine(2);
    let mixed = e2
        .generate(vec![
            Request::from_text(0, "the model rotates", 8),
            Request::from_text(1, "completely different prompt here", 12),
            Request::from_text(2, "yet another one", 6),
        ])
        .unwrap();

    assert_eq!(solo[0].tokens, mixed[0].tokens, "slot cross-talk detected");
}

#[test]
fn more_requests_than_slots_recycles() {
    let mut e = engine(3);
    let reqs: Vec<Request> = (0..20)
        .map(|i| Request::from_text(i, "abcdefgh", 4))
        .collect();
    let comps = e.generate(reqs).unwrap();
    assert_eq!(comps.len(), 20);
    assert!(e.metrics.counter("completed") == 20);
    assert!(e.metrics.counter("decode_steps") > 0);
    e.slots_check().unwrap();
}

#[test]
fn throughput_counters_consistent() {
    let mut e = engine(4);
    let reqs: Vec<Request> = (0..8)
        .map(|i| Request::from_text(i, "some text prompt", 6))
        .collect();
    let comps = e.generate(reqs).unwrap();
    let generated: usize = comps.iter().map(|c| c.tokens.len()).sum();
    // first token comes from prefill; the rest from decode
    let decoded = e.metrics.counter("decode_tokens") as usize;
    assert_eq!(decoded, generated - comps.len());
    assert!(e.decode_throughput() > 0.0);
}

#[test]
fn server_roundtrip() {
    use std::sync::mpsc::channel;
    let addr = "127.0.0.1:17433";
    let (tx, rx) = channel::<()>();
    let handle = std::thread::spawn(move || {
        let mut e = engine(5);
        tx.send(()).unwrap();
        transmla::server::serve(&mut e, addr).unwrap();
    });
    rx.recv().unwrap();
    std::thread::sleep(std::time::Duration::from_millis(200));
    let resp = transmla::server::client_request(addr, "hello server", 4).unwrap();
    assert!(resp.get("text").is_some(), "{resp:?}");
    assert_eq!(resp.get("prompt_len").and_then(|x| x.as_usize()), Some(12));
    transmla::server::client_shutdown(addr).unwrap();
    handle.join().unwrap();
}
