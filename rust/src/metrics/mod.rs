//! Serving metrics: counters, latency histograms, throughput meters.

use crate::util::timing::BenchStats;
use std::collections::BTreeMap;
use std::time::Instant;

/// Percentile summary of one latency series (seconds).
#[derive(Clone, Copy, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

/// One-shot percentile summary of a caller-held sample slice (`None`
/// if empty) — the standalone counterpart of [`Metrics::summary`] for
/// code that aggregates its own series, e.g. the workload report's
/// client-side TTFT/TPOT tables.
pub fn summarize(samples: &[f64]) -> Option<Summary> {
    if samples.is_empty() {
        return None;
    }
    let st = BenchStats::new(samples.to_vec());
    Some(Summary {
        n: samples.len(),
        mean: st.mean(),
        p50: st.percentile(50.0),
        p95: st.percentile(95.0),
        p99: st.percentile(99.0),
        max: st.max(),
    })
}

/// Most recent samples retained per series: percentiles are computed
/// over a sliding window so a long-running server holds bounded memory.
/// Lifetime aggregates (count + sum) are tracked separately and stay
/// exact — `decode_throughput` style rates never lose trimmed history.
const MAX_SAMPLES_PER_SERIES: usize = 4096;

/// Engine-wide metrics registry.
#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    counters: BTreeMap<String, u64>,
    samples: BTreeMap<String, Vec<f64>>,
    /// Lifetime (count, sum) per sample series, immune to window trims.
    totals: BTreeMap<String, (u64, f64)>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            started: Instant::now(),
            counters: BTreeMap::new(),
            samples: BTreeMap::new(),
            totals: BTreeMap::new(),
        }
    }

    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Record a latency/duration sample in seconds.
    pub fn observe(&mut self, name: &str, seconds: f64) {
        let t = self.totals.entry(name.to_string()).or_insert((0, 0.0));
        t.0 += 1;
        t.1 += seconds;
        let v = self.samples.entry(name.to_string()).or_default();
        if v.len() >= MAX_SAMPLES_PER_SERIES {
            // Drop the older half; amortized O(1) per observe.
            v.drain(..MAX_SAMPLES_PER_SERIES / 2);
        }
        v.push(seconds);
    }

    /// Lifetime sum of a sample series (exact even after window trims).
    pub fn total(&self, name: &str) -> f64 {
        self.totals.get(name).map(|t| t.1).unwrap_or(0.0)
    }

    /// Lifetime observation count of a sample series.
    pub fn n_observed(&self, name: &str) -> u64 {
        self.totals.get(name).map(|t| t.0).unwrap_or(0)
    }

    pub fn stats(&self, name: &str) -> Option<BenchStats> {
        self.samples
            .get(name)
            .filter(|s| !s.is_empty())
            .map(|s| BenchStats::new(s.clone()))
    }

    /// Percentile summary of a sample series (None if empty/missing).
    pub fn summary(&self, name: &str) -> Option<Summary> {
        self.stats(name).map(|st| Summary {
            n: st.samples.len(),
            mean: st.mean(),
            p50: st.percentile(50.0),
            p95: st.percentile(95.0),
            p99: st.percentile(99.0),
            max: st.max(),
        })
    }

    /// All counters, for external reporting (server `stats` command).
    pub fn counters(&self) -> &BTreeMap<String, u64> {
        &self.counters
    }

    /// Names of all recorded sample series.
    pub fn sample_names(&self) -> Vec<String> {
        self.samples.keys().cloned().collect()
    }

    pub fn elapsed_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Tokens/s for a counter over the metrics lifetime.
    pub fn rate(&self, counter: &str) -> f64 {
        self.counter(counter) as f64 / self.elapsed_s().max(1e-9)
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("{k}: {v}\n"));
        }
        for (k, s) in &self.samples {
            let st = BenchStats::new(s.clone());
            out.push_str(&format!(
                "{k}: n={} mean={:.3}ms p50={:.3}ms p95={:.3}ms p99={:.3}ms max={:.3}ms\n",
                s.len(),
                st.mean() * 1e3,
                st.percentile(50.0) * 1e3,
                st.percentile(95.0) * 1e3,
                st.percentile(99.0) * 1e3,
                st.max() * 1e3,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_samples() {
        let mut m = Metrics::new();
        m.inc("tokens", 5);
        m.inc("tokens", 3);
        assert_eq!(m.counter("tokens"), 8);
        m.observe("step", 0.010);
        m.observe("step", 0.020);
        let st = m.stats("step").unwrap();
        assert!((st.mean() - 0.015).abs() < 1e-12);
        assert!(m.report().contains("tokens: 8"));
    }

    #[test]
    fn standalone_summarize_matches_registry_summary() {
        assert!(summarize(&[]).is_none());
        let samples: Vec<f64> = (1..=100).map(|i| i as f64 / 100.0).collect();
        let mut m = Metrics::new();
        for &s in &samples {
            m.observe("lat", s);
        }
        let a = summarize(&samples).unwrap();
        let b = m.summary("lat").unwrap();
        assert_eq!(a.n, b.n);
        assert!((a.p50 - b.p50).abs() < 1e-12);
        assert!((a.p99 - b.p99).abs() < 1e-12);
        assert!((a.max - b.max).abs() < 1e-12);
    }

    #[test]
    fn missing_series_is_none() {
        let m = Metrics::new();
        assert!(m.stats("nope").is_none());
        assert!(m.summary("nope").is_none());
        assert_eq!(m.counter("nope"), 0);
    }

    #[test]
    fn window_bounds_samples_but_totals_stay_exact() {
        let mut m = Metrics::new();
        let n = MAX_SAMPLES_PER_SERIES * 2 + 10;
        for _ in 0..n {
            m.observe("step", 1.0);
        }
        let kept = m.stats("step").unwrap().samples.len();
        assert!(kept <= MAX_SAMPLES_PER_SERIES, "window leaked: {kept}");
        assert_eq!(m.n_observed("step"), n as u64);
        assert!((m.total("step") - n as f64).abs() < 1e-9);
    }

    #[test]
    fn summary_percentiles_ordered() {
        let mut m = Metrics::new();
        for i in 0..100 {
            m.observe("lat", (i + 1) as f64 / 100.0);
        }
        let s = m.summary("lat").unwrap();
        assert_eq!(s.n, 100);
        assert!((s.p50 - 0.50).abs() < 0.02, "p50={}", s.p50);
        assert!((s.p95 - 0.95).abs() < 0.02, "p95={}", s.p95);
        assert!((s.p99 - 0.99).abs() < 0.02, "p99={}", s.p99);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert!(m.report().contains("p99="));
        assert_eq!(m.sample_names(), vec!["lat".to_string()]);
    }
}
