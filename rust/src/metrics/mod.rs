//! Serving metrics: counters, latency histograms, throughput meters.

use crate::util::timing::BenchStats;
use std::collections::BTreeMap;
use std::time::Instant;

/// Engine-wide metrics registry.
#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    counters: BTreeMap<String, u64>,
    samples: BTreeMap<String, Vec<f64>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            started: Instant::now(),
            counters: BTreeMap::new(),
            samples: BTreeMap::new(),
        }
    }

    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Record a latency/duration sample in seconds.
    pub fn observe(&mut self, name: &str, seconds: f64) {
        self.samples
            .entry(name.to_string())
            .or_default()
            .push(seconds);
    }

    pub fn stats(&self, name: &str) -> Option<BenchStats> {
        self.samples
            .get(name)
            .filter(|s| !s.is_empty())
            .map(|s| BenchStats::new(s.clone()))
    }

    pub fn elapsed_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Tokens/s for a counter over the metrics lifetime.
    pub fn rate(&self, counter: &str) -> f64 {
        self.counter(counter) as f64 / self.elapsed_s().max(1e-9)
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("{k}: {v}\n"));
        }
        for (k, s) in &self.samples {
            let st = BenchStats::new(s.clone());
            out.push_str(&format!(
                "{k}: n={} mean={:.3}ms p50={:.3}ms p95={:.3}ms max={:.3}ms\n",
                s.len(),
                st.mean() * 1e3,
                st.percentile(50.0) * 1e3,
                st.percentile(95.0) * 1e3,
                st.max() * 1e3,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_samples() {
        let mut m = Metrics::new();
        m.inc("tokens", 5);
        m.inc("tokens", 3);
        assert_eq!(m.counter("tokens"), 8);
        m.observe("step", 0.010);
        m.observe("step", 0.020);
        let st = m.stats("step").unwrap();
        assert!((st.mean() - 0.015).abs() < 1e-12);
        assert!(m.report().contains("tokens: 8"));
    }

    #[test]
    fn missing_series_is_none() {
        let m = Metrics::new();
        assert!(m.stats("nope").is_none());
        assert_eq!(m.counter("nope"), 0);
    }
}
