//! TransMLA: migrating GQA models to MLA with absorb-based serving speedup.
//!
//! Reproduction of Meng et al., *"TransMLA: Multi-Head Latent Attention Is
//! All You Need"* (2025) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L1/L2 (build-time Python)** — Pallas decode-attention kernels and the
//!   JAX transformer models, AOT-lowered to HLO text in `artifacts/`.
//! * **L3 (this crate)** — the serving stack, the full TransMLA conversion
//!   toolchain (RoRoPE, FreqFold, BKV, joint PCA, Absorb), a training loop,
//!   evaluation drivers for every table/figure in the paper, and an
//!   analytical accelerator model for the paper's three GPU profiles.
//!
//! # Serving architecture (the StepPlan pipeline)
//!
//! The serving core is three decoupled layers around one idea: each
//! engine iteration executes a scheduler-built **plan**, not a single
//! mutually-exclusive action. A `StepPlan` composes admissions, bounded
//! prefill work, and a decode step in the SAME iteration, so a long
//! prompt enters the cache chunk-by-chunk while active sequences keep
//! decoding — prefill is compute-bound, decode is memory-bound, and
//! interleaving them is where the TTFT/TPOT frontier moves.
//!
//! * [`backend`] — the [`backend::ExecBackend`] trait with three entry
//!   points: batched `prefill` (rows-sized), resumable single-sequence
//!   `prefill_chunk` (writes straight into the sequence's cache rows),
//!   and masked `decode`. [`backend::XlaBackend`] executes the AOT
//!   artifacts through PJRT (chunking recomputes through the fixed-shape
//!   prefill artifact — the AOT ABI is untouched); [`backend::SimBackend`]
//!   is a deterministic pure-Rust model of the same contract for both
//!   `CacheLayout::Gqa` and `CacheLayout::Mla` with *exact* chunk resume,
//!   so the engine, server, benches, and integration tests run
//!   **hermetically on a bare checkout** — no `make artifacts`, no XLA
//!   runtime. The [`backend::CacheStore`] seam lets the engine run over
//!   either the fixed slot pool (what the artifacts bake in) or the
//!   paged block pool (`SimBackend` drives both, completion-identically,
//!   chunked or monolithic). With `--prefix-cache on`, the paged pool
//!   additionally shares cached prompt-prefix blocks across sequences
//!   (copy-on-write protected, LRU-evicted under pressure) — a burst of
//!   same-prefix requests admits far beyond the unshared block budget,
//!   bit-identically.
//!
//! A prose tour of the architecture lives in `docs/ARCHITECTURE.md`; the
//! server wire protocol is specified in `docs/PROTOCOL.md`.
//! * [`coordinator::scheduler`] — pluggable `SchedulePolicy` building a
//!   per-iteration `StepPlan` over the three queues (waiting →
//!   prefilling → decoding), selected via [`config::EngineConfig`]:
//!   admit-first / decode-first / hybrid emit degenerate plans
//!   (admit+monolithic-prefill XOR decode — the pre-plan behaviour,
//!   ordering-identical); `chunked:N` admits eagerly, advances the
//!   prefilling queue by at most N prompt tokens, and decodes in the
//!   same iteration, bounding the decode stall to one chunk. An
//!   anti-starvation contract (never idle with pending work) is
//!   property-tested over every policy.
//! * [`coordinator::seqmgr`] — `SequenceManager`: slot lifecycle with the
//!   `Prefilling` → `Decoding` phase split and per-slot prefilled
//!   watermark, completion rules, and TTFT (queue_s + prefill_s) / TPOT
//!   / latency accounting.
//!
//! [`coordinator::engine::Engine`] composes the three and exposes
//! `submit` / `step` / `generate` / `take_completions`.
//!
//! # Module map
//!
//! | module        | role                                                    |
//! |---------------|---------------------------------------------------------|
//! | [`backend`]   | execution backends: `ExecBackend` (prefill / prefill_chunk / decode), `SimBackend`, `XlaBackend`, `ModelBundle` |
//! | [`coordinator`] | engine (StepPlan executor), scheduler (StepPlan builder: admit-first / decode-first / hybrid / chunked), sequence manager (phase + watermark), sampling, request types |
//! | [`kvcache`]   | fixed slot pool + paged block pool (`PagedKvCache`: ref-counted 16-token blocks, per-sequence block tables, admission-time reservation) with cross-sequence prefix sharing (`PrefixIndex`: block-granular prefix hashes, copy-on-write, LRU eviction), lossy block codecs (`quant::QuantKind`: int8 / simulated fp8-e4m3 per-row encoding with decode-on-read staging — same byte budget, ~3× the blocks), and layout-aware byte accounting (GQA vs MLA) |
//! | [`runtime`]   | PJRT artifact loading/execution (real `xla` bindings or the vendored stub) |
//! | [`server`]    | TCP JSONL front-end (protocol v2): `EngineRegistry` hosting N named engines with routed requests (`default:<name>` / round-robin / least-loaded), a fair multi-engine stepper, per-engine stats, and in-band protocol errors |
//! | [`workload`]  | open-loop traffic harness: seeded trace generator (Poisson / bursty / diurnal-ramp × agent/chat tenants), loopback replay driver, SLO/goodput report (JSONL + HTML) |
//! | [`qeval`]     | serving-level quality harness: JSONL datasets, pluggable scorers (exact / contains / levenshtein / regex / json), cross-model A/B driver over protocol v2, per-model × per-scorer report with baseline deltas |
//! | [`metrics`]   | counters + latency series with p50/p95/p99 summaries     |
//! | [`config`]    | model/engine/policy/hardware configuration               |
//! | [`convert`]   | TransMLA conversion toolchain (RoRoPE, FreqFold, BKV, PCA, Absorb) |
//! | [`model`]     | parameter containers, init, checkpoint IO                |
//! | [`train`]     | AOT train-step driver                                    |
//! | [`eval`]      | perplexity/accuracy + paper experiment drivers           |
//! | [`corpus`]    | deterministic synthetic byte corpus                      |
//! | [`perfmodel`] | analytical GPU serving model (paper Fig. 4 / Table 4), codec-aware cache traffic (`CacheModel`), and roofline-driven knob picking (`autotune`) |
//! | [`tensor`], [`linalg`] | dense f32 substrate for the converter          |
//! | [`io`], [`json`], [`util`] | checkpoint archive, JSON, PRNG/timing/prop-testing |
//!
//! Python never runs on the request path, and with the `SimBackend` neither
//! does XLA: a bare `cargo test -q` exercises the full admit → decode →
//! complete loop in both cache layouts.

pub mod backend;
pub mod config;
pub mod convert;
pub mod coordinator;
pub mod corpus;
pub mod eval;
pub mod io;
pub mod json;
pub mod kvcache;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod perfmodel;
pub mod qeval;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod train;
pub mod util;
pub mod workload;

pub use anyhow::{anyhow, bail, Context, Result};
