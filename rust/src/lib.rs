//! TransMLA: migrating GQA models to MLA with absorb-based serving speedup.
//!
//! Reproduction of Meng et al., *"TransMLA: Multi-Head Latent Attention Is
//! All You Need"* (2025) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L1/L2 (build-time Python)** — Pallas decode-attention kernels and the
//!   JAX transformer models, AOT-lowered to HLO text in `artifacts/`.
//! * **L3 (this crate)** — the serving coordinator (continuous batching,
//!   KV-cache management, PJRT runtime), the full TransMLA conversion
//!   toolchain (RoRoPE, FreqFold, BKV, joint PCA, Absorb) over an in-repo
//!   tensor/linalg substrate, a training loop, evaluation drivers for every
//!   table/figure in the paper, and an analytical accelerator model for the
//!   paper's three GPU profiles.
//!
//! Python never runs on the request path: once `make artifacts` has been
//! executed, everything here is self-contained.

pub mod config;
pub mod convert;
pub mod coordinator;
pub mod corpus;
pub mod eval;
pub mod io;
pub mod json;
pub mod kvcache;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod perfmodel;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod train;
pub mod util;

pub use anyhow::{anyhow, bail, Context, Result};
