//! Synthetic byte-level corpus: the stand-in for the paper's pretraining /
//! calibration text (SmolLM corpus, WikiText-2 — see DESIGN.md's
//! substitution table).
//!
//! A deterministic stochastic grammar produces English-like sentences with
//! long-range structure (topic words recur within a paragraph), giving the
//! byte LM something real to learn: local orthography, word boundaries,
//! punctuation, and paragraph-level reuse. Perplexity deltas on held-out
//! text from the same distribution play the role of the paper's benchmark
//! deltas.

use crate::util::Rng;

const SUBJECTS: &[&str] = &[
    "the model", "a transformer", "the latent cache", "the scheduler",
    "our system", "the decoder", "a rotation", "the compiler",
    "the attention head", "the router", "a query", "the key head",
];

const VERBS: &[&str] = &[
    "compresses", "rotates", "absorbs", "predicts", "stores", "serves",
    "reduces", "balances", "concentrates", "projects", "recovers", "merges",
];

const OBJECTS: &[&str] = &[
    "the kv cache", "positional information", "a latent vector",
    "the principal components", "every batch", "the throughput",
    "low rank structure", "the context window", "a shared key",
    "the rope frequencies", "token embeddings", "the memory budget",
];

const MODIFIERS: &[&str] = &[
    "quickly", "without loss", "at long context", "during decode",
    "after fine tuning", "in latent space", "per attention head",
    "with high fidelity", "under load", "at scale",
];

const CONNECTIVES: &[&str] = &[
    "meanwhile", "therefore", "in practice", "as a result", "moreover",
    "by contrast", "empirically",
];

/// Deterministic corpus generator. Same seed -> same byte stream.
pub struct CorpusGen {
    rng: Rng,
    topic: Vec<&'static str>,
    sentences_left: usize,
}

impl CorpusGen {
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x7355_608);
        let topic = pick_topic(&mut rng);
        CorpusGen { rng, topic, sentences_left: 6 }
    }

    fn sentence(&mut self) -> String {
        let r = &mut self.rng;
        // Topic words recur: pull from the paragraph topic 60% of the time.
        let mut pick = |pool: &[&'static str], topic_slot: usize| -> &'static str {
            if r.uniform() < 0.6 {
                self.topic[topic_slot]
            } else {
                pool[r.below(pool.len())]
            }
        };
        let s = pick(SUBJECTS, 0);
        let v = pick(VERBS, 1);
        let o = pick(OBJECTS, 2);
        let mut out = String::new();
        if self.rng.uniform() < 0.25 {
            out.push_str(CONNECTIVES[self.rng.below(CONNECTIVES.len())]);
            out.push_str(", ");
        }
        out.push_str(s);
        out.push(' ');
        out.push_str(v);
        out.push(' ');
        out.push_str(o);
        if self.rng.uniform() < 0.5 {
            out.push(' ');
            out.push_str(MODIFIERS[self.rng.below(MODIFIERS.len())]);
        }
        if self.rng.uniform() < 0.15 {
            out.push_str(&format!(" {} times", 2 + self.rng.below(31)));
        }
        out.push_str(". ");
        out
    }

    /// Produce `n` bytes of text.
    pub fn bytes(&mut self, n: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(n + 128);
        while out.len() < n {
            if self.sentences_left == 0 {
                out.extend_from_slice(b"\n\n");
                self.topic = pick_topic(&mut self.rng);
                self.sentences_left = 3 + self.rng.below(6);
            }
            let s = self.sentence();
            out.extend_from_slice(s.as_bytes());
            self.sentences_left -= 1;
        }
        out.truncate(n);
        out
    }
}

fn pick_topic(rng: &mut Rng) -> Vec<&'static str> {
    vec![
        SUBJECTS[rng.below(SUBJECTS.len())],
        VERBS[rng.below(VERBS.len())],
        OBJECTS[rng.below(OBJECTS.len())],
    ]
}

/// Token dataset with deterministic train/val split and batch sampling.
pub struct Corpus {
    pub train: Vec<u8>,
    pub val: Vec<u8>,
}

impl Corpus {
    /// Generate `total` bytes, 90/10 split.
    pub fn synthetic(seed: u64, total: usize) -> Self {
        let mut g = CorpusGen::new(seed);
        let all = g.bytes(total);
        let split = total * 9 / 10;
        Corpus { train: all[..split].to_vec(), val: all[split..].to_vec() }
    }

    /// Sample a [b, t] batch of token ids (bytes) from the training split.
    pub fn sample_batch(&self, b: usize, t: usize, rng: &mut Rng) -> Vec<i32> {
        let mut out = Vec::with_capacity(b * t);
        for _ in 0..b {
            let start = rng.below(self.train.len().saturating_sub(t + 1));
            out.extend(self.train[start..start + t].iter().map(|&x| x as i32));
        }
        out
    }

    /// Deterministic sequential val batches [b, t] (for perplexity).
    pub fn val_batches(&self, b: usize, t: usize) -> Vec<Vec<i32>> {
        let per = self.val.len() / (b * t);
        (0..per)
            .map(|i| {
                self.val[i * b * t..(i + 1) * b * t]
                    .iter()
                    .map(|&x| x as i32)
                    .collect()
            })
            .collect()
    }

    /// A human-ish prompt sampled from val (for the case study, Table 5).
    pub fn prompt(&self, len: usize, idx: usize) -> Vec<i32> {
        let start = (idx * 97) % self.val.len().saturating_sub(len + 1).max(1);
        self.val[start..start + len].iter().map(|&x| x as i32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = Corpus::synthetic(1, 10_000);
        let b = Corpus::synthetic(1, 10_000);
        assert_eq!(a.train, b.train);
        assert_eq!(a.val, b.val);
    }

    #[test]
    fn text_is_ascii_english_like() {
        let c = Corpus::synthetic(2, 5_000);
        assert!(c.train.iter().all(|&b| b.is_ascii()));
        let s = String::from_utf8(c.train.clone()).unwrap();
        assert!(s.contains(". "));
        assert!(s.split_whitespace().count() > 100);
    }

    #[test]
    fn batches_have_right_shape_and_range() {
        let c = Corpus::synthetic(3, 50_000);
        let mut rng = Rng::new(0);
        let b = c.sample_batch(4, 128, &mut rng);
        assert_eq!(b.len(), 4 * 128);
        assert!(b.iter().all(|&x| (0..256).contains(&x)));
        let vb = c.val_batches(2, 64);
        assert!(!vb.is_empty());
        assert!(vb.iter().all(|v| v.len() == 128));
    }

    #[test]
    fn topics_recur_within_paragraphs() {
        // Long-range structure: some word appears many times.
        let c = Corpus::synthetic(4, 20_000);
        let s = String::from_utf8(c.train).unwrap();
        let max_count = SUBJECTS
            .iter()
            .map(|w| s.matches(w).count())
            .max()
            .unwrap();
        assert!(max_count > 10, "{max_count}");
    }
}
