//! Model parameter containers: named tensors in the manifest's canonical
//! order, plus GQA initialization and checkpoint IO.
//!
//! The Rust side owns the weights end-to-end: it initializes them, trains
//! them through the AOT train-step executable, converts them with the
//! TransMLA toolchain, and serves them — Python never touches a weight at
//! runtime.

use crate::config::ModelConfig;
use crate::io::TensorArchive;
use crate::json::Json;
use crate::runtime::{ArtifactSpec, Value};
use crate::tensor::Tensor;
use crate::util::Rng;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Named parameter set with a canonical ordering (the artifact ABI).
#[derive(Clone, Debug)]
pub struct Params {
    pub keys: Vec<String>,
    pub tensors: Vec<Tensor>,
}

impl Params {
    pub fn new(keys: Vec<String>, tensors: Vec<Tensor>) -> Result<Self> {
        if keys.len() != tensors.len() {
            bail!("{} keys vs {} tensors", keys.len(), tensors.len());
        }
        Ok(Params { keys, tensors })
    }

    pub fn get(&self, key: &str) -> Result<&Tensor> {
        let i = self
            .keys
            .iter()
            .position(|k| k == key)
            .with_context(|| format!("param `{key}` missing"))?;
        Ok(&self.tensors[i])
    }

    pub fn set(&mut self, key: &str, t: Tensor) -> Result<()> {
        let i = self
            .keys
            .iter()
            .position(|k| k == key)
            .with_context(|| format!("param `{key}` missing"))?;
        self.tensors[i] = t;
        Ok(())
    }

    pub fn n_params(&self) -> usize {
        self.tensors.iter().map(Tensor::len).sum()
    }

    /// Flatten to runtime Values in canonical order.
    pub fn values(&self) -> Vec<Value> {
        self.tensors.iter().cloned().map(Value::F32).collect()
    }

    /// Zeroed clone (Adam moment buffers).
    pub fn zeros_like(&self) -> Params {
        Params {
            keys: self.keys.clone(),
            tensors: self
                .tensors
                .iter()
                .map(|t| Tensor::zeros(&t.shape))
                .collect(),
        }
    }

    /// Validate against an artifact's expected parameter shapes.
    pub fn check_against(&self, spec: &ArtifactSpec) -> Result<()> {
        if self.keys != spec.params {
            bail!(
                "param order mismatch for `{}`:\n  have {:?}\n  want {:?}",
                spec.name, self.keys, spec.params
            );
        }
        for (i, t) in self.tensors.iter().enumerate() {
            let want = &spec.inputs[i].shape;
            if &t.shape != want {
                bail!(
                    "param `{}` shape {:?} != artifact `{}` expects {:?}",
                    self.keys[i], t.shape, spec.name, want
                );
            }
        }
        Ok(())
    }

    pub fn save(&self, path: &Path, meta: Json) -> Result<()> {
        let mut ar = TensorArchive::new();
        for (k, t) in self.keys.iter().zip(&self.tensors) {
            ar.insert(k, t.clone());
        }
        let mut m = meta;
        m.set(
            "keys",
            Json::Arr(self.keys.iter().map(|k| Json::Str(k.clone())).collect()),
        );
        ar.meta = m;
        ar.save(path)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let ar = TensorArchive::load(path)?;
        let keys: Vec<String> = ar
            .meta
            .get("keys")
            .and_then(Json::as_arr)
            .context("checkpoint missing key order")?
            .iter()
            .map(|k| k.as_str().unwrap_or("").to_string())
            .collect();
        let tensors = keys
            .iter()
            .map(|k| ar.get(k).cloned())
            .collect::<Result<Vec<_>>>()?;
        Params::new(keys, tensors)
    }
}

/// GQA parameter key order — must mirror `model.GQA_KEYS` on the python
/// side (enforced at runtime by `Params::check_against`).
pub const GQA_KEYS: &[&str] = &[
    "embed", "wq", "wk", "wv", "wo", "ln1", "w_gate", "w_up", "w_down",
    "ln2", "ln_f", "lm_head",
];

pub const MLA_ABS_KEYS: &[&str] = &[
    "embed", "wq_rope", "wq_lat", "w_dkv", "w_krope", "wo_abs", "ln1",
    "w_gate", "w_up", "w_down", "ln2", "ln_f", "lm_head", "rope_freqs",
];

pub const MLA_TRAIN_KEYS: &[&str] = &[
    "embed", "wq", "wqr", "w_dkv", "w_krope", "w_uk", "w_uv", "wo", "ln1",
    "w_gate", "w_up", "w_down", "ln2", "ln_f", "lm_head", "rope_freqs",
];

pub const MERGED_KEYS: &[&str] = &[
    "embed", "wqm", "wk", "wv", "wo", "ln1", "w_gate", "w_up", "w_down",
    "ln2", "ln_f", "lm_head", "rope_freqs", "rope_mask",
];

fn keys_vec(keys: &[&str]) -> Vec<String> {
    keys.iter().map(|s| s.to_string()).collect()
}

/// Initialize a GQA model (same distribution family as the python-side
/// `init_gqa_params`: N(0, 0.02) projections, unit norms).
pub fn init_gqa(cfg: &ModelConfig, seed: u64) -> Params {
    let mut rng = Rng::new(seed);
    let (l, dm, f, v) = (cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab);
    let (hd, gd) = (cfg.q_dim(), cfg.kv_dim());
    let s = 0.02;
    let tensors = vec![
        Tensor::randn(&[v, dm], s, &mut rng),      // embed
        Tensor::randn(&[l, dm, hd], s, &mut rng),  // wq
        Tensor::randn(&[l, dm, gd], s, &mut rng),  // wk
        Tensor::randn(&[l, dm, gd], s, &mut rng),  // wv
        Tensor::randn(&[l, hd, dm], s, &mut rng),  // wo
        Tensor::ones(&[l, dm]),                    // ln1
        Tensor::randn(&[l, dm, f], s, &mut rng),   // w_gate
        Tensor::randn(&[l, dm, f], s, &mut rng),   // w_up
        Tensor::randn(&[l, f, dm], s, &mut rng),   // w_down
        Tensor::ones(&[l, dm]),                    // ln2
        Tensor::ones(&[dm]),                       // ln_f
        Tensor::randn(&[dm, v], s, &mut rng),      // lm_head
    ];
    Params::new(keys_vec(GQA_KEYS), tensors).unwrap()
}

/// Default per-pair RoPE frequency schedule of a d-dim head.
pub fn default_freqs(d: usize, theta: f64) -> Vec<f32> {
    (0..d / 2)
        .map(|l| theta.powf(-2.0 * l as f64 / d as f64) as f32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 64,
            d_model: 32,
            n_heads: 4,
            n_kv_groups: 2,
            head_dim: 8,
            n_layers: 2,
            d_ff: 48,
            max_seq: 16,
            rope_theta: 10000.0,
        }
    }

    #[test]
    fn init_shapes() {
        let cfg = tiny_cfg();
        let p = init_gqa(&cfg, 0);
        assert_eq!(p.get("wk").unwrap().shape, vec![2, 32, 16]);
        assert_eq!(p.get("ln_f").unwrap().shape, vec![32]);
        assert!(p.n_params() > 10_000);
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = tiny_cfg();
        let p = init_gqa(&cfg, 1);
        let path = std::env::temp_dir().join("transmla_model_test.tnz");
        p.save(&path, Json::obj()).unwrap();
        let q = Params::load(&path).unwrap();
        assert_eq!(p.keys, q.keys);
        for (a, b) in p.tensors.iter().zip(&q.tensors) {
            assert_eq!(a, b);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn get_set() {
        let cfg = tiny_cfg();
        let mut p = init_gqa(&cfg, 2);
        let t = Tensor::ones(&[2, 32]);
        p.set("ln1", t.clone()).unwrap();
        assert_eq!(p.get("ln1").unwrap(), &t);
        assert!(p.get("nope").is_err());
    }

    #[test]
    fn freqs_schedule() {
        let f = default_freqs(8, 10000.0);
        assert_eq!(f.len(), 4);
        assert!((f[0] - 1.0).abs() < 1e-6);
        assert!(f[3] < f[2] && f[2] < f[1] && f[1] < f[0]);
    }
}
