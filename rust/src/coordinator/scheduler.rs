//! Scheduling: per-iteration **step plans** over the three serving
//! queues — waiting (submitted, no slot yet) → prefilling (slot bound,
//! prompt entering the cache chunk-by-chunk) → decoding (emitting
//! tokens).
//!
//! In the memory-bound decode regime TransMLA targets, admission policy
//! dominates tail latency: a monolithic prefill call stalls every active
//! decode for the full prompt, so admitting one request can cost every
//! running sequence a step. The pre-StepPlan scheduler could only pick
//! *one* mutually-exclusive action per iteration (admit XOR decode);
//! a [`StepPlan`] instead composes admission, bounded prefill work, and
//! a decode step in the SAME iteration, which is what lets a long prompt
//! enter the cache without ever stalling decode for more than one chunk.
//!
//! Policies, selected via `EngineConfig::policy`:
//!
//!   * [`AdmitFirst`] — admit whenever a slot is free and prefill the
//!     admitted prompts to completion in one batched call (the original
//!     fused engine's behaviour; best TTFT, worst TPOT under load);
//!   * [`DecodeFirst`] — drain the active batch before admitting (best
//!     TPOT, worst TTFT);
//!   * [`Hybrid`] — admit only when at least `min_free` slots are free
//!     (or nothing is running), amortising each monolithic prefill stall
//!     over a bigger admission batch;
//!   * [`Chunked`] — the pipeline's native policy: admit eagerly (slot
//!     binding runs no model code), advance the prefilling queue by at
//!     most `chunk_tokens` prompt tokens, and decode in the same
//!     iteration. TPOT stall is bounded by one chunk instead of one
//!     prompt. With the prefix cache on, chunking is prefix-aware for
//!     free: a sequence admitted over shared blocks starts its prefill
//!     watermark at the shared coverage, so chunks fully covered by the
//!     cached prefix are never scheduled at all.
//!   * [`Speculative`] — admit like `admit-first`, but decode plans carry
//!     `speculate: Some(k)`: the engine's decode step becomes the
//!     draft-propose / target-verify loop emitting up to `k` tokens per
//!     slot per iteration (see `Engine::speculative_decode_step`).
//!
//! The first three are degenerate plans (admit+monolithic-prefill XOR
//! decode), so their observable admission orderings are unchanged from
//! the `Action` era — the integration suite still asserts them.

use crate::config::PolicyKind;

/// Prefill work for one engine iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefillWork {
    /// No prefill this iteration.
    None,
    /// Prefill every admitted prompt to completion in one batched
    /// fixed-shape call (the pre-StepPlan behaviour: stalls decode for
    /// the whole prompt, but admits a batch through a single call).
    Monolithic,
    /// Advance the prefilling queue (FIFO) by at most `max_tokens`
    /// prompt tokens through the backend's resumable chunk entry point.
    Chunk { max_tokens: usize },
}

/// What the engine executes this iteration. The fields compose — a
/// bounded prefill chunk can ride along with a decode step instead of
/// stalling it, which is the whole point of the plan pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StepPlan {
    /// Pop up to this many waiting requests and bind them to slots.
    pub admit: usize,
    /// Prefill execution mode for this iteration.
    pub prefill: PrefillWork,
    /// Advance the decoding queue one step.
    pub decode: bool,
    /// When set (and `decode` is true), run the decode step as a
    /// speculative propose/verify iteration emitting up to `k` tokens
    /// per slot. The engine falls back to the serial one-token step when
    /// the target backend cannot batch-verify or no draft is attached,
    /// so a speculate plan is always safe to emit.
    pub speculate: Option<usize>,
}

impl StepPlan {
    /// The empty plan (legal only when no work is pending).
    pub const IDLE: StepPlan = StepPlan {
        admit: 0,
        prefill: PrefillWork::None,
        decode: false,
        speculate: None,
    };

    /// Admit `n` requests and prefill their prompts to completion in one
    /// batched call — the degenerate plan the monolithic policies emit.
    pub fn admit_monolithic(n: usize) -> StepPlan {
        StepPlan {
            admit: n,
            prefill: PrefillWork::Monolithic,
            decode: false,
            speculate: None,
        }
    }

    /// Decode only.
    pub fn decode_only() -> StepPlan {
        StepPlan {
            admit: 0,
            prefill: PrefillWork::None,
            decode: true,
            speculate: None,
        }
    }

    /// Does this plan do nothing at all?
    pub fn is_idle(&self) -> bool {
        self.admit == 0 && self.prefill == PrefillWork::None && !self.decode
    }
}

/// Scheduler-visible engine state: the sizes of the three queues plus
/// admission capacity.
#[derive(Clone, Copy, Debug)]
pub struct SchedView {
    /// Waiting requests (no slot bound yet).
    pub queued: usize,
    /// Slot-bound sequences whose prompts are still entering the cache.
    pub prefilling: usize,
    /// Slot-bound sequences emitting tokens.
    pub decoding: usize,
    /// Admission capacity, not raw slot count: the engine clamps this to
    /// what the cache store can actually hold — for the paged cache, the
    /// queue prefix whose bounded block demands fit the unreserved pool.
    /// Policies therefore admit on blocks-free, not slots-free, with no
    /// paging knowledge of their own.
    pub free_slots: usize,
    pub prefill_batch: usize,
}

impl SchedView {
    /// Largest admissible batch right now.
    fn admissible(&self) -> usize {
        self.queued.min(self.free_slots).min(self.prefill_batch)
    }

    /// Slot-bound sequences in either in-flight phase.
    pub fn in_flight(&self) -> usize {
        self.prefilling + self.decoding
    }
}

/// `Send` because the policy travels with its engine onto a worker
/// thread in `--workers` mode; all shipped policies are plain data.
pub trait SchedulePolicy: Send {
    fn name(&self) -> &'static str;

    /// Build the next iteration's plan. Contract (anti-starvation):
    /// never return an idle plan while `queued + prefilling + decoding
    /// > 0` and progress is possible — i.e. something is admissible,
    /// prefilling, or decoding. The engine treats a violation as a
    /// policy bug and fails loudly instead of spinning. The property
    /// test below checks every policy against randomized views.
    fn plan(&mut self, v: &SchedView) -> StepPlan;
}

/// A prefilling queue normally only exists under [`Chunked`], but the
/// anti-starvation contract binds every policy over every view (a view
/// with prefilling sequences can reach a monolithic policy if the engine
/// was rebuilt mid-flight or a policy is driven directly): finish them
/// in one unbounded chunk.
fn drain_prefilling() -> StepPlan {
    StepPlan {
        admit: 0,
        prefill: PrefillWork::Chunk { max_tokens: usize::MAX },
        decode: false,
        speculate: None,
    }
}

/// Admit whenever a slot is free — the seed engine's behaviour.
pub struct AdmitFirst;

impl SchedulePolicy for AdmitFirst {
    fn name(&self) -> &'static str {
        "admit-first"
    }

    fn plan(&mut self, v: &SchedView) -> StepPlan {
        if v.admissible() > 0 {
            StepPlan::admit_monolithic(v.admissible())
        } else if v.prefilling > 0 {
            drain_prefilling()
        } else if v.decoding > 0 {
            StepPlan::decode_only()
        } else {
            StepPlan::IDLE
        }
    }
}

/// Drain the active batch before admitting anything new.
pub struct DecodeFirst;

impl SchedulePolicy for DecodeFirst {
    fn name(&self) -> &'static str {
        "decode-first"
    }

    fn plan(&mut self, v: &SchedView) -> StepPlan {
        if v.decoding > 0 {
            StepPlan::decode_only()
        } else if v.prefilling > 0 {
            drain_prefilling()
        } else if v.admissible() > 0 {
            StepPlan::admit_monolithic(v.admissible())
        } else {
            StepPlan::IDLE
        }
    }
}

/// Admit only when at least `min_free` slots are free (or the engine is
/// fully drained), so a single free slot never stalls a full batch of
/// active decodes for one monolithic prefill.
pub struct Hybrid {
    pub min_free: usize,
}

impl SchedulePolicy for Hybrid {
    fn name(&self) -> &'static str {
        "hybrid"
    }

    fn plan(&mut self, v: &SchedView) -> StepPlan {
        // Note: when nothing is in flight, the first branch always
        // admits (if anything is admissible), so the policy cannot
        // deadlock below the threshold.
        let n = v.admissible();
        if n > 0 && (v.in_flight() == 0 || v.free_slots >= self.min_free.max(1)) {
            StepPlan::admit_monolithic(n)
        } else if v.prefilling > 0 {
            drain_prefilling()
        } else if v.decoding > 0 {
            StepPlan::decode_only()
        } else {
            StepPlan::IDLE
        }
    }
}

/// The StepPlan pipeline's native policy: admit eagerly (binding a slot
/// runs no model code), advance the prefilling queue by at most
/// `chunk_tokens` prompt tokens, and decode in the SAME iteration — a
/// long prompt never stalls active decodes for more than one chunk.
pub struct Chunked {
    pub chunk_tokens: usize,
}

impl SchedulePolicy for Chunked {
    fn name(&self) -> &'static str {
        "chunked"
    }

    fn plan(&mut self, v: &SchedView) -> StepPlan {
        let admit = v.admissible();
        let prefill = if v.prefilling > 0 || admit > 0 {
            PrefillWork::Chunk { max_tokens: self.chunk_tokens.max(1) }
        } else {
            PrefillWork::None
        };
        StepPlan { admit, prefill, decode: v.decoding > 0, speculate: None }
    }
}

/// Admission shaped like [`AdmitFirst`], but every decode plan carries a
/// `speculate: Some(k)` marker: the engine's decode step becomes the
/// draft-propose / target-verify loop emitting up to `k` tokens per slot
/// per iteration. `k = 1` degenerates to a verify-checked serial step.
pub struct Speculative {
    pub k: usize,
}

impl SchedulePolicy for Speculative {
    fn name(&self) -> &'static str {
        "speculative"
    }

    fn plan(&mut self, v: &SchedView) -> StepPlan {
        if v.admissible() > 0 {
            StepPlan::admit_monolithic(v.admissible())
        } else if v.prefilling > 0 {
            drain_prefilling()
        } else if v.decoding > 0 {
            StepPlan { speculate: Some(self.k.max(1)), ..StepPlan::decode_only() }
        } else {
            StepPlan::IDLE
        }
    }
}

/// Instantiate the policy selected in the engine config.
pub fn build(kind: PolicyKind) -> Box<dyn SchedulePolicy> {
    match kind {
        PolicyKind::AdmitFirst => Box::new(AdmitFirst),
        PolicyKind::DecodeFirst => Box::new(DecodeFirst),
        PolicyKind::Hybrid { min_free } => Box::new(Hybrid { min_free }),
        PolicyKind::Chunked { chunk_tokens } => Box::new(Chunked { chunk_tokens }),
        PolicyKind::Speculative { k } => Box::new(Speculative { k }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, PropConfig};
    use crate::util::Rng;

    fn v(queued: usize, prefilling: usize, decoding: usize, free: usize) -> SchedView {
        SchedView { queued, prefilling, decoding, free_slots: free, prefill_batch: 8 }
    }

    #[test]
    fn admit_first_matches_seed_behaviour() {
        let mut p = AdmitFirst;
        assert_eq!(p.plan(&v(3, 0, 0, 8)), StepPlan::admit_monolithic(3));
        assert_eq!(
            p.plan(&v(10, 0, 7, 1)),
            StepPlan::admit_monolithic(1),
            "one free slot admits"
        );
        assert_eq!(p.plan(&v(0, 0, 5, 3)), StepPlan::decode_only());
        assert_eq!(p.plan(&v(4, 0, 8, 0)), StepPlan::decode_only());
        assert!(p.plan(&v(0, 0, 0, 8)).is_idle());
    }

    #[test]
    fn decode_first_drains_before_admitting() {
        let mut p = DecodeFirst;
        assert_eq!(p.plan(&v(10, 0, 7, 1)), StepPlan::decode_only());
        assert_eq!(p.plan(&v(10, 0, 0, 8)), StepPlan::admit_monolithic(8));
        assert!(p.plan(&v(0, 0, 0, 8)).is_idle());
    }

    #[test]
    fn hybrid_waits_for_threshold_but_never_deadlocks() {
        let mut p = Hybrid { min_free: 4 };
        // One free slot no longer stalls every active decode.
        assert_eq!(p.plan(&v(10, 0, 7, 1)), StepPlan::decode_only());
        assert_eq!(p.plan(&v(10, 0, 4, 4)), StepPlan::admit_monolithic(4));
        // Fully drained: admit regardless of the threshold.
        assert_eq!(p.plan(&v(2, 0, 0, 8)), StepPlan::admit_monolithic(2));
        // min_free = 1 degrades to admit-first.
        let mut p1 = Hybrid { min_free: 1 };
        assert_eq!(p1.plan(&v(10, 0, 7, 1)), StepPlan::admit_monolithic(1));
    }

    #[test]
    fn chunked_overlaps_prefill_with_decode() {
        let mut p = Chunked { chunk_tokens: 8 };
        // The headline plan: admit, chunk, AND decode in one iteration.
        assert_eq!(
            p.plan(&v(1, 1, 3, 2)),
            StepPlan {
                admit: 1,
                prefill: PrefillWork::Chunk { max_tokens: 8 },
                decode: true,
                speculate: None,
            }
        );
        // Nothing waiting or prefilling: pure decode.
        assert_eq!(p.plan(&v(0, 0, 3, 5)), StepPlan::decode_only());
        // Prefilling but no decodes yet: chunk only.
        assert_eq!(
            p.plan(&v(0, 2, 0, 0)),
            StepPlan {
                admit: 0,
                prefill: PrefillWork::Chunk { max_tokens: 8 },
                decode: false,
                speculate: None,
            }
        );
        assert!(p.plan(&v(0, 0, 0, 8)).is_idle());
        // A zero chunk config degrades to 1 token, never a no-op plan.
        let mut z = Chunked { chunk_tokens: 0 };
        assert_eq!(
            z.plan(&v(0, 1, 0, 0)).prefill,
            PrefillWork::Chunk { max_tokens: 1 }
        );
    }

    #[test]
    fn speculative_marks_decode_plans_with_k() {
        let mut p = Speculative { k: 4 };
        // Admission and prefill drain are admit-first shaped.
        assert_eq!(p.plan(&v(3, 0, 0, 8)), StepPlan::admit_monolithic(3));
        assert!(matches!(
            p.plan(&v(0, 2, 0, 0)).prefill,
            PrefillWork::Chunk { .. }
        ));
        // Decode plans carry the speculation depth.
        assert_eq!(
            p.plan(&v(0, 0, 5, 3)),
            StepPlan { speculate: Some(4), ..StepPlan::decode_only() }
        );
        assert!(p.plan(&v(0, 0, 0, 8)).is_idle());
        // A zero depth degrades to 1 (a verify-checked serial step),
        // never a meaningless plan.
        let mut z = Speculative { k: 0 };
        assert_eq!(p.plan(&v(0, 0, 1, 0)).speculate, Some(4));
        assert_eq!(z.plan(&v(0, 0, 1, 0)).speculate, Some(1));
    }

    #[test]
    fn monolithic_policies_drain_foreign_prefilling_state() {
        // The contract holds even on views these policies never create
        // themselves: prefilling sequences must be finished, not idled on.
        for p in [&mut AdmitFirst as &mut dyn SchedulePolicy, &mut DecodeFirst] {
            let plan = p.plan(&v(0, 2, 0, 6));
            assert!(
                matches!(plan.prefill, PrefillWork::Chunk { max_tokens } if max_tokens > 0),
                "{} idles on prefilling sequences",
                p.name()
            );
        }
        let mut h = Hybrid { min_free: 4 };
        let plan = h.plan(&v(0, 2, 0, 1));
        assert!(matches!(plan.prefill, PrefillWork::Chunk { .. }));
    }

    /// The documented anti-starvation contract, property-tested: no
    /// policy (old or new) may return an idle plan while work is pending
    /// and progress is possible, over randomized `SchedView`s — plus the
    /// plan sanity bounds (never over-admit, never decode an empty
    /// decode queue, never admit without prefill work to follow).
    #[test]
    fn props_no_policy_idles_with_pending_work() {
        check(
            "scheduler_anti_starvation",
            PropConfig { cases: 500, seed: 0xA11CE },
            |r: &mut Rng| {
                let batch = 1 + r.below(8);
                let prefilling = r.below(batch + 1);
                let decoding = r.below(batch + 1 - prefilling);
                let free = batch - prefilling - decoding;
                SchedView {
                    queued: r.below(6),
                    prefilling,
                    decoding,
                    // The engine may clamp admission capacity below the
                    // raw free-slot count (paged block shortage); the
                    // contract must hold under the clamp too.
                    free_slots: r.below(free + 1),
                    prefill_batch: 1 + r.below(4),
                }
            },
            |view| {
                let mut policies: Vec<Box<dyn SchedulePolicy>> = vec![
                    Box::new(AdmitFirst),
                    Box::new(DecodeFirst),
                    Box::new(Hybrid { min_free: 3 }),
                    Box::new(Hybrid { min_free: 0 }),
                    Box::new(Chunked { chunk_tokens: 4 }),
                    Box::new(Chunked { chunk_tokens: 0 }),
                    Box::new(Speculative { k: 4 }),
                    Box::new(Speculative { k: 0 }),
                ];
                let pending = view.queued + view.prefilling + view.decoding > 0;
                let possible =
                    view.admissible() > 0 || view.prefilling > 0 || view.decoding > 0;
                for p in policies.iter_mut() {
                    let plan = p.plan(view);
                    if pending && possible && plan.is_idle() {
                        return Err(format!("{} idled on {view:?}", p.name()));
                    }
                    if plan.admit > view.admissible() {
                        return Err(format!("{} over-admits on {view:?}", p.name()));
                    }
                    if plan.decode && view.decoding == 0 {
                        return Err(format!("{} decodes an empty queue", p.name()));
                    }
                    if plan.admit > 0 && plan.prefill == PrefillWork::None {
                        return Err(format!("{} admits without prefill work", p.name()));
                    }
                    if let PrefillWork::Chunk { max_tokens } = plan.prefill {
                        if max_tokens == 0 {
                            return Err(format!("{} emits a zero-token chunk", p.name()));
                        }
                    }
                    if let Some(k) = plan.speculate {
                        if !plan.decode {
                            return Err(format!(
                                "{} speculates without decoding",
                                p.name()
                            ));
                        }
                        if k == 0 {
                            return Err(format!("{} emits k = 0", p.name()));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
