//! Scheduling policies: who gets the next engine iteration — queued
//! requests (prefill/admission) or active sequences (decode)?
//!
//! In the memory-bound decode regime TransMLA targets, this choice
//! dominates tail latency: a prefill call stalls every active decode for
//! a full fixed-shape prefill, so admitting one request into one free
//! slot can cost every running sequence a step. The engine therefore
//! delegates the choice to a [`SchedulePolicy`] selected via
//! `EngineConfig::policy`:
//!
//!   * [`AdmitFirst`] — admit whenever a slot is free (the original fused
//!     engine's behaviour; best TTFT, worst TPOT under load);
//!   * [`DecodeFirst`] — drain the active batch before admitting (best
//!     TPOT, worst TTFT);
//!   * [`Hybrid`] — admit only when at least `min_free` slots are free
//!     (or nothing is running), amortising each prefill stall over a
//!     bigger admission batch.

use crate::config::PolicyKind;

/// What the engine should do this iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// Admit up to `n` queued requests through one prefill call.
    Admit(usize),
    /// Advance all active slots one decode step.
    Decode,
    /// Nothing to do.
    Idle,
}

/// Scheduler-visible engine state.
#[derive(Clone, Copy, Debug)]
pub struct SchedView {
    pub queued: usize,
    pub active: usize,
    /// Admission capacity, not raw slot count: the engine clamps this to
    /// what the cache store can actually hold — for the paged cache, the
    /// queue prefix whose bounded block demands fit the unreserved pool.
    /// Policies therefore admit on blocks-free, not slots-free, with no
    /// paging knowledge of their own.
    pub free_slots: usize,
    pub prefill_batch: usize,
}

impl SchedView {
    /// Largest admissible batch right now.
    fn admissible(&self) -> usize {
        self.queued.min(self.free_slots).min(self.prefill_batch)
    }
}

pub trait SchedulePolicy {
    fn name(&self) -> &'static str;

    /// Pick the next action. Contract: never return `Idle` while
    /// `queued + active > 0` and progress is possible (the engine treats
    /// that as a policy bug and fails loudly instead of spinning).
    fn decide(&mut self, v: &SchedView) -> Action;
}

/// Admit whenever a slot is free — the seed engine's behaviour.
pub struct AdmitFirst;

impl SchedulePolicy for AdmitFirst {
    fn name(&self) -> &'static str {
        "admit-first"
    }

    fn decide(&mut self, v: &SchedView) -> Action {
        if v.admissible() > 0 {
            Action::Admit(v.admissible())
        } else if v.active > 0 {
            Action::Decode
        } else {
            Action::Idle
        }
    }
}

/// Drain the active batch before admitting anything new.
pub struct DecodeFirst;

impl SchedulePolicy for DecodeFirst {
    fn name(&self) -> &'static str {
        "decode-first"
    }

    fn decide(&mut self, v: &SchedView) -> Action {
        if v.active > 0 {
            Action::Decode
        } else if v.admissible() > 0 {
            Action::Admit(v.admissible())
        } else {
            Action::Idle
        }
    }
}

/// Admit only when at least `min_free` slots are free (or the engine is
/// fully drained), so a single free slot never stalls a full batch of
/// active decodes for one prefill.
pub struct Hybrid {
    pub min_free: usize,
}

impl SchedulePolicy for Hybrid {
    fn name(&self) -> &'static str {
        "hybrid"
    }

    fn decide(&mut self, v: &SchedView) -> Action {
        // Note: when nothing is active, the first branch always admits
        // (if anything is admissible), so the policy cannot deadlock
        // below the threshold.
        let n = v.admissible();
        if n > 0 && (v.active == 0 || v.free_slots >= self.min_free.max(1)) {
            Action::Admit(n)
        } else if v.active > 0 {
            Action::Decode
        } else {
            Action::Idle
        }
    }
}

/// Instantiate the policy selected in the engine config.
pub fn build(kind: PolicyKind) -> Box<dyn SchedulePolicy> {
    match kind {
        PolicyKind::AdmitFirst => Box::new(AdmitFirst),
        PolicyKind::DecodeFirst => Box::new(DecodeFirst),
        PolicyKind::Hybrid { min_free } => Box::new(Hybrid { min_free }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(queued: usize, active: usize, free: usize) -> SchedView {
        SchedView { queued, active, free_slots: free, prefill_batch: 8 }
    }

    #[test]
    fn admit_first_matches_seed_behaviour() {
        let mut p = AdmitFirst;
        assert_eq!(p.decide(&v(3, 0, 8)), Action::Admit(3));
        assert_eq!(p.decide(&v(10, 7, 1)), Action::Admit(1), "one free slot admits");
        assert_eq!(p.decide(&v(0, 5, 3)), Action::Decode);
        assert_eq!(p.decide(&v(4, 8, 0)), Action::Decode);
        assert_eq!(p.decide(&v(0, 0, 8)), Action::Idle);
    }

    #[test]
    fn decode_first_drains_before_admitting() {
        let mut p = DecodeFirst;
        assert_eq!(p.decide(&v(10, 7, 1)), Action::Decode);
        assert_eq!(p.decide(&v(10, 0, 8)), Action::Admit(8));
        assert_eq!(p.decide(&v(0, 0, 8)), Action::Idle);
    }

    #[test]
    fn hybrid_waits_for_threshold_but_never_deadlocks() {
        let mut p = Hybrid { min_free: 4 };
        // One free slot no longer stalls every active decode.
        assert_eq!(p.decide(&v(10, 7, 1)), Action::Decode);
        assert_eq!(p.decide(&v(10, 4, 4)), Action::Admit(4));
        // Fully drained: admit regardless of the threshold.
        assert_eq!(p.decide(&v(2, 0, 8)), Action::Admit(2));
        // min_free = 1 degrades to admit-first.
        let mut p1 = Hybrid { min_free: 1 };
        assert_eq!(p1.decide(&v(10, 7, 1)), Action::Admit(1));
    }

    #[test]
    fn no_policy_idles_with_pending_work() {
        let mut policies: Vec<Box<dyn SchedulePolicy>> = vec![
            Box::new(AdmitFirst),
            Box::new(DecodeFirst),
            Box::new(Hybrid { min_free: 3 }),
            Box::new(Hybrid { min_free: 0 }),
        ];
        let batch = 4usize;
        for p in policies.iter_mut() {
            for queued in 0..4 {
                for active in 0..=batch {
                    let view = SchedView {
                        queued,
                        active,
                        free_slots: batch - active,
                        prefill_batch: 2,
                    };
                    let act = p.decide(&view);
                    if queued + active > 0 {
                        assert_ne!(
                            act,
                            Action::Idle,
                            "{} idled on {view:?}",
                            p.name()
                        );
                    }
                    if let Action::Admit(n) = act {
                        assert!(n > 0 && n <= view.admissible(), "{} over-admits", p.name());
                    }
                }
            }
        }
    }
}
