//! Sequence/slot lifecycle: one place that owns per-sequence state,
//! slot allocation, the prefilling/decoding phase split, per-slot length
//! tracking, completion rules, and the TTFT / TPOT / latency accounting
//! that the metrics and the server report. The engine talks to the
//! backend; this type tracks what every slot is doing.
//!
//! A slot-bound sequence moves through two phases (see [`SeqPhase`]):
//! **Prefilling** — slot bound and cache reserved, with a per-slot
//! *prefilled watermark* tracking how much of the prompt is in cache
//! (advanced chunk-by-chunk under the chunked policy, or in one shot by
//! the monolithic path) — then **Decoding** once the first token exists.
//! TTFT accounting splits accordingly: `queue_s` (enqueue → slot bound /
//! prefill started) vs `prefill_s` (prefill started → first token).

use crate::backend::CacheStore;
use crate::coordinator::request::{Completion, Request};
use crate::kvcache::SlotAllocator;
use anyhow::{anyhow, bail, Context, Result};
use std::time::Instant;

/// A failed admission hands the request back to the caller (for
/// requeueing) alongside the cause; slot and cache state are already
/// rolled back.
pub type AdmitError = (Request, anyhow::Error);

/// Total cache positions a sequence with this geometry can ever write:
/// the prompt plus one position per decode step. The final sampled token
/// is never fed back, so it needs no cache write — a sequence emitting
/// `n` tokens only writes `n - 1` decode positions. This is the paged
/// cache's admission-time reservation (bounded actual demand, not the
/// worst-case capacity).
pub fn bounded_cache_tokens(prompt_len: usize, max_new: usize, capacity: usize) -> usize {
    let room = capacity.saturating_sub(prompt_len) + 1;
    prompt_len + max_new.min(room).max(1) - 1
}

/// Lifecycle phase of a slot-bound sequence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqPhase {
    /// Slot bound and cache reserved; `done` prompt positions are in the
    /// cache (the prefilled watermark). No tokens emitted yet.
    Prefilling { done: usize },
    /// Prompt fully in cache; emitting tokens.
    Decoding,
}

/// One active sequence pinned to a decode slot.
pub struct SeqState {
    pub req: Request,
    pub slot: usize,
    /// Where in the prefill→decode lifecycle this sequence is.
    pub phase: SeqPhase,
    /// Effective prompt length after clamping to the backend geometry.
    pub prompt_len: usize,
    /// Position the next decode step writes to (prompt_len initially).
    pub next_pos: usize,
    pub last_token: i32,
    pub generated: Vec<i32>,
    pub enqueued: Instant,
    /// When this request's prefill started (end of queueing): the slot
    /// bind under the chunked policy, the batched call otherwise.
    pub prefill_started: Instant,
    /// When prefill finished and the first token existed (TTFT point).
    /// Provisional (= `prefill_started`) while still prefilling.
    pub admitted: Instant,
}

/// Owns `SeqState` and slot lifecycle for one engine.
pub struct SequenceManager {
    slots: SlotAllocator,
    seqs: Vec<Option<SeqState>>,
    /// Decode cache capacity T (completion bound).
    capacity: usize,
}

impl SequenceManager {
    pub fn new(batch: usize, capacity: usize) -> SequenceManager {
        SequenceManager {
            slots: SlotAllocator::new(batch),
            seqs: (0..batch).map(|_| None).collect(),
            capacity,
        }
    }

    pub fn batch(&self) -> usize {
        self.seqs.len()
    }

    /// Slot-bound sequences in either phase (prefilling + decoding).
    pub fn n_active(&self) -> usize {
        self.slots.n_active()
    }

    pub fn n_free(&self) -> usize {
        self.slots.n_free()
    }

    /// Sequences still feeding their prompt into the cache.
    pub fn n_prefilling(&self) -> usize {
        self.seqs
            .iter()
            .flatten()
            .filter(|s| matches!(s.phase, SeqPhase::Prefilling { .. }))
            .count()
    }

    /// Sequences in the decode queue.
    pub fn n_decoding(&self) -> usize {
        self.n_active() - self.n_prefilling()
    }

    /// Slots in the `Decoding` phase, ascending.
    pub fn decoding_slots(&self) -> Vec<usize> {
        self.seqs
            .iter()
            .enumerate()
            .filter_map(|(slot, s)| match s {
                Some(seq) if seq.phase == SeqPhase::Decoding => Some(slot),
                _ => None,
            })
            .collect()
    }

    pub fn seq(&self, slot: usize) -> Option<&SeqState> {
        self.seqs.get(slot).and_then(Option::as_ref)
    }

    /// Bind a request to a free slot, reserving its bounded cache demand
    /// in the store (block table for the paged cache; no-op for the
    /// fixed pool, whose slot row *is* the reservation) and materialising
    /// the first `materialize` positions. The monolithic path needs the
    /// whole prompt materialised for its splice; the chunked path passes
    /// 0 and grows block-by-block as chunks land. The sequence starts in
    /// `Prefilling` with its watermark at the store's shared-prefix
    /// coverage: positions below it were mapped from the prefix cache at
    /// admission (0 without sharing), so chunked prefill skips straight
    /// past them — the ROADMAP's prefix-cache-aware chunking.
    fn bind(
        &mut self,
        req: Request,
        prompt_len: usize,
        materialize: usize,
        enqueued: Instant,
        prefill_started: Instant,
        cache: &mut CacheStore,
    ) -> std::result::Result<usize, AdmitError> {
        let slot = match self.slots.alloc(req.id) {
            Some(slot) => slot,
            None => return Err((req, anyhow!("slot alloc: no free slot"))),
        };
        let reserve = bounded_cache_tokens(prompt_len, req.max_new_tokens, self.capacity);
        let prompt = &req.prompt[..prompt_len.min(req.prompt.len())];
        let shared = match cache.admit_slot(slot, reserve, materialize, prompt) {
            Ok(shared) => shared,
            Err(e) => {
                // Roll the slot back so allocator and seq state stay in step.
                let _ = self.slots.release(slot);
                return Err((req, e));
            }
        };
        self.seqs[slot] = Some(SeqState {
            phase: SeqPhase::Prefilling { done: shared.min(prompt_len) },
            prompt_len,
            next_pos: prompt_len,
            last_token: 0,
            generated: Vec::new(),
            enqueued,
            prefill_started,
            admitted: prefill_started,
            slot,
            req,
        });
        Ok(slot)
    }

    /// Bind a freshly *and fully* prefilled request to a free slot — the
    /// monolithic path: the prompt is already in cache and the first
    /// token sampled, so the sequence enters `Decoding` directly. On an
    /// admission failure the request comes back to the caller
    /// ([`AdmitError`]) for requeueing.
    #[allow(clippy::too_many_arguments)]
    pub fn admit(
        &mut self,
        req: Request,
        prompt_len: usize,
        first_token: i32,
        enqueued: Instant,
        prefill_started: Instant,
        now: Instant,
        cache: &mut CacheStore,
    ) -> std::result::Result<usize, AdmitError> {
        let slot =
            self.bind(req, prompt_len, prompt_len, enqueued, prefill_started, cache)?;
        self.finish_prefill(slot, first_token, now)
            .expect("a freshly bound slot accepts its first token");
        Ok(slot)
    }

    /// Chunked admission: bind a request to a slot with its cache
    /// reservation and enter `Prefilling` — no model call has happened
    /// yet, and (paged store) no *unshared* prompt blocks are
    /// materialised yet either: they commit at chunk granularity as the
    /// prompt enters the cache. With prefix sharing the watermark starts
    /// at the shared coverage, so chunking skips the cached prefix
    /// entirely (no recompute, no rewrite).
    pub fn admit_prefilling(
        &mut self,
        req: Request,
        prompt_len: usize,
        enqueued: Instant,
        prefill_started: Instant,
        cache: &mut CacheStore,
    ) -> std::result::Result<usize, AdmitError> {
        self.bind(req, prompt_len, 0, enqueued, prefill_started, cache)
    }

    /// Advance the prefilled watermark after a chunk wrote prompt
    /// positions up to (exclusive) `done`. An empty prompt is driven by
    /// one pad-token step, so the watermark bound is `max(prompt_len, 1)`.
    pub fn record_prefill(&mut self, slot: usize, done: usize) -> Result<()> {
        let seq = self.seqs[slot].as_mut().context("record_prefill on idle slot")?;
        match seq.phase {
            SeqPhase::Prefilling { done: prev }
                if done >= prev && done <= seq.prompt_len.max(1) =>
            {
                seq.phase = SeqPhase::Prefilling { done };
                Ok(())
            }
            SeqPhase::Prefilling { done: prev } => bail!(
                "slot {slot} watermark {done} out of order (was {prev}, prompt {})",
                seq.prompt_len
            ),
            SeqPhase::Decoding => bail!("record_prefill on decoding slot {slot}"),
        }
    }

    /// Complete prefill: the first sampled token exists, the sequence
    /// joins the decode queue, and the TTFT clock stops.
    pub fn finish_prefill(&mut self, slot: usize, first_token: i32, now: Instant) -> Result<()> {
        let seq = self.seqs[slot].as_mut().context("finish_prefill on idle slot")?;
        if seq.phase == SeqPhase::Decoding {
            bail!("finish_prefill on decoding slot {slot}");
        }
        seq.phase = SeqPhase::Decoding;
        seq.admitted = now;
        seq.last_token = first_token;
        seq.generated.push(first_token);
        Ok(())
    }

    /// Token / write-position / active vectors for the next decode call.
    /// Only `Decoding`-phase slots are active; idle and prefilling slots
    /// are masked out (a prefilling slot's cache rows are live resume
    /// state — the backend must not touch them).
    pub fn decode_io(&self) -> (Vec<i32>, Vec<i32>, Vec<bool>) {
        let b = self.batch();
        let mut token = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut active = vec![false; b];
        for (slot, s) in self.seqs.iter().enumerate() {
            if let Some(seq) = s {
                if seq.phase == SeqPhase::Decoding {
                    token[slot] = seq.last_token;
                    pos[slot] = seq.next_pos as i32;
                    active[slot] = true;
                }
            }
        }
        (token, pos, active)
    }

    /// Grow every decoding slot's cache to cover its next write position
    /// — called before each decode step so the backend's in-place writes
    /// always land in materialised blocks. Growth draws on the
    /// admission-time reservation, so it cannot fail for a healthy
    /// engine. No-op over the fixed pool. (Prefilling slots grow at
    /// chunk granularity on the chunk path instead.)
    pub fn grow_for_decode(&self, cache: &mut CacheStore) -> Result<()> {
        for (slot, s) in self.seqs.iter().enumerate() {
            if let Some(seq) = s {
                if seq.phase == SeqPhase::Decoding {
                    cache.grow(slot, seq.next_pos + 1)?;
                }
            }
        }
        Ok(())
    }

    /// Record one decoded token for a decoding slot.
    pub fn push_token(&mut self, slot: usize, tok: i32) -> Result<()> {
        let seq = self.seqs[slot].as_mut().context("push on idle slot")?;
        if seq.phase != SeqPhase::Decoding {
            bail!("push_token on prefilling slot {slot}");
        }
        seq.next_pos += 1;
        seq.last_token = tok;
        seq.generated.push(tok);
        Ok(())
    }

    /// Append a run of decoded tokens to a decoding slot in one call —
    /// the speculative accept path. Equivalent to `push_token` per
    /// token: `next_pos` advances by the run length, `last_token`
    /// becomes the final token of the run.
    pub fn push_tokens(&mut self, slot: usize, toks: &[i32]) -> Result<()> {
        let seq = self.seqs[slot].as_mut().context("push on idle slot")?;
        if seq.phase != SeqPhase::Decoding {
            bail!("push_tokens on prefilling slot {slot}");
        }
        if toks.is_empty() {
            bail!("push_tokens with no tokens on slot {slot}");
        }
        seq.next_pos += toks.len();
        seq.last_token = *toks.last().expect("non-empty run");
        seq.generated.extend_from_slice(toks);
        Ok(())
    }

    /// Retract the last `n` decoded tokens — the speculative reject
    /// path. The first token never rolls back (it came from prefill,
    /// not a decode step, and TTFT has already been stamped on it), so
    /// `n` must leave at least one generated token. The caller is
    /// responsible for the matching [`CacheStore::truncate`] to the new
    /// `next_pos`, so the retracted cache rows can never be read.
    pub fn rollback(&mut self, slot: usize, n: usize) -> Result<()> {
        let seq = self.seqs[slot].as_mut().context("rollback on idle slot")?;
        if seq.phase != SeqPhase::Decoding {
            bail!("rollback on prefilling slot {slot}");
        }
        if n == 0 {
            return Ok(());
        }
        if n >= seq.generated.len() {
            bail!(
                "rollback of {n} tokens would retract slot {slot}'s first \
                 token ({} generated)",
                seq.generated.len()
            );
        }
        seq.next_pos -= n;
        seq.generated.truncate(seq.generated.len() - n);
        seq.last_token = *seq.generated.last().expect("non-empty after rollback");
        Ok(())
    }

    /// Tokens the completion rule still allows `slot` to emit — the
    /// bound a speculative step must clamp its per-slot candidate count
    /// to, so a multi-token accept can never overshoot `is_done`'s
    /// budget or the cache reservation backing it. Zero for idle,
    /// prefilling, or finished slots.
    pub fn tokens_left(&self, slot: usize) -> usize {
        match self.seqs.get(slot).and_then(Option::as_ref) {
            Some(seq) if seq.phase == SeqPhase::Decoding => {
                let room = self.capacity.saturating_sub(seq.prompt_len) + 1;
                let budget = seq.req.max_new_tokens.min(room).max(1);
                budget.saturating_sub(seq.generated.len())
            }
            _ => 0,
        }
    }

    /// Has this sequence hit its token budget or the cache capacity?
    ///
    /// The capacity bound is `next_pos >= capacity`, not
    /// `next_pos + 1 >= capacity`: the final sampled token is never fed
    /// back through decode, so it needs no cache write, and a sequence
    /// may therefore emit one more token than it has cache positions
    /// left. The old `+ 1` bound silently dropped the last emittable
    /// token of every capacity-bounded sequence (and the `max_new` clamp
    /// below had the matching off-by-one).
    pub fn is_done(&self, slot: usize) -> bool {
        match &self.seqs[slot] {
            None => false,
            // A prefilling sequence has emitted nothing yet.
            Some(seq) if matches!(seq.phase, SeqPhase::Prefilling { .. }) => false,
            Some(seq) => {
                let room = self.capacity.saturating_sub(seq.prompt_len) + 1;
                let max_new = seq.req.max_new_tokens.min(room);
                seq.generated.len() >= max_new.max(1)
                    || seq.next_pos >= self.capacity
            }
        }
    }

    /// Release the slot (and its cache memory) and produce the completion
    /// record with latency, queueing, TTFT, and TPOT accounting.
    pub fn finish(&mut self, slot: usize, cache: &mut CacheStore) -> Result<Completion> {
        let seq = match self.seqs[slot].take() {
            Some(s) => s,
            None => bail!("finish on idle slot {slot}"),
        };
        self.slots.release(seq.slot)?;
        cache.release_slot(slot)?;
        let now = Instant::now();
        let latency_s = now.duration_since(seq.enqueued).as_secs_f64();
        // TTFT decomposes as queue_s (enqueue -> prefill started) +
        // prefill_s (prefill started -> first token; under the chunked
        // policy this spans the interleaved decode steps too — that IS
        // the observed prefill component of TTFT).
        let queue_s = seq.prefill_started.duration_since(seq.enqueued).as_secs_f64();
        let prefill_s = seq.admitted.duration_since(seq.prefill_started).as_secs_f64();
        let ttft_s = seq.admitted.duration_since(seq.enqueued).as_secs_f64();
        let decoded = seq.generated.len().saturating_sub(1);
        let tpot_s = if decoded > 0 {
            now.duration_since(seq.admitted).as_secs_f64() / decoded as f64
        } else {
            0.0
        };
        // The budget the completion rule enforced (`is_done`): requested
        // max_new clamped to the cache room left after the prompt. The
        // server echoes this so over-asking clients see the real bound.
        let room = self.capacity.saturating_sub(seq.prompt_len) + 1;
        let max_new = seq.req.max_new_tokens.min(room).max(1);
        Ok(Completion {
            id: seq.req.id,
            // The engine stamps its registry name before handing the
            // completion out; the manager does not know it.
            model: String::new(),
            prompt_len: seq.req.prompt.len(),
            max_new,
            tokens: seq.generated,
            latency_s,
            queue_s,
            prefill_s,
            ttft_s,
            tpot_s,
        })
    }

    /// Slot allocator, per-slot state, and phase bookkeeping must agree
    /// exactly.
    pub fn check_invariants(&self) -> Result<()> {
        self.slots.check_invariants()?;
        for (i, s) in self.seqs.iter().enumerate() {
            match (s, self.slots.owner_of(i)) {
                (Some(seq), Some(owner)) if seq.req.id == owner => {}
                (None, None) => {}
                _ => bail!("slot {i} state and allocator disagree"),
            }
            if let Some(seq) = s {
                match seq.phase {
                    SeqPhase::Decoding if seq.generated.is_empty() => {
                        bail!("decoding slot {i} has no first token")
                    }
                    // Position/token accounting must agree under any mix
                    // of single-token, multi-token, and rollback steps:
                    // the first token writes no cache position, every
                    // later one writes exactly one.
                    SeqPhase::Decoding
                        if seq.next_pos + 1 != seq.prompt_len + seq.generated.len() =>
                    {
                        bail!(
                            "decoding slot {i} next_pos {} disagrees with prompt \
                             {} + {} generated",
                            seq.next_pos,
                            seq.prompt_len,
                            seq.generated.len()
                        )
                    }
                    SeqPhase::Prefilling { .. } if !seq.generated.is_empty() => {
                        bail!("prefilling slot {i} already emitted tokens")
                    }
                    SeqPhase::Prefilling { done } if done > seq.prompt_len.max(1) => {
                        bail!("slot {i} watermark {done} past its prompt")
                    }
                    _ => {}
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{CacheLayout, KvCache, PagedKvCache};

    fn req(id: u64, plen: usize, max_new: usize) -> Request {
        Request::new(id, vec![1; plen], max_new)
    }

    fn store(batch: usize, cap: usize) -> CacheStore {
        CacheStore::Fixed(KvCache::new(CacheLayout::Mla { r: 4, dr: 4 }, 1, batch, cap))
    }

    #[test]
    fn admit_track_finish_cycle() {
        let mut m = SequenceManager::new(2, 32);
        let mut c = store(2, 32);
        let t0 = Instant::now();
        let slot = m.admit(req(7, 3, 4), 3, 42, t0, t0, t0, &mut c).unwrap();
        assert_eq!(m.n_active(), 1);
        assert_eq!(m.seq(slot).unwrap().next_pos, 3);
        assert!(!m.is_done(slot), "one token of four");
        m.push_token(slot, 43).unwrap();
        m.push_token(slot, 44).unwrap();
        m.push_token(slot, 45).unwrap();
        assert!(m.is_done(slot));
        let c2 = m.finish(slot, &mut c).unwrap();
        assert_eq!(c2.id, 7);
        assert_eq!(c2.tokens, vec![42, 43, 44, 45]);
        assert_eq!(m.n_active(), 0);
        m.check_invariants().unwrap();
        assert!(m.finish(slot, &mut c).is_err(), "double finish must fail");
    }

    #[test]
    fn capacity_bounds_generation_without_dropping_the_last_token() {
        // Regression for the off-by-one: a prompt of capacity-2 has two
        // cache writes left (positions cap-2 and cap-1) and the final
        // sampled token needs none, so THREE tokens are emittable — the
        // old `next_pos + 1 >= capacity` bound stopped at two.
        let mut m = SequenceManager::new(1, 8);
        let mut c = store(1, 8);
        let t0 = Instant::now();
        let slot = m.admit(req(1, 6, 100), 6, 9, t0, t0, t0, &mut c).unwrap();
        m.push_token(slot, 10).unwrap();
        assert!(!m.is_done(slot), "position 7 is still writable");
        m.push_token(slot, 11).unwrap();
        assert!(m.is_done(slot), "next_pos reached capacity");
        let done = m.finish(slot, &mut c).unwrap();
        assert_eq!(done.tokens.len(), 3, "capacity-2 prompt yields 3 tokens");
    }

    #[test]
    fn bounded_cache_tokens_matches_the_completion_rule() {
        // prompt 6, cap 8: 3 tokens emittable, last needs no write.
        assert_eq!(bounded_cache_tokens(6, 100, 8), 8);
        assert_eq!(bounded_cache_tokens(6, 2, 8), 7);
        // Empty prompt: n tokens cost n-1 writes.
        assert_eq!(bounded_cache_tokens(0, 3, 64), 2);
        // max_new 0 clamps to one (write-free) token.
        assert_eq!(bounded_cache_tokens(5, 0, 64), 5);
        // Never exceeds capacity.
        assert!(bounded_cache_tokens(63, 1000, 64) <= 64);
    }

    #[test]
    fn empty_prompt_still_yields_a_token() {
        let mut m = SequenceManager::new(1, 8);
        let mut c = store(1, 8);
        let t0 = Instant::now();
        let slot = m.admit(req(1, 0, 0), 0, 5, t0, t0, t0, &mut c).unwrap();
        // max_new 0 clamps to 1: the prefill token completes it.
        assert!(m.is_done(slot));
        let done = m.finish(slot, &mut c).unwrap();
        assert_eq!(done.tokens, vec![5]);
        assert_eq!(done.prompt_len, 0);
    }

    #[test]
    fn multi_token_append_and_rollback() {
        let mut m = SequenceManager::new(1, 32);
        let mut c = store(1, 32);
        let t0 = Instant::now();
        let slot = m.admit(req(1, 4, 10), 4, 40, t0, t0, t0, &mut c).unwrap();
        assert_eq!(m.tokens_left(slot), 9);
        m.push_tokens(slot, &[41, 42, 43]).unwrap();
        {
            let s = m.seq(slot).unwrap();
            assert_eq!((s.next_pos, s.last_token), (7, 43));
            assert_eq!(s.generated, vec![40, 41, 42, 43]);
        }
        m.check_invariants().unwrap();
        m.rollback(slot, 2).unwrap();
        {
            let s = m.seq(slot).unwrap();
            assert_eq!((s.next_pos, s.last_token), (5, 41));
            assert_eq!(s.generated, vec![40, 41]);
        }
        m.check_invariants().unwrap();
        assert!(m.rollback(slot, 2).is_err(), "first token never rolls back");
        m.rollback(slot, 0).unwrap();
        assert_eq!(m.tokens_left(slot), 8);
        assert!(m.push_tokens(slot, &[]).is_err(), "empty run is a bug");
        m.push_tokens(slot, &[50, 51, 52, 53, 54, 55, 56, 57]).unwrap();
        assert!(m.is_done(slot));
        assert_eq!(m.tokens_left(slot), 0);
        let done = m.finish(slot, &mut c).unwrap();
        assert_eq!(done.tokens.len(), 10);
        m.check_invariants().unwrap();
    }

    #[test]
    fn decode_io_masks_idle_slots() {
        let mut m = SequenceManager::new(3, 16);
        let mut c = store(3, 16);
        let t0 = Instant::now();
        let slot = m.admit(req(1, 2, 4), 2, 77, t0, t0, t0, &mut c).unwrap();
        let (tok, pos, act) = m.decode_io();
        for s in 0..3 {
            if s == slot {
                assert_eq!((tok[s], pos[s], act[s]), (77, 2, true));
            } else {
                assert_eq!((tok[s], pos[s], act[s]), (0, 0, false));
            }
        }
    }

    #[test]
    fn prefilling_lifecycle_watermark_then_decode() {
        let mut m = SequenceManager::new(2, 32);
        let mut c = store(2, 32);
        let t0 = Instant::now();
        let slot = m.admit_prefilling(req(3, 10, 4), 10, t0, t0, &mut c).unwrap();
        assert_eq!(m.n_active(), 1);
        assert_eq!(m.n_prefilling(), 1);
        assert_eq!(m.n_decoding(), 0);
        assert!(m.decoding_slots().is_empty());
        assert!(!m.is_done(slot), "prefilling sequences are never done");
        let (_, _, act) = m.decode_io();
        assert!(!act[slot], "prefilling slots are masked out of decode");
        assert!(m.push_token(slot, 1).is_err(), "no decode mid-prefill");
        m.record_prefill(slot, 6).unwrap();
        assert!(m.record_prefill(slot, 4).is_err(), "watermark cannot regress");
        assert!(m.record_prefill(slot, 11).is_err(), "watermark past prompt");
        m.record_prefill(slot, 10).unwrap();
        m.check_invariants().unwrap();
        m.finish_prefill(slot, 42, Instant::now()).unwrap();
        assert!(m.finish_prefill(slot, 42, Instant::now()).is_err());
        assert_eq!(m.n_decoding(), 1);
        assert_eq!(m.decoding_slots(), vec![slot]);
        let (tok, pos, act) = m.decode_io();
        assert_eq!((tok[slot], pos[slot], act[slot]), (42, 10, true));
        for t in 0..3 {
            m.push_token(slot, 50 + t).unwrap();
        }
        assert!(m.is_done(slot));
        let done = m.finish(slot, &mut c).unwrap();
        assert_eq!(done.tokens, vec![42, 50, 51, 52]);
        assert!(done.prefill_s >= 0.0);
        assert!(done.ttft_s >= done.queue_s);
        m.check_invariants().unwrap();
    }

    #[test]
    fn empty_prompt_watermark_allows_the_pad_step() {
        // An empty prompt is driven by one pad-token chunk: the
        // watermark bound is max(prompt_len, 1), not prompt_len.
        let mut m = SequenceManager::new(1, 8);
        let mut c = store(1, 8);
        let t0 = Instant::now();
        let slot = m.admit_prefilling(req(1, 0, 2), 0, t0, t0, &mut c).unwrap();
        m.record_prefill(slot, 1).unwrap();
        m.finish_prefill(slot, 9, Instant::now()).unwrap();
        assert_eq!(m.seq(slot).unwrap().next_pos, 0, "decode starts at pos 0");
        m.check_invariants().unwrap();
    }

    #[test]
    fn chunked_admission_commits_paged_blocks_at_chunk_granularity() {
        let mut m = SequenceManager::new(2, 32);
        let mut c = CacheStore::Paged(
            PagedKvCache::new(CacheLayout::Mla { r: 4, dr: 4 }, 1, 2, 4, 16).unwrap(),
        );
        let t0 = Instant::now();
        // Prompt 12 + max_new 2 -> bounded demand 13 tokens = 4 blocks,
        // all reserved but NONE materialised at bind time.
        let slot = m.admit_prefilling(req(1, 12, 2), 12, t0, t0, &mut c).unwrap();
        {
            let p = c.as_paged().unwrap();
            assert_eq!(p.blocks_in_use(), 0, "no prompt blocks before any chunk");
            assert_eq!(p.blocks_reserved(), 4, "full bounded demand reserved");
        }
        // Chunks land 4 tokens at a time; blocks commit as they land.
        c.grow(slot, 4).unwrap();
        m.record_prefill(slot, 4).unwrap();
        assert_eq!(c.as_paged().unwrap().blocks_in_use(), 1);
        c.grow(slot, 12).unwrap();
        m.record_prefill(slot, 12).unwrap();
        assert_eq!(c.as_paged().unwrap().blocks_in_use(), 3);
        m.finish_prefill(slot, 7, Instant::now()).unwrap();
        m.finish(slot, &mut c).unwrap();
        let p = c.as_paged().unwrap();
        assert_eq!(p.blocks_in_use(), 0);
        assert_eq!(p.blocks_reserved(), 0, "unused reservation released too");
        c.check_invariants().unwrap();
    }

    #[test]
    fn paged_lifecycle_grows_and_releases_blocks() {
        let mut m = SequenceManager::new(2, 32);
        let mut c = CacheStore::Paged(
            PagedKvCache::new(CacheLayout::Mla { r: 4, dr: 4 }, 1, 2, 4, 16).unwrap(),
        );
        let t0 = Instant::now();
        // Prompt 5 + max_new 6 -> bounded demand 10 tokens = 3 blocks.
        let slot = m.admit(req(1, 5, 6), 5, 42, t0, t0, t0, &mut c).unwrap();
        {
            let p = c.as_paged().unwrap();
            assert_eq!(p.blocks_in_use(), 2, "prompt of 5 spans 2 blocks");
            assert_eq!(p.blocks_reserved(), 1, "one block held back for decode");
        }
        for t in 0..5 {
            m.grow_for_decode(&mut c).unwrap();
            m.push_token(slot, 50 + t).unwrap();
        }
        assert!(m.is_done(slot));
        {
            let p = c.as_paged().unwrap();
            assert_eq!(p.blocks_in_use(), 3, "grew within the reservation");
            c.check_invariants().unwrap();
        }
        m.finish(slot, &mut c).unwrap();
        let p = c.as_paged().unwrap();
        assert_eq!(p.blocks_in_use(), 0);
        assert_eq!(p.blocks_reserved(), 0);
        c.check_invariants().unwrap();
        m.check_invariants().unwrap();
    }

    #[test]
    fn shared_admission_starts_the_watermark_at_the_prefix() {
        let mut m = SequenceManager::new(2, 32);
        let mut c = CacheStore::Paged({
            let mut p =
                PagedKvCache::new(CacheLayout::Mla { r: 4, dr: 4 }, 1, 2, 4, 16).unwrap();
            p.enable_prefix_cache();
            p
        });
        let t0 = Instant::now();
        // Seed: one sequence fills and registers the 12-token prompt.
        let prompt: Vec<i32> = (0..12).collect();
        let seed = Request::new(1, prompt.clone(), 2);
        let slot = m.admit(seed, 12, 7, t0, t0, t0, &mut c).unwrap();
        c.register_prefix(slot, &prompt).unwrap();
        m.push_token(slot, 8).unwrap();
        m.finish(slot, &mut c).unwrap();
        // Same-prefix chunked admission: sharing caps at floor(11/4) = 2
        // blocks, so the watermark starts at 8 of 12 prompt positions.
        let slot = m
            .admit_prefilling(Request::new(2, prompt, 2), 12, t0, t0, &mut c)
            .unwrap();
        assert_eq!(
            m.seq(slot).unwrap().phase,
            SeqPhase::Prefilling { done: 8 },
            "chunked prefill must skip the shared prefix"
        );
        m.check_invariants().unwrap();
        c.check_invariants().unwrap();
        // The remainder prefills as usual.
        m.record_prefill(slot, 12).unwrap();
        m.finish_prefill(slot, 9, Instant::now()).unwrap();
        m.finish(slot, &mut c).unwrap();
        c.check_invariants().unwrap();
    }

    #[test]
    fn admit_rolls_back_the_slot_when_blocks_run_out() {
        let mut m = SequenceManager::new(2, 32);
        // Only 2 blocks of 4 tokens: a long sequence cannot fit.
        let mut c = CacheStore::Paged(
            PagedKvCache::new(CacheLayout::Mla { r: 4, dr: 4 }, 1, 2, 4, 2).unwrap(),
        );
        let t0 = Instant::now();
        assert!(m.admit(req(1, 20, 8), 20, 1, t0, t0, t0, &mut c).is_err());
        assert_eq!(m.n_active(), 0, "slot rolled back");
        m.check_invariants().unwrap();
        c.check_invariants().unwrap();
    }
}
