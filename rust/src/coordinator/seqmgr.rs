//! Sequence/slot lifecycle: one place that owns per-sequence state,
//! slot allocation, per-slot length tracking, completion rules, and the
//! TTFT / TPOT / latency accounting that the metrics and the server
//! report. The engine talks to the backend; this type tracks what every
//! slot is doing.

use crate::coordinator::request::{Completion, Request};
use crate::kvcache::SlotAllocator;
use anyhow::{bail, Context, Result};
use std::time::Instant;

/// One active sequence pinned to a decode slot.
pub struct SeqState {
    pub req: Request,
    pub slot: usize,
    /// Effective prompt length after clamping to the backend geometry.
    pub prompt_len: usize,
    /// Position the next decode step writes to (prompt_len initially).
    pub next_pos: usize,
    pub last_token: i32,
    pub generated: Vec<i32>,
    pub enqueued: Instant,
    /// When this request's prefill call started (end of queueing).
    pub prefill_started: Instant,
    /// When prefill finished and the first token existed (TTFT point).
    pub admitted: Instant,
}

/// Owns `SeqState` and slot lifecycle for one engine.
pub struct SequenceManager {
    slots: SlotAllocator,
    seqs: Vec<Option<SeqState>>,
    /// Decode cache capacity T (completion bound).
    capacity: usize,
}

impl SequenceManager {
    pub fn new(batch: usize, capacity: usize) -> SequenceManager {
        SequenceManager {
            slots: SlotAllocator::new(batch),
            seqs: (0..batch).map(|_| None).collect(),
            capacity,
        }
    }

    pub fn batch(&self) -> usize {
        self.seqs.len()
    }

    pub fn n_active(&self) -> usize {
        self.slots.n_active()
    }

    pub fn n_free(&self) -> usize {
        self.slots.n_free()
    }

    pub fn active_slots(&self) -> Vec<usize> {
        self.slots.active_slots()
    }

    pub fn seq(&self, slot: usize) -> Option<&SeqState> {
        self.seqs.get(slot).and_then(Option::as_ref)
    }

    /// Bind a freshly prefilled request to a free slot.
    pub fn admit(
        &mut self,
        req: Request,
        prompt_len: usize,
        first_token: i32,
        enqueued: Instant,
        prefill_started: Instant,
        now: Instant,
    ) -> Result<usize> {
        let slot = self.slots.alloc(req.id).context("slot alloc")?;
        self.seqs[slot] = Some(SeqState {
            prompt_len,
            next_pos: prompt_len,
            last_token: first_token,
            generated: vec![first_token],
            enqueued,
            prefill_started,
            admitted: now,
            slot,
            req,
        });
        Ok(slot)
    }

    /// Token + write-position vectors for the next decode call
    /// (idle slots contribute 0/0; backends mask them by position).
    pub fn decode_io(&self) -> (Vec<i32>, Vec<i32>) {
        let b = self.batch();
        let mut token = vec![0i32; b];
        let mut pos = vec![0i32; b];
        for (slot, s) in self.seqs.iter().enumerate() {
            if let Some(seq) = s {
                token[slot] = seq.last_token;
                pos[slot] = seq.next_pos as i32;
            }
        }
        (token, pos)
    }

    /// Record one decoded token for an active slot.
    pub fn push_token(&mut self, slot: usize, tok: i32) -> Result<()> {
        let seq = self.seqs[slot].as_mut().context("push on idle slot")?;
        seq.next_pos += 1;
        seq.last_token = tok;
        seq.generated.push(tok);
        Ok(())
    }

    /// Has this sequence hit its token budget or the cache capacity?
    pub fn is_done(&self, slot: usize) -> bool {
        match &self.seqs[slot] {
            None => false,
            Some(seq) => {
                let max_new = seq
                    .req
                    .max_new_tokens
                    .min(self.capacity.saturating_sub(seq.prompt_len));
                seq.generated.len() >= max_new.max(1)
                    || seq.next_pos + 1 >= self.capacity
            }
        }
    }

    /// Release the slot and produce the completion record with latency,
    /// queueing, TTFT, and TPOT accounting.
    pub fn finish(&mut self, slot: usize) -> Result<Completion> {
        let seq = match self.seqs[slot].take() {
            Some(s) => s,
            None => bail!("finish on idle slot {slot}"),
        };
        self.slots.release(seq.slot)?;
        let now = Instant::now();
        let latency_s = now.duration_since(seq.enqueued).as_secs_f64();
        // queue_s ends when prefill starts; ttft_s additionally includes
        // the prefill itself (first token exists at `admitted`).
        let queue_s = seq.prefill_started.duration_since(seq.enqueued).as_secs_f64();
        let ttft_s = seq.admitted.duration_since(seq.enqueued).as_secs_f64();
        let decoded = seq.generated.len().saturating_sub(1);
        let tpot_s = if decoded > 0 {
            now.duration_since(seq.admitted).as_secs_f64() / decoded as f64
        } else {
            0.0
        };
        Ok(Completion {
            id: seq.req.id,
            prompt_len: seq.req.prompt.len(),
            tokens: seq.generated,
            latency_s,
            queue_s,
            ttft_s,
            tpot_s,
        })
    }

    /// Slot allocator and per-slot state must agree exactly.
    pub fn check_invariants(&self) -> Result<()> {
        self.slots.check_invariants()?;
        for (i, s) in self.seqs.iter().enumerate() {
            match (s, self.slots.owner_of(i)) {
                (Some(seq), Some(owner)) if seq.req.id == owner => {}
                (None, None) => {}
                _ => bail!("slot {i} state and allocator disagree"),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, plen: usize, max_new: usize) -> Request {
        Request::new(id, vec![1; plen], max_new)
    }

    #[test]
    fn admit_track_finish_cycle() {
        let mut m = SequenceManager::new(2, 32);
        let t0 = Instant::now();
        let slot = m.admit(req(7, 3, 4), 3, 42, t0, t0, t0).unwrap();
        assert_eq!(m.n_active(), 1);
        assert_eq!(m.seq(slot).unwrap().next_pos, 3);
        assert!(!m.is_done(slot), "one token of four");
        m.push_token(slot, 43).unwrap();
        m.push_token(slot, 44).unwrap();
        m.push_token(slot, 45).unwrap();
        assert!(m.is_done(slot));
        let c = m.finish(slot).unwrap();
        assert_eq!(c.id, 7);
        assert_eq!(c.tokens, vec![42, 43, 44, 45]);
        assert_eq!(m.n_active(), 0);
        m.check_invariants().unwrap();
        assert!(m.finish(slot).is_err(), "double finish must fail");
    }

    #[test]
    fn capacity_bounds_generation() {
        let mut m = SequenceManager::new(1, 8);
        let t0 = Instant::now();
        // Prompt of 6 in capacity 8: at most 2 new tokens fit.
        let slot = m.admit(req(1, 6, 100), 6, 9, t0, t0, t0).unwrap();
        m.push_token(slot, 9).unwrap();
        assert!(m.is_done(slot), "next_pos+1 reached capacity");
    }

    #[test]
    fn empty_prompt_still_yields_a_token() {
        let mut m = SequenceManager::new(1, 8);
        let t0 = Instant::now();
        let slot = m.admit(req(1, 0, 0), 0, 5, t0, t0, t0).unwrap();
        // max_new 0 clamps to 1: the prefill token completes it.
        assert!(m.is_done(slot));
        let c = m.finish(slot).unwrap();
        assert_eq!(c.tokens, vec![5]);
        assert_eq!(c.prompt_len, 0);
    }

    #[test]
    fn decode_io_masks_idle_slots() {
        let mut m = SequenceManager::new(3, 16);
        let t0 = Instant::now();
        let slot = m.admit(req(1, 2, 4), 2, 77, t0, t0, t0).unwrap();
        let (tok, pos) = m.decode_io();
        for s in 0..3 {
            if s == slot {
                assert_eq!((tok[s], pos[s]), (77, 2));
            } else {
                assert_eq!((tok[s], pos[s]), (0, 0));
            }
        }
    }
}
