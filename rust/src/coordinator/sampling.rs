//! Token sampling from a logits row: greedy or temperature-softmax.

use crate::util::Rng;

/// Greedy argmax.
pub fn greedy(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

/// Temperature sampling (temperature <= 0 degrades to greedy).
pub fn sample(logits: &[f32], temperature: f32, rng: &mut Rng) -> i32 {
    if temperature <= 0.0 {
        return greedy(logits);
    }
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f32> = logits
        .iter()
        .map(|&l| ((l - m) / temperature).exp())
        .collect();
    rng.categorical(&weights) as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        assert_eq!(greedy(&[0.1, 2.0, -1.0]), 1);
        assert_eq!(greedy(&[5.0, 2.0]), 0);
    }

    #[test]
    fn zero_temperature_is_greedy() {
        let mut rng = Rng::new(0);
        assert_eq!(sample(&[0.0, 3.0, 1.0], 0.0, &mut rng), 1);
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let mut rng = Rng::new(1);
        let logits = [1.0f32, 1.1, 0.9];
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[sample(&logits, 5.0, &mut rng) as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c > 500), "{counts:?}");
    }

    #[test]
    fn low_temperature_concentrates() {
        let mut rng = Rng::new(2);
        let logits = [0.0f32, 4.0, 0.0];
        let hits = (0..200)
            .filter(|_| sample(&logits, 0.1, &mut rng) == 1)
            .count();
        assert!(hits > 195, "{hits}");
    }
}
