//! The serving engine: continuous batching over fixed decode slots.
//!
//! One `Engine` drives one architecture (GQA baseline or converted MLA)
//! through its AOT prefill/decode executables:
//!
//!   * **admission** — up to `batch` queued requests are prefilled in one
//!     fixed-shape prefill call; their caches are spliced into free slots;
//!   * **decode** — all active slots advance one token per step through
//!     the decode executable (position-masked, so idle slots are inert);
//!   * **completion** — finished slots are released immediately and can be
//!     refilled on the next admission, vLLM-style.
//!
//! Weights live on-device for the whole engine lifetime; only the caches
//! and per-step scalars cross the host boundary (see runtime/mod.rs).

use crate::config::EngineConfig;
use crate::coordinator::request::{Completion, Request};
use crate::coordinator::sampling;
use crate::kvcache::{CacheLayout, KvCache, SlotAllocator};
use crate::metrics::Metrics;
use crate::model::Params;
use crate::runtime::{Exec, Runtime, Value};
use crate::util::{Rng, Timer};
use anyhow::{bail, Context, Result};
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

/// Which architecture an engine serves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arch {
    Gqa,
    Mla { rank: usize },
}

/// The compiled artifact pair + device-resident weights for one model.
pub struct ModelBundle {
    pub arch: Arch,
    pub cfg_name: String,
    pub prefill: Arc<Exec>,
    pub decode: Arc<Exec>,
    pub params: Params,
    param_bufs: Vec<xla::PjRtBuffer>,
    /// Host literals backing `param_bufs` — kept alive for the bundle's
    /// lifetime because PJRT host->device transfers are asynchronous.
    _param_lits: Vec<xla::Literal>,
    pub layout: CacheLayout,
    pub batch: usize,
    pub prefill_batch: usize,
    pub capacity: usize,
}

impl ModelBundle {
    pub fn load(
        rt: &Runtime,
        cfg_name: &str,
        arch: Arch,
        batch: usize,
        params: Params,
    ) -> Result<ModelBundle> {
        let (prefill_name, decode_name) = match arch {
            Arch::Gqa => (
                format!("{cfg_name}_gqa_prefill"),
                format!("{cfg_name}_gqa_decode_b{batch}"),
            ),
            Arch::Mla { rank } => (
                format!("{cfg_name}_mla_prefill_r{rank}"),
                format!("{cfg_name}_mla_decode_r{rank}_b{batch}"),
            ),
        };
        Self::load_named(rt, cfg_name, arch, batch, params, &prefill_name, &decode_name)
    }

    /// Load with explicit artifact names (context-length variants carry a
    /// `_t{T}` suffix on the decode artifact).
    pub fn load_named(
        rt: &Runtime,
        cfg_name: &str,
        arch: Arch,
        batch: usize,
        params: Params,
        prefill_name: &str,
        decode_name: &str,
    ) -> Result<ModelBundle> {
        let prefill = rt.load(prefill_name)?;
        let decode = rt.load(decode_name)?;
        params.check_against(&decode.spec)?;
        let cfg = &decode.spec.config;
        let layout = match arch {
            Arch::Gqa => CacheLayout::Gqa { g: cfg.n_kv_groups, d: cfg.head_dim },
            Arch::Mla { rank } => CacheLayout::Mla { r: rank, dr: cfg.head_dim },
        };
        let mut param_bufs = Vec::new();
        let mut _param_lits = Vec::new();
        for v in params.values() {
            let (buf, lit) = prefill.upload_owned(&v)?;
            param_bufs.push(buf);
            _param_lits.push(lit);
        }
        let prefill_batch = prefill.spec.batch.context("prefill batch")?;
        // Cache capacity comes from the decode artifact's cache input
        // shape [L, B, T, ...] (context-length variants differ from the
        // config's max_seq).
        let n = decode.spec.params.len();
        let capacity = decode.spec.inputs[n + 2].shape[2];
        Ok(ModelBundle {
            arch,
            cfg_name: cfg_name.to_string(),
            prefill,
            decode,
            params,
            param_bufs,
            _param_lits,
            layout,
            batch,
            prefill_batch,
            capacity,
        })
    }

    pub fn n_layers(&self) -> usize {
        self.decode.spec.config.n_layers
    }

    pub fn vocab(&self) -> usize {
        self.decode.spec.config.vocab
    }
}

struct SeqState {
    req: Request,
    slot: usize,
    /// Position the next decode step writes to (prompt_len initially).
    next_pos: usize,
    last_token: i32,
    generated: Vec<i32>,
    admitted: Instant,
    enqueued: Instant,
}

/// Continuous-batching serving engine for one model bundle.
pub struct Engine {
    pub bundle: ModelBundle,
    pub cache: KvCache,
    slots: SlotAllocator,
    seqs: Vec<Option<SeqState>>,
    queue: VecDeque<(Request, Instant)>,
    pub completions: Vec<Completion>,
    pub metrics: Metrics,
    rng: Rng,
    cfg: EngineConfig,
}

impl Engine {
    pub fn new(bundle: ModelBundle, cfg: EngineConfig) -> Engine {
        let cache = KvCache::new(
            bundle.layout,
            bundle.n_layers(),
            bundle.batch,
            bundle.capacity,
        );
        let batch = bundle.batch;
        Engine {
            bundle,
            cache,
            slots: SlotAllocator::new(batch),
            seqs: (0..batch).map(|_| None).collect(),
            queue: VecDeque::new(),
            completions: Vec::new(),
            metrics: Metrics::new(),
            rng: Rng::new(cfg.seed),
            cfg,
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.metrics.inc("requests", 1);
        self.queue.push_back((req, Instant::now()));
    }

    pub fn n_pending(&self) -> usize {
        self.queue.len()
    }

    pub fn n_active(&self) -> usize {
        self.slots.n_active()
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.slots.n_active() == 0
    }

    /// One scheduler iteration: admit new requests (prefill) if there is
    /// room, otherwise advance all active sequences one decode step.
    pub fn step(&mut self) -> Result<()> {
        if !self.queue.is_empty() && self.slots.n_free() > 0 {
            self.admit()?;
        } else if self.slots.n_active() > 0 {
            self.decode_step()?;
        }
        Ok(())
    }

    /// Run until all submitted work is complete.
    pub fn run_to_completion(&mut self) -> Result<()> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(())
    }

    /// Convenience: submit prompts, run, return completions in order.
    pub fn generate(&mut self, reqs: Vec<Request>) -> Result<Vec<Completion>> {
        let first = self.completions.len();
        for r in reqs {
            self.submit(r);
        }
        self.run_to_completion()?;
        let mut out: Vec<Completion> = self.completions[first..].to_vec();
        out.sort_by_key(|c| c.id);
        Ok(out)
    }

    // -- admission / prefill -------------------------------------------------

    fn admit(&mut self) -> Result<()> {
        let n = self
            .queue
            .len()
            .min(self.slots.n_free())
            .min(self.bundle.prefill_batch);
        let mut admitted = Vec::with_capacity(n);
        for _ in 0..n {
            let (req, enq) = self.queue.pop_front().unwrap();
            admitted.push((req, enq));
        }

        // The prefill artifact has its own (fixed) sequence length; the
        // decode cache capacity may be shorter for context-length variants
        // (splice truncates).
        let t = self.bundle.prefill.spec.inputs.last().unwrap().shape[1];
        let max_prompt = self.bundle.capacity.min(t) - 1;
        let bp = self.bundle.prefill_batch;
        let mut tokens = vec![0i32; bp * t];
        for (row, (req, _)) in admitted.iter().enumerate() {
            let len = req.prompt.len().min(max_prompt);
            tokens[row * t..row * t + len].copy_from_slice(&req.prompt[..len]);
        }

        let timer = Timer::start();
        let outs = self.bundle.prefill.run_b(
            &self.bundle.param_bufs,
            &[Value::i32_mat(tokens, &[bp, t])],
        )?;
        self.metrics.observe("prefill_s", timer.elapsed_s());
        let (logits, caches) = outs.split_first().context("prefill outputs")?;

        let now = Instant::now();
        let vocab = self.bundle.vocab();
        for (row, (req, enq)) in admitted.into_iter().enumerate() {
            let slot = self.slots.alloc(req.id).context("slot alloc")?;
            self.cache.splice_from(caches, row, slot)?;
            let plen = req.prompt.len().min(max_prompt);
            self.metrics.inc("prefill_tokens", plen as u64);
            // logits [Bp, T, V]: next token follows position plen-1.
            let off = (row * t + (plen - 1)) * vocab;
            let temp = self.effective_temp(&req);
            let first_tok = sampling::sample(
                &logits.data[off..off + vocab],
                temp,
                &mut self.rng,
            );
            self.seqs[slot] = Some(SeqState {
                next_pos: plen,
                last_token: first_tok,
                generated: vec![first_tok],
                admitted: now,
                enqueued: enq,
                slot,
                req,
            });
            // A prompt that already fills the cache finishes immediately.
            self.maybe_complete(slot)?;
        }
        Ok(())
    }

    fn effective_temp(&self, req: &Request) -> f32 {
        if req.temperature > 0.0 {
            req.temperature
        } else {
            self.cfg.temperature
        }
    }

    // -- decode ---------------------------------------------------------------

    fn decode_step(&mut self) -> Result<()> {
        let b = self.bundle.batch;
        let mut token = vec![0i32; b];
        let mut pos = vec![0i32; b];
        for slot in 0..b {
            if let Some(seq) = &self.seqs[slot] {
                token[slot] = seq.last_token;
                pos[slot] = seq.next_pos as i32;
            }
        }
        let timer = Timer::start();
        let outs = self.bundle.decode.run_b_mixed(
            &self.bundle.param_bufs,
            &[Value::i32_vec(token), Value::i32_vec(pos)],
            &[&self.cache.bufs[0], &self.cache.bufs[1]],
        )?;
        self.metrics.observe("decode_s", timer.elapsed_s());
        let mut it = outs.into_iter();
        let logits = it.next().context("decode logits")?;
        let c0 = it.next().context("cache0")?;
        let c1 = it.next().context("cache1")?;
        self.cache.store(vec![c0, c1])?;

        let vocab = self.bundle.vocab();
        let active = self.slots.active_slots();
        self.metrics.inc("decode_tokens", active.len() as u64);
        self.metrics.inc("decode_steps", 1);
        for slot in active {
            let temp = {
                let seq = self.seqs[slot].as_ref().unwrap();
                self.effective_temp(&seq.req)
            };
            let row = &logits.data[slot * vocab..(slot + 1) * vocab];
            let tok = sampling::sample(row, temp, &mut self.rng);
            let seq = self.seqs[slot].as_mut().unwrap();
            seq.next_pos += 1;
            seq.last_token = tok;
            seq.generated.push(tok);
            self.maybe_complete(slot)?;
        }
        Ok(())
    }

    fn maybe_complete(&mut self, slot: usize) -> Result<()> {
        let done = {
            let seq = self.seqs[slot].as_ref().unwrap();
            let max_new = seq.req.max_new_tokens.min(
                self.bundle.capacity.saturating_sub(seq.req.prompt.len()),
            );
            seq.generated.len() >= max_new.max(1)
                || seq.next_pos + 1 >= self.bundle.capacity
        };
        if !done {
            return Ok(());
        }
        let seq = self.seqs[slot].take().unwrap();
        self.slots.release(seq.slot)?;
        self.metrics.inc("completed", 1);
        self.completions.push(Completion {
            id: seq.req.id,
            prompt_len: seq.req.prompt.len(),
            tokens: seq.generated,
            latency_s: seq.enqueued.elapsed().as_secs_f64(),
            queue_s: (seq.admitted - seq.enqueued).as_secs_f64(),
        });
        Ok(())
    }

    /// Decode throughput measured so far (generated tokens / decode time).
    pub fn decode_throughput(&self) -> f64 {
        let toks = self.metrics.counter("decode_tokens") as f64;
        let time: f64 = self
            .metrics
            .stats("decode_s")
            .map(|s| s.samples.iter().sum())
            .unwrap_or(0.0);
        if time > 0.0 {
            toks / time
        } else {
            0.0
        }
    }

    pub fn slots_check(&self) -> Result<()> {
        self.slots.check_invariants()?;
        for (i, s) in self.seqs.iter().enumerate() {
            match (s, self.slots.owner_of(i)) {
                (Some(seq), Some(owner)) if seq.req.id == owner => {}
                (None, None) => {}
                _ => bail!("slot {i} state and allocator disagree"),
            }
        }
        Ok(())
    }
}
