//! The serving engine: continuous batching over fixed decode slots,
//! layered as Backend / Scheduler / SequenceManager — the scheduler
//! *builds* a per-iteration [`StepPlan`], this engine *executes* it.
//!
//! One `Engine` drives one [`ExecBackend`] (compiled XLA artifacts or the
//! hermetic simulator) through three decoupled concerns:
//!
//!   * **scheduling** — a pluggable [`SchedulePolicy`] emits a plan over
//!     the three queues (waiting → prefilling → decoding): how many
//!     requests to admit, how much prefill work to run (one batched
//!     monolithic call, or a bounded resumable chunk), and whether to
//!     decode — all composable in the SAME iteration, so a long prompt
//!     entering the cache never stalls active decodes for more than one
//!     chunk under the `chunked` policy;
//!   * **execution** — the backend runs prefill / prefill_chunk / decode
//!     over the opaque cache store (fixed slot pool or paged block
//!     pool), layout-agnostic (GQA or MLA-latent);
//!   * **sequences** — a [`SequenceManager`] owns slot lifecycle, the
//!     prefilling/decoding phase split with its per-slot watermark,
//!     completion rules, and latency accounting.
//!
//! Completion frees a slot immediately for the next admission,
//! vLLM-style. Finished requests accumulate until [`Engine::take_completions`]
//! drains them (the server does this every loop iteration).

use crate::backend::{BackendSpec, CacheStore, ExecBackend, ModelBundle, XlaBackend};
use crate::config::{CacheKind, EngineConfig};
use crate::coordinator::request::{Completion, Request};
use crate::coordinator::sampling;
use crate::coordinator::scheduler::{self, PrefillWork, SchedView, SchedulePolicy, StepPlan};
use crate::coordinator::seqmgr::{bounded_cache_tokens, SeqPhase, SequenceManager};
use crate::kvcache::{PrefixStats, QuantKind};
use crate::metrics::Metrics;
use crate::tensor::Tensor;
use crate::util::{Rng, Timer};
use anyhow::{bail, Context, Result};
use std::collections::{HashSet, VecDeque};
use std::time::Instant;

// Re-exported here because the engine's `Arch` predates the backend
// layer; existing imports (`coordinator::engine::Arch`) keep working.
pub use crate::backend::Arch;

/// Continuous-batching serving engine over one execution backend.
pub struct Engine {
    /// Registry name of this engine (`"default"` outside a multi-model
    /// registry); stamped onto every [`Completion`] it produces.
    name: String,
    backend: Box<dyn ExecBackend>,
    pub cache: CacheStore,
    seqs: SequenceManager,
    queue: VecDeque<(Request, Instant)>,
    /// Slots currently in the `Prefilling` phase, FIFO by admission —
    /// chunk budget is spent head-first so earlier requests reach their
    /// first token first.
    prefillq: VecDeque<usize>,
    completions: Vec<Completion>,
    pub metrics: Metrics,
    rng: Rng,
    cfg: EngineConfig,
    policy: Box<dyn SchedulePolicy>,
    /// Cheap proposer model for speculative decoding, attached via
    /// [`Engine::set_draft`]; `None` keeps every decode step serial.
    draft: Option<DraftState>,
    /// (active-before, admitted request ids) per admission — the
    /// observable ordering trace the policy tests assert on. A ring
    /// buffer bounded to the most recent [`ADMISSION_LOG_CAP`] entries
    /// (trimming is O(1); the old `Vec::remove(0)` shifted the whole log
    /// on every admission past the cap).
    admission_log: VecDeque<(usize, Vec<u64>)>,
}

/// Most recent admissions kept for inspection (`Engine::admission_log`).
const ADMISSION_LOG_CAP: usize = 64;

/// The draft half of the speculative decode pipeline: a cheap model the
/// engine runs serially to *propose* candidate tokens the target then
/// scores in one batched [`ExecBackend::verify`] call.
struct DraftState {
    backend: Box<dyn ExecBackend>,
    /// Always a private fixed pool sized by the draft's own spec. Draft
    /// state is scratch, rebuilt lazily from the confirmed stream, so it
    /// needs no paging, no sharing, and no truncation: rejected-token
    /// writes sit beyond the `done` watermark and are overwritten by the
    /// next catch-up or proposal round before anything reads them.
    cache: CacheStore,
    /// Per-slot watermark: how many positions of the slot's *confirmed*
    /// token stream the draft cache currently holds. Lags lazily (a slot
    /// that never proposes is never caught up) and resets to 0 when the
    /// slot's sequence completes, because slots are reused.
    done: Vec<usize>,
}

/// Lifetime speculative-decoding counters (see [`Engine::spec_stats`]).
#[derive(Clone, Copy, Debug)]
pub struct SpecStats {
    /// Draft tokens proposed to the target (k-1 per speculating slot per
    /// verify step).
    pub proposed: u64,
    /// Proposed tokens the target agreed with (emitted unmodified).
    pub accepted: u64,
    /// Verify iterations run.
    pub steps: u64,
    /// Tokens emitted by verify iterations (accepted + one target token
    /// per slot per step).
    pub tokens: u64,
    /// `accepted / proposed` (0 before any proposal).
    pub acceptance_rate: f64,
    /// `tokens / steps` — the speedup signal: serial decode is pinned at
    /// 1.0, a well-matched draft pushes this toward k.
    pub tokens_per_step: f64,
}

/// The dual-stream aliasing seam: a raw pointer that may cross a scoped
/// thread boundary. Used ONLY by [`Engine::overlapped_chunk_decode_step`]
/// to hand the prefill stream its own view of the backend and cache
/// store while the decode stream runs on the spawning thread.
///
/// Safety contract (documented invariant, enforced by construction and
/// by the overlap property tests):
///   * the backend signed [`ExecBackend::supports_overlap`] — both entry
///     points are interiorly immutable and touch only the cache rows of
///     the slots named in their arguments;
///   * the two streams' slot sets are disjoint (prefilling vs decoding
///     slots — a slot is in exactly one phase);
///   * every block/row either stream writes was materialised *before*
///     the streams launched (`grow` calls on the coordinating thread),
///     and no allocator, block-table, or prefix-index mutation happens
///     while they run: growth is pre-done, copy-on-write cannot trigger
///     (freshly grown blocks have refcount 1), and `register_prefix` /
///     completion release are deferred to after the join.
/// Under that contract the two `&mut` reborrows never touch the same
/// memory, so no data race exists despite the aliased pointers.
struct PtrSend<T: ?Sized>(*mut T);

// SAFETY: see the struct docs — the pointer is only dereferenced under
// the disjoint-rows contract above.
unsafe impl<T: ?Sized> Send for PtrSend<T> {}

/// One planned chunk of the overlapped step's prefill stream: the exact
/// arguments `prefill_chunk_step` would have passed, precomputed so the
/// stream runs no queue/watermark logic (pure backend calls).
struct ChunkJob {
    slot: usize,
    /// Prompt positions already in cache before this chunk.
    done: usize,
    /// Watermark after this chunk.
    end: usize,
    /// `prompt_len.max(1)` — the chunk finishes the prompt iff
    /// `end >= target`.
    target: usize,
    /// Clamped prompt length (0 for the empty-prompt pad step).
    plen: usize,
    /// Prompt prefix `[..end]` (the pad token for an empty prompt).
    prefix: Vec<i32>,
}

impl Engine {
    /// Build over any backend (the hermetic path: `Engine::new(SimBackend::gqa(8), cfg)`).
    /// Panics on an unbuildable cache config; use [`Engine::try_new`]
    /// where the config comes from user input.
    pub fn new<B: ExecBackend + 'static>(backend: B, cfg: EngineConfig) -> Engine {
        Engine::try_new(backend, cfg).expect("engine cache config")
    }

    /// Fallible construction: surfaces cache-store sizing errors (e.g. a
    /// paged pool too small for one full-capacity sequence).
    pub fn try_new<B: ExecBackend + 'static>(backend: B, cfg: EngineConfig) -> Result<Engine> {
        Engine::from_boxed(Box::new(backend), cfg)
    }

    pub fn from_boxed(backend: Box<dyn ExecBackend>, cfg: EngineConfig) -> Result<Engine> {
        let spec = backend.spec().clone();
        let cache = spec.new_cache_store(cfg.cache, cfg.prefix_cache, cfg.kv_quant)?;
        Ok(Engine {
            name: "default".to_string(),
            backend,
            cache,
            seqs: SequenceManager::new(spec.batch, spec.capacity),
            queue: VecDeque::new(),
            prefillq: VecDeque::new(),
            completions: Vec::new(),
            metrics: Metrics::new(),
            rng: Rng::new(cfg.seed),
            policy: scheduler::build(cfg.policy),
            cfg,
            draft: None,
            admission_log: VecDeque::new(),
        })
    }

    /// Build over compiled artifacts (the XLA path).
    pub fn with_bundle(bundle: ModelBundle, cfg: EngineConfig) -> Engine {
        Engine::new(XlaBackend::new(bundle), cfg)
    }

    pub fn spec(&self) -> &BackendSpec {
        self.backend.spec()
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Registry name of this engine (`"default"` unless renamed).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Rename the engine (the `EngineRegistry` does this at
    /// registration); every completion produced afterwards carries the
    /// new name in its `model` field.
    pub fn set_name(&mut self, name: &str) {
        self.name = name.to_string();
    }

    /// Attach a cheap draft model for speculative decoding (`draft=SPEC`
    /// in the `--model` grammar). The draft must line up with the target
    /// geometry: same slot count and vocab, and at least the target's
    /// cache capacity (its serial proposals walk the same positions).
    pub fn set_draft(&mut self, backend: Box<dyn ExecBackend>) -> Result<()> {
        let target = self.backend.spec();
        let spec = backend.spec();
        if spec.batch != target.batch {
            bail!("draft batch {} != engine batch {}", spec.batch, target.batch);
        }
        if spec.vocab != target.vocab {
            bail!("draft vocab {} != engine vocab {}", spec.vocab, target.vocab);
        }
        if spec.capacity < target.capacity {
            bail!(
                "draft capacity {} < engine capacity {}",
                spec.capacity,
                target.capacity
            );
        }
        let cache = spec.new_cache_store(CacheKind::Fixed, false, QuantKind::Off)?;
        let done = vec![0; spec.batch];
        self.draft = Some(DraftState { backend, cache, done });
        Ok(())
    }

    /// Name of the attached draft model, if any.
    pub fn draft_name(&self) -> Option<&str> {
        self.draft.as_ref().map(|d| d.backend.spec().name.as_str())
    }

    /// Lifetime speculative-decoding counters, derived from the metrics
    /// the verify steps maintain. All-zero when speculation never ran.
    pub fn spec_stats(&self) -> SpecStats {
        let proposed = self.metrics.counter("spec_proposed");
        let accepted = self.metrics.counter("spec_accepted");
        let steps = self.metrics.counter("spec_steps");
        let tokens = self.metrics.counter("spec_tokens");
        SpecStats {
            proposed,
            accepted,
            steps,
            tokens,
            acceptance_rate: if proposed > 0 {
                accepted as f64 / proposed as f64
            } else {
                0.0
            },
            tokens_per_step: if steps > 0 {
                tokens as f64 / steps as f64
            } else {
                0.0
            },
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.metrics.inc("requests", 1);
        self.queue.push_back((req, Instant::now()));
    }

    pub fn n_pending(&self) -> usize {
        self.queue.len()
    }

    /// Slot-bound sequences in either phase (prefilling + decoding).
    pub fn n_active(&self) -> usize {
        self.seqs.n_active()
    }

    /// Sequences still feeding their prompt into the cache.
    pub fn n_prefilling(&self) -> usize {
        self.seqs.n_prefilling()
    }

    /// Sequences in the decode queue.
    pub fn n_decoding(&self) -> usize {
        self.seqs.n_decoding()
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.seqs.n_active() == 0
    }

    /// Total pipeline depth — queued + prefilling + decoding — the load
    /// signal `least-loaded` routing compares engines by.
    pub fn load(&self) -> usize {
        self.queue.len() + self.seqs.n_active()
    }

    /// Fair-share weight in the multi-engine sweep (`weight=K` in a
    /// `--model` SPEC): a weight-K engine gets K step opportunities per
    /// sweep / worker iteration. Always >= 1.
    pub fn weight(&self) -> usize {
        self.cfg.weight.max(1)
    }

    /// Largest `max_new` this engine can actually serve for a prompt of
    /// `prompt_tokens` (pre-clamp length): the cache room left after the
    /// clamped prompt, plus the write-free final token. The server edge
    /// clamps hostile `max_new` values to this before submitting, so a
    /// request can never demand an unserveable reservation.
    pub fn max_new_ceiling(&self, prompt_tokens: usize) -> usize {
        let spec = self.backend.spec();
        let plen = prompt_tokens.min(spec.max_prompt());
        (spec.capacity.saturating_sub(plen) + 1).max(1)
    }

    /// Drain all finished requests accumulated since the last call.
    pub fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    /// Admission trace: (active sequences at admission time, request ids
    /// admitted), one entry per admission batch (one prefill call on the
    /// monolithic path; one slot-binding batch on the chunked path).
    pub fn admission_log(&self) -> &VecDeque<(usize, Vec<u64>)> {
        &self.admission_log
    }

    fn log_admission(&mut self, active_before: usize, ids: Vec<u64>) {
        self.admission_log.push_back((active_before, ids));
        if self.admission_log.len() > ADMISSION_LOG_CAP {
            self.admission_log.pop_front();
        }
    }

    /// How many of the next queued requests the cache store can take
    /// right now, looking at most `limit` deep: all of them for the
    /// fixed pool; for the paged one, the queue prefix whose cumulative
    /// bounded block demand — *net of shared-prefix coverage* — fits the
    /// unreserved pool plus what LRU eviction of cached prefix blocks
    /// could reclaim. Blocks any scanned request would share are never
    /// counted as eviction headroom: evicting them to admit an earlier
    /// request would invalidate a later one's plan. FIFO: a head request
    /// that does not fit blocks later ones rather than being reordered
    /// around. Single source of truth for both the scheduler's view and
    /// the actual admission pop in [`Engine::pop_admissions`].
    fn plan_admissions(&self, limit: usize) -> usize {
        let spec = self.backend.spec();
        let limit = limit.min(self.queue.len());
        match &self.cache {
            CacheStore::Fixed(_) => limit,
            CacheStore::Paged(p) => {
                let demands: Vec<(usize, Vec<usize>)> = self
                    .queue
                    .iter()
                    .take(limit)
                    .map(|(req, _)| {
                        let plen = req.prompt.len().min(spec.max_prompt());
                        let total = p.blocks_for(bounded_cache_tokens(
                            plen,
                            req.max_new_tokens,
                            spec.capacity,
                        ));
                        (total, p.peek_shared(&req.prompt[..plen]))
                    })
                    .collect();
                let shared_union: HashSet<usize> = demands
                    .iter()
                    .flat_map(|(_, s)| s.iter().copied())
                    .collect();
                let mut evictable = p
                    .evictable_blocks()
                    .into_iter()
                    .filter(|b| !shared_union.contains(b))
                    .count();
                let mut unreserved = p.n_unreserved();
                let mut n = 0;
                for (total, shared) in &demands {
                    let need = total.saturating_sub(shared.len());
                    if need > unreserved + evictable {
                        break;
                    }
                    if need > unreserved {
                        evictable -= need - unreserved;
                        unreserved = 0;
                    } else {
                        unreserved -= need;
                    }
                    n += 1;
                }
                n
            }
        }
    }

    /// Admission capacity the scheduler sees: free decode slots, clamped
    /// by free cache blocks when the paged pool is short (admit on
    /// blocks-free, not slots-free). When every queued request fits, the
    /// raw free-slot count is reported — exactly what the pre-paged
    /// engine passed — so policy thresholds (hybrid `min_free`) behave
    /// identically across cache kinds and backend prefill widths; only a
    /// genuine block shortage shrinks the scheduler's view.
    fn admit_capacity(&self) -> usize {
        let free = self.seqs.n_free();
        // One admission batch takes at most prefill_batch requests, so
        // the block plan never needs to look deeper than that.
        let depth = free.min(self.backend.spec().prefill_batch);
        let fit = self.plan_admissions(depth);
        if fit >= self.queue.len().min(depth) {
            free
        } else {
            fit
        }
    }

    /// One scheduler iteration: the policy builds a [`StepPlan`] over the
    /// three queues; the engine executes it — admissions, then prefill
    /// work, then a decode step, composable in one iteration.
    pub fn step(&mut self) -> Result<StepPlan> {
        let view = SchedView {
            queued: self.queue.len(),
            prefilling: self.seqs.n_prefilling(),
            decoding: self.seqs.n_decoding(),
            free_slots: self.admit_capacity(),
            prefill_batch: self.backend.spec().prefill_batch,
        };
        let plan = self.policy.plan(&view);
        if plan.is_idle() {
            if !self.is_idle() {
                bail!(
                    "policy `{}` idled with pending work ({} queued, {} prefilling, \
                     {} decoding)",
                    self.policy.name(),
                    self.queue.len(),
                    self.seqs.n_prefilling(),
                    self.seqs.n_decoding()
                );
            }
            return Ok(plan);
        }
        let mut decoded = false;
        match plan.prefill {
            // The degenerate pre-StepPlan path: admission and full
            // prefill fused into one batched call.
            PrefillWork::Monolithic => {
                if plan.admit > 0 {
                    self.admit_monolithic(plan.admit)?;
                }
            }
            PrefillWork::Chunk { max_tokens } => {
                if plan.admit > 0 {
                    self.admit_prefilling(plan.admit)?;
                }
                // Dual-stream execution: when both streams have work and
                // the backend signs the contract, run this iteration's
                // prefill chunk(s) and decode batch concurrently.
                // Completions are bit-identical either way (the overlap
                // parity tests assert it).
                if self.cfg.overlap
                    && plan.decode
                    && self.backend.supports_overlap()
                    && !self.prefillq.is_empty()
                    && self.seqs.n_decoding() > 0
                {
                    self.overlapped_chunk_decode_step(max_tokens)?;
                    decoded = true;
                } else {
                    self.prefill_chunk_step(max_tokens)?;
                }
            }
            PrefillWork::None => {
                if plan.admit > 0 {
                    bail!(
                        "policy `{}` admitted {} requests without prefill work",
                        self.policy.name(),
                        plan.admit
                    );
                }
            }
        }
        if plan.decode && !decoded {
            // A speculate plan needs both halves of the pipeline: a
            // target that can batch-verify and an attached draft. When
            // either is missing (the XLA artifacts, or no `draft=SPEC`
            // was wired), fall back to the serial step — same graceful
            // degradation as the overlap gate above.
            match plan.speculate {
                Some(k) if self.backend.supports_verify() && self.draft.is_some() => {
                    self.speculative_decode_step(k)?;
                }
                _ => self.decode_step()?,
            }
        }
        Ok(plan)
    }

    /// Run until all submitted work is complete.
    pub fn run_to_completion(&mut self) -> Result<()> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(())
    }

    /// Convenience: submit prompts, run, return all drained completions
    /// in request-id order.
    pub fn generate(&mut self, reqs: Vec<Request>) -> Result<Vec<Completion>> {
        for r in reqs {
            self.submit(r);
        }
        self.run_to_completion()?;
        let mut out = self.take_completions();
        out.sort_by_key(|c| c.id);
        Ok(out)
    }

    // -- admission -----------------------------------------------------------

    /// Pop the queue prefix that fits the cache store — the same rule
    /// `admit_capacity` showed the scheduler.
    fn pop_admissions(&mut self, want: usize) -> Vec<(Request, Instant)> {
        let prefill_batch = self.backend.spec().prefill_batch;
        let limit = want
            .min(self.queue.len())
            .min(self.seqs.n_free())
            .min(prefill_batch);
        let n = self.plan_admissions(limit);
        (0..n).map(|_| self.queue.pop_front().unwrap()).collect()
    }

    /// Monolithic admission: one batched prefill call covers every
    /// admitted prompt end-to-end; the sequences enter `Decoding`
    /// directly with their first token sampled.
    fn admit_monolithic(&mut self, want: usize) -> Result<()> {
        let spec = self.backend.spec().clone();
        let admitted = self.pop_admissions(want);
        let n = admitted.len();
        if n == 0 {
            return Ok(());
        }
        let active_before = self.seqs.n_active();
        // Freshen the LRU stamp of every admitted request's cached
        // prefix chain before any of them admits: evictions triggered by
        // earlier admissions in this wave then prefer victims no planned
        // admission depends on (matching the planner's headroom math).
        for (req, _) in &admitted {
            let plen = req.prompt.len().min(spec.max_prompt());
            self.cache.touch_prefix(&req.prompt[..plen]);
        }

        // The prefill entry point has its own (fixed) sequence length;
        // the decode cache capacity may be shorter for context-length
        // variants (splice truncates). The token matrix (and the sim
        // backend's compute + logits buffers) is sized to the admitted
        // rows — admitting one short prompt no longer zero-fills a full
        // `Bp x prefill_seq` matrix; only the XLA path pads back up to
        // its fixed artifact shape.
        let t = spec.prefill_seq;
        let max_prompt = spec.max_prompt();
        let mut tokens = vec![0i32; n * t];
        for (row, (req, _)) in admitted.iter().enumerate() {
            let len = req.prompt.len().min(max_prompt);
            tokens[row * t..row * t + len].copy_from_slice(&req.prompt[..len]);
        }

        let prefill_started = Instant::now();
        let timer = Timer::start();
        let out = self.backend.prefill(&tokens, n)?;
        self.metrics.observe("prefill_s", timer.elapsed_s());
        self.metrics.observe("admit_n", n as f64);

        let now = Instant::now();
        let vocab = spec.vocab;
        // Output rows dim: `n` from the sim backend, the full prefill
        // batch from the XLA one; the position stride is `t` either way.
        let mut ids = Vec::with_capacity(n);
        let mut requeue: Vec<(Request, Instant)> = Vec::new();
        let mut it = admitted.into_iter().enumerate();
        for (row, (req, enq)) in it.by_ref() {
            let plen = req.prompt.len().min(max_prompt);
            self.metrics.inc("prefill_tokens", plen as u64);
            // logits [rows, T, V]: the next token follows position
            // plen-1. An empty prompt clamps to position 0 (the pad row)
            // instead of underflowing — see the regression test.
            let off = (row * t + plen.saturating_sub(1)) * vocab;
            let temp = self.effective_temp(&req);
            let first_tok = sampling::sample(
                &out.logits.data[off..off + vocab],
                temp,
                &mut self.rng,
            );
            let id = req.id;
            match self.seqs.admit(
                req, plen, first_tok, enq, prefill_started, now, &mut self.cache,
            ) {
                Ok(slot) => {
                    ids.push(id);
                    self.cache.splice_from(&out.caches, row, slot, plen)?;
                    // Cache the freshly-filled prompt blocks for future
                    // same-prefix admissions (paged + prefix cache only).
                    // The prompt now lives in the slot's state — no copy.
                    let prompt = &self
                        .seqs
                        .seq(slot)
                        .context("admitted slot has state")?
                        .req
                        .prompt;
                    self.cache.register_prefix(slot, &prompt[..plen])?;
                    // A prompt that already fills the cache finishes
                    // immediately.
                    self.maybe_complete(slot)?;
                }
                Err((req, e)) => {
                    // Planned admission no longer fits (a rare plan/admit
                    // race under prefix eviction): requeue this request
                    // and the rest of the batch in order and keep
                    // serving. Only an engine with nothing else in
                    // flight cannot make progress — fail loudly there
                    // instead of spinning on the same head request.
                    if self.seqs.n_active() == 0 {
                        return Err(e).context("admission on an idle engine");
                    }
                    self.metrics.inc("admit_requeued", 1);
                    requeue.push((req, enq));
                    requeue.extend(it.by_ref().map(|(_, r)| r));
                    break;
                }
            }
        }
        for r in requeue.into_iter().rev() {
            self.queue.push_front(r);
        }
        if !ids.is_empty() {
            self.log_admission(active_before, ids);
        }
        Ok(())
    }

    /// Chunked admission: bind requests to slots (cache reserved, phase
    /// `Prefilling`) without running any model code — their prompts
    /// enter the cache chunk-by-chunk on this and subsequent iterations.
    fn admit_prefilling(&mut self, want: usize) -> Result<()> {
        let max_prompt = self.backend.spec().max_prompt();
        let admitted = self.pop_admissions(want);
        if admitted.is_empty() {
            return Ok(());
        }
        let active_before = self.seqs.n_active();
        // Same wave pre-touch as the monolithic path: planned shared
        // chains become LRU-freshest, so same-wave evictions prefer
        // other victims.
        for (req, _) in &admitted {
            let plen = req.prompt.len().min(max_prompt);
            self.cache.touch_prefix(&req.prompt[..plen]);
        }
        let now = Instant::now();
        self.metrics.observe("admit_n", admitted.len() as f64);
        let mut ids = Vec::with_capacity(admitted.len());
        let mut requeue: Vec<(Request, Instant)> = Vec::new();
        let mut it = admitted.into_iter();
        for (req, enq) in it.by_ref() {
            let plen = req.prompt.len().min(max_prompt);
            let id = req.id;
            match self.seqs.admit_prefilling(req, plen, enq, now, &mut self.cache) {
                Ok(slot) => {
                    ids.push(id);
                    // With prefix sharing, the watermark starts at the
                    // shared coverage: those chunks are skipped outright
                    // (no recompute, no rewrite) — prefix-cache-aware
                    // chunking.
                    if let Some(SeqPhase::Prefilling { done }) =
                        self.seqs.seq(slot).map(|s| s.phase)
                    {
                        if done > 0 {
                            self.metrics.inc("prefix_tokens_skipped", done as u64);
                        }
                    }
                    self.prefillq.push_back(slot);
                }
                Err((req, e)) => {
                    // Same plan/admit race handling as the monolithic
                    // path: requeue in order, fail only with no progress
                    // possible.
                    if self.seqs.n_active() == 0 {
                        return Err(e).context("admission on an idle engine");
                    }
                    self.metrics.inc("admit_requeued", 1);
                    requeue.push((req, enq));
                    requeue.extend(it.by_ref());
                    break;
                }
            }
        }
        for r in requeue.into_iter().rev() {
            self.queue.push_front(r);
        }
        if !ids.is_empty() {
            self.log_admission(active_before, ids);
        }
        Ok(())
    }

    // -- chunked prefill -----------------------------------------------------

    /// Advance the prefilling queue (FIFO) by at most `budget` prompt
    /// tokens through the backend's resumable chunk entry point. A
    /// sequence whose final chunk lands samples its first token and
    /// joins the decode queue in the same iteration. Paged-cache block
    /// growth happens here at chunk granularity, drawing on the
    /// admission-time reservation.
    fn prefill_chunk_step(&mut self, budget: usize) -> Result<()> {
        let mut left = budget.max(1);
        while left > 0 {
            let slot = match self.prefillq.front() {
                Some(&s) => s,
                None => break,
            };
            let (done, plen) = {
                let seq = self.seqs.seq(slot).context("prefilling slot has state")?;
                match seq.phase {
                    SeqPhase::Prefilling { done } => (done, seq.prompt_len),
                    SeqPhase::Decoding => {
                        bail!("decoding slot {slot} on the prefill queue")
                    }
                }
            };
            // An empty prompt still needs one pad-token step to produce
            // its first logits row — the same pad state the monolithic
            // path reads at padded position 0.
            let target = plen.max(1);
            // saturating: `left` is usize::MAX for drain plans.
            let end = target.min(done.saturating_add(left));
            let prefix: Vec<i32> = if plen == 0 {
                vec![0]
            } else {
                let seq = self.seqs.seq(slot).context("prefilling slot has state")?;
                seq.req.prompt[..end].to_vec()
            };
            self.cache.grow(slot, end)?;
            let timer = Timer::start();
            let logits = self.backend.prefill_chunk(&prefix, slot, done, &mut self.cache)?;
            self.metrics.observe("chunk_s", timer.elapsed_s());
            let processed = end - done;
            self.metrics.inc("prefill_chunks", 1);
            self.metrics.inc("prefill_tokens", processed as u64);
            self.metrics.observe("chunk_tokens", processed as f64);
            left = left.saturating_sub(processed);
            self.seqs.record_prefill(slot, end)?;
            if plen > 0 {
                // Index the prompt blocks this chunk filled for future
                // same-prefix admissions (paged + prefix cache only; the
                // pad step of an empty prompt caches nothing). Mid-prefill
                // registration — not just at prompt completion — lets a
                // same-wave burst of shared-prefix prompts dedupe against
                // a long prompt still streaming in; `register_prefix`
                // indexes fully-filled blocks only, and re-registering a
                // longer prefix later just extends the cached chain.
                self.cache.register_prefix(slot, &prefix)?;
            }
            if end >= target {
                // Prompt fully in cache: first token, decode queue.
                self.prefillq.pop_front();
                let temp = {
                    let seq = self.seqs.seq(slot).context("prefilled slot has state")?;
                    self.effective_temp(&seq.req)
                };
                let tok = sampling::sample(&logits.data, temp, &mut self.rng);
                self.seqs.finish_prefill(slot, tok, Instant::now())?;
                self.maybe_complete(slot)?;
            }
        }
        Ok(())
    }

    // -- dual-stream overlap -------------------------------------------------

    /// One iteration's prefill chunk(s) and decode batch, executed
    /// concurrently on two streams — the perf path behind `--overlap on`.
    /// Serial-equivalent by construction: completions (and every rng
    /// draw) are bit-identical to `prefill_chunk_step` + `decode_step`.
    ///
    /// Shape of the step:
    ///   1. **Plan** (coordinating thread): precompute the exact chunk
    ///      schedule `prefill_chunk_step` would run (pure queue/watermark
    ///      math), materialise every block either stream writes (`grow`
    ///      is reservation-backed, so ordering cannot change success),
    ///      and snapshot the decode batch's inputs.
    ///   2. **Streams** (scoped threads over the [`PtrSend`] seam): the
    ///      prefill stream runs the scheduled `prefill_chunk` calls in
    ///      order; the decode stream runs one `decode` over the slots
    ///      that were already decoding. Disjoint slot sets ⇒ disjoint
    ///      cache rows ⇒ no race (see [`PtrSend`] for the full invariant).
    ///   3. **Join + bookkeeping** (coordinating thread, serial order):
    ///      record watermarks, register prefixes, sample first tokens
    ///      (prefill-queue FIFO — the same rng order as serial), then a
    ///      catch-up `decode` for sequences whose prompt finished *this*
    ///      iteration (serially they would join the very next decode
    ///      call), and finally sample decode tokens ascending over the
    ///      union — again the serial draw order.
    fn overlapped_chunk_decode_step(&mut self, budget: usize) -> Result<()> {
        // 1. Plan: mirror prefill_chunk_step's loop without model calls.
        // Only the last job can be partial (a non-finishing chunk always
        // exhausts the budget), so each slot appears at most once.
        let mut jobs: Vec<ChunkJob> = Vec::new();
        let mut left = budget.max(1);
        let mut qi = 0usize;
        while left > 0 && qi < self.prefillq.len() {
            let slot = self.prefillq[qi];
            let seq = self.seqs.seq(slot).context("prefilling slot has state")?;
            let (done, plen) = match seq.phase {
                SeqPhase::Prefilling { done } => (done, seq.prompt_len),
                SeqPhase::Decoding => {
                    bail!("decoding slot {slot} on the prefill queue")
                }
            };
            let target = plen.max(1);
            let end = target.min(done.saturating_add(left));
            let prefix: Vec<i32> = if plen == 0 {
                vec![0]
            } else {
                seq.req.prompt[..end].to_vec()
            };
            left = left.saturating_sub(end - done);
            if end >= target {
                qi += 1;
            }
            jobs.push(ChunkJob { slot, done, end, target, plen, prefix });
        }
        // Materialise every row either stream writes while we still hold
        // the only &mut: chunk blocks in schedule order, then the decode
        // batch's next positions. After this point the streams run over
        // frozen allocator/table state (the PtrSend invariant).
        for j in &jobs {
            self.cache.grow(j.slot, j.end)?;
        }
        self.seqs.grow_for_decode(&mut self.cache)?;
        if let CacheStore::Paged(p) = &self.cache {
            self.metrics.observe("blocks_in_use", p.blocks_in_use() as f64);
        }
        let (token, pos, active) = self.seqs.decode_io();
        // The decode stream covers exactly the slots decoding *before*
        // this iteration's chunks land; sequences finishing prefill now
        // get a catch-up decode after the join.
        let old_active = active.clone();

        // 2. Streams.
        let backend_raw: *mut dyn ExecBackend = &mut *self.backend;
        let cache_raw: *mut CacheStore = &mut self.cache;
        let seam_backend = PtrSend(backend_raw);
        let seam_cache = PtrSend(cache_raw);
        let jobs_ref = &jobs;
        let timer = Timer::start();
        let (chunk_res, decode_res) = std::thread::scope(|s| {
            let prefill_stream = s.spawn(move || -> Result<Vec<(Tensor, f64)>> {
                // SAFETY: PtrSend contract — supports_overlap() backend,
                // prefilling slots only, rows pre-grown, no allocator or
                // table mutation until the join.
                let backend = unsafe { &mut *seam_backend.0 };
                let cache = unsafe { &mut *seam_cache.0 };
                let mut outs = Vec::with_capacity(jobs_ref.len());
                for j in jobs_ref {
                    let t = Timer::start();
                    let logits = backend.prefill_chunk(&j.prefix, j.slot, j.done, cache)?;
                    outs.push((logits, t.elapsed_s()));
                }
                Ok(outs)
            });
            // Decode stream on the coordinating thread (no extra spawn).
            // SAFETY: the other half of the same seam — decoding slots
            // only, disjoint from every job's slot.
            let t = Timer::start();
            let decode_res = unsafe {
                let backend = &mut *backend_raw;
                let cache = &mut *cache_raw;
                backend
                    .decode(&token, &pos, &active, cache)
                    .map(|l| (l, t.elapsed_s()))
            };
            let chunk_res = prefill_stream
                .join()
                .unwrap_or_else(|p| std::panic::resume_unwind(p));
            (chunk_res, decode_res)
        });
        self.metrics.observe("overlap_s", timer.elapsed_s());
        self.metrics.inc("overlap_steps", 1);
        let chunk_outs = chunk_res?;
        let (decode_logits, decode_s) = decode_res?;
        self.metrics.observe("decode_s", decode_s);

        // 3a. Prefill bookkeeping, in schedule (= serial FIFO) order.
        for (j, (logits, chunk_s)) in jobs.iter().zip(&chunk_outs) {
            self.metrics.observe("chunk_s", *chunk_s);
            let processed = j.end - j.done;
            self.metrics.inc("prefill_chunks", 1);
            self.metrics.inc("prefill_tokens", processed as u64);
            self.metrics.observe("chunk_tokens", processed as f64);
            self.seqs.record_prefill(j.slot, j.end)?;
            if j.plen > 0 {
                // Mid-prefill indexing, same as the serial path — safe
                // here because 3a runs after the join (index/refcount
                // mutation is barred while the streams run).
                self.cache.register_prefix(j.slot, &j.prefix)?;
            }
            if j.end >= j.target {
                let front = self.prefillq.pop_front();
                debug_assert_eq!(front, Some(j.slot), "schedule tracks the queue");
                let temp = {
                    let seq = self.seqs.seq(j.slot).context("prefilled slot has state")?;
                    self.effective_temp(&seq.req)
                };
                let tok = sampling::sample(&logits.data, temp, &mut self.rng);
                self.seqs.finish_prefill(j.slot, tok, Instant::now())?;
                self.maybe_complete(j.slot)?;
            }
        }

        // 3b. Catch-up decode for sequences that finished prefill above:
        // serially they were already `Decoding` when the iteration's one
        // decode call ran. Slot-isolated backends (the supports_overlap
        // contract) make the split call bit-identical per slot.
        let new_slots: Vec<usize> = self
            .seqs
            .decoding_slots()
            .into_iter()
            .filter(|&s| !old_active[s])
            .collect();
        let catchup_logits = if new_slots.is_empty() {
            None
        } else {
            self.seqs.grow_for_decode(&mut self.cache)?;
            let (token, pos, mut active) = self.seqs.decode_io();
            for (s, a) in active.iter_mut().enumerate() {
                if old_active[s] {
                    *a = false;
                }
            }
            let t = Timer::start();
            let l = self.backend.decode(&token, &pos, &active, &mut self.cache)?;
            self.metrics.observe("decode_s", t.elapsed_s());
            Some(l)
        };

        // 3c. Sample decode tokens ascending over the union — serial's
        // draw order. Old slots read the concurrent stream's logits, new
        // slots the catch-up call's.
        let vocab = self.backend.spec().vocab;
        let decoding = self.seqs.decoding_slots();
        self.metrics.inc("decode_tokens", decoding.len() as u64);
        self.metrics.inc("decode_steps", 1);
        for slot in decoding {
            let temp = {
                let seq = self.seqs.seq(slot).expect("decoding slot has state");
                self.effective_temp(&seq.req)
            };
            let row = if old_active[slot] {
                &decode_logits.data[slot * vocab..(slot + 1) * vocab]
            } else {
                let l = catchup_logits
                    .as_ref()
                    .context("newly decoding slot has catch-up logits")?;
                &l.data[slot * vocab..(slot + 1) * vocab]
            };
            let tok = sampling::sample(row, temp, &mut self.rng);
            self.seqs.push_token(slot, tok)?;
            self.maybe_complete(slot)?;
        }
        Ok(())
    }

    fn effective_temp(&self, req: &Request) -> f32 {
        if req.temperature > 0.0 {
            req.temperature
        } else {
            self.cfg.temperature
        }
    }

    // -- decode ---------------------------------------------------------------

    fn decode_step(&mut self) -> Result<()> {
        // Materialise the blocks this step writes (paged; no-op fixed).
        self.seqs.grow_for_decode(&mut self.cache)?;
        if let CacheStore::Paged(p) = &self.cache {
            self.metrics.observe("blocks_in_use", p.blocks_in_use() as f64);
        }
        let (token, pos, active) = self.seqs.decode_io();
        let timer = Timer::start();
        let logits = self.backend.decode(&token, &pos, &active, &mut self.cache)?;
        self.metrics.observe("decode_s", timer.elapsed_s());

        let vocab = self.backend.spec().vocab;
        let decoding = self.seqs.decoding_slots();
        self.metrics.inc("decode_tokens", decoding.len() as u64);
        self.metrics.inc("decode_steps", 1);
        for slot in decoding {
            let temp = {
                let seq = self.seqs.seq(slot).expect("decoding slot has state");
                self.effective_temp(&seq.req)
            };
            let row = &logits.data[slot * vocab..(slot + 1) * vocab];
            let tok = sampling::sample(row, temp, &mut self.rng);
            self.seqs.push_token(slot, tok)?;
            self.maybe_complete(slot)?;
        }
        Ok(())
    }

    /// One speculative decode iteration — the propose/verify/rollback
    /// pipeline behind `--policy speculative[:K]`:
    ///
    ///   1. **Propose** (draft stream): catch the draft cache up to the
    ///      slot's confirmed token stream, then run the cheap model's own
    ///      serial decode loop to draft up to `k-1` candidate tokens per
    ///      slot (always greedy — drafts are guesses, not samples).
    ///   2. **Verify** (one target call): feed each slot's chain
    ///      `[newest confirmed token, draft_1..]` at consecutive
    ///      positions through [`ExecBackend::verify`]; output row `j` is
    ///      the target's own next-token logits after consuming candidate
    ///      `j` — exactly what `j+1` serial decode steps would produce.
    ///   3. **Accept + rollback**: keep the longest draft prefix the
    ///      target's greedy choices agree with, plus the target's own
    ///      next token (so every iteration emits >= 1 token), then
    ///      [`CacheStore::truncate`] the rejected candidates' cache
    ///      writes. At temperature 0 the emitted stream is bit-identical
    ///      to plain serial decode by construction; sampled slots fall
    ///      back to a verify-checked serial step (`k_slot = 1`).
    fn speculative_decode_step(&mut self, k: usize) -> Result<()> {
        let k = k.max(1);
        let spec = self.backend.spec().clone();
        let b = spec.batch;
        let vocab = spec.vocab;
        let decoding = self.seqs.decoding_slots();

        // Per-slot depth: clamp to the sequence's remaining budget (the
        // final token needs no cache write, but everything before does),
        // and pin sampled slots to 1 — speculation only promises
        // bit-identity for greedy decoding.
        let mut k_of = vec![0usize; b];
        for &slot in &decoding {
            let seq = self.seqs.seq(slot).context("decoding slot has state")?;
            let temp = self.effective_temp(&seq.req);
            k_of[slot] = if temp > 0.0 {
                1
            } else {
                k.min(self.seqs.tokens_left(slot)).max(1)
            };
        }

        // 1. Propose.
        let mut drafts: Vec<Vec<i32>> = vec![Vec::new(); b];
        {
            let draft = self.draft.as_mut().context("speculative step without a draft")?;
            let timer = Timer::start();
            // Lazy catch-up: replay the confirmed stream (prompt plus
            // accepted tokens, minus the newest — that token is this
            // step's decode input) into the draft cache. Runs the cheap
            // model, never the target; a slot admitted over a long
            // prompt costs one draft prefill here, then stays warm.
            for &slot in &decoding {
                if k_of[slot] < 2 {
                    continue; // not proposing: no draft state needed
                }
                let seq = self.seqs.seq(slot).context("decoding slot has state")?;
                let p = seq.next_pos;
                if draft.done[slot] < p {
                    let mut confirmed = seq.req.prompt[..seq.prompt_len].to_vec();
                    confirmed.extend_from_slice(&seq.generated[..seq.generated.len() - 1]);
                    debug_assert_eq!(confirmed.len(), p, "confirmed stream is the cache");
                    draft.backend.prefill_chunk(
                        &confirmed,
                        slot,
                        draft.done[slot],
                        &mut draft.cache,
                    )?;
                    draft.done[slot] = p;
                }
            }
            // Proposal rounds, batched across slots: round 0 feeds the
            // slot's newest confirmed token at its next position (the
            // exact serial decode input); round j feeds the round-(j-1)
            // draft one position later.
            let rounds = decoding
                .iter()
                .map(|&s| k_of[s].saturating_sub(1))
                .max()
                .unwrap_or(0);
            let mut token = vec![0i32; b];
            let mut pos = vec![0i32; b];
            for j in 0..rounds {
                let mut active = vec![false; b];
                for &slot in &decoding {
                    if j + 1 >= k_of[slot] {
                        continue;
                    }
                    let seq = self.seqs.seq(slot).context("decoding slot has state")?;
                    active[slot] = true;
                    pos[slot] = (seq.next_pos + j) as i32;
                    token[slot] = if j == 0 { seq.last_token } else { drafts[slot][j - 1] };
                }
                let logits = draft.backend.decode(&token, &pos, &active, &mut draft.cache)?;
                for &slot in &decoding {
                    if active[slot] {
                        let row = &logits.data[slot * vocab..(slot + 1) * vocab];
                        drafts[slot].push(sampling::greedy(row));
                    }
                }
            }
            self.metrics.observe("draft_s", timer.elapsed_s());
        }

        // 2. Verify: materialise every position the chains write (the
        // depth clamp keeps them inside the admission-time reservation),
        // then score all chains in ONE batched target call.
        for &slot in &decoding {
            let seq = self.seqs.seq(slot).context("decoding slot has state")?;
            self.cache.grow(slot, seq.next_pos + k_of[slot])?;
        }
        if let CacheStore::Paged(p) = &self.cache {
            self.metrics.observe("blocks_in_use", p.blocks_in_use() as f64);
        }
        let mut tokens = vec![0i32; b * k];
        let mut start_pos = vec![0i32; b];
        let mut counts = vec![0usize; b];
        for &slot in &decoding {
            let seq = self.seqs.seq(slot).context("decoding slot has state")?;
            counts[slot] = k_of[slot];
            start_pos[slot] = seq.next_pos as i32;
            tokens[slot * k] = seq.last_token;
            for (j, &d) in drafts[slot].iter().enumerate() {
                tokens[slot * k + 1 + j] = d;
            }
        }
        let timer = Timer::start();
        let logits = self.backend.verify(&tokens, &start_pos, &counts, k, &mut self.cache)?;
        self.metrics.observe("decode_s", timer.elapsed_s());

        // 3. Accept + rollback, slots ascending (serial sampling order).
        let mut emitted_total = 0u64;
        let mut proposed = 0u64;
        let mut accepted = 0u64;
        for &slot in &decoding {
            let n = k_of[slot];
            let p = start_pos[slot] as usize;
            let temp = {
                let seq = self.seqs.seq(slot).expect("decoding slot has state");
                self.effective_temp(&seq.req)
            };
            let mut emitted: Vec<i32> = Vec::with_capacity(n);
            for j in 0..n {
                let row = &logits.data[(slot * k + j) * vocab..(slot * k + j + 1) * vocab];
                let tok = sampling::sample(row, temp, &mut self.rng);
                emitted.push(tok);
                if j + 1 < n && tok != drafts[slot][j] {
                    break; // rows past j scored a now-rejected candidate
                }
            }
            let e = emitted.len();
            proposed += (n - 1) as u64;
            accepted += (e - 1) as u64;
            emitted_total += e as u64;
            self.seqs.push_tokens(slot, &emitted)?;
            // Retract the rejected candidates' cache writes: the store
            // is valid exactly through the new next position (the newest
            // emitted token enters the cache on the next iteration, same
            // as serial decode).
            let next = self.seqs.seq(slot).context("slot has state")?.next_pos;
            self.cache.truncate(slot, next)?;
            if let Some(d) = &mut self.draft {
                if n >= 2 {
                    // The draft cache now holds the confirmed token at
                    // `p` plus the fed drafts: valid through the
                    // accepted prefix, clamped to what was written.
                    d.done[slot] = p + e.min(n - 1);
                }
            }
            self.maybe_complete(slot)?;
        }
        self.metrics.inc("decode_tokens", emitted_total);
        self.metrics.inc("decode_steps", 1);
        self.metrics.inc("spec_steps", 1);
        self.metrics.inc("spec_tokens", emitted_total);
        self.metrics.inc("spec_proposed", proposed);
        self.metrics.inc("spec_accepted", accepted);
        Ok(())
    }

    fn maybe_complete(&mut self, slot: usize) -> Result<()> {
        if !self.seqs.is_done(slot) {
            return Ok(());
        }
        let mut c = self.seqs.finish(slot, &mut self.cache)?;
        c.model = self.name.clone();
        // The slot will be reused: whatever the draft cache holds for it
        // belongs to the finished sequence.
        if let Some(d) = &mut self.draft {
            d.done[slot] = 0;
        }
        self.metrics.inc("completed", 1);
        self.metrics.observe("latency_s", c.latency_s);
        self.metrics.observe("queue_s", c.queue_s);
        self.metrics.observe("req_prefill_s", c.prefill_s);
        self.metrics.observe("ttft_s", c.ttft_s);
        if c.tpot_s > 0.0 {
            self.metrics.observe("tpot_s", c.tpot_s);
        }
        self.completions.push(c);
        Ok(())
    }

    /// Decode throughput measured so far (generated tokens / decode time).
    /// Uses lifetime totals, so it stays exact on long-running servers
    /// where the percentile window has trimmed old samples.
    pub fn decode_throughput(&self) -> f64 {
        let toks = self.metrics.counter("decode_tokens") as f64;
        let time = self.metrics.total("decode_s");
        if time > 0.0 {
            toks / time
        } else {
            0.0
        }
    }

    pub fn slots_check(&self) -> Result<()> {
        self.seqs.check_invariants()?;
        self.cache.check_invariants()
    }

    /// Snapshot of the cache store's memory accounting, for the server
    /// `stats` command and benches: actual bytes committed vs what the
    /// worst-case fixed reservation would hold (`batch * capacity`).
    pub fn cache_stats(&self) -> CacheStats {
        let spec = self.backend.spec();
        let fp32_per_token = spec.layout.per_token_per_layer() * spec.n_layers * 4;
        // Worst case stays fp32-denominated on purpose: it is the "what
        // would the unquantized fixed reservation cost" baseline, so the
        // dedup/compression ratios read as savings against it.
        let bytes_worst_case = spec.batch * spec.capacity * fp32_per_token;
        match &self.cache {
            CacheStore::Fixed(kv) => CacheStats {
                kind: "fixed",
                bytes_total: kv.bytes_total(),
                bytes_in_use: kv.bytes_total(),
                bytes_worst_case,
                block_size: 0,
                blocks_total: 0,
                blocks_in_use: 0,
                blocks_reserved: 0,
                bytes_deduped: 0,
                quant: QuantStats {
                    kind: QuantKind::Off.name(),
                    bytes_per_token: fp32_per_token,
                    bytes_per_token_fp32: fp32_per_token,
                    compression: 1.0,
                },
                prefix: None,
            },
            CacheStore::Paged(p) => {
                let bpt = p.bytes_per_token();
                let bpt_fp32 = p.bytes_per_token_fp32();
                CacheStats {
                    kind: "paged",
                    bytes_total: p.bytes_total(),
                    bytes_in_use: p.bytes_in_use(),
                    bytes_worst_case,
                    block_size: p.block_size,
                    blocks_total: p.n_blocks(),
                    blocks_in_use: p.blocks_in_use(),
                    blocks_reserved: p.blocks_reserved(),
                    bytes_deduped: p.bytes_deduped(),
                    quant: QuantStats {
                        kind: p.quant_kind().name(),
                        bytes_per_token: bpt,
                        bytes_per_token_fp32: bpt_fp32,
                        compression: bpt_fp32 as f64 / bpt.max(1) as f64,
                    },
                    prefix: p.prefix_stats(),
                }
            }
        }
    }
}

/// Cache memory accounting snapshot (see [`Engine::cache_stats`]).
#[derive(Clone, Copy, Debug)]
pub struct CacheStats {
    pub kind: &'static str,
    /// Bytes the pool's backing tensors occupy.
    pub bytes_total: usize,
    /// Bytes actually committed to live sequences (equals `bytes_total`
    /// for the fixed pool — every slot row is reserved up front).
    pub bytes_in_use: usize,
    /// What a worst-case `batch * capacity` reservation would occupy.
    pub bytes_worst_case: usize,
    /// Zero for the fixed pool.
    pub block_size: usize,
    pub blocks_total: usize,
    pub blocks_in_use: usize,
    pub blocks_reserved: usize,
    /// Bytes saved right now by cross-sequence block sharing: every
    /// table reference beyond a block's first would otherwise be a
    /// private copy. Zero for the fixed pool or with sharing off.
    pub bytes_deduped: usize,
    /// Block-codec accounting — always present; the fixed pool and an
    /// unquantized paged pool report kind `"off"` at compression 1.0.
    pub quant: QuantStats,
    /// Prefix-cache counters (hit rate, blocks shared/cached, evictions);
    /// `None` for the fixed pool or when `--prefix-cache off`.
    pub prefix: Option<PrefixStats>,
}

/// Block-codec slice of [`CacheStats`] (`stats.cache.quant` on the wire).
#[derive(Clone, Copy, Debug)]
pub struct QuantStats {
    /// Codec name: `"off"`, `"int8"`, or `"fp8"`.
    pub kind: &'static str,
    /// Encoded bytes one cached token actually occupies (all layers,
    /// both buffers — includes the per-row scale prefix).
    pub bytes_per_token: usize,
    /// What the same token costs unencoded (f32).
    pub bytes_per_token_fp32: usize,
    /// `bytes_per_token_fp32 / bytes_per_token` — 1.0 when off.
    pub compression: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{SimBackend, SimConfig};
    use crate::config::{CacheKind, PolicyKind};

    fn engine(seed: u64) -> Engine {
        Engine::new(
            SimBackend::gqa(4),
            EngineConfig { seed, ..Default::default() },
        )
    }

    #[test]
    fn admit_decode_complete_loop() {
        let mut e = engine(0);
        let comps = e
            .generate(vec![
                Request::from_text(0, "hello", 4),
                Request::from_text(1, "world!", 6),
            ])
            .unwrap();
        assert_eq!(comps.len(), 2);
        assert_eq!(comps[0].tokens.len(), 4);
        assert_eq!(comps[1].tokens.len(), 6);
        assert!(e.is_idle());
        e.slots_check().unwrap();
    }

    #[test]
    fn empty_prompt_does_not_panic() {
        // Regression: plen == 0 used to underflow `(plen - 1)` when
        // indexing prefill logits.
        let mut e = engine(1);
        let comps = e.generate(vec![Request::new(0, vec![], 3)]).unwrap();
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0].prompt_len, 0);
        assert_eq!(comps[0].tokens.len(), 3);
        e.slots_check().unwrap();
    }

    #[test]
    fn capacity_bounded_prompt_emits_the_final_token() {
        // Regression for the `next_pos + 1 >= capacity` off-by-one: a
        // prompt of capacity-2 leaves two cache writes, and the final
        // sampled token needs none — three tokens, not two.
        let mut e = engine(7);
        let cap = e.spec().capacity;
        let comps = e
            .generate(vec![Request::new(0, vec![65; cap - 2], 100)])
            .unwrap();
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0].tokens.len(), 3, "capacity-2 prompt yields 3 tokens");
        e.slots_check().unwrap();
    }

    #[test]
    fn chunked_policy_runs_the_full_loop_on_both_stores() {
        for cache in [
            CacheKind::Fixed,
            CacheKind::Paged { block_size: 8, n_blocks: None },
        ] {
            let mut e = Engine::new(
                SimBackend::gqa(4),
                EngineConfig {
                    policy: PolicyKind::Chunked { chunk_tokens: 3 },
                    cache,
                    ..Default::default()
                },
            );
            let comps = e
                .generate(vec![
                    Request::from_text(0, "a long prompt that takes chunks", 5),
                    Request::from_text(1, "short", 4),
                    Request::new(2, vec![], 3), // empty prompt chunks too
                ])
                .unwrap();
            assert_eq!(comps.len(), 3, "{cache:?}");
            assert_eq!(comps[0].tokens.len(), 5);
            assert_eq!(comps[1].tokens.len(), 4);
            assert_eq!(comps[2].tokens.len(), 3);
            assert!(e.metrics.counter("prefill_chunks") > 0);
            assert!(e.is_idle());
            e.slots_check().unwrap();
        }
    }

    #[test]
    fn chunked_ttft_decomposes_into_queue_and_prefill() {
        let mut e = Engine::new(
            SimBackend::gqa(2),
            EngineConfig {
                policy: PolicyKind::Chunked { chunk_tokens: 4 },
                ..Default::default()
            },
        );
        let comps = e
            .generate(vec![Request::from_text(0, "a chunked prompt arrives", 3)])
            .unwrap();
        let c = &comps[0];
        let sum = c.queue_s + c.prefill_s;
        assert!(
            (c.ttft_s - sum).abs() <= 1e-9,
            "ttft {} != queue {} + prefill {}",
            c.ttft_s,
            c.queue_s,
            c.prefill_s
        );
        assert!(e.metrics.summary("req_prefill_s").is_some());
    }

    #[test]
    fn paged_cache_runs_the_full_loop_and_releases_blocks() {
        let mut e = Engine::new(
            SimBackend::gqa(4),
            EngineConfig {
                cache: CacheKind::Paged { block_size: 8, n_blocks: None },
                ..Default::default()
            },
        );
        let comps = e
            .generate(vec![
                Request::from_text(0, "hello", 4),
                Request::from_text(1, "paged world", 6),
                Request::new(2, vec![], 3), // empty prompt pages too
            ])
            .unwrap();
        assert_eq!(comps.len(), 3);
        assert_eq!(comps[0].tokens.len(), 4);
        assert_eq!(comps[1].tokens.len(), 6);
        assert_eq!(comps[2].tokens.len(), 3);
        let cs = e.cache_stats();
        assert_eq!(cs.kind, "paged");
        assert_eq!(cs.blocks_in_use, 0, "all blocks released on completion");
        assert_eq!(cs.blocks_reserved, 0);
        assert!(e.metrics.summary("blocks_in_use").is_some());
        e.slots_check().unwrap();
    }

    #[test]
    fn undersized_paged_pool_is_a_construction_error() {
        let r = Engine::try_new(
            SimBackend::gqa(4),
            EngineConfig {
                // One 8-token block cannot hold a 64-token sequence.
                cache: CacheKind::Paged { block_size: 8, n_blocks: Some(1) },
                ..Default::default()
            },
        );
        assert!(r.is_err(), "pool below one full sequence must be rejected");
    }

    #[test]
    fn completions_drain_instead_of_growing() {
        let mut e = engine(2);
        e.submit(Request::from_text(0, "abc", 2));
        e.run_to_completion().unwrap();
        assert_eq!(e.take_completions().len(), 1);
        assert!(e.take_completions().is_empty(), "drained");
        e.submit(Request::from_text(1, "def", 2));
        e.run_to_completion().unwrap();
        let again = e.take_completions();
        assert_eq!(again.len(), 1);
        assert_eq!(again[0].id, 1);
    }

    #[test]
    fn completions_carry_engine_name_and_effective_budget() {
        let mut e = engine(3);
        e.set_name("mla-paged");
        let cap = e.spec().capacity;
        // Over-asking clamps to the cache room (prompt 2 + write-free
        // final token) and the completion echoes the enforced budget.
        let comps = e.generate(vec![Request::from_text(0, "hi", 100_000)]).unwrap();
        assert_eq!(comps[0].model, "mla-paged");
        assert_eq!(comps[0].max_new, cap - 2 + 1);
        assert_eq!(comps[0].tokens.len(), cap - 2 + 1);
        assert_eq!(e.max_new_ceiling(2), cap - 2 + 1);
        // An in-range budget echoes unchanged.
        let comps = e.generate(vec![Request::from_text(1, "hi", 4)]).unwrap();
        assert_eq!(comps[0].max_new, 4);
        assert_eq!(comps[0].model, "mla-paged");
    }

    #[test]
    fn engine_is_send() {
        // Worker mode moves whole engines onto threads; the bound must
        // hold for the boxed backend + policy + cache store stack.
        fn assert_send<T: Send>() {}
        assert_send::<Engine>();
    }

    #[test]
    fn overlapped_step_matches_serial_bit_exactly() {
        // The core dual-stream claim, at unit scope: same requests, same
        // seed, overlap on vs off → identical token streams AND identical
        // rng draw order (temperature > 0 makes any divergence visible).
        for mla in [false, true] {
            for cache in [
                CacheKind::Fixed,
                CacheKind::Paged { block_size: 8, n_blocks: None },
            ] {
                let build = |overlap: bool| {
                    let cfg = EngineConfig {
                        policy: PolicyKind::Chunked { chunk_tokens: 3 },
                        cache,
                        temperature: 0.7,
                        seed: 42,
                        overlap,
                        ..Default::default()
                    };
                    if mla {
                        Engine::new(SimBackend::mla(4, 8), cfg)
                    } else {
                        Engine::new(SimBackend::gqa(4), cfg)
                    }
                };
                let reqs = || {
                    vec![
                        Request::from_text(0, "a long prompt that takes many chunks", 6),
                        Request::from_text(1, "short", 5),
                        Request::from_text(2, "medium length one", 4),
                        Request::new(3, vec![], 3),
                    ]
                };
                let mut serial = build(false);
                let mut overlapped = build(true);
                let a = serial.generate(reqs()).unwrap();
                let b = overlapped.generate(reqs()).unwrap();
                assert!(
                    overlapped.metrics.counter("overlap_steps") > 0,
                    "overlap path must actually run (mla={mla}, {cache:?})"
                );
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(&b) {
                    assert_eq!(x.id, y.id);
                    assert_eq!(x.tokens, y.tokens, "mla={mla}, {cache:?}");
                    assert_eq!(x.max_new, y.max_new);
                }
                overlapped.slots_check().unwrap();
            }
        }
    }

    #[test]
    fn overlap_gates_off_without_decode_work() {
        // A lone request never has both streams live: the engine must
        // fall back to the serial path and still finish.
        let mut e = Engine::new(
            SimBackend::gqa(2),
            EngineConfig {
                policy: PolicyKind::Chunked { chunk_tokens: 2 },
                overlap: true,
                ..Default::default()
            },
        );
        let comps = e.generate(vec![Request::from_text(0, "solo", 3)]).unwrap();
        assert_eq!(comps[0].tokens.len(), 3);
        assert_eq!(
            e.metrics.counter("overlap_steps"),
            0,
            "one sequence cannot overlap with itself"
        );
    }

    #[test]
    fn speculative_decode_matches_serial_and_takes_fewer_steps() {
        let reqs = || {
            vec![
                Request::from_text(0, "hello speculative decoding", 12),
                Request::from_text(1, "w", 9),
                Request::new(2, vec![], 5), // empty prompt speculates too
            ]
        };
        let mut plain = engine(0);
        let a = plain.generate(reqs()).unwrap();
        let mut spec = Engine::new(
            SimBackend::gqa(4),
            EngineConfig {
                policy: PolicyKind::Speculative { k: 4 },
                ..Default::default()
            },
        );
        // The sim's state chain depends only on tokens + seed, never on
        // layout or rank, so a same-seed MLA draft agrees with the GQA
        // target on every greedy token: acceptance is perfect.
        spec.set_draft(Box::new(SimBackend::mla(4, 2))).unwrap();
        assert_eq!(spec.draft_name().unwrap(), "sim");
        let b = spec.generate(reqs()).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.tokens, y.tokens, "speculative output must be bit-identical");
        }
        let s = spec.spec_stats();
        assert!(s.steps > 0 && s.proposed > 0);
        assert_eq!(s.accepted, s.proposed, "same-seed draft never misses");
        assert_eq!(s.acceptance_rate, 1.0);
        assert!(s.tokens_per_step > 1.0, "got {}", s.tokens_per_step);
        assert!(
            spec.metrics.counter("decode_steps") < plain.metrics.counter("decode_steps"),
            "speculation must take fewer target iterations ({} vs {})",
            spec.metrics.counter("decode_steps"),
            plain.metrics.counter("decode_steps")
        );
        spec.slots_check().unwrap();
    }

    #[test]
    fn mismatched_draft_disagrees_but_stays_correct() {
        // A draft from a different seed proposes junk: the verify walk
        // must reject it and still emit the target's exact stream.
        let mut plain = engine(0);
        let a = plain.generate(vec![Request::from_text(0, "abc", 8)]).unwrap();
        let mut spec = Engine::new(
            SimBackend::gqa(4),
            EngineConfig {
                policy: PolicyKind::Speculative { k: 4 },
                ..Default::default()
            },
        );
        let draft = SimBackend::new(SimConfig { seed: 999, ..SimConfig::gqa(4) }).unwrap();
        spec.set_draft(Box::new(draft)).unwrap();
        let b = spec.generate(vec![Request::from_text(0, "abc", 8)]).unwrap();
        assert_eq!(a[0].tokens, b[0].tokens);
        let s = spec.spec_stats();
        assert!(
            s.acceptance_rate < 0.5,
            "a foreign-seed draft should rarely agree, got {}",
            s.acceptance_rate
        );
        spec.slots_check().unwrap();
    }

    #[test]
    fn speculative_policy_without_draft_falls_back_to_serial() {
        // The XLA shape of the world: a speculate plan with no draft (or
        // no verify support) degrades to the plain decode step.
        let mut e = Engine::new(
            SimBackend::gqa(2),
            EngineConfig {
                policy: PolicyKind::Speculative { k: 4 },
                ..Default::default()
            },
        );
        let comps = e.generate(vec![Request::from_text(0, "solo", 5)]).unwrap();
        assert_eq!(comps[0].tokens.len(), 5);
        assert_eq!(e.spec_stats().steps, 0, "no draft, no verify iterations");
        let mut plain = engine(9);
        let a = plain.generate(vec![Request::from_text(0, "solo", 5)]).unwrap();
        assert_eq!(a[0].tokens, comps[0].tokens);
    }

    #[test]
    fn set_draft_rejects_mismatched_geometry() {
        let mut e = engine(0);
        assert!(
            e.set_draft(Box::new(SimBackend::gqa(3))).is_err(),
            "batch mismatch"
        );
        let short = SimConfig { capacity: 16, prefill_seq: 16, ..SimConfig::gqa(4) };
        assert!(
            e.set_draft(Box::new(SimBackend::new(short).unwrap())).is_err(),
            "capacity mismatch"
        );
        assert!(e.set_draft(Box::new(SimBackend::mla(4, 2))).is_ok());
    }

    #[test]
    fn admission_log_stays_bounded() {
        // The ring buffer keeps only the most recent entries.
        let mut e = Engine::new(
            SimBackend::gqa(1),
            EngineConfig {
                policy: PolicyKind::DecodeFirst,
                ..Default::default()
            },
        );
        for i in 0..(super::ADMISSION_LOG_CAP as u64 + 10) {
            e.submit(Request::from_text(i, "x", 1));
        }
        e.run_to_completion().unwrap();
        assert_eq!(e.admission_log().len(), super::ADMISSION_LOG_CAP);
        // The newest admission is the last request id.
        let last = e.admission_log().back().unwrap();
        assert_eq!(last.1, vec![super::ADMISSION_LOG_CAP as u64 + 9]);
    }
}
