//! L3 coordinator — the serving system around the execution backends:
//! request queue, continuous batcher, pluggable prefill/decode scheduler,
//! sequence/slot lifecycle, sampling, and per-request accounting.
//!
//! This is the paper's deployment story: after TransMLA conversion the
//! MLA model drops into the same engine as the GQA baseline (same slots,
//! same scheduler), but with the latent cache layout — the serving-side
//! speedup of Sec. 5.4 falls out of the smaller per-step cache traffic.
//!
//! Layering (see `backend` for the execution side):
//!
//!   * [`engine`] — the continuous-batching loop over `dyn ExecBackend`;
//!   * [`scheduler`] — `SchedulePolicy` (admit-first / decode-first /
//!     hybrid) deciding admission vs decode each iteration;
//!   * [`seqmgr`] — `SequenceManager`: slot lifecycle, length tracking,
//!     completion rules, TTFT/TPOT accounting.

pub mod engine;
pub mod request;
pub mod sampling;
pub mod scheduler;
pub mod seqmgr;

pub use crate::backend::{Arch, CacheStore, ModelBundle};
pub use engine::{CacheStats, Engine};
pub use request::{Completion, Request};
pub use scheduler::{Action, SchedView, SchedulePolicy};
pub use seqmgr::SequenceManager;
