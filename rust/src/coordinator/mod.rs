//! L3 coordinator — the serving system around the AOT-compiled models:
//! request queue, continuous batcher, prefill/decode scheduler, sampling,
//! and per-request accounting.
//!
//! This is the paper's deployment story: after TransMLA conversion the
//! MLA model drops into the same engine as the GQA baseline (same slots,
//! same scheduler), but with the latent cache layout — the serving-side
//! speedup of Sec. 5.4 falls out of the smaller per-step cache traffic.

pub mod engine;
pub mod request;
pub mod sampling;

pub use engine::{Engine, ModelBundle};
pub use request::{Completion, Request};
