//! L3 coordinator — the serving system around the execution backends:
//! request queue, continuous batcher, pluggable prefill/decode scheduler,
//! sequence/slot lifecycle, sampling, and per-request accounting.
//!
//! This is the paper's deployment story: after TransMLA conversion the
//! MLA model drops into the same engine as the GQA baseline (same slots,
//! same scheduler), but with the latent cache layout — the serving-side
//! speedup of Sec. 5.4 falls out of the smaller per-step cache traffic.
//!
//! Layering (see `backend` for the execution side):
//!
//!   * [`engine`] — the continuous-batching loop over `dyn ExecBackend`:
//!     a `StepPlan` *executor*;
//!   * [`scheduler`] — `SchedulePolicy` (admit-first / decode-first /
//!     hybrid / chunked) building a per-iteration `StepPlan` over the
//!     three queues (waiting → prefilling → decoding) — admissions,
//!     bounded prefill work, and a decode step compose in one iteration;
//!   * [`seqmgr`] — `SequenceManager`: slot lifecycle with the
//!     prefilling/decoding phase split and per-slot prefilled watermark,
//!     completion rules, TTFT (queue + prefill) / TPOT accounting.

pub mod engine;
pub mod request;
pub mod sampling;
pub mod scheduler;
pub mod seqmgr;

pub use crate::backend::{Arch, CacheStore, ModelBundle};
pub use engine::{CacheStats, Engine, QuantStats};
pub use request::{Completion, Request};
pub use scheduler::{PrefillWork, SchedView, SchedulePolicy, StepPlan};
pub use seqmgr::{AdmitError, SeqPhase, SequenceManager};
