//! Request / completion types shared by the engine, server, and benches.

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Byte-level prompt tokens.
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// 0.0 = greedy.
    pub temperature: f32,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        Request { id, prompt, max_new_tokens, temperature: 0.0 }
    }

    pub fn from_text(id: u64, text: &str, max_new_tokens: usize) -> Self {
        Request::new(id, text.bytes().map(|b| b as i32).collect(), max_new_tokens)
    }
}

#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    /// Name of the engine that served this request (`"default"` outside
    /// a multi-model registry). Stamped by the engine at completion so
    /// multi-model servers can route replies and clients can verify
    /// which model answered (protocol v2 `model` field).
    pub model: String,
    pub prompt_len: usize,
    /// Effective new-token budget: the submitted `max_new` clamped to
    /// the engine's remaining cache capacity for this prompt — the bound
    /// the completion rule actually enforced. Echoed on the wire so a
    /// client that over-asked sees what was serveable.
    pub max_new: usize,
    pub tokens: Vec<i32>,
    /// Wall-clock seconds from enqueue to completion.
    pub latency_s: f64,
    /// Seconds spent queued before this request's prefill started.
    pub queue_s: f64,
    /// Seconds from prefill start to first token — the prefill component
    /// of TTFT (under chunked prefill this spans the interleaved decode
    /// steps too). `ttft_s = queue_s + prefill_s`.
    pub prefill_s: f64,
    /// Time to first token (enqueue -> prefill done), seconds; always
    /// >= `queue_s` by the prefill duration.
    pub ttft_s: f64,
    /// Mean time per decoded output token, seconds (0 if none decoded).
    pub tpot_s: f64,
}

impl Completion {
    pub fn text(&self) -> String {
        let bytes: Vec<u8> = self
            .tokens
            .iter()
            .map(|&t| t.clamp(0, 255) as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn text_roundtrip() {
        let r = Request::from_text(1, "hi there", 4);
        assert_eq!(r.prompt, vec![104, 105, 32, 116, 104, 101, 114, 101]);
        let c = Completion {
            id: 1,
            model: "default".to_string(),
            prompt_len: 8,
            max_new: 2,
            tokens: vec![111, 107],
            latency_s: 0.0,
            queue_s: 0.0,
            prefill_s: 0.0,
            ttft_s: 0.0,
            tpot_s: 0.0,
        };
        assert_eq!(c.text(), "ok");
    }
}
