//! `.tnz` — a minimal named-tensor archive for checkpoints and converted
//! weights (offline stand-in for safetensors/npz).
//!
//! Layout (little-endian):
//!   magic "TNZ1" | u32 n_entries | u32 meta_len | meta (JSON, UTF-8)
//!   then per entry:
//!     u32 name_len | name | u32 rank | u64 dims[rank] | f32 data[...]

use crate::json::Json;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"TNZ1";

#[derive(Debug, Clone)]
pub struct TensorArchive {
    pub tensors: BTreeMap<String, Tensor>,
    pub meta: Json,
}

impl Default for TensorArchive {
    fn default() -> Self {
        Self::new()
    }
}

impl TensorArchive {
    pub fn new() -> Self {
        TensorArchive { tensors: BTreeMap::new(), meta: Json::obj() }
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("tensor `{name}` missing from archive"))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        let meta = self.meta.to_string().into_bytes();
        f.write_all(&(meta.len() as u32).to_le_bytes())?;
        f.write_all(&meta)?;
        for (name, t) in &self.tensors {
            let nb = name.as_bytes();
            f.write_all(&(nb.len() as u32).to_le_bytes())?;
            f.write_all(nb)?;
            f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            // Bulk-write the f32 payload.
            let bytes: Vec<u8> =
                t.data.iter().flat_map(|x| x.to_le_bytes()).collect();
            f.write_all(&bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path)
                .with_context(|| format!("open {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{} is not a TNZ1 archive", path.display());
        }
        let n = read_u32(&mut f)? as usize;
        let meta_len = read_u32(&mut f)? as usize;
        let mut meta_buf = vec![0u8; meta_len];
        f.read_exact(&mut meta_buf)?;
        let meta = Json::parse(std::str::from_utf8(&meta_buf)?)?;
        let mut tensors = BTreeMap::new();
        for _ in 0..n {
            let name_len = read_u32(&mut f)? as usize;
            let mut name_buf = vec![0u8; name_len];
            f.read_exact(&mut name_buf)?;
            let name = String::from_utf8(name_buf)?;
            let rank = read_u32(&mut f)? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                let mut b = [0u8; 8];
                f.read_exact(&mut b)?;
                shape.push(u64::from_le_bytes(b) as usize);
            }
            let count: usize = shape.iter().product();
            let mut bytes = vec![0u8; count * 4];
            f.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.insert(name, Tensor::new(&shape, data)?);
        }
        Ok(TensorArchive { tensors, meta })
    }
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(0);
        let mut ar = TensorArchive::new();
        ar.insert("a", Tensor::randn(&[3, 4], 1.0, &mut rng));
        ar.insert("b/c", Tensor::randn(&[2, 2, 2], 1.0, &mut rng));
        ar.insert("scalar", Tensor::scalar(7.5));
        ar.meta.set("step", Json::Num(42.0));
        let dir = std::env::temp_dir().join("transmla_io_test");
        let path = dir.join("x.tnz");
        ar.save(&path).unwrap();
        let back = TensorArchive::load(&path).unwrap();
        assert_eq!(back.tensors.len(), 3);
        assert_eq!(back.get("a").unwrap(), ar.get("a").unwrap());
        assert_eq!(back.get("b/c").unwrap().shape, vec![2, 2, 2]);
        assert_eq!(back.meta.get("step").unwrap().as_f64(), Some(42.0));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_tensor_errors() {
        let ar = TensorArchive::new();
        assert!(ar.get("nope").is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("transmla_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.tnz");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(TensorArchive::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
