//! Open-loop traffic harness: seeded traces, replay, SLO/goodput report.
//!
//! Every serving number this repo publishes flows through three pieces:
//!
//!   * [`trace`] — a deterministic seeded trace generator: Poisson /
//!     bursty / diurnal-ramp arrival processes over a two-tenant prompt
//!     mix (shared-prefix agent traffic vs long-tail chat), fully
//!     reproducible from one `util::prng` seed and serializable as
//!     byte-stable JSONL;
//!   * [`driver`] — an open-loop replayer: one thread per scheduled
//!     event fires at its trace time against a live server over
//!     loopback TCP, independent of completions, and classifies the
//!     single reply (completed / shed / error);
//!   * [`report`] — p50/p95/p99 TTFT/TPOT summaries and goodput under a
//!     configurable [`crate::config::SloSpec`], emitted as JSONL
//!     comparison rows (per policy × cache × route — what
//!     `bench_serving` feeds into `BENCH_serving.json`) and a small
//!     static HTML table.
//!
//! The server-side counterpart is admission backpressure
//! (`serve --max-pending N`): a bounded pending queue that sheds excess
//! requests in-band (`{"error":"overloaded","retry_after_ms":...}`, see
//! `docs/PROTOCOL.md`) so sustained overload degrades goodput
//! gracefully instead of growing queue waits without bound —
//! `rust/tests/integration_workload.rs` drives a 3×-sustainable trace
//! through both halves and pins the graceful-degradation claim.

pub mod driver;
pub mod report;
pub mod trace;

pub use driver::{replay, Outcome, RunOutcome, RunResult};
pub use report::{render_bench_trend_html, render_html, to_jsonl, ReportRow};
pub use trace::{ArrivalKind, Tenant, Trace, TraceEvent, TraceSpec};
