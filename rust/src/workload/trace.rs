//! Seeded open-loop trace generation.
//!
//! A trace is a fully materialized arrival schedule: every request's
//! send time, tenant, prompt bytes, and decode budget, decided up front
//! from one `util::prng` seed. The driver then replays the schedule
//! *open-loop* — send times never depend on completions — which is the
//! only arrival model under which goodput/SLO numbers mean anything
//! (closed-loop clients self-throttle and hide overload).
//!
//! Three arrival processes cover the serving regimes the paper's
//! throughput claims live in:
//!
//!   * `poisson` — memoryless steady-state arrivals at `rate`/s
//!     (exponential inter-arrival gaps);
//!   * `bursty:B` — Poisson-arriving *bursts* of ~B back-to-back
//!     requests (mean total rate still `rate`/s) — the agent-fanout
//!     pattern that stresses admission and the paged pool;
//!   * `ramp` — a diurnal half-sine: the instantaneous rate ramps from
//!     0.25× through 1.75× of `rate` and back across the trace
//!     duration (thinning over the peak rate), so a fixed `--max-pending`
//!     bound sees both slack and overload in one run.
//!
//! Two tenants model the prompt mix: **agent** traffic shares one fixed
//! prompt prefix (exercising `--prefix-cache` sharing) with a short
//! random suffix and a homogeneous decode budget; **chat** traffic is
//! long-tail — lengths drawn from a cubed-uniform (mostly short, rare
//! long) with per-request decode budgets.
//!
//! Determinism contract: `generate` draws every random value from one
//! `Rng::new(seed)` stream in event order, and `to_jsonl` serializes
//! through the `BTreeMap`-backed [`Json`] writer — same spec ⇒
//! byte-identical JSONL (`integration_workload.rs` pins this).

use crate::json::Json;
use crate::util::Rng;
use anyhow::{bail, Context, Result};

/// Arrival process of a trace (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Memoryless arrivals at the spec rate.
    Poisson,
    /// Poisson-arriving bursts of ~`burst` back-to-back requests.
    Bursty { burst: usize },
    /// Diurnal half-sine ramp (0.25×..1.75× of the spec rate).
    Ramp,
}

impl ArrivalKind {
    /// Parse `poisson` / `bursty[:B]` / `ramp`.
    pub fn parse(s: &str) -> Result<ArrivalKind> {
        match s {
            "poisson" => Ok(ArrivalKind::Poisson),
            "ramp" => Ok(ArrivalKind::Ramp),
            "bursty" => Ok(ArrivalKind::Bursty { burst: 8 }),
            other => match other.strip_prefix("bursty:") {
                Some(b) => Ok(ArrivalKind::Bursty {
                    burst: b
                        .parse::<usize>()
                        .ok()
                        .filter(|&n| n >= 1)
                        .with_context(|| format!("bad burst size `{b}`"))?,
                }),
                None => bail!("unknown arrival process `{other}` (poisson|bursty[:B]|ramp)"),
            },
        }
    }

    /// Wire/report spelling (round-trips through [`ArrivalKind::parse`]).
    pub fn name(&self) -> String {
        match self {
            ArrivalKind::Poisson => "poisson".to_string(),
            ArrivalKind::Bursty { burst } => format!("bursty:{burst}"),
            ArrivalKind::Ramp => "ramp".to_string(),
        }
    }
}

/// Traffic tenant of one trace event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tenant {
    /// Shared-prefix agent traffic (homogeneous decode budget).
    Agent,
    /// Long-tail chat traffic (varied lengths and budgets).
    Chat,
}

impl Tenant {
    pub fn name(&self) -> &'static str {
        match self {
            Tenant::Agent => "agent",
            Tenant::Chat => "chat",
        }
    }

    pub fn parse(s: &str) -> Result<Tenant> {
        match s {
            "agent" => Ok(Tenant::Agent),
            "chat" => Ok(Tenant::Chat),
            other => bail!("unknown tenant `{other}` (agent|chat)"),
        }
    }
}

/// Everything [`Trace::generate`] needs; one seed reproduces the trace.
#[derive(Clone, Debug)]
pub struct TraceSpec {
    pub seed: u64,
    pub arrivals: ArrivalKind,
    /// Mean arrival rate, requests/s.
    pub rate: f64,
    /// Trace span, seconds (arrivals past it are dropped).
    pub duration_s: f64,
    /// Fraction of events carrying agent (shared-prefix) traffic.
    pub agent_frac: f64,
    /// Decode-budget ceiling: agent events use it verbatim, chat events
    /// draw uniformly from `1..=max_new`.
    pub max_new: usize,
    /// The shared agent prompt prefix (keep it under the serving
    /// engine's `max_prompt` together with the suffix).
    pub agent_prefix: String,
    /// Agent suffix length bounds, bytes (inclusive).
    pub agent_suffix: (usize, usize),
    /// Chat prompt length bounds, bytes (inclusive; cubed-uniform draw
    /// skews toward the minimum — long prompts are the rare tail).
    pub chat_len: (usize, usize),
}

impl Default for TraceSpec {
    fn default() -> Self {
        TraceSpec {
            seed: 0,
            arrivals: ArrivalKind::Poisson,
            rate: 32.0,
            duration_s: 2.0,
            agent_frac: 0.5,
            max_new: 16,
            agent_prefix: "agent: answer from the shared context. q: ".to_string(),
            agent_suffix: (4, 24),
            chat_len: (8, 96),
        }
    }
}

/// One scheduled request.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Send time, seconds from trace start.
    pub at_s: f64,
    pub tenant: Tenant,
    pub prompt: String,
    pub max_new: usize,
}

/// A materialized arrival schedule (spec + events, in time order).
#[derive(Clone, Debug)]
pub struct Trace {
    pub spec: TraceSpec,
    pub events: Vec<TraceEvent>,
}

/// Word pool for synthetic prompt bytes (ASCII only, so byte-length
/// truncation is char-safe).
const WORDS: &[&str] = &[
    "latent", "cache", "rotary", "absorb", "decode", "prefill", "block", "route",
    "tenant", "batch", "paged", "rank", "head", "chunk", "query", "stream",
];

fn words_of_len(rng: &mut Rng, len: usize) -> String {
    let mut s = String::with_capacity(len + 8);
    while s.len() < len {
        if !s.is_empty() {
            s.push(' ');
        }
        s.push_str(WORDS[rng.below(WORDS.len())]);
    }
    s.truncate(len.max(1));
    s
}

/// Exponential inter-arrival gap for a Poisson process at `rate`/s.
fn exp_gap(rng: &mut Rng, rate: f64) -> f64 {
    // uniform() is [0, 1): 1-u is (0, 1], so ln stays finite.
    -(1.0 - rng.uniform()).ln() / rate
}

impl TraceSpec {
    fn validate(&self) -> Result<()> {
        if !(self.rate > 0.0 && self.rate.is_finite()) {
            bail!("trace rate must be a positive finite number (got {})", self.rate);
        }
        if !(self.duration_s > 0.0 && self.duration_s.is_finite()) {
            bail!("trace duration must be positive (got {})", self.duration_s);
        }
        if !(0.0..=1.0).contains(&self.agent_frac) {
            bail!("agent_frac must be in [0, 1] (got {})", self.agent_frac);
        }
        if self.max_new == 0 {
            bail!("max_new must be >= 1");
        }
        for (name, (lo, hi)) in
            [("agent_suffix", self.agent_suffix), ("chat_len", self.chat_len)]
        {
            if lo == 0 || lo > hi {
                bail!("{name} bounds must satisfy 1 <= min <= max (got {lo}..{hi})");
            }
        }
        Ok(())
    }
}

impl Trace {
    /// Materialize the schedule: arrival times first, then per-event
    /// tenant/prompt/budget — all from one seeded stream, in order.
    pub fn generate(spec: &TraceSpec) -> Result<Trace> {
        spec.validate()?;
        let mut rng = Rng::new(spec.seed);
        let mut times = Vec::new();
        match spec.arrivals {
            ArrivalKind::Poisson => {
                let mut t = exp_gap(&mut rng, spec.rate);
                while t < spec.duration_s {
                    times.push(t);
                    t += exp_gap(&mut rng, spec.rate);
                }
            }
            ArrivalKind::Bursty { burst } => {
                // Bursts arrive Poisson at rate/burst; each carries
                // 1..=2*burst-1 requests (mean `burst`) 0.2ms apart, so
                // the total mean rate stays `rate`.
                let mut t = exp_gap(&mut rng, spec.rate / burst as f64);
                while t < spec.duration_s {
                    let n = rng.range(1, 2 * burst);
                    for k in 0..n {
                        let at = t + k as f64 * 2e-4;
                        if at < spec.duration_s {
                            times.push(at);
                        }
                    }
                    t += exp_gap(&mut rng, spec.rate / burst as f64);
                }
            }
            ArrivalKind::Ramp => {
                // Thinning: candidates at the 1.75× peak, kept with
                // probability rate(t)/peak where rate(t) follows a
                // half-sine diurnal curve 0.25×..1.75×.
                let peak = 1.75 * spec.rate;
                let mut t = exp_gap(&mut rng, peak);
                while t < spec.duration_s {
                    let phase = std::f64::consts::PI * t / spec.duration_s;
                    let rate_t = spec.rate * (0.25 + 1.5 * phase.sin());
                    if rng.uniform() < rate_t / peak {
                        times.push(t);
                    }
                    t += exp_gap(&mut rng, peak);
                }
            }
        }
        let mut events = Vec::with_capacity(times.len());
        for at_s in times {
            let tenant = if rng.uniform() < spec.agent_frac {
                Tenant::Agent
            } else {
                Tenant::Chat
            };
            let (prompt, max_new) = match tenant {
                Tenant::Agent => {
                    let n = rng.range(spec.agent_suffix.0, spec.agent_suffix.1 + 1);
                    let suffix = words_of_len(&mut rng, n);
                    (format!("{}{suffix}", spec.agent_prefix), spec.max_new)
                }
                Tenant::Chat => {
                    // Cubed-uniform length: mostly near the minimum,
                    // rare long-tail prompts near the maximum.
                    let span = (spec.chat_len.1 - spec.chat_len.0) as f64;
                    let u = rng.uniform();
                    let n = spec.chat_len.0 + (span * u * u * u) as usize;
                    let prompt = words_of_len(&mut rng, n);
                    (prompt, rng.range(1, spec.max_new + 1))
                }
            };
            events.push(TraceEvent { at_s, tenant, prompt, max_new });
        }
        Ok(Trace { spec: spec.clone(), events })
    }

    /// Serialize: one meta line, then one line per event, all through
    /// the deterministic [`Json`] writer — byte-stable per seed.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        let mut meta = Json::obj();
        meta.set("agent_frac", Json::Num(self.spec.agent_frac));
        meta.set("arrivals", Json::Str(self.spec.arrivals.name()));
        meta.set("duration_s", Json::Num(self.spec.duration_s));
        meta.set("events", Json::Num(self.events.len() as f64));
        meta.set("rate", Json::Num(self.spec.rate));
        meta.set("seed", Json::Num(self.spec.seed as f64));
        meta.set("trace", Json::Str("v1".to_string()));
        out.push_str(&meta.to_string());
        out.push('\n');
        for e in &self.events {
            let mut j = Json::obj();
            j.set("at_s", Json::Num(e.at_s));
            j.set("max_new", Json::Num(e.max_new as f64));
            j.set("prompt", Json::Str(e.prompt.clone()));
            j.set("tenant", Json::Str(e.tenant.name().to_string()));
            out.push_str(&j.to_string());
            out.push('\n');
        }
        out
    }

    /// Parse a [`Trace::to_jsonl`] file back. Spec fields absent from
    /// the meta line (prompt-mix bounds) take their defaults — they
    /// only matter for generation, which already happened.
    pub fn parse_jsonl(s: &str) -> Result<Trace> {
        let mut lines = s.lines().filter(|l| !l.trim().is_empty());
        let meta = Json::parse(lines.next().context("empty trace file")?)?;
        if meta.get("trace").and_then(Json::as_str) != Some("v1") {
            bail!("not a v1 trace file (missing `\"trace\":\"v1\"` meta line)");
        }
        let num = |k: &str| -> Result<f64> {
            meta.get(k)
                .and_then(Json::as_f64)
                .with_context(|| format!("trace meta missing `{k}`"))
        };
        let spec = TraceSpec {
            seed: num("seed")? as u64,
            arrivals: ArrivalKind::parse(
                meta.get("arrivals").and_then(Json::as_str).context("meta `arrivals`")?,
            )?,
            rate: num("rate")?,
            duration_s: num("duration_s")?,
            agent_frac: num("agent_frac")?,
            ..TraceSpec::default()
        };
        let mut events = Vec::new();
        for line in lines {
            let j = Json::parse(line)?;
            events.push(TraceEvent {
                at_s: j.get("at_s").and_then(Json::as_f64).context("event `at_s`")?,
                tenant: Tenant::parse(
                    j.get("tenant").and_then(Json::as_str).context("event `tenant`")?,
                )?,
                prompt: j
                    .get("prompt")
                    .and_then(Json::as_str)
                    .context("event `prompt`")?
                    .to_string(),
                max_new: j
                    .get("max_new")
                    .and_then(Json::as_usize)
                    .context("event `max_new`")?,
            });
        }
        Ok(Trace { spec, events })
    }

    /// Longest prompt in the trace, bytes (admission sizing helper).
    pub fn max_prompt_len(&self) -> usize {
        self.events.iter().map(|e| e.prompt.len()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_kinds() -> Vec<ArrivalKind> {
        vec![
            ArrivalKind::Poisson,
            ArrivalKind::Bursty { burst: 4 },
            ArrivalKind::Ramp,
        ]
    }

    #[test]
    fn arrival_kind_parses_and_round_trips() {
        for s in ["poisson", "bursty:4", "ramp"] {
            assert_eq!(ArrivalKind::parse(s).unwrap().name(), s);
        }
        assert_eq!(ArrivalKind::parse("bursty").unwrap(), ArrivalKind::Bursty { burst: 8 });
        assert!(ArrivalKind::parse("bursty:0").is_err());
        assert!(ArrivalKind::parse("flat").is_err());
    }

    #[test]
    fn generation_is_sorted_in_time_and_bounded() {
        for arrivals in all_kinds() {
            let spec = TraceSpec { arrivals, rate: 200.0, duration_s: 0.5, ..Default::default() };
            let trace = Trace::generate(&spec).unwrap();
            assert!(!trace.events.is_empty(), "{arrivals:?} produced no events");
            for w in trace.events.windows(2) {
                assert!(w[0].at_s <= w[1].at_s, "{arrivals:?} out of order");
            }
            for e in &trace.events {
                assert!(e.at_s < spec.duration_s);
                assert!((1..=spec.max_new).contains(&e.max_new));
                assert!(!e.prompt.is_empty());
                if e.tenant == Tenant::Agent {
                    assert!(e.prompt.starts_with(&spec.agent_prefix));
                }
            }
        }
    }

    #[test]
    fn same_seed_same_trace_different_seed_different_trace() {
        for arrivals in all_kinds() {
            let spec = TraceSpec { arrivals, rate: 150.0, duration_s: 0.4, ..Default::default() };
            let a = Trace::generate(&spec).unwrap();
            let b = Trace::generate(&spec).unwrap();
            assert_eq!(a.to_jsonl(), b.to_jsonl(), "{arrivals:?} not reproducible");
            let other = Trace::generate(&TraceSpec { seed: 99, ..spec }).unwrap();
            assert_ne!(a.to_jsonl(), other.to_jsonl(), "{arrivals:?} ignores the seed");
        }
    }

    #[test]
    fn jsonl_round_trips_byte_identically() {
        let spec = TraceSpec { rate: 100.0, duration_s: 0.3, ..Default::default() };
        let trace = Trace::generate(&spec).unwrap();
        let text = trace.to_jsonl();
        let parsed = Trace::parse_jsonl(&text).unwrap();
        assert_eq!(parsed.events.len(), trace.events.len());
        assert_eq!(parsed.to_jsonl(), text, "parse/serialize must be a fixed point");
        assert!(Trace::parse_jsonl("{\"nope\":1}\n").is_err());
    }

    #[test]
    fn spec_validation_rejects_nonsense() {
        assert!(Trace::generate(&TraceSpec { rate: 0.0, ..Default::default() }).is_err());
        assert!(Trace::generate(&TraceSpec { duration_s: -1.0, ..Default::default() }).is_err());
        assert!(Trace::generate(&TraceSpec { agent_frac: 1.5, ..Default::default() }).is_err());
        assert!(Trace::generate(&TraceSpec { max_new: 0, ..Default::default() }).is_err());
        assert!(Trace::generate(&TraceSpec { chat_len: (9, 3), ..Default::default() }).is_err());
    }

    #[test]
    fn tenant_mix_tracks_agent_frac() {
        let spec = TraceSpec {
            rate: 500.0,
            duration_s: 1.0,
            agent_frac: 0.8,
            ..Default::default()
        };
        let trace = Trace::generate(&spec).unwrap();
        let agents = trace.events.iter().filter(|e| e.tenant == Tenant::Agent).count();
        let frac = agents as f64 / trace.events.len() as f64;
        assert!((frac - 0.8).abs() < 0.1, "agent fraction {frac} far from 0.8");
    }
}
