//! Open-loop trace replay against a live server over loopback TCP.
//!
//! One thread per scheduled event: each sleeps until its `at_s`, sends
//! its request on a fresh connection, and blocks for exactly one reply
//! line — so send times never depend on completions (closed-loop-free
//! by construction) and every event yields **exactly one**
//! [`Outcome`]: completed, shed (the server's in-band
//! `{"error":"overloaded","retry_after_ms":...}` reply), or a client
//! error. Overload tests reconcile these against the server's
//! `stats.server.shed` counters.

use super::trace::{Tenant, Trace};
use crate::json::Json;
use crate::server;
use anyhow::{bail, Result};
use std::time::{Duration, Instant};

/// What one replayed request came back with.
#[derive(Clone, Debug)]
pub enum Outcome {
    /// A completion reply (server-side timing fields, seconds).
    Done {
        ttft_s: f64,
        tpot_s: f64,
        latency_s: f64,
        queue_s: f64,
        model: String,
        /// Client-observed send → reply wall time (includes the wire).
        client_s: f64,
    },
    /// Admission backpressure: the server refused the request in-band.
    Shed { retry_after_ms: f64 },
    /// Transport failure or a non-overload error reply.
    Error { msg: String },
}

/// One trace event's replay record.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    /// Index of the event in the trace (outcomes are returned in trace
    /// order regardless of completion order).
    pub index: usize,
    pub tenant: Tenant,
    /// Scheduled send time, seconds from replay start.
    pub at_s: f64,
    pub outcome: Outcome,
}

/// A full replay: per-event outcomes plus the run's wall time.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub outcomes: Vec<RunOutcome>,
    /// Replay start → last reply, seconds (the goodput denominator).
    pub wall_s: f64,
}

impl RunResult {
    pub fn completed(&self) -> usize {
        self.count(|o| matches!(o, Outcome::Done { .. }))
    }

    pub fn shed(&self) -> usize {
        self.count(|o| matches!(o, Outcome::Shed { .. }))
    }

    pub fn errors(&self) -> usize {
        self.count(|o| matches!(o, Outcome::Error { .. }))
    }

    fn count(&self, f: impl Fn(&Outcome) -> bool) -> usize {
        self.outcomes.iter().filter(|o| f(&o.outcome)).count()
    }
}

/// Send one request line and classify the single reply line. A refused
/// connection is retried briefly (a near-simultaneous burst can
/// overflow the listener backlog); every other failure is an `Error`
/// outcome — never a panic, so one bad socket cannot sink a replay.
fn send_one(addr: &str, prompt: &str, max_new: usize) -> Outcome {
    let sent = Instant::now();
    let mut reply = server::client_request(addr, prompt, max_new);
    for attempt in 0..2 {
        if reply.is_ok() {
            break;
        }
        std::thread::sleep(Duration::from_millis(5 << attempt));
        reply = server::client_request(addr, prompt, max_new);
    }
    let j = match reply {
        Ok(j) => j,
        Err(e) => return Outcome::Error { msg: format!("{e:#}") },
    };
    let client_s = sent.elapsed().as_secs_f64();
    if let Some(err) = j.get("error").and_then(Json::as_str) {
        if err == "overloaded" {
            return Outcome::Shed {
                retry_after_ms: j
                    .get("retry_after_ms")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0),
            };
        }
        return Outcome::Error { msg: err.to_string() };
    }
    let f = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0);
    Outcome::Done {
        ttft_s: f("ttft_s"),
        tpot_s: f("tpot_s"),
        latency_s: f("latency_s"),
        queue_s: f("queue_s"),
        model: j
            .get("model")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string(),
        client_s,
    }
}

/// Replay `trace` against the server at `addr`. Blocks until every
/// event has its one outcome; outcomes come back in trace order.
pub fn replay(trace: &Trace, addr: &str) -> Result<RunResult> {
    if trace.events.is_empty() {
        bail!("trace has no events to replay");
    }
    let start = Instant::now();
    let mut handles = Vec::with_capacity(trace.events.len());
    for (index, e) in trace.events.iter().enumerate() {
        let addr = addr.to_string();
        let prompt = e.prompt.clone();
        let (at_s, max_new, tenant) = (e.at_s, e.max_new, e.tenant);
        handles.push(std::thread::spawn(move || {
            let wait = at_s - start.elapsed().as_secs_f64();
            if wait > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(wait));
            }
            RunOutcome { index, tenant, at_s, outcome: send_one(&addr, &prompt, max_new) }
        }));
    }
    let mut outcomes = Vec::with_capacity(handles.len());
    for h in handles {
        match h.join() {
            Ok(o) => outcomes.push(o),
            Err(_) => bail!("replay sender thread panicked"),
        }
    }
    outcomes.sort_by_key(|o| o.index);
    Ok(RunResult { outcomes, wall_s: start.elapsed().as_secs_f64() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::TraceSpec;

    #[test]
    fn empty_trace_is_an_error() {
        let trace = Trace { spec: TraceSpec::default(), events: Vec::new() };
        assert!(replay(&trace, "127.0.0.1:1").is_err());
    }

    #[test]
    fn unreachable_server_yields_error_outcomes_not_panics() {
        let spec = TraceSpec { rate: 100.0, duration_s: 0.05, ..Default::default() };
        let trace = Trace::generate(&spec).unwrap();
        // Port 9 (discard) on loopback: nothing listens in the test env.
        let result = replay(&trace, "127.0.0.1:9").unwrap();
        assert_eq!(result.outcomes.len(), trace.events.len());
        assert_eq!(result.errors(), trace.events.len());
        assert_eq!(result.completed() + result.shed(), 0);
    }
}
