//! SLO/goodput reporting over replayed traces.
//!
//! One [`ReportRow`] per (trace × server-config) run: request counts
//! by outcome, p50/p95/p99 summaries of the server-reported TTFT /
//! TPOT / latency series, and **goodput** — completions that met the
//! [`SloSpec`] per wall second, the paper-relevant denomination under
//! which policy × cache × route choices actually rank. Rows carry a
//! free-form `tags` map (policy, cache, route, …) so several runs in
//! one JSONL file form a comparison table; `bench_serving` feeds such
//! rows into `BENCH_serving.json`, and [`render_html`] turns the same
//! rows into a small static page.
//!
//! Determinism contract: given identical outcomes, [`ReportRow::build`]
//! + [`to_jsonl`] / [`render_html`] are pure — the `BTreeMap`-backed
//! [`Json`] writer and fixed-precision HTML formatting make the bytes
//! reproducible (pinned by `integration_workload.rs`).

use super::driver::{Outcome, RunResult};
use crate::config::SloSpec;
use crate::json::Json;
use crate::metrics::{summarize, Summary};
use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// One run's aggregated report (see the module docs).
#[derive(Clone, Debug)]
pub struct ReportRow {
    /// Run label (`--label`; the comparison table's row key).
    pub label: String,
    /// Free-form comparison dimensions (policy / cache / route / …),
    /// serialized sorted by key.
    pub tags: BTreeMap<String, String>,
    pub slo: SloSpec,
    /// Scheduled requests in the trace.
    pub n: usize,
    pub completed: usize,
    pub shed: usize,
    pub errors: usize,
    /// Completions that met the SLO.
    pub slo_met: usize,
    /// Replay wall time, seconds.
    pub wall_s: f64,
    /// Completions per wall second.
    pub throughput_rps: f64,
    /// SLO-met completions per wall second — the headline number.
    pub goodput_rps: f64,
    /// Server-reported series over completions (None when none completed).
    pub ttft: Option<Summary>,
    pub tpot: Option<Summary>,
    pub latency: Option<Summary>,
}

impl ReportRow {
    /// Aggregate one replay into a row. Pure in its inputs: identical
    /// outcomes produce identical rows (and identical serialized bytes).
    pub fn build(
        label: &str,
        tags: &[(&str, String)],
        slo: SloSpec,
        result: &RunResult,
    ) -> ReportRow {
        let (mut ttft, mut tpot, mut latency) = (Vec::new(), Vec::new(), Vec::new());
        let mut slo_met = 0usize;
        for o in &result.outcomes {
            if let Outcome::Done { ttft_s, tpot_s, latency_s, .. } = o.outcome {
                ttft.push(ttft_s);
                tpot.push(tpot_s);
                latency.push(latency_s);
                if slo.met(ttft_s, tpot_s) {
                    slo_met += 1;
                }
            }
        }
        let wall = result.wall_s.max(1e-9);
        ReportRow {
            label: label.to_string(),
            tags: tags.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
            slo,
            n: result.outcomes.len(),
            completed: result.completed(),
            shed: result.shed(),
            errors: result.errors(),
            slo_met,
            wall_s: result.wall_s,
            throughput_rps: result.completed() as f64 / wall,
            goodput_rps: slo_met as f64 / wall,
            ttft: summarize(&ttft),
            tpot: summarize(&tpot),
            latency: summarize(&latency),
        }
    }

    /// One JSONL object (deterministic key order via the `Json` writer).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("label", Json::Str(self.label.clone()));
        let mut tags = Json::obj();
        for (k, v) in &self.tags {
            tags.set(k, Json::Str(v.clone()));
        }
        j.set("tags", tags);
        j.set("slo", Json::Str(self.slo.name()));
        j.set("n", Json::Num(self.n as f64));
        j.set("completed", Json::Num(self.completed as f64));
        j.set("shed", Json::Num(self.shed as f64));
        j.set("errors", Json::Num(self.errors as f64));
        j.set("slo_met", Json::Num(self.slo_met as f64));
        j.set("wall_s", Json::Num(self.wall_s));
        j.set("throughput_rps", Json::Num(self.throughput_rps));
        j.set("goodput_rps", Json::Num(self.goodput_rps));
        for (name, s) in
            [("ttft_s", &self.ttft), ("tpot_s", &self.tpot), ("latency_s", &self.latency)]
        {
            if let Some(s) = s {
                let mut sj = Json::obj();
                sj.set("n", Json::Num(s.n as f64));
                sj.set("mean", Json::Num(s.mean));
                sj.set("p50", Json::Num(s.p50));
                sj.set("p95", Json::Num(s.p95));
                sj.set("p99", Json::Num(s.p99));
                sj.set("max", Json::Num(s.max));
                j.set(name, sj);
            }
        }
        j
    }

    /// Parse one [`ReportRow::to_json`] line back (summaries are
    /// re-read only as far as the comparison tooling needs).
    pub fn parse(line: &str) -> Result<Json> {
        let j = Json::parse(line)?;
        for k in ["label", "n", "completed", "shed", "goodput_rps"] {
            j.get(k).with_context(|| format!("report row missing `{k}`"))?;
        }
        Ok(j)
    }

    /// Human one-liner for CLI output.
    pub fn human(&self) -> String {
        let pct = |s: &Option<Summary>| match s {
            Some(s) => format!(
                "p50 {:.1}ms p95 {:.1}ms p99 {:.1}ms",
                s.p50 * 1e3,
                s.p95 * 1e3,
                s.p99 * 1e3
            ),
            None => "none".to_string(),
        };
        format!(
            "{}: {} req in {:.2}s — completed {} ({:.1}/s), shed {}, errors {}\n\
             goodput {:.1}/s (SLO {} met by {}/{})\n\
             ttft {}; tpot {}",
            self.label,
            self.n,
            self.wall_s,
            self.completed,
            self.throughput_rps,
            self.shed,
            self.errors,
            self.goodput_rps,
            self.slo.name(),
            self.slo_met,
            self.completed,
            pct(&self.ttft),
            pct(&self.tpot),
        )
    }
}

/// Serialize rows as JSONL, one comparison row per line.
pub fn to_jsonl(rows: &[ReportRow]) -> String {
    let mut out = String::new();
    for r in rows {
        out.push_str(&r.to_json().to_string());
        out.push('\n');
    }
    out
}

fn html_escape(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

/// A small static HTML comparison page over the same rows the JSONL
/// carries (fixed-precision formatting keeps the bytes deterministic).
pub fn render_html(title: &str, rows: &[ReportRow]) -> String {
    let mut tag_keys: Vec<String> = Vec::new();
    for r in rows {
        for k in r.tags.keys() {
            if !tag_keys.contains(k) {
                tag_keys.push(k.clone());
            }
        }
    }
    tag_keys.sort();
    let ms = |s: &Option<Summary>, f: fn(&Summary) -> f64| match s {
        Some(s) => format!("{:.2}", f(s) * 1e3),
        None => "–".to_string(),
    };
    let mut h = String::new();
    h.push_str("<!doctype html>\n<html><head><meta charset=\"utf-8\">\n");
    h.push_str(&format!("<title>{}</title>\n", html_escape(title)));
    h.push_str(
        "<style>body{font:14px sans-serif;margin:2em}table{border-collapse:collapse}\n\
         th,td{border:1px solid #999;padding:4px 8px;text-align:right}\n\
         th{background:#eee}td.l,th.l{text-align:left}</style></head><body>\n",
    );
    h.push_str(&format!("<h1>{}</h1>\n<table>\n<tr>", html_escape(title)));
    h.push_str("<th class=\"l\">label</th>");
    for k in &tag_keys {
        h.push_str(&format!("<th class=\"l\">{}</th>", html_escape(k)));
    }
    h.push_str(
        "<th>n</th><th>completed</th><th>shed</th><th>errors</th>\
         <th>goodput/s</th><th>throughput/s</th>\
         <th>ttft p50 (ms)</th><th>ttft p95</th><th>ttft p99</th>\
         <th>tpot p50 (ms)</th><th>tpot p95</th><th>tpot p99</th><th>SLO</th></tr>\n",
    );
    for r in rows {
        h.push_str(&format!("<tr><td class=\"l\">{}</td>", html_escape(&r.label)));
        for k in &tag_keys {
            let v = r.tags.get(k).map(String::as_str).unwrap_or("–");
            h.push_str(&format!("<td class=\"l\">{}</td>", html_escape(v)));
        }
        h.push_str(&format!(
            "<td>{}</td><td>{}</td><td>{}</td><td>{}</td>\
             <td>{:.2}</td><td>{:.2}</td>\
             <td>{}</td><td>{}</td><td>{}</td>\
             <td>{}</td><td>{}</td><td>{}</td><td class=\"l\">{}</td></tr>\n",
            r.n,
            r.completed,
            r.shed,
            r.errors,
            r.goodput_rps,
            r.throughput_rps,
            ms(&r.ttft, |s| s.p50),
            ms(&r.ttft, |s| s.p95),
            ms(&r.ttft, |s| s.p99),
            ms(&r.tpot, |s| s.p50),
            ms(&r.tpot, |s| s.p95),
            ms(&r.tpot, |s| s.p99),
            html_escape(&r.slo.name()),
        ));
    }
    h.push_str("</table></body></html>\n");
    h
}

/// Aggregate several `BENCH_serving.json`-shaped documents — `(label,
/// parsed JSON)` pairs, e.g. one per commit or per run — into one
/// trend table: rows are result names in first-seen order, one column
/// per run. Timing results (`mean_s`) render as mean milliseconds,
/// metric results as `value unit`, absent cells as dashes. Fixed
/// precision keeps the bytes deterministic, like [`render_html`].
pub fn render_bench_trend_html(title: &str, runs: &[(String, Json)]) -> String {
    fn results(j: &Json) -> &[Json] {
        j.get("results").and_then(Json::as_arr).unwrap_or(&[])
    }
    let mut names: Vec<&str> = Vec::new();
    for (_, j) in runs {
        for r in results(j) {
            if let Some(n) = r.get("name").and_then(Json::as_str) {
                if !names.contains(&n) {
                    names.push(n);
                }
            }
        }
    }
    let cell = |j: &Json, name: &str| -> String {
        let Some(r) = results(j)
            .iter()
            .find(|r| r.get("name").and_then(Json::as_str) == Some(name))
        else {
            return "–".to_string();
        };
        if let Some(mean) = r.get("mean_s").and_then(Json::as_f64) {
            return format!("{:.3} ms", mean * 1e3);
        }
        if let Some(v) = r.get("value").and_then(Json::as_f64) {
            let unit = r.get("unit").and_then(Json::as_str).unwrap_or("");
            return if unit.is_empty() {
                format!("{v:.2}")
            } else {
                format!("{v:.2} {}", html_escape(unit))
            };
        }
        "?".to_string()
    };
    let mut h = String::new();
    h.push_str("<!doctype html>\n<html><head><meta charset=\"utf-8\">\n");
    h.push_str(&format!("<title>{}</title>\n", html_escape(title)));
    h.push_str(
        "<style>body{font:14px sans-serif;margin:2em}table{border-collapse:collapse}\n\
         th,td{border:1px solid #999;padding:4px 8px;text-align:right}\n\
         th{background:#eee}td.l,th.l{text-align:left}</style></head><body>\n",
    );
    h.push_str(&format!("<h1>{}</h1>\n<table>\n<tr>", html_escape(title)));
    h.push_str("<th class=\"l\">result</th>");
    for (label, _) in runs {
        h.push_str(&format!("<th>{}</th>", html_escape(label)));
    }
    h.push_str("</tr>\n");
    for name in &names {
        h.push_str(&format!("<tr><td class=\"l\">{}</td>", html_escape(name)));
        for (_, j) in runs {
            h.push_str(&format!("<td>{}</td>", cell(j, name)));
        }
        h.push_str("</tr>\n");
    }
    h.push_str("</table></body></html>\n");
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::driver::RunOutcome;
    use crate::workload::trace::Tenant;

    /// A deterministic synthetic replay: index-derived timings, every
    /// third request shed.
    fn synthetic_result(n: usize) -> RunResult {
        let outcomes = (0..n)
            .map(|i| RunOutcome {
                index: i,
                tenant: if i % 2 == 0 { Tenant::Agent } else { Tenant::Chat },
                at_s: i as f64 * 0.01,
                outcome: if i % 3 == 2 {
                    Outcome::Shed { retry_after_ms: 2.0 }
                } else {
                    Outcome::Done {
                        ttft_s: 0.010 + i as f64 * 0.005,
                        tpot_s: 0.002,
                        latency_s: 0.050 + i as f64 * 0.005,
                        queue_s: 0.001,
                        model: "default".to_string(),
                        client_s: 0.055,
                    }
                },
            })
            .collect();
        RunResult { outcomes, wall_s: 1.5 }
    }

    #[test]
    fn counts_and_goodput_add_up() {
        let slo = SloSpec { ttft_ms: Some(30.0), tpot_ms: None };
        let row = ReportRow::build("t", &[("policy", "admit-first".into())], slo, &synthetic_result(9));
        assert_eq!(row.n, 9);
        assert_eq!(row.shed, 3);
        assert_eq!(row.completed, 6);
        assert_eq!(row.errors, 0);
        // ttft = 10ms + 5ms*i for i in {0,1,3,4,6,7}: <=30ms holds for
        // i in {0,1,3,4} -> 4 of 6 completions meet the SLO.
        assert_eq!(row.slo_met, 4);
        assert!((row.goodput_rps - 4.0 / 1.5).abs() < 1e-9);
        assert!((row.throughput_rps - 6.0 / 1.5).abs() < 1e-9);
        assert!(row.goodput_rps <= row.throughput_rps);
        let t = row.ttft.unwrap();
        assert_eq!(t.n, 6);
        assert!(t.p50 <= t.p95 && t.p95 <= t.p99 && t.p99 <= t.max);
    }

    #[test]
    fn jsonl_and_html_are_deterministic_and_parse() {
        let slo = SloSpec { ttft_ms: Some(100.0), tpot_ms: Some(50.0) };
        let result = synthetic_result(12);
        let tags: &[(&str, String)] =
            &[("policy", "chunked:8".into()), ("cache", "paged".into()), ("route", "least-loaded".into())];
        let a = ReportRow::build("cmp", tags, slo, &result);
        let b = ReportRow::build("cmp", tags, slo, &result);
        assert_eq!(to_jsonl(&[a.clone()]), to_jsonl(&[b.clone()]), "JSONL must be byte-stable");
        assert_eq!(
            render_html("t", &[a.clone()]),
            render_html("t", &[b]),
            "HTML must be byte-stable"
        );
        let text = to_jsonl(&[a]);
        let parsed = ReportRow::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(parsed.get("label").and_then(Json::as_str), Some("cmp"));
        assert_eq!(
            parsed.get("tags").and_then(|t| t.get("cache")).and_then(Json::as_str),
            Some("paged")
        );
        assert!(parsed.get("goodput_rps").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(ReportRow::parse("{\"label\":\"x\"}").is_err(), "missing fields rejected");
    }

    #[test]
    fn bench_trend_aggregates_multiple_runs() {
        let run = |tp: f64, with_extra: bool| {
            let mut extra = String::new();
            if with_extra {
                extra = ",{\"name\":\"decode/p50\",\"mean_s\":0.004,\"p50_s\":0.004,\
                         \"min_s\":0.003,\"n\":5}"
                    .to_string();
            }
            Json::parse(&format!(
                "{{\"bench\":\"serving\",\"quick\":true,\"results\":[\
                 {{\"name\":\"goodput\",\"value\":{tp},\"unit\":\"req/s\"}}{extra}]}}"
            ))
            .unwrap()
        };
        let runs = vec![
            ("commit-a".to_string(), run(10.0, false)),
            ("commit-b".to_string(), run(12.5, true)),
        ];
        let a = render_bench_trend_html("trend", &runs);
        let b = render_bench_trend_html("trend", &runs);
        assert_eq!(a, b, "trend HTML must be byte-stable");
        assert!(a.contains("<th>commit-a</th>"));
        assert!(a.contains("<th>commit-b</th>"));
        assert!(a.contains("10.00 req/s"));
        assert!(a.contains("12.50 req/s"));
        assert!(a.contains("4.000 ms"), "timing rows render as mean ms");
        assert!(a.contains("<td>–</td>"), "absent cells render as dashes");
        // Row order is first-seen across runs.
        let goodput_at = a.find("goodput").unwrap();
        let decode_at = a.find("decode/p50").unwrap();
        assert!(goodput_at < decode_at);
    }

    #[test]
    fn empty_run_reports_zero_goodput_without_summaries() {
        let result = RunResult {
            outcomes: vec![RunOutcome {
                index: 0,
                tenant: Tenant::Chat,
                at_s: 0.0,
                outcome: Outcome::Error { msg: "refused".into() },
            }],
            wall_s: 0.5,
        };
        let row = ReportRow::build("err", &[], SloSpec::default(), &result);
        assert_eq!((row.completed, row.shed, row.errors), (0, 0, 1));
        assert_eq!(row.goodput_rps, 0.0);
        assert!(row.ttft.is_none());
        let html = render_html("t", &[row]);
        assert!(html.contains("–"), "missing summaries render as dashes");
    }
}
