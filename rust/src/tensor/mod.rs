//! Dense row-major f32 tensors — the substrate for the Rust-side
//! conversion toolchain (weights are at most `[L, 256, 768]` here, so a
//! straightforward cache-blocked matmul is plenty).

use crate::util::Rng;
use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn ones(shape: &[usize]) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![1.0; shape.iter().product()],
        }
    }

    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: (0..n).map(|_| rng.normal_f32(std)).collect(),
        }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Number of rows / columns for a 2-D tensor.
    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        self.shape[1]
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.shape[1] + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.shape[1] + j] = v;
    }

    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("reshape {:?} -> {:?} mismatch", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// View row i of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.shape[1];
        &self.data[i * c..(i + 1) * c]
    }

    /// 2-D matrix product: [m,k] x [k,n] -> [m,n], cache-blocked (ikj).
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        if self.rank() != 2 || other.rank() != 2 || self.cols() != other.rows() {
            bail!("matmul shapes {:?} x {:?}", self.shape, other.shape);
        }
        let (m, k, n) = (self.rows(), self.cols(), other.cols());
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[kk * n..(kk + 1) * n];
                for (o, &b) in orow.iter_mut().zip(brow.iter()) {
                    *o += a * b;
                }
            }
        }
        Tensor::new(&[m, n], out)
    }

    /// Transpose of a 2-D tensor.
    pub fn t(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.rows(), self.cols());
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor { shape: vec![n, m], data: out }
    }

    /// Select columns (2-D) by index list.
    pub fn select_cols(&self, idx: &[usize]) -> Tensor {
        assert_eq!(self.rank(), 2);
        let m = self.rows();
        let mut out = vec![0.0f32; m * idx.len()];
        for i in 0..m {
            let row = self.row(i);
            for (jj, &j) in idx.iter().enumerate() {
                out[i * idx.len() + jj] = row[j];
            }
        }
        Tensor { shape: vec![m, idx.len()], data: out }
    }

    /// Horizontal concat of 2-D tensors with equal row counts.
    pub fn hcat(parts: &[&Tensor]) -> Result<Tensor> {
        let m = parts[0].rows();
        let n: usize = parts.iter().map(|p| p.cols()).sum();
        for p in parts {
            if p.rank() != 2 || p.rows() != m {
                bail!("hcat shape mismatch");
            }
        }
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let mut off = 0;
            for p in parts {
                let c = p.cols();
                out[i * n + off..i * n + off + c].copy_from_slice(p.row(i));
                off += c;
            }
        }
        Tensor::new(&[m, n], out)
    }

    /// Slice columns [lo, hi) of a 2-D tensor.
    pub fn slice_cols(&self, lo: usize, hi: usize) -> Tensor {
        self.select_cols(&(lo..hi).collect::<Vec<_>>())
    }

    /// Slice along axis 0 (any rank): returns sub-tensor [i] with rank-1.
    pub fn index0(&self, i: usize) -> Tensor {
        let inner: usize = self.shape[1..].iter().product();
        Tensor {
            shape: self.shape[1..].to_vec(),
            data: self.data[i * inner..(i + 1) * inner].to_vec(),
        }
    }

    /// Stack equal-shaped tensors along a new leading axis.
    pub fn stack(parts: &[Tensor]) -> Result<Tensor> {
        let inner = &parts[0].shape;
        let mut data = Vec::with_capacity(parts.len() * parts[0].len());
        for p in parts {
            if &p.shape != inner {
                bail!("stack shape mismatch {:?} vs {:?}", p.shape, inner);
            }
            data.extend_from_slice(&p.data);
        }
        let mut shape = vec![parts.len()];
        shape.extend_from_slice(inner);
        Tensor::new(&shape, data)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape != other.shape {
            bail!("add shape mismatch");
        }
        Ok(Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a + b)
                .collect(),
        })
    }

    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape != other.shape {
            bail!("sub shape mismatch");
        }
        Ok(Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a - b)
                .collect(),
        })
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Mean L2 norm of rows (2-D).
    pub fn mean_row_norm(&self) -> f32 {
        let m = self.rows();
        let mut s = 0.0f64;
        for i in 0..m {
            s += self
                .row(i)
                .iter()
                .map(|&x| (x as f64) * (x as f64))
                .sum::<f64>()
                .sqrt();
        }
        (s / m as f64) as f32
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Identity matrix.
pub fn eye(n: usize) -> Tensor {
    let mut t = Tensor::zeros(&[n, n]);
    for i in 0..n {
        t.set2(i, i, 1.0);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::new(&[2, 2], vec![5.0, 6.0, 7.0, 8.0]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(0);
        let a = Tensor::randn(&[5, 7], 1.0, &mut rng);
        let c = a.matmul(&eye(7)).unwrap();
        assert!(a.max_abs_diff(&c) < 1e-6);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[4, 9], 1.0, &mut rng);
        assert!(a.t().t().max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn hcat_slice_roundtrip() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[3, 4], 1.0, &mut rng);
        let b = Tensor::randn(&[3, 2], 1.0, &mut rng);
        let c = Tensor::hcat(&[&a, &b]).unwrap();
        assert!(c.slice_cols(0, 4).max_abs_diff(&a) < 1e-9);
        assert!(c.slice_cols(4, 6).max_abs_diff(&b) < 1e-9);
    }

    #[test]
    fn stack_index_roundtrip() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[2, 3], 1.0, &mut rng);
        let b = Tensor::randn(&[2, 3], 1.0, &mut rng);
        let s = Tensor::stack(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(s.shape, vec![2, 2, 3]);
        assert!(s.index0(1).max_abs_diff(&b) < 1e-9);
    }

    #[test]
    fn shape_validation() {
        assert!(Tensor::new(&[2, 2], vec![0.0; 3]).is_err());
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        assert!(a.matmul(&b).is_err());
    }

    #[test]
    fn mean_row_norm_constant() {
        let t = Tensor::ones(&[4, 9]);
        assert!((t.mean_row_norm() - 3.0).abs() < 1e-6);
    }
}
