//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json`) and executes them on the CPU PJRT client.
//!
//! Interchange is HLO *text* — the image's xla_extension 0.5.1 rejects
//! jax>=0.5 serialized protos (64-bit instruction ids); the text parser
//! reassigns ids cleanly (see /opt/xla-example/README.md).
//!
//! Weights are uploaded once and stay device-resident (`execute_b`);
//! per-step tensors (tokens, positions, KV caches) cross the host/device
//! boundary each step because XLA 0.1.6 returns tuple outputs as a single
//! tuple buffer that cannot be re-fed. This makes the decode step's cost
//! scale with KV-cache bytes — the exact quantity TransMLA compresses —
//! so measured CPU speedups are structurally faithful to the paper's
//! memory-bound decode regime (DESIGN.md §Hardware-Adaptation).

use crate::config::ModelConfig;
use crate::json::Json;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

// NOTE: `xla::PjRtClient` is Rc-backed (not Send); the Runtime is
// single-threaded by construction — the server runs the engine on a
// dedicated thread and talks to it over channels.

/// Dtype of an artifact argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl ArgSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let dt = match j.get("dtype").and_then(Json::as_str) {
            Some("float32") => Dtype::F32,
            Some("int32") => Dtype::I32,
            other => bail!("unsupported dtype {:?}", other),
        };
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .context("shape")?
            .iter()
            .map(|d| d.as_usize().context("dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(ArgSpec { dtype: dt, shape })
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One exported HLO entry point, as described by the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub arch: String,
    pub rank: Option<usize>,
    pub batch: Option<usize>,
    pub seq: usize,
    pub params: Vec<String>,
    pub inputs: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
    pub config: ModelConfig,
}

/// Parsed `artifacts/manifest.json`.
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: HashMap<String, ArtifactSpec>,
    pub configs: HashMap<String, ModelConfig>,
    pub table1_ranks: HashMap<String, Vec<usize>>,
    pub sweep_ranks: HashMap<String, Vec<usize>>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| {
                format!("read {}/manifest.json — run `make artifacts`",
                        dir.display())
            })?;
        let j = Json::parse(&text)?;
        let mut entries = HashMap::new();
        for e in j.get("entries").and_then(Json::as_arr).context("entries")? {
            let cfg = ModelConfig::from_json(e.get("config").context("config")?)?;
            let spec = ArtifactSpec {
                name: e.get("name").and_then(Json::as_str).context("name")?.into(),
                file: e.get("file").and_then(Json::as_str).context("file")?.into(),
                kind: e.get("kind").and_then(Json::as_str).context("kind")?.into(),
                arch: e.get("arch").and_then(Json::as_str).context("arch")?.into(),
                rank: e.get("rank").and_then(Json::as_usize),
                batch: e.get("batch").and_then(Json::as_usize),
                seq: e.get("seq").and_then(Json::as_usize).context("seq")?,
                params: e
                    .get("params")
                    .and_then(Json::as_arr)
                    .context("params")?
                    .iter()
                    .map(|p| p.as_str().unwrap_or("").to_string())
                    .collect(),
                inputs: e
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .context("inputs")?
                    .iter()
                    .map(ArgSpec::from_json)
                    .collect::<Result<Vec<_>>>()?,
                outputs: e
                    .get("outputs")
                    .and_then(Json::as_arr)
                    .context("outputs")?
                    .iter()
                    .map(ArgSpec::from_json)
                    .collect::<Result<Vec<_>>>()?,
                config: cfg,
            };
            entries.insert(spec.name.clone(), spec);
        }
        let mut configs = HashMap::new();
        if let Some(cs) = j.get("configs").and_then(Json::as_obj) {
            for (k, v) in cs {
                configs.insert(k.clone(), ModelConfig::from_json(v)?);
            }
        }
        let parse_ranks = |key: &str| -> HashMap<String, Vec<usize>> {
            let mut out = HashMap::new();
            if let Some(m) = j.get(key).and_then(Json::as_obj) {
                for (k, v) in m {
                    if let Some(arr) = v.as_arr() {
                        out.insert(
                            k.clone(),
                            arr.iter().filter_map(Json::as_usize).collect(),
                        );
                    }
                }
            }
            out
        };
        Ok(Manifest {
            dir: dir.to_path_buf(),
            entries,
            configs,
            table1_ranks: parse_ranks("table1_ranks"),
            sweep_ranks: parse_ranks("sweep_ranks"),
        })
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("artifact `{name}` not in manifest"))
    }
}

/// Host value crossing into an executable.
#[derive(Clone, Debug)]
pub enum Value {
    F32(Tensor),
    I32(Vec<i32>, Vec<usize>), // data, shape
}

impl Value {
    pub fn scalar_f32(v: f32) -> Value {
        Value::F32(Tensor::scalar(v))
    }

    pub fn i32_vec(v: Vec<i32>) -> Value {
        let n = v.len();
        Value::I32(v, vec![n])
    }

    pub fn i32_mat(v: Vec<i32>, shape: &[usize]) -> Value {
        Value::I32(v, shape.to_vec())
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Value::F32(t) => {
                if t.shape.is_empty() {
                    xla::Literal::scalar(t.data[0])
                } else {
                    let dims: Vec<i64> =
                        t.shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(&t.data).reshape(&dims)?
                }
            }
            Value::I32(v, shape) => {
                if shape.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    let dims: Vec<i64> =
                        shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(v).reshape(&dims)?
                }
            }
        })
    }
}

/// Copy an output literal into an existing host tensor in place
/// (shape- and dtype-checked). `Literal::to_vec` still materialises a
/// staging buffer on the bindings side, so this trades one extra memcpy
/// for keeping the destination allocation stable — the win is standing
/// multi-MB cache buffers that never churn through the allocator, not
/// fewer copies. (Bindings with a direct copy-into would remove the
/// staging buffer here with no caller change.)
pub fn copy_literal_into(lit: &xla::Literal, dst: &mut Tensor) -> Result<()> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    if dims != dst.shape {
        bail!("in-place output shape {:?} vs buffer {:?}", dims, dst.shape);
    }
    match lit.ty()? {
        xla::ElementType::F32 => {
            let data = lit.to_vec::<f32>()?;
            dst.data.copy_from_slice(&data);
        }
        other => bail!("in-place reuse expects f32 output, got {:?}", other),
    }
    Ok(())
}

/// Convert an output literal into a host f32 tensor.
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = match lit.ty()? {
        xla::ElementType::F32 => lit.to_vec::<f32>()?,
        xla::ElementType::S32 => lit
            .to_vec::<i32>()?
            .into_iter()
            .map(|x| x as f32)
            .collect(),
        other => bail!("unsupported output type {:?}", other),
    };
    Tensor::new(&dims, data)
}

/// A compiled artifact ready to execute.
pub struct Exec {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
}

impl Exec {
    /// Execute with host values; returns host tensors (tuple flattened).
    pub fn run(&self, args: &[Value]) -> Result<Vec<Tensor>> {
        self.check_arity(args.len())?;
        let lits: Vec<xla::Literal> = args
            .iter()
            .map(|v| v.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let bufs = self.exe.execute::<xla::Literal>(&lits)?;
        untuple(&bufs[0][0])
    }

    /// Execute with device-resident leading args (weights) followed by
    /// fresh host values — the decode hot path.
    ///
    /// SAFETY NOTE: `buffer_from_host_literal` transfers asynchronously;
    /// the source literals MUST outlive the execution (xla_extension
    /// CHECK-fails — or worse — if a literal is freed mid-copy). We
    /// therefore keep them alive until the outputs have materialised.
    pub fn run_b(
        &self,
        device_args: &[xla::PjRtBuffer],
        host_args: &[Value],
    ) -> Result<Vec<Tensor>> {
        self.check_arity(device_args.len() + host_args.len())?;
        let lits: Vec<xla::Literal> = host_args
            .iter()
            .map(|v| v.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let uploaded: Vec<xla::PjRtBuffer> = lits
            .iter()
            .map(|l| Ok(self.client.buffer_from_host_literal(None, l)?))
            .collect::<Result<Vec<_>>>()?;
        let mut bufs: Vec<&xla::PjRtBuffer> = device_args.iter().collect();
        bufs.extend(uploaded.iter());
        let out = self.exe.execute_b::<&xla::PjRtBuffer>(&bufs)?;
        let tensors = untuple(&out[0][0])?;
        drop(lits); // keep the host literals alive past the execution
        Ok(tensors)
    }

    /// Like `run_b`, but the trailing f32 tensors are borrowed rather than
    /// wrapped in `Value` — avoids cloning multi-MB KV caches on the
    /// decode hot path (§Perf in EXPERIMENTS.md).
    pub fn run_b_mixed(
        &self,
        device_args: &[xla::PjRtBuffer],
        host_args: &[Value],
        tensor_args: &[&Tensor],
    ) -> Result<Vec<Tensor>> {
        self.check_arity(device_args.len() + host_args.len() + tensor_args.len())?;
        let mut lits: Vec<xla::Literal> = host_args
            .iter()
            .map(|v| v.to_literal())
            .collect::<Result<Vec<_>>>()?;
        for t in tensor_args {
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            lits.push(xla::Literal::vec1(&t.data).reshape(&dims)?);
        }
        let uploaded: Vec<xla::PjRtBuffer> = lits
            .iter()
            .map(|l| Ok(self.client.buffer_from_host_literal(None, l)?))
            .collect::<Result<Vec<_>>>()?;
        let mut bufs: Vec<&xla::PjRtBuffer> = device_args.iter().collect();
        bufs.extend(uploaded.iter());
        let out = self.exe.execute_b::<&xla::PjRtBuffer>(&bufs)?;
        let tensors = untuple(&out[0][0])?;
        drop(lits);
        Ok(tensors)
    }

    /// Like `run_b_mixed`, but the trailing tensors are **in/out**: they
    /// are uploaded as the executable's trailing inputs, and after
    /// execution the same number of trailing tuple outputs is written
    /// back into them in place. Leading outputs (logits) are returned as
    /// fresh tensors. The caller's buffers (the engine's KV cache) stay
    /// the same allocations across every decode step — no realloc churn
    /// and no full-buffer swap through the cache — at the cost of one
    /// staging memcpy per output until the bindings grow a direct
    /// copy-into (see `copy_literal_into`). It is also the write path a
    /// paged decode artifact would need (outputs landing in
    /// caller-managed memory).
    pub fn run_b_mixed_io(
        &self,
        device_args: &[xla::PjRtBuffer],
        host_args: &[Value],
        io_tensors: &mut [&mut Tensor],
    ) -> Result<Vec<Tensor>> {
        self.check_arity(device_args.len() + host_args.len() + io_tensors.len())?;
        let mut lits: Vec<xla::Literal> = host_args
            .iter()
            .map(|v| v.to_literal())
            .collect::<Result<Vec<_>>>()?;
        for t in io_tensors.iter() {
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            lits.push(xla::Literal::vec1(&t.data).reshape(&dims)?);
        }
        let uploaded: Vec<xla::PjRtBuffer> = lits
            .iter()
            .map(|l| Ok(self.client.buffer_from_host_literal(None, l)?))
            .collect::<Result<Vec<_>>>()?;
        let mut bufs: Vec<&xla::PjRtBuffer> = device_args.iter().collect();
        bufs.extend(uploaded.iter());
        let out = self.exe.execute_b::<&xla::PjRtBuffer>(&bufs)?;
        let tuple = out[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() < io_tensors.len() {
            bail!(
                "artifact `{}` returned {} outputs, expected >= {} in-place",
                self.spec.name,
                parts.len(),
                io_tensors.len()
            );
        }
        let n_lead = parts.len() - io_tensors.len();
        let lead: Vec<Tensor> = parts[..n_lead]
            .iter()
            .map(literal_to_tensor)
            .collect::<Result<Vec<_>>>()?;
        for (part, dst) in parts[n_lead..].iter().zip(io_tensors.iter_mut()) {
            copy_literal_into(part, &mut **dst)?;
        }
        drop(lits); // keep the host literals alive past the execution
        Ok(lead)
    }

    /// Upload a host value, returning the device buffer AND the backing
    /// literal — the caller must keep the literal alive as long as the
    /// buffer may still be read (async transfer, see `run_b`).
    pub fn upload_owned(&self, v: &Value) -> Result<(xla::PjRtBuffer, xla::Literal)> {
        let lit = v.to_literal()?;
        let buf = self.client.buffer_from_host_literal(None, &lit)?;
        Ok((buf, lit))
    }

    fn check_arity(&self, got: usize) -> Result<()> {
        if got != self.spec.inputs.len() {
            bail!(
                "artifact `{}` wants {} args, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                got
            );
        }
        Ok(())
    }
}

fn untuple(buf: &xla::PjRtBuffer) -> Result<Vec<Tensor>> {
    let lit = buf.to_literal_sync()?;
    let parts = lit.to_tuple()?;
    parts.iter().map(literal_to_tensor).collect()
}

/// The PJRT runtime: one CPU client + a compiled-executable cache.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: std::sync::Mutex<HashMap<String, Arc<Exec>>>,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            manifest,
            client,
            cache: std::sync::Mutex::new(HashMap::new()),
        })
    }

    /// Load + compile an artifact (cached across callers).
    pub fn load(&self, name: &str) -> Result<Arc<Exec>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.spec(name)?.clone();
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("path utf8")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let exec = Arc::new(Exec { spec, exe, client: self.client.clone() });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    /// Upload a host value; returns (buffer, literal) — keep the literal
    /// alive while the buffer may still be in flight (async transfer).
    pub fn upload_owned(&self, v: &Value) -> Result<(xla::PjRtBuffer, xla::Literal)> {
        let lit = v.to_literal()?;
        let buf = self.client.buffer_from_host_literal(None, &lit)?;
        Ok((buf, lit))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
