//! Minimal JSON parser/serializer (offline stand-in for `serde_json`).
//!
//! Supports the full JSON grammar minus exotic number forms; used for the
//! artifact manifest, run configs, experiment outputs, and the TCP server's
//! line protocol.

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing JSON at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn set(&mut self, key: &str, v: Json) -> &mut Json {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), v);
        }
        self
    }

    pub fn from_usizes(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&" ".repeat(indent + 2));
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&" ".repeat(indent + 2));
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push('}');
            }
            _ => self.write(out),
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other, self.i),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => bail!("bad escape {:?}", other),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => bail!("expected , or ] got {:?}", other),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => bail!("expected , or }} got {:?}", other),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true}, "s": "x\ny"}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn parses_real_manifest_snippet() {
        let src = r#"{"entries": [{"name": "x", "inputs": [{"dtype": "int32", "shape": [8, 512]}]}]}"#;
        let v = Json::parse(src).unwrap();
        let shape = v.get("entries").unwrap().idx(0).unwrap()
            .get("inputs").unwrap().idx(0).unwrap()
            .get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape.len(), 2);
        assert_eq!(shape[1].as_usize(), Some(512));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""A\t\"""#).unwrap();
        assert_eq!(v.as_str(), Some("A\t\""));
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn pretty_is_reparseable() {
        let src = r#"{"a":[1,2],"b":{"c":[{"d":1}]}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }
}
