//! Training loop: drives the AOT train-step executable (fwd + bwd + Adam
//! inside one HLO module) from Rust. Used to pretrain the GQA byte-LM and
//! to fine-tune converted MLA models (the paper's recovery experiments).

use crate::corpus::Corpus;
use crate::model::Params;
use crate::runtime::{Exec, Value};
use crate::tensor::Tensor;
use crate::util::{Rng, Timer};
use anyhow::{bail, Context, Result};
use std::sync::Arc;

pub struct TrainReport {
    pub losses: Vec<f32>,
    pub steps: usize,
    pub tokens: usize,
    pub seconds: f64,
}

impl TrainReport {
    pub fn final_loss(&self) -> f32 {
        self.losses.last().copied().unwrap_or(f32::NAN)
    }

    /// Mean loss over the last k steps (smoother than the single final
    /// minibatch).
    pub fn tail_loss(&self, k: usize) -> f32 {
        let n = self.losses.len();
        if n == 0 {
            return f32::NAN;
        }
        let k = k.min(n);
        self.losses[n - k..].iter().sum::<f32>() / k as f32
    }
}

pub struct Trainer {
    exec: Arc<Exec>,
    pub params: Params,
    m: Params,
    v: Params,
    pub step: usize,
    batch: usize,
    seq: usize,
}

impl Trainer {
    pub fn new(exec: Arc<Exec>, params: Params) -> Result<Self> {
        if exec.spec.kind != "train" {
            bail!("`{}` is not a train artifact", exec.spec.name);
        }
        let n = exec.spec.params.len();
        // train artifact ABI: params*3 + step + lr + tokens
        if exec.spec.inputs.len() != 3 * n + 3 {
            bail!("unexpected train arity");
        }
        for (i, key) in exec.spec.params.iter().enumerate() {
            let have = &params.get(key)?.shape;
            let want = &exec.spec.inputs[i].shape;
            if have != want {
                bail!("param `{key}`: shape {have:?} != {want:?}");
            }
        }
        let m = params.zeros_like();
        let v = params.zeros_like();
        let batch = exec.spec.batch.context("train batch")?;
        let seq = exec.spec.seq;
        Ok(Trainer { exec, params, m, v, step: 0, batch, seq })
    }

    /// One optimizer step on a [batch, seq] token matrix; returns the loss.
    pub fn step_on(&mut self, tokens: Vec<i32>, lr: f32) -> Result<f32> {
        if tokens.len() != self.batch * self.seq {
            bail!("train batch wants {}x{}", self.batch, self.seq);
        }
        self.step += 1;
        let mut args: Vec<Value> = Vec::with_capacity(self.exec.spec.inputs.len());
        args.extend(self.params.values());
        args.extend(self.m.values());
        args.extend(self.v.values());
        args.push(Value::scalar_f32(self.step as f32));
        args.push(Value::scalar_f32(lr));
        args.push(Value::i32_mat(tokens, &[self.batch, self.seq]));
        let mut outs = self.exec.run(&args)?;
        let loss = outs
            .pop()
            .context("train loss output")?
            .data
            .first()
            .copied()
            .context("loss scalar")?;
        let n = self.params.keys.len();
        let mut it = outs.into_iter();
        let take = |it: &mut dyn Iterator<Item = Tensor>, n: usize| -> Vec<Tensor> {
            it.take(n).collect()
        };
        self.params.tensors = take(&mut it, n);
        self.m.tensors = take(&mut it, n);
        self.v.tensors = take(&mut it, n);
        Ok(loss)
    }

    /// Train for `steps` minibatches sampled from the corpus.
    pub fn run(
        &mut self,
        corpus: &Corpus,
        steps: usize,
        lr: f32,
        seed: u64,
        log_every: usize,
        label: &str,
    ) -> Result<TrainReport> {
        let mut rng = Rng::new(seed);
        let mut losses = Vec::with_capacity(steps);
        let timer = Timer::start();
        for s in 0..steps {
            let tokens = corpus.sample_batch(self.batch, self.seq, &mut rng);
            let loss = self.step_on(tokens, lr)?;
            losses.push(loss);
            if log_every > 0 && (s + 1) % log_every == 0 {
                eprintln!(
                    "[train:{label}] step {:>4}/{steps} loss {loss:.4} ({:.2}s)",
                    s + 1,
                    timer.elapsed_s()
                );
            }
        }
        Ok(TrainReport {
            steps,
            tokens: steps * self.batch * self.seq,
            seconds: timer.elapsed_s(),
            losses,
        })
    }
}
