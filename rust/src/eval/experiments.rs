//! Experiment drivers — one per table/figure in the paper's evaluation.
//!
//! Every driver prints a human-readable table and returns a `Json` blob
//! that the CLI writes under `runs/`. Paper-vs-measured commentary lives
//! in EXPERIMENTS.md.

use crate::config::{EngineConfig, HardwareProfile, ModelConfig};
use crate::convert::{
    self, Baseline, Calib, ConvertOptions, PcaMode,
};
use crate::coordinator::{Engine, ModelBundle, Request};
use crate::coordinator::engine::Arch;
use crate::corpus::Corpus;
use crate::eval::{capture_calib, evaluate, per_dim_norms, EvalResult};
use crate::json::Json;
use crate::model::{init_gqa, Params};
use crate::perfmodel;
use crate::runtime::Runtime;
use crate::train::Trainer;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Shared experiment state: runtime, trained base model, corpus, calib.
pub struct ExpContext<'a> {
    pub rt: &'a Runtime,
    pub cfg_name: String,
    pub cfg: ModelConfig,
    pub gqa: Params,
    pub corpus: Corpus,
    pub calib: Calib,
    pub out_dir: PathBuf,
    pub eval_batches: Vec<Vec<i32>>,
    pub ft_steps: usize,
}

impl<'a> ExpContext<'a> {
    /// Load (or briefly pretrain) the base GQA model and capture
    /// calibration activations.
    pub fn prepare(
        rt: &'a Runtime,
        cfg_name: &str,
        ckpt: Option<&Path>,
        pretrain_steps: usize,
        ft_steps: usize,
        out_dir: &Path,
        n_eval_batches: usize,
    ) -> Result<ExpContext<'a>> {
        std::fs::create_dir_all(out_dir)?;
        let cfg = rt
            .manifest
            .configs
            .get(cfg_name)
            .context("unknown config")?
            .clone();
        let corpus = Corpus::synthetic(7, 2_000_000);

        let gqa = match ckpt {
            Some(p) if p.exists() => {
                eprintln!("[exp] loading base checkpoint {}", p.display());
                Params::load(p)?
            }
            _ => {
                let mut params = init_gqa(&cfg, 42);
                if pretrain_steps > 0 {
                    eprintln!("[exp] pretraining GQA base for {pretrain_steps} steps");
                    let exec = rt.load(&format!("{cfg_name}_gqa_train"))?;
                    let mut tr = Trainer::new(exec, params)?;
                    tr.run(&corpus, pretrain_steps, 1e-3, 1, 20, "gqa-base")?;
                    params = tr.params.clone();
                    if let Some(p) = ckpt {
                        params.save(p, Json::obj())?;
                    }
                }
                params
            }
        };

        let calib_exec = rt.load(&format!("{cfg_name}_calib"))?;
        let spec_b = calib_exec.spec.batch.context("calib batch")?;
        let t = cfg.max_seq;
        let mut rng = crate::util::Rng::new(1234);
        let calib_tokens = corpus.sample_batch(spec_b, t, &mut rng);
        let calib = capture_calib(&calib_exec, &gqa, &calib_tokens, 1024)?;

        let eval_batches: Vec<Vec<i32>> = corpus
            .val_batches(spec_b, t)
            .into_iter()
            .take(n_eval_batches)
            .collect();

        Ok(ExpContext {
            rt,
            cfg_name: cfg_name.to_string(),
            cfg,
            gqa,
            corpus,
            calib,
            out_dir: out_dir.to_path_buf(),
            eval_batches,
            ft_steps,
        })
    }

    pub fn save_json(&self, name: &str, j: &Json) -> Result<()> {
        let path = self.out_dir.join(format!("{name}.json"));
        std::fs::write(&path, j.to_pretty())?;
        eprintln!("[exp] wrote {}", path.display());
        Ok(())
    }

    fn eval_gqa(&self) -> Result<EvalResult> {
        let exec = self.rt.load(&format!("{}_gqa_prefill", self.cfg_name))?;
        evaluate(&exec, &self.gqa, &self.eval_batches)
    }

    fn eval_merged(&self, params: &Params) -> Result<EvalResult> {
        let exec = self.rt.load(&format!("{}_merged_prefill", self.cfg_name))?;
        evaluate(&exec, params, &self.eval_batches)
    }

    fn eval_mla(&self, params: &Params, rank: usize) -> Result<EvalResult> {
        let exec = self
            .rt
            .load(&format!("{}_mla_prefill_r{rank}", self.cfg_name))?;
        evaluate(&exec, params, &self.eval_batches)
    }
}

// ---------------------------------------------------------------------------
// Figure 2a — key norms per dimension: original vs RoRoPE vs +FreqFold
// ---------------------------------------------------------------------------

pub fn fig2a(ctx: &ExpContext) -> Result<Json> {
    let k = &ctx.calib.k_pre[0]; // first layer, as in the paper
    let orig = per_dim_norms(k);

    let (q1, _) = convert::rorope_rotation(k, &ctx.cfg, 1)?;
    let rot1 = per_dim_norms(&k.matmul(&q1.t())?);

    let (q4, _) = convert::rorope_rotation(k, &ctx.cfg, 4)?;
    let rot4 = per_dim_norms(&k.matmul(&q4.t())?);

    let d = ctx.cfg.head_dim;
    let head_energy = |norms: &[f64]| -> Vec<f64> {
        (0..ctx.cfg.n_kv_groups)
            .map(|j| norms[j * d..(j + 1) * d].iter().map(|x| x * x).sum::<f64>().sqrt())
            .collect()
    };

    println!("\n=== Figure 2a: per-dimension key L2 norms (layer 0) ===");
    println!("head-level norm concentration (L2 over each head's dims):");
    println!("  original : {:?}", fmt_vec(&head_energy(&orig)));
    println!("  RoRoPE   : {:?}", fmt_vec(&head_energy(&rot1)));
    println!("  +4D fold : {:?}", fmt_vec(&head_energy(&rot4)));

    let mut j = Json::obj();
    j.set("orig", Json::from_f64s(&orig));
    j.set("rorope", Json::from_f64s(&rot1));
    j.set("rorope_fold4", Json::from_f64s(&rot4));
    Ok(j)
}

fn fmt_vec(v: &[f64]) -> Vec<f64> {
    v.iter().map(|x| (x * 100.0).round() / 100.0).collect()
}

// ---------------------------------------------------------------------------
// Figure 2b — log-ppl vs RoPE removal ratio, per strategy
// ---------------------------------------------------------------------------

pub fn fig2b(ctx: &ExpContext) -> Result<Json> {
    let cfg = &ctx.cfg;
    let g = cfg.n_kv_groups;
    let n_freq = cfg.head_dim / 2;
    let mut out = Json::obj();
    println!("\n=== Figure 2b: log-perplexity vs RoPE removal ratio ===");

    // MHA2MLA-norm baseline: keep k pairs per head.
    {
        let mut pts = vec![];
        for keep in [n_freq, n_freq / 2, n_freq / 4, n_freq / 8, 1] {
            let mask = convert::mha2mla_mask(
                cfg, &ctx.calib.k_pre[0], &ctx.calib.q_pre[0], keep,
            );
            let removal = 1.0 - keep as f64 / n_freq as f64;
            let p = convert::merged_params_from(&ctx.gqa, cfg, None, None, Some(mask))?;
            let ev = ctx.eval_merged(&p)?;
            println!("  mha2mla keep={keep:>2}/head removal={removal:.3} logppl={:.4}", ev.loss);
            pts.push((removal, ev.loss));
        }
        out.set("mha2mla", pts_json(&pts));
    }

    // RoRoPE (+folds): keep top-c components per frequency group.
    for fold in [1usize, 2, 4] {
        let rotations: Vec<_> = ctx
            .calib
            .k_pre
            .iter()
            .map(|k| convert::rorope_rotation(k, cfg, fold).map(|x| x.0))
            .collect::<Result<Vec<_>>>()?;
        let freqs = convert::rorope_rotation(&ctx.calib.k_pre[0], cfg, fold)?.1;
        let mut pts = vec![];
        let keeps: Vec<usize> = [g * fold, g * fold / 2, g * fold / 4, fold.max(2), fold, 1]
            .into_iter()
            .filter(|&k| k >= 1 && k <= g * fold)
            .collect();
        for keep in dedup(keeps) {
            let mask = convert::rorope_mask(cfg, keep, fold);
            let removal = 1.0 - keep as f64 / (g * fold) as f64;
            let p = convert::merged_params_from(
                &ctx.gqa, cfg, Some(&rotations), Some(freqs.clone()), Some(mask),
            )?;
            let ev = ctx.eval_merged(&p)?;
            println!("  rorope(fold={fold}) keep={keep:>2} removal={removal:.3} logppl={:.4}", ev.loss);
            pts.push((removal, ev.loss));
        }
        out.set(&format!("rorope_fold{fold}"), pts_json(&pts));
    }
    Ok(out)
}

fn dedup(mut v: Vec<usize>) -> Vec<usize> {
    v.sort_unstable();
    v.dedup();
    v.reverse();
    v
}

fn pts_json(pts: &[(f64, f64)]) -> Json {
    Json::Arr(
        pts.iter()
            .map(|&(x, y)| Json::Arr(vec![Json::Num(x), Json::Num(y)]))
            .collect(),
    )
}

// ---------------------------------------------------------------------------
// Figure 3a — K vs V norms before/after balancing
// ---------------------------------------------------------------------------

pub fn fig3a(ctx: &ExpContext) -> Result<Json> {
    let cfg = &ctx.cfg;
    let k = &ctx.calib.k_pre[0];
    let v = &ctx.calib.v_act[0];
    let (q1, _) = convert::rorope_rotation(k, cfg, 1)?;
    let k_rot = k.matmul(&q1.t())?;
    let d = cfg.head_dim;
    let k_nope = k_rot.slice_cols(d, cfg.kv_dim());
    let alpha = convert::kv_balance_alpha(&k_nope, v);

    let kn = k_nope.mean_row_norm();
    let vn = v.mean_row_norm();
    println!("\n=== Figure 3a: key/value norm disparity (layer 0) ===");
    println!("  mean ||k_nope|| = {kn:.4}  mean ||v|| = {vn:.4}  alpha = {alpha:.4}");
    println!("  after balancing: ||k_nope/alpha|| = {:.4}", kn / alpha);

    let mut j = Json::obj();
    j.set("k_nope_norm", Json::Num(kn as f64));
    j.set("v_norm", Json::Num(vn as f64));
    j.set("alpha", Json::Num(alpha as f64));
    j.set("k_dims", Json::from_f64s(&per_dim_norms(&k_nope)));
    j.set("v_dims", Json::from_f64s(&per_dim_norms(v)));
    Ok(j)
}

// ---------------------------------------------------------------------------
// Figure 3b — ppl vs compression: W-based vs WX-based PCA, +/- BKV
// ---------------------------------------------------------------------------

pub fn fig3b(ctx: &ExpContext) -> Result<Json> {
    let ranks = ctx
        .rt
        .manifest
        .sweep_ranks
        .get(&ctx.cfg_name)
        .cloned()
        .context("sweep ranks")?;
    println!("\n=== Figure 3b: ppl after joint KV low-rank compression ===");
    let mut out = Json::obj();
    for (label, mode, balance) in [
        ("wx_bkv", PcaMode::Activations, true),
        ("wx", PcaMode::Activations, false),
        ("w_bkv", PcaMode::Weights, true),
        ("w", PcaMode::Weights, false),
    ] {
        let mut pts = vec![];
        for &r in &ranks {
            let opts = ConvertOptions {
                rank: r,
                fold: 1,
                balance,
                pca_mode: mode,
                baseline: Baseline::TransMla,
                keep_pairs_per_head: None,
            };
            let (_, absorbed, _) = convert::convert_model(&ctx.gqa, &ctx.calib, &ctx.cfg, &opts)?;
            let ev = ctx.eval_mla(&absorbed, r)?;
            let keep = ctx.cfg.mla_kv_per_token(r) as f64 / ctx.cfg.kv_per_token() as f64;
            println!("  {label:<7} r={r:>3} kv_keep={keep:.3} logppl={:.4}", ev.loss);
            pts.push((keep, ev.loss));
        }
        out.set(label, pts_json(&pts));
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Table 1 — benchmark-style quality: orig vs MHA2MLA vs TransMLA, +/- FT
// ---------------------------------------------------------------------------

pub fn table1(ctx: &ExpContext) -> Result<Json> {
    let ranks = ctx
        .rt
        .manifest
        .table1_ranks
        .get(&ctx.cfg_name)
        .cloned()
        .context("table1 ranks")?;
    let mut out = Json::obj();
    println!("\n=== Table 1 analogue ({}): loss / ppl / top-1 acc ===", ctx.cfg_name);

    let base = ctx.eval_gqa()?;
    println!(
        "  {:<26} loss {:.4}  ppl {:>8.3}  acc {:.4}",
        "original GQA", base.loss, base.ppl, base.top1
    );
    out.set("original", eval_json(&base, None));

    let mut rows = vec![];
    for &r in &ranks {
        let comp = ctx.cfg.compression(r);
        // MHA2MLA baseline (no fine-tuning; the paper's "0 tokens" rows).
        let opts = ConvertOptions::mha2mla(r);
        let (_, absorbed, _) = convert::convert_model(&ctx.gqa, &ctx.calib, &ctx.cfg, &opts)?;
        let ev = ctx.eval_mla(&absorbed, r)?;
        println!(
            "  {:<26} loss {:.4}  ppl {:>8.3}  acc {:.4}",
            format!("MHA2MLA  -{:.2}% (0 tok)", comp * 100.0),
            ev.loss, ev.ppl, ev.top1
        );
        rows.push((format!("mha2mla_r{r}"), eval_json(&ev, Some(comp))));

        // TransMLA, untrained.
        let opts = ConvertOptions::transmla(r);
        let (train_p, absorbed, _) =
            convert::convert_model(&ctx.gqa, &ctx.calib, &ctx.cfg, &opts)?;
        let ev0 = ctx.eval_mla(&absorbed, r)?;
        println!(
            "  {:<26} loss {:.4}  ppl {:>8.3}  acc {:.4}",
            format!("TransMLA -{:.2}% (0 tok)", comp * 100.0),
            ev0.loss, ev0.ppl, ev0.top1
        );
        rows.push((format!("transmla_r{r}"), eval_json(&ev0, Some(comp))));

        // TransMLA + fine-tuning (the recovery rows).
        if ctx.ft_steps > 0 {
            let exec = ctx
                .rt
                .load(&format!("{}_mla_train_r{r}", ctx.cfg_name))?;
            let mut tr = Trainer::new(exec, train_p)?;
            let rep = tr.run(&ctx.corpus, ctx.ft_steps, 5e-4, 2, 20,
                             &format!("ft-r{r}"))?;
            let absorbed_ft = convert::absorb_trainable(&tr.params, &ctx.cfg)?;
            let ev_ft = ctx.eval_mla(&absorbed_ft, r)?;
            println!(
                "  {:<26} loss {:.4}  ppl {:>8.3}  acc {:.4}   ({} tokens FT)",
                format!("TransMLA -{:.2}% (+FT)", comp * 100.0),
                ev_ft.loss, ev_ft.ppl, ev_ft.top1, rep.tokens
            );
            let mut jj = eval_json(&ev_ft, Some(comp));
            jj.set("ft_tokens", Json::Num(rep.tokens as f64));
            jj.set("ft_final_loss", Json::Num(rep.tail_loss(10) as f64));
            rows.push((format!("transmla_r{r}_ft"), jj));
        }
    }
    for (k, v) in rows {
        out.set(&k, v);
    }
    Ok(out)
}

fn eval_json(ev: &EvalResult, comp: Option<f64>) -> Json {
    let mut j = Json::obj();
    j.set("loss", Json::Num(ev.loss));
    j.set("ppl", Json::Num(ev.ppl));
    j.set("top1", Json::Num(ev.top1));
    if let Some(c) = comp {
        j.set("kv_compression", Json::Num(c));
    }
    j
}

// ---------------------------------------------------------------------------
// Figure 4 / Table 4 — serving throughput: measured (CPU) + modeled (GPU)
// ---------------------------------------------------------------------------

pub fn table4(ctx: &ExpContext, measured_ctx: &[usize]) -> Result<Json> {
    let mut out = Json::obj();
    let rank = *ctx
        .rt
        .manifest
        .table1_ranks
        .get(&ctx.cfg_name)
        .and_then(|r| r.last())
        .context("rank")?;

    // Convert once at the highest compression (the paper's 92.97% row).
    let opts = ConvertOptions::transmla(rank);
    let (_, absorbed, _) = convert::convert_model(&ctx.gqa, &ctx.calib, &ctx.cfg, &opts)?;

    println!("\n=== Table 4 / Figure 4 (measured on CPU PJRT) ===");
    println!("  ctx | GQA tok/s | MLA tok/s (r={rank}) | speedup");
    let mut measured = vec![];
    for &ctx_len in measured_ctx {
        let gqa_tps = measure_throughput(ctx, Arch::Gqa, ctx_len, None)?;
        let mla_tps = measure_throughput(ctx, Arch::Mla { rank }, ctx_len, Some(&absorbed))?;
        let speedup = mla_tps / gqa_tps.max(1e-9);
        println!("  {ctx_len:>4} | {gqa_tps:>9.1} | {mla_tps:>9.1} | {speedup:.2}x");
        let mut j = Json::obj();
        j.set("context", Json::Num(ctx_len as f64));
        j.set("gqa_tps", Json::Num(gqa_tps));
        j.set("mla_tps", Json::Num(mla_tps));
        j.set("speedup", Json::Num(speedup));
        measured.push(j);
    }
    out.set("measured_cpu", Json::Arr(measured));

    // Analytical model at LLaMA-2-7B scale on the paper's three GPUs.
    let modeled = perfmodel::table4_model(&HardwareProfile::paper_profiles());
    println!("\n  analytical model (LLaMA-2-7B scale, tokens/s; `OOM` as in paper):");
    perfmodel::print_table4(&modeled);
    out.set("modeled", modeled);
    Ok(out)
}

fn measure_throughput(
    ctx: &ExpContext,
    arch: Arch,
    ctx_len: usize,
    mla_params: Option<&Params>,
) -> Result<f64> {
    let batch = 8;
    // Decode artifacts exist at several cache capacities (t-suffixed).
    let t_default = ctx.cfg.max_seq;
    let suffix = if ctx_len == t_default {
        String::new()
    } else {
        format!("_t{ctx_len}")
    };
    let (prefill_name, decode_name) = match arch {
        Arch::Gqa => (
            format!("{}_gqa_prefill", ctx.cfg_name),
            format!("{}_gqa_decode_b{batch}{suffix}", ctx.cfg_name),
        ),
        Arch::Mla { rank } => (
            format!("{}_mla_prefill_r{rank}", ctx.cfg_name),
            format!("{}_mla_decode_r{rank}_b{batch}{suffix}", ctx.cfg_name),
        ),
    };
    let params = match arch {
        Arch::Gqa => ctx.gqa.clone(),
        Arch::Mla { .. } => mla_params.unwrap().clone(),
    };
    let bundle = ModelBundle::load_named(
        ctx.rt, &ctx.cfg_name, arch, batch, params, &prefill_name, &decode_name,
    )?;
    let mut engine = Engine::with_bundle(bundle, EngineConfig::default());

    // Paper's protocol: input length = output length = ctx/2.
    let half = (ctx_len / 2).min(ctx_len - 8);
    let n_requests = 16;
    let mut rng = crate::util::Rng::new(5);
    for i in 0..n_requests {
        let start = rng.below(ctx.corpus.train.len() - half - 1);
        let prompt: Vec<i32> = ctx.corpus.train[start..start + half]
            .iter()
            .map(|&b| b as i32)
            .collect();
        let mut req = Request::new(i, prompt, half);
        req.temperature = 0.7;
        engine.submit(req);
    }
    engine.run_to_completion()?;
    Ok(engine.decode_throughput())
}

// ---------------------------------------------------------------------------
// Table 5 — case study generations
// ---------------------------------------------------------------------------

pub fn table5(ctx: &ExpContext) -> Result<Json> {
    let rank = *ctx
        .rt
        .manifest
        .table1_ranks
        .get(&ctx.cfg_name)
        .and_then(|r| r.last())
        .context("rank")?;
    let opts = ConvertOptions::transmla(rank);
    let (train_p, absorbed, _) =
        convert::convert_model(&ctx.gqa, &ctx.calib, &ctx.cfg, &opts)?;

    let prompts = ["the model ", "our system serves ", "meanwhile, the scheduler "];
    let mut out = Json::obj();
    println!("\n=== Table 5 analogue: generations at -{:.2}% KV ===",
             ctx.cfg.compression(rank) * 100.0);

    let gen_with = |params: &Params, label: &str| -> Result<Json> {
        let bundle = ModelBundle::load(ctx.rt, &ctx.cfg_name,
                                       Arch::Mla { rank }, 8, params.clone())?;
        let mut engine = Engine::with_bundle(bundle, EngineConfig::default());
        let reqs: Vec<Request> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| Request::from_text(i as u64, p, 48))
            .collect();
        let comps = engine.generate(reqs)?;
        let mut arr = vec![];
        for (p, c) in prompts.iter().zip(&comps) {
            let text = c.text();
            println!("  [{label}] {p:?} -> {text:?}");
            arr.push(Json::Str(format!("{p}{text}")));
        }
        Ok(Json::Arr(arr))
    };

    out.set("without_training", gen_with(&absorbed, "w/o train")?);

    if ctx.ft_steps > 0 {
        let exec = ctx.rt.load(&format!("{}_mla_train_r{rank}", ctx.cfg_name))?;
        let mut tr = Trainer::new(exec, train_p)?;
        tr.run(&ctx.corpus, ctx.ft_steps, 5e-4, 3, 0, "table5-ft")?;
        let absorbed_ft = convert::absorb_trainable(&tr.params, &ctx.cfg)?;
        out.set("after_finetune", gen_with(&absorbed_ft, "fine-tuned")?);
    }
    Ok(out)
}
