//! Evaluation: perplexity / accuracy over the held-out corpus, calibration
//! capture, and the drivers that regenerate every table and figure of the
//! paper (see `experiments`).

pub mod experiments;

use crate::convert::Calib;
use crate::model::Params;
use crate::runtime::{Exec, Value};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// Cross-entropy + top-1 accuracy of next-byte prediction from prefill
/// logits [B, T, V] against the token matrix [B, T].
pub fn lm_metrics(logits: &Tensor, tokens: &[i32], b: usize, t: usize) -> (f64, f64) {
    let v = logits.shape[2];
    let mut nll = 0.0f64;
    let mut correct = 0usize;
    let mut count = 0usize;
    for row in 0..b {
        for pos in 0..t - 1 {
            let target = tokens[row * t + pos + 1] as usize;
            let off = (row * t + pos) * v;
            let lrow = &logits.data[off..off + v];
            let m = lrow.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut lse = 0.0f64;
            let mut best = 0usize;
            let mut best_v = f32::NEG_INFINITY;
            for (i, &x) in lrow.iter().enumerate() {
                lse += ((x - m) as f64).exp();
                if x > best_v {
                    best_v = x;
                    best = i;
                }
            }
            let logp = (lrow[target] - m) as f64 - lse.ln();
            nll -= logp;
            if best == target {
                correct += 1;
            }
            count += 1;
        }
    }
    (nll / count as f64, correct as f64 / count as f64)
}

/// Evaluation result over a set of batches.
#[derive(Clone, Debug)]
pub struct EvalResult {
    pub loss: f64,
    pub ppl: f64,
    pub top1: f64,
    pub n_batches: usize,
}

/// Perplexity of a prefill-style artifact (first output = logits [B,T,V]).
pub fn evaluate(
    exec: &Arc<Exec>,
    params: &Params,
    batches: &[Vec<i32>],
) -> Result<EvalResult> {
    let spec = &exec.spec;
    let b = spec.batch.context("prefill batch")?;
    let t = spec.config.max_seq;
    if spec.kind != "prefill" {
        bail!("evaluate wants a prefill artifact, got `{}`", spec.name);
    }
    let mut args = params.values();
    args.push(Value::i32_vec(vec![])); // placeholder, replaced per batch
    let mut loss_sum = 0.0;
    let mut acc_sum = 0.0;
    let mut n = 0usize;
    for batch in batches {
        if batch.len() != b * t {
            bail!("batch len {} != {}x{}", batch.len(), b, t);
        }
        *args.last_mut().unwrap() = Value::i32_mat(batch.clone(), &[b, t]);
        let outs = exec.run(&args)?;
        let logits = &outs[0];
        let (loss, top1) = lm_metrics(logits, batch, b, t);
        loss_sum += loss;
        acc_sum += top1;
        n += 1;
    }
    if n == 0 {
        bail!("no eval batches");
    }
    let loss = loss_sum / n as f64;
    Ok(EvalResult { loss, ppl: loss.exp(), top1: acc_sum / n as f64, n_batches: n })
}

/// Run the calibration artifact and build per-layer activation matrices,
/// optionally subsampled to `max_rows` rows per layer (PCA cost control).
pub fn capture_calib(
    exec: &Arc<Exec>,
    params: &Params,
    tokens: &[i32],
    max_rows: usize,
) -> Result<Calib> {
    let spec = &exec.spec;
    let b = spec.batch.context("calib batch")?;
    let t = spec.config.max_seq;
    if tokens.len() != b * t {
        bail!("calib tokens len");
    }
    let mut args = params.values();
    args.push(Value::i32_mat(tokens.to_vec(), &[b, t]));
    let outs = exec.run(&args)?;
    let (k, v, q) = (&outs[0], &outs[1], &outs[2]);
    let calib = Calib::from_stacked(k, v, q)?;
    Ok(subsample_calib(calib, max_rows))
}

fn subsample_rows(t: &Tensor, max_rows: usize) -> Tensor {
    let (n, d) = (t.rows(), t.cols());
    if n <= max_rows {
        return t.clone();
    }
    let stride = n / max_rows;
    let mut data = Vec::with_capacity(max_rows * d);
    for i in 0..max_rows {
        data.extend_from_slice(t.row(i * stride));
    }
    Tensor::new(&[max_rows, d], data).unwrap()
}

fn subsample_calib(c: Calib, max_rows: usize) -> Calib {
    Calib {
        k_pre: c.k_pre.iter().map(|t| subsample_rows(t, max_rows)).collect(),
        v_act: c.v_act.iter().map(|t| subsample_rows(t, max_rows)).collect(),
        q_pre: c.q_pre.iter().map(|t| subsample_rows(t, max_rows)).collect(),
    }
}

/// Mean L2 norm per dimension of a sample matrix [N, D] -> [D].
pub fn per_dim_norms(samples: &Tensor) -> Vec<f64> {
    let (n, d) = (samples.rows(), samples.cols());
    let mut out = vec![0.0f64; d];
    for i in 0..n {
        for (j, &x) in samples.row(i).iter().enumerate() {
            out[j] += (x as f64) * (x as f64);
        }
    }
    out.iter_mut().for_each(|x| *x = (*x / n as f64).sqrt());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lm_metrics_uniform_logits() {
        let (b, t, v) = (2, 4, 8);
        let logits = Tensor::zeros(&[b, t, v]);
        let tokens = vec![1i32; b * t];
        let (loss, top1) = lm_metrics(&logits, &tokens, b, t);
        assert!((loss - (v as f64).ln()).abs() < 1e-9);
        // argmax of all-zero logits is index 0, target is 1 -> never right
        assert_eq!(top1, 0.0);
    }

    #[test]
    fn lm_metrics_perfect_prediction() {
        let (b, t, v) = (1, 3, 4);
        let tokens = vec![0i32, 2, 3];
        let mut logits = Tensor::zeros(&[b, t, v]);
        // position 0 predicts token 2; position 1 predicts 3
        logits.data[0 * v + 2] = 50.0;
        logits.data[1 * v + 3] = 50.0;
        let (loss, top1) = lm_metrics(&logits, &tokens, b, t);
        assert!(loss < 1e-6);
        assert_eq!(top1, 1.0);
    }

    #[test]
    fn per_dim_norms_constant() {
        let t = Tensor::new(&[4, 2], vec![3.0; 8]).unwrap();
        let n = per_dim_norms(&t);
        assert!((n[0] - 3.0).abs() < 1e-9 && (n[1] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn subsample_keeps_shape() {
        let t = Tensor::new(&[10, 2], (0..20).map(|x| x as f32).collect()).unwrap();
        let s = subsample_rows(&t, 5);
        assert_eq!(s.shape, vec![5, 2]);
        assert_eq!(s.row(0), &[0.0, 1.0]);
    }
}
