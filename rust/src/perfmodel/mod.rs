//! Analytical accelerator model — regenerates the *shape* of the paper's
//! Figure 4 / Table 4 on the three GPU profiles the authors used, at
//! LLaMA-2-7B scale.
//!
//! Decode on modern accelerators is memory-bound: each generated token
//! must stream the model weights once per batch *plus* the KV cache of
//! every active sequence. A roofline over (FLOPs / peak-compute) vs
//! (bytes / bandwidth) per step therefore reproduces who wins, by what
//! factor, and where the OOM cliff falls — without the authors' testbed.

pub mod autotune;

use crate::config::DEFAULT_BLOCK_SIZE;
use crate::json::Json;
use crate::kvcache::QuantKind;

/// Transformer dimensioning for the performance model.
#[derive(Clone, Debug)]
pub struct ModelDims {
    pub name: String,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_groups: usize,
    pub head_dim: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub bytes_per_el: f64, // fp16 = 2
}

impl ModelDims {
    /// LLaMA-2-7B (MHA: g == h == 32).
    pub fn llama2_7b() -> Self {
        ModelDims {
            name: "llama2-7b".into(),
            d_model: 4096,
            n_heads: 32,
            n_kv_groups: 32,
            head_dim: 128,
            n_layers: 32,
            d_ff: 11008,
            vocab: 32000,
            bytes_per_el: 2.0,
        }
    }

    pub fn n_params(&self) -> f64 {
        let d = self.d_model as f64;
        let hd = (self.n_heads * self.head_dim) as f64;
        let gd = (self.n_kv_groups * self.head_dim) as f64;
        let f = self.d_ff as f64;
        let l = self.n_layers as f64;
        let v = self.vocab as f64;
        2.0 * v * d + l * (d * hd + 2.0 * d * gd + hd * d + 3.0 * d * f)
    }

    /// Bytes one cache row of `inner` elements occupies under `quant`:
    /// the model's native element width unencoded, one byte per element
    /// plus the 4-byte per-row scale for the lossy codecs (both int8 and
    /// the simulated fp8 store one code byte per element).
    fn enc_row_bytes(&self, inner: usize, quant: QuantKind) -> f64 {
        if quant.is_off() {
            inner as f64 * self.bytes_per_el
        } else {
            inner as f64 + 4.0
        }
    }

    /// GQA KV-cache bytes per token (all layers), unencoded.
    pub fn kv_bytes_per_token(&self) -> f64 {
        self.kv_bytes_per_token_enc(QuantKind::Off)
    }

    /// GQA KV-cache bytes per token under a block codec: two rows (k, v)
    /// of `g*d` elements per layer.
    pub fn kv_bytes_per_token_enc(&self, quant: QuantKind) -> f64 {
        2.0 * self.enc_row_bytes(self.n_kv_groups * self.head_dim, quant)
            * self.n_layers as f64
    }

    /// MLA KV-cache bytes per token at latent rank r (+ shared RoPE
    /// head), unencoded.
    pub fn mla_kv_bytes_per_token(&self, r: usize) -> f64 {
        self.mla_kv_bytes_per_token_enc(r, QuantKind::Off)
    }

    /// MLA KV-cache bytes per token under a block codec: one latent row
    /// (r) and one rope-key row (head_dim) per layer.
    pub fn mla_kv_bytes_per_token_enc(&self, r: usize, quant: QuantKind) -> f64 {
        (self.enc_row_bytes(r, quant) + self.enc_row_bytes(self.head_dim, quant))
            * self.n_layers as f64
    }
}

/// The serving-side cache configuration the roofline now prices: the
/// block codec scales every cache byte the decode step streams (and the
/// capacity check), the block size rounds context up to allocation
/// granularity (internal fragmentation is read as real traffic).
#[derive(Clone, Copy, Debug)]
pub struct CacheModel {
    pub quant: QuantKind,
    pub block_size: usize,
}

impl Default for CacheModel {
    fn default() -> Self {
        CacheModel { quant: QuantKind::Off, block_size: DEFAULT_BLOCK_SIZE }
    }
}

impl CacheModel {
    /// Context rounded up to whole blocks — the positions the pool has
    /// actually materialised (and the step actually streams).
    fn ctx_blocks(&self, ctx: f64) -> f64 {
        let bs = self.block_size.max(1) as f64;
        (ctx / bs).ceil() * bs
    }

    /// Cache bytes per token for `arch` under this config.
    pub fn bytes_per_token(&self, dims: &ModelDims, arch: ArchModel) -> f64 {
        match arch {
            ArchModel::Gqa => dims.kv_bytes_per_token_enc(self.quant),
            ArchModel::Mla { r, .. } => dims.mla_kv_bytes_per_token_enc(r, self.quant),
        }
    }
}

/// Architecture variant for the model.
#[derive(Clone, Copy, Debug)]
pub enum ArchModel {
    Gqa,
    /// Absorbed MLA with latent rank r; `low_rank_q` also compresses the
    /// query projections (paper Fig. 4's two variants).
    Mla { r: usize, low_rank_q: bool },
}

/// Per-decode-step cost (one token for each of `batch` sequences at
/// context length `ctx`), priced under the actual cache config: the
/// codec scales the cache bytes streamed per step, the block size rounds
/// `ctx` up to allocation granularity. FLOPs are unchanged by the codec
/// — decode stays fp after the staging dequant.
pub fn decode_step_cost(
    dims: &ModelDims,
    arch: ArchModel,
    cache_cfg: &CacheModel,
    batch: f64,
    ctx: f64,
) -> (f64, f64) {
    let d = dims.d_model as f64;
    let h = dims.n_heads as f64;
    let hd = (dims.n_heads * dims.head_dim) as f64;
    let gd = (dims.n_kv_groups * dims.head_dim) as f64;
    let f = dims.d_ff as f64;
    let l = dims.n_layers as f64;
    let be = dims.bytes_per_el;

    // Weights stream once per step (batched GEMV regime).
    let weight_bytes = dims.n_params() * be;

    let (attn_flops, cache_bytes, proj_flops) = match arch {
        ArchModel::Gqa => {
            let per_layer = 2.0 * hd * ctx * 2.0; // scores + values, all heads
            let cache = dims.kv_bytes_per_token_enc(cache_cfg.quant)
                * cache_cfg.ctx_blocks(ctx)
                * batch;
            let proj = 2.0 * d * (hd + 2.0 * gd + hd); // q,k,v,o
            (per_layer * l * batch, cache, proj * l * batch)
        }
        ArchModel::Mla { r, low_rank_q } => {
            let rr = r as f64;
            let dr = dims.head_dim as f64;
            // Absorbed attention: every head scores against the shared
            // latent (r) + rope key (dr), then latent-weighted sum (r).
            let per_layer = 2.0 * h * ctx * (rr + dr) + 2.0 * h * ctx * rr;
            let cache = dims.mla_kv_bytes_per_token_enc(r, cache_cfg.quant)
                * cache_cfg.ctx_blocks(ctx)
                * batch;
            // Projections: q (full or low-rank), latent down, rope key,
            // absorbed output.
            let q_proj = if low_rank_q {
                2.0 * d * (rr + dr) * h * 0.25 // factored q, rank ~ d/4
            } else {
                2.0 * d * (rr + dr) * h
            };
            let proj = q_proj + 2.0 * d * (rr + dr) + 2.0 * h * rr * d;
            (per_layer * l * batch, cache, proj * l * batch)
        }
    };
    let mlp_flops = 2.0 * 3.0 * d * f * l * batch;
    let lm_head = 2.0 * d * dims.vocab as f64 * batch;
    let flops = attn_flops + proj_flops + mlp_flops + lm_head;
    let bytes = weight_bytes + cache_bytes;
    (flops, bytes)
}

/// Tokens/s for decode at a given hardware profile, or None if the
/// weights + caches exceed device memory (the paper's OOM entries).
/// The serial path: exactly one token per sequence per step.
pub fn decode_throughput(
    dims: &ModelDims,
    arch: ArchModel,
    cache_cfg: &CacheModel,
    hw: &crate::config::HardwareProfile,
    batch: f64,
    ctx: f64,
) -> Option<f64> {
    decode_throughput_spec(dims, arch, cache_cfg, hw, batch, ctx, 1.0)
}

/// [`decode_throughput`] generalized to `tokens_per_step` accepted
/// tokens per sequence per step — the speculative-decoding regime, where
/// one batched verify call scores a whole candidate chain.
///
/// The roofline explains why speculation pays in the memory-bound decode
/// regime: the weights stream once per *step* no matter how many
/// positions the step scores, so their bytes are amortized over every
/// accepted token, while compute (and per-position cache traffic) scale
/// with the chain length. Throughput therefore improves sublinearly in
/// `tokens_per_step` and saturates once the step turns compute-bound.
pub fn decode_throughput_spec(
    dims: &ModelDims,
    arch: ArchModel,
    cache_cfg: &CacheModel,
    hw: &crate::config::HardwareProfile,
    batch: f64,
    ctx: f64,
    tokens_per_step: f64,
) -> Option<f64> {
    let tps = tokens_per_step.max(1.0);
    let weight_gb = dims.n_params() * dims.bytes_per_el / 1e9;
    // Capacity is charged at encoded size — a lossy codec moves the OOM
    // cliff, which is exactly the admission win it exists for.
    let cache_gb =
        cache_cfg.bytes_per_token(dims, arch) * cache_cfg.ctx_blocks(ctx) * batch / 1e9;
    // Activations + framework overhead headroom (~10%).
    if weight_gb + cache_gb > hw.mem_gb * 0.9 {
        return None;
    }
    let (flops, bytes) = decode_step_cost(dims, arch, cache_cfg, batch, ctx);
    // Split the step's bytes: weights stream once per step (amortized
    // across the chain), cache reads repeat per scored position.
    let weight_bytes = dims.n_params() * dims.bytes_per_el;
    let cache_bytes = bytes - weight_bytes;
    let step_flops = flops * tps;
    let step_bytes = weight_bytes + cache_bytes * tps;
    // MFU/bandwidth efficiency: serving stacks reach ~60% of peak BW and
    // ~40% of peak compute in the batched-decode regime.
    let t_compute = step_flops / (hw.tflops * 1e12 * 0.4);
    let t_memory = step_bytes / (hw.bw_gbs * 1e9 * 0.6);
    let step = t_compute.max(t_memory);
    Some(batch * tps / step)
}

/// The paper's protocol: input len = output len = ctx/2; batch sized to
/// fill memory like vLLM does (we model a fixed 64-sequence batch cap).
pub fn table4_model(profiles: &[crate::config::HardwareProfile]) -> Json {
    let dims = ModelDims::llama2_7b();
    // r chosen to mirror the paper's 92.97% compression:
    // kept = (r + d) / (2gd) = 576/8192 -> r = 448.
    let r = 448;
    let contexts = [1024usize, 2048, 4096, 8192, 16384, 32768];
    let mut rows = vec![];
    for &ctx in &contexts {
        let mut row = Json::obj();
        row.set("context", Json::Num(ctx as f64));
        for hw in profiles {
            // vLLM grows the batch until KV memory is exhausted; cap 64.
            let pick_batch = |arch: ArchModel| -> Option<(f64, f64)> {
                let cc = CacheModel::default();
                let mut best = None;
                for b in [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0] {
                    if let Some(tps) = decode_throughput(&dims, arch, &cc, hw, b, ctx as f64) {
                        best = Some((b, tps));
                    }
                }
                best
            };
            let gqa = pick_batch(ArchModel::Gqa);
            let mla = pick_batch(ArchModel::Mla { r, low_rank_q: false });
            let mla_lrq = pick_batch(ArchModel::Mla { r, low_rank_q: true });
            let mut cell = Json::obj();
            cell.set("gqa_tps", opt_num(gqa.map(|x| x.1)));
            cell.set("mla_tps", opt_num(mla.map(|x| x.1)));
            cell.set("mla_lowrank_q_tps", opt_num(mla_lrq.map(|x| x.1)));
            cell.set(
                "speedup",
                match (gqa, mla) {
                    (Some(g), Some(m)) => Json::Num(m.1 / g.1),
                    (None, Some(_)) => Json::Str("inf (GQA OOM)".into()),
                    _ => Json::Null,
                },
            );
            row.set(&hw.name, cell);
        }
        rows.push(row);
    }
    Json::Arr(rows)
}

fn opt_num(x: Option<f64>) -> Json {
    match x {
        Some(v) => Json::Num(v),
        None => Json::Str("OOM".into()),
    }
}

pub fn print_table4(j: &Json) {
    if let Some(rows) = j.as_arr() {
        for row in rows {
            let ctx = row.get("context").and_then(Json::as_f64).unwrap_or(0.0);
            print!("    ctx {:>6}:", ctx as usize);
            if let Some(obj) = row.as_obj() {
                for (k, v) in obj {
                    if k == "context" {
                        continue;
                    }
                    let g = fmt_cell(v.get("gqa_tps"));
                    let m = fmt_cell(v.get("mla_tps"));
                    let s = match v.get("speedup") {
                        Some(Json::Num(x)) => format!("{x:.1}x"),
                        Some(Json::Str(s)) => s.clone(),
                        _ => "-".into(),
                    };
                    print!("  [{k}] gqa={g} mla={m} ({s})");
                }
            }
            println!();
        }
    }
}

fn fmt_cell(v: Option<&Json>) -> String {
    match v {
        Some(Json::Num(x)) => format!("{x:.0}"),
        Some(Json::Str(s)) => s.clone(),
        _ => "-".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareProfile;

    #[test]
    fn param_count_matches_7b() {
        let d = ModelDims::llama2_7b();
        let n = d.n_params();
        assert!(n > 6.0e9 && n < 7.5e9, "{n}");
    }

    #[test]
    fn kv_bytes_match_llama2() {
        let d = ModelDims::llama2_7b();
        // 2 * 32 heads * 128 dim * 32 layers * 2 bytes = 512 KiB/token
        assert_eq!(d.kv_bytes_per_token() as u64, 524_288);
        // paper's 92.97% row
        let ratio = 1.0 - d.mla_kv_bytes_per_token(448) / d.kv_bytes_per_token();
        assert!((ratio - 0.9297).abs() < 1e-3, "{ratio}");
    }

    #[test]
    fn mla_wins_and_gap_grows_with_context() {
        let d = ModelDims::llama2_7b();
        let hw = &HardwareProfile::paper_profiles()[1];
        let cc = CacheModel::default();
        let s = |ctx: f64| {
            let g = decode_throughput(&d, ArchModel::Gqa, &cc, hw, 2.0, ctx).unwrap();
            let m = decode_throughput(
                &d, ArchModel::Mla { r: 448, low_rank_q: false }, &cc, hw, 2.0, ctx,
            )
            .unwrap();
            m / g
        };
        let (s1, s8) = (s(1024.0), s(8192.0));
        assert!(s1 > 1.0, "MLA should win at 1k: {s1}");
        assert!(s8 > s1, "speedup should grow with context: {s1} vs {s8}");
    }

    #[test]
    fn speculative_throughput_improves_sublinearly() {
        let d = ModelDims::llama2_7b();
        let hw = &HardwareProfile::paper_profiles()[1];
        let arch = ArchModel::Mla { r: 448, low_rank_q: false };
        let cc = CacheModel::default();
        let serial = decode_throughput(&d, arch, &cc, hw, 4.0, 4096.0).unwrap();
        // tokens_per_step = 1 is exactly the serial model.
        let one = decode_throughput_spec(&d, arch, &cc, hw, 4.0, 4096.0, 1.0).unwrap();
        assert_eq!(serial, one);
        // Accepting ~3 tokens/step must beat serial (weights amortized)
        // but cannot reach a full 3x (compute and cache traffic scale
        // with the chain).
        let spec = decode_throughput_spec(&d, arch, &cc, hw, 4.0, 4096.0, 3.0).unwrap();
        assert!(spec > serial, "speculation must pay: {spec} vs {serial}");
        assert!(spec < 3.0 * serial, "speedup is sublinear: {spec} vs {serial}");
        // Sub-1 inputs clamp to the serial model instead of rewarding a
        // nonsense acceptance rate.
        let clamped =
            decode_throughput_spec(&d, arch, &cc, hw, 4.0, 4096.0, 0.25).unwrap();
        assert_eq!(clamped, serial);
        // The OOM cliff is unchanged by speculation.
        let hw24 = &HardwareProfile::paper_profiles()[0];
        assert!(
            decode_throughput_spec(&d, ArchModel::Gqa, &cc, hw24, 8.0, 16384.0, 3.0)
                .is_none()
        );
    }

    #[test]
    fn gqa_ooms_first_on_24gb() {
        let d = ModelDims::llama2_7b();
        let hw = &HardwareProfile::paper_profiles()[0]; // 24 GB
        // Paper Table 4: LLaMA-2-7B OOMs at 16K on the 24GB card (their
        // batch); with batch 32 the model reproduces the cliff.
        let cc = CacheModel::default();
        let gqa = decode_throughput(&d, ArchModel::Gqa, &cc, hw, 8.0, 16384.0);
        let mla = decode_throughput(
            &d, ArchModel::Mla { r: 448, low_rank_q: false }, &cc, hw, 8.0, 16384.0,
        );
        assert!(gqa.is_none(), "GQA should OOM");
        assert!(mla.is_some(), "MLA should fit");
        // The capacity check is codec-aware: at batch 2 / 8K context the
        // fp16 GQA cache (8.6 GB) plus weights (13.5 GB) just tips over
        // the 24 GB card's 90% headroom, and int8 halving pulls it back
        // under the cliff.
        let int8 = CacheModel { quant: QuantKind::Int8, ..CacheModel::default() };
        assert!(decode_throughput(&d, ArchModel::Gqa, &cc, hw, 2.0, 8192.0).is_none());
        assert!(
            decode_throughput(&d, ArchModel::Gqa, &int8, hw, 2.0, 8192.0).is_some(),
            "int8 GQA should fit where fp16 OOMs"
        );
    }

    #[test]
    fn table4_shape() {
        let t = table4_model(&HardwareProfile::paper_profiles());
        let rows = t.as_arr().unwrap();
        assert_eq!(rows.len(), 6);
        // 8K context on the smallest card: speedup should be large (paper: 10.6x)
        let row8k = &rows[3];
        let cell = row8k.get("165.2TF|24GB").unwrap();
        if let Some(Json::Num(s)) = cell.get("speedup") {
            assert!(*s > 3.0, "8k speedup too small: {s}");
        }
    }
}
