//! Closing the perfmodel's predict→tune loop: pick the serving knobs —
//! block codec, paged block size, prefill chunk budget — from the
//! roofline's compute-vs-memory-bound split on a concrete
//! [`HardwareProfile`], instead of asking the operator to guess.
//!
//! The decision procedure is deliberately transparent (two regimes, one
//! threshold) so the unit tests can pin every choice:
//!
//!   * **memory-bound** (`t_memory > t_compute` for one decode step at
//!     the target batch/context): bytes are the bottleneck, so spend
//!     accuracy headroom on the int8 codec (per-row scales keep the sim
//!     backend's greedy outputs exact — see `kvcache::quant`), keep
//!     blocks small (fragmented bytes are streamed bytes), and keep
//!     prefill chunks short so the memory-bound decode cadence is never
//!     stalled behind a long prompt.
//!   * **compute-bound**: bytes are cheap, FLOPs are not. Store fp32
//!     blocks (no staging work on the read path), coarsen blocks (fewer
//!     table entries, no bandwidth penalty worth trading), and run big
//!     prefill chunks to amortize per-call overhead on the saturated
//!     compute units.
//!
//! The fp8 codec is never auto-picked: it buys the same byte ratio as
//! int8 in this repo's simulated layout (one code byte per element) at
//! strictly worse accuracy, so it stays an explicit operator opt-in.

use super::{decode_step_cost, ArchModel, CacheModel, ModelDims};
use crate::config::HardwareProfile;
use crate::kvcache::QuantKind;

/// Serving knobs chosen by [`autotune`], plus the roofline evidence
/// (`t_compute` / `t_memory`, seconds per decode step) behind the call.
#[derive(Clone, Debug, PartialEq)]
pub struct TunePlan {
    pub quant: QuantKind,
    pub block_size: usize,
    /// Prefill token budget per iteration for the `chunked` policy.
    pub chunk_tokens: usize,
    /// Which side of the roofline the workload sits on.
    pub memory_bound: bool,
    pub t_compute: f64,
    pub t_memory: f64,
}

/// Fine-grained allocation for memory-bound serving; matches the paged
/// store's default so an autotuned config only *coarsens* when compute
/// is the bottleneck.
const BLOCK_MEMORY_BOUND: usize = 16;
const BLOCK_COMPUTE_BOUND: usize = 32;
/// Short chunks keep decode cadence; long chunks amortize compute.
const CHUNK_MEMORY_BOUND: usize = 16;
const CHUNK_COMPUTE_BOUND: usize = 64;

/// Pick codec, block size, and chunk budget for serving `dims`/`arch` at
/// `batch` concurrent sequences around context length `ctx` on `hw`.
///
/// The split is evaluated on the *unquantized* step cost: the tuner asks
/// "is this workload memory-bound as configured today?", then spends the
/// codec to attack exactly that bottleneck. (Evaluating under int8 would
/// make the decision self-referential without changing the answer —
/// quantization only ever moves a step toward compute-bound, never past
/// the point where the codec stops helping.)
pub fn autotune(
    dims: &ModelDims,
    arch: ArchModel,
    hw: &HardwareProfile,
    batch: usize,
    ctx: usize,
) -> TunePlan {
    let probe = CacheModel { quant: QuantKind::Off, block_size: BLOCK_MEMORY_BOUND };
    let (flops, bytes) = decode_step_cost(dims, arch, &probe, batch as f64, ctx as f64);
    // Same efficiency factors as `decode_throughput`: ~40% of peak
    // compute, ~60% of peak bandwidth in the batched-decode regime.
    let t_compute = flops / (hw.tflops * 1e12 * 0.4);
    let t_memory = bytes / (hw.bw_gbs * 1e9 * 0.6);
    let memory_bound = t_memory > t_compute;
    if memory_bound {
        TunePlan {
            quant: QuantKind::Int8,
            block_size: BLOCK_MEMORY_BOUND,
            chunk_tokens: CHUNK_MEMORY_BOUND,
            memory_bound,
            t_compute,
            t_memory,
        }
    } else {
        TunePlan {
            quant: QuantKind::Off,
            block_size: BLOCK_COMPUTE_BOUND,
            chunk_tokens: CHUNK_COMPUTE_BOUND,
            memory_bound,
            t_compute,
            t_memory,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Two workloads that sit on opposite sides of the roofline on every
    // paper profile:
    //  * GQA at batch 4 / 8K context streams ~30 GB of weights+cache per
    //    step against ~0.05 TFLOP of work — memory-bound everywhere;
    //  * MLA r=64 at batch 256 / 512 context multiplies the FLOPs by the
    //    huge batch while the weights still stream once and the latent
    //    cache is tiny — compute-bound everywhere.
    fn memory_bound_workload() -> (ModelDims, ArchModel, usize, usize) {
        (ModelDims::llama2_7b(), ArchModel::Gqa, 4, 8192)
    }

    fn compute_bound_workload() -> (ModelDims, ArchModel, usize, usize) {
        (
            ModelDims::llama2_7b(),
            ArchModel::Mla { r: 64, low_rank_q: false },
            256,
            512,
        )
    }

    #[test]
    fn memory_bound_picks_int8_fine_blocks_short_chunks() {
        let (dims, arch, batch, ctx) = memory_bound_workload();
        for hw in &HardwareProfile::paper_profiles()[..2] {
            let plan = autotune(&dims, arch, hw, batch, ctx);
            assert!(plan.memory_bound, "{}: {plan:?}", hw.name);
            assert!(plan.t_memory > plan.t_compute, "{}: {plan:?}", hw.name);
            assert_eq!(plan.quant, QuantKind::Int8, "{}", hw.name);
            assert_eq!(plan.block_size, 16, "{}", hw.name);
            assert_eq!(plan.chunk_tokens, 16, "{}", hw.name);
        }
    }

    #[test]
    fn compute_bound_picks_fp32_coarse_blocks_long_chunks() {
        let (dims, arch, batch, ctx) = compute_bound_workload();
        for hw in &HardwareProfile::paper_profiles()[..2] {
            let plan = autotune(&dims, arch, hw, batch, ctx);
            assert!(!plan.memory_bound, "{}: {plan:?}", hw.name);
            assert!(plan.t_compute >= plan.t_memory, "{}: {plan:?}", hw.name);
            assert_eq!(plan.quant, QuantKind::Off, "{}", hw.name);
            assert_eq!(plan.block_size, 32, "{}", hw.name);
            assert_eq!(plan.chunk_tokens, 64, "{}", hw.name);
        }
    }

    #[test]
    fn fp8_is_never_auto_picked() {
        let dims = ModelDims::llama2_7b();
        for hw in &HardwareProfile::paper_profiles() {
            for arch in [ArchModel::Gqa, ArchModel::Mla { r: 448, low_rank_q: false }] {
                for batch in [1usize, 8, 64, 256] {
                    for ctx in [128usize, 2048, 16384] {
                        let plan = autotune(&dims, arch, hw, batch, ctx);
                        assert_ne!(plan.quant, QuantKind::Fp8);
                    }
                }
            }
        }
    }

    #[test]
    fn bandwidth_moves_the_split() {
        // Same workload, one profile with 100x the bandwidth: the step
        // flips from memory- to compute-bound and the plan follows.
        let (dims, arch, batch, ctx) = memory_bound_workload();
        let slow = HardwareProfile {
            name: "slow-hbm".into(),
            tflops: 312.0,
            mem_gb: 40.0,
            bw_gbs: 1555.0,
        };
        let fast = HardwareProfile { bw_gbs: 155_500.0, ..slow.clone() };
        assert!(autotune(&dims, arch, &slow, batch, ctx).memory_bound);
        let plan = autotune(&dims, arch, &fast, batch, ctx);
        assert!(!plan.memory_bound, "{plan:?}");
        assert_eq!(plan.quant, QuantKind::Off);
    }
}
