//! Dense symmetric linear algebra: cyclic Jacobi eigendecomposition and
//! PCA — the numerical core of the Rust-side TransMLA converter.
//!
//! Jacobi is chosen for its unconditional robustness on symmetric
//! matrices; the converter's largest problem is (2g-1)d = 480 for the
//! `llama2tiny` config, well within Jacobi's comfortable range.

use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Eigendecomposition of a symmetric matrix via cyclic Jacobi rotations.
/// Returns (eigenvalues desc, eigenvectors as columns, same order).
pub fn eigh_desc(a: &Tensor) -> Result<(Vec<f64>, Tensor)> {
    if a.rank() != 2 || a.rows() != a.cols() {
        bail!("eigh wants square matrix, got {:?}", a.shape);
    }
    let n = a.rows();
    // Work in f64 for a clean oracle-grade result.
    let mut m: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    let idx = |i: usize, j: usize| i * n + j;
    let off = |m: &[f64]| -> f64 {
        let mut s = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                s += m[idx(i, j)] * m[idx(i, j)];
            }
        }
        s
    };

    let scale: f64 = m.iter().map(|x| x * x).sum::<f64>().max(1e-300);
    let tol = 1e-24 * scale;
    for _sweep in 0..64 {
        if off(&m) <= tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[idx(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[idx(p, p)];
                let aqq = m[idx(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p and q.
                for k in 0..n {
                    let akp = m[idx(k, p)];
                    let akq = m[idx(k, q)];
                    m[idx(k, p)] = c * akp - s * akq;
                    m[idx(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = m[idx(p, k)];
                    let aqk = m[idx(q, k)];
                    m[idx(p, k)] = c * apk - s * aqk;
                    m[idx(q, k)] = s * apk + c * aqk;
                }
                for k in 0..n {
                    let vkp = v[idx(k, p)];
                    let vkq = v[idx(k, q)];
                    v[idx(k, p)] = c * vkp - s * vkq;
                    v[idx(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    let mut order: Vec<usize> = (0..n).collect();
    let eigs: Vec<f64> = (0..n).map(|i| m[idx(i, i)]).collect();
    order.sort_by(|&a, &b| eigs[b].partial_cmp(&eigs[a]).unwrap());

    let mut vecs = Tensor::zeros(&[n, n]);
    let mut vals = Vec::with_capacity(n);
    for (new_col, &old_col) in order.iter().enumerate() {
        vals.push(eigs[old_col]);
        for row in 0..n {
            vecs.set2(row, new_col, v[idx(row, old_col)] as f32);
        }
    }
    Ok((vals, vecs))
}

/// Covariance-style Gram matrix Z^T Z of samples [N, D] (f64 accumulate).
pub fn gram(z: &Tensor) -> Tensor {
    let (n, d) = (z.rows(), z.cols());
    let mut out = vec![0.0f64; d * d];
    for s in 0..n {
        let row = z.row(s);
        for i in 0..d {
            let zi = row[i] as f64;
            if zi == 0.0 {
                continue;
            }
            let o = &mut out[i * d..(i + 1) * d];
            for (j, &zj) in row.iter().enumerate() {
                o[j] += zi * zj as f64;
            }
        }
    }
    Tensor {
        shape: vec![d, d],
        data: out.into_iter().map(|x| x as f32).collect(),
    }
}

/// Sum of two Gram matrices (for the RoPE-invariant real+imag covariance).
pub fn gram_sum(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    a.add(b)
}

/// Top-r PCA basis of samples [N, D]: returns [D, r] with orthonormal
/// columns ordered by explained variance.
pub fn pca_basis(samples: &Tensor, r: usize) -> Result<Tensor> {
    let c = gram(samples);
    pca_from_gram(&c, r)
}

/// Top-r eigenvector basis from a precomputed Gram/covariance matrix.
pub fn pca_from_gram(c: &Tensor, r: usize) -> Result<Tensor> {
    let (_vals, vecs) = eigh_desc(c)?;
    let d = c.rows();
    let r = r.min(d);
    Ok(vecs.slice_cols(0, r))
}

/// Max |Q^T Q - I| — orthogonality defect used by tests/assertions.
pub fn orthogonality_defect(q: &Tensor) -> f32 {
    let qtq = q.t().matmul(q).unwrap();
    let n = qtq.rows();
    let mut worst = 0.0f32;
    for i in 0..n {
        for j in 0..n {
            let want = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((qtq.at2(i, j) - want).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_symmetric(n: usize, rng: &mut Rng) -> Tensor {
        let a = Tensor::randn(&[n, n], 1.0, rng);
        a.add(&a.t()).unwrap().scale(0.5)
    }

    #[test]
    fn eigh_reconstructs_matrix() {
        let mut rng = Rng::new(0);
        let a = random_symmetric(12, &mut rng);
        let (vals, vecs) = eigh_desc(&a).unwrap();
        // A == V diag(w) V^T
        let mut d = Tensor::zeros(&[12, 12]);
        for i in 0..12 {
            d.set2(i, i, vals[i] as f32);
        }
        let rec = vecs.matmul(&d).unwrap().matmul(&vecs.t()).unwrap();
        assert!(rec.max_abs_diff(&a) < 1e-4, "{}", rec.max_abs_diff(&a));
    }

    #[test]
    fn eigh_values_descending_and_orthonormal() {
        let mut rng = Rng::new(1);
        let a = random_symmetric(20, &mut rng);
        let (vals, vecs) = eigh_desc(&a).unwrap();
        for w in vals.windows(2) {
            assert!(w[0] >= w[1] - 1e-9);
        }
        assert!(orthogonality_defect(&vecs) < 1e-5);
    }

    #[test]
    fn eigh_diagonal_matrix() {
        let mut a = Tensor::zeros(&[3, 3]);
        a.set2(0, 0, 1.0);
        a.set2(1, 1, 5.0);
        a.set2(2, 2, 3.0);
        let (vals, _) = eigh_desc(&a).unwrap();
        assert!((vals[0] - 5.0).abs() < 1e-10);
        assert!((vals[1] - 3.0).abs() < 1e-10);
        assert!((vals[2] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn pca_recovers_dominant_direction() {
        // Samples along direction (3,4)/5 with tiny noise.
        let mut rng = Rng::new(2);
        let n = 500;
        let mut data = Vec::with_capacity(n * 2);
        for _ in 0..n {
            let t = rng.normal_f32(1.0);
            data.push(0.6 * t + rng.normal_f32(0.01));
            data.push(0.8 * t + rng.normal_f32(0.01));
        }
        let z = Tensor::new(&[n, 2], data).unwrap();
        let basis = pca_basis(&z, 1).unwrap();
        let dir = (basis.at2(0, 0).abs(), basis.at2(1, 0).abs());
        assert!((dir.0 - 0.6).abs() < 0.02, "{dir:?}");
        assert!((dir.1 - 0.8).abs() < 0.02, "{dir:?}");
    }

    #[test]
    fn pca_full_rank_is_orthogonal() {
        let mut rng = Rng::new(3);
        let z = Tensor::randn(&[64, 10], 1.0, &mut rng);
        let basis = pca_basis(&z, 10).unwrap();
        assert!(orthogonality_defect(&basis) < 1e-5);
    }

    #[test]
    fn gram_matches_naive() {
        let z = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let g = gram(&z);
        assert_eq!(g.data, vec![10.0, 14.0, 14.0, 20.0]);
    }
}
