//! `transmla` CLI — leader entrypoint for the whole pipeline:
//! train → convert → evaluate → serve → reproduce the paper's experiments.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use transmla::backend::{SimBackend, SimConfig};
use transmla::config::{
    CacheKind, EngineConfig, EvalOpts, HardwareProfile, ModelSpec, PolicyKind, SloSpec,
};
use transmla::convert::{self, Baseline, ConvertOptions, PcaMode};
use transmla::coordinator::engine::Arch;
use transmla::coordinator::{Engine, ModelBundle, Request};
use transmla::eval::experiments::{self, ExpContext};
use transmla::eval::{capture_calib, evaluate};
use transmla::json::Json;
use transmla::kvcache::QuantKind;
use transmla::model::{init_gqa, Params};
use transmla::perfmodel;
use transmla::runtime::Runtime;
use transmla::train::Trainer;
use transmla::{corpus::Corpus, qeval, server, workload};

const USAGE: &str = "\
transmla — GQA->MLA conversion + absorbed-MLA serving (TransMLA reproduction)

USAGE: transmla <command> [flags]

COMMANDS
  selfcheck                       load runtime + run one prefill end-to-end
  train      --steps N [--config C] [--out ckpt.tnz]
  convert    --ckpt ckpt.tnz --rank R [--fold M] [--baseline mha2mla]
             [--pca w|wx] [--no-balance] [--out mla.tnz]
  ppl        --arch gqa|mla --ckpt p.tnz [--rank R]
  generate   --arch gqa|mla --ckpt p.tnz [--rank R] --prompt TEXT [--max-new N]
  serve      --arch gqa|mla --ckpt p.tnz [--rank R] [--addr host:port]
             [--model name[=SPEC]]... [--route R] [--workers N]
             [--max-pending N]
             (multi-model serving; see MULTI-MODEL SERVING below)
  workload   [--arrivals poisson|bursty[:B]|ramp] [--rate R] [--duration S]
             [--seed N] [--agent-frac F] [--max-new N]
             [--slo-ttft-ms MS] [--slo-tpot-ms MS] [--label L]
             [--trace-out t.jsonl] [--report r.jsonl] [--html r.html]
             [--attach host:port]
             (open-loop traffic replay + SLO/goodput report; see
             WORKLOAD HARNESS below)
  eval       --data d.jsonl [--model name[=SPEC]]... [--baseline NAME]
             [--exact] [--contains] [--contains-i] [--levenshtein MIN]
             [--regex PATTERN] [--json] [--max-new N] [--concurrency N]
             [--label L] [--report r.jsonl] [--html r.html]
             [--attach host:port]
             (quality harness: score one dataset across hosted models;
             see QUALITY HARNESS below)
  exp        fig2a|fig2b|fig3a|fig3b|table1|table4|table5|all
             [--out runs] [--config C] [--pretrain N] [--ft N] [--eval-batches N]

COMMON FLAGS
  --artifacts DIR   artifact directory (default: artifacts)
  --config NAME     model config (default: llama2tiny)
  --backend xla|sim backend for generate/serve (default: xla; `sim` is the
                    hermetic deterministic backend — no artifacts needed)
  --policy P        scheduling policy: admit-first|decode-first|hybrid[:N]
                    |chunked[:N]|speculative[:K] (chunked = decode-
                    overlapped prefill, at most N prompt tokens per
                    engine iteration; speculative = draft-propose /
                    target-verify decode emitting up to K tokens per
                    slot per step — needs --backend sim and a draft)
  --prefill-chunk N shorthand for --policy chunked:N
  --batch N         decode slots (sim backend; default 8)
  --capacity N      sim cache capacity (default 256)
  --cache K         KV-cache store: fixed|paged (default fixed; paged needs
                    --backend sim — the XLA artifacts bake in the fixed pool)
  --block-size N    paged cache tokens per block (default 16)
  --cache-blocks N  paged pool size in blocks (default: the fixed pool's
                    worst-case byte budget, batch * ceil(capacity/block))
  --prefix-cache M  on|off (default off): cross-sequence prefix sharing over
                    the paged store — same-prefix prompts share cached
                    blocks copy-on-write; requires --cache paged
  --kv-quant Q      off|int8|fp8 (default off): lossy block codec for the
                    paged KV store — encoded blocks shrink bytes/token, so
                    the same --cache-blocks byte budget admits more
                    sequences; requires --cache paged. SPEC key: quant=int8
  --autotune        pick codec, block size, and prefill chunk from the
                    perfmodel roofline (llama2-7b scale on the first paper
                    hardware profile, at --batch/--capacity): memory-bound
                    -> paged int8 blocks + short chunks, compute-bound ->
                    fp32 blocks, coarser blocks, long chunks. Explicit
                    flags always win over the autotuned choice
  --overlap M       on|off (default off): inside one chunked-policy engine
                    iteration, run the prefill chunk and the decode batch
                    on two concurrent streams (needs --policy chunked and
                    a backend that supports overlap, i.e. sim); completions
                    stay bit-identical to the serial schedule
  --draft A         draft model for --policy speculative: gqa|mla[:R]
                    (sim backend only). Built with the target's batch,
                    capacity, and seed over a private fixed cache; at
                    temperature 0 completions stay bit-identical to
                    serial decode. Also a SPEC key: draft=mla:2

MULTI-MODEL SERVING (serve only)
  --model N[=SPEC]  register a named engine; SPEC is a comma-separated
                    key=value list overriding the flags above for this
                    engine (keys: arch/layout, rank, backend, policy,
                    prefill-chunk, cache, block-size, cache-blocks,
                    prefix-cache, batch, capacity, seed, ckpt, weight,
                    overlap, draft, quant), e.g.
                    --model gqa-base=layout=gqa \\
                    --model mla=layout=mla,cache=paged,policy=chunked:8
                    Repeatable; unspecified keys inherit the bare flags.
                    Without any --model, the bare flags become the
                    implicit `default` model (v1 invocations unchanged).
  --route R         routing for requests without a \"model\" field:
                    default:<name>|round-robin|least-loaded
                    (default: default:<first registered model>)
  --workers N       engine worker threads (default 0 = single-threaded
                    sweep on the serving thread). N >= 1 spawns
                    min(N, #models) workers, each owning a share of the
                    engines behind a channel mailbox; completions are
                    bit-identical to --workers 0
  weight=K          (SPEC key, default 1) fair-share weight: a weight-K
                    engine gets K step opportunities per sweep, in both
                    the single-threaded and worker modes
  --max-pending N   admission backpressure bound (default 0 = unbounded):
                    a generation request arriving while N requests are
                    already in flight is shed with an in-band
                    {\"error\":\"overloaded\",\"retry_after_ms\":...} reply
                    instead of queueing without bound (docs/PROTOCOL.md)

WORKLOAD HARNESS (workload only)
  Generates a seeded open-loop arrival trace (Poisson / bursty / diurnal
  ramp over a shared-prefix agent + long-tail chat tenant mix), replays
  it against a server over loopback TCP, and reports p50/p95/p99
  TTFT/TPOT plus goodput (SLO-met completions per wall second).
  By default it self-hosts: the serve flags above (--model/--route/
  --workers/--max-pending/--policy/--cache/...) configure an in-process
  server on --addr (default 127.0.0.1:7434) with --backend defaulting
  to `sim`, so a bare checkout reproduces every number hermetically.
  --attach H:P      replay against an already-running server instead
  --rate R          mean arrival rate, requests/s (default 32)
  --duration S      trace span, seconds (default 2)
  --agent-frac F    fraction of shared-prefix agent traffic (default 0.5)
  --slo-ttft-ms MS  TTFT bound for goodput (default 250; 0 disables)
  --slo-tpot-ms MS  TPOT bound for goodput (default 0 = disabled)
  --trace-out F     also write the generated trace as JSONL (byte-stable
                    per seed)
  --report F        append-free JSONL report row (comparison tables)
  --html F          static HTML comparison page over the same rows

QUALITY HARNESS (eval only)
  Scores one JSONL dataset ({\"id\": ..., \"input\": ..., \"expected\": ...}
  rows; id and expected optional) across every --model engine through
  protocol-v2 routing and reports a per-model x per-scorer matrix
  (pass-rate, mean score, n, errors) with latency percentiles. With
  --baseline NAME every other model's row carries quality + latency
  deltas against it — the GQA vs MLA A/B in one table. Self-hosts on
  --addr (default 127.0.0.1:7435) with --backend defaulting to `sim`,
  or scores a running server via --attach (model names from --model
  flags, or the server's own listing). Malformed dataset lines and
  missing/duplicate ids are reported in-band, never fatal.
  --exact           output equals expected, byte for byte
  --contains        output contains expected (--contains-i case-folds)
  --levenshtein M   normalized edit similarity >= M (graded in [0,1])
  --regex P         output matches P (anchors, classes, * + ?, |)
  --json            output parses as JSON
  --concurrency N   bounded in-flight requests (default 8)
  --report F        deterministic JSONL (one meta line + one line per
                    model); --html F renders the same matrix as HTML
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

struct Args {
    cmd: String,
    sub: Option<String>,
    flags: HashMap<String, String>,
    /// Every `--flag value` occurrence in command-line order, so
    /// repeatable flags (`--model`) keep all their values; `flags`
    /// holds the last occurrence for single-valued lookups.
    all_flags: Vec<(String, String)>,
}

fn parse_args() -> Result<Args> {
    let mut it = std::env::args().skip(1);
    let cmd = it.next().unwrap_or_else(|| "help".into());
    let mut sub = None;
    let mut flags = HashMap::new();
    let mut all_flags = Vec::new();
    let mut pending_key: Option<String> = None;
    let mut record = |flags: &mut HashMap<String, String>, k: String, v: String| {
        flags.insert(k.clone(), v.clone());
        all_flags.push((k, v));
    };
    for a in it {
        // A new `--flag` closes any pending key as a boolean, so bare
        // flags compose anywhere (`--exact --levenshtein 0.8`), not
        // just in final position. The tradeoff: a *value* that itself
        // starts with `--` must be passed as `--flag=value`.
        if let Some(stripped) = a.strip_prefix("--") {
            if let Some(k) = pending_key.take() {
                record(&mut flags, k, "true".into());
            }
            if let Some((k, v)) = stripped.split_once('=') {
                record(&mut flags, k.to_string(), v.to_string());
            } else {
                pending_key = Some(stripped.to_string());
            }
        } else if let Some(k) = pending_key.take() {
            record(&mut flags, k, a);
        } else if sub.is_none() {
            sub = Some(a);
        } else {
            bail!("unexpected argument `{a}`");
        }
    }
    if let Some(k) = pending_key {
        record(&mut flags, k, "true".into()); // boolean flag
    }
    drop(record);
    Ok(Args { cmd, sub, flags, all_flags })
}

impl Args {
    fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(String::as_str)
    }

    /// All values of a repeatable flag, in command-line order.
    fn get_all(&self, k: &str) -> Vec<&str> {
        self.all_flags
            .iter()
            .filter(|(key, _)| key == k)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    fn usize_flag(&self, k: &str, default: usize) -> usize {
        self.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn f64_flag(&self, k: &str, default: f64) -> Result<f64> {
        match self.get(k) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .ok()
                .filter(|x| x.is_finite())
                .with_context(|| format!("bad --{k} `{v}` (finite number)")),
        }
    }

    fn str_flag<'a>(&'a self, k: &str, default: &'a str) -> &'a str {
        self.get(k).unwrap_or(default)
    }

    fn has(&self, k: &str) -> bool {
        self.flags.contains_key(k)
    }
}

/// Flag lookup for one engine build: per-model SPEC overrides first
/// (last occurrence wins), then the top-level flags — so every `--model`
/// engine inherits any setting its SPEC leaves out from the bare flags,
/// and a legacy invocation is just the empty-override view.
struct FlagView<'a> {
    args: &'a Args,
    overrides: &'a [(String, String)],
}

impl<'a> FlagView<'a> {
    fn base(args: &'a Args) -> FlagView<'a> {
        FlagView { args, overrides: &[] }
    }

    fn get(&self, k: &str) -> Option<&str> {
        self.overrides
            .iter()
            .rev()
            .find(|(key, _)| key == k)
            .map(|(_, v)| v.as_str())
            .or_else(|| self.args.get(k))
    }

    /// Lookup under two spellings (`arch` vs the SPEC's `layout`),
    /// overrides before base flags for both.
    fn get_either(&self, k1: &str, k2: &str) -> Option<&str> {
        self.overrides
            .iter()
            .rev()
            .find(|(key, _)| key == k1 || key == k2)
            .map(|(_, v)| v.as_str())
            .or_else(|| self.args.get(k1))
            .or_else(|| self.args.get(k2))
    }

    fn usize_flag(&self, k: &str, default: usize) -> usize {
        self.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn str_flag<'b>(&'b self, k: &str, default: &'b str) -> &'b str {
        self.get(k).unwrap_or(default)
    }
}

fn run() -> Result<()> {
    let mut args = parse_args()?;
    if args.cmd == "help" || args.cmd == "--help" {
        print!("{USAGE}");
        return Ok(());
    }
    // `workload` and `eval` are the hermetic reproduction paths: unless
    // the operator asks for the artifact backend, self-hosted runs use
    // `sim`.
    if (args.cmd == "workload" || args.cmd == "eval") && !args.has("backend") {
        args.flags.insert("backend".to_string(), "sim".to_string());
    }
    let art_dir = PathBuf::from(args.str_flag("artifacts", "artifacts"));
    let cfg_name = args.str_flag("config", "llama2tiny").to_string();

    // The sim backend is hermetic: generate/serve must work on a bare
    // checkout, so the artifact runtime is only constructed on the paths
    // that execute compiled HLO.
    match args.cmd.as_str() {
        "generate" => cmd_generate(&art_dir, &cfg_name, &args),
        "serve" => cmd_serve(&art_dir, &cfg_name, &args),
        "workload" => cmd_workload(&art_dir, &cfg_name, &args),
        "eval" => cmd_eval(&art_dir, &cfg_name, &args),
        _ => {
            let rt = Runtime::new(&art_dir)?;
            match args.cmd.as_str() {
                "selfcheck" => selfcheck(&rt, &cfg_name),
                "train" => cmd_train(&rt, &cfg_name, &args),
                "convert" => cmd_convert(&rt, &cfg_name, &args),
                "ppl" => cmd_ppl(&rt, &cfg_name, &args),
                "exp" => cmd_exp(&rt, &cfg_name, &args),
                other => bail!("unknown command `{other}` (try `transmla help`)"),
            }
        }
    }
}

/// Engine settings from the common flags (or a `--model` SPEC view).
fn engine_cfg(args: &FlagView) -> Result<EngineConfig> {
    // --autotune: let the perfmodel roofline pick the knobs the operator
    // left unset. Runs the split at llama2-7b scale on the first paper
    // profile, with --batch/--capacity as the workload point; every
    // explicitly-passed flag below still wins over the plan.
    let plan = match args.get("autotune") {
        None | Some("off") | Some("false") => None,
        Some("true") | Some("on") | Some("1") => {
            let arch = match parse_arch(args)? {
                Arch::Gqa => perfmodel::ArchModel::Gqa,
                Arch::Mla { rank } => {
                    perfmodel::ArchModel::Mla { r: rank, low_rank_q: false }
                }
            };
            let dims = perfmodel::ModelDims::llama2_7b();
            let hw = &HardwareProfile::paper_profiles()[0];
            let batch = args.usize_flag("batch", 8);
            let ctx = args.usize_flag("capacity", 256);
            let plan = perfmodel::autotune::autotune(&dims, arch, hw, batch, ctx);
            eprintln!(
                "[autotune] {} bound on {} (t_compute {:.3e}s, t_memory {:.3e}s): \
                 quant={} block-size={} prefill-chunk={}",
                if plan.memory_bound { "memory" } else { "compute" },
                hw.name,
                plan.t_compute,
                plan.t_memory,
                plan.quant.name(),
                plan.block_size,
                plan.chunk_tokens,
            );
            Some(plan)
        }
        Some(other) => bail!("bad --autotune `{other}` (on|off)"),
    };
    let mut cache = match (args.get("cache"), &plan) {
        (Some(c), _) => CacheKind::parse(c)?,
        (None, Some(p)) => {
            CacheKind::Paged { block_size: p.block_size, n_blocks: None }
        }
        (None, None) => CacheKind::Fixed,
    };
    if let CacheKind::Paged { ref mut block_size, ref mut n_blocks } = cache {
        if let Some(b) = args.get("block-size") {
            *block_size = b
                .parse::<usize>()
                .ok()
                .filter(|&n| n > 0)
                .with_context(|| format!("bad --block-size `{b}`"))?;
        }
        if let Some(n) = args.get("cache-blocks") {
            *n_blocks = Some(
                n.parse::<usize>()
                    .ok()
                    .filter(|&n| n > 0)
                    .with_context(|| format!("bad --cache-blocks `{n}`"))?,
            );
        }
    }
    let prefix_cache = match args.str_flag("prefix-cache", "off") {
        "on" => true,
        "off" => false,
        other => bail!("bad --prefix-cache `{other}` (on|off)"),
    };
    if prefix_cache && cache == CacheKind::Fixed {
        bail!(
            "--prefix-cache on requires --cache paged (the fixed pool has \
             no blocks to share)"
        );
    }
    // --kv-quant flag / `quant=` SPEC key; an autotuned plan fills it
    // only when it also produced (or found) a paged store to encode.
    let kv_quant = match args.get_either("kv-quant", "quant") {
        Some(q) => QuantKind::parse(q)?,
        None => match (&plan, &cache) {
            (Some(p), CacheKind::Paged { .. }) => p.quant,
            _ => QuantKind::Off,
        },
    };
    if !kv_quant.is_off() && cache == CacheKind::Fixed {
        bail!(
            "--kv-quant {} requires --cache paged (the fixed pool stores \
             raw f32 rows)",
            kv_quant.name()
        );
    }
    let mut policy = match (args.get("policy"), &plan) {
        (Some(p), _) => PolicyKind::parse(p)?,
        (None, Some(pl)) => PolicyKind::Chunked { chunk_tokens: pl.chunk_tokens },
        (None, None) => PolicyKind::AdmitFirst,
    };
    if let Some(raw) = args.get("prefill-chunk") {
        let chunk = raw
            .parse::<usize>()
            .ok()
            .filter(|&c| c > 0)
            .with_context(|| format!("bad --prefill-chunk `{raw}`"))?;
        // Shorthand for --policy chunked:N; an explicit non-chunked
        // --policy is a conflict, not something to silently override.
        match (args.get("policy"), policy) {
            (None, _) | (Some(_), PolicyKind::Chunked { .. }) => {
                policy = PolicyKind::Chunked { chunk_tokens: chunk };
            }
            (Some(p), _) => bail!(
                "--prefill-chunk {chunk} conflicts with --policy {p} \
                 (chunked prefill needs --policy chunked)"
            ),
        }
    }
    let weight = match args.get("weight") {
        None => 1,
        Some(w) => w
            .parse::<usize>()
            .ok()
            .filter(|&k| k >= 1)
            .with_context(|| format!("bad weight `{w}` (integer >= 1)"))?,
    };
    let overlap = match args.str_flag("overlap", "off") {
        "on" => true,
        "off" => false,
        other => bail!("bad --overlap `{other}` (on|off)"),
    };
    if overlap && !matches!(policy, PolicyKind::Chunked { .. }) {
        bail!(
            "--overlap on requires --policy chunked (only the chunked \
             policy has a prefill stream to run beside the decode)"
        );
    }
    Ok(EngineConfig {
        policy,
        seed: args.usize_flag("seed", 0) as u64,
        cache,
        prefix_cache,
        weight,
        overlap,
        kv_quant,
        ..EngineConfig::default()
    })
}

/// Build an engine for generate/serve: hermetic sim or artifact-backed.
fn build_engine(art_dir: &Path, cfg_name: &str, args: &FlagView) -> Result<Engine> {
    let cfg = engine_cfg(args)?;
    match args.str_flag("backend", "xla") {
        "sim" => {
            let batch = args.usize_flag("batch", 8);
            let capacity = args.usize_flag("capacity", 256);
            let (seed, policy) = (cfg.seed, cfg.policy);
            let base = match parse_arch(args)? {
                Arch::Gqa => SimConfig::gqa(batch),
                Arch::Mla { rank } => SimConfig::mla(batch, rank),
            };
            let sim = SimBackend::new(SimConfig {
                capacity,
                prefill_seq: capacity,
                seed,
                ..base
            })?;
            let mut engine = Engine::try_new(sim, cfg)?;
            if let Some(d) = args.get("draft") {
                // Same batch/capacity/seed as the target: the draft
                // walks the same positions over a private fixed cache.
                let draft_base = match parse_draft_arch(d)? {
                    Arch::Gqa => SimConfig::gqa(batch),
                    Arch::Mla { rank } => SimConfig::mla(batch, rank),
                };
                let draft = SimBackend::new(SimConfig {
                    capacity,
                    prefill_seq: capacity,
                    seed,
                    ..draft_base
                })?;
                engine.set_draft(Box::new(draft))?;
            } else if matches!(policy, PolicyKind::Speculative { .. }) {
                bail!(
                    "--policy speculative requires a draft model \
                     (--draft gqa|mla[:R], or draft=... in the --model SPEC)"
                );
            }
            Ok(engine)
        }
        "xla" => {
            if cfg.cache != CacheKind::Fixed {
                bail!(
                    "--cache paged requires --backend sim: the AOT decode \
                     artifacts operate on the fixed padded cache"
                );
            }
            if matches!(cfg.policy, PolicyKind::Speculative { .. }) {
                bail!(
                    "--policy speculative requires --backend sim: the AOT \
                     decode artifacts score one position per slot per call \
                     and cannot batch-verify candidate chains"
                );
            }
            if args.get("draft").is_some() {
                bail!("--draft requires --backend sim");
            }
            let rt = Runtime::new(art_dir)?;
            let params = load_ckpt_or_init(&rt, cfg_name, args)?;
            let arch = parse_arch(args)?;
            let batch = args.usize_flag("batch", 8);
            let bundle = ModelBundle::load(&rt, cfg_name, arch, batch, params)?;
            Ok(Engine::with_bundle(bundle, cfg))
        }
        other => bail!("unknown backend `{other}` (xla|sim)"),
    }
}

fn selfcheck(rt: &Runtime, cfg_name: &str) -> Result<()> {
    println!("platform: {}", rt.platform());
    let cfg = rt.manifest.configs.get(cfg_name).context("config")?.clone();
    let params = init_gqa(&cfg, 0);
    let exec = rt.load(&format!("{cfg_name}_gqa_prefill"))?;
    let corpus = Corpus::synthetic(1, 100_000);
    let batches = corpus.val_batches(8, cfg.max_seq);
    let ev = evaluate(&exec, &params, &batches[..1])?;
    println!(
        "random-init GQA: loss {:.4} (ln V = {:.4}) ppl {:.1}",
        ev.loss,
        (cfg.vocab as f64).ln(),
        ev.ppl
    );
    if (ev.loss - (cfg.vocab as f64).ln()).abs() > 1.0 {
        bail!("random-init loss far from ln(V) — pipeline broken");
    }
    println!("selfcheck OK ({} artifacts)", rt.manifest.entries.len());
    Ok(())
}

fn cmd_train(rt: &Runtime, cfg_name: &str, args: &Args) -> Result<()> {
    let steps = args.usize_flag("steps", 200);
    let out = PathBuf::from(args.str_flag("out", "runs/base.tnz"));
    let cfg = rt.manifest.configs.get(cfg_name).context("config")?.clone();
    let corpus = Corpus::synthetic(7, 2_000_000);
    let exec = rt.load(&format!("{cfg_name}_gqa_train"))?;
    let mut tr = Trainer::new(exec, init_gqa(&cfg, 42))?;
    let rep = tr.run(&corpus, steps, 1e-3, 1, 10, "base")?;
    println!(
        "trained {} steps ({} tokens) in {:.1}s; loss {:.4} -> {:.4}",
        rep.steps,
        rep.tokens,
        rep.seconds,
        rep.losses.first().unwrap_or(&f32::NAN),
        rep.tail_loss(10)
    );
    let mut meta = Json::obj();
    meta.set("steps", Json::Num(rep.steps as f64));
    meta.set("final_loss", Json::Num(rep.tail_loss(10) as f64));
    tr.params.save(&out, meta)?;
    println!("saved {}", out.display());
    Ok(())
}

fn load_ckpt_or_init(rt: &Runtime, cfg_name: &str, args: &FlagView) -> Result<Params> {
    match args.get("ckpt") {
        Some(p) if Path::new(p).exists() => Params::load(Path::new(p)),
        Some(p) => bail!("checkpoint {p} not found"),
        None => {
            eprintln!("[warn] no --ckpt; using random init");
            let cfg = rt.manifest.configs.get(cfg_name).context("config")?;
            Ok(init_gqa(cfg, 42))
        }
    }
}

fn make_calib(rt: &Runtime, cfg_name: &str, params: &Params) -> Result<convert::Calib> {
    let cfg = rt.manifest.configs.get(cfg_name).context("config")?;
    let corpus = Corpus::synthetic(7, 500_000);
    let exec = rt.load(&format!("{cfg_name}_calib"))?;
    let b = exec.spec.batch.context("batch")?;
    let mut rng = transmla::util::Rng::new(1234);
    let toks = corpus.sample_batch(b, cfg.max_seq, &mut rng);
    capture_calib(&exec, params, &toks, 1024)
}

fn cmd_convert(rt: &Runtime, cfg_name: &str, args: &Args) -> Result<()> {
    let rank = args.usize_flag("rank", 32);
    let fold = args.usize_flag("fold", 1);
    let out = PathBuf::from(args.str_flag("out", "runs/mla.tnz"));
    let cfg = rt.manifest.configs.get(cfg_name).context("config")?.clone();
    let gqa = load_ckpt_or_init(rt, cfg_name, &FlagView::base(args))?;
    let calib = make_calib(rt, cfg_name, &gqa)?;
    let opts = ConvertOptions {
        rank,
        fold,
        balance: !args.has("no-balance"),
        pca_mode: if args.get("pca") == Some("w") {
            PcaMode::Weights
        } else {
            PcaMode::Activations
        },
        baseline: if args.get("baseline") == Some("mha2mla") {
            Baseline::Mha2Mla
        } else {
            Baseline::TransMla
        },
        keep_pairs_per_head: None,
    };
    let (train_p, absorbed, diag) = convert::convert_model(&gqa, &calib, &cfg, &opts)?;
    println!(
        "converted {} -> MLA r={rank} (-{:.2}% KV cache), alphas {:?}",
        cfg_name,
        cfg.compression(rank) * 100.0,
        diag.alphas
    );
    let mut meta = Json::obj();
    meta.set("rank", Json::Num(rank as f64));
    absorbed.save(&out, meta.clone())?;
    let train_out = out.with_extension("train.tnz");
    train_p.save(&train_out, meta)?;
    println!("saved {} and {}", out.display(), train_out.display());
    Ok(())
}

/// Parse a `--draft` / `draft=` value: `gqa`, `mla` (default rank 32),
/// or `mla:R`. Colon-separated so the value stays comma-free inside a
/// `--model` SPEC (which splits on commas).
fn parse_draft_arch(s: &str) -> Result<Arch> {
    match s {
        "gqa" => Ok(Arch::Gqa),
        "mla" => Ok(Arch::Mla { rank: 32 }),
        other => match other.strip_prefix("mla:") {
            Some(r) => Ok(Arch::Mla {
                rank: r
                    .parse::<usize>()
                    .ok()
                    .filter(|&n| n > 0)
                    .with_context(|| format!("bad draft rank `{r}`"))?,
            }),
            None => bail!("bad draft `{other}` (gqa|mla[:R])"),
        },
    }
}

fn parse_arch(args: &FlagView) -> Result<Arch> {
    // `layout` is the `--model` SPEC spelling of `--arch`.
    match args.get_either("arch", "layout").unwrap_or("gqa") {
        "gqa" => Ok(Arch::Gqa),
        "mla" => Ok(Arch::Mla { rank: args.usize_flag("rank", 32) }),
        other => bail!("unknown arch `{other}`"),
    }
}

fn cmd_ppl(rt: &Runtime, cfg_name: &str, args: &Args) -> Result<()> {
    let cfg = rt.manifest.configs.get(cfg_name).context("config")?.clone();
    let params = load_ckpt_or_init(rt, cfg_name, &FlagView::base(args))?;
    let corpus = Corpus::synthetic(7, 2_000_000);
    let batches: Vec<_> = corpus
        .val_batches(8, cfg.max_seq)
        .into_iter()
        .take(4)
        .collect();
    let name = match parse_arch(&FlagView::base(args))? {
        Arch::Gqa => format!("{cfg_name}_gqa_prefill"),
        Arch::Mla { rank } => format!("{cfg_name}_mla_prefill_r{rank}"),
    };
    let exec = rt.load(&name)?;
    let ev = evaluate(&exec, &params, &batches)?;
    println!("loss {:.4}  ppl {:.3}  top1 {:.4}", ev.loss, ev.ppl, ev.top1);
    Ok(())
}

fn cmd_generate(art_dir: &Path, cfg_name: &str, args: &Args) -> Result<()> {
    let mut engine = build_engine(art_dir, cfg_name, &FlagView::base(args))?;
    let prompt = args.str_flag("prompt", "the model ");
    let max_new = args.usize_flag("max-new", 64);
    let mut req = Request::from_text(0, prompt, max_new);
    req.temperature = args
        .get("temperature")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.0);
    let comps = engine.generate(vec![req])?;
    println!("{prompt}{}", comps[0].text());
    eprintln!(
        "[{:.1} tok/s decode | backend `{}` | policy `{}`]",
        engine.decode_throughput(),
        engine.spec().name,
        engine.policy_name()
    );
    Ok(())
}

/// `serve`: build the engine registry from repeatable `--model name=SPEC`
/// flags (each SPEC overrides the bare flags for that engine only), or —
/// with no `--model` at all — the legacy single-model invocation, whose
/// bare flags become the implicit `default` model. Requests without a
/// `model` field follow `--route` (default: the first registered model).
fn cmd_serve(art_dir: &Path, cfg_name: &str, args: &Args) -> Result<()> {
    let addr = args.str_flag("addr", "127.0.0.1:7433").to_string();
    let mut registry = build_registry(art_dir, cfg_name, args)?;
    server::serve_with(&mut registry, &addr, serve_opts(args)?)
}

/// The registry both `serve` and the self-hosting `workload` command
/// build: repeatable `--model name=SPEC` engines (first registered is
/// the default route), or the bare flags as the implicit single model.
fn build_registry(
    art_dir: &Path,
    cfg_name: &str,
    args: &Args,
) -> Result<server::EngineRegistry> {
    let model_flags = args.get_all("model");
    let mut registry = if model_flags.is_empty() {
        server::EngineRegistry::single(build_engine(
            art_dir,
            cfg_name,
            &FlagView::base(args),
        )?)
    } else {
        let specs = model_flags
            .iter()
            .map(|m| ModelSpec::parse(m))
            .collect::<Result<Vec<_>>>()?;
        let mut reg = server::EngineRegistry::new(server::RoutePolicy::Default(
            specs[0].name.clone(),
        ));
        for spec in &specs {
            let view = FlagView { args, overrides: &spec.overrides };
            reg.register(&spec.name, build_engine(art_dir, cfg_name, &view)?)?;
        }
        reg
    };
    if let Some(r) = args.get("route") {
        registry.set_route(server::RoutePolicy::parse(r)?);
    }
    Ok(registry)
}

/// `--workers` / `--max-pending` → [`server::ServeOpts`].
fn serve_opts(args: &Args) -> Result<server::ServeOpts> {
    let uint = |k: &str| -> Result<usize> {
        match args.get(k) {
            None => Ok(0),
            Some(v) => v
                .parse::<usize>()
                .ok()
                .with_context(|| format!("bad --{k} `{v}` (integer >= 0)")),
        }
    };
    Ok(server::ServeOpts { workers: uint("workers")?, max_pending: uint("max-pending")? })
}

/// `workload`: generate a seeded open-loop trace, replay it against a
/// live server — self-hosted over loopback by default (hermetic on the
/// sim backend), or an external one via `--attach` — and report
/// p50/p95/p99 TTFT/TPOT plus goodput under the `--slo-*` bounds.
fn cmd_workload(art_dir: &Path, cfg_name: &str, args: &Args) -> Result<()> {
    let spec = workload::TraceSpec {
        seed: args.usize_flag("seed", 0) as u64,
        arrivals: workload::ArrivalKind::parse(args.str_flag("arrivals", "poisson"))?,
        rate: args.f64_flag("rate", 32.0)?,
        duration_s: args.f64_flag("duration", 2.0)?,
        agent_frac: args.f64_flag("agent-frac", 0.5)?,
        max_new: args.usize_flag("max-new", 16),
        ..workload::TraceSpec::default()
    };
    let trace = workload::Trace::generate(&spec)?;
    if let Some(path) = args.get("trace-out") {
        std::fs::write(path, trace.to_jsonl())
            .with_context(|| format!("writing trace {path}"))?;
        eprintln!("[workload] wrote {} events to {path}", trace.events.len());
    }
    let slo = SloSpec {
        ttft_ms: Some(args.f64_flag("slo-ttft-ms", 250.0)?).filter(|&b| b > 0.0),
        tpot_ms: Some(args.f64_flag("slo-tpot-ms", 0.0)?).filter(|&b| b > 0.0),
    };

    let opts = serve_opts(args)?;
    let result = if let Some(attach) = args.get("attach") {
        eprintln!(
            "[workload] replaying {} events ({}) against {attach}",
            trace.events.len(),
            spec.arrivals.name()
        );
        workload::replay(&trace, attach)?
    } else {
        let addr = args.str_flag("addr", "127.0.0.1:7434").to_string();
        let mut registry = build_registry(art_dir, cfg_name, args)?;
        let server_addr = addr.clone();
        let handle = std::thread::spawn(move || {
            server::serve_with(&mut registry, &server_addr, opts)
        });
        wait_for_server(&addr)?;
        eprintln!(
            "[workload] replaying {} events ({}) against {addr} (self-hosted)",
            trace.events.len(),
            spec.arrivals.name()
        );
        let result = workload::replay(&trace, &addr);
        server::client_shutdown(&addr)?;
        handle
            .join()
            .map_err(|_| anyhow::anyhow!("server thread panicked"))??;
        result?
    };

    let tags = [
        ("arrivals", spec.arrivals.name()),
        ("cache", args.str_flag("cache", "fixed").to_string()),
        ("max_pending", opts.max_pending.to_string()),
        ("policy", args.str_flag("policy", "admit-first").to_string()),
        ("rate", format!("{}", spec.rate)),
    ];
    let row =
        workload::ReportRow::build(args.str_flag("label", "workload"), &tags, slo, &result);
    println!("{}", row.human());
    if let Some(path) = args.get("report") {
        std::fs::write(path, workload::to_jsonl(std::slice::from_ref(&row)))
            .with_context(|| format!("writing report {path}"))?;
        eprintln!("[workload] wrote report row to {path}");
    }
    if let Some(path) = args.get("html") {
        std::fs::write(
            path,
            workload::render_html("transmla workload report", std::slice::from_ref(&row)),
        )
        .with_context(|| format!("writing html {path}"))?;
        eprintln!("[workload] wrote html report to {path}");
    }
    Ok(())
}

/// `eval`: the quality harness — score one JSONL dataset across N
/// hosted models through protocol-v2 routing and report the per-model
/// × per-scorer matrix (see `qeval`). Self-hosts a registry over
/// loopback by default (hermetic on the sim backend, the same
/// `build_registry`/`serve_opts` path as `serve` and `workload`), or
/// scores an already-running server via `--attach`.
fn cmd_eval(art_dir: &Path, cfg_name: &str, args: &Args) -> Result<()> {
    let data = args.get("data").context("--data <dataset.jsonl> is required")?;
    let ds = qeval::Dataset::load(Path::new(data))?;
    for (line, msg) in &ds.errors {
        eprintln!("[eval] {data}:{line}: {msg}");
    }
    if ds.rows.is_empty() {
        bail!("dataset {data} has no usable rows ({} malformed)", ds.errors.len());
    }
    let scorers = qeval::scorers::from_flags(&args.all_flags)?;
    if scorers.is_empty() {
        bail!(
            "no scorers selected (pass --exact, --contains, --contains-i, \
             --levenshtein MIN, --regex PATTERN, and/or --json)"
        );
    }
    let opts = EvalOpts {
        concurrency: args.usize_flag("concurrency", 8),
        max_new: args.usize_flag("max-new", 16),
        baseline: args.get("baseline").map(str::to_string),
    };
    // Model names come from the `--model` SPECs; in `--attach` mode
    // with none given, from the server's own listing.
    let mut model_names: Vec<String> = args
        .get_all("model")
        .iter()
        .map(|m| ModelSpec::parse(m).map(|s| s.name))
        .collect::<Result<Vec<_>>>()?;
    let run = if let Some(attach) = args.get("attach") {
        if model_names.is_empty() {
            if let Some(arr) = server::client_models(attach)?.get("models").and_then(Json::as_arr)
            {
                model_names = arr
                    .iter()
                    .filter_map(|m| m.get("name").and_then(Json::as_str).map(str::to_string))
                    .collect();
            }
        }
        if model_names.is_empty() {
            bail!("no models to evaluate at {attach}");
        }
        eprintln!(
            "[eval] scoring {} rows x {} models against {attach}",
            ds.rows.len(),
            model_names.len()
        );
        qeval::run_eval(&ds, &model_names, attach, &opts)?
    } else {
        if model_names.is_empty() {
            model_names.push("default".to_string());
        }
        let addr = args.str_flag("addr", "127.0.0.1:7435").to_string();
        let mut registry = build_registry(art_dir, cfg_name, args)?;
        let sopts = serve_opts(args)?;
        let server_addr = addr.clone();
        let handle = std::thread::spawn(move || {
            server::serve_with(&mut registry, &server_addr, sopts)
        });
        wait_for_server(&addr)?;
        eprintln!(
            "[eval] scoring {} rows x {} models against {addr} (self-hosted)",
            ds.rows.len(),
            model_names.len()
        );
        let run = qeval::run_eval(&ds, &model_names, &addr, &opts);
        server::client_shutdown(&addr)?;
        handle
            .join()
            .map_err(|_| anyhow::anyhow!("server thread panicked"))??;
        run?
    };
    let report = qeval::EvalReport::build(
        args.str_flag("label", "eval"),
        &ds,
        &scorers,
        &run,
        opts.baseline.as_deref(),
    )?;
    println!("{}", report.human());
    if let Some(path) = args.get("report") {
        std::fs::write(path, report.to_jsonl())
            .with_context(|| format!("writing report {path}"))?;
        eprintln!("[eval] wrote report to {path}");
    }
    if let Some(path) = args.get("html") {
        std::fs::write(path, report.render_html("transmla eval report"))
            .with_context(|| format!("writing html {path}"))?;
        eprintln!("[eval] wrote html report to {path}");
    }
    Ok(())
}

/// Poll a freshly-spawned server until its stats endpoint answers.
fn wait_for_server(addr: &str) -> Result<()> {
    for _ in 0..200 {
        if server::client_stats(addr).is_ok() {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    bail!("server at {addr} did not come up within 2s")
}

fn cmd_exp(rt: &Runtime, cfg_name: &str, args: &Args) -> Result<()> {
    let which = args.sub.clone().unwrap_or_else(|| "all".into());
    let out_dir = PathBuf::from(args.str_flag("out", "runs"));
    let pretrain = args.usize_flag("pretrain", 150);
    let ft = args.usize_flag("ft", 40);
    let n_eval = args.usize_flag("eval-batches", 2);
    let ckpt = out_dir.join(format!("{cfg_name}_base.tnz"));
    let ctx = ExpContext::prepare(
        rt, cfg_name, Some(&ckpt), pretrain, ft, &out_dir, n_eval,
    )?;

    let run = |name: &str, ctx: &ExpContext| -> Result<()> {
        let j = match name {
            "fig2a" => experiments::fig2a(ctx)?,
            "fig2b" => experiments::fig2b(ctx)?,
            "fig3a" => experiments::fig3a(ctx)?,
            "fig3b" => experiments::fig3b(ctx)?,
            "table1" => experiments::table1(ctx)?,
            "table4" | "fig4" => experiments::table4(ctx, &[128, 256, 512])?,
            "table5" => experiments::table5(ctx)?,
            other => bail!("unknown experiment `{other}`"),
        };
        ctx.save_json(name, &j)
    };

    if which == "all" {
        for name in ["fig2a", "fig2b", "fig3a", "fig3b", "table1", "table4", "table5"] {
            run(name, &ctx)?;
        }
    } else {
        run(&which, &ctx)?;
    }
    Ok(())
}
