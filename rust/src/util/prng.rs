//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core, plus
//! normal/uniform/categorical sampling helpers.

/// xoshiro256** with SplitMix64 seeding. Deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        let s = [
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-worker / per-layer use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize % n
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self, std: f32) -> f32 {
        (self.normal() as f32) * std
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w.max(0.0) as f64).sum();
        if total <= 0.0 {
            return 0;
        }
        let mut u = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w.max(0.0) as f64;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(11);
        let w = [1.0f32, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..8_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.5, "ratio={ratio}");
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn forked_streams_differ() {
        let mut r = Rng::new(1);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
