//! Timing + statistics helpers shared by the engine metrics and the
//! in-repo benchmark harness (`rust/benches/harness.rs`).

use std::time::Instant;

pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

/// Summary statistics over a set of timing samples (seconds).
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub samples: Vec<f64>,
}

impl BenchStats {
    pub fn new(mut samples: Vec<f64>) -> Self {
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        BenchStats { samples }
    }

    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len().max(1) as f64
    }

    pub fn std(&self) -> f64 {
        let m = self.mean();
        let v = self
            .samples
            .iter()
            .map(|x| (x - m) * (x - m))
            .sum::<f64>()
            / self.samples.len().max(1) as f64;
        v.sqrt()
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let idx = ((self.samples.len() - 1) as f64 * p / 100.0).round() as usize;
        self.samples[idx]
    }

    pub fn min(&self) -> f64 {
        self.samples.first().copied().unwrap_or(f64::NAN)
    }

    pub fn max(&self) -> f64 {
        self.samples.last().copied().unwrap_or(f64::NAN)
    }
}

/// Time `f` with warmup; returns stats over `iters` runs.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        samples.push(t.elapsed_s());
    }
    BenchStats::new(samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = BenchStats::new(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 3.0);
        assert!((s.mean() - 2.0).abs() < 1e-12);
        assert_eq!(s.percentile(50.0), 2.0);
    }

    #[test]
    fn bench_runs() {
        let mut n = 0;
        let st = bench(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(st.samples.len(), 5);
    }
}
