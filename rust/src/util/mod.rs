//! Substrate utilities: deterministic PRNG, timing/statistics, and a
//! miniature property-testing framework.
//!
//! The build image is fully offline and its vendor set contains only the
//! `xla` and `anyhow` crates, so `rand`, `criterion` and `proptest` are
//! re-implemented here at the scale this project needs (see DESIGN.md's
//! substitution table).

pub mod prng;
pub mod prop;
pub mod timing;

pub use prng::Rng;
pub use timing::{BenchStats, Timer};
