//! Miniature property-testing framework (offline stand-in for `proptest`).
//!
//! Runs a property over `cases` randomly generated inputs; on failure it
//! performs greedy input shrinking via the user-provided `shrink` hook and
//! reports the minimal reproducing seed.

use super::prng::Rng;

pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xC0FFEE }
    }
}

/// Check `prop(gen(rng))` over many random cases. Panics (with the failing
/// seed and case index) on the first violated property.
pub fn check<T, G, P>(name: &str, cfg: PropConfig, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut case_rng = rng.fork(case as u64);
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed at case {case} (seed {}):\n  {msg}\n  input: {input:?}",
                cfg.seed
            );
        }
    }
}

/// Check with shrinking: on failure, repeatedly try `shrink(input)`
/// candidates that still fail, reporting the smallest found.
pub fn check_shrink<T, G, P, S>(
    name: &str,
    cfg: PropConfig,
    mut gen: G,
    mut prop: P,
    mut shrink: S,
) where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    S: FnMut(&T) -> Vec<T>,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut case_rng = rng.fork(case as u64);
        let input = gen(&mut case_rng);
        if let Err(first_msg) = prop(&input) {
            // Greedy shrink loop.
            let mut best = input.clone();
            let mut best_msg = first_msg;
            let mut improved = true;
            let mut budget = 200usize;
            while improved && budget > 0 {
                improved = false;
                for cand in shrink(&best) {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        improved = true;
                        break;
                    }
                    if budget == 0 {
                        break;
                    }
                }
            }
            panic!(
                "property `{name}` failed at case {case} (seed {}):\n  {best_msg}\n  minimal input: {best:?}",
                cfg.seed
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            "add_commutes",
            PropConfig::default(),
            |r| (r.below(1000) as i64, r.below(1000) as i64),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property `always_small`")]
    fn failing_property_panics_with_name() {
        check(
            "always_small",
            PropConfig { cases: 256, seed: 1 },
            |r| r.below(100),
            |&x| if x < 50 { Ok(()) } else { Err(format!("{x} >= 50")) },
        );
    }

    #[test]
    #[should_panic(expected = "minimal input: 50")]
    fn shrinking_finds_boundary() {
        check_shrink(
            "shrinks_to_50",
            PropConfig { cases: 64, seed: 2 },
            |r| r.below(1000),
            |&x| if x < 50 { Ok(()) } else { Err(format!("{x}")) },
            |&x| if x > 0 { vec![x / 2, x - 1] } else { vec![] },
        );
    }
}
