//! KV-cache management: the fixed slot-based cache pool shared by the
//! continuous batcher, and the paged block-granular pool ([`paged`]),
//! with layout-aware byte accounting for GQA vs MLA-latent caches.
//!
//! The decode artifacts operate on fixed-shape padded caches
//! (`[L, B, T, ...]`); a **slot** is one batch row. The manager owns the
//! host-side backing tensors, splices prefill output into slots, and
//! enforces the allocation invariants that the property tests target
//! (no double-allocation, no leaks, byte accounting exact).
//!
//! [`paged`] replaces the worst-case per-slot row reservation with
//! ref-counted fixed-size blocks over one shared pool, so a short prompt
//! only holds the blocks it actually writes. [`prefix`] adds the
//! cross-sequence layer on top: a block-granular prefix index so
//! same-prefix sequences share cached blocks (copy-on-write protected),
//! with prompt blocks outliving their sequence until memory pressure
//! evicts them. [`quant`] adds lossy per-row block codecs (int8, fp8)
//! so the paged pool can hold 2.4-3.2x more blocks at the same byte
//! budget — the paper's "FP8 is the next multiplier" direction.

pub mod paged;
pub mod prefix;
pub mod quant;

use crate::tensor::Tensor;
use anyhow::{bail, Result};

pub use paged::{BlockAllocator, PagedKvCache};
pub use prefix::{PrefixIndex, PrefixStats};
pub use quant::QuantKind;

/// Cache layout per architecture.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheLayout {
    /// Keys + values, per group: [L,B,T,g,d] x2.
    Gqa { g: usize, d: usize },
    /// Latent + shared RoPE key: [L,B,T,r] + [L,B,T,dr].
    Mla { r: usize, dr: usize },
}

impl CacheLayout {
    /// f32 elements cached per token per layer.
    pub fn per_token_per_layer(&self) -> usize {
        match *self {
            CacheLayout::Gqa { g, d } => 2 * g * d,
            CacheLayout::Mla { r, dr } => r + dr,
        }
    }

    /// Inner (per-token, per-layer) widths of the two backing buffers:
    /// GQA -> (k, v) = (g*d, g*d); MLA -> (latent, rope-key) = (r, dr).
    pub fn inner_dims(&self) -> (usize, usize) {
        match *self {
            CacheLayout::Gqa { g, d } => (g * d, g * d),
            CacheLayout::Mla { r, dr } => (r, dr),
        }
    }
}

/// The slot-based cache pool.
pub struct KvCache {
    pub layout: CacheLayout,
    pub n_layers: usize,
    pub batch: usize,
    pub capacity: usize, // T
    /// Backing tensors: GQA -> [k, v]; MLA -> [c, kr]. Shapes [L,B,T,...].
    pub bufs: Vec<Tensor>,
}

impl KvCache {
    pub fn new(layout: CacheLayout, n_layers: usize, batch: usize, capacity: usize) -> Self {
        let bufs = match layout {
            CacheLayout::Gqa { g, d } => vec![
                Tensor::zeros(&[n_layers, batch, capacity, g, d]),
                Tensor::zeros(&[n_layers, batch, capacity, g, d]),
            ],
            CacheLayout::Mla { r, dr } => vec![
                Tensor::zeros(&[n_layers, batch, capacity, r]),
                Tensor::zeros(&[n_layers, batch, capacity, dr]),
            ],
        };
        KvCache { layout, n_layers, batch, capacity, bufs }
    }

    pub fn bytes_total(&self) -> usize {
        self.bufs.iter().map(|b| b.len() * 4).sum()
    }

    pub fn bytes_per_token(&self) -> usize {
        self.layout.per_token_per_layer() * self.n_layers * 4
    }

    /// Splice prefill output (same layout, batch Bp) row `src` into slot
    /// `dst`, all layers. Tensors are [L, B, T, inner...].
    pub fn splice_from(&mut self, prefill_bufs: &[Tensor], src: usize, dst: usize) -> Result<()> {
        if prefill_bufs.len() != self.bufs.len() {
            bail!("layout mismatch");
        }
        for (mine, theirs) in self.bufs.iter_mut().zip(prefill_bufs) {
            let (l_mine, b_mine) = (mine.shape[0], mine.shape[1]);
            if theirs.shape.len() < 3 || theirs.shape[0] != l_mine {
                bail!(
                    "cache layer count mismatch {:?} vs {:?}",
                    mine.shape, theirs.shape
                );
            }
            let b_theirs = theirs.shape[1];
            let t_theirs = theirs.shape[2];
            let row_mine: usize = mine.shape[3..].iter().product::<usize>();
            let row_theirs: usize = theirs.shape[3..].iter().product::<usize>();
            if row_mine != row_theirs {
                bail!(
                    "cache inner shape mismatch {:?} vs {:?}",
                    mine.shape, theirs.shape
                );
            }
            if dst >= b_mine || src >= b_theirs {
                bail!("slot out of range");
            }
            let t_copy = self.capacity.min(t_theirs);
            for l in 0..l_mine {
                let off_m = ((l * b_mine) + dst) * self.capacity * row_mine;
                let off_t = ((l * b_theirs) + src) * t_theirs * row_theirs;
                let n = t_copy * row_mine;
                mine.data[off_m..off_m + n]
                    .copy_from_slice(&theirs.data[off_t..off_t + n]);
            }
        }
        Ok(())
    }

    /// Zero one slot (hygiene; correctness comes from position masking).
    /// Bounds-checked: an out-of-range slot returns the same "slot out of
    /// range" error as `splice_from` instead of panicking.
    pub fn clear_slot(&mut self, slot: usize) -> Result<()> {
        if slot >= self.batch {
            bail!("slot out of range: {slot} >= batch {}", self.batch);
        }
        for buf in &mut self.bufs {
            let b = buf.shape[1];
            let row: usize = buf.shape[2..].iter().product();
            let l_count = buf.shape[0];
            for l in 0..l_count {
                let off = (l * b + slot) * row;
                buf.data[off..off + row].iter_mut().for_each(|x| *x = 0.0);
            }
        }
        Ok(())
    }
}

/// Slot allocator with leak/double-free checking.
#[derive(Debug)]
pub struct SlotAllocator {
    owner: Vec<Option<u64>>, // request id per slot
    free: Vec<usize>,
}

impl SlotAllocator {
    pub fn new(n: usize) -> Self {
        SlotAllocator { owner: vec![None; n], free: (0..n).rev().collect() }
    }

    pub fn capacity(&self) -> usize {
        self.owner.len()
    }

    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    pub fn n_active(&self) -> usize {
        self.capacity() - self.n_free()
    }

    pub fn alloc(&mut self, req_id: u64) -> Option<usize> {
        let slot = self.free.pop()?;
        debug_assert!(self.owner[slot].is_none());
        self.owner[slot] = Some(req_id);
        Some(slot)
    }

    pub fn release(&mut self, slot: usize) -> Result<u64> {
        match self.owner.get_mut(slot) {
            Some(o @ Some(_)) => {
                let id = o.take().unwrap();
                self.free.push(slot);
                Ok(id)
            }
            Some(None) => bail!("double free of slot {slot}"),
            None => bail!("slot {slot} out of range"),
        }
    }

    pub fn owner_of(&self, slot: usize) -> Option<u64> {
        self.owner.get(slot).copied().flatten()
    }

    pub fn active_slots(&self) -> Vec<usize> {
        (0..self.capacity())
            .filter(|&s| self.owner[s].is_some())
            .collect()
    }

    /// Internal consistency: free list and owner map agree, no duplicates.
    pub fn check_invariants(&self) -> Result<()> {
        let mut seen = vec![false; self.capacity()];
        for &s in &self.free {
            if s >= self.capacity() {
                bail!("free slot {s} out of range");
            }
            if seen[s] {
                bail!("slot {s} twice in free list");
            }
            seen[s] = true;
            if self.owner[s].is_some() {
                bail!("slot {s} both free and owned");
            }
        }
        for s in 0..self.capacity() {
            if self.owner[s].is_none() && !seen[s] {
                bail!("slot {s} leaked (neither free nor owned)");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, PropConfig};
    use crate::util::Rng;

    #[test]
    fn layout_accounting() {
        let gqa = CacheLayout::Gqa { g: 8, d: 32 };
        let mla = CacheLayout::Mla { r: 4, dr: 32 };
        assert_eq!(gqa.per_token_per_layer(), 512);
        assert_eq!(mla.per_token_per_layer(), 36);
        // the paper's -92.97% row
        let ratio: f64 = 1.0 - 36.0 / 512.0;
        assert!((ratio - 0.9297).abs() < 1e-3);
    }

    #[test]
    fn cache_bytes() {
        let c = KvCache::new(CacheLayout::Mla { r: 32, dr: 32 }, 4, 8, 512);
        assert_eq!(c.bytes_per_token(), (32 + 32) * 4 * 4);
        assert_eq!(c.bytes_total(), 2 * 4 * 8 * 512 * 32 * 4);
    }

    #[test]
    fn splice_moves_the_right_row() {
        let mut c = KvCache::new(CacheLayout::Mla { r: 2, dr: 2 }, 1, 2, 4);
        let mut src_c = Tensor::zeros(&[1, 3, 4, 2]);
        let src_kr = Tensor::zeros(&[1, 3, 4, 2]);
        // mark row 1 of the prefill output
        for t in 0..4 {
            for x in 0..2 {
                src_c.data[(4 + t) * 2 + x] = (t * 10 + x) as f32;
            }
        }
        c.splice_from(&[src_c, src_kr], 1, 0).unwrap();
        // slot 0 of the pool now holds that row
        assert_eq!(c.bufs[0].data[0..2], [0.0, 1.0]);
        assert_eq!(c.bufs[0].data[6..8], [30.0, 31.0]);
        // slot 1 untouched
        assert!(c.bufs[0].data[8..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn splice_rejects_layer_count_mismatch() {
        // Regression: a prefill buffer with fewer layers used to panic
        // out-of-bounds in the copy loop instead of returning Err.
        let mut c = KvCache::new(CacheLayout::Mla { r: 2, dr: 2 }, 2, 2, 4);
        let short_c = Tensor::zeros(&[1, 2, 4, 2]);
        let short_kr = Tensor::zeros(&[1, 2, 4, 2]);
        let err = c.splice_from(&[short_c, short_kr], 0, 0).unwrap_err();
        assert!(err.to_string().contains("layer count"), "{err}");
    }

    #[test]
    fn clear_slot_out_of_range_is_an_error_not_a_panic() {
        let mut c = KvCache::new(CacheLayout::Mla { r: 2, dr: 2 }, 1, 2, 4);
        let err = c.clear_slot(2).unwrap_err();
        assert!(err.to_string().contains("slot out of range"), "{err}");
        c.clear_slot(1).unwrap();
    }

    #[test]
    fn clear_slot_zeroes_only_that_slot() {
        let mut c = KvCache::new(CacheLayout::Gqa { g: 1, d: 2 }, 2, 2, 3);
        for b in &mut c.bufs {
            b.data.iter_mut().for_each(|x| *x = 1.0);
        }
        c.clear_slot(0).unwrap();
        let row = 3 * 1 * 2;
        for buf in &c.bufs {
            for l in 0..2 {
                let s0 = (l * 2) * row;
                let s1 = (l * 2 + 1) * row;
                assert!(buf.data[s0..s0 + row].iter().all(|&x| x == 0.0));
                assert!(buf.data[s1..s1 + row].iter().all(|&x| x == 1.0));
            }
        }
    }

    #[test]
    fn alloc_release_cycle() {
        let mut a = SlotAllocator::new(3);
        let s1 = a.alloc(10).unwrap();
        let s2 = a.alloc(11).unwrap();
        assert_ne!(s1, s2);
        assert_eq!(a.n_active(), 2);
        assert_eq!(a.release(s1).unwrap(), 10);
        assert!(a.release(s1).is_err(), "double free must fail");
        a.check_invariants().unwrap();
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut a = SlotAllocator::new(2);
        assert!(a.alloc(1).is_some());
        assert!(a.alloc(2).is_some());
        assert!(a.alloc(3).is_none());
    }

    #[test]
    fn props_allocator_invariants_under_random_workload() {
        check(
            "slot_allocator_invariants",
            PropConfig { cases: 200, seed: 99 },
            |r: &mut Rng| {
                let n = 1 + r.below(8);
                let ops: Vec<u8> = (0..64).map(|_| r.next_u64() as u8).collect();
                (n, ops)
            },
            |(n, ops)| {
                let mut a = SlotAllocator::new(*n);
                let mut live: Vec<usize> = vec![];
                let mut next_id = 0u64;
                for &op in ops {
                    if op % 2 == 0 {
                        if let Some(s) = a.alloc(next_id) {
                            if live.contains(&s) {
                                return Err(format!("slot {s} double-allocated"));
                            }
                            live.push(s);
                            next_id += 1;
                        } else if live.len() != *n {
                            return Err("alloc failed below capacity".into());
                        }
                    } else if !live.is_empty() {
                        let s = live.remove((op as usize / 2) % live.len());
                        a.release(s).map_err(|e| e.to_string())?;
                    }
                    a.check_invariants().map_err(|e| e.to_string())?;
                    if a.n_active() != live.len() {
                        return Err("active count mismatch".into());
                    }
                }
                Ok(())
            },
        );
    }
}
