//! Paged block-granular KV cache: vLLM-style block tables over one
//! shared ref-counted pool, replacing the fixed pool's worst-case
//! per-slot row reservation.
//!
//! The fixed [`super::KvCache`] reserves a full `capacity`-length row per
//! slot, so a 16-token prompt costs as much memory as an 8K one and
//! concurrency is bounded by the worst case. Here the unit of allocation
//! is a **block** of `block_size` tokens:
//!
//!   * [`BlockAllocator`] owns the ref-counted free list (ref counts so
//!     future prefix-sharing / copy-on-write can alias blocks across
//!     sequences) with the same leak/double-free invariant checking as
//!     `SlotAllocator::check_invariants`;
//!   * [`PagedKvCache`] holds one backing tensor pair shaped
//!     `[n_blocks, L, block_size, inner]` (layout-aware: GQA k/v or MLA
//!     latent/rope-key) plus a per-slot **block table** mapping token
//!     position -> (block, offset).
//!
//! Admission *reserves* the sequence's bounded demand (prompt plus its
//! clamped `max_new`, not the cache capacity) so lazy per-step `grow`
//! can never fail mid-decode, and the scheduler can admit on blocks-free
//! rather than slots-free.
//!
//! With the optional **prefix cache** enabled
//! ([`PagedKvCache::enable_prefix_cache`]), a [`super::PrefixIndex`] maps
//! token-prefix hashes at block granularity to filled block chains:
//! admission maps the longest cached prefix into the new sequence's table
//! via `retain` and reserves only the unshared remainder, indexed prompt
//! blocks outlive their sequence (LRU-evicted under pressure), and any
//! write to a block other holders still reference triggers copy-on-write
//! in [`PagedKvCache::row_mut`] — a reader's bytes can never change
//! underneath it.

use super::{CacheLayout, PrefixIndex, PrefixStats};
use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Ref-counted fixed-size block allocator with a free list.
#[derive(Debug)]
pub struct BlockAllocator {
    refcount: Vec<u32>,
    free: Vec<usize>,
}

impl BlockAllocator {
    pub fn new(n_blocks: usize) -> Self {
        BlockAllocator {
            refcount: vec![0; n_blocks],
            free: (0..n_blocks).rev().collect(),
        }
    }

    pub fn n_blocks(&self) -> usize {
        self.refcount.len()
    }

    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    pub fn n_in_use(&self) -> usize {
        self.n_blocks() - self.n_free()
    }

    /// Take a free block (refcount 1), or None when the pool is empty.
    pub fn alloc(&mut self) -> Option<usize> {
        let b = self.free.pop()?;
        debug_assert_eq!(self.refcount[b], 0);
        self.refcount[b] = 1;
        Some(b)
    }

    /// Bump the refcount of an allocated block (prefix sharing / CoW).
    pub fn retain(&mut self, block: usize) -> Result<()> {
        match self.refcount.get_mut(block) {
            Some(rc) if *rc > 0 => {
                *rc += 1;
                Ok(())
            }
            Some(_) => bail!("retain of free block {block}"),
            None => bail!("block {block} out of range"),
        }
    }

    /// Drop one reference; returns true when the block went back to the
    /// free list. Releasing a free block is a double free and errors.
    pub fn release(&mut self, block: usize) -> Result<bool> {
        match self.refcount.get_mut(block) {
            Some(rc) if *rc > 0 => {
                *rc -= 1;
                if *rc == 0 {
                    self.free.push(block);
                    Ok(true)
                } else {
                    Ok(false)
                }
            }
            Some(_) => bail!("double free of block {block}"),
            None => bail!("block {block} out of range"),
        }
    }

    pub fn refcount_of(&self, block: usize) -> u32 {
        self.refcount.get(block).copied().unwrap_or(0)
    }

    /// Internal consistency: free list and refcounts agree, no
    /// duplicates, no leaks (mirrors `SlotAllocator::check_invariants`).
    pub fn check_invariants(&self) -> Result<()> {
        let mut on_free = vec![false; self.n_blocks()];
        for &b in &self.free {
            if b >= self.n_blocks() {
                bail!("free block {b} out of range");
            }
            if on_free[b] {
                bail!("block {b} twice in free list");
            }
            on_free[b] = true;
            if self.refcount[b] != 0 {
                bail!("block {b} both free and referenced");
            }
        }
        for (b, &on) in on_free.iter().enumerate() {
            if self.refcount[b] == 0 && !on {
                bail!("block {b} leaked (zero refs, not in free list)");
            }
        }
        Ok(())
    }
}

/// The paged cache pool: per-sequence block tables over shared blocks.
///
/// The admit → grow → release lifecycle:
///
/// ```
/// use transmla::kvcache::{CacheLayout, PagedKvCache};
///
/// // 2 slots over 8 four-token blocks of MLA-latent cache.
/// let mut c = PagedKvCache::new(CacheLayout::Mla { r: 4, dr: 4 }, 1, 2, 4, 8).unwrap();
/// // Admission reserves the sequence's bounded demand (10 tokens = 3
/// // blocks) and materialises the 5-token prompt (2 blocks).
/// c.admit_slot(0, 10, 5).unwrap();
/// assert_eq!((c.blocks_in_use(), c.blocks_reserved()), (2, 1));
/// // Decode growth draws on the reservation, so it cannot fail.
/// c.grow(0, 9).unwrap();
/// assert_eq!((c.blocks_in_use(), c.blocks_reserved()), (3, 0));
/// // Completion returns every block (and any unused reservation).
/// assert_eq!(c.release_slot(0).unwrap(), 3);
/// assert_eq!(c.blocks_in_use(), 0);
/// ```
pub struct PagedKvCache {
    pub layout: CacheLayout,
    pub n_layers: usize,
    /// Tokens per block.
    pub block_size: usize,
    alloc: BlockAllocator,
    /// Backing tensors, one per layout buffer (GQA: k, v; MLA: latent,
    /// rope-key), shaped `[n_blocks, L, block_size, inner]`.
    pool: Vec<Tensor>,
    /// Per-slot block tables: `tables[slot][pos / block_size]` is the
    /// block holding token position `pos`.
    tables: Vec<Vec<usize>>,
    /// Blocks reserved at admission but not yet in the table, per slot.
    reserved: Vec<usize>,
    /// Prompt positions per slot backed by blocks mapped from the prefix
    /// index at admission (always a multiple of `block_size`; the
    /// sequence itself never writes below this watermark).
    shared: Vec<usize>,
    /// Cross-sequence prefix index; `None` when prefix caching is off.
    /// The cache holds one `retain` per indexed block.
    prefix: Option<PrefixIndex>,
}

impl PagedKvCache {
    pub fn new(
        layout: CacheLayout,
        n_layers: usize,
        n_slots: usize,
        block_size: usize,
        n_blocks: usize,
    ) -> Result<Self> {
        if n_layers == 0 || n_slots == 0 || block_size == 0 || n_blocks == 0 {
            bail!(
                "degenerate paged cache geometry: layers {n_layers}, slots \
                 {n_slots}, block_size {block_size}, blocks {n_blocks}"
            );
        }
        let (i0, i1) = layout.inner_dims();
        let pool = vec![
            Tensor::zeros(&[n_blocks, n_layers, block_size, i0]),
            Tensor::zeros(&[n_blocks, n_layers, block_size, i1]),
        ];
        Ok(PagedKvCache {
            layout,
            n_layers,
            block_size,
            alloc: BlockAllocator::new(n_blocks),
            pool,
            tables: (0..n_slots).map(|_| Vec::new()).collect(),
            reserved: vec![0; n_slots],
            shared: vec![0; n_slots],
            prefix: None,
        })
    }

    /// Turn on cross-sequence prefix sharing (see the module docs).
    pub fn enable_prefix_cache(&mut self) {
        if self.prefix.is_none() {
            self.prefix = Some(PrefixIndex::new());
        }
    }

    pub fn prefix_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Lifetime prefix-sharing counters, `None` when the index is off.
    pub fn prefix_stats(&self) -> Option<PrefixStats> {
        self.prefix.as_ref().map(PrefixIndex::stats)
    }

    /// Prompt positions of `slot` backed by shared prefix blocks.
    pub fn shared_tokens(&self, slot: usize) -> usize {
        self.shared.get(slot).copied().unwrap_or(0)
    }

    pub fn n_slots(&self) -> usize {
        self.tables.len()
    }

    pub fn n_blocks(&self) -> usize {
        self.alloc.n_blocks()
    }

    pub fn blocks_in_use(&self) -> usize {
        self.alloc.n_in_use()
    }

    /// Blocks promised to admitted sequences but not yet allocated.
    pub fn blocks_reserved(&self) -> usize {
        self.reserved.iter().sum()
    }

    /// Outstanding (not yet materialised) reservation of one slot.
    pub fn reserved_of(&self, slot: usize) -> usize {
        self.reserved.get(slot).copied().unwrap_or(0)
    }

    /// Blocks available for *new* admissions: free minus outstanding
    /// reservations (the scheduler's blocks-free admission signal).
    pub fn n_unreserved(&self) -> usize {
        self.alloc.n_free().saturating_sub(self.blocks_reserved())
    }

    /// Blocks needed to hold `tokens` cache positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        (tokens + self.block_size - 1) / self.block_size
    }

    /// Inner (per-token, per-layer) width of pool buffer `buf`.
    pub fn inner_dim(&self, buf: usize) -> usize {
        self.pool[buf].shape[3]
    }

    pub fn bytes_per_token(&self) -> usize {
        self.layout.per_token_per_layer() * self.n_layers * 4
    }

    pub fn bytes_total(&self) -> usize {
        self.pool.iter().map(|b| b.len() * 4).sum()
    }

    /// Bytes actually held by allocated blocks.
    pub fn bytes_in_use(&self) -> usize {
        self.blocks_in_use() * self.block_size * self.bytes_per_token()
    }

    /// Bind `slot` to a fresh sequence: reserve `reserve_tokens` worth of
    /// blocks (its bounded lifetime demand) and materialise the first
    /// `initial_len` positions (the prompt, about to be spliced). No
    /// prefix sharing — shorthand for [`PagedKvCache::admit_slot_shared`]
    /// with an empty prompt.
    pub fn admit_slot(
        &mut self,
        slot: usize,
        reserve_tokens: usize,
        initial_len: usize,
    ) -> Result<()> {
        self.admit_slot_shared(slot, reserve_tokens, initial_len, &[])
            .map(|_| ())
    }

    /// Like [`PagedKvCache::admit_slot`], but first maps the longest
    /// indexed prefix of `prompt` into the slot's table (retaining each
    /// shared block) and reserves only the *unshared* remainder — a burst
    /// of same-prefix sequences costs one copy of the prefix plus one
    /// private tail each. Returns the number of shared token positions
    /// (always a multiple of the block size).
    ///
    /// Sharing caps at `floor((prompt_len - 1) / block_size)` full
    /// blocks, so at least one prompt position is always computed by the
    /// backend (the sequence's first logits) and the sequence never
    /// writes a shared block on the serving path — copy-on-write in
    /// [`PagedKvCache::row_mut`] stays a defensive backstop. When the
    /// unreserved pool is short, cached blocks only the index references
    /// are LRU-evicted to make room.
    pub fn admit_slot_shared(
        &mut self,
        slot: usize,
        reserve_tokens: usize,
        initial_len: usize,
        prompt: &[i32],
    ) -> Result<usize> {
        if slot >= self.tables.len() {
            bail!("slot out of range: {slot} >= {}", self.tables.len());
        }
        if !self.tables[slot].is_empty() || self.reserved[slot] != 0 {
            bail!("slot {slot} already admitted");
        }
        let total = self.blocks_for(reserve_tokens.max(initial_len));
        // Cap sharing one block below the prompt (the backend must
        // compute at least one position for the first logits) AND one
        // below the bounded demand (so `need >= 1` even for degenerate
        // reserve/prompt combinations a direct caller might pass).
        let max_share = (prompt.len().saturating_sub(1) / self.block_size)
            .min(total.saturating_sub(1));
        let matched = match self.prefix.as_mut() {
            Some(ix) if max_share > 0 => ix.lookup(prompt, self.block_size, max_share),
            _ => Vec::new(),
        };
        // Retain the shared chain *before* any eviction below, so the
        // blocks this admission depends on can never be its victims.
        for &b in &matched {
            self.alloc.retain(b)?;
        }
        let need = total - matched.len();
        if need > self.n_unreserved() {
            let short = need - self.n_unreserved();
            self.evict_for(short)?;
        }
        if need > self.n_unreserved() {
            for &b in &matched {
                self.alloc.release(b)?;
            }
            bail!(
                "out of cache blocks: slot {slot} needs {need} beyond its {} \
                 shared, {} unreserved",
                matched.len(),
                self.n_unreserved()
            );
        }
        let shared_tokens = matched.len() * self.block_size;
        if let Some(ix) = self.prefix.as_mut() {
            ix.record_shared(matched.len(), shared_tokens);
        }
        self.tables[slot] = matched;
        self.shared[slot] = shared_tokens;
        self.reserved[slot] = need;
        self.grow(slot, initial_len)?;
        Ok(shared_tokens)
    }

    /// The blocks a sharing admission of `prompt` would map right now —
    /// the scheduler's non-mutating planning view (no stats, no LRU).
    pub fn peek_shared(&self, prompt: &[i32]) -> Vec<usize> {
        let max_share = prompt.len().saturating_sub(1) / self.block_size;
        match &self.prefix {
            Some(ix) if max_share > 0 => ix.peek(prompt, self.block_size, max_share),
            _ => Vec::new(),
        }
    }

    /// Freshen the LRU stamp of `prompt`'s cached prefix chain (no
    /// stats, no mapping). Called for every request of an admission wave
    /// before any of them admits, so same-wave evictions prefer blocks
    /// no planned admission is counting on.
    pub fn touch_prefix(&mut self, prompt: &[i32]) {
        let max_share = prompt.len().saturating_sub(1) / self.block_size;
        if max_share > 0 {
            if let Some(ix) = self.prefix.as_mut() {
                ix.touch(prompt, self.block_size, max_share);
            }
        }
    }

    /// Cached blocks reclaimable right now: indexed, and referenced by
    /// nothing but the index (refcount 1).
    pub fn evictable_blocks(&self) -> Vec<usize> {
        match &self.prefix {
            Some(ix) => ix
                .blocks()
                .into_iter()
                .filter(|&b| self.alloc.refcount_of(b) == 1)
                .collect(),
            None => Vec::new(),
        }
    }

    /// Evict up to `want` LRU cached blocks that only the index still
    /// references, returning them to the free list. Returns how many
    /// were reclaimed (possibly fewer than asked).
    fn evict_for(&mut self, want: usize) -> Result<usize> {
        let Some(ix) = self.prefix.as_ref() else {
            return Ok(0);
        };
        let mut cands: Vec<(u64, usize)> = ix
            .candidates()
            .into_iter()
            .filter(|&(b, _)| self.alloc.refcount_of(b) == 1)
            .map(|(b, t)| (t, b))
            .collect();
        cands.sort_unstable();
        let mut freed = 0;
        for (_, b) in cands {
            if freed >= want {
                break;
            }
            self.prefix
                .as_mut()
                .expect("prefix index present")
                .remove_block(b);
            let went_free = self.alloc.release(b)?;
            debug_assert!(went_free, "evicted block {b} had hidden references");
            freed += 1;
        }
        Ok(freed)
    }

    /// Index `slot`'s fully-filled prompt blocks so later same-prefix
    /// admissions can share them. Call once the prompt is entirely in
    /// cache (post-splice, or when the final chunk lands). Only blocks
    /// completely covered by prompt tokens are indexed — decode writes
    /// always land beyond them. Returns how many blocks were newly
    /// cached; a no-op (0) when the index is off.
    pub fn register_prefix(&mut self, slot: usize, prompt: &[i32]) -> Result<usize> {
        if self.prefix.is_none() {
            return Ok(0);
        }
        if slot >= self.tables.len() {
            bail!("slot out of range: {slot} >= {}", self.tables.len());
        }
        let full = prompt.len() / self.block_size;
        if full == 0 {
            return Ok(0);
        }
        if self.tables[slot].len() < full {
            bail!(
                "slot {slot} table ({} blocks) does not cover its {full} full \
                 prompt blocks",
                self.tables[slot].len()
            );
        }
        let newly = self
            .prefix
            .as_mut()
            .expect("prefix index present")
            .insert_chain(prompt, self.block_size, &self.tables[slot][..full]);
        for &b in &newly {
            // The index's own reference: the block now outlives the slot.
            self.alloc.retain(b)?;
        }
        Ok(newly.len())
    }

    /// Bytes that sharing is saving right now: every table reference to a
    /// block beyond the first would be a private copy without sharing.
    pub fn bytes_deduped(&self) -> usize {
        let mut refs = vec![0usize; self.alloc.n_blocks()];
        for t in &self.tables {
            for &b in t {
                refs[b] += 1;
            }
        }
        let extra: usize = refs.iter().map(|&r| r.saturating_sub(1)).sum();
        extra * self.block_size * self.bytes_per_token()
    }

    /// Ensure the slot's table covers `len` token positions, drawing new
    /// blocks from the slot's admission-time reservation (so growth
    /// during decode can never race another sequence for memory).
    pub fn grow(&mut self, slot: usize, len: usize) -> Result<()> {
        if slot >= self.tables.len() {
            bail!("slot out of range: {slot} >= {}", self.tables.len());
        }
        let want = self.blocks_for(len);
        while self.tables[slot].len() < want {
            if self.reserved[slot] == 0 {
                bail!(
                    "slot {slot} grew past its reservation ({} blocks)",
                    self.tables[slot].len()
                );
            }
            let b = match self.alloc.alloc() {
                Some(b) => b,
                None => bail!("block pool exhausted despite reservation"),
            };
            self.reserved[slot] -= 1;
            self.tables[slot].push(b);
        }
        Ok(())
    }

    /// Release every block the slot holds plus its unused reservation;
    /// returns the number of blocks returned to the free list.
    pub fn release_slot(&mut self, slot: usize) -> Result<usize> {
        if slot >= self.tables.len() {
            bail!("slot out of range: {slot} >= {}", self.tables.len());
        }
        let blocks = std::mem::take(&mut self.tables[slot]);
        let mut freed = 0;
        for b in blocks {
            // Shared or index-cached blocks survive (refcount stays > 0);
            // only the last holder actually frees.
            if self.alloc.release(b)? {
                freed += 1;
            }
        }
        self.reserved[slot] = 0;
        self.shared[slot] = 0;
        Ok(freed)
    }

    /// Shrink `slot`'s materialised coverage to at most `len` token
    /// positions — the speculative-decode rollback primitive. Tail
    /// blocks past the new end are `release`d back to the allocator,
    /// never zeroed, so a block another table or the prefix index still
    /// references survives with its bytes (and its other holders'
    /// refcounts) intact. Each block that actually frees re-credits the
    /// slot's reservation — it was drawn from that reservation by
    /// [`PagedKvCache::grow`], and the retracted positions will be
    /// re-grown on a later decode step. A still-shared block re-credits
    /// nothing: re-growing would need a genuinely free block, which its
    /// release did not produce (never hit on the serving path, where
    /// truncation stays above the prompt and decode blocks are private).
    ///
    /// Positions below the shared-prefix watermark are never truncated:
    /// the mapped blocks hold prompt content the slot logically still
    /// covers.
    pub fn truncate(&mut self, slot: usize, len: usize) -> Result<()> {
        if slot >= self.tables.len() {
            bail!("slot out of range: {slot} >= {}", self.tables.len());
        }
        let floor = self.shared[slot];
        let want = self.blocks_for(len.max(floor));
        while self.tables[slot].len() > want {
            let b = self.tables[slot].pop().expect("non-empty table");
            if self.alloc.release(b)? {
                self.reserved[slot] += 1;
            }
        }
        Ok(())
    }

    /// Does the slot's table cover token position `pos`? (False for idle
    /// slots — backends use this as the position mask.)
    pub fn covers(&self, slot: usize, pos: usize) -> bool {
        match self.tables.get(slot) {
            Some(t) => pos / self.block_size < t.len(),
            None => false,
        }
    }

    fn offset(&self, buf: usize, slot: usize, layer: usize, pos: usize) -> Result<usize> {
        let table = match self.tables.get(slot) {
            Some(t) => t,
            None => bail!("slot out of range: {slot} >= {}", self.tables.len()),
        };
        let block = match table.get(pos / self.block_size) {
            Some(&b) => b,
            None => bail!(
                "position {pos} beyond slot {slot}'s block table ({} blocks)",
                table.len()
            ),
        };
        if layer >= self.n_layers {
            bail!("layer {layer} out of range");
        }
        let inner = self.pool[buf].shape[3];
        let off = pos % self.block_size;
        Ok(((block * self.n_layers + layer) * self.block_size + off) * inner)
    }

    /// The inner-dim row of pool buffer `buf` at (slot, layer, pos).
    pub fn row(&self, buf: usize, slot: usize, layer: usize, pos: usize) -> Result<&[f32]> {
        let inner = self.pool[buf].shape[3];
        let o = self.offset(buf, slot, layer, pos)?;
        Ok(&self.pool[buf].data[o..o + inner])
    }

    /// Mutable row access, with **copy-on-write**: when the block holding
    /// `pos` is also referenced by another table or the prefix index, the
    /// slot first gets a private copy (all layers, both buffers), so the
    /// write can never corrupt another reader's bytes.
    pub fn row_mut(
        &mut self,
        buf: usize,
        slot: usize,
        layer: usize,
        pos: usize,
    ) -> Result<&mut [f32]> {
        self.ensure_private(slot, pos)?;
        let inner = self.pool[buf].shape[3];
        let o = self.offset(buf, slot, layer, pos)?;
        Ok(&mut self.pool[buf].data[o..o + inner])
    }

    /// Copy-on-write: if `slot`'s block holding `pos` has other holders
    /// (refcount > 1), copy its full contents into a fresh block and
    /// repoint the table entry. Draws on the unreserved pool (evicting
    /// cached blocks if needed) so outstanding reservations stay intact.
    fn ensure_private(&mut self, slot: usize, pos: usize) -> Result<()> {
        let idx = pos / self.block_size;
        let b = match self.tables.get(slot).and_then(|t| t.get(idx)) {
            Some(&b) => b,
            // Out-of-range slots/positions fall through to `offset`'s
            // error on the actual access.
            None => return Ok(()),
        };
        if self.alloc.refcount_of(b) <= 1 {
            return Ok(());
        }
        if self.n_unreserved() == 0 {
            self.evict_for(1)?;
        }
        if self.n_unreserved() == 0 {
            bail!(
                "block pool exhausted during copy-on-write of block {b} \
                 (reservations hold the remaining free blocks)"
            );
        }
        let nb = match self.alloc.alloc() {
            Some(nb) => nb,
            None => bail!("block pool exhausted during copy-on-write of block {b}"),
        };
        for buf in &mut self.pool {
            let stride = self.n_layers * self.block_size * buf.shape[3];
            buf.data.copy_within(b * stride..(b + 1) * stride, nb * stride);
        }
        // Drop this slot's reference to the shared block; it cannot free
        // (other holders remain), and any index entry stays with it.
        self.alloc.release(b)?;
        self.tables[slot][idx] = nb;
        Ok(())
    }

    /// Splice prefill output (tensors `[L, Bp, T, inner...]`) row `src`
    /// into `slot`, copying only the first `len` positions — unlike the
    /// fixed pool there is no padded tail to fill. The slot must already
    /// cover `len` positions (admit_slot/grow first). Positions below the
    /// slot's shared-prefix watermark are skipped: the mapped blocks
    /// already hold exactly those rows (same tokens, same content), which
    /// is the whole point of sharing them.
    pub fn splice_from(
        &mut self,
        prefill_bufs: &[Tensor],
        src: usize,
        slot: usize,
        len: usize,
    ) -> Result<()> {
        if prefill_bufs.len() != self.pool.len() {
            bail!("layout mismatch");
        }
        if len > 0 && !self.covers(slot, len - 1) {
            bail!("slot {slot} block table does not cover {len} positions");
        }
        let start = self.shared.get(slot).copied().unwrap_or(0).min(len);
        // Defensive CoW pre-pass over every block this splice writes —
        // the serving path never splices into shared blocks (the skip
        // above), but a direct caller must not corrupt other readers.
        let mut p = start;
        while p < len {
            self.ensure_private(slot, p)?;
            p = (p / self.block_size + 1) * self.block_size;
        }
        for (i, theirs) in prefill_bufs.iter().enumerate() {
            if theirs.shape.len() < 3 || theirs.shape[0] != self.n_layers {
                bail!(
                    "cache layer count mismatch: pool has {} layers, \
                     prefill buffer is {:?}",
                    self.n_layers, theirs.shape
                );
            }
            let bp = theirs.shape[1];
            let t = theirs.shape[2];
            let inner: usize = theirs.shape[3..].iter().product();
            if inner != self.pool[i].shape[3] {
                bail!(
                    "cache inner shape mismatch {:?} vs {:?}",
                    self.pool[i].shape, theirs.shape
                );
            }
            if src >= bp {
                bail!("slot out of range");
            }
            if len > t {
                bail!("splice wants {len} positions, prefill has {t}");
            }
            for l in 0..self.n_layers {
                for pos in start..len {
                    let src_off = ((l * bp + src) * t + pos) * inner;
                    let dst_off = self.offset(i, slot, l, pos)?;
                    let src_row = &theirs.data[src_off..src_off + inner];
                    self.pool[i].data[dst_off..dst_off + inner]
                        .copy_from_slice(src_row);
                }
            }
        }
        Ok(())
    }

    /// Allocator consistency plus table/refcount agreement: every block
    /// reference in some table — plus the prefix index's one reference
    /// per cached block — is accounted for by exactly its refcount, and
    /// outstanding reservations never exceed the free list.
    pub fn check_invariants(&self) -> Result<()> {
        self.alloc.check_invariants()?;
        let mut refs = vec![0u32; self.alloc.n_blocks()];
        for (slot, table) in self.tables.iter().enumerate() {
            for &b in table {
                if b >= refs.len() {
                    bail!("slot {slot} references out-of-range block {b}");
                }
                refs[b] += 1;
            }
        }
        if let Some(ix) = &self.prefix {
            ix.check()?;
            for b in ix.blocks() {
                if b >= refs.len() {
                    bail!("prefix index references out-of-range block {b}");
                }
                refs[b] += 1;
            }
        }
        for (b, &r) in refs.iter().enumerate() {
            if r != self.alloc.refcount_of(b) {
                bail!(
                    "block {b} refcount {} != {r} table+index references",
                    self.alloc.refcount_of(b)
                );
            }
        }
        if self.blocks_reserved() > self.alloc.n_free() {
            bail!(
                "reserved {} blocks exceed {} free",
                self.blocks_reserved(),
                self.alloc.n_free()
            );
        }
        for (slot, &s) in self.shared.iter().enumerate() {
            if s % self.block_size != 0 {
                bail!("slot {slot} shared watermark {s} is not block-aligned");
            }
            if s > self.tables[slot].len() * self.block_size {
                bail!("slot {slot} shared watermark {s} exceeds its table");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, PropConfig};
    use crate::util::Rng;

    fn mla_cache(slots: usize, block_size: usize, blocks: usize) -> PagedKvCache {
        PagedKvCache::new(CacheLayout::Mla { r: 2, dr: 2 }, 2, slots, block_size, blocks)
            .unwrap()
    }

    #[test]
    fn allocator_alloc_release_cycle() {
        let mut a = BlockAllocator::new(3);
        let b1 = a.alloc().unwrap();
        let b2 = a.alloc().unwrap();
        assert_ne!(b1, b2);
        assert_eq!(a.n_in_use(), 2);
        assert!(a.release(b1).unwrap(), "refcount 1 frees");
        assert!(a.release(b1).is_err(), "double free must fail");
        a.check_invariants().unwrap();
    }

    #[test]
    fn allocator_refcounts_defer_the_free() {
        let mut a = BlockAllocator::new(2);
        let b = a.alloc().unwrap();
        a.retain(b).unwrap();
        assert_eq!(a.refcount_of(b), 2);
        assert!(!a.release(b).unwrap(), "still referenced");
        assert!(a.release(b).unwrap(), "last ref frees");
        assert!(a.retain(b).is_err(), "retain of a free block must fail");
        a.check_invariants().unwrap();
    }

    #[test]
    fn allocator_exhaustion_returns_none() {
        let mut a = BlockAllocator::new(1);
        assert!(a.alloc().is_some());
        assert!(a.alloc().is_none());
    }

    #[test]
    fn props_block_allocator_invariants_under_random_workload() {
        check(
            "block_allocator_invariants",
            PropConfig { cases: 200, seed: 77 },
            |r: &mut Rng| {
                let n = 1 + r.below(8);
                let ops: Vec<u8> = (0..96).map(|_| r.next_u64() as u8).collect();
                (n, ops)
            },
            |(n, ops)| {
                let mut a = BlockAllocator::new(*n);
                // live[i] = (block, refs we still hold on it)
                let mut live: Vec<(usize, u32)> = vec![];
                for &op in ops {
                    match op % 3 {
                        0 => {
                            if let Some(b) = a.alloc() {
                                if live.iter().any(|&(x, _)| x == b) {
                                    return Err(format!("block {b} double-allocated"));
                                }
                                live.push((b, 1));
                            } else if live.len() != *n {
                                return Err("alloc failed below capacity".into());
                            }
                        }
                        1 => {
                            if !live.is_empty() {
                                let i = (op as usize / 3) % live.len();
                                live[i].1 += 1;
                                a.retain(live[i].0).map_err(|e| e.to_string())?;
                            }
                        }
                        _ => {
                            if !live.is_empty() {
                                let i = (op as usize / 3) % live.len();
                                let freed =
                                    a.release(live[i].0).map_err(|e| e.to_string())?;
                                live[i].1 -= 1;
                                if freed != (live[i].1 == 0) {
                                    return Err(format!(
                                        "block {} freed={freed} with {} refs held",
                                        live[i].0, live[i].1
                                    ));
                                }
                                if live[i].1 == 0 {
                                    live.remove(i);
                                }
                            }
                        }
                    }
                    a.check_invariants().map_err(|e| e.to_string())?;
                    if a.n_in_use() != live.len() {
                        return Err("in-use count mismatch".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn admit_grow_release_lifecycle() {
        let mut c = mla_cache(2, 4, 6);
        // Reserve 10 tokens (3 blocks), materialise the 5-token prompt.
        c.admit_slot(0, 10, 5).unwrap();
        assert_eq!(c.blocks_in_use(), 2, "5 tokens span 2 blocks of 4");
        assert_eq!(c.blocks_reserved(), 1, "one block still reserved");
        assert_eq!(c.n_unreserved(), 3);
        assert!(c.covers(0, 4) && !c.covers(0, 8));
        c.grow(0, 9).unwrap();
        assert_eq!(c.blocks_in_use(), 3);
        assert_eq!(c.blocks_reserved(), 0);
        assert!(c.grow(0, 13).is_err(), "growth past reservation fails");
        c.check_invariants().unwrap();
        assert_eq!(c.release_slot(0).unwrap(), 3);
        assert_eq!(c.blocks_in_use(), 0);
        assert_eq!(c.n_unreserved(), 6);
        c.check_invariants().unwrap();
    }

    #[test]
    fn admission_respects_outstanding_reservations() {
        let mut c = mla_cache(3, 4, 4);
        // Slot 0 reserves 3 blocks but only materialises 1.
        c.admit_slot(0, 12, 2).unwrap();
        assert_eq!(c.n_unreserved(), 1);
        // A second sequence may only take the 1 unreserved block.
        assert!(c.admit_slot(1, 8, 2).is_err(), "would eat slot 0's reserve");
        c.admit_slot(1, 4, 2).unwrap();
        assert_eq!(c.n_unreserved(), 0);
        assert!(c.admit_slot(2, 1, 1).is_err(), "pool fully committed");
        // Slot 0's lazy growth still succeeds: its blocks were promised.
        c.grow(0, 12).unwrap();
        c.check_invariants().unwrap();
    }

    #[test]
    fn truncate_releases_tail_blocks_and_recredits_the_reservation() {
        let mut c = mla_cache(2, 4, 6);
        // Reserve 16 tokens (4 blocks), materialise the 5-token prompt.
        c.admit_slot(0, 16, 5).unwrap();
        c.grow(0, 13).unwrap();
        assert_eq!((c.blocks_in_use(), c.reserved_of(0)), (4, 0));
        // Roll back to 6 positions: two tail blocks free and their
        // reservation comes back, so the re-grow below cannot fail.
        c.truncate(0, 6).unwrap();
        assert_eq!((c.blocks_in_use(), c.reserved_of(0)), (2, 2));
        assert!(c.covers(0, 5) && !c.covers(0, 8));
        c.check_invariants().unwrap();
        c.grow(0, 13).unwrap();
        assert_eq!((c.blocks_in_use(), c.reserved_of(0)), (4, 0));
        c.truncate(0, 0).unwrap();
        assert_eq!(c.blocks_in_use(), 0);
        assert!(c.truncate(9, 0).is_err(), "slot out of range");
        c.check_invariants().unwrap();
        c.release_slot(0).unwrap();
        c.check_invariants().unwrap();
    }

    #[test]
    fn double_admit_and_bad_slots_error() {
        let mut c = mla_cache(2, 4, 4);
        c.admit_slot(0, 4, 2).unwrap();
        assert!(c.admit_slot(0, 4, 2).is_err(), "slot already admitted");
        assert!(c.admit_slot(9, 4, 2).is_err(), "slot out of range");
        assert!(c.grow(9, 1).is_err());
        assert!(c.release_slot(9).is_err());
        assert!(c.row(0, 0, 0, 7).is_err(), "beyond the block table");
    }

    #[test]
    fn rows_roundtrip_through_blocks() {
        let mut c = mla_cache(2, 4, 8);
        c.admit_slot(1, 7, 7).unwrap();
        for pos in 0..7 {
            for l in 0..2 {
                let v = (pos * 10 + l) as f32;
                c.row_mut(0, 1, l, pos).unwrap().fill(v);
                c.row_mut(1, 1, l, pos).unwrap().fill(-v);
            }
        }
        for pos in 0..7 {
            for l in 0..2 {
                let v = (pos * 10 + l) as f32;
                assert_eq!(c.row(0, 1, l, pos).unwrap(), [v, v]);
                assert_eq!(c.row(1, 1, l, pos).unwrap(), [-v, -v]);
            }
        }
        c.check_invariants().unwrap();
    }

    #[test]
    fn splice_copies_the_right_prefill_row() {
        let mut c = mla_cache(2, 4, 8);
        c.admit_slot(0, 6, 6).unwrap();
        // Prefill buffers [L=2, Bp=3, T=8, inner=2]; mark row 1.
        let mut src_c = Tensor::zeros(&[2, 3, 8, 2]);
        let src_kr = Tensor::zeros(&[2, 3, 8, 2]);
        for l in 0..2 {
            for t in 0..8 {
                for x in 0..2 {
                    src_c.data[((l * 3 + 1) * 8 + t) * 2 + x] =
                        (l * 1000 + t * 10 + x) as f32;
                }
            }
        }
        c.splice_from(&[src_c, src_kr], 1, 0, 6).unwrap();
        assert_eq!(c.row(0, 0, 0, 0).unwrap(), [0.0, 1.0]);
        assert_eq!(c.row(0, 0, 0, 5).unwrap(), [50.0, 51.0]);
        assert_eq!(c.row(0, 0, 1, 3).unwrap(), [1030.0, 1031.0]);
        // Positions past the splice length were never touched.
        assert!(c.row(0, 0, 0, 6).is_err(), "position 6 not materialised");
    }

    #[test]
    fn splice_validates_layer_count_like_the_fixed_pool() {
        let mut c = mla_cache(1, 4, 4);
        c.admit_slot(0, 4, 4).unwrap();
        let short_c = Tensor::zeros(&[1, 1, 4, 2]);
        let short_kr = Tensor::zeros(&[1, 1, 4, 2]);
        let err = c.splice_from(&[short_c, short_kr], 0, 0, 4).unwrap_err();
        assert!(err.to_string().contains("layer count"), "{err}");
    }

    #[test]
    fn byte_accounting_tracks_blocks_not_worst_case() {
        let c0 = mla_cache(4, 16, 16);
        assert_eq!(c0.bytes_per_token(), (2 + 2) * 2 * 4);
        assert_eq!(c0.bytes_total(), 16 * 16 * c0.bytes_per_token());
        assert_eq!(c0.bytes_in_use(), 0);
        let mut c = mla_cache(4, 16, 16);
        c.admit_slot(0, 20, 20).unwrap();
        assert_eq!(c.bytes_in_use(), 2 * 16 * c.bytes_per_token());
    }

    // -- prefix sharing + copy-on-write --------------------------------------

    /// A cache with the prefix index on, slot 0 prefilled with `prompt`
    /// via row_mut (the chunk path's write shape) and registered.
    fn shared_setup(
        slots: usize,
        block_size: usize,
        blocks: usize,
        prompt: &[i32],
    ) -> PagedKvCache {
        let mut c = PagedKvCache::new(
            CacheLayout::Mla { r: 2, dr: 2 },
            2,
            slots,
            block_size,
            blocks,
        )
        .unwrap();
        c.enable_prefix_cache();
        let shared = c
            .admit_slot_shared(0, prompt.len() + 2, prompt.len(), prompt)
            .unwrap();
        assert_eq!(shared, 0, "empty index shares nothing");
        for pos in 0..prompt.len() {
            for l in 0..2 {
                let v = (prompt[pos] * 100 + l as i32) as f32;
                c.row_mut(0, 0, l, pos).unwrap().fill(v);
                c.row_mut(1, 0, l, pos).unwrap().fill(-v);
            }
        }
        c.register_prefix(0, prompt).unwrap();
        c.check_invariants().unwrap();
        c
    }

    #[test]
    fn prefix_sharing_maps_cached_blocks_and_reserves_the_remainder() {
        let prompt: Vec<i32> = (0..10).collect();
        // block_size 4: prompt 10 -> 2 full blocks cacheable, sharing
        // capped at floor(9/4) = 2 blocks = 8 tokens.
        let mut c = shared_setup(3, 4, 12, &prompt);
        assert_eq!(c.prefix_stats().unwrap().blocks_cached, 2);
        let before = c.blocks_in_use();
        let shared = c
            .admit_slot_shared(1, prompt.len() + 2, 0, &prompt)
            .unwrap();
        assert_eq!(shared, 8, "two full blocks shared");
        // Bounded demand 12 tokens = 3 blocks; only the unshared third is
        // reserved, nothing new materialised yet.
        assert_eq!(c.blocks_in_use(), before, "sharing allocates nothing");
        assert_eq!(c.reserved_of(1), 1);
        // The shared rows read back slot 0's bytes.
        assert_eq!(c.row(0, 1, 0, 5).unwrap(), c.row(0, 0, 0, 5).unwrap());
        let s = c.prefix_stats().unwrap();
        assert_eq!((s.hits, s.blocks_shared, s.tokens_shared), (1, 2, 8));
        c.check_invariants().unwrap();
    }

    #[test]
    fn cached_prefix_survives_the_writer_and_eviction_reclaims_it() {
        let prompt: Vec<i32> = (0..10).collect();
        let mut c = shared_setup(2, 4, 8, &prompt);
        // The writer completes: its private tail frees, the 2 cached
        // prefix blocks stay resident for future admissions.
        c.release_slot(0).unwrap();
        assert_eq!(c.blocks_in_use(), 2, "prefix blocks outlive the writer");
        let shared = c
            .admit_slot_shared(0, prompt.len() + 2, 0, &prompt)
            .unwrap();
        assert_eq!(shared, 8, "hit after the writer completed");
        c.release_slot(0).unwrap();
        // A big unsharable admission forces LRU eviction of the cache.
        let other: Vec<i32> = (50..80).collect();
        c.admit_slot_shared(1, 30, 0, &other).unwrap();
        assert_eq!(c.reserved_of(1), 8, "whole pool reserved");
        assert_eq!(c.prefix_stats().unwrap().blocks_cached, 0);
        assert_eq!(c.prefix_stats().unwrap().evictions, 2);
        c.check_invariants().unwrap();
    }

    #[test]
    fn cow_write_preserves_the_readers_bytes() {
        let prompt: Vec<i32> = (0..10).collect();
        let mut c = shared_setup(2, 4, 12, &prompt);
        c.admit_slot_shared(1, prompt.len() + 2, 0, &prompt).unwrap();
        let reader_row: Vec<f32> = c.row(0, 0, 0, 5).unwrap().to_vec();
        // Slot 1 writes a shared position (never happens on the serving
        // path; row_mut must copy-on-write).
        c.row_mut(0, 1, 0, 5).unwrap().fill(777.0);
        assert_eq!(
            c.row(0, 0, 0, 5).unwrap(),
            &reader_row[..],
            "CoW must not touch the reader's block"
        );
        assert_eq!(c.row(0, 1, 0, 5).unwrap(), [777.0, 777.0]);
        // Untouched positions of the copied block carried over.
        assert_eq!(c.row(0, 1, 1, 4).unwrap(), c.row(0, 0, 1, 4).unwrap());
        c.check_invariants().unwrap();
    }

    #[test]
    fn release_of_a_sharing_sequence_never_frees_mapped_blocks() {
        let prompt: Vec<i32> = (0..10).collect();
        let mut c = shared_setup(2, 4, 12, &prompt);
        c.admit_slot_shared(1, prompt.len() + 2, 0, &prompt).unwrap();
        let row: Vec<f32> = c.row(0, 1, 0, 3).unwrap().to_vec();
        // Releasing the original writer must leave slot 1's mapped
        // blocks fully readable.
        c.release_slot(0).unwrap();
        assert_eq!(c.row(0, 1, 0, 3).unwrap(), &row[..]);
        c.check_invariants().unwrap();
        c.release_slot(1).unwrap();
        // Now only the index holds the prefix blocks.
        assert_eq!(c.blocks_in_use(), 2);
        c.check_invariants().unwrap();
    }

    #[test]
    fn truncate_keeps_shared_prefix_blocks_mapped() {
        let prompt: Vec<i32> = (0..10).collect();
        let mut c = shared_setup(2, 4, 12, &prompt);
        // Slot 1 maps the 2 cached prefix blocks (8 tokens) and grows a
        // private tail block.
        c.admit_slot_shared(1, 14, 0, &prompt).unwrap();
        c.grow(1, 12).unwrap();
        let reader_row: Vec<f32> = c.row(0, 1, 0, 5).unwrap().to_vec();
        // Truncating below the shared watermark clamps at it: the
        // private tail frees, the mapped prefix blocks survive with
        // their bytes and their other holders' refcounts intact.
        c.truncate(1, 4).unwrap();
        assert!(c.covers(1, 7), "shared watermark is the truncation floor");
        assert!(!c.covers(1, 8), "private tail released");
        assert_eq!(c.row(0, 1, 0, 5).unwrap(), &reader_row[..]);
        assert_eq!(c.reserved_of(1), 2, "freed tail re-credits the reservation");
        c.check_invariants().unwrap();
        c.release_slot(1).unwrap();
        c.check_invariants().unwrap();
    }

    #[test]
    fn props_truncate_rollback_under_random_accept_reject() {
        // The speculative-decode lifecycle against the block ledger:
        // propose (grow k positions), accept a random prefix (truncate
        // the rejected tail). Throughout, the slot's table plus its
        // outstanding reservation must equal its admission-time bounded
        // demand exactly — no leak, no double-free — and a reader
        // sharing the prompt prefix must keep its bytes.
        check(
            "truncate_rollback",
            PropConfig { cases: 80, seed: 4242 },
            |r: &mut Rng| {
                let bs = 2 + r.below(3); // 2..=4
                let plen = bs + 1 + r.below(2 * bs);
                let ops: Vec<u64> = (0..24).map(|_| r.next_u64()).collect();
                (bs, plen, ops)
            },
            |(bs, plen, ops)| {
                let prompt: Vec<i32> = (0..*plen as i32).collect();
                let cap = *plen + 16;
                let mut c =
                    PagedKvCache::new(CacheLayout::Mla { r: 2, dr: 2 }, 1, 2, *bs, 48)
                        .map_err(|e| e.to_string())?;
                c.enable_prefix_cache();
                c.admit_slot_shared(0, cap, *plen, &prompt)
                    .map_err(|e| e.to_string())?;
                for pos in 0..*plen {
                    c.row_mut(0, 0, 0, pos)
                        .map_err(|e| e.to_string())?
                        .fill(pos as f32);
                }
                c.register_prefix(0, &prompt).map_err(|e| e.to_string())?;
                let shared_blocks = c
                    .admit_slot_shared(1, cap, *plen, &prompt)
                    .map_err(|e| e.to_string())?
                    / *bs;
                let demand = c.blocks_for(cap) - shared_blocks;
                let table_len = |c: &PagedKvCache, len: usize| {
                    // covers() probes reconstruct the table length.
                    let mut blocks = 0;
                    while c.covers(1, blocks * *bs) {
                        blocks += 1;
                    }
                    if blocks != c.blocks_for(len) {
                        return Err(format!(
                            "table covers {blocks} blocks, expected {} for len {len}",
                            c.blocks_for(len)
                        ));
                    }
                    Ok(blocks)
                };
                let mut len = *plen;
                for &op in ops {
                    let k = 1 + (op as usize) % 4;
                    let grown = (len + k).min(cap);
                    c.grow(1, grown).map_err(|e| e.to_string())?;
                    let accepted = (op as usize / 8) % (grown - len + 1);
                    len += accepted;
                    c.truncate(1, len).map_err(|e| e.to_string())?;
                    let blocks = table_len(&c, len)?;
                    // Ledger: materialised + outstanding == bounded
                    // demand, always (the no-leak/no-double-free claim).
                    if blocks - shared_blocks + c.reserved_of(1) != demand {
                        return Err(format!(
                            "ledger broke: {blocks} mapped ({shared_blocks} shared), \
                             {} reserved, demand {demand}",
                            c.reserved_of(1)
                        ));
                    }
                    c.check_invariants().map_err(|e| e.to_string())?;
                }
                // The sharing reader's bytes survived every rollback.
                for pos in 0..*plen {
                    let got = c.row(0, 0, 0, pos).map_err(|e| e.to_string())?;
                    if got != [pos as f32, pos as f32] {
                        return Err(format!("reader corrupted at pos {pos}: {got:?}"));
                    }
                }
                c.release_slot(0).map_err(|e| e.to_string())?;
                c.release_slot(1).map_err(|e| e.to_string())?;
                c.check_invariants().map_err(|e| e.to_string())
            },
        );
    }

    #[test]
    fn props_cow_under_random_sharing_preserves_every_reader() {
        check(
            "cow_preserves_readers",
            PropConfig { cases: 60, seed: 1213 },
            |r: &mut Rng| {
                let bs = 2 + r.below(4); // 2..=5
                let plen = bs + 1 + r.below(3 * bs); // at least one full block
                let writes: Vec<u64> = (0..12).map(|_| r.next_u64()).collect();
                (bs, plen, writes)
            },
            |(bs, plen, writes)| {
                let prompt: Vec<i32> = (0..*plen as i32).collect();
                let mut c = PagedKvCache::new(
                    CacheLayout::Mla { r: 2, dr: 2 },
                    1,
                    3,
                    *bs,
                    24,
                )
                .map_err(|e| e.to_string())?;
                c.enable_prefix_cache();
                c.admit_slot_shared(0, *plen + 2, *plen, &prompt)
                    .map_err(|e| e.to_string())?;
                for pos in 0..*plen {
                    c.row_mut(0, 0, 0, pos)
                        .map_err(|e| e.to_string())?
                        .fill(pos as f32);
                }
                c.register_prefix(0, &prompt).map_err(|e| e.to_string())?;
                let shared = c
                    .admit_slot_shared(1, *plen + 2, 0, &prompt)
                    .map_err(|e| e.to_string())?;
                if shared != ((*plen - 1) / *bs) * *bs {
                    return Err(format!("shared {shared} for plen {plen} bs {bs}"));
                }
                // Random writes through slot 1 at shared positions: slot
                // 0 must keep reading its own bytes at every position.
                for &w in writes {
                    if shared == 0 {
                        break;
                    }
                    let pos = (w as usize) % shared;
                    c.row_mut(0, 1, 0, pos)
                        .map_err(|e| e.to_string())?
                        .fill(9000.0 + pos as f32);
                    c.check_invariants().map_err(|e| e.to_string())?;
                }
                for pos in 0..*plen {
                    let got = c.row(0, 0, 0, pos).map_err(|e| e.to_string())?;
                    if got != [pos as f32, pos as f32] {
                        return Err(format!("reader corrupted at pos {pos}: {got:?}"));
                    }
                }
                // Both lifecycles unwind cleanly under sharing + CoW.
                c.release_slot(0).map_err(|e| e.to_string())?;
                c.release_slot(1).map_err(|e| e.to_string())?;
                c.check_invariants().map_err(|e| e.to_string())
            },
        );
    }

    #[test]
    fn props_paged_cache_invariants_under_random_workload() {
        check(
            "paged_cache_invariants",
            PropConfig { cases: 120, seed: 41 },
            |r: &mut Rng| {
                let slots = 1 + r.below(4);
                let blocks = 2 + r.below(10);
                let ops: Vec<u64> = (0..48).map(|_| r.next_u64()).collect();
                (slots, blocks, ops)
            },
            |(slots, blocks, ops)| {
                let mut c = PagedKvCache::new(
                    CacheLayout::Mla { r: 2, dr: 2 },
                    1,
                    *slots,
                    4,
                    *blocks,
                )
                .map_err(|e| e.to_string())?;
                // active[slot] = Some(reserved_tokens) while admitted.
                let mut active: Vec<Option<usize>> = vec![None; *slots];
                for &op in ops {
                    let slot = (op as usize / 4) % *slots;
                    match op % 3 {
                        0 => {
                            if active[slot].is_none() {
                                let tokens = 1 + (op as usize / 16) % 12;
                                let initial = 1 + (op as usize / 64) % tokens;
                                let fits = c.blocks_for(tokens) <= c.n_unreserved();
                                let got = c.admit_slot(slot, tokens, initial);
                                if fits != got.is_ok() {
                                    return Err(format!(
                                        "admit fits={fits} but result {got:?}"
                                    ));
                                }
                                if got.is_ok() {
                                    active[slot] = Some(tokens);
                                }
                            }
                        }
                        1 => {
                            if let Some(tokens) = active[slot] {
                                // Growth within the reservation always works.
                                let len = 1 + (op as usize / 8) % tokens;
                                c.grow(slot, len).map_err(|e| e.to_string())?;
                            }
                        }
                        _ => {
                            if active[slot].take().is_some() {
                                c.release_slot(slot).map_err(|e| e.to_string())?;
                            }
                        }
                    }
                    c.check_invariants().map_err(|e| e.to_string())?;
                }
                Ok(())
            },
        );
    }
}
