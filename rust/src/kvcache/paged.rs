//! Paged block-granular KV cache: vLLM-style block tables over one
//! shared ref-counted pool, replacing the fixed pool's worst-case
//! per-slot row reservation.
//!
//! The fixed [`super::KvCache`] reserves a full `capacity`-length row per
//! slot, so a 16-token prompt costs as much memory as an 8K one and
//! concurrency is bounded by the worst case. Here the unit of allocation
//! is a **block** of `block_size` tokens:
//!
//!   * [`BlockAllocator`] owns the ref-counted free list (ref counts so
//!     future prefix-sharing / copy-on-write can alias blocks across
//!     sequences) with the same leak/double-free invariant checking as
//!     `SlotAllocator::check_invariants`;
//!   * [`PagedKvCache`] holds one backing tensor pair shaped
//!     `[n_blocks, L, block_size, inner]` (layout-aware: GQA k/v or MLA
//!     latent/rope-key) plus a per-slot **block table** mapping token
//!     position -> (block, offset).
//!
//! Admission *reserves* the sequence's bounded demand (prompt plus its
//! clamped `max_new`, not the cache capacity) so lazy per-step `grow`
//! can never fail mid-decode, and the scheduler can admit on blocks-free
//! rather than slots-free.
//!
//! With the optional **prefix cache** enabled
//! ([`PagedKvCache::enable_prefix_cache`]), a [`super::PrefixIndex`] maps
//! token-prefix hashes at block granularity to filled block chains:
//! admission maps the longest cached prefix into the new sequence's table
//! via `retain` and reserves only the unshared remainder, indexed prompt
//! blocks outlive their sequence (LRU-evicted under pressure), and any
//! write to a block other holders still reference triggers copy-on-write
//! in [`PagedKvCache::row_mut`] — a reader's bytes can never change
//! underneath it.
//!
//! # Quantized blocks
//!
//! With a lossy [`QuantKind`] codec ([`PagedKvCache::new_quant`]), the
//! pool stores **encoded** blocks (byte pools, one per layout buffer)
//! and `row`/`row_mut` go through a per-slot write-back **staging
//! buffer**: the decoded f32 image of exactly one cache row at a time.
//! Reads decode on demand; writes mark the staged row dirty and it is
//! encoded back when the slot's staging moves to another row (or at an
//! explicit flush point). Backends are oblivious — they see the same
//! `&[f32]` / `&mut [f32]` rows either way.
//!
//! The **staging-buffer invariant**: a *dirty* staged row always lives
//! in a block with refcount 1. Sequences only write their private tail
//! (`row_mut` copy-on-writes shared blocks first), and
//! [`PagedKvCache::register_prefix`] flushes the slot's staging *before*
//! the index takes its reference — so a block can never become shared
//! while a newer truth for one of its rows sits unencoded in staging.
//! CoW copies and prefix sharing therefore move encoded blocks as
//! opaque bytes, and `truncate` simply *drops* a staged row whose block
//! is retracted (rollback discards the bytes exactly like the fp32
//! pool leaves stale rows behind).
//!
//! Because [`PagedKvCache::row`] must stay `&self` (backends read two
//! buffers of one row in a single expression), the staging state lives
//! in an [`UnsafeCell`]. Callers sign the same discipline the
//! dual-stream overlap already relies on (see `ExecBackend`): a row
//! reference is not held across an access to a *different* row of the
//! same slot, and concurrent streams touch disjoint slots.

use super::quant::QuantKind;
use super::{CacheLayout, PrefixIndex, PrefixStats};
use crate::tensor::Tensor;
use anyhow::{bail, Result};
use std::cell::UnsafeCell;

/// Ref-counted fixed-size block allocator with a free list.
#[derive(Debug)]
pub struct BlockAllocator {
    refcount: Vec<u32>,
    free: Vec<usize>,
}

impl BlockAllocator {
    pub fn new(n_blocks: usize) -> Self {
        BlockAllocator {
            refcount: vec![0; n_blocks],
            free: (0..n_blocks).rev().collect(),
        }
    }

    pub fn n_blocks(&self) -> usize {
        self.refcount.len()
    }

    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    pub fn n_in_use(&self) -> usize {
        self.n_blocks() - self.n_free()
    }

    /// Take a free block (refcount 1), or None when the pool is empty.
    pub fn alloc(&mut self) -> Option<usize> {
        let b = self.free.pop()?;
        debug_assert_eq!(self.refcount[b], 0);
        self.refcount[b] = 1;
        Some(b)
    }

    /// Bump the refcount of an allocated block (prefix sharing / CoW).
    pub fn retain(&mut self, block: usize) -> Result<()> {
        match self.refcount.get_mut(block) {
            Some(rc) if *rc > 0 => {
                *rc += 1;
                Ok(())
            }
            Some(_) => bail!("retain of free block {block}"),
            None => bail!("block {block} out of range"),
        }
    }

    /// Drop one reference; returns true when the block went back to the
    /// free list. Releasing a free block is a double free and errors.
    pub fn release(&mut self, block: usize) -> Result<bool> {
        match self.refcount.get_mut(block) {
            Some(rc) if *rc > 0 => {
                *rc -= 1;
                if *rc == 0 {
                    self.free.push(block);
                    Ok(true)
                } else {
                    Ok(false)
                }
            }
            Some(_) => bail!("double free of block {block}"),
            None => bail!("block {block} out of range"),
        }
    }

    pub fn refcount_of(&self, block: usize) -> u32 {
        self.refcount.get(block).copied().unwrap_or(0)
    }

    /// Internal consistency: free list and refcounts agree, no
    /// duplicates, no leaks (mirrors `SlotAllocator::check_invariants`).
    pub fn check_invariants(&self) -> Result<()> {
        let mut on_free = vec![false; self.n_blocks()];
        for &b in &self.free {
            if b >= self.n_blocks() {
                bail!("free block {b} out of range");
            }
            if on_free[b] {
                bail!("block {b} twice in free list");
            }
            on_free[b] = true;
            if self.refcount[b] != 0 {
                bail!("block {b} both free and referenced");
            }
        }
        for (b, &on) in on_free.iter().enumerate() {
            if self.refcount[b] == 0 && !on {
                bail!("block {b} leaked (zero refs, not in free list)");
            }
        }
        Ok(())
    }
}

/// Per-slot write-back staging over the encoded pool: the decoded f32
/// image of exactly one cache row key `(block, layer, offset)`, both
/// layout buffers (the same key addresses both byte pools).
struct StageSlot {
    key: Option<(usize, usize, usize)>,
    dirty: [bool; 2],
    data: [Vec<f32>; 2],
}

/// Everything the lossy-codec path owns: the encoded byte pools and the
/// per-slot staging buffers. Self-contained geometry copies keep its
/// methods free of borrow entanglement with the outer cache.
struct QuantState {
    kind: QuantKind,
    n_layers: usize,
    block_size: usize,
    /// Encoded bytes per row, per layout buffer.
    bpr: [usize; 2],
    /// Encoded pools, one per layout buffer:
    /// `n_blocks * n_layers * block_size` rows of `bpr[buf]` bytes.
    pools: [Vec<u8>; 2],
    stage: Vec<StageSlot>,
}

impl QuantState {
    /// Byte range of row `(block, layer, off)` in `pools[buf]`.
    fn row_range(&self, buf: usize, block: usize, layer: usize, off: usize) -> std::ops::Range<usize> {
        let row = (block * self.n_layers + layer) * self.block_size + off;
        row * self.bpr[buf]..(row + 1) * self.bpr[buf]
    }

    /// Bytes of one whole encoded block in `pools[buf]`.
    fn block_stride(&self, buf: usize) -> usize {
        self.n_layers * self.block_size * self.bpr[buf]
    }

    /// Encode `slot`'s staged row back into the pool (dirty buffers
    /// only) and mark it clean. The staged image stays valid for reads.
    fn flush_slot(&mut self, slot: usize) {
        let Some((block, layer, off)) = self.stage[slot].key else {
            return;
        };
        for buf in 0..2 {
            if !self.stage[slot].dirty[buf] {
                continue;
            }
            let r = self.row_range(buf, block, layer, off);
            self.kind
                .encode_row(&self.stage[slot].data[buf], &mut self.pools[buf][r]);
            self.stage[slot].dirty[buf] = false;
        }
    }

    /// Forget `slot`'s staged row without encoding it — the rollback /
    /// release primitive (any dirty data is discarded).
    fn drop_stage(&mut self, slot: usize) {
        self.stage[slot].key = None;
        self.stage[slot].dirty = [false, false];
    }

    /// Make `slot`'s staging hold the decoded row at `key`: flush the
    /// previously staged row (write-back), then decode both buffers.
    /// No-op when `key` is already staged.
    fn stage_row(&mut self, slot: usize, key: (usize, usize, usize)) {
        if self.stage[slot].key == Some(key) {
            return;
        }
        self.flush_slot(slot);
        let (block, layer, off) = key;
        for buf in 0..2 {
            let r = self.row_range(buf, block, layer, off);
            self.kind
                .decode_row(&self.pools[buf][r], &mut self.stage[slot].data[buf]);
        }
        self.stage[slot].key = Some(key);
        self.stage[slot].dirty = [false, false];
    }
}

/// The paged cache pool: per-sequence block tables over shared blocks.
///
/// The admit → grow → release lifecycle:
///
/// ```
/// use transmla::kvcache::{CacheLayout, PagedKvCache};
///
/// // 2 slots over 8 four-token blocks of MLA-latent cache.
/// let mut c = PagedKvCache::new(CacheLayout::Mla { r: 4, dr: 4 }, 1, 2, 4, 8).unwrap();
/// // Admission reserves the sequence's bounded demand (10 tokens = 3
/// // blocks) and materialises the 5-token prompt (2 blocks).
/// c.admit_slot(0, 10, 5).unwrap();
/// assert_eq!((c.blocks_in_use(), c.blocks_reserved()), (2, 1));
/// // Decode growth draws on the reservation, so it cannot fail.
/// c.grow(0, 9).unwrap();
/// assert_eq!((c.blocks_in_use(), c.blocks_reserved()), (3, 0));
/// // Completion returns every block (and any unused reservation).
/// assert_eq!(c.release_slot(0).unwrap(), 3);
/// assert_eq!(c.blocks_in_use(), 0);
/// ```
pub struct PagedKvCache {
    pub layout: CacheLayout,
    pub n_layers: usize,
    /// Tokens per block.
    pub block_size: usize,
    alloc: BlockAllocator,
    /// Backing tensors, one per layout buffer (GQA: k, v; MLA: latent,
    /// rope-key), shaped `[n_blocks, L, block_size, inner]`.
    pool: Vec<Tensor>,
    /// Per-slot block tables: `tables[slot][pos / block_size]` is the
    /// block holding token position `pos`.
    tables: Vec<Vec<usize>>,
    /// Blocks reserved at admission but not yet in the table, per slot.
    reserved: Vec<usize>,
    /// Prompt positions per slot backed by blocks mapped from the prefix
    /// index at admission (always a multiple of `block_size`; the
    /// sequence itself never writes below this watermark).
    shared: Vec<usize>,
    /// Cross-sequence prefix index; `None` when prefix caching is off.
    /// The cache holds one `retain` per indexed block.
    prefix: Option<PrefixIndex>,
    /// Which codec the pool stores blocks in ([`QuantKind::Off`] for the
    /// raw f32 pool).
    quant_kind: QuantKind,
    /// Encoded pools + staging, present iff `quant_kind` is lossy. In an
    /// `UnsafeCell` because [`PagedKvCache::row`] must stage (decode)
    /// from `&self` — see the module docs for the access discipline.
    quant: Option<UnsafeCell<QuantState>>,
}

impl PagedKvCache {
    pub fn new(
        layout: CacheLayout,
        n_layers: usize,
        n_slots: usize,
        block_size: usize,
        n_blocks: usize,
    ) -> Result<Self> {
        Self::new_quant(layout, n_layers, n_slots, block_size, n_blocks, QuantKind::Off)
    }

    /// Like [`PagedKvCache::new`], but storing blocks in the given
    /// codec. `n_blocks` counts *encoded* blocks: at a fixed byte
    /// budget, a lossy pool holds proportionally more of them (the
    /// caller sizes the pool; see `BackendSpec::new_cache_store`).
    pub fn new_quant(
        layout: CacheLayout,
        n_layers: usize,
        n_slots: usize,
        block_size: usize,
        n_blocks: usize,
        quant: QuantKind,
    ) -> Result<Self> {
        if n_layers == 0 || n_slots == 0 || block_size == 0 || n_blocks == 0 {
            bail!(
                "degenerate paged cache geometry: layers {n_layers}, slots \
                 {n_slots}, block_size {block_size}, blocks {n_blocks}"
            );
        }
        let (i0, i1) = layout.inner_dims();
        // With a lossy codec the f32 pool is unused: keep zero-block
        // tensors so shape queries (`inner_dim`) stay uniform while the
        // bytes live in the encoded pools.
        let pool_blocks = if quant.is_off() { n_blocks } else { 0 };
        let pool = vec![
            Tensor::zeros(&[pool_blocks, n_layers, block_size, i0]),
            Tensor::zeros(&[pool_blocks, n_layers, block_size, i1]),
        ];
        let qstate = if quant.is_off() {
            None
        } else {
            let rows = n_blocks * n_layers * block_size;
            let bpr = [quant.bytes_per_row(i0), quant.bytes_per_row(i1)];
            Some(UnsafeCell::new(QuantState {
                kind: quant,
                n_layers,
                block_size,
                bpr,
                // Zero bytes decode to zero rows (see `kvcache::quant`),
                // so a fresh encoded pool matches the zeroed f32 pool.
                pools: [vec![0u8; rows * bpr[0]], vec![0u8; rows * bpr[1]]],
                stage: (0..n_slots)
                    .map(|_| StageSlot {
                        key: None,
                        dirty: [false, false],
                        data: [vec![0.0; i0], vec![0.0; i1]],
                    })
                    .collect(),
            }))
        };
        Ok(PagedKvCache {
            layout,
            n_layers,
            block_size,
            alloc: BlockAllocator::new(n_blocks),
            pool,
            tables: (0..n_slots).map(|_| Vec::new()).collect(),
            reserved: vec![0; n_slots],
            shared: vec![0; n_slots],
            prefix: None,
            quant_kind: quant,
            quant: qstate,
        })
    }

    /// The codec the pool stores blocks in.
    pub fn quant_kind(&self) -> QuantKind {
        self.quant_kind
    }

    /// Turn on cross-sequence prefix sharing (see the module docs).
    pub fn enable_prefix_cache(&mut self) {
        if self.prefix.is_none() {
            self.prefix = Some(PrefixIndex::new());
        }
    }

    pub fn prefix_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Lifetime prefix-sharing counters, `None` when the index is off.
    pub fn prefix_stats(&self) -> Option<PrefixStats> {
        self.prefix.as_ref().map(PrefixIndex::stats)
    }

    /// Prompt positions of `slot` backed by shared prefix blocks.
    pub fn shared_tokens(&self, slot: usize) -> usize {
        self.shared.get(slot).copied().unwrap_or(0)
    }

    pub fn n_slots(&self) -> usize {
        self.tables.len()
    }

    pub fn n_blocks(&self) -> usize {
        self.alloc.n_blocks()
    }

    pub fn blocks_in_use(&self) -> usize {
        self.alloc.n_in_use()
    }

    /// Blocks promised to admitted sequences but not yet allocated.
    pub fn blocks_reserved(&self) -> usize {
        self.reserved.iter().sum()
    }

    /// Outstanding (not yet materialised) reservation of one slot.
    pub fn reserved_of(&self, slot: usize) -> usize {
        self.reserved.get(slot).copied().unwrap_or(0)
    }

    /// Blocks available for *new* admissions: free minus outstanding
    /// reservations (the scheduler's blocks-free admission signal).
    pub fn n_unreserved(&self) -> usize {
        self.alloc.n_free().saturating_sub(self.blocks_reserved())
    }

    /// Blocks needed to hold `tokens` cache positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        (tokens + self.block_size - 1) / self.block_size
    }

    /// Inner (per-token, per-layer) width of pool buffer `buf`.
    pub fn inner_dim(&self, buf: usize) -> usize {
        self.pool[buf].shape[3]
    }

    /// Bytes one token position actually occupies in the pool — codec-
    /// aware: the raw f32 cost when quant is off, the encoded cost (one
    /// byte per value plus the per-row scale) otherwise.
    pub fn bytes_per_token(&self) -> usize {
        let (i0, i1) = self.layout.inner_dims();
        (self.quant_kind.bytes_per_row(i0) + self.quant_kind.bytes_per_row(i1))
            * self.n_layers
    }

    /// The fp32 worst-case cost of one token position — the codec-free
    /// reference that compression/dedup ratios are quoted against.
    pub fn bytes_per_token_fp32(&self) -> usize {
        self.layout.per_token_per_layer() * self.n_layers * 4
    }

    pub fn bytes_total(&self) -> usize {
        self.alloc.n_blocks() * self.block_size * self.bytes_per_token()
    }

    /// Bytes actually held by allocated blocks.
    pub fn bytes_in_use(&self) -> usize {
        self.blocks_in_use() * self.block_size * self.bytes_per_token()
    }

    /// Bind `slot` to a fresh sequence: reserve `reserve_tokens` worth of
    /// blocks (its bounded lifetime demand) and materialise the first
    /// `initial_len` positions (the prompt, about to be spliced). No
    /// prefix sharing — shorthand for [`PagedKvCache::admit_slot_shared`]
    /// with an empty prompt.
    pub fn admit_slot(
        &mut self,
        slot: usize,
        reserve_tokens: usize,
        initial_len: usize,
    ) -> Result<()> {
        self.admit_slot_shared(slot, reserve_tokens, initial_len, &[])
            .map(|_| ())
    }

    /// Like [`PagedKvCache::admit_slot`], but first maps the longest
    /// indexed prefix of `prompt` into the slot's table (retaining each
    /// shared block) and reserves only the *unshared* remainder — a burst
    /// of same-prefix sequences costs one copy of the prefix plus one
    /// private tail each. Returns the number of shared token positions
    /// (always a multiple of the block size).
    ///
    /// Sharing caps at `floor((prompt_len - 1) / block_size)` full
    /// blocks, so at least one prompt position is always computed by the
    /// backend (the sequence's first logits) and the sequence never
    /// writes a shared block on the serving path — copy-on-write in
    /// [`PagedKvCache::row_mut`] stays a defensive backstop. When the
    /// unreserved pool is short, cached blocks only the index references
    /// are LRU-evicted to make room.
    pub fn admit_slot_shared(
        &mut self,
        slot: usize,
        reserve_tokens: usize,
        initial_len: usize,
        prompt: &[i32],
    ) -> Result<usize> {
        if slot >= self.tables.len() {
            bail!("slot out of range: {slot} >= {}", self.tables.len());
        }
        if !self.tables[slot].is_empty() || self.reserved[slot] != 0 {
            bail!("slot {slot} already admitted");
        }
        let total = self.blocks_for(reserve_tokens.max(initial_len));
        // Cap sharing one block below the prompt (the backend must
        // compute at least one position for the first logits) AND one
        // below the bounded demand (so `need >= 1` even for degenerate
        // reserve/prompt combinations a direct caller might pass).
        let max_share = (prompt.len().saturating_sub(1) / self.block_size)
            .min(total.saturating_sub(1));
        let matched = match self.prefix.as_mut() {
            Some(ix) if max_share > 0 => ix.lookup(prompt, self.block_size, max_share),
            _ => Vec::new(),
        };
        // Retain the shared chain *before* any eviction below, so the
        // blocks this admission depends on can never be its victims.
        for &b in &matched {
            self.alloc.retain(b)?;
        }
        let need = total - matched.len();
        if need > self.n_unreserved() {
            let short = need - self.n_unreserved();
            self.evict_for(short)?;
        }
        if need > self.n_unreserved() {
            for &b in &matched {
                self.alloc.release(b)?;
            }
            bail!(
                "out of cache blocks: slot {slot} needs {need} beyond its {} \
                 shared, {} unreserved",
                matched.len(),
                self.n_unreserved()
            );
        }
        let shared_tokens = matched.len() * self.block_size;
        if let Some(ix) = self.prefix.as_mut() {
            ix.record_shared(matched.len(), shared_tokens);
        }
        if let Some(cell) = self.quant.as_mut() {
            // Defensive: a fresh sequence must never read the previous
            // occupant's staged row (release_slot already dropped it).
            cell.get_mut().drop_stage(slot);
        }
        self.tables[slot] = matched;
        self.shared[slot] = shared_tokens;
        self.reserved[slot] = need;
        self.grow(slot, initial_len)?;
        Ok(shared_tokens)
    }

    /// The blocks a sharing admission of `prompt` would map right now —
    /// the scheduler's non-mutating planning view (no stats, no LRU).
    pub fn peek_shared(&self, prompt: &[i32]) -> Vec<usize> {
        let max_share = prompt.len().saturating_sub(1) / self.block_size;
        match &self.prefix {
            Some(ix) if max_share > 0 => ix.peek(prompt, self.block_size, max_share),
            _ => Vec::new(),
        }
    }

    /// Freshen the LRU stamp of `prompt`'s cached prefix chain (no
    /// stats, no mapping). Called for every request of an admission wave
    /// before any of them admits, so same-wave evictions prefer blocks
    /// no planned admission is counting on.
    pub fn touch_prefix(&mut self, prompt: &[i32]) {
        let max_share = prompt.len().saturating_sub(1) / self.block_size;
        if max_share > 0 {
            if let Some(ix) = self.prefix.as_mut() {
                ix.touch(prompt, self.block_size, max_share);
            }
        }
    }

    /// Cached blocks reclaimable right now: indexed, and referenced by
    /// nothing but the index (refcount 1).
    pub fn evictable_blocks(&self) -> Vec<usize> {
        match &self.prefix {
            Some(ix) => ix
                .blocks()
                .into_iter()
                .filter(|&b| self.alloc.refcount_of(b) == 1)
                .collect(),
            None => Vec::new(),
        }
    }

    /// Evict up to `want` LRU cached blocks that only the index still
    /// references, returning them to the free list. Returns how many
    /// were reclaimed (possibly fewer than asked).
    fn evict_for(&mut self, want: usize) -> Result<usize> {
        let Some(ix) = self.prefix.as_ref() else {
            return Ok(0);
        };
        let mut cands: Vec<(u64, usize)> = ix
            .candidates()
            .into_iter()
            .filter(|&(b, _)| self.alloc.refcount_of(b) == 1)
            .map(|(b, t)| (t, b))
            .collect();
        cands.sort_unstable();
        let mut freed = 0;
        for (_, b) in cands {
            if freed >= want {
                break;
            }
            self.prefix
                .as_mut()
                .expect("prefix index present")
                .remove_block(b);
            let went_free = self.alloc.release(b)?;
            debug_assert!(went_free, "evicted block {b} had hidden references");
            freed += 1;
        }
        Ok(freed)
    }

    /// Index `slot`'s fully-filled prompt blocks so later same-prefix
    /// admissions can share them. Call once the prompt is entirely in
    /// cache (post-splice, or when the final chunk lands). Only blocks
    /// completely covered by prompt tokens are indexed — decode writes
    /// always land beyond them. Returns how many blocks were newly
    /// cached; a no-op (0) when the index is off.
    pub fn register_prefix(&mut self, slot: usize, prompt: &[i32]) -> Result<usize> {
        if self.prefix.is_none() {
            return Ok(0);
        }
        if slot >= self.tables.len() {
            bail!("slot out of range: {slot} >= {}", self.tables.len());
        }
        let full = prompt.len() / self.block_size;
        if full == 0 {
            return Ok(0);
        }
        if self.tables[slot].len() < full {
            bail!(
                "slot {slot} table ({} blocks) does not cover its {full} full \
                 prompt blocks",
                self.tables[slot].len()
            );
        }
        if let Some(cell) = self.quant.as_mut() {
            // Flush *before* the index takes its reference: a block must
            // never become shareable while a newer truth for one of its
            // rows sits unencoded in staging (the staging invariant).
            cell.get_mut().flush_slot(slot);
        }
        let newly = self
            .prefix
            .as_mut()
            .expect("prefix index present")
            .insert_chain(prompt, self.block_size, &self.tables[slot][..full]);
        for &b in &newly {
            // The index's own reference: the block now outlives the slot.
            self.alloc.retain(b)?;
        }
        Ok(newly.len())
    }

    /// Bytes that sharing is saving right now: every table reference to a
    /// block beyond the first would be a private copy without sharing.
    pub fn bytes_deduped(&self) -> usize {
        let mut refs = vec![0usize; self.alloc.n_blocks()];
        for t in &self.tables {
            for &b in t {
                refs[b] += 1;
            }
        }
        let extra: usize = refs.iter().map(|&r| r.saturating_sub(1)).sum();
        extra * self.block_size * self.bytes_per_token()
    }

    /// Ensure the slot's table covers `len` token positions, drawing new
    /// blocks from the slot's admission-time reservation (so growth
    /// during decode can never race another sequence for memory).
    pub fn grow(&mut self, slot: usize, len: usize) -> Result<()> {
        if slot >= self.tables.len() {
            bail!("slot out of range: {slot} >= {}", self.tables.len());
        }
        let want = self.blocks_for(len);
        while self.tables[slot].len() < want {
            if self.reserved[slot] == 0 {
                bail!(
                    "slot {slot} grew past its reservation ({} blocks)",
                    self.tables[slot].len()
                );
            }
            let b = match self.alloc.alloc() {
                Some(b) => b,
                None => bail!("block pool exhausted despite reservation"),
            };
            self.reserved[slot] -= 1;
            self.tables[slot].push(b);
        }
        Ok(())
    }

    /// Release every block the slot holds plus its unused reservation;
    /// returns the number of blocks returned to the free list.
    pub fn release_slot(&mut self, slot: usize) -> Result<usize> {
        if slot >= self.tables.len() {
            bail!("slot out of range: {slot} >= {}", self.tables.len());
        }
        let blocks = std::mem::take(&mut self.tables[slot]);
        if let Some(cell) = self.quant.as_mut() {
            // The sequence is done: its staged row dies with it.
            cell.get_mut().drop_stage(slot);
        }
        let mut freed = 0;
        for b in blocks {
            // Shared or index-cached blocks survive (refcount stays > 0);
            // only the last holder actually frees.
            if self.alloc.release(b)? {
                freed += 1;
            }
        }
        self.reserved[slot] = 0;
        self.shared[slot] = 0;
        Ok(freed)
    }

    /// Shrink `slot`'s materialised coverage to at most `len` token
    /// positions — the speculative-decode rollback primitive. Tail
    /// blocks past the new end are `release`d back to the allocator,
    /// never zeroed, so a block another table or the prefix index still
    /// references survives with its bytes (and its other holders'
    /// refcounts) intact. Each block that actually frees re-credits the
    /// slot's reservation — it was drawn from that reservation by
    /// [`PagedKvCache::grow`], and the retracted positions will be
    /// re-grown on a later decode step. A still-shared block re-credits
    /// nothing: re-growing would need a genuinely free block, which its
    /// release did not produce (never hit on the serving path, where
    /// truncation stays above the prompt and decode blocks are private).
    ///
    /// Positions below the shared-prefix watermark are never truncated:
    /// the mapped blocks hold prompt content the slot logically still
    /// covers.
    pub fn truncate(&mut self, slot: usize, len: usize) -> Result<()> {
        if slot >= self.tables.len() {
            bail!("slot out of range: {slot} >= {}", self.tables.len());
        }
        let floor = self.shared[slot];
        let want = self.blocks_for(len.max(floor));
        while self.tables[slot].len() > want {
            let b = self.tables[slot].pop().expect("non-empty table");
            if let Some(cell) = self.quant.as_mut() {
                let q = cell.get_mut();
                // Rollback drops (never flushes) a staged row of a
                // retracted block — mirroring the fp32 pool, whose
                // popped blocks simply keep their stale bytes.
                if matches!(q.stage[slot].key, Some((sb, _, _)) if sb == b) {
                    q.drop_stage(slot);
                }
            }
            if self.alloc.release(b)? {
                self.reserved[slot] += 1;
            }
        }
        Ok(())
    }

    /// Does the slot's table cover token position `pos`? (False for idle
    /// slots — backends use this as the position mask.)
    pub fn covers(&self, slot: usize, pos: usize) -> bool {
        match self.tables.get(slot) {
            Some(t) => pos / self.block_size < t.len(),
            None => false,
        }
    }

    /// Resolve (slot, layer, pos) to the pool row key
    /// `(block, layer, offset-within-block)`, with bounds checks.
    fn row_key(&self, slot: usize, layer: usize, pos: usize) -> Result<(usize, usize, usize)> {
        let table = match self.tables.get(slot) {
            Some(t) => t,
            None => bail!("slot out of range: {slot} >= {}", self.tables.len()),
        };
        let block = match table.get(pos / self.block_size) {
            Some(&b) => b,
            None => bail!(
                "position {pos} beyond slot {slot}'s block table ({} blocks)",
                table.len()
            ),
        };
        if layer >= self.n_layers {
            bail!("layer {layer} out of range");
        }
        Ok((block, layer, pos % self.block_size))
    }

    fn offset(&self, buf: usize, slot: usize, layer: usize, pos: usize) -> Result<usize> {
        let (block, layer, off) = self.row_key(slot, layer, pos)?;
        let inner = self.pool[buf].shape[3];
        Ok(((block * self.n_layers + layer) * self.block_size + off) * inner)
    }

    /// The inner-dim row of pool buffer `buf` at (slot, layer, pos).
    ///
    /// With a lossy codec this is a decode-on-read through the slot's
    /// staging buffer, which may displace (write back) the previously
    /// staged row of the *same slot* — so a returned reference must not
    /// be held across an access to a different row of that slot. Reads
    /// of the two buffers of one row never restage (one key covers
    /// both), which is exactly the pattern backends use.
    pub fn row(&self, buf: usize, slot: usize, layer: usize, pos: usize) -> Result<&[f32]> {
        let Some(cell) = &self.quant else {
            let inner = self.pool[buf].shape[3];
            let o = self.offset(buf, slot, layer, pos)?;
            return Ok(&self.pool[buf].data[o..o + inner]);
        };
        let key = self.row_key(slot, layer, pos)?;
        // SAFETY: interior staging from `&self` under the documented
        // row discipline (module docs): no reference into this slot's
        // staging outlives a staging change, and concurrent streams
        // touch disjoint slots. The `&mut` below is confined to this
        // call and only taken when the key actually changes.
        unsafe {
            if (*cell.get()).stage[slot].key != Some(key) {
                (*cell.get()).stage_row(slot, key);
            }
            Ok(&(*cell.get()).stage[slot].data[buf][..])
        }
    }

    /// Mutable row access, with **copy-on-write**: when the block holding
    /// `pos` is also referenced by another table or the prefix index, the
    /// slot first gets a private copy (all layers, both buffers), so the
    /// write can never corrupt another reader's bytes.
    ///
    /// With a lossy codec the returned row is the slot's staged f32
    /// image, marked dirty; it is encoded back into the (now private)
    /// block when the staging moves on — the CoW above is what keeps
    /// dirty staged rows confined to refcount-1 blocks.
    pub fn row_mut(
        &mut self,
        buf: usize,
        slot: usize,
        layer: usize,
        pos: usize,
    ) -> Result<&mut [f32]> {
        self.ensure_private(slot, pos)?;
        if self.quant.is_some() {
            let key = self.row_key(slot, layer, pos)?;
            let q = self.quant.as_mut().expect("quant state").get_mut();
            q.stage_row(slot, key);
            q.stage[slot].dirty[buf] = true;
            return Ok(&mut q.stage[slot].data[buf][..]);
        }
        let inner = self.pool[buf].shape[3];
        let o = self.offset(buf, slot, layer, pos)?;
        Ok(&mut self.pool[buf].data[o..o + inner])
    }

    /// Copy-on-write: if `slot`'s block holding `pos` has other holders
    /// (refcount > 1), copy its full contents into a fresh block and
    /// repoint the table entry. Draws on the unreserved pool (evicting
    /// cached blocks if needed) so outstanding reservations stay intact.
    fn ensure_private(&mut self, slot: usize, pos: usize) -> Result<()> {
        let idx = pos / self.block_size;
        let b = match self.tables.get(slot).and_then(|t| t.get(idx)) {
            Some(&b) => b,
            // Out-of-range slots/positions fall through to `offset`'s
            // error on the actual access.
            None => return Ok(()),
        };
        if self.alloc.refcount_of(b) <= 1 {
            return Ok(());
        }
        if self.n_unreserved() == 0 {
            self.evict_for(1)?;
        }
        if self.n_unreserved() == 0 {
            bail!(
                "block pool exhausted during copy-on-write of block {b} \
                 (reservations hold the remaining free blocks)"
            );
        }
        let nb = match self.alloc.alloc() {
            Some(nb) => nb,
            None => bail!("block pool exhausted during copy-on-write of block {b}"),
        };
        if let Some(cell) = self.quant.as_mut() {
            // Encoded blocks copy as opaque bytes — no decode round-trip,
            // so the copy is bit-exact for every holder.
            let q = cell.get_mut();
            for buf in 0..2 {
                let stride = q.block_stride(buf);
                q.pools[buf].copy_within(b * stride..(b + 1) * stride, nb * stride);
            }
            // The slot's staged image of the shared block (necessarily
            // clean: dirty rows live in refcount-1 blocks) moves with
            // its table entry.
            if let Some((sb, l, o)) = q.stage[slot].key {
                if sb == b {
                    q.stage[slot].key = Some((nb, l, o));
                }
            }
        } else {
            for buf in &mut self.pool {
                let stride = self.n_layers * self.block_size * buf.shape[3];
                buf.data.copy_within(b * stride..(b + 1) * stride, nb * stride);
            }
        }
        // Drop this slot's reference to the shared block; it cannot free
        // (other holders remain), and any index entry stays with it.
        self.alloc.release(b)?;
        self.tables[slot][idx] = nb;
        Ok(())
    }

    /// Splice prefill output (tensors `[L, Bp, T, inner...]`) row `src`
    /// into `slot`, copying only the first `len` positions — unlike the
    /// fixed pool there is no padded tail to fill. The slot must already
    /// cover `len` positions (admit_slot/grow first). Positions below the
    /// slot's shared-prefix watermark are skipped: the mapped blocks
    /// already hold exactly those rows (same tokens, same content), which
    /// is the whole point of sharing them.
    pub fn splice_from(
        &mut self,
        prefill_bufs: &[Tensor],
        src: usize,
        slot: usize,
        len: usize,
    ) -> Result<()> {
        if prefill_bufs.len() != self.pool.len() {
            bail!("layout mismatch");
        }
        if len > 0 && !self.covers(slot, len - 1) {
            bail!("slot {slot} block table does not cover {len} positions");
        }
        let start = self.shared.get(slot).copied().unwrap_or(0).min(len);
        // Defensive CoW pre-pass over every block this splice writes —
        // the serving path never splices into shared blocks (the skip
        // above), but a direct caller must not corrupt other readers.
        let mut p = start;
        while p < len {
            self.ensure_private(slot, p)?;
            p = (p / self.block_size + 1) * self.block_size;
        }
        if let Some(cell) = self.quant.as_mut() {
            // The splice writes the pool directly below: persist any
            // staged write elsewhere in the slot, then invalidate the
            // staging so later reads decode the freshly spliced bytes.
            let q = cell.get_mut();
            q.flush_slot(slot);
            q.drop_stage(slot);
        }
        for (i, theirs) in prefill_bufs.iter().enumerate() {
            if theirs.shape.len() < 3 || theirs.shape[0] != self.n_layers {
                bail!(
                    "cache layer count mismatch: pool has {} layers, \
                     prefill buffer is {:?}",
                    self.n_layers, theirs.shape
                );
            }
            let bp = theirs.shape[1];
            let t = theirs.shape[2];
            let inner: usize = theirs.shape[3..].iter().product();
            if inner != self.pool[i].shape[3] {
                bail!(
                    "cache inner shape mismatch {:?} vs {:?}",
                    self.pool[i].shape, theirs.shape
                );
            }
            if src >= bp {
                bail!("slot out of range");
            }
            if len > t {
                bail!("splice wants {len} positions, prefill has {t}");
            }
            for l in 0..self.n_layers {
                for pos in start..len {
                    let src_off = ((l * bp + src) * t + pos) * inner;
                    let src_row = &theirs.data[src_off..src_off + inner];
                    if self.quant.is_some() {
                        // Encode straight into the pool — the splice is
                        // the one bulk path that bypasses staging.
                        let (block, _, off) = self.row_key(slot, l, pos)?;
                        let q = self.quant.as_mut().expect("quant state").get_mut();
                        let r = q.row_range(i, block, l, off);
                        q.kind.encode_row(src_row, &mut q.pools[i][r]);
                    } else {
                        let dst_off = self.offset(i, slot, l, pos)?;
                        self.pool[i].data[dst_off..dst_off + inner]
                            .copy_from_slice(src_row);
                    }
                }
            }
        }
        Ok(())
    }

    /// Allocator consistency plus table/refcount agreement: every block
    /// reference in some table — plus the prefix index's one reference
    /// per cached block — is accounted for by exactly its refcount, and
    /// outstanding reservations never exceed the free list.
    pub fn check_invariants(&self) -> Result<()> {
        self.alloc.check_invariants()?;
        let mut refs = vec![0u32; self.alloc.n_blocks()];
        for (slot, table) in self.tables.iter().enumerate() {
            for &b in table {
                if b >= refs.len() {
                    bail!("slot {slot} references out-of-range block {b}");
                }
                refs[b] += 1;
            }
        }
        if let Some(ix) = &self.prefix {
            ix.check()?;
            for b in ix.blocks() {
                if b >= refs.len() {
                    bail!("prefix index references out-of-range block {b}");
                }
                refs[b] += 1;
            }
        }
        for (b, &r) in refs.iter().enumerate() {
            if r != self.alloc.refcount_of(b) {
                bail!(
                    "block {b} refcount {} != {r} table+index references",
                    self.alloc.refcount_of(b)
                );
            }
        }
        if self.blocks_reserved() > self.alloc.n_free() {
            bail!(
                "reserved {} blocks exceed {} free",
                self.blocks_reserved(),
                self.alloc.n_free()
            );
        }
        for (slot, &s) in self.shared.iter().enumerate() {
            if s % self.block_size != 0 {
                bail!("slot {slot} shared watermark {s} is not block-aligned");
            }
            if s > self.tables[slot].len() * self.block_size {
                bail!("slot {slot} shared watermark {s} exceeds its table");
            }
        }
        if let Some(cell) = &self.quant {
            // SAFETY: shared read; invariant checks never run concurrently
            // with a staging mutation (same discipline as `row`).
            let q = unsafe { &*cell.get() };
            for (slot, st) in q.stage.iter().enumerate() {
                let Some((b, _, _)) = st.key else { continue };
                if !self.tables[slot].contains(&b) {
                    bail!(
                        "slot {slot} stages block {b} absent from its table"
                    );
                }
                if st.dirty.iter().any(|&d| d) && self.alloc.refcount_of(b) != 1 {
                    bail!(
                        "staging invariant broken: slot {slot} has a dirty \
                         staged row in shared block {b} (refcount {})",
                        self.alloc.refcount_of(b)
                    );
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, PropConfig};
    use crate::util::Rng;

    fn mla_cache(slots: usize, block_size: usize, blocks: usize) -> PagedKvCache {
        PagedKvCache::new(CacheLayout::Mla { r: 2, dr: 2 }, 2, slots, block_size, blocks)
            .unwrap()
    }

    #[test]
    fn allocator_alloc_release_cycle() {
        let mut a = BlockAllocator::new(3);
        let b1 = a.alloc().unwrap();
        let b2 = a.alloc().unwrap();
        assert_ne!(b1, b2);
        assert_eq!(a.n_in_use(), 2);
        assert!(a.release(b1).unwrap(), "refcount 1 frees");
        assert!(a.release(b1).is_err(), "double free must fail");
        a.check_invariants().unwrap();
    }

    #[test]
    fn allocator_refcounts_defer_the_free() {
        let mut a = BlockAllocator::new(2);
        let b = a.alloc().unwrap();
        a.retain(b).unwrap();
        assert_eq!(a.refcount_of(b), 2);
        assert!(!a.release(b).unwrap(), "still referenced");
        assert!(a.release(b).unwrap(), "last ref frees");
        assert!(a.retain(b).is_err(), "retain of a free block must fail");
        a.check_invariants().unwrap();
    }

    #[test]
    fn allocator_exhaustion_returns_none() {
        let mut a = BlockAllocator::new(1);
        assert!(a.alloc().is_some());
        assert!(a.alloc().is_none());
    }

    #[test]
    fn props_block_allocator_invariants_under_random_workload() {
        check(
            "block_allocator_invariants",
            PropConfig { cases: 200, seed: 77 },
            |r: &mut Rng| {
                let n = 1 + r.below(8);
                let ops: Vec<u8> = (0..96).map(|_| r.next_u64() as u8).collect();
                (n, ops)
            },
            |(n, ops)| {
                let mut a = BlockAllocator::new(*n);
                // live[i] = (block, refs we still hold on it)
                let mut live: Vec<(usize, u32)> = vec![];
                for &op in ops {
                    match op % 3 {
                        0 => {
                            if let Some(b) = a.alloc() {
                                if live.iter().any(|&(x, _)| x == b) {
                                    return Err(format!("block {b} double-allocated"));
                                }
                                live.push((b, 1));
                            } else if live.len() != *n {
                                return Err("alloc failed below capacity".into());
                            }
                        }
                        1 => {
                            if !live.is_empty() {
                                let i = (op as usize / 3) % live.len();
                                live[i].1 += 1;
                                a.retain(live[i].0).map_err(|e| e.to_string())?;
                            }
                        }
                        _ => {
                            if !live.is_empty() {
                                let i = (op as usize / 3) % live.len();
                                let freed =
                                    a.release(live[i].0).map_err(|e| e.to_string())?;
                                live[i].1 -= 1;
                                if freed != (live[i].1 == 0) {
                                    return Err(format!(
                                        "block {} freed={freed} with {} refs held",
                                        live[i].0, live[i].1
                                    ));
                                }
                                if live[i].1 == 0 {
                                    live.remove(i);
                                }
                            }
                        }
                    }
                    a.check_invariants().map_err(|e| e.to_string())?;
                    if a.n_in_use() != live.len() {
                        return Err("in-use count mismatch".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn admit_grow_release_lifecycle() {
        let mut c = mla_cache(2, 4, 6);
        // Reserve 10 tokens (3 blocks), materialise the 5-token prompt.
        c.admit_slot(0, 10, 5).unwrap();
        assert_eq!(c.blocks_in_use(), 2, "5 tokens span 2 blocks of 4");
        assert_eq!(c.blocks_reserved(), 1, "one block still reserved");
        assert_eq!(c.n_unreserved(), 3);
        assert!(c.covers(0, 4) && !c.covers(0, 8));
        c.grow(0, 9).unwrap();
        assert_eq!(c.blocks_in_use(), 3);
        assert_eq!(c.blocks_reserved(), 0);
        assert!(c.grow(0, 13).is_err(), "growth past reservation fails");
        c.check_invariants().unwrap();
        assert_eq!(c.release_slot(0).unwrap(), 3);
        assert_eq!(c.blocks_in_use(), 0);
        assert_eq!(c.n_unreserved(), 6);
        c.check_invariants().unwrap();
    }

    #[test]
    fn admission_respects_outstanding_reservations() {
        let mut c = mla_cache(3, 4, 4);
        // Slot 0 reserves 3 blocks but only materialises 1.
        c.admit_slot(0, 12, 2).unwrap();
        assert_eq!(c.n_unreserved(), 1);
        // A second sequence may only take the 1 unreserved block.
        assert!(c.admit_slot(1, 8, 2).is_err(), "would eat slot 0's reserve");
        c.admit_slot(1, 4, 2).unwrap();
        assert_eq!(c.n_unreserved(), 0);
        assert!(c.admit_slot(2, 1, 1).is_err(), "pool fully committed");
        // Slot 0's lazy growth still succeeds: its blocks were promised.
        c.grow(0, 12).unwrap();
        c.check_invariants().unwrap();
    }

    #[test]
    fn truncate_releases_tail_blocks_and_recredits_the_reservation() {
        let mut c = mla_cache(2, 4, 6);
        // Reserve 16 tokens (4 blocks), materialise the 5-token prompt.
        c.admit_slot(0, 16, 5).unwrap();
        c.grow(0, 13).unwrap();
        assert_eq!((c.blocks_in_use(), c.reserved_of(0)), (4, 0));
        // Roll back to 6 positions: two tail blocks free and their
        // reservation comes back, so the re-grow below cannot fail.
        c.truncate(0, 6).unwrap();
        assert_eq!((c.blocks_in_use(), c.reserved_of(0)), (2, 2));
        assert!(c.covers(0, 5) && !c.covers(0, 8));
        c.check_invariants().unwrap();
        c.grow(0, 13).unwrap();
        assert_eq!((c.blocks_in_use(), c.reserved_of(0)), (4, 0));
        c.truncate(0, 0).unwrap();
        assert_eq!(c.blocks_in_use(), 0);
        assert!(c.truncate(9, 0).is_err(), "slot out of range");
        c.check_invariants().unwrap();
        c.release_slot(0).unwrap();
        c.check_invariants().unwrap();
    }

    #[test]
    fn double_admit_and_bad_slots_error() {
        let mut c = mla_cache(2, 4, 4);
        c.admit_slot(0, 4, 2).unwrap();
        assert!(c.admit_slot(0, 4, 2).is_err(), "slot already admitted");
        assert!(c.admit_slot(9, 4, 2).is_err(), "slot out of range");
        assert!(c.grow(9, 1).is_err());
        assert!(c.release_slot(9).is_err());
        assert!(c.row(0, 0, 0, 7).is_err(), "beyond the block table");
    }

    #[test]
    fn rows_roundtrip_through_blocks() {
        let mut c = mla_cache(2, 4, 8);
        c.admit_slot(1, 7, 7).unwrap();
        for pos in 0..7 {
            for l in 0..2 {
                let v = (pos * 10 + l) as f32;
                c.row_mut(0, 1, l, pos).unwrap().fill(v);
                c.row_mut(1, 1, l, pos).unwrap().fill(-v);
            }
        }
        for pos in 0..7 {
            for l in 0..2 {
                let v = (pos * 10 + l) as f32;
                assert_eq!(c.row(0, 1, l, pos).unwrap(), [v, v]);
                assert_eq!(c.row(1, 1, l, pos).unwrap(), [-v, -v]);
            }
        }
        c.check_invariants().unwrap();
    }

    #[test]
    fn splice_copies_the_right_prefill_row() {
        let mut c = mla_cache(2, 4, 8);
        c.admit_slot(0, 6, 6).unwrap();
        // Prefill buffers [L=2, Bp=3, T=8, inner=2]; mark row 1.
        let mut src_c = Tensor::zeros(&[2, 3, 8, 2]);
        let src_kr = Tensor::zeros(&[2, 3, 8, 2]);
        for l in 0..2 {
            for t in 0..8 {
                for x in 0..2 {
                    src_c.data[((l * 3 + 1) * 8 + t) * 2 + x] =
                        (l * 1000 + t * 10 + x) as f32;
                }
            }
        }
        c.splice_from(&[src_c, src_kr], 1, 0, 6).unwrap();
        assert_eq!(c.row(0, 0, 0, 0).unwrap(), [0.0, 1.0]);
        assert_eq!(c.row(0, 0, 0, 5).unwrap(), [50.0, 51.0]);
        assert_eq!(c.row(0, 0, 1, 3).unwrap(), [1030.0, 1031.0]);
        // Positions past the splice length were never touched.
        assert!(c.row(0, 0, 0, 6).is_err(), "position 6 not materialised");
    }

    #[test]
    fn splice_validates_layer_count_like_the_fixed_pool() {
        let mut c = mla_cache(1, 4, 4);
        c.admit_slot(0, 4, 4).unwrap();
        let short_c = Tensor::zeros(&[1, 1, 4, 2]);
        let short_kr = Tensor::zeros(&[1, 1, 4, 2]);
        let err = c.splice_from(&[short_c, short_kr], 0, 0, 4).unwrap_err();
        assert!(err.to_string().contains("layer count"), "{err}");
    }

    #[test]
    fn byte_accounting_tracks_blocks_not_worst_case() {
        let c0 = mla_cache(4, 16, 16);
        assert_eq!(c0.bytes_per_token(), (2 + 2) * 2 * 4);
        assert_eq!(c0.bytes_total(), 16 * 16 * c0.bytes_per_token());
        assert_eq!(c0.bytes_in_use(), 0);
        let mut c = mla_cache(4, 16, 16);
        c.admit_slot(0, 20, 20).unwrap();
        assert_eq!(c.bytes_in_use(), 2 * 16 * c.bytes_per_token());
    }

    // -- prefix sharing + copy-on-write --------------------------------------

    /// A cache with the prefix index on, slot 0 prefilled with `prompt`
    /// via row_mut (the chunk path's write shape) and registered.
    fn shared_setup(
        slots: usize,
        block_size: usize,
        blocks: usize,
        prompt: &[i32],
    ) -> PagedKvCache {
        let mut c = PagedKvCache::new(
            CacheLayout::Mla { r: 2, dr: 2 },
            2,
            slots,
            block_size,
            blocks,
        )
        .unwrap();
        c.enable_prefix_cache();
        let shared = c
            .admit_slot_shared(0, prompt.len() + 2, prompt.len(), prompt)
            .unwrap();
        assert_eq!(shared, 0, "empty index shares nothing");
        for pos in 0..prompt.len() {
            for l in 0..2 {
                let v = (prompt[pos] * 100 + l as i32) as f32;
                c.row_mut(0, 0, l, pos).unwrap().fill(v);
                c.row_mut(1, 0, l, pos).unwrap().fill(-v);
            }
        }
        c.register_prefix(0, prompt).unwrap();
        c.check_invariants().unwrap();
        c
    }

    #[test]
    fn prefix_sharing_maps_cached_blocks_and_reserves_the_remainder() {
        let prompt: Vec<i32> = (0..10).collect();
        // block_size 4: prompt 10 -> 2 full blocks cacheable, sharing
        // capped at floor(9/4) = 2 blocks = 8 tokens.
        let mut c = shared_setup(3, 4, 12, &prompt);
        assert_eq!(c.prefix_stats().unwrap().blocks_cached, 2);
        let before = c.blocks_in_use();
        let shared = c
            .admit_slot_shared(1, prompt.len() + 2, 0, &prompt)
            .unwrap();
        assert_eq!(shared, 8, "two full blocks shared");
        // Bounded demand 12 tokens = 3 blocks; only the unshared third is
        // reserved, nothing new materialised yet.
        assert_eq!(c.blocks_in_use(), before, "sharing allocates nothing");
        assert_eq!(c.reserved_of(1), 1);
        // The shared rows read back slot 0's bytes.
        assert_eq!(c.row(0, 1, 0, 5).unwrap(), c.row(0, 0, 0, 5).unwrap());
        let s = c.prefix_stats().unwrap();
        assert_eq!((s.hits, s.blocks_shared, s.tokens_shared), (1, 2, 8));
        c.check_invariants().unwrap();
    }

    #[test]
    fn cached_prefix_survives_the_writer_and_eviction_reclaims_it() {
        let prompt: Vec<i32> = (0..10).collect();
        let mut c = shared_setup(2, 4, 8, &prompt);
        // The writer completes: its private tail frees, the 2 cached
        // prefix blocks stay resident for future admissions.
        c.release_slot(0).unwrap();
        assert_eq!(c.blocks_in_use(), 2, "prefix blocks outlive the writer");
        let shared = c
            .admit_slot_shared(0, prompt.len() + 2, 0, &prompt)
            .unwrap();
        assert_eq!(shared, 8, "hit after the writer completed");
        c.release_slot(0).unwrap();
        // A big unsharable admission forces LRU eviction of the cache.
        let other: Vec<i32> = (50..80).collect();
        c.admit_slot_shared(1, 30, 0, &other).unwrap();
        assert_eq!(c.reserved_of(1), 8, "whole pool reserved");
        assert_eq!(c.prefix_stats().unwrap().blocks_cached, 0);
        assert_eq!(c.prefix_stats().unwrap().evictions, 2);
        c.check_invariants().unwrap();
    }

    #[test]
    fn cow_write_preserves_the_readers_bytes() {
        let prompt: Vec<i32> = (0..10).collect();
        let mut c = shared_setup(2, 4, 12, &prompt);
        c.admit_slot_shared(1, prompt.len() + 2, 0, &prompt).unwrap();
        let reader_row: Vec<f32> = c.row(0, 0, 0, 5).unwrap().to_vec();
        // Slot 1 writes a shared position (never happens on the serving
        // path; row_mut must copy-on-write).
        c.row_mut(0, 1, 0, 5).unwrap().fill(777.0);
        assert_eq!(
            c.row(0, 0, 0, 5).unwrap(),
            &reader_row[..],
            "CoW must not touch the reader's block"
        );
        assert_eq!(c.row(0, 1, 0, 5).unwrap(), [777.0, 777.0]);
        // Untouched positions of the copied block carried over.
        assert_eq!(c.row(0, 1, 1, 4).unwrap(), c.row(0, 0, 1, 4).unwrap());
        c.check_invariants().unwrap();
    }

    #[test]
    fn release_of_a_sharing_sequence_never_frees_mapped_blocks() {
        let prompt: Vec<i32> = (0..10).collect();
        let mut c = shared_setup(2, 4, 12, &prompt);
        c.admit_slot_shared(1, prompt.len() + 2, 0, &prompt).unwrap();
        let row: Vec<f32> = c.row(0, 1, 0, 3).unwrap().to_vec();
        // Releasing the original writer must leave slot 1's mapped
        // blocks fully readable.
        c.release_slot(0).unwrap();
        assert_eq!(c.row(0, 1, 0, 3).unwrap(), &row[..]);
        c.check_invariants().unwrap();
        c.release_slot(1).unwrap();
        // Now only the index holds the prefix blocks.
        assert_eq!(c.blocks_in_use(), 2);
        c.check_invariants().unwrap();
    }

    #[test]
    fn truncate_keeps_shared_prefix_blocks_mapped() {
        let prompt: Vec<i32> = (0..10).collect();
        let mut c = shared_setup(2, 4, 12, &prompt);
        // Slot 1 maps the 2 cached prefix blocks (8 tokens) and grows a
        // private tail block.
        c.admit_slot_shared(1, 14, 0, &prompt).unwrap();
        c.grow(1, 12).unwrap();
        let reader_row: Vec<f32> = c.row(0, 1, 0, 5).unwrap().to_vec();
        // Truncating below the shared watermark clamps at it: the
        // private tail frees, the mapped prefix blocks survive with
        // their bytes and their other holders' refcounts intact.
        c.truncate(1, 4).unwrap();
        assert!(c.covers(1, 7), "shared watermark is the truncation floor");
        assert!(!c.covers(1, 8), "private tail released");
        assert_eq!(c.row(0, 1, 0, 5).unwrap(), &reader_row[..]);
        assert_eq!(c.reserved_of(1), 2, "freed tail re-credits the reservation");
        c.check_invariants().unwrap();
        c.release_slot(1).unwrap();
        c.check_invariants().unwrap();
    }

    #[test]
    fn props_truncate_rollback_under_random_accept_reject() {
        // The speculative-decode lifecycle against the block ledger:
        // propose (grow k positions), accept a random prefix (truncate
        // the rejected tail). Throughout, the slot's table plus its
        // outstanding reservation must equal its admission-time bounded
        // demand exactly — no leak, no double-free — and a reader
        // sharing the prompt prefix must keep its bytes.
        check(
            "truncate_rollback",
            PropConfig { cases: 80, seed: 4242 },
            |r: &mut Rng| {
                let bs = 2 + r.below(3); // 2..=4
                let plen = bs + 1 + r.below(2 * bs);
                let ops: Vec<u64> = (0..24).map(|_| r.next_u64()).collect();
                (bs, plen, ops)
            },
            |(bs, plen, ops)| {
                let prompt: Vec<i32> = (0..*plen as i32).collect();
                let cap = *plen + 16;
                let mut c =
                    PagedKvCache::new(CacheLayout::Mla { r: 2, dr: 2 }, 1, 2, *bs, 48)
                        .map_err(|e| e.to_string())?;
                c.enable_prefix_cache();
                c.admit_slot_shared(0, cap, *plen, &prompt)
                    .map_err(|e| e.to_string())?;
                for pos in 0..*plen {
                    c.row_mut(0, 0, 0, pos)
                        .map_err(|e| e.to_string())?
                        .fill(pos as f32);
                }
                c.register_prefix(0, &prompt).map_err(|e| e.to_string())?;
                let shared_blocks = c
                    .admit_slot_shared(1, cap, *plen, &prompt)
                    .map_err(|e| e.to_string())?
                    / *bs;
                let demand = c.blocks_for(cap) - shared_blocks;
                let table_len = |c: &PagedKvCache, len: usize| {
                    // covers() probes reconstruct the table length.
                    let mut blocks = 0;
                    while c.covers(1, blocks * *bs) {
                        blocks += 1;
                    }
                    if blocks != c.blocks_for(len) {
                        return Err(format!(
                            "table covers {blocks} blocks, expected {} for len {len}",
                            c.blocks_for(len)
                        ));
                    }
                    Ok(blocks)
                };
                let mut len = *plen;
                for &op in ops {
                    let k = 1 + (op as usize) % 4;
                    let grown = (len + k).min(cap);
                    c.grow(1, grown).map_err(|e| e.to_string())?;
                    let accepted = (op as usize / 8) % (grown - len + 1);
                    len += accepted;
                    c.truncate(1, len).map_err(|e| e.to_string())?;
                    let blocks = table_len(&c, len)?;
                    // Ledger: materialised + outstanding == bounded
                    // demand, always (the no-leak/no-double-free claim).
                    if blocks - shared_blocks + c.reserved_of(1) != demand {
                        return Err(format!(
                            "ledger broke: {blocks} mapped ({shared_blocks} shared), \
                             {} reserved, demand {demand}",
                            c.reserved_of(1)
                        ));
                    }
                    c.check_invariants().map_err(|e| e.to_string())?;
                }
                // The sharing reader's bytes survived every rollback.
                for pos in 0..*plen {
                    let got = c.row(0, 0, 0, pos).map_err(|e| e.to_string())?;
                    if got != [pos as f32, pos as f32] {
                        return Err(format!("reader corrupted at pos {pos}: {got:?}"));
                    }
                }
                c.release_slot(0).map_err(|e| e.to_string())?;
                c.release_slot(1).map_err(|e| e.to_string())?;
                c.check_invariants().map_err(|e| e.to_string())
            },
        );
    }

    #[test]
    fn props_cow_under_random_sharing_preserves_every_reader() {
        check(
            "cow_preserves_readers",
            PropConfig { cases: 60, seed: 1213 },
            |r: &mut Rng| {
                let bs = 2 + r.below(4); // 2..=5
                let plen = bs + 1 + r.below(3 * bs); // at least one full block
                let writes: Vec<u64> = (0..12).map(|_| r.next_u64()).collect();
                (bs, plen, writes)
            },
            |(bs, plen, writes)| {
                let prompt: Vec<i32> = (0..*plen as i32).collect();
                let mut c = PagedKvCache::new(
                    CacheLayout::Mla { r: 2, dr: 2 },
                    1,
                    3,
                    *bs,
                    24,
                )
                .map_err(|e| e.to_string())?;
                c.enable_prefix_cache();
                c.admit_slot_shared(0, *plen + 2, *plen, &prompt)
                    .map_err(|e| e.to_string())?;
                for pos in 0..*plen {
                    c.row_mut(0, 0, 0, pos)
                        .map_err(|e| e.to_string())?
                        .fill(pos as f32);
                }
                c.register_prefix(0, &prompt).map_err(|e| e.to_string())?;
                let shared = c
                    .admit_slot_shared(1, *plen + 2, 0, &prompt)
                    .map_err(|e| e.to_string())?;
                if shared != ((*plen - 1) / *bs) * *bs {
                    return Err(format!("shared {shared} for plen {plen} bs {bs}"));
                }
                // Random writes through slot 1 at shared positions: slot
                // 0 must keep reading its own bytes at every position.
                for &w in writes {
                    if shared == 0 {
                        break;
                    }
                    let pos = (w as usize) % shared;
                    c.row_mut(0, 1, 0, pos)
                        .map_err(|e| e.to_string())?
                        .fill(9000.0 + pos as f32);
                    c.check_invariants().map_err(|e| e.to_string())?;
                }
                for pos in 0..*plen {
                    let got = c.row(0, 0, 0, pos).map_err(|e| e.to_string())?;
                    if got != [pos as f32, pos as f32] {
                        return Err(format!("reader corrupted at pos {pos}: {got:?}"));
                    }
                }
                // Both lifecycles unwind cleanly under sharing + CoW.
                c.release_slot(0).map_err(|e| e.to_string())?;
                c.release_slot(1).map_err(|e| e.to_string())?;
                c.check_invariants().map_err(|e| e.to_string())
            },
        );
    }

    // -- quantized blocks ----------------------------------------------------

    fn quant_cache(
        kind: QuantKind,
        slots: usize,
        block_size: usize,
        blocks: usize,
    ) -> PagedKvCache {
        PagedKvCache::new_quant(
            CacheLayout::Mla { r: 2, dr: 2 },
            2,
            slots,
            block_size,
            blocks,
            kind,
        )
        .unwrap()
    }

    #[test]
    fn quant_rows_roundtrip_through_staging_within_tolerance() {
        // The staged write-back path: values survive encode/decode within
        // the int8 tolerance (max|row|/254), and re-reads are stable.
        let mut c = quant_cache(QuantKind::Int8, 2, 4, 8);
        c.admit_slot(1, 7, 7).unwrap();
        for pos in 0..7 {
            for l in 0..2 {
                let v = (pos * 10 + l) as f32;
                c.row_mut(0, 1, l, pos).unwrap().fill(v);
                c.row_mut(1, 1, l, pos).unwrap().fill(-v);
            }
        }
        for pos in 0..7 {
            for l in 0..2 {
                let v = (pos * 10 + l) as f32;
                let r0 = c.row(0, 1, l, pos).unwrap().to_vec();
                let r1 = c.row(1, 1, l, pos).unwrap().to_vec();
                for (got, want) in
                    r0.iter().chain(r1.iter()).zip([v, v, -v, -v])
                {
                    assert!(
                        (got - want).abs() <= want.abs() / 250.0 + 1e-6,
                        "pos {pos} l {l}: {got} vs {want}"
                    );
                }
            }
        }
        c.check_invariants().unwrap();
        // Zero-init: unwritten rows of a covered block decode to zeros,
        // exactly like the fp32 pool.
        c.admit_slot(0, 3, 3).unwrap();
        assert!(c.row(0, 0, 0, 1).unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn quant_both_buffers_of_one_row_read_in_one_expression() {
        // The backend pattern `f(c.row(0, ..)?, c.row(1, ..)?)`: one key
        // covers both buffers, so the second read never restages and the
        // first reference stays valid.
        let mut c = quant_cache(QuantKind::Int8, 1, 4, 4);
        c.admit_slot(0, 4, 4).unwrap();
        c.row_mut(0, 0, 0, 2).unwrap().fill(42.0);
        c.row_mut(1, 0, 0, 2).unwrap().fill(-7.0);
        let sum: f32 = c
            .row(0, 0, 0, 2)
            .unwrap()
            .iter()
            .chain(c.row(1, 0, 0, 2).unwrap().iter())
            .sum();
        assert!((sum - (42.0 * 2.0 - 7.0 * 2.0)).abs() < 0.5, "sum {sum}");
    }

    #[test]
    fn quant_cow_write_preserves_the_readers_decoded_bytes() {
        // CoW over encoded blocks: the reader's *decoded* rows must be
        // bit-stable across another slot's write (encoded bytes move as
        // opaque bytes; decode is deterministic).
        let prompt: Vec<i32> = (0..10).collect();
        let mut c = quant_cache(QuantKind::Int8, 2, 4, 12);
        c.enable_prefix_cache();
        c.admit_slot_shared(0, prompt.len() + 2, prompt.len(), &prompt)
            .unwrap();
        for pos in 0..prompt.len() {
            for l in 0..2 {
                let v = (prompt[pos] * 100 + l as i32) as f32;
                c.row_mut(0, 0, l, pos).unwrap().fill(v);
                c.row_mut(1, 0, l, pos).unwrap().fill(-v);
            }
        }
        c.register_prefix(0, &prompt).unwrap();
        c.admit_slot_shared(1, prompt.len() + 2, 0, &prompt).unwrap();
        let reader: Vec<f32> = c.row(0, 0, 0, 5).unwrap().to_vec();
        c.row_mut(0, 1, 0, 5).unwrap().fill(777.0);
        assert_eq!(
            c.row(0, 0, 0, 5).unwrap(),
            &reader[..],
            "CoW must not change the reader's decoded bytes"
        );
        let writer: Vec<f32> = c.row(0, 1, 0, 5).unwrap().to_vec();
        assert!(writer.iter().all(|&x| (x - 777.0).abs() < 777.0 / 250.0));
        c.check_invariants().unwrap();
    }

    #[test]
    fn quant_register_prefix_flushes_staging_before_sharing() {
        // The staging invariant's load-bearing edge: the *last written
        // row* of a prompt is still staged (dirty) when the prompt is
        // registered. Without the flush, a later sharer would decode the
        // stale (zero) pool bytes instead.
        let prompt: Vec<i32> = (0..8).collect(); // exactly 2 full blocks
        let mut c = quant_cache(QuantKind::Int8, 2, 4, 12);
        c.enable_prefix_cache();
        c.admit_slot_shared(0, prompt.len() + 2, prompt.len(), &prompt)
            .unwrap();
        for pos in 0..prompt.len() {
            for l in 0..2 {
                c.row_mut(0, 0, l, pos).unwrap().fill((pos * 10 + l) as f32);
                c.row_mut(1, 0, l, pos).unwrap().fill(1.0);
            }
        }
        c.register_prefix(0, &prompt).unwrap();
        c.check_invariants().unwrap();
        let shared = c
            .admit_slot_shared(1, prompt.len() + 2, 0, &prompt)
            .unwrap();
        assert_eq!(shared, 4, "one full block shared (cap below the prompt)");
        for pos in 0..shared {
            assert_eq!(
                c.row(0, 1, 0, pos).unwrap(),
                c.row(0, 0, 0, pos).unwrap(),
                "sharer decodes the writer's flushed bytes at pos {pos}"
            );
        }
        c.check_invariants().unwrap();
    }

    #[test]
    fn quant_byte_accounting_reports_encoded_bytes() {
        // Mla{2,2} x 2 layers: fp32 costs (2+2)*2*4 = 32 B/token; int8
        // costs ((4+2)+(4+2))*2 = 24 B/token (scale amortizes poorly at
        // these tiny test dims; real geometries compress 2.4-3.2x).
        let c = quant_cache(QuantKind::Int8, 4, 16, 16);
        assert_eq!(c.bytes_per_token(), 24);
        assert_eq!(c.bytes_per_token_fp32(), 32);
        assert_eq!(c.bytes_total(), 16 * 16 * 24);
        let mut c = quant_cache(QuantKind::Fp8, 4, 16, 16);
        assert_eq!(c.bytes_per_token(), 24);
        c.admit_slot(0, 20, 20).unwrap();
        assert_eq!(c.bytes_in_use(), 2 * 16 * 24);
        assert_eq!(c.quant_kind(), QuantKind::Fp8);
    }

    #[test]
    fn props_quant_truncate_rollback_matches_fp32_shadow() {
        // Satellite: the speculative rollback walk over quantized blocks,
        // with an fp32 shadow cache running the identical op sequence.
        // Refcounts, reservation credits, and coverage must agree at
        // every step — the codec must be invisible to the block ledger —
        // and the sharing reader's digit rows must survive in both.
        check(
            "quant_truncate_rollback_matches_fp32_shadow",
            PropConfig { cases: 60, seed: 0x5EED },
            |r: &mut Rng| {
                let bs = 2 + r.below(3); // 2..=4
                let plen = bs + 1 + r.below(2 * bs);
                let ops: Vec<u64> = (0..24).map(|_| r.next_u64()).collect();
                (bs, plen, ops)
            },
            |(bs, plen, ops)| {
                let prompt: Vec<i32> = (0..*plen as i32).collect();
                let cap = *plen + 16;
                let mut caches = [
                    quant_cache(QuantKind::Off, 2, *bs, 48),
                    quant_cache(QuantKind::Int8, 2, *bs, 48),
                ];
                for c in &mut caches {
                    c.enable_prefix_cache();
                    c.admit_slot_shared(0, cap, *plen, &prompt)
                        .map_err(|e| e.to_string())?;
                    for pos in 0..*plen {
                        // Digit-valued rows (0..=99): int8 decodes them
                        // exactly after rounding.
                        c.row_mut(0, 0, 0, pos)
                            .map_err(|e| e.to_string())?
                            .fill((pos % 100) as f32);
                    }
                    c.register_prefix(0, &prompt).map_err(|e| e.to_string())?;
                    c.admit_slot_shared(1, cap, *plen, &prompt)
                        .map_err(|e| e.to_string())?;
                }
                let mut len = *plen;
                for &op in ops {
                    let k = 1 + (op as usize) % 4;
                    let grown = (len + k).min(cap);
                    let accepted = (op as usize / 8) % (grown - len + 1);
                    for c in &mut caches {
                        c.grow(1, grown).map_err(|e| e.to_string())?;
                        // Write the proposed rows (the verify path's
                        // write shape) before rolling back the tail.
                        for pos in len..grown {
                            c.row_mut(0, 1, 0, pos)
                                .map_err(|e| e.to_string())?
                                .fill((pos % 100) as f32);
                        }
                        c.truncate(1, len + accepted).map_err(|e| e.to_string())?;
                        c.check_invariants().map_err(|e| e.to_string())?;
                    }
                    len += accepted;
                    let (a, b) = (&caches[0], &caches[1]);
                    if a.blocks_in_use() != b.blocks_in_use()
                        || a.blocks_reserved() != b.blocks_reserved()
                        || a.reserved_of(1) != b.reserved_of(1)
                        || a.shared_tokens(1) != b.shared_tokens(1)
                    {
                        return Err(format!(
                            "ledgers diverged at len {len}: fp32 \
                             ({}, {}, {}) vs int8 ({}, {}, {})",
                            a.blocks_in_use(),
                            a.blocks_reserved(),
                            a.reserved_of(1),
                            b.blocks_in_use(),
                            b.blocks_reserved(),
                            b.reserved_of(1)
                        ));
                    }
                    for probe in [len.saturating_sub(1), len, len + 3] {
                        if a.covers(1, probe) != b.covers(1, probe) {
                            return Err(format!("coverage diverged at {probe}"));
                        }
                    }
                }
                // The sharing reader's digit rows survived every rollback
                // in both caches (int8 after round-to-nearest).
                for c in &caches {
                    for pos in 0..*plen {
                        let got = c.row(0, 0, 0, pos).map_err(|e| e.to_string())?;
                        if got[0].round() != (pos % 100) as f32 {
                            return Err(format!(
                                "{:?} reader corrupted at {pos}: {got:?}",
                                c.quant_kind()
                            ));
                        }
                    }
                }
                for c in &mut caches {
                    c.release_slot(0).map_err(|e| e.to_string())?;
                    c.release_slot(1).map_err(|e| e.to_string())?;
                    c.check_invariants().map_err(|e| e.to_string())?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn props_paged_cache_invariants_under_random_workload() {
        check(
            "paged_cache_invariants",
            PropConfig { cases: 120, seed: 41 },
            |r: &mut Rng| {
                let slots = 1 + r.below(4);
                let blocks = 2 + r.below(10);
                let ops: Vec<u64> = (0..48).map(|_| r.next_u64()).collect();
                (slots, blocks, ops)
            },
            |(slots, blocks, ops)| {
                let mut c = PagedKvCache::new(
                    CacheLayout::Mla { r: 2, dr: 2 },
                    1,
                    *slots,
                    4,
                    *blocks,
                )
                .map_err(|e| e.to_string())?;
                // active[slot] = Some(reserved_tokens) while admitted.
                let mut active: Vec<Option<usize>> = vec![None; *slots];
                for &op in ops {
                    let slot = (op as usize / 4) % *slots;
                    match op % 3 {
                        0 => {
                            if active[slot].is_none() {
                                let tokens = 1 + (op as usize / 16) % 12;
                                let initial = 1 + (op as usize / 64) % tokens;
                                let fits = c.blocks_for(tokens) <= c.n_unreserved();
                                let got = c.admit_slot(slot, tokens, initial);
                                if fits != got.is_ok() {
                                    return Err(format!(
                                        "admit fits={fits} but result {got:?}"
                                    ));
                                }
                                if got.is_ok() {
                                    active[slot] = Some(tokens);
                                }
                            }
                        }
                        1 => {
                            if let Some(tokens) = active[slot] {
                                // Growth within the reservation always works.
                                let len = 1 + (op as usize / 8) % tokens;
                                c.grow(slot, len).map_err(|e| e.to_string())?;
                            }
                        }
                        _ => {
                            if active[slot].take().is_some() {
                                c.release_slot(slot).map_err(|e| e.to_string())?;
                            }
                        }
                    }
                    c.check_invariants().map_err(|e| e.to_string())?;
                }
                Ok(())
            },
        );
    }
}
