//! Lossy per-row block codecs for the paged KV cache.
//!
//! The paper's headline result is that KV bytes are the scaling currency
//! of memory-bandwidth-bound decode: MLA compresses the cache 93% and
//! names FP8 quantization as the next multiplier. This module provides
//! that multiplier for the serving stack: two row codecs that shrink a
//! cache row of `inner` f32 values to `4 + inner` bytes (a per-row f32
//! scale followed by one quantized byte per value).
//!
//! Encoded row layout (both lossy codecs):
//!
//! ```text
//! [ scale: f32 LE ][ q_0 ][ q_1 ] ... [ q_{inner-1} ]
//! ```
//!
//! * `Int8` — symmetric per-row int8: `scale = max|v| / 127`,
//!   `q = round(v / scale)` clamped to ±127. Worst-case absolute error
//!   is `scale / 2 = max|v| / 254`.
//! * `Fp8` — an e4m3 simulation (1 sign, 4 exponent, 3 mantissa bits,
//!   bias 7, max finite 448, no infinities): `scale = max|v| / 448`,
//!   each value maps to the nearest representable e4m3 magnitude.
//!   Worst-case relative error for normal values is 2^-4 (one half ULP
//!   at 3 mantissa bits); subnormals bottom out at an absolute error of
//!   `scale * 2^-10`.
//!
//! An all-zero encoded row (scale bits 0.0, all codes 0) decodes to an
//! all-zero f32 row for both codecs — so a zero-initialized byte pool is
//! decode-equivalent to the zero-initialized f32 pool it replaces.
//!
//! The codec is deliberately stateless and row-granular: copy-on-write,
//! prefix sharing, and truncate in [`crate::kvcache::PagedKvCache`] move
//! whole encoded blocks as opaque bytes, so refcount accounting is
//! untouched by the choice of codec.

use anyhow::{bail, Result};

/// Bytes of the per-row scale prefix.
const SCALE_BYTES: usize = 4;

/// Largest finite e4m3 magnitude (exponent 15, mantissa 6/8, bias 7).
const E4M3_MAX: f32 = 448.0;

/// Which codec the paged pool stores blocks in.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QuantKind {
    /// Raw f32 rows (the seed behaviour).
    #[default]
    Off,
    /// Symmetric per-row int8 with an f32 scale.
    Int8,
    /// Simulated fp8 (e4m3) per-row with an f32 scale.
    Fp8,
}

impl QuantKind {
    /// Parse the `--kv-quant` / `quant=` grammar.
    pub fn parse(s: &str) -> Result<QuantKind> {
        match s {
            "off" => Ok(QuantKind::Off),
            "int8" => Ok(QuantKind::Int8),
            "fp8" => Ok(QuantKind::Fp8),
            other => bail!("unknown kv quant kind {other:?} (want off|int8|fp8)"),
        }
    }

    /// The wire/CLI name.
    pub fn name(self) -> &'static str {
        match self {
            QuantKind::Off => "off",
            QuantKind::Int8 => "int8",
            QuantKind::Fp8 => "fp8",
        }
    }

    pub fn is_off(self) -> bool {
        self == QuantKind::Off
    }

    /// Encoded bytes for one cache row of `inner` f32 values.
    pub fn bytes_per_row(self, inner: usize) -> usize {
        match self {
            QuantKind::Off => inner * 4,
            QuantKind::Int8 | QuantKind::Fp8 => SCALE_BYTES + inner,
        }
    }

    /// Encode one row. `dst` must be exactly `bytes_per_row(src.len())`.
    pub fn encode_row(self, src: &[f32], dst: &mut [u8]) {
        debug_assert_eq!(dst.len(), self.bytes_per_row(src.len()));
        match self {
            QuantKind::Off => {
                for (v, b) in src.iter().zip(dst.chunks_exact_mut(4)) {
                    b.copy_from_slice(&v.to_le_bytes());
                }
            }
            QuantKind::Int8 => {
                let max = row_max_abs(src);
                let scale = if max > 0.0 { max / 127.0 } else { 0.0 };
                dst[..SCALE_BYTES].copy_from_slice(&scale.to_le_bytes());
                for (v, b) in src.iter().zip(dst[SCALE_BYTES..].iter_mut()) {
                    let q = if scale > 0.0 {
                        (v / scale).round().clamp(-127.0, 127.0) as i8
                    } else {
                        0
                    };
                    *b = q as u8;
                }
            }
            QuantKind::Fp8 => {
                let max = row_max_abs(src);
                let scale = if max > 0.0 { max / E4M3_MAX } else { 0.0 };
                dst[..SCALE_BYTES].copy_from_slice(&scale.to_le_bytes());
                for (v, b) in src.iter().zip(dst[SCALE_BYTES..].iter_mut()) {
                    *b = if scale > 0.0 {
                        let sign = if v.is_sign_negative() { 0x80 } else { 0 };
                        sign | e4m3_encode_mag(v.abs() / scale)
                    } else {
                        0
                    };
                }
            }
        }
    }

    /// Decode one row. `src` must be exactly `bytes_per_row(dst.len())`.
    pub fn decode_row(self, src: &[u8], dst: &mut [f32]) {
        debug_assert_eq!(src.len(), self.bytes_per_row(dst.len()));
        match self {
            QuantKind::Off => {
                for (b, v) in src.chunks_exact(4).zip(dst.iter_mut()) {
                    *v = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
                }
            }
            QuantKind::Int8 => {
                let scale = scale_of(src);
                for (b, v) in src[SCALE_BYTES..].iter().zip(dst.iter_mut()) {
                    *v = (*b as i8) as f32 * scale;
                }
            }
            QuantKind::Fp8 => {
                let scale = scale_of(src);
                for (b, v) in src[SCALE_BYTES..].iter().zip(dst.iter_mut()) {
                    let mag = e4m3_decode_mag(b & 0x7F) * scale;
                    *v = if b & 0x80 != 0 { -mag } else { mag };
                }
            }
        }
    }
}

fn row_max_abs(row: &[f32]) -> f32 {
    row.iter().fold(0.0f32, |m, v| m.max(v.abs()))
}

fn scale_of(src: &[u8]) -> f32 {
    f32::from_le_bytes([src[0], src[1], src[2], src[3]])
}

/// Magnitude of an e4m3 code (sign bit already stripped).
/// Exponent 0 is subnormal (`m * 2^-9`); the max finite code is 0x7E
/// (448); 0x7F would be NaN and is never emitted by the encoder.
fn e4m3_decode_mag(code: u8) -> f32 {
    let e = (code >> 3) & 0xF;
    let m = (code & 7) as f32;
    if e == 0 {
        m * (1.0 / 512.0)
    } else {
        (1.0 + m / 8.0) * (2.0f32).powi(e as i32 - 7)
    }
}

/// Nearest-representable e4m3 code for a non-negative magnitude.
/// Saturates at 0x7E (448); ties break toward the smaller code, so the
/// mapping is deterministic.
fn e4m3_encode_mag(a: f32) -> u8 {
    if a >= E4M3_MAX {
        return 0x7E;
    }
    let mut best = 0u8;
    let mut best_err = f32::INFINITY;
    for code in 0..=0x7Eu8 {
        let err = (e4m3_decode_mag(code) - a).abs();
        if err < best_err {
            best = code;
            best_err = err;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, PropConfig};
    use crate::util::Rng;

    /// Property-test case count, overridable for the CI high-iteration
    /// job (`QUANT_PROP_CASES=2048 cargo test -q --release quant`).
    fn prop_cases(default: usize) -> usize {
        std::env::var("QUANT_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    fn unit(rng: &mut Rng) -> f32 {
        (rng.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// A random row: length 1..=64, values spanning several magnitudes.
    fn random_row(rng: &mut Rng) -> Vec<f32> {
        let n = rng.below(64) + 1;
        let mag = 10f32.powi(rng.below(5) as i32 - 2);
        (0..n).map(|_| (unit(rng) * 2.0 - 1.0) * mag).collect()
    }

    fn roundtrip(kind: QuantKind, row: &[f32]) -> Vec<f32> {
        let mut enc = vec![0u8; kind.bytes_per_row(row.len())];
        kind.encode_row(row, &mut enc);
        let mut dec = vec![0.0f32; row.len()];
        kind.decode_row(&enc, &mut dec);
        dec
    }

    #[test]
    fn parse_and_names_roundtrip() {
        for kind in [QuantKind::Off, QuantKind::Int8, QuantKind::Fp8] {
            assert_eq!(QuantKind::parse(kind.name()).unwrap(), kind);
        }
        assert!(QuantKind::parse("int4").is_err());
        assert_eq!(QuantKind::Off.bytes_per_row(12), 48);
        assert_eq!(QuantKind::Int8.bytes_per_row(12), 16);
        assert_eq!(QuantKind::Fp8.bytes_per_row(12), 16);
    }

    #[test]
    fn off_roundtrip_is_bit_exact() {
        check(
            "off_roundtrip_is_bit_exact",
            PropConfig { cases: prop_cases(64), seed: 0x0FF0 },
            random_row,
            |row| {
                let dec = roundtrip(QuantKind::Off, row);
                for (a, b) in row.iter().zip(dec.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn props_int8_roundtrip_error_is_bounded() {
        // Stated tolerance: worst-case error is scale/2 = max|v|/254;
        // assert the slightly looser max|v|/250 to absorb f32 rounding.
        check(
            "props_int8_roundtrip_error_is_bounded",
            PropConfig { cases: prop_cases(128), seed: 0x1228 },
            random_row,
            |row| {
                let max = row_max_abs(row);
                let dec = roundtrip(QuantKind::Int8, row);
                for (a, b) in row.iter().zip(dec.iter()) {
                    let err = (a - b).abs();
                    if err > max / 250.0 + 1e-7 {
                        return Err(format!(
                            "int8 err {err} vs bound {} (v={a}, max={max})",
                            max / 250.0
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn props_fp8_roundtrip_error_is_bounded() {
        // Stated tolerance: |err| <= |v| * 2^-4 + max|v| * 1e-5 — the
        // half-ULP relative bound for e4m3 normals plus the subnormal
        // absolute floor (scale * 2^-10 ≈ max * 2.2e-6).
        check(
            "props_fp8_roundtrip_error_is_bounded",
            PropConfig { cases: prop_cases(128), seed: 0xF8F8 },
            random_row,
            |row| {
                let max = row_max_abs(row);
                let dec = roundtrip(QuantKind::Fp8, row);
                for (a, b) in row.iter().zip(dec.iter()) {
                    let err = (a - b).abs();
                    if err > a.abs() * 0.0625 + max * 1e-5 {
                        return Err(format!(
                            "fp8 err {err} vs bound {} (v={a}, max={max})",
                            a.abs() * 0.0625 + max * 1e-5
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn props_int8_preserves_base100_digits_exactly() {
        // The SimBackend stores its rolling state as base-100 digits
        // (0..=99) in the leading inner dims with filler in [-1, 1].
        // int8's per-row scale is max|v|/127 <= 99/127 < 1, so the
        // worst-case error scale/2 < 0.5 and round-to-nearest recovers
        // every digit exactly — the invariant behind the acceptance
        // test's "greedy completions identical to fp32".
        check(
            "props_int8_preserves_base100_digits_exactly",
            PropConfig { cases: prop_cases(128), seed: 0xD161 },
            |rng| {
                let digits = rng.below(10) + 1;
                let filler = rng.below(23);
                let mut row: Vec<f32> =
                    (0..digits).map(|_| rng.below(100) as f32).collect();
                row.extend((0..filler).map(|_| unit(rng) * 2.0 - 1.0));
                (digits, row)
            },
            |(digits, row)| {
                let dec = roundtrip(QuantKind::Int8, row);
                for j in 0..*digits {
                    if dec[j].round() != row[j] {
                        return Err(format!(
                            "digit {j}: wrote {} read {}",
                            row[j], dec[j]
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn zero_bytes_decode_to_zero_rows() {
        // The pool-init invariant: a freshly zeroed byte pool must be
        // decode-equivalent to the zeroed f32 pool it replaces.
        for kind in [QuantKind::Off, QuantKind::Int8, QuantKind::Fp8] {
            let enc = vec![0u8; kind.bytes_per_row(12)];
            let mut dec = vec![1.0f32; 12];
            kind.decode_row(&enc, &mut dec);
            assert!(dec.iter().all(|&v| v == 0.0), "{kind:?} zero decode");
            // And the all-zero row encodes back to all-zero bytes.
            let mut back = vec![0xAAu8; kind.bytes_per_row(12)];
            kind.encode_row(&dec, &mut back);
            assert!(back.iter().all(|&b| b == 0), "{kind:?} zero encode");
        }
    }

    #[test]
    fn e4m3_table_pins_the_format() {
        // Pin the corners of the simulated format: max finite 448,
        // smallest normal 2^-6, smallest subnormal 2^-9, exact powers.
        assert_eq!(e4m3_decode_mag(0x7E), 448.0);
        assert_eq!(e4m3_decode_mag(0x08), 1.0 / 64.0);
        assert_eq!(e4m3_decode_mag(0x01), 1.0 / 512.0);
        assert_eq!(e4m3_decode_mag(0x38), 1.0);
        assert_eq!(e4m3_encode_mag(448.0), 0x7E);
        assert_eq!(e4m3_encode_mag(1e9), 0x7E, "saturates, never NaN");
        assert_eq!(e4m3_encode_mag(1.0), 0x38);
        assert_eq!(e4m3_encode_mag(0.0), 0x00);
    }
}
