//! Cross-sequence prefix index: token-prefix hashes at block granularity
//! mapped to filled block chains, so a new sequence whose prompt starts
//! with an already-cached prefix maps those blocks into its own table
//! (via `BlockAllocator::retain`) instead of recomputing and re-storing
//! them.
//!
//! The index is **chained**: the entry for prefix block `k` records the
//! chain hash of blocks `0..k` (its *parent*) plus block `k`'s own
//! tokens, and a lookup walks level by level, verifying both at every
//! step — so a match guarantees the whole token prefix agrees unless two
//! *different* prefixes collide on a full 64-bit chain hash (the same
//! per-block verification vLLM-style prefix caches rely on).
//!
//! Ownership: the index is *strong* — [`super::PagedKvCache`] holds one
//! block reference (`retain`) for every indexed block, so cached prefix
//! blocks outlive the sequence that filled them and a later same-prefix
//! request hits even after the first one completed. Memory pressure is
//! handled by LRU eviction of blocks only the index still references
//! (refcount 1): see `PagedKvCache::evict_for`.
//!
//! The index itself never touches the allocator; it only records which
//! blocks hold which prefixes and reports what to retain or evict — the
//! cache stays the single owner of block lifecycle.

use anyhow::{bail, Result};
use std::collections::HashMap;

/// Seed of every hash chain (the empty prefix).
const CHAIN_SEED: u64 = 0x9E37_79B9_7F4A_7C15;

/// SplitMix64-style avalanche of `a` perturbed by `b` (the same shape the
/// sim backend uses; duplicated to keep `kvcache` backend-independent).
fn mix(a: u64, b: u64) -> u64 {
    let mut z = a ^ b.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Chain hash of one more block of tokens on top of `parent`.
fn chain_hash(parent: u64, tokens: &[i32]) -> u64 {
    let mut h = mix(parent, 0x50_F1_D0 ^ tokens.len() as u64);
    for &t in tokens {
        h = mix(h, t as i64 as u64);
    }
    h
}

/// One indexed prefix block.
struct Entry {
    /// Pool block holding this prefix block's cache rows.
    block: usize,
    /// Chain hash of the prefix before this block (CHAIN_SEED at level 0).
    parent: u64,
    /// The block's own tokens, verified on every lookup.
    tokens: Vec<i32>,
    /// LRU stamp (index-local logical clock).
    last_used: u64,
}

/// Lifetime + occupancy counters for the server's `stats` command.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixStats {
    /// Admission-time prefix lookups.
    pub lookups: u64,
    /// Lookups that matched at least one block.
    pub hits: u64,
    /// Cumulative blocks mapped into tables via sharing.
    pub blocks_shared: u64,
    /// Cumulative prompt tokens covered by shared blocks.
    pub tokens_shared: u64,
    /// Cached blocks reclaimed under memory pressure.
    pub evictions: u64,
    /// Prefix blocks currently cached (index-referenced).
    pub blocks_cached: usize,
}

/// The prefix index (see the module docs for the ownership contract).
#[derive(Default)]
pub struct PrefixIndex {
    by_hash: HashMap<u64, Entry>,
    /// block -> chain hash, for O(1) invalidation on eviction.
    by_block: HashMap<usize, u64>,
    tick: u64,
    lookups: u64,
    hits: u64,
    blocks_shared: u64,
    tokens_shared: u64,
    evictions: u64,
}

impl PrefixIndex {
    pub fn new() -> PrefixIndex {
        PrefixIndex::default()
    }

    /// Number of blocks the index currently references.
    pub fn n_cached(&self) -> usize {
        self.by_block.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_block.is_empty()
    }

    pub fn contains_block(&self, block: usize) -> bool {
        self.by_block.contains_key(&block)
    }

    /// All indexed blocks (the cache's extra reference per block).
    pub fn blocks(&self) -> Vec<usize> {
        self.by_block.keys().copied().collect()
    }

    /// `(block, last_used)` pairs, for the cache's LRU eviction scan.
    pub fn candidates(&self) -> Vec<(usize, u64)> {
        self.by_hash.values().map(|e| (e.block, e.last_used)).collect()
    }

    /// Walk the chain over `prompt`, at most `max_blocks` levels deep,
    /// returning the matched blocks (longest verified prefix) and their
    /// chain hashes.
    fn walk(&self, prompt: &[i32], block_size: usize, max_blocks: usize) -> (Vec<usize>, Vec<u64>) {
        let mut blocks = Vec::new();
        let mut hashes = Vec::new();
        let mut parent = CHAIN_SEED;
        for k in 0..max_blocks.min(prompt.len() / block_size.max(1)) {
            let toks = &prompt[k * block_size..(k + 1) * block_size];
            let h = chain_hash(parent, toks);
            match self.by_hash.get(&h) {
                Some(e) if e.parent == parent && e.tokens == toks => {
                    blocks.push(e.block);
                    hashes.push(h);
                    parent = h;
                }
                _ => break,
            }
        }
        (blocks, hashes)
    }

    /// Non-mutating lookup (the scheduler's planning view): the blocks a
    /// sharing admission of `prompt` would map, without touching stats or
    /// LRU stamps.
    pub fn peek(&self, prompt: &[i32], block_size: usize, max_blocks: usize) -> Vec<usize> {
        self.walk(prompt, block_size, max_blocks).0
    }

    /// Admission-time lookup: like [`PrefixIndex::peek`] but counts the
    /// lookup/hit and freshens the LRU stamp of every matched level.
    pub fn lookup(&mut self, prompt: &[i32], block_size: usize, max_blocks: usize) -> Vec<usize> {
        let (blocks, hashes) = self.walk(prompt, block_size, max_blocks);
        self.lookups += 1;
        if !blocks.is_empty() {
            self.hits += 1;
        }
        self.tick += 1;
        for h in &hashes {
            if let Some(e) = self.by_hash.get_mut(h) {
                e.last_used = self.tick;
            }
        }
        blocks
    }

    /// Freshen the LRU stamps of `prompt`'s matched chain without
    /// counting a lookup. The engine touches every request of an
    /// admission wave before admitting any of them, so evictions
    /// triggered by earlier admissions in the wave prefer victims no
    /// planned admission depends on (the planner already excluded these
    /// blocks from its eviction headroom).
    pub fn touch(&mut self, prompt: &[i32], block_size: usize, max_blocks: usize) {
        let (_, hashes) = self.walk(prompt, block_size, max_blocks);
        self.tick += 1;
        for h in &hashes {
            if let Some(e) = self.by_hash.get_mut(h) {
                e.last_used = self.tick;
            }
        }
    }

    /// Record a successful sharing admission (cumulative stats).
    pub fn record_shared(&mut self, blocks: usize, tokens: usize) {
        self.blocks_shared += blocks as u64;
        self.tokens_shared += tokens as u64;
    }

    /// Index the chain of fully-filled prompt blocks `table[k]` holding
    /// `prompt[k*bs..(k+1)*bs]`. Levels already cached are freshened and
    /// skipped; the rest are inserted. Returns the newly indexed blocks —
    /// the caller must `retain` each one (the index's reference).
    pub fn insert_chain(
        &mut self,
        prompt: &[i32],
        block_size: usize,
        table: &[usize],
    ) -> Vec<usize> {
        let mut parent = CHAIN_SEED;
        let mut newly = Vec::new();
        self.tick += 1;
        for (k, &block) in table.iter().enumerate() {
            let toks = &prompt[k * block_size..(k + 1) * block_size];
            let h = chain_hash(parent, toks);
            // Probe with an immutable borrow first (inserting in the
            // None arm of a `get_mut` match trips the borrow checker).
            let cached = self
                .by_hash
                .get(&h)
                .map(|e| e.parent == parent && e.tokens == toks);
            match cached {
                Some(true) => {
                    // This prefix level is already cached (usually the
                    // very blocks this sequence shared at admission).
                    if let Some(e) = self.by_hash.get_mut(&h) {
                        e.last_used = self.tick;
                    }
                }
                Some(false) => break, // full 64-bit chain collision: stop
                None => {
                    if self.by_block.contains_key(&block) {
                        // The block already caches a different prefix —
                        // indexing it twice would corrupt invalidation.
                        break;
                    }
                    self.by_hash.insert(
                        h,
                        Entry {
                            block,
                            parent,
                            tokens: toks.to_vec(),
                            last_used: self.tick,
                        },
                    );
                    self.by_block.insert(block, h);
                    newly.push(block);
                }
            }
            parent = h;
        }
        newly
    }

    /// Drop the entry for `block` (eviction). Returns true if it was
    /// indexed — the caller must then `release` the index's reference.
    pub fn remove_block(&mut self, block: usize) -> bool {
        match self.by_block.remove(&block) {
            Some(h) => {
                self.by_hash.remove(&h);
                self.evictions += 1;
                true
            }
            None => false,
        }
    }

    pub fn stats(&self) -> PrefixStats {
        PrefixStats {
            lookups: self.lookups,
            hits: self.hits,
            blocks_shared: self.blocks_shared,
            tokens_shared: self.tokens_shared,
            evictions: self.evictions,
            blocks_cached: self.n_cached(),
        }
    }

    /// Internal consistency: the two maps mirror each other exactly.
    pub fn check(&self) -> Result<()> {
        if self.by_hash.len() != self.by_block.len() {
            bail!(
                "prefix index maps disagree: {} hashes vs {} blocks",
                self.by_hash.len(),
                self.by_block.len()
            );
        }
        for (h, e) in &self.by_hash {
            match self.by_block.get(&e.block) {
                Some(bh) if bh == h => {}
                _ => bail!("prefix block {} not mapped back to its hash", e.block),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prompt(n: usize) -> Vec<i32> {
        (0..n as i32).map(|i| i * 7 % 251).collect()
    }

    #[test]
    fn insert_then_lookup_matches_the_chain() {
        let mut ix = PrefixIndex::new();
        let p = prompt(20);
        // Blocks 10 and 11 hold the two full 8-token prefix blocks.
        let newly = ix.insert_chain(&p, 8, &[10, 11]);
        assert_eq!(newly, vec![10, 11]);
        assert_eq!(ix.n_cached(), 2);
        ix.check().unwrap();
        assert_eq!(ix.lookup(&p, 8, 2), vec![10, 11]);
        // A shorter prompt only matches the levels it covers.
        assert_eq!(ix.peek(&p[..9], 8, 1), vec![10]);
        // A diverging prompt misses from the divergence point on.
        let mut q = p.clone();
        q[9] += 1; // inside block 1
        assert_eq!(ix.peek(&q, 8, 2), vec![10]);
        q[3] += 1; // inside block 0
        assert!(ix.peek(&q, 8, 2).is_empty());
        let s = ix.stats();
        assert_eq!((s.lookups, s.hits), (1, 1));
    }

    #[test]
    fn reinsert_freshens_instead_of_duplicating() {
        let mut ix = PrefixIndex::new();
        let p = prompt(16);
        assert_eq!(ix.insert_chain(&p, 8, &[3, 4]).len(), 2);
        // A second sequence with private copies of the same prefix: the
        // cached levels win, nothing new is indexed.
        assert!(ix.insert_chain(&p, 8, &[5, 6]).is_empty());
        assert_eq!(ix.n_cached(), 2);
        ix.check().unwrap();
    }

    #[test]
    fn remove_block_invalidates_the_level() {
        let mut ix = PrefixIndex::new();
        let p = prompt(16);
        ix.insert_chain(&p, 8, &[3, 4]);
        assert!(ix.remove_block(3));
        assert!(!ix.remove_block(3), "already removed");
        // The child level survives but is unreachable (its parent is
        // gone), so lookups stop at level 0.
        assert!(ix.lookup(&p, 8, 2).is_empty());
        assert_eq!(ix.n_cached(), 1);
        assert_eq!(ix.stats().evictions, 1);
        ix.check().unwrap();
    }

    #[test]
    fn lru_stamps_order_the_eviction_candidates() {
        let mut ix = PrefixIndex::new();
        let a = prompt(8);
        let b: Vec<i32> = prompt(8).iter().map(|t| t + 1).collect();
        ix.insert_chain(&a, 8, &[0]);
        ix.insert_chain(&b, 8, &[1]);
        // Touch `a` last: block 1 becomes the LRU candidate.
        ix.lookup(&a, 8, 1);
        let mut cands = ix.candidates();
        cands.sort_by_key(|&(_, t)| t);
        assert_eq!(cands.first().map(|&(b, _)| b), Some(1));
        assert_eq!(cands.last().map(|&(b, _)| b), Some(0));
    }
}
