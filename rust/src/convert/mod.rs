//! The TransMLA conversion toolchain in Rust (paper Sec. 4), mirroring the
//! python oracle `python/compile/convert_ref.py`:
//!
//!   merge -> RoRoPE (+FreqFold) -> BKV -> joint low-rank PCA -> Absorb
//!
//! plus the MHA2MLA baseline (norm-selected partial RoPE + unbalanced
//! weight-SVD). Output parameter sets plug straight into the AOT-compiled
//! MLA artifacts; the whole train → convert → serve pipeline is
//! Python-free.

use crate::config::ModelConfig;
use crate::linalg::{eigh_desc, gram, pca_from_gram};
use crate::model::{default_freqs, Params, MLA_ABS_KEYS, MLA_TRAIN_KEYS, MERGED_KEYS};
use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Calibration activations captured from the GQA model (one entry per
/// layer): pre-RoPE merged keys [N, g*d], values [N, g*d], queries [N, h*d].
#[derive(Clone, Debug)]
pub struct Calib {
    pub k_pre: Vec<Tensor>,
    pub v_act: Vec<Tensor>,
    pub q_pre: Vec<Tensor>,
}

impl Calib {
    /// Build from the calib artifact's stacked outputs [L,B,T,*].
    pub fn from_stacked(k: &Tensor, v: &Tensor, q: &Tensor) -> Result<Calib> {
        let split = |t: &Tensor| -> Result<Vec<Tensor>> {
            if t.rank() != 4 {
                bail!("calib tensor rank {:?}", t.shape);
            }
            let (l, b, s, d) = (t.shape[0], t.shape[1], t.shape[2], t.shape[3]);
            Ok((0..l)
                .map(|i| {
                    t.index0(i)
                        .reshape(&[b * s, d])
                        .expect("reshape")
                })
                .collect())
        };
        Ok(Calib { k_pre: split(k)?, v_act: split(v)?, q_pre: split(q)? })
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PcaMode {
    /// Activation-based PCA (the paper's "WX-based").
    Activations,
    /// Weight-based PCA (Fig. 3b ablation, and MHA2MLA's choice).
    Weights,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Baseline {
    TransMla,
    Mha2Mla,
}

#[derive(Clone, Debug)]
pub struct ConvertOptions {
    pub rank: usize,
    pub fold: usize,
    pub balance: bool,
    pub pca_mode: PcaMode,
    pub baseline: Baseline,
    /// MHA2MLA: RoPE pairs kept per KV head (None = match TransMLA budget).
    pub keep_pairs_per_head: Option<usize>,
}

impl ConvertOptions {
    pub fn transmla(rank: usize) -> Self {
        ConvertOptions {
            rank,
            fold: 1,
            balance: true,
            pca_mode: PcaMode::Activations,
            baseline: Baseline::TransMla,
            keep_pairs_per_head: None,
        }
    }

    pub fn mha2mla(rank: usize) -> Self {
        ConvertOptions {
            rank,
            fold: 1,
            balance: false,
            pca_mode: PcaMode::Weights,
            baseline: Baseline::Mha2Mla,
            keep_pairs_per_head: None,
        }
    }
}

// ---------------------------------------------------------------------------
// Geometry helpers
// ---------------------------------------------------------------------------

/// Initial per-query-head mixers M_i [d, g*d] (block selectors, Sec. 4.1).
pub fn selector_mixers(cfg: &ModelConfig) -> Vec<Tensor> {
    let (h, g, d) = (cfg.n_heads, cfg.n_kv_groups, cfg.head_dim);
    let rep = h / g;
    (0..h)
        .map(|i| {
            let j = i / rep;
            let mut m = Tensor::zeros(&[d, g * d]);
            for k in 0..d {
                m.set2(k, j * d + k, 1.0);
            }
            m
        })
        .collect()
}

/// Per-pair frequency schedule of the merged key head [g*d/2].
pub fn merged_freqs(cfg: &ModelConfig) -> Vec<f32> {
    let base = default_freqs(cfg.head_dim, cfg.rope_theta);
    let mut out = Vec::with_capacity(cfg.kv_dim() / 2);
    for _ in 0..cfg.n_kv_groups {
        out.extend_from_slice(&base);
    }
    out
}

fn real_dim(head: usize, l: usize, d: usize) -> usize {
    head * d + 2 * l
}

// ---------------------------------------------------------------------------
// RoRoPE (+FreqFold)
// ---------------------------------------------------------------------------

/// Compute the RoPE-commuting rotation Q [gd, gd] + folded freq schedule
/// from pre-RoPE merged-key samples [N, gd]. See convert_ref.rorope_rotation
/// for the component-relayout convention (head 0 collects the top `fold`
/// components of each frequency group).
pub fn rorope_rotation(
    k_samples: &Tensor,
    cfg: &ModelConfig,
    fold: usize,
) -> Result<(Tensor, Vec<f32>)> {
    let (g, d) = (cfg.n_kv_groups, cfg.head_dim);
    let n_freq = d / 2;
    if n_freq % fold != 0 {
        bail!("fold {fold} must divide d/2 = {n_freq}");
    }
    let gd = g * d;
    let mut q_big = Tensor::zeros(&[gd, gd]);
    let base = default_freqs(d, cfg.rope_theta);
    let mut new_freqs_chunk = vec![0.0f32; n_freq];

    for m in 0..(n_freq / fold) {
        let ls: Vec<usize> = (m * fold..(m + 1) * fold).collect();
        let re_cols: Vec<usize> = ls
            .iter()
            .flat_map(|&l| (0..g).map(move |j| real_dim(j, l, d)))
            .collect();
        let im_cols: Vec<usize> = re_cols.iter().map(|&c| c + 1).collect();
        let zr = k_samples.select_cols(&re_cols);
        let zi = k_samples.select_cols(&im_cols);
        // RoPE-invariant covariance: C_rr + C_ii.
        let cmat = gram(&zr).add(&gram(&zi))?;
        let (_vals, u) = eigh_desc(&cmat)?; // columns = components desc
        let fg = fold * g;
        for c in 0..fg {
            let (jc, p) = (c / fold, c % fold);
            let l_new = m * fold + p;
            let rd_new = real_dim(jc, l_new, d);
            for (idx, (&l, j)) in ls
                .iter()
                .flat_map(|l| (0..g).map(move |j| (l, j)))
                .enumerate()
            {
                let rd_old = real_dim(j, l, d);
                let val = u.at2(idx, c);
                q_big.set2(rd_new, rd_old, val);
                q_big.set2(rd_new + 1, rd_old + 1, val);
            }
        }
        for &l in &ls {
            new_freqs_chunk[l] = base[m * fold];
        }
    }
    let mut new_freqs = Vec::with_capacity(gd / 2);
    for _ in 0..g {
        new_freqs.extend_from_slice(&new_freqs_chunk);
    }
    Ok((q_big, new_freqs))
}

/// Rotate the merged key space: wk [D, gd] -> wk Q^T; every mixer
/// M_i [d, gd] -> M_i Q^T (Eq. 19 both-sides rotation).
pub fn apply_rotation(
    wk: &Tensor,
    mixers: &[Tensor],
    q_big: &Tensor,
) -> Result<(Tensor, Vec<Tensor>)> {
    let qt = q_big.t();
    let wk_rot = wk.matmul(&qt)?;
    let mixers_rot = mixers
        .iter()
        .map(|m| m.matmul(&qt))
        .collect::<Result<Vec<_>>>()?;
    Ok((wk_rot, mixers_rot))
}

/// RoPE-keep mask after RoRoPE: keep the top `keep_components` components
/// per frequency group (head-major relayout).
pub fn rorope_mask(cfg: &ModelConfig, keep_components: usize, fold: usize) -> Vec<f32> {
    let (g, d) = (cfg.n_kv_groups, cfg.head_dim);
    let mut mask = vec![0.0f32; g * d];
    let n_freq = d / 2;
    for m in 0..(n_freq / fold) {
        for c in 0..keep_components.min(fold * g) {
            let (jc, p) = (c / fold, c % fold);
            let l_new = m * fold + p;
            let rd = real_dim(jc, l_new, d);
            mask[rd] = 1.0;
            mask[rd + 1] = 1.0;
        }
    }
    mask
}

/// MHA2MLA "norm" strategy: per KV head keep the `keep_pairs` pairs with
/// largest mean ||q_pair|| * ||k_pair||.
pub fn mha2mla_mask(
    cfg: &ModelConfig,
    k_samples: &Tensor,
    q_samples: &Tensor,
    keep_pairs: usize,
) -> Vec<f32> {
    let (h, g, d) = (cfg.n_heads, cfg.n_kv_groups, cfg.head_dim);
    let rep = h / g;
    let n_freq = d / 2;
    let n = k_samples.rows();
    let mut mask = vec![0.0f32; g * d];
    for j in 0..g {
        let mut scores: Vec<(f64, usize)> = Vec::with_capacity(n_freq);
        for l in 0..n_freq {
            let (kr, ki) = (real_dim(j, l, d), real_dim(j, l, d) + 1);
            let mut knorm = 0.0f64;
            for s in 0..n {
                let row = k_samples.row(s);
                knorm += ((row[kr] as f64).powi(2) + (row[ki] as f64).powi(2)).sqrt();
            }
            knorm /= n as f64;
            let mut qnorm = 0.0f64;
            for i in j * rep..(j + 1) * rep {
                let (qr, qi) = (i * d + 2 * l, i * d + 2 * l + 1);
                let mut acc = 0.0f64;
                for s in 0..n {
                    let row = q_samples.row(s);
                    acc += ((row[qr] as f64).powi(2) + (row[qi] as f64).powi(2)).sqrt();
                }
                qnorm += acc / n as f64;
            }
            scores.push((knorm * qnorm, l));
        }
        scores.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        for &(_, l) in scores.iter().take(keep_pairs) {
            mask[real_dim(j, l, d)] = 1.0;
            mask[real_dim(j, l, d) + 1] = 1.0;
        }
    }
    mask
}

// ---------------------------------------------------------------------------
// BKV + joint low-rank PCA
// ---------------------------------------------------------------------------

/// Eq. 20: alpha = E||k_nope|| / E||v||.
pub fn kv_balance_alpha(k_nope: &Tensor, v: &Tensor) -> f32 {
    k_nope.mean_row_norm() / v.mean_row_norm().max(1e-12)
}

/// PCA basis [(n_k+n_v), r] of the balanced joint NoPE-key/value space.
pub fn joint_lowrank_basis(
    k_nope: &Tensor,
    v: &Tensor,
    alpha: f32,
    r: usize,
    mode: PcaMode,
    wk_nope: &Tensor,
    wv: &Tensor,
) -> Result<Tensor> {
    let cmat = match mode {
        PcaMode::Activations => {
            let z = Tensor::hcat(&[&k_nope.scale(1.0 / alpha), v])?;
            gram(&z)
        }
        PcaMode::Weights => {
            let w = Tensor::hcat(&[&wk_nope.scale(1.0 / alpha), wv])?;
            gram(&w)
        }
    };
    pca_from_gram(&cmat, r)
}

// ---------------------------------------------------------------------------
// Per-layer conversion
// ---------------------------------------------------------------------------

pub struct LayerOut {
    pub wqr: Tensor,     // [h, d, dr]
    pub w_dkv: Tensor,   // [D, r]
    pub w_krope: Tensor, // [D, dr]
    pub w_uk: Tensor,    // [h, r, d]
    pub w_uv: Tensor,    // [h, r, d]
    pub rope_freqs: Vec<f32>,
    pub alpha: f32,
    pub dr: usize,
}

pub fn convert_layer(
    wk: &Tensor,
    wv: &Tensor,
    k_pre: &Tensor,
    q_pre: &Tensor,
    v_act: &Tensor,
    cfg: &ModelConfig,
    opts: &ConvertOptions,
) -> Result<LayerOut> {
    let (h, g, d) = (cfg.n_heads, cfg.n_kv_groups, cfg.head_dim);
    let gd = g * d;
    let mixers = selector_mixers(cfg);

    let (wk_rot, mixers, k_rot, rope_dims, freqs_out): (
        Tensor,
        Vec<Tensor>,
        Tensor,
        Vec<bool>,
        Vec<f32>,
    ) = match opts.baseline {
        Baseline::TransMla => {
            let (q_big, new_freqs) = rorope_rotation(k_pre, cfg, opts.fold)?;
            let (wk_rot, mixers) = apply_rotation(wk, &mixers, &q_big)?;
            let k_rot = k_pre.matmul(&q_big.t())?;
            let mut rope_dims = vec![false; gd];
            for rd in rope_dims.iter_mut().take(d) {
                *rd = true; // head 0 carries all positional information
            }
            let freqs_out = new_freqs[..d / 2].to_vec();
            (wk_rot, mixers, k_rot, rope_dims, freqs_out)
        }
        Baseline::Mha2Mla => {
            let kp = opts.keep_pairs_per_head.unwrap_or(d / (2 * g).max(1));
            let mask = mha2mla_mask(cfg, k_pre, q_pre, kp);
            let rope_dims: Vec<bool> = mask.iter().map(|&m| m > 0.5).collect();
            let mf = merged_freqs(cfg);
            let freqs_out: Vec<f32> = (0..gd)
                .step_by(2)
                .filter(|&i| rope_dims[i])
                .map(|i| mf[i / 2])
                .collect();
            (wk.clone(), mixers, k_pre.clone(), rope_dims, freqs_out)
        }
    };

    let rope_idx: Vec<usize> =
        (0..gd).filter(|&i| rope_dims[i]).collect();
    let nope_idx: Vec<usize> =
        (0..gd).filter(|&i| !rope_dims[i]).collect();
    let dr = rope_idx.len();
    let n_nope = nope_idx.len();

    let wk_rope = wk_rot.select_cols(&rope_idx); // [D, dr]
    let wk_nope = wk_rot.select_cols(&nope_idx); // [D, n_nope]
    let k_nope_act = k_rot.select_cols(&nope_idx);

    let alpha = if opts.balance && opts.baseline == Baseline::TransMla {
        kv_balance_alpha(&k_nope_act, v_act)
    } else {
        1.0
    };

    let r = opts.rank.min(n_nope + gd);
    let rbasis = joint_lowrank_basis(
        &k_nope_act, v_act, alpha, r, opts.pca_mode, &wk_nope, wv,
    )?; // [(n_nope+gd), r]

    let r_k = Tensor::new(
        &[n_nope, r],
        (0..n_nope)
            .flat_map(|i| rbasis.row(i).to_vec())
            .collect(),
    )?;
    let r_v = Tensor::new(
        &[gd, r],
        (n_nope..n_nope + gd)
            .flat_map(|i| rbasis.row(i).to_vec())
            .collect(),
    )?;

    let w_dkv = Tensor::hcat(&[&wk_nope.scale(1.0 / alpha), wv])?.matmul(&rbasis)?;

    let rep = h / g;
    let mut wqr_parts = Vec::with_capacity(h);
    let mut wuk_parts = Vec::with_capacity(h);
    let mut wuv_parts = Vec::with_capacity(h);
    for i in 0..h {
        let m_i = &mixers[i]; // [d, gd]
        wqr_parts.push(m_i.select_cols(&rope_idx)); // [d, dr]
        let b_i = m_i.select_cols(&nope_idx); // [d, n_nope]
        wuk_parts.push(b_i.matmul(&r_k)?.scale(alpha).t()); // [r, d]
        let j = i / rep;
        // w_uv_i = R_V[j*d:(j+1)*d, :]^T
        let block = Tensor::new(
            &[d, r],
            (j * d..(j + 1) * d)
                .flat_map(|row| r_v.row(row).to_vec())
                .collect(),
        )?;
        wuv_parts.push(block.t());
    }

    Ok(LayerOut {
        wqr: Tensor::stack(&wqr_parts)?,
        w_dkv,
        w_krope: wk_rope,
        w_uk: Tensor::stack(&wuk_parts)?,
        w_uv: Tensor::stack(&wuv_parts)?,
        rope_freqs: freqs_out,
        alpha,
        dr,
    })
}

// ---------------------------------------------------------------------------
// Whole-model conversion + Absorb
// ---------------------------------------------------------------------------

pub struct Diag {
    pub alphas: Vec<f32>,
    pub dr: usize,
}

/// Convert a GQA `Params` (canonical order) into trainable-MLA and
/// absorbed-MLA `Params`.
pub fn convert_model(
    gqa: &Params,
    calib: &Calib,
    cfg: &ModelConfig,
    opts: &ConvertOptions,
) -> Result<(Params, Params, Diag)> {
    let lyr = cfg.n_layers;
    let (wq_all, wk_all, wv_all, wo_all) = (
        gqa.get("wq")?, gqa.get("wk")?, gqa.get("wv")?, gqa.get("wo")?,
    );

    let mut layers = Vec::with_capacity(lyr);
    for l in 0..lyr {
        layers.push(convert_layer(
            &wk_all.index0(l),
            &wv_all.index0(l),
            &calib.k_pre[l],
            &calib.q_pre[l],
            &calib.v_act[l],
            cfg,
            opts,
        )?);
    }
    let dr = layers[0].dr;
    for lp in &layers {
        if lp.dr != dr {
            bail!("per-layer RoPE dims differ ({} vs {dr}) — \
                   unsupported by the exported MLA artifacts", lp.dr);
        }
    }

    let stack = |f: &dyn Fn(&LayerOut) -> Tensor| -> Result<Tensor> {
        Tensor::stack(&layers.iter().map(f).collect::<Vec<_>>())
    };

    let rope_freqs = Tensor::new(
        &[layers[0].rope_freqs.len()],
        layers[0].rope_freqs.clone(),
    )?;

    let keys_vec =
        |ks: &[&str]| ks.iter().map(|s| s.to_string()).collect::<Vec<_>>();

    let train = Params::new(
        keys_vec(MLA_TRAIN_KEYS),
        vec![
            gqa.get("embed")?.clone(),
            gqa.get("wq")?.clone(),
            stack(&|l| l.wqr.clone())?,
            stack(&|l| l.w_dkv.clone())?,
            stack(&|l| l.w_krope.clone())?,
            stack(&|l| l.w_uk.clone())?,
            stack(&|l| l.w_uv.clone())?,
            gqa.get("wo")?.clone(),
            gqa.get("ln1")?.clone(),
            gqa.get("w_gate")?.clone(),
            gqa.get("w_up")?.clone(),
            gqa.get("w_down")?.clone(),
            gqa.get("ln2")?.clone(),
            gqa.get("ln_f")?.clone(),
            gqa.get("lm_head")?.clone(),
            rope_freqs.clone(),
        ],
    )?;

    // Absorb (Eq. 10): fold W^UK into Q, W^UV into O.
    let (h, d) = (cfg.n_heads, cfg.head_dim);
    let dm = cfg.d_model;
    let mut wq_rope_l = Vec::with_capacity(lyr);
    let mut wq_lat_l = Vec::with_capacity(lyr);
    let mut wo_abs_l = Vec::with_capacity(lyr);
    for (l, lp) in layers.iter().enumerate() {
        let wq = wq_all.index0(l); // [D, h*d]
        let wo = wo_all.index0(l); // [h*d, D]
        let mut qr_h = Vec::with_capacity(h);
        let mut ql_h = Vec::with_capacity(h);
        let mut oa_h = Vec::with_capacity(h);
        for i in 0..h {
            let wq_i = wq.select_cols(&(i * d..(i + 1) * d).collect::<Vec<_>>()); // [D, d]
            let wqr_i = lp.wqr.index0(i); // [d, dr]
            let wuk_i = lp.w_uk.index0(i); // [r, d]
            let wuv_i = lp.w_uv.index0(i); // [r, d]
            qr_h.push(wq_i.matmul(&wqr_i)?); // [D, dr]
            ql_h.push(wq_i.matmul(&wuk_i.t())?); // [D, r]
            // wo block rows i*d..(i+1)*d: [d, D]
            let wo_block = Tensor::new(
                &[d, dm],
                (i * d..(i + 1) * d)
                    .flat_map(|row| wo.row(row).to_vec())
                    .collect(),
            )?;
            oa_h.push(wuv_i.matmul(&wo_block)?); // [r, D]
        }
        wq_rope_l.push(Tensor::stack(&qr_h)?);
        wq_lat_l.push(Tensor::stack(&ql_h)?);
        wo_abs_l.push(Tensor::stack(&oa_h)?);
    }

    let absorbed = Params::new(
        keys_vec(MLA_ABS_KEYS),
        vec![
            gqa.get("embed")?.clone(),
            Tensor::stack(&wq_rope_l)?,
            Tensor::stack(&wq_lat_l)?,
            train.get("w_dkv")?.clone(),
            train.get("w_krope")?.clone(),
            Tensor::stack(&wo_abs_l)?,
            gqa.get("ln1")?.clone(),
            gqa.get("w_gate")?.clone(),
            gqa.get("w_up")?.clone(),
            gqa.get("w_down")?.clone(),
            gqa.get("ln2")?.clone(),
            gqa.get("ln_f")?.clone(),
            gqa.get("lm_head")?.clone(),
            rope_freqs,
        ],
    )?;

    let diag = Diag { alphas: layers.iter().map(|l| l.alpha).collect(), dr };
    Ok((train, absorbed, diag))
}

/// Re-absorb a (possibly fine-tuned) trainable-MLA `Params` into the
/// absorbed serving form.
pub fn absorb_trainable(train: &Params, cfg: &ModelConfig) -> Result<Params> {
    let (h, d, dm, lyr) = (cfg.n_heads, cfg.head_dim, cfg.d_model, cfg.n_layers);
    let wq_all = train.get("wq")?;
    let wo_all = train.get("wo")?;
    let wqr_all = train.get("wqr")?;
    let wuk_all = train.get("w_uk")?;
    let wuv_all = train.get("w_uv")?;
    let mut wq_rope_l = Vec::new();
    let mut wq_lat_l = Vec::new();
    let mut wo_abs_l = Vec::new();
    for l in 0..lyr {
        let wq = wq_all.index0(l);
        let wo = wo_all.index0(l);
        let wqr = wqr_all.index0(l);
        let wuk = wuk_all.index0(l);
        let wuv = wuv_all.index0(l);
        let mut qr_h = Vec::new();
        let mut ql_h = Vec::new();
        let mut oa_h = Vec::new();
        for i in 0..h {
            let wq_i = wq.select_cols(&(i * d..(i + 1) * d).collect::<Vec<_>>());
            qr_h.push(wq_i.matmul(&wqr.index0(i))?);
            ql_h.push(wq_i.matmul(&wuk.index0(i).t())?);
            let wo_block = Tensor::new(
                &[d, dm],
                (i * d..(i + 1) * d)
                    .flat_map(|row| wo.row(row).to_vec())
                    .collect(),
            )?;
            oa_h.push(wuv.index0(i).matmul(&wo_block)?);
        }
        wq_rope_l.push(Tensor::stack(&qr_h)?);
        wq_lat_l.push(Tensor::stack(&ql_h)?);
        wo_abs_l.push(Tensor::stack(&oa_h)?);
    }
    let keys_vec =
        |ks: &[&str]| ks.iter().map(|s| s.to_string()).collect::<Vec<_>>();
    Params::new(
        keys_vec(MLA_ABS_KEYS),
        vec![
            train.get("embed")?.clone(),
            Tensor::stack(&wq_rope_l)?,
            Tensor::stack(&wq_lat_l)?,
            train.get("w_dkv")?.clone(),
            train.get("w_krope")?.clone(),
            Tensor::stack(&wo_abs_l)?,
            train.get("ln1")?.clone(),
            train.get("w_gate")?.clone(),
            train.get("w_up")?.clone(),
            train.get("w_down")?.clone(),
            train.get("ln2")?.clone(),
            train.get("ln_f")?.clone(),
            train.get("lm_head")?.clone(),
            train.get("rope_freqs")?.clone(),
        ],
    )
}

/// Build merged-form params (MERGED_KEYS) for Fig. 2b evaluation:
/// optional per-layer rotation, frequency schedule and RoPE mask.
pub fn merged_params_from(
    gqa: &Params,
    cfg: &ModelConfig,
    rotations: Option<&[Tensor]>,
    freqs: Option<Vec<f32>>,
    mask: Option<Vec<f32>>,
) -> Result<Params> {
    let (h, g, d, lyr) = (cfg.n_heads, cfg.n_kv_groups, cfg.head_dim, cfg.n_layers);
    let gd = g * d;
    let mixers = selector_mixers(cfg);
    let wq_all = gqa.get("wq")?;
    let wk_all = gqa.get("wk")?;
    let mut wqm_l = Vec::with_capacity(lyr);
    let mut wk_l_out = Vec::with_capacity(lyr);
    for l in 0..lyr {
        let wk_l = wk_all.index0(l);
        let (wk_rot, mx) = match rotations {
            Some(qs) => apply_rotation(&wk_l, &mixers, &qs[l])?,
            None => (wk_l, mixers.clone()),
        };
        wk_l_out.push(wk_rot);
        let wq = wq_all.index0(l);
        let mut heads = Vec::with_capacity(h);
        for i in 0..h {
            let wq_i = wq.select_cols(&(i * d..(i + 1) * d).collect::<Vec<_>>());
            heads.push(wq_i.matmul(&mx[i])?); // [D, gd]
        }
        wqm_l.push(Tensor::stack(&heads)?);
    }
    let freqs = freqs.unwrap_or_else(|| merged_freqs(cfg));
    let mask = mask.unwrap_or_else(|| vec![1.0; gd]);
    let keys_vec =
        |ks: &[&str]| ks.iter().map(|s| s.to_string()).collect::<Vec<_>>();
    Params::new(
        keys_vec(MERGED_KEYS),
        vec![
            gqa.get("embed")?.clone(),
            Tensor::stack(&wqm_l)?,
            Tensor::stack(&wk_l_out)?,
            gqa.get("wv")?.clone(),
            gqa.get("wo")?.clone(),
            gqa.get("ln1")?.clone(),
            gqa.get("w_gate")?.clone(),
            gqa.get("w_up")?.clone(),
            gqa.get("w_down")?.clone(),
            gqa.get("ln2")?.clone(),
            gqa.get("ln_f")?.clone(),
            gqa.get("lm_head")?.clone(),
            Tensor::new(&[gd / 2], freqs)?,
            Tensor::new(&[gd], mask)?,
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::orthogonality_defect;
    use crate::model::init_gqa;
    use crate::util::Rng;

    fn tiny_cfg(g: usize) -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 64,
            d_model: 32,
            n_heads: 4,
            n_kv_groups: g,
            head_dim: 8,
            n_layers: 2,
            d_ff: 48,
            max_seq: 16,
            rope_theta: 10000.0,
        }
    }

    fn fake_calib(cfg: &ModelConfig, n: usize, seed: u64) -> Calib {
        let mut rng = Rng::new(seed);
        let gd = cfg.kv_dim();
        let hd = cfg.q_dim();
        // Give keys a strong low-rank cross-head structure so PCA has
        // something to concentrate (mimics real activations).
        let mk = |rng: &mut Rng, dim: usize, boost: bool| {
            let mut t = Tensor::randn(&[n, dim], 1.0, rng);
            if boost {
                let dir = Tensor::randn(&[dim], 1.0, rng);
                for s in 0..n {
                    let a = rng.normal_f32(3.0);
                    for j in 0..dim {
                        t.data[s * dim + j] += a * dir.data[j];
                    }
                }
                // keys larger than values, like the paper observes
                t = t.scale(2.5);
            }
            t
        };
        Calib {
            k_pre: (0..cfg.n_layers).map(|l| mk(&mut rng.fork(l as u64), gd, true)).collect(),
            v_act: (0..cfg.n_layers).map(|l| mk(&mut rng.fork(100 + l as u64), gd, false)).collect(),
            q_pre: (0..cfg.n_layers).map(|l| mk(&mut rng.fork(200 + l as u64), hd, false)).collect(),
        }
    }

    #[test]
    fn rotation_is_orthogonal_any_fold() {
        for g in [2, 4] {
            let cfg = tiny_cfg(g);
            let calib = fake_calib(&cfg, 64, 0);
            for fold in [1, 2, 4] {
                let (q, freqs) = rorope_rotation(&calib.k_pre[0], &cfg, fold).unwrap();
                assert!(orthogonality_defect(&q) < 1e-4,
                        "g={g} fold={fold}: {}", orthogonality_defect(&q));
                assert_eq!(freqs.len(), cfg.kv_dim() / 2);
            }
        }
    }

    #[test]
    fn fold1_preserves_freqs() {
        let cfg = tiny_cfg(2);
        let calib = fake_calib(&cfg, 32, 1);
        let (_, freqs) = rorope_rotation(&calib.k_pre[0], &cfg, 1).unwrap();
        let want = merged_freqs(&cfg);
        for (a, b) in freqs.iter().zip(&want) {
            assert!((a - b).abs() < 1e-7);
        }
    }

    #[test]
    fn rotation_concentrates_energy() {
        let cfg = tiny_cfg(4);
        let calib = fake_calib(&cfg, 128, 2);
        let (q, _) = rorope_rotation(&calib.k_pre[0], &cfg, 1).unwrap();
        let k_rot = calib.k_pre[0].matmul(&q.t()).unwrap();
        let d = cfg.head_dim;
        let energy = |t: &Tensor, j: usize| -> f64 {
            let mut s = 0.0;
            for r in 0..t.rows() {
                for c in j * d..(j + 1) * d {
                    s += (t.at2(r, c) as f64).powi(2);
                }
            }
            s
        };
        let e: Vec<f64> = (0..cfg.n_kv_groups).map(|j| energy(&k_rot, j)).collect();
        assert!(e[0] >= e[1] && e[1] >= e[2] && e[2] >= e[3], "{e:?}");
        // total energy preserved (orthogonality)
        let tot_rot: f64 = e.iter().sum();
        let tot: f64 = calib.k_pre[0].data.iter().map(|&x| (x as f64).powi(2)).sum();
        assert!((tot_rot - tot).abs() / tot < 1e-4);
    }

    #[test]
    fn alpha_balances() {
        let cfg = tiny_cfg(2);
        let calib = fake_calib(&cfg, 64, 3);
        let a = kv_balance_alpha(&calib.k_pre[0], &calib.v_act[0]);
        assert!(a > 1.0, "keys boosted so alpha>1, got {a}");
        let balanced = calib.k_pre[0].scale(1.0 / a);
        let r = balanced.mean_row_norm() / calib.v_act[0].mean_row_norm();
        assert!((r - 1.0).abs() < 1e-4);
    }

    #[test]
    fn convert_model_shapes_and_absorb() {
        for g in [2, 4] {
            let cfg = tiny_cfg(g);
            let gqa = init_gqa(&cfg, 4);
            let calib = fake_calib(&cfg, 64, 5);
            let opts = ConvertOptions::transmla(12);
            let (train, absorbed, diag) =
                convert_model(&gqa, &calib, &cfg, &opts).unwrap();
            let (h, d, dm, lyr) = (cfg.n_heads, cfg.head_dim, cfg.d_model, cfg.n_layers);
            assert_eq!(diag.dr, d);
            assert_eq!(train.get("w_dkv").unwrap().shape, vec![lyr, dm, 12]);
            assert_eq!(train.get("w_uk").unwrap().shape, vec![lyr, h, 12, d]);
            assert_eq!(absorbed.get("wq_lat").unwrap().shape, vec![lyr, h, dm, 12]);
            assert_eq!(absorbed.get("wo_abs").unwrap().shape, vec![lyr, h, 12, dm]);
            // Re-absorbing the trainable params must equal the converter's
            // own absorbed params.
            let re = absorb_trainable(&train, &cfg).unwrap();
            for (k, t) in re.keys.iter().zip(&re.tensors) {
                let want = absorbed.get(k).unwrap();
                assert!(t.max_abs_diff(want) < 1e-5, "{k}");
            }
        }
    }

    #[test]
    fn full_rank_basis_is_orthogonal_and_lossless_on_samples() {
        let cfg = tiny_cfg(2);
        let calib = fake_calib(&cfg, 64, 6);
        let d = cfg.head_dim;
        let k_nope = calib.k_pre[0].slice_cols(d, cfg.kv_dim());
        let v = &calib.v_act[0];
        let full = k_nope.cols() + v.cols();
        let rb = joint_lowrank_basis(
            &k_nope, v, 1.0, full, PcaMode::Activations,
            &Tensor::zeros(&[2, k_nope.cols()]), &Tensor::zeros(&[2, v.cols()]),
        ).unwrap();
        assert!(orthogonality_defect(&rb) < 1e-4);
        let z = Tensor::hcat(&[&k_nope, v]).unwrap();
        let rec = z.matmul(&rb).unwrap().matmul(&rb.t()).unwrap();
        assert!(rec.max_abs_diff(&z) < 1e-3, "{}", rec.max_abs_diff(&z));
    }

    #[test]
    fn mha2mla_mask_budget() {
        let cfg = tiny_cfg(2);
        let calib = fake_calib(&cfg, 32, 7);
        let m = mha2mla_mask(&cfg, &calib.k_pre[0], &calib.q_pre[0], 2);
        let kept: f32 = m.iter().sum();
        assert_eq!(kept as usize, cfg.n_kv_groups * 2 * 2);
    }

    #[test]
    fn merged_params_shapes() {
        let cfg = tiny_cfg(2);
        let gqa = init_gqa(&cfg, 8);
        let p = merged_params_from(&gqa, &cfg, None, None, None).unwrap();
        assert_eq!(
            p.get("wqm").unwrap().shape,
            vec![cfg.n_layers, cfg.n_heads, cfg.d_model, cfg.kv_dim()]
        );
        assert_eq!(p.get("rope_mask").unwrap().shape, vec![cfg.kv_dim()]);
    }
}
