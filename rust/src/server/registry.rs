//! Multi-model serving: a named collection of engines behind one
//! endpoint, with per-request routing and a fair stepper.
//!
//! TransMLA's whole pitch is *migration*: a GQA checkpoint and its
//! MLA-converted twin coexist, and operators A/B them behind one server.
//! The [`EngineRegistry`] hosts N named [`Engine`]s (each with its own
//! backend / cache store / policy config) and a [`RoutePolicy`] picks
//! the engine for requests that do not name a model themselves:
//!
//!   * `default:<name>` — everything unrouted goes to one engine (the
//!     single-model server's behaviour, and what a legacy invocation
//!     gets: its engine is registered as `default`);
//!   * `round-robin` — unrouted requests rotate through the engines in
//!     registration order;
//!   * `least-loaded` — unrouted requests go to the engine with the
//!     smallest pipeline depth (queued + prefilling + decoding;
//!     ties break toward registration order).
//!
//! The serving loop calls [`EngineRegistry::step_non_idle`] every
//! iteration: every non-idle engine advances one [`Engine::step`], so
//! one model's long prefill never starves another model's decodes — the
//! StepPlan contract bounds stalls *within* an engine, the registry
//! bounds them *across* engines.

use crate::coordinator::{Completion, Engine};
use anyhow::{bail, Result};

/// How requests without an explicit `model` field pick an engine.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Route everything unnamed to this engine.
    Default(String),
    /// Rotate through engines in registration order.
    RoundRobin,
    /// Pick the engine with the smallest queued+prefilling+decoding
    /// depth (ties break toward registration order).
    LeastLoaded,
}

impl RoutePolicy {
    /// Parse `default:<name>` / `round-robin` / `least-loaded`.
    pub fn parse(s: &str) -> Result<RoutePolicy> {
        match s {
            "round-robin" => Ok(RoutePolicy::RoundRobin),
            "least-loaded" => Ok(RoutePolicy::LeastLoaded),
            other => match other.strip_prefix("default:") {
                Some(name) if !name.is_empty() => {
                    Ok(RoutePolicy::Default(name.to_string()))
                }
                _ => bail!(
                    "unknown route policy `{other}` \
                     (default:<model>|round-robin|least-loaded)"
                ),
            },
        }
    }

    /// Wire / stats spelling (round-trips through [`RoutePolicy::parse`]).
    pub fn name(&self) -> String {
        match self {
            RoutePolicy::Default(m) => format!("default:{m}"),
            RoutePolicy::RoundRobin => "round-robin".to_string(),
            RoutePolicy::LeastLoaded => "least-loaded".to_string(),
        }
    }
}

/// N named engines behind one serving endpoint (see the module docs).
pub struct EngineRegistry {
    engines: Vec<Engine>,
    route: RoutePolicy,
    /// Next engine index for `round-robin` routing.
    rr_next: usize,
}

impl EngineRegistry {
    /// An empty registry; [`EngineRegistry::register`] engines, then
    /// [`EngineRegistry::validate`] before serving.
    pub fn new(route: RoutePolicy) -> EngineRegistry {
        EngineRegistry { engines: Vec::new(), route, rr_next: 0 }
    }

    /// The legacy single-model server: one engine named `default`,
    /// routed `default:default` — every v1 invocation maps onto this.
    pub fn single(engine: Engine) -> EngineRegistry {
        let mut reg = EngineRegistry::new(RoutePolicy::Default("default".to_string()));
        reg.register("default", engine).expect("fresh registry accepts one engine");
        reg
    }

    /// Add a named engine. Names must be unique and non-empty; the
    /// engine is renamed to `name` so its completions carry it.
    pub fn register(&mut self, name: &str, mut engine: Engine) -> Result<()> {
        if name.is_empty() {
            bail!("model name must be non-empty");
        }
        if self.engines.iter().any(|e| e.name() == name) {
            bail!("duplicate model name `{name}`");
        }
        engine.set_name(name);
        self.engines.push(engine);
        Ok(())
    }

    /// Replace the routing policy (validated on the next
    /// [`EngineRegistry::validate`]).
    pub fn set_route(&mut self, route: RoutePolicy) {
        self.route = route;
    }

    pub fn route_policy(&self) -> &RoutePolicy {
        &self.route
    }

    /// Serving-time sanity: at least one engine, and a `default:<name>`
    /// route must name a registered engine.
    pub fn validate(&self) -> Result<()> {
        if self.engines.is_empty() {
            bail!("registry has no engines (register at least one model)");
        }
        if let RoutePolicy::Default(name) = &self.route {
            if self.get(name).is_none() {
                bail!(
                    "route policy `default:{name}` names no registered model \
                     (have: {})",
                    self.names().join(", ")
                );
            }
        }
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.engines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.engines.is_empty()
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> Vec<String> {
        self.engines.iter().map(|e| e.name().to_string()).collect()
    }

    pub fn engines(&self) -> &[Engine] {
        &self.engines
    }

    pub fn get(&self, name: &str) -> Option<&Engine> {
        self.engines.iter().find(|e| e.name() == name)
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Engine> {
        self.engines.iter_mut().find(|e| e.name() == name)
    }

    /// Pick the engine for a request: an explicit model name wins (an
    /// unknown one is an in-band error), otherwise the routing policy
    /// decides. Returns the engine index so the caller can borrow it
    /// mutably afterwards.
    pub fn route(&mut self, model: Option<&str>) -> Result<usize> {
        if self.engines.is_empty() {
            bail!("registry has no engines");
        }
        if let Some(name) = model {
            return match self.engines.iter().position(|e| e.name() == name) {
                Some(i) => Ok(i),
                None => bail!(
                    "unknown model `{name}` (have: {})",
                    self.names().join(", ")
                ),
            };
        }
        match &self.route {
            RoutePolicy::Default(name) => {
                let name = name.clone();
                self.route(Some(&name))
            }
            RoutePolicy::RoundRobin => {
                let i = self.rr_next % self.engines.len();
                self.rr_next = (self.rr_next + 1) % self.engines.len();
                Ok(i)
            }
            RoutePolicy::LeastLoaded => Ok(self
                .engines
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.load())
                .map(|(i, _)| i)
                .expect("non-empty registry")),
        }
    }

    /// Smallest pipeline depth (queued + prefilling + decoding) across
    /// the engines — the `least-loaded` routing signal. The serving
    /// loop's admission backpressure reuses it to size the advisory
    /// `retry_after_ms` hint on shed replies.
    pub fn min_load(&self) -> usize {
        self.engines.iter().map(Engine::load).min().unwrap_or(0)
    }

    pub fn engine_at_mut(&mut self, idx: usize) -> &mut Engine {
        &mut self.engines[idx]
    }

    /// Detach every engine for worker-mode serving (`serve_with`,
    /// `workers >= 1`): the registry keeps only routing metadata while
    /// the engines live on worker threads. Reattach the same engines in
    /// the same order with [`EngineRegistry::put_engines`] once the
    /// workers join.
    pub(crate) fn take_engines(&mut self) -> Vec<Engine> {
        std::mem::take(&mut self.engines)
    }

    pub(crate) fn put_engines(&mut self, engines: Vec<Engine>) {
        self.engines = engines;
    }

    /// All engines drained of work?
    pub fn is_idle(&self) -> bool {
        self.engines.iter().all(Engine::is_idle)
    }

    /// The fair multi-engine stepper: advance every non-idle engine up
    /// to its fair-share weight of iterations (`weight=K` in a `--model`
    /// SPEC — a weight-2 engine gets two step opportunities per sweep;
    /// idling mid-sweep forfeits the rest). Within an engine the
    /// StepPlan contract bounds a decode stall to one prefill chunk;
    /// across engines this weighted round-robin sweep bounds it to one
    /// sweep of the co-hosted models — a long prefill on one model
    /// cannot starve another model's decodes. Returns total iterations
    /// stepped.
    pub fn step_non_idle(&mut self) -> Result<usize> {
        let mut stepped = 0;
        for e in &mut self.engines {
            for _ in 0..e.weight() {
                if e.is_idle() {
                    break;
                }
                e.step()?;
                stepped += 1;
            }
        }
        Ok(stepped)
    }

    /// Drain finished requests from every engine (each completion's
    /// `model` field says which engine produced it).
    pub fn take_completions(&mut self) -> Vec<Completion> {
        let mut out = Vec::new();
        for e in &mut self.engines {
            out.extend(e.take_completions());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::SimBackend;
    use crate::config::EngineConfig;
    use crate::coordinator::Request;

    fn engine() -> Engine {
        Engine::new(SimBackend::gqa(4), EngineConfig::default())
    }

    fn two_model_registry(route: RoutePolicy) -> EngineRegistry {
        let mut reg = EngineRegistry::new(route);
        reg.register("gqa-base", engine()).unwrap();
        reg.register(
            "mla",
            Engine::new(SimBackend::mla(4, 8), EngineConfig::default()),
        )
        .unwrap();
        reg
    }

    #[test]
    fn route_policy_parses_and_round_trips() {
        for s in ["default:mla", "round-robin", "least-loaded"] {
            assert_eq!(RoutePolicy::parse(s).unwrap().name(), s);
        }
        assert!(RoutePolicy::parse("default:").is_err());
        assert!(RoutePolicy::parse("fastest").is_err());
    }

    #[test]
    fn registration_rejects_duplicates_and_empty_names() {
        let mut reg = EngineRegistry::new(RoutePolicy::RoundRobin);
        reg.register("a", engine()).unwrap();
        assert!(reg.register("a", engine()).is_err(), "duplicate name");
        assert!(reg.register("", engine()).is_err(), "empty name");
        assert_eq!(reg.names(), vec!["a"]);
        assert_eq!(reg.get("a").unwrap().name(), "a");
    }

    #[test]
    fn validate_catches_empty_and_dangling_default() {
        assert!(EngineRegistry::new(RoutePolicy::RoundRobin).validate().is_err());
        let mut reg = EngineRegistry::new(RoutePolicy::Default("missing".to_string()));
        reg.register("present", engine()).unwrap();
        assert!(reg.validate().is_err(), "default must name a registered model");
        reg.set_route(RoutePolicy::Default("present".to_string()));
        reg.validate().unwrap();
    }

    #[test]
    fn explicit_model_routing_beats_the_policy() {
        let mut reg = two_model_registry(RoutePolicy::Default("gqa-base".to_string()));
        let i = reg.route(Some("mla")).unwrap();
        assert_eq!(reg.engine_at_mut(i).name(), "mla");
        assert!(reg.route(Some("nope")).is_err(), "unknown model is an error");
        let i = reg.route(None).unwrap();
        assert_eq!(reg.engine_at_mut(i).name(), "gqa-base");
    }

    #[test]
    fn round_robin_rotates_in_registration_order() {
        let mut reg = two_model_registry(RoutePolicy::RoundRobin);
        let picks: Vec<String> = (0..4)
            .map(|_| {
                let i = reg.route(None).unwrap();
                reg.engine_at_mut(i).name().to_string()
            })
            .collect();
        assert_eq!(picks, vec!["gqa-base", "mla", "gqa-base", "mla"]);
    }

    #[test]
    fn least_loaded_follows_pipeline_depth() {
        let mut reg = two_model_registry(RoutePolicy::LeastLoaded);
        // Equal (zero) load ties toward registration order.
        let i = reg.route(None).unwrap();
        assert_eq!(reg.engine_at_mut(i).name(), "gqa-base");
        // Loading gqa-base tips the next unrouted request to mla.
        reg.get_mut("gqa-base")
            .unwrap()
            .submit(Request::from_text(1, "queued work", 4));
        let i = reg.route(None).unwrap();
        assert_eq!(reg.engine_at_mut(i).name(), "mla");
    }

    #[test]
    fn weighted_sweep_gives_extra_step_opportunities() {
        let mut reg = EngineRegistry::new(RoutePolicy::RoundRobin);
        reg.register("light", engine()).unwrap();
        reg.register(
            "heavy",
            Engine::new(
                SimBackend::gqa(4),
                EngineConfig { weight: 3, ..Default::default() },
            ),
        )
        .unwrap();
        reg.get_mut("light")
            .unwrap()
            .submit(Request::from_text(1, "one", 8));
        reg.get_mut("heavy")
            .unwrap()
            .submit(Request::from_text(2, "two", 8));
        // One sweep: the weight-1 engine steps once, the weight-3 engine
        // up to three times (both have plenty of decode work queued).
        assert_eq!(reg.step_non_idle().unwrap(), 4);
        while !reg.is_idle() {
            reg.step_non_idle().unwrap();
        }
        assert_eq!(reg.take_completions().len(), 2);
        // An idle engine forfeits its weight entirely.
        assert_eq!(reg.step_non_idle().unwrap(), 0);
    }

    #[test]
    fn fair_stepper_advances_every_non_idle_engine() {
        let mut reg = two_model_registry(RoutePolicy::RoundRobin);
        reg.get_mut("gqa-base")
            .unwrap()
            .submit(Request::from_text(1, "one", 2));
        reg.get_mut("mla")
            .unwrap()
            .submit(Request::from_text(2, "two", 2));
        assert!(!reg.is_idle());
        assert_eq!(reg.step_non_idle().unwrap(), 2, "both engines step");
        while !reg.is_idle() {
            reg.step_non_idle().unwrap();
        }
        let mut comps = reg.take_completions();
        comps.sort_by_key(|c| c.id);
        assert_eq!(comps.len(), 2);
        assert_eq!(comps[0].model, "gqa-base");
        assert_eq!(comps[1].model, "mla");
        assert_eq!(reg.step_non_idle().unwrap(), 0, "idle engines are skipped");
    }
}
