//! TCP JSONL serving front-end.
//!
//! Protocol (normative reference: `docs/PROTOCOL.md` at the repo root —
//! the schema regression tests in `tests/integration_server.rs` assert
//! the field lists documented there): one JSON object per line.
//!   -> {"prompt": "...", "max_new": 32, "temperature": 0.7}
//!   <- {"id": 1, "text": "...", "latency_s": 0.12, "ttft_s": 0.02,
//!       "tpot_s": 0.005, "prompt_len": 9}
//!   -> {"cmd": "stats"}    <- {"counters": {...}, "policy": "...",
//!                              "cache": {..., "prefix": {...}},
//!                              "decode_s": {"p50": ..., "p95": ..., "p99": ...}, ...}
//!   -> {"cmd": "ping"}     <- {"pong": true}
//!   -> {"cmd": "shutdown"} <- {"ok": true}
//!
//! Unknown fields on a request line are ignored (forward compatibility);
//! unknown *commands* are errors. Error paths answer in-band instead of
//! dropping the line:
//!   bad JSON        <- {"error": "bad json: ..."}
//!   unknown cmd     <- {"error": "unknown cmd `...`"}
//!   missing prompt  <- {"error": "missing prompt"}
//!
//! The engine runs on the caller's thread (the XLA client is not `Send`);
//! connection handlers exchange plain data with it through a shared
//! queue, so acceptor threads never touch backend state. Completions are
//! drained from the engine every loop iteration (`take_completions`), so
//! long-running servers hold no unbounded history.

use crate::coordinator::{Engine, Request};
use crate::json::Json;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

enum Incoming {
    /// A generation request awaiting a completion reply.
    Req { req: Request, reply: Sender<Json> },
    /// A stats snapshot request (answered by the engine loop).
    Stats { reply: Sender<Json> },
}

/// Shared state between acceptor threads and the engine loop.
#[derive(Clone)]
pub struct ServerState {
    incoming: Arc<Mutex<Vec<Incoming>>>,
    next_id: Arc<AtomicU64>,
    shutdown: Arc<AtomicBool>,
}

impl Default for ServerState {
    fn default() -> Self {
        Self::new()
    }
}

impl ServerState {
    pub fn new() -> Self {
        ServerState {
            incoming: Arc::new(Mutex::new(Vec::new())),
            next_id: Arc::new(AtomicU64::new(1)),
            shutdown: Arc::new(AtomicBool::new(false)),
        }
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }
}

fn error_json(msg: &str) -> Json {
    let mut err = Json::obj();
    err.set("error", Json::Str(msg.to_string()));
    err
}

fn handle_conn(stream: TcpStream, state: ServerState) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let msg = match Json::parse(&line) {
            Ok(m) => m,
            Err(e) => {
                writeln!(writer, "{}", error_json(&format!("bad json: {e}")).to_string())?;
                continue;
            }
        };
        match msg.get("cmd").and_then(Json::as_str) {
            Some("shutdown") => {
                state.shutdown.store(true, Ordering::SeqCst);
                writeln!(writer, "{{\"ok\":true}}")?;
                return Ok(());
            }
            Some("ping") => {
                writeln!(writer, "{{\"pong\":true}}")?;
                continue;
            }
            Some("stats") => {
                let (tx, rx) = channel();
                state
                    .incoming
                    .lock()
                    .unwrap()
                    .push(Incoming::Stats { reply: tx });
                match rx.recv() {
                    Ok(resp) => writeln!(writer, "{}", resp.to_string())?,
                    Err(_) => break,
                }
                continue;
            }
            Some(other) => {
                writeln!(
                    writer,
                    "{}",
                    error_json(&format!("unknown cmd `{other}`")).to_string()
                )?;
                continue;
            }
            None => {}
        }
        let prompt = match msg.get("prompt").and_then(Json::as_str) {
            Some(p) if !p.is_empty() => p.to_string(),
            _ => {
                writeln!(writer, "{}", error_json("missing prompt").to_string())?;
                continue;
            }
        };
        let max_new = msg
            .get("max_new")
            .and_then(Json::as_usize)
            .unwrap_or(32)
            .max(1);
        let temperature = msg
            .get("temperature")
            .and_then(Json::as_f64)
            .unwrap_or(0.0) as f32;
        let id = state.next_id.fetch_add(1, Ordering::SeqCst);
        let mut req = Request::from_text(id, &prompt, max_new);
        req.temperature = temperature;
        let (tx, rx) = channel();
        state
            .incoming
            .lock()
            .unwrap()
            .push(Incoming::Req { req, reply: tx });
        // Block this connection until the engine answers.
        match rx.recv() {
            Ok(resp) => writeln!(writer, "{}", resp.to_string())?,
            Err(_) => break,
        }
    }
    Ok(())
}

/// Stats snapshot: counters, throughput, and p50/p95/p99 latency
/// summaries for every recorded series (decode_s, prefill_s, latency_s,
/// queue_s, ttft_s, tpot_s, ...).
fn stats_json(engine: &Engine) -> Json {
    let m = &engine.metrics;
    let mut j = Json::obj();
    let mut counters = Json::obj();
    for (k, v) in m.counters() {
        counters.set(k, Json::Num(*v as f64));
    }
    j.set("counters", counters);
    j.set("policy", Json::Str(engine.policy_name().to_string()));
    j.set("decode_tok_per_s", Json::Num(engine.decode_throughput()));
    j.set("uptime_s", Json::Num(m.elapsed_s()));
    // Live queue depths of the StepPlan pipeline (waiting -> prefilling
    // -> decoding); chunk metrics land in the series below
    // (chunk_s / chunk_tokens) once the chunked policy runs.
    j.set("queued", Json::Num(engine.n_pending() as f64));
    j.set("prefilling", Json::Num(engine.n_prefilling() as f64));
    j.set("decoding", Json::Num(engine.n_decoding() as f64));
    // Cache memory accounting: actual bytes committed vs the worst-case
    // batch*capacity reservation (the paged cache's whole point).
    let cs = engine.cache_stats();
    let mut cache = Json::obj();
    cache.set("kind", Json::Str(cs.kind.to_string()));
    cache.set("bytes_total", Json::Num(cs.bytes_total as f64));
    cache.set("bytes_in_use", Json::Num(cs.bytes_in_use as f64));
    cache.set("bytes_worst_case", Json::Num(cs.bytes_worst_case as f64));
    cache.set("block_size", Json::Num(cs.block_size as f64));
    cache.set("blocks_total", Json::Num(cs.blocks_total as f64));
    cache.set("blocks_in_use", Json::Num(cs.blocks_in_use as f64));
    cache.set("blocks_reserved", Json::Num(cs.blocks_reserved as f64));
    cache.set("bytes_deduped", Json::Num(cs.bytes_deduped as f64));
    // Prefix-sharing counters ride along only when the prefix cache is
    // on (paged store + --prefix-cache on) — see docs/PROTOCOL.md.
    if let Some(ps) = cs.prefix {
        let mut pj = Json::obj();
        pj.set("lookups", Json::Num(ps.lookups as f64));
        pj.set("hits", Json::Num(ps.hits as f64));
        let rate = if ps.lookups > 0 {
            ps.hits as f64 / ps.lookups as f64
        } else {
            0.0
        };
        pj.set("hit_rate", Json::Num(rate));
        pj.set("blocks_shared", Json::Num(ps.blocks_shared as f64));
        pj.set("tokens_shared", Json::Num(ps.tokens_shared as f64));
        pj.set("blocks_cached", Json::Num(ps.blocks_cached as f64));
        pj.set("evictions", Json::Num(ps.evictions as f64));
        cache.set("prefix", pj);
    }
    j.set("cache", cache);
    for name in m.sample_names() {
        if let Some(s) = m.summary(&name) {
            let mut sj = Json::obj();
            sj.set("n", Json::Num(s.n as f64));
            sj.set("mean", Json::Num(s.mean));
            sj.set("p50", Json::Num(s.p50));
            sj.set("p95", Json::Num(s.p95));
            sj.set("p99", Json::Num(s.p99));
            sj.set("max", Json::Num(s.max));
            j.set(&name, sj);
        }
    }
    j
}

fn completion_json(c: &crate::coordinator::Completion) -> Json {
    let mut j = Json::obj();
    j.set("id", Json::Num(c.id as f64));
    j.set("text", Json::Str(c.text()));
    j.set("prompt_len", Json::Num(c.prompt_len as f64));
    j.set("latency_s", Json::Num(c.latency_s));
    j.set("queue_s", Json::Num(c.queue_s));
    j.set("prefill_s", Json::Num(c.prefill_s));
    j.set("ttft_s", Json::Num(c.ttft_s));
    j.set("tpot_s", Json::Num(c.tpot_s));
    j
}

/// Run the serving loop: accepts connections on `addr`, feeds the engine,
/// replies per request. Returns once a `shutdown` command arrives and all
/// in-flight work is drained.
pub fn serve(engine: &mut Engine, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("bind {addr}"))?;
    listener.set_nonblocking(true)?;
    eprintln!(
        "[server] listening on {addr} (backend `{}`, policy `{}`)",
        engine.spec().name,
        engine.policy_name()
    );
    let state = ServerState::new();
    let mut pending: Vec<(u64, Sender<Json>)> = Vec::new();

    loop {
        // Accept any waiting connections; each gets its own thread.
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    let st = state.clone();
                    std::thread::spawn(move || {
                        let _ = handle_conn(stream, st);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(e.into()),
            }
        }
        // Drain new work into the engine; answer stats immediately.
        for inc in state.incoming.lock().unwrap().drain(..) {
            match inc {
                Incoming::Req { req, reply } => {
                    pending.push((req.id, reply));
                    engine.submit(req);
                }
                Incoming::Stats { reply } => {
                    let _ = reply.send(stats_json(engine));
                }
            }
        }
        // Advance the engine.
        if !engine.is_idle() {
            engine.step()?;
        } else if state.is_shutdown() && pending.is_empty() {
            eprintln!("[server] shutdown");
            return Ok(());
        } else {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        // Deliver completions (drained every iteration so the history
        // cannot grow without bound in server mode).
        for c in engine.take_completions() {
            if let Some(idx) = pending.iter().position(|(id, _)| *id == c.id) {
                let (_, tx) = pending.swap_remove(idx);
                let _ = tx.send(completion_json(&c));
            }
        }
    }
}

/// Minimal client helper (used by tests and examples).
pub fn client_request(addr: &str, prompt: &str, max_new: usize) -> Result<Json> {
    let mut msg = Json::obj();
    msg.set("prompt", Json::Str(prompt.into()));
    msg.set("max_new", Json::Num(max_new as f64));
    client_line(addr, &msg.to_string())
}

/// Send one raw protocol line and return the first reply line (exercises
/// error paths that a well-formed helper could never produce).
pub fn client_line(addr: &str, line: &str) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{line}")?;
    let mut reader = BufReader::new(stream);
    let mut out = String::new();
    reader.read_line(&mut out)?;
    Json::parse(out.trim())
}

/// Fetch the stats snapshot.
pub fn client_stats(addr: &str) -> Result<Json> {
    client_line(addr, "{\"cmd\":\"stats\"}")
}

/// Send the shutdown command.
pub fn client_shutdown(addr: &str) -> Result<()> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{{\"cmd\":\"shutdown\"}}")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let _ = reader.read_line(&mut line);
    Ok(())
}
