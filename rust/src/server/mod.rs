//! TCP JSONL serving front-end: one server, N named engines.
//!
//! Protocol **v2** (normative reference: `docs/PROTOCOL.md` at the repo
//! root — the schema regression tests in `tests/integration_server.rs`
//! assert the field lists documented there): one JSON object per line.
//!   -> {"prompt": "...", "max_new": 32, "temperature": 0.7,
//!       "model": "mla"}                          // model optional
//!   <- {"id": 1, "model": "mla", "text": "...", "max_new": 32,
//!       "latency_s": 0.12, "ttft_s": 0.02, "tpot_s": 0.005,
//!       "prompt_len": 9, ...}
//!   -> {"cmd": "models"}   <- {"models": [{"name": ..., "arch": ...,
//!                              ...}], "routing": "default:mla"}
//!   -> {"cmd": "stats"}    <- {"engines": {"<name>": <per-engine stats,
//!                              shape unchanged from v1>},
//!                              "server": {"routing": ..., ...}}
//!   -> {"cmd": "ping"}     <- {"pong": true}
//!   -> {"cmd": "shutdown"} <- {"ok": true}
//!
//! The server hosts an [`EngineRegistry`]: requests carrying a `model`
//! field go to that engine (an unknown name is an in-band error), the
//! rest follow the registry's [`RoutePolicy`] (`default:<name>` /
//! `round-robin` / `least-loaded`). A legacy single-model invocation is
//! just a one-engine registry named `default`, so every v1 client line
//! keeps working unchanged.
//!
//! Unknown fields on a request line are ignored (forward compatibility);
//! unknown *commands* are errors. Error paths answer in-band instead of
//! dropping the line:
//!   bad JSON         <- {"error": "bad json: ..."}
//!   unknown cmd      <- {"error": "unknown cmd `...`"}
//!   missing prompt   <- {"error": "missing prompt"}
//!   bad temperature  <- {"error": "bad temperature"}   // negative/NaN/inf
//!   bad model        <- {"error": "bad model"} / {"error": "unknown model `...`"}
//!   overloaded       <- {"error": "overloaded", "retry_after_ms": N}
//!
//! # Admission backpressure (`--max-pending N`)
//!
//! With [`ServeOpts::max_pending`] `> 0` the pending-reply map is a
//! *bounded* queue: a generation request arriving while `pending` is at
//! the bound is **shed** — answered in-band with the 429-style
//! `overloaded` reply above (`retry_after_ms` is an advisory hint sized
//! off the `least-loaded` depth signal) *before* it is registered, so a
//! refused id never occupies a `pending` slot and never reaches an
//! engine. Sustained overload therefore degrades goodput gracefully
//! (accepted requests keep their latency; excess load is refused fast)
//! instead of growing queue waits without bound. Shed totals surface in
//! `stats.server.shed`; `0` (the default) keeps the queue unbounded.
//!
//! # Threading model (see `docs/ARCHITECTURE.md` for the full picture)
//!
//! A dedicated **acceptor** thread blocks on the listener and spawns one
//! handler thread per connection. Handlers never touch engine state:
//! every parsed line becomes an [`Event`] on ONE merged mpsc channel the
//! serving loop blocks on — an idle server burns no CPU, and a new
//! request is picked up the moment it arrives (no sleep polling).
//!
//! Two serving loops sit behind that channel, selected by
//! [`ServeOpts::workers`]:
//!
//!   * `workers == 0` — the single-threaded **sweep**: engines step on
//!     the serving thread via [`EngineRegistry::step_non_idle`]. This is
//!     the bit-parity fallback the integration tests pin the threaded
//!     mode against.
//!   * `workers >= 1` — **worker mode**: `min(workers, engines)` worker
//!     threads each own a round-robin share of the engines behind an
//!     mpsc mailbox. The serving thread routes requests to the owning
//!     worker's mailbox (static name/spec snapshots plus shared atomic
//!     load counters — `least-loaded` becomes approximate by one
//!     in-flight iteration), workers run the weighted step sweep over
//!     their engines and send [`Completion`]s back over the merged
//!     channel. Shutdown forwards to every mailbox; workers drain their
//!     in-flight sequences, flush, and exit — no wedge, no pending leak
//!     (the serving loop stops routing once shutdown is sent, and it is
//!     each mailbox's only sender, so a drained mailbox stays drained).
//!
//! A disconnected client's reply send fails silently and its pending
//! entry is removed with the completion, so abandoned requests cannot
//! wedge either loop or leak.

mod registry;

pub use registry::{EngineRegistry, RoutePolicy};

use crate::backend::Arch;
use crate::coordinator::{Completion, Engine, Request};
use crate::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Serving options beyond the bind address.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeOpts {
    /// Engine worker threads (`--workers N`): `0` runs the
    /// single-threaded registry sweep on the serving thread; `N >= 1`
    /// spawns `min(N, engines)` workers, each owning a round-robin
    /// share of the engines behind an mpsc mailbox. Completions are
    /// bit-identical across modes.
    pub workers: usize,
    /// Admission backpressure bound (`--max-pending N`): a generation
    /// request arriving while `pending` holds this many in-flight
    /// requests is shed with an in-band
    /// `{"error":"overloaded","retry_after_ms":...}` reply instead of
    /// being queued. `0` (default) = unbounded, the pre-backpressure
    /// behaviour.
    pub max_pending: usize,
}

/// Serving-loop shed accounting (one per loop; surfaced as
/// `stats.server.shed` — see `docs/PROTOCOL.md`).
struct Shed {
    max_pending: usize,
    count: u64,
    last_retry_ms: u64,
}

impl Shed {
    fn new(max_pending: usize) -> Shed {
        Shed { max_pending, count: 0, last_retry_ms: 0 }
    }

    /// Admission check, run BEFORE a request is registered in `pending`
    /// (a shed request must never leak a reply-map entry). Returns the
    /// in-band overload reply when the bound is hit. `min_depth` is the
    /// `least-loaded` routing signal — the smallest engine pipeline
    /// depth — which sizes the advisory `retry_after_ms` hint: roughly
    /// how long until the shallowest engine drains what is ahead.
    fn admit(&mut self, pending_len: usize, min_depth: usize) -> Option<Json> {
        if self.max_pending == 0 || pending_len < self.max_pending {
            return None;
        }
        // ~2ms per queued-ahead request on the least-loaded engine;
        // floor 1ms so clients always see a positive hint.
        let retry_ms = ((min_depth as u64) * 2).max(1);
        self.count += 1;
        self.last_retry_ms = retry_ms;
        let mut j = Json::obj();
        j.set("error", Json::Str("overloaded".to_string()));
        j.set("retry_after_ms", Json::Num(retry_ms as f64));
        Some(j)
    }

    /// The `stats.server.shed` object.
    fn json(&self) -> Json {
        let mut j = Json::obj();
        j.set("count", Json::Num(self.count as f64));
        j.set("last_retry_after_ms", Json::Num(self.last_retry_ms as f64));
        j
    }
}

enum Incoming {
    /// A generation request awaiting a completion reply. `model` is the
    /// request's explicit engine choice (`None` follows the routing
    /// policy); routing happens on the serving thread, where the load
    /// depths are.
    Req { req: Request, model: Option<String>, reply: Sender<Json> },
    /// A stats snapshot request (answered by the serving loop).
    Stats { reply: Sender<Json> },
    /// A model-listing request (answered by the serving loop).
    Models { reply: Sender<Json> },
}

/// Everything the serving loop can wake on, merged into ONE channel so
/// the idle path is a single blocking `recv` (std mpsc has no `select`).
enum Event {
    /// A parsed line from a connection handler.
    Conn(Incoming),
    /// A finished request flushed by a worker (worker mode only).
    Done(Completion),
    /// A worker drained its engines and exited (worker mode only; sent
    /// after that worker's last `Done`, so per-sender FIFO ordering
    /// guarantees no completion is still in flight behind it).
    WorkerStopped,
    /// A worker hit a fatal engine error (it stops right after).
    WorkerFailed(String),
    /// Wake a blocked `recv` to re-check control flags (sent on
    /// shutdown).
    Wake,
}

/// Shared state between connection handlers and the serving loop.
#[derive(Clone)]
struct ServerState {
    events: Sender<Event>,
    next_id: Arc<AtomicU64>,
    shutdown: Arc<AtomicBool>,
}

impl ServerState {
    fn new(events: Sender<Event>) -> Self {
        ServerState {
            events,
            next_id: Arc::new(AtomicU64::new(1)),
            shutdown: Arc::new(AtomicBool::new(false)),
        }
    }

    fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }
}

fn error_json(msg: &str) -> Json {
    let mut err = Json::obj();
    err.set("error", Json::Str(msg.to_string()));
    err
}

fn handle_conn(stream: TcpStream, state: ServerState) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let msg = match Json::parse(&line) {
            Ok(m) => m,
            Err(e) => {
                writeln!(writer, "{}", error_json(&format!("bad json: {e}")).to_string())?;
                continue;
            }
        };
        match msg.get("cmd").and_then(Json::as_str) {
            Some("shutdown") => {
                state.shutdown.store(true, Ordering::SeqCst);
                // A blocked serving loop only notices flags when an
                // event arrives.
                let _ = state.events.send(Event::Wake);
                writeln!(writer, "{{\"ok\":true}}")?;
                return Ok(());
            }
            Some("ping") => {
                writeln!(writer, "{{\"pong\":true}}")?;
                continue;
            }
            Some(cmd @ ("stats" | "models")) => {
                let (tx, rx) = channel();
                let inc = if cmd == "stats" {
                    Incoming::Stats { reply: tx }
                } else {
                    Incoming::Models { reply: tx }
                };
                if state.events.send(Event::Conn(inc)).is_err() {
                    break; // serving loop gone
                }
                match rx.recv() {
                    Ok(resp) => writeln!(writer, "{}", resp.to_string())?,
                    Err(_) => break,
                }
                continue;
            }
            Some(other) => {
                writeln!(
                    writer,
                    "{}",
                    error_json(&format!("unknown cmd `{other}`")).to_string()
                )?;
                continue;
            }
            None => {}
        }
        let prompt = match msg.get("prompt").and_then(Json::as_str) {
            Some(p) if !p.is_empty() => p.to_string(),
            _ => {
                writeln!(writer, "{}", error_json("missing prompt").to_string())?;
                continue;
            }
        };
        // Sampling params are validated in-band at the edge: a negative,
        // NaN, infinite, or non-numeric temperature never reaches an
        // engine (JSON cannot encode NaN, but `1e999` overflows to inf).
        // The finiteness check runs on the f32 the engine will actually
        // use — a finite f64 like 1e300 saturates to inf in the cast.
        let temperature = match msg.get("temperature") {
            None => 0.0,
            Some(t) => match t.as_f64() {
                Some(v) if v >= 0.0 && (v as f32).is_finite() => v as f32,
                _ => {
                    writeln!(writer, "{}", error_json("bad temperature").to_string())?;
                    continue;
                }
            },
        };
        // An explicit model choice must be a string; the serving loop
        // checks it against the registry (unknown names answer in-band).
        let model = match msg.get("model") {
            None => None,
            Some(m) => match m.as_str() {
                Some(name) => Some(name.to_string()),
                None => {
                    writeln!(writer, "{}", error_json("bad model").to_string())?;
                    continue;
                }
            },
        };
        let max_new = msg
            .get("max_new")
            .and_then(Json::as_usize)
            .unwrap_or(32)
            .max(1);
        let id = state.next_id.fetch_add(1, Ordering::SeqCst);
        let mut req = Request::from_text(id, &prompt, max_new);
        req.temperature = temperature;
        let (tx, rx) = channel();
        if state
            .events
            .send(Event::Conn(Incoming::Req { req, model, reply: tx }))
            .is_err()
        {
            break;
        }
        // Block this connection until the engine answers.
        match rx.recv() {
            Ok(resp) => writeln!(writer, "{}", resp.to_string())?,
            Err(_) => break,
        }
    }
    Ok(())
}

/// Per-engine stats snapshot: counters, throughput, and p50/p95/p99
/// latency summaries for every recorded series (decode_s, prefill_s,
/// latency_s, queue_s, ttft_s, tpot_s, ...). This object's shape is the
/// v1 `stats` reply unchanged — v2 nests one per engine under
/// `engines.<name>`, so existing dashboards re-point instead of
/// re-parse.
fn engine_stats_json(engine: &Engine) -> Json {
    let m = &engine.metrics;
    let mut j = Json::obj();
    let mut counters = Json::obj();
    for (k, v) in m.counters() {
        counters.set(k, Json::Num(*v as f64));
    }
    j.set("counters", counters);
    j.set("policy", Json::Str(engine.policy_name().to_string()));
    j.set("decode_tok_per_s", Json::Num(engine.decode_throughput()));
    j.set("uptime_s", Json::Num(m.elapsed_s()));
    // Live queue depths of the StepPlan pipeline (waiting -> prefilling
    // -> decoding); chunk metrics land in the series below
    // (chunk_s / chunk_tokens) once the chunked policy runs.
    j.set("queued", Json::Num(engine.n_pending() as f64));
    j.set("prefilling", Json::Num(engine.n_prefilling() as f64));
    j.set("decoding", Json::Num(engine.n_decoding() as f64));
    // Cache memory accounting: actual bytes committed vs the worst-case
    // batch*capacity reservation (the paged cache's whole point).
    let cs = engine.cache_stats();
    let mut cache = Json::obj();
    cache.set("kind", Json::Str(cs.kind.to_string()));
    cache.set("bytes_total", Json::Num(cs.bytes_total as f64));
    cache.set("bytes_in_use", Json::Num(cs.bytes_in_use as f64));
    cache.set("bytes_worst_case", Json::Num(cs.bytes_worst_case as f64));
    cache.set("block_size", Json::Num(cs.block_size as f64));
    cache.set("blocks_total", Json::Num(cs.blocks_total as f64));
    cache.set("blocks_in_use", Json::Num(cs.blocks_in_use as f64));
    cache.set("blocks_reserved", Json::Num(cs.blocks_reserved as f64));
    cache.set("bytes_deduped", Json::Num(cs.bytes_deduped as f64));
    // Block-codec accounting, always present: `kind` is "off" at
    // compression 1.0 when no lossy codec is active, so clients never
    // branch on field presence — see docs/PROTOCOL.md.
    let mut qj = Json::obj();
    qj.set("kind", Json::Str(cs.quant.kind.to_string()));
    qj.set("bytes_per_token", Json::Num(cs.quant.bytes_per_token as f64));
    qj.set(
        "bytes_per_token_fp32",
        Json::Num(cs.quant.bytes_per_token_fp32 as f64),
    );
    qj.set("compression", Json::Num(cs.quant.compression));
    cache.set("quant", qj);
    // Prefix-sharing counters ride along only when the prefix cache is
    // on (paged store + --prefix-cache on) — see docs/PROTOCOL.md.
    if let Some(ps) = cs.prefix {
        let mut pj = Json::obj();
        pj.set("lookups", Json::Num(ps.lookups as f64));
        pj.set("hits", Json::Num(ps.hits as f64));
        let rate = if ps.lookups > 0 {
            ps.hits as f64 / ps.lookups as f64
        } else {
            0.0
        };
        pj.set("hit_rate", Json::Num(rate));
        pj.set("blocks_shared", Json::Num(ps.blocks_shared as f64));
        pj.set("tokens_shared", Json::Num(ps.tokens_shared as f64));
        pj.set("blocks_cached", Json::Num(ps.blocks_cached as f64));
        pj.set("evictions", Json::Num(ps.evictions as f64));
        cache.set("prefix", pj);
    }
    j.set("cache", cache);
    // Speculative decoding counters (all-zero when no draft is attached
    // or the policy never speculated): the acceptance rate is the draft
    // quality signal, tokens_per_step the realized speedup over serial
    // decode (which is pinned at 1.0).
    let ss = engine.spec_stats();
    let mut spec = Json::obj();
    spec.set("proposed", Json::Num(ss.proposed as f64));
    spec.set("accepted", Json::Num(ss.accepted as f64));
    spec.set("steps", Json::Num(ss.steps as f64));
    spec.set("tokens", Json::Num(ss.tokens as f64));
    spec.set("acceptance_rate", Json::Num(ss.acceptance_rate));
    spec.set("tokens_per_step", Json::Num(ss.tokens_per_step));
    if let Some(d) = engine.draft_name() {
        spec.set("draft", Json::Str(d.to_string()));
    }
    j.set("spec", spec);
    for name in m.sample_names() {
        if let Some(s) = m.summary(&name) {
            let mut sj = Json::obj();
            sj.set("n", Json::Num(s.n as f64));
            sj.set("mean", Json::Num(s.mean));
            sj.set("p50", Json::Num(s.p50));
            sj.set("p95", Json::Num(s.p95));
            sj.set("p99", Json::Num(s.p99));
            sj.set("max", Json::Num(s.max));
            j.set(&name, sj);
        }
    }
    j
}

/// v2 stats: one v1-shaped object per engine under `engines`, plus a
/// `server` object for registry-level facts.
fn stats_json(
    registry: &EngineRegistry,
    pending: usize,
    started: Instant,
    shed: &Shed,
) -> Json {
    let mut j = Json::obj();
    let mut engines = Json::obj();
    for e in registry.engines() {
        engines.set(e.name(), engine_stats_json(e));
    }
    j.set("engines", engines);
    j.set(
        "server",
        server_json(registry.len(), &registry.route_policy().name(), pending, started, shed),
    );
    j
}

/// The `server` object of a stats reply.
fn server_json(
    models: usize,
    routing: &str,
    pending: usize,
    started: Instant,
    shed: &Shed,
) -> Json {
    let mut srv = Json::obj();
    srv.set("models", Json::Num(models as f64));
    srv.set("routing", Json::Str(routing.to_string()));
    srv.set("pending", Json::Num(pending as f64));
    srv.set("max_pending", Json::Num(shed.max_pending as f64));
    srv.set("shed", shed.json());
    srv.set("uptime_s", Json::Num(started.elapsed().as_secs_f64()));
    srv
}

/// `{"cmd":"models"}`: every hosted engine with its serving spec, plus
/// the routing policy. `default` marks the engine unrouted requests go
/// to under a `default:<name>` policy.
fn models_json(registry: &EngineRegistry) -> Json {
    let default = match registry.route_policy() {
        RoutePolicy::Default(name) => Some(name.clone()),
        _ => None,
    };
    let mut entries = Vec::new();
    for e in registry.engines() {
        let spec = e.spec();
        let mut m = Json::obj();
        m.set("name", Json::Str(e.name().to_string()));
        m.set("backend", Json::Str(spec.name.clone()));
        match spec.arch {
            Arch::Gqa => {
                m.set("arch", Json::Str("gqa".to_string()));
            }
            Arch::Mla { rank } => {
                m.set("arch", Json::Str("mla".to_string()));
                m.set("rank", Json::Num(rank as f64));
            }
        }
        m.set("policy", Json::Str(e.policy_name().to_string()));
        m.set("cache", Json::Str(e.cache.kind_name().to_string()));
        m.set("batch", Json::Num(spec.batch as f64));
        m.set("capacity", Json::Num(spec.capacity as f64));
        m.set("max_prompt", Json::Num(spec.max_prompt() as f64));
        m.set("default", Json::Bool(default.as_deref() == Some(e.name())));
        entries.push(m);
    }
    let mut j = Json::obj();
    j.set("models", Json::Arr(entries));
    j.set("routing", Json::Str(registry.route_policy().name()));
    j
}

fn completion_json(c: &Completion) -> Json {
    let mut j = Json::obj();
    j.set("id", Json::Num(c.id as f64));
    j.set("model", Json::Str(c.model.clone()));
    j.set("text", Json::Str(c.text()));
    j.set("prompt_len", Json::Num(c.prompt_len as f64));
    j.set("max_new", Json::Num(c.max_new as f64));
    j.set("latency_s", Json::Num(c.latency_s));
    j.set("queue_s", Json::Num(c.queue_s));
    j.set("prefill_s", Json::Num(c.prefill_s));
    j.set("ttft_s", Json::Num(c.ttft_s));
    j.set("tpot_s", Json::Num(c.tpot_s));
    j
}

/// Run the serving loop over a registry of named engines with default
/// options (single-threaded sweep): accepts connections on `addr`,
/// routes each request to an engine (explicit `model` field, else the
/// registry's [`RoutePolicy`]), and replies per request. Returns once a
/// `shutdown` command arrives and all in-flight work is drained.
pub fn serve(registry: &mut EngineRegistry, addr: &str) -> Result<()> {
    serve_with(registry, addr, ServeOpts::default())
}

/// [`serve`] with explicit [`ServeOpts`] (worker threads etc.).
pub fn serve_with(registry: &mut EngineRegistry, addr: &str, opts: ServeOpts) -> Result<()> {
    registry.validate()?;
    let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
    let local = listener.local_addr()?;
    eprintln!(
        "[server] listening on {addr} ({} model(s): {}; routing `{}`; workers {}; \
         max-pending {})",
        registry.len(),
        registry.names().join(", "),
        registry.route_policy().name(),
        opts.workers,
        if opts.max_pending == 0 { "unbounded".to_string() } else { opts.max_pending.to_string() }
    );
    let started = Instant::now();
    let (events_tx, events_rx) = channel();
    let state = ServerState::new(events_tx);

    // The acceptor owns the listener and blocks on it; each connection
    // gets its own handler thread. The serving loop never touches
    // sockets, so it can block on the event channel instead of polling.
    let acceptor = {
        let st = state.clone();
        std::thread::Builder::new()
            .name("acceptor".to_string())
            .spawn(move || {
                for conn in listener.incoming() {
                    if st.is_shutdown() {
                        break;
                    }
                    if let Ok(stream) = conn {
                        let st = st.clone();
                        std::thread::spawn(move || {
                            let _ = handle_conn(stream, st);
                        });
                    }
                }
            })
            .context("spawn acceptor")?
    };

    let result = if opts.workers == 0 {
        serve_sweep(registry, &state, &events_rx, started, opts.max_pending)
    } else {
        serve_workers(registry, &state, &events_rx, started, opts.workers, opts.max_pending)
    };

    // Retire the acceptor on every exit path: set the flag, then
    // self-connect to pop its blocking accept.
    state.shutdown.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(local);
    let _ = acceptor.join();
    if result.is_ok() {
        eprintln!("[server] shutdown");
    }
    result
}

/// The single-threaded serving loop: engines step on this thread via
/// the weighted registry sweep. Idle means blocked on the event channel
/// — zero CPU until a line arrives.
fn serve_sweep(
    registry: &mut EngineRegistry,
    state: &ServerState,
    events: &Receiver<Event>,
    started: Instant,
    max_pending: usize,
) -> Result<()> {
    // Reply channels by request id — O(1) completion delivery.
    let mut pending: HashMap<u64, Sender<Json>> = HashMap::new();
    let mut shed = Shed::new(max_pending);
    loop {
        if registry.is_idle() {
            if state.is_shutdown() && pending.is_empty() {
                return Ok(());
            }
            // Nothing to step: block until the next event. Shutdown
            // sends a Wake, so this cannot wedge.
            match events.recv() {
                Ok(ev) => sweep_event(ev, registry, &mut pending, started, &mut shed),
                Err(_) => return Ok(()),
            }
        }
        // Busy (or just woken): drain whatever queued without blocking,
        // advance every non-idle engine, deliver completions.
        while let Ok(ev) = events.try_recv() {
            sweep_event(ev, registry, &mut pending, started, &mut shed);
        }
        if !registry.is_idle() {
            registry.step_non_idle()?;
        }
        for c in registry.take_completions() {
            if let Some(tx) = pending.remove(&c.id) {
                let _ = tx.send(completion_json(&c));
            }
        }
    }
}

fn sweep_event(
    ev: Event,
    registry: &mut EngineRegistry,
    pending: &mut HashMap<u64, Sender<Json>>,
    started: Instant,
    shed: &mut Shed,
) {
    match ev {
        Event::Conn(Incoming::Req { mut req, model, reply }) => {
            // Backpressure runs first, BEFORE the id is registered: a
            // shed request never occupies a `pending` slot (the leak
            // regression test in integration_server.rs pins this).
            if let Some(overloaded) = shed.admit(pending.len(), registry.min_load()) {
                let _ = reply.send(overloaded);
                return;
            }
            match registry.route(model.as_deref()) {
                Ok(idx) => {
                    let engine = registry.engine_at_mut(idx);
                    // Server-edge clamp: a hostile max_new cannot demand
                    // more than the engine's remaining capacity for this
                    // prompt. The completion echoes the effective budget.
                    let ceiling = engine.max_new_ceiling(req.prompt.len());
                    req.max_new_tokens = req.max_new_tokens.min(ceiling);
                    pending.insert(req.id, reply);
                    engine.submit(req);
                }
                Err(e) => {
                    let _ = reply.send(error_json(&format!("{e}")));
                }
            }
        }
        Event::Conn(Incoming::Stats { reply }) => {
            let _ = reply.send(stats_json(registry, pending.len(), started, shed));
        }
        Event::Conn(Incoming::Models { reply }) => {
            let _ = reply.send(models_json(registry));
        }
        // Worker-mode events never fire in sweep mode; Wake just pops
        // the blocking recv so flags get re-checked.
        Event::Done(_) | Event::WorkerStopped | Event::WorkerFailed(_) | Event::Wake => {}
    }
}

/// One message into a worker's mailbox. The serving thread is the only
/// sender, so per-sender FIFO ordering means nothing can arrive behind
/// a `Shutdown` except `Stats` probes — a drained mailbox after the
/// shutdown marker stays free of submits.
enum WorkerMsg {
    /// Route `req` to the worker's `local`-th engine.
    Submit { local: usize, req: Request },
    /// Snapshot stats for every owned engine (name, v1-shaped object).
    Stats { reply: Sender<Vec<(String, Json)>> },
    /// Finish in-flight work, flush, and exit.
    Shutdown,
}

struct WorkerHandle {
    mailbox: Sender<WorkerMsg>,
    handle: JoinHandle<Vec<Engine>>,
    /// Registry indices of the owned engines, in the worker's local
    /// order (for reattaching after the join).
    owns: Vec<usize>,
}

/// A worker thread's life: block on the mailbox while idle, otherwise
/// drain it, run the weighted step sweep over the owned engines, flush
/// completions, and publish authoritative load depths. Exits once
/// shutdown has been seen (or the serving loop is gone) and every owned
/// engine is drained. Returns the engines for reattachment.
fn worker_loop(
    wid: usize,
    mut engines: Vec<Engine>,
    loads: Vec<Arc<AtomicUsize>>,
    mailbox: Receiver<WorkerMsg>,
    events: Sender<Event>,
) -> Vec<Engine> {
    let mut shutdown = false;
    let mut disconnected = false;
    loop {
        if engines.iter().all(Engine::is_idle) && !shutdown && !disconnected {
            // Idle: block for work — an idle worker burns no CPU.
            match mailbox.recv() {
                Ok(m) => apply_worker_msg(m, &mut engines, &mut shutdown),
                Err(_) => disconnected = true,
            }
        }
        loop {
            match mailbox.try_recv() {
                Ok(m) => apply_worker_msg(m, &mut engines, &mut shutdown),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        if (shutdown || disconnected) && engines.iter().all(Engine::is_idle) {
            break;
        }
        // The same weighted fair sweep the single-threaded mode runs,
        // over this worker's share of the engines.
        for e in engines.iter_mut() {
            for _ in 0..e.weight() {
                if e.is_idle() {
                    break;
                }
                if let Err(err) = e.step() {
                    let _ = events.send(Event::WorkerFailed(format!(
                        "worker {wid}: engine `{}`: {err:#}",
                        e.name()
                    )));
                    let _ = events.send(Event::WorkerStopped);
                    return engines;
                }
            }
        }
        for (i, e) in engines.iter_mut().enumerate() {
            for c in e.take_completions() {
                let _ = events.send(Event::Done(c));
            }
            loads[i].store(e.load(), Ordering::Relaxed);
        }
    }
    for (i, e) in engines.iter().enumerate() {
        loads[i].store(e.load(), Ordering::Relaxed);
    }
    let _ = events.send(Event::WorkerStopped);
    engines
}

fn apply_worker_msg(m: WorkerMsg, engines: &mut [Engine], shutdown: &mut bool) {
    match m {
        WorkerMsg::Submit { local, req } => engines[local].submit(req),
        WorkerMsg::Stats { reply } => {
            let stats = engines
                .iter()
                .map(|e| (e.name().to_string(), engine_stats_json(e)))
                .collect();
            let _ = reply.send(stats);
        }
        WorkerMsg::Shutdown => *shutdown = true,
    }
}

/// Routing on the serving thread while the engines live on workers:
/// the registry's [`RoutePolicy`] semantics over static name snapshots
/// and shared load counters. `least-loaded` reads worker-published
/// depths plus optimistic submit bumps, so it can trail the truth by
/// one in-flight iteration — approximate by design.
fn route_static(
    names: &[String],
    route: &RoutePolicy,
    rr_next: &mut usize,
    loads: &[Arc<AtomicUsize>],
    model: Option<&str>,
) -> Result<usize> {
    let by_name = |name: &str| -> Result<usize> {
        names.iter().position(|n| n == name).with_context(|| {
            format!("unknown model `{name}` (have: {})", names.join(", "))
        })
    };
    if let Some(name) = model {
        return by_name(name);
    }
    match route {
        RoutePolicy::Default(name) => by_name(name),
        RoutePolicy::RoundRobin => {
            let i = *rr_next % names.len();
            *rr_next = (*rr_next + 1) % names.len();
            Ok(i)
        }
        RoutePolicy::LeastLoaded => Ok(loads
            .iter()
            .enumerate()
            .min_by_key(|(_, l)| l.load(Ordering::Relaxed))
            .map(|(i, _)| i)
            .expect("non-empty registry")),
    }
}

/// Worker-mode stats: fan the probe out to every live worker's mailbox,
/// then assemble the replies in registry order. Workers answer from
/// their own threads (next mailbox drain — immediate when idle); a
/// worker that already exited is skipped.
fn worker_stats_json(
    handles: &[WorkerHandle],
    names: &[String],
    routing: &str,
    pending: usize,
    started: Instant,
    shed: &Shed,
) -> Json {
    let mut collected: HashMap<String, Json> = HashMap::new();
    for h in handles {
        let (tx, rx) = channel();
        if h.mailbox.send(WorkerMsg::Stats { reply: tx }).is_ok() {
            if let Ok(stats) = rx.recv() {
                for (name, s) in stats {
                    collected.insert(name, s);
                }
            }
        }
    }
    let mut j = Json::obj();
    let mut engines = Json::obj();
    for name in names {
        if let Some(s) = collected.remove(name) {
            engines.set(name, s);
        }
    }
    j.set("engines", engines);
    j.set("server", server_json(names.len(), routing, pending, started, shed));
    j
}

/// The worker-mode serving loop (`--workers N`): engines are detached
/// onto `min(N, engines)` worker threads; this thread only routes,
/// clamps, tracks pending replies, and answers control commands.
fn serve_workers(
    registry: &mut EngineRegistry,
    state: &ServerState,
    events: &Receiver<Event>,
    started: Instant,
    workers: usize,
    max_pending: usize,
) -> Result<()> {
    let n = registry.len();
    let w = workers.min(n).max(1);
    // Static snapshots, taken while the engines are still attached:
    // routing metadata, the (fully static) models reply, and each
    // engine's capacity/max-prompt pair for the server-edge clamp.
    let names = registry.names();
    let route = registry.route_policy().clone();
    let routing_name = route.name();
    let models_reply = models_json(registry);
    let clamp: Vec<(usize, usize)> = registry
        .engines()
        .iter()
        .map(|e| {
            let s = e.spec();
            (s.capacity, s.max_prompt())
        })
        .collect();
    let loads: Vec<Arc<AtomicUsize>> = (0..n).map(|_| Arc::new(AtomicUsize::new(0))).collect();

    // Distribute the engines round-robin by registry index and launch
    // the workers.
    let mut assignment: Vec<(usize, usize)> = vec![(0, 0); n]; // engine -> (worker, local)
    let mut per_worker: Vec<Vec<(usize, Engine)>> = (0..w).map(|_| Vec::new()).collect();
    for (i, e) in registry.take_engines().into_iter().enumerate() {
        let wid = i % w;
        assignment[i] = (wid, per_worker[wid].len());
        per_worker[wid].push((i, e));
    }
    let mut handles: Vec<WorkerHandle> = Vec::with_capacity(w);
    for (wid, owned) in per_worker.into_iter().enumerate() {
        let owns: Vec<usize> = owned.iter().map(|(i, _)| *i).collect();
        let engs: Vec<Engine> = owned.into_iter().map(|(_, e)| e).collect();
        let wloads: Vec<Arc<AtomicUsize>> = owns.iter().map(|&i| loads[i].clone()).collect();
        let (tx, rx) = channel();
        let ev = state.events.clone();
        let handle = std::thread::Builder::new()
            .name(format!("engine-worker-{wid}"))
            .spawn(move || worker_loop(wid, engs, wloads, rx, ev))
            .context("spawn engine worker")?;
        handles.push(WorkerHandle { mailbox: tx, handle, owns });
    }

    let mut pending: HashMap<u64, Sender<Json>> = HashMap::new();
    let mut shed = Shed::new(max_pending);
    let mut rr_next = 0usize;
    let mut shutdown_sent = false;
    let mut stopped = 0usize;
    let mut failed: Option<String> = None;

    loop {
        // Block for the next event — the serving thread is fully
        // event-driven in worker mode — then drain without blocking.
        let first = match events.recv() {
            Ok(ev) => ev,
            Err(_) => break,
        };
        let mut batch = vec![first];
        while let Ok(ev) = events.try_recv() {
            batch.push(ev);
        }
        for ev in batch {
            match ev {
                Event::Conn(Incoming::Req { mut req, model, reply }) => {
                    if shutdown_sent {
                        // Routing past the shutdown marker could land a
                        // submit behind a worker's drain-and-exit check;
                        // answer in-band instead.
                        let _ = reply.send(error_json("server is shutting down"));
                        continue;
                    }
                    // Backpressure before registration, mirroring the
                    // sweep loop; the depth signal is the workers'
                    // published load minimum (approximate by one
                    // in-flight iteration, like `least-loaded` routing).
                    let min_depth = loads
                        .iter()
                        .map(|l| l.load(Ordering::Relaxed))
                        .min()
                        .unwrap_or(0);
                    if let Some(overloaded) = shed.admit(pending.len(), min_depth) {
                        let _ = reply.send(overloaded);
                        continue;
                    }
                    match route_static(&names, &route, &mut rr_next, &loads, model.as_deref()) {
                        Ok(idx) => {
                            let (cap, maxp) = clamp[idx];
                            let plen = req.prompt.len().min(maxp);
                            // Same clamp as Engine::max_new_ceiling.
                            let ceiling = (cap.saturating_sub(plen) + 1).max(1);
                            req.max_new_tokens = req.max_new_tokens.min(ceiling);
                            let id = req.id;
                            let (wid, local) = assignment[idx];
                            pending.insert(id, reply);
                            loads[idx].fetch_add(1, Ordering::Relaxed);
                            if handles[wid]
                                .mailbox
                                .send(WorkerMsg::Submit { local, req })
                                .is_err()
                            {
                                if let Some(tx) = pending.remove(&id) {
                                    let _ =
                                        tx.send(error_json("server is shutting down"));
                                }
                            }
                        }
                        Err(e) => {
                            let _ = reply.send(error_json(&format!("{e}")));
                        }
                    }
                }
                Event::Conn(Incoming::Stats { reply }) => {
                    let _ = reply.send(worker_stats_json(
                        &handles,
                        &names,
                        &routing_name,
                        pending.len(),
                        started,
                        &shed,
                    ));
                }
                Event::Conn(Incoming::Models { reply }) => {
                    let _ = reply.send(models_reply.clone());
                }
                Event::Done(c) => {
                    if let Some(tx) = pending.remove(&c.id) {
                        let _ = tx.send(completion_json(&c));
                    }
                }
                Event::WorkerStopped => stopped += 1,
                Event::WorkerFailed(msg) => {
                    if failed.is_none() {
                        failed = Some(msg);
                    }
                    // A dead engine cannot drain; stop the rest too.
                    state.shutdown.store(true, Ordering::SeqCst);
                }
                Event::Wake => {}
            }
        }
        if state.is_shutdown() && !shutdown_sent {
            for h in &handles {
                let _ = h.mailbox.send(WorkerMsg::Shutdown);
            }
            shutdown_sent = true;
        }
        // Workers flush every completion before announcing their stop
        // (per-sender FIFO), so once all have stopped and pending is
        // empty nothing is in flight. A failed worker's requests can
        // never complete — don't wait on them.
        if shutdown_sent && stopped == handles.len() && (pending.is_empty() || failed.is_some())
        {
            break;
        }
    }

    // Fail whatever can no longer complete, then reattach the engines
    // in registry order.
    for (_, tx) in pending.drain() {
        let _ = tx.send(error_json("server is shutting down"));
    }
    let mut slots: Vec<Option<Engine>> = (0..n).map(|_| None).collect();
    for h in handles {
        let owns = h.owns;
        match h.handle.join() {
            Ok(engines) => {
                for (i, e) in owns.into_iter().zip(engines) {
                    slots[i] = Some(e);
                }
            }
            Err(_) => bail!("engine worker panicked"),
        }
    }
    registry.put_engines(
        slots
            .into_iter()
            .map(|s| s.expect("every worker returned its engines"))
            .collect(),
    );
    if let Some(msg) = failed {
        bail!("engine worker failed: {msg}");
    }
    Ok(())
}

/// Minimal client helper (used by tests and examples).
pub fn client_request(addr: &str, prompt: &str, max_new: usize) -> Result<Json> {
    client_request_model(addr, prompt, max_new, None)
}

/// Like [`client_request`], targeting a named model (protocol v2).
pub fn client_request_model(
    addr: &str,
    prompt: &str,
    max_new: usize,
    model: Option<&str>,
) -> Result<Json> {
    let mut msg = Json::obj();
    msg.set("prompt", Json::Str(prompt.into()));
    msg.set("max_new", Json::Num(max_new as f64));
    if let Some(m) = model {
        msg.set("model", Json::Str(m.to_string()));
    }
    client_line(addr, &msg.to_string())
}

/// Send one raw protocol line and return the first reply line (exercises
/// error paths that a well-formed helper could never produce).
pub fn client_line(addr: &str, line: &str) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{line}")?;
    let mut reader = BufReader::new(stream);
    let mut out = String::new();
    reader.read_line(&mut out)?;
    Json::parse(out.trim())
}

/// Fetch the stats snapshot.
pub fn client_stats(addr: &str) -> Result<Json> {
    client_line(addr, "{\"cmd\":\"stats\"}")
}

/// Fetch the hosted-model listing.
pub fn client_models(addr: &str) -> Result<Json> {
    client_line(addr, "{\"cmd\":\"models\"}")
}

/// Send the shutdown command.
pub fn client_shutdown(addr: &str) -> Result<()> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{{\"cmd\":\"shutdown\"}}")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let _ = reader.read_line(&mut line);
    Ok(())
}
