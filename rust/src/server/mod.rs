//! TCP JSONL serving front-end.
//!
//! Protocol: one JSON object per line.
//!   -> {"prompt": "...", "max_new": 32, "temperature": 0.7}
//!   <- {"id": 1, "text": "...", "latency_s": 0.12, "prompt_len": 9}
//!   -> {"cmd": "stats"}   <- {"decode_tokens": ..., "tok_per_s": ...}
//!   -> {"cmd": "shutdown"}
//!
//! The PJRT client is not `Send`, so the engine runs on the caller's
//! thread and connection handlers exchange plain data with it through a
//! shared queue (acceptor threads never touch XLA state).

use crate::coordinator::{Engine, Request};
use crate::json::Json;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

struct Incoming {
    req: Request,
    reply: Sender<Json>,
}

/// Shared state between acceptor threads and the engine loop.
#[derive(Clone)]
pub struct ServerState {
    incoming: Arc<Mutex<Vec<Incoming>>>,
    next_id: Arc<AtomicU64>,
    shutdown: Arc<AtomicBool>,
}

impl Default for ServerState {
    fn default() -> Self {
        Self::new()
    }
}

impl ServerState {
    pub fn new() -> Self {
        ServerState {
            incoming: Arc::new(Mutex::new(Vec::new())),
            next_id: Arc::new(AtomicU64::new(1)),
            shutdown: Arc::new(AtomicBool::new(false)),
        }
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }
}

fn handle_conn(stream: TcpStream, state: ServerState) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let msg = match Json::parse(&line) {
            Ok(m) => m,
            Err(e) => {
                let mut err = Json::obj();
                err.set("error", Json::Str(format!("bad json: {e}")));
                writeln!(writer, "{}", err.to_string())?;
                continue;
            }
        };
        match msg.get("cmd").and_then(Json::as_str) {
            Some("shutdown") => {
                state.shutdown.store(true, Ordering::SeqCst);
                writeln!(writer, "{{\"ok\":true}}")?;
                return Ok(());
            }
            Some("ping") => {
                writeln!(writer, "{{\"pong\":true}}")?;
                continue;
            }
            _ => {}
        }
        let prompt = msg
            .get("prompt")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string();
        let max_new = msg
            .get("max_new")
            .and_then(Json::as_usize)
            .unwrap_or(32);
        let temperature = msg
            .get("temperature")
            .and_then(Json::as_f64)
            .unwrap_or(0.0) as f32;
        let id = state.next_id.fetch_add(1, Ordering::SeqCst);
        let mut req = Request::from_text(id, &prompt, max_new);
        req.temperature = temperature;
        let (tx, rx) = channel();
        state
            .incoming
            .lock()
            .unwrap()
            .push(Incoming { req, reply: tx });
        // Block this connection until the engine answers.
        match rx.recv() {
            Ok(resp) => writeln!(writer, "{}", resp.to_string())?,
            Err(_) => break,
        }
    }
    let _ = peer;
    Ok(())
}

/// Run the serving loop: accepts connections on `addr`, feeds the engine,
/// replies per request. Returns once a `shutdown` command arrives and all
/// in-flight work is drained.
pub fn serve(engine: &mut Engine, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("bind {addr}"))?;
    listener.set_nonblocking(true)?;
    eprintln!("[server] listening on {addr}");
    let state = ServerState::new();
    let mut pending: Vec<(u64, Sender<Json>)> = Vec::new();

    loop {
        // Accept any waiting connections; each gets its own thread.
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    let st = state.clone();
                    std::thread::spawn(move || {
                        let _ = handle_conn(stream, st);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(e.into()),
            }
        }
        // Drain new requests into the engine.
        for inc in state.incoming.lock().unwrap().drain(..) {
            pending.push((inc.req.id, inc.reply));
            engine.submit(inc.req);
        }
        // Advance the engine.
        if !engine.is_idle() {
            engine.step()?;
        } else if state.is_shutdown() && pending.is_empty() {
            eprintln!("[server] shutdown");
            return Ok(());
        } else {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        // Deliver completions.
        if !pending.is_empty() {
            let done: Vec<_> = engine.completions.drain(..).collect();
            for c in done {
                if let Some(idx) = pending.iter().position(|(id, _)| *id == c.id) {
                    let (_, tx) = pending.swap_remove(idx);
                    let mut j = Json::obj();
                    j.set("id", Json::Num(c.id as f64));
                    j.set("text", Json::Str(c.text()));
                    j.set("prompt_len", Json::Num(c.prompt_len as f64));
                    j.set("latency_s", Json::Num(c.latency_s));
                    let _ = tx.send(j);
                }
            }
        }
    }
}

/// Minimal client helper (used by tests and examples).
pub fn client_request(addr: &str, prompt: &str, max_new: usize) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    let mut msg = Json::obj();
    msg.set("prompt", Json::Str(prompt.into()));
    msg.set("max_new", Json::Num(max_new as f64));
    writeln!(stream, "{}", msg.to_string())?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    Json::parse(line.trim())
}

/// Send the shutdown command.
pub fn client_shutdown(addr: &str) -> Result<()> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{{\"cmd\":\"shutdown\"}}")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let _ = reader.read_line(&mut line);
    Ok(())
}
