//! TCP JSONL serving front-end: one server, N named engines.
//!
//! Protocol **v2** (normative reference: `docs/PROTOCOL.md` at the repo
//! root — the schema regression tests in `tests/integration_server.rs`
//! assert the field lists documented there): one JSON object per line.
//!   -> {"prompt": "...", "max_new": 32, "temperature": 0.7,
//!       "model": "mla"}                          // model optional
//!   <- {"id": 1, "model": "mla", "text": "...", "max_new": 32,
//!       "latency_s": 0.12, "ttft_s": 0.02, "tpot_s": 0.005,
//!       "prompt_len": 9, ...}
//!   -> {"cmd": "models"}   <- {"models": [{"name": ..., "arch": ...,
//!                              ...}], "routing": "default:mla"}
//!   -> {"cmd": "stats"}    <- {"engines": {"<name>": <per-engine stats,
//!                              shape unchanged from v1>},
//!                              "server": {"routing": ..., ...}}
//!   -> {"cmd": "ping"}     <- {"pong": true}
//!   -> {"cmd": "shutdown"} <- {"ok": true}
//!
//! The server hosts an [`EngineRegistry`]: requests carrying a `model`
//! field go to that engine (an unknown name is an in-band error), the
//! rest follow the registry's [`RoutePolicy`] (`default:<name>` /
//! `round-robin` / `least-loaded`). A legacy single-model invocation is
//! just a one-engine registry named `default`, so every v1 client line
//! keeps working unchanged.
//!
//! Unknown fields on a request line are ignored (forward compatibility);
//! unknown *commands* are errors. Error paths answer in-band instead of
//! dropping the line:
//!   bad JSON         <- {"error": "bad json: ..."}
//!   unknown cmd      <- {"error": "unknown cmd `...`"}
//!   missing prompt   <- {"error": "missing prompt"}
//!   bad temperature  <- {"error": "bad temperature"}   // negative/NaN/inf
//!   bad model        <- {"error": "bad model"} / {"error": "unknown model `...`"}
//!
//! The engines run on the caller's thread (the XLA client is not `Send`);
//! connection handlers exchange plain data with them through a shared
//! queue, so acceptor threads never touch backend state. Every loop
//! iteration steps each non-idle engine once (the fair multi-engine
//! sweep — one model's long prefill never starves another's decodes) and
//! drains completions, delivering each through a per-request reply
//! channel looked up by id in O(1). A disconnected client's reply send
//! fails silently and its pending entry is removed with the completion,
//! so abandoned requests cannot wedge the loop or leak.

mod registry;

pub use registry::{EngineRegistry, RoutePolicy};

use crate::backend::Arch;
use crate::coordinator::{Engine, Request};
use crate::json::Json;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

enum Incoming {
    /// A generation request awaiting a completion reply. `model` is the
    /// request's explicit engine choice (`None` follows the routing
    /// policy); routing happens on the engine thread, where the live
    /// load depths are.
    Req { req: Request, model: Option<String>, reply: Sender<Json> },
    /// A stats snapshot request (answered by the engine loop).
    Stats { reply: Sender<Json> },
    /// A model-listing request (answered by the engine loop).
    Models { reply: Sender<Json> },
}

/// Shared state between acceptor threads and the engine loop.
#[derive(Clone)]
pub struct ServerState {
    incoming: Arc<Mutex<Vec<Incoming>>>,
    next_id: Arc<AtomicU64>,
    shutdown: Arc<AtomicBool>,
}

impl Default for ServerState {
    fn default() -> Self {
        Self::new()
    }
}

impl ServerState {
    pub fn new() -> Self {
        ServerState {
            incoming: Arc::new(Mutex::new(Vec::new())),
            next_id: Arc::new(AtomicU64::new(1)),
            shutdown: Arc::new(AtomicBool::new(false)),
        }
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }
}

fn error_json(msg: &str) -> Json {
    let mut err = Json::obj();
    err.set("error", Json::Str(msg.to_string()));
    err
}

fn handle_conn(stream: TcpStream, state: ServerState) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let msg = match Json::parse(&line) {
            Ok(m) => m,
            Err(e) => {
                writeln!(writer, "{}", error_json(&format!("bad json: {e}")).to_string())?;
                continue;
            }
        };
        match msg.get("cmd").and_then(Json::as_str) {
            Some("shutdown") => {
                state.shutdown.store(true, Ordering::SeqCst);
                writeln!(writer, "{{\"ok\":true}}")?;
                return Ok(());
            }
            Some("ping") => {
                writeln!(writer, "{{\"pong\":true}}")?;
                continue;
            }
            Some(cmd @ ("stats" | "models")) => {
                let (tx, rx) = channel();
                let inc = if cmd == "stats" {
                    Incoming::Stats { reply: tx }
                } else {
                    Incoming::Models { reply: tx }
                };
                state.incoming.lock().unwrap().push(inc);
                match rx.recv() {
                    Ok(resp) => writeln!(writer, "{}", resp.to_string())?,
                    Err(_) => break,
                }
                continue;
            }
            Some(other) => {
                writeln!(
                    writer,
                    "{}",
                    error_json(&format!("unknown cmd `{other}`")).to_string()
                )?;
                continue;
            }
            None => {}
        }
        let prompt = match msg.get("prompt").and_then(Json::as_str) {
            Some(p) if !p.is_empty() => p.to_string(),
            _ => {
                writeln!(writer, "{}", error_json("missing prompt").to_string())?;
                continue;
            }
        };
        // Sampling params are validated in-band at the edge: a negative,
        // NaN, infinite, or non-numeric temperature never reaches an
        // engine (JSON cannot encode NaN, but `1e999` overflows to inf).
        // The finiteness check runs on the f32 the engine will actually
        // use — a finite f64 like 1e300 saturates to inf in the cast.
        let temperature = match msg.get("temperature") {
            None => 0.0,
            Some(t) => match t.as_f64() {
                Some(v) if v >= 0.0 && (v as f32).is_finite() => v as f32,
                _ => {
                    writeln!(writer, "{}", error_json("bad temperature").to_string())?;
                    continue;
                }
            },
        };
        // An explicit model choice must be a string; the engine loop
        // checks it against the registry (unknown names answer in-band).
        let model = match msg.get("model") {
            None => None,
            Some(m) => match m.as_str() {
                Some(name) => Some(name.to_string()),
                None => {
                    writeln!(writer, "{}", error_json("bad model").to_string())?;
                    continue;
                }
            },
        };
        let max_new = msg
            .get("max_new")
            .and_then(Json::as_usize)
            .unwrap_or(32)
            .max(1);
        let id = state.next_id.fetch_add(1, Ordering::SeqCst);
        let mut req = Request::from_text(id, &prompt, max_new);
        req.temperature = temperature;
        let (tx, rx) = channel();
        state
            .incoming
            .lock()
            .unwrap()
            .push(Incoming::Req { req, model, reply: tx });
        // Block this connection until the engine answers.
        match rx.recv() {
            Ok(resp) => writeln!(writer, "{}", resp.to_string())?,
            Err(_) => break,
        }
    }
    Ok(())
}

/// Per-engine stats snapshot: counters, throughput, and p50/p95/p99
/// latency summaries for every recorded series (decode_s, prefill_s,
/// latency_s, queue_s, ttft_s, tpot_s, ...). This object's shape is the
/// v1 `stats` reply unchanged — v2 nests one per engine under
/// `engines.<name>`, so existing dashboards re-point instead of
/// re-parse.
fn engine_stats_json(engine: &Engine) -> Json {
    let m = &engine.metrics;
    let mut j = Json::obj();
    let mut counters = Json::obj();
    for (k, v) in m.counters() {
        counters.set(k, Json::Num(*v as f64));
    }
    j.set("counters", counters);
    j.set("policy", Json::Str(engine.policy_name().to_string()));
    j.set("decode_tok_per_s", Json::Num(engine.decode_throughput()));
    j.set("uptime_s", Json::Num(m.elapsed_s()));
    // Live queue depths of the StepPlan pipeline (waiting -> prefilling
    // -> decoding); chunk metrics land in the series below
    // (chunk_s / chunk_tokens) once the chunked policy runs.
    j.set("queued", Json::Num(engine.n_pending() as f64));
    j.set("prefilling", Json::Num(engine.n_prefilling() as f64));
    j.set("decoding", Json::Num(engine.n_decoding() as f64));
    // Cache memory accounting: actual bytes committed vs the worst-case
    // batch*capacity reservation (the paged cache's whole point).
    let cs = engine.cache_stats();
    let mut cache = Json::obj();
    cache.set("kind", Json::Str(cs.kind.to_string()));
    cache.set("bytes_total", Json::Num(cs.bytes_total as f64));
    cache.set("bytes_in_use", Json::Num(cs.bytes_in_use as f64));
    cache.set("bytes_worst_case", Json::Num(cs.bytes_worst_case as f64));
    cache.set("block_size", Json::Num(cs.block_size as f64));
    cache.set("blocks_total", Json::Num(cs.blocks_total as f64));
    cache.set("blocks_in_use", Json::Num(cs.blocks_in_use as f64));
    cache.set("blocks_reserved", Json::Num(cs.blocks_reserved as f64));
    cache.set("bytes_deduped", Json::Num(cs.bytes_deduped as f64));
    // Prefix-sharing counters ride along only when the prefix cache is
    // on (paged store + --prefix-cache on) — see docs/PROTOCOL.md.
    if let Some(ps) = cs.prefix {
        let mut pj = Json::obj();
        pj.set("lookups", Json::Num(ps.lookups as f64));
        pj.set("hits", Json::Num(ps.hits as f64));
        let rate = if ps.lookups > 0 {
            ps.hits as f64 / ps.lookups as f64
        } else {
            0.0
        };
        pj.set("hit_rate", Json::Num(rate));
        pj.set("blocks_shared", Json::Num(ps.blocks_shared as f64));
        pj.set("tokens_shared", Json::Num(ps.tokens_shared as f64));
        pj.set("blocks_cached", Json::Num(ps.blocks_cached as f64));
        pj.set("evictions", Json::Num(ps.evictions as f64));
        cache.set("prefix", pj);
    }
    j.set("cache", cache);
    for name in m.sample_names() {
        if let Some(s) = m.summary(&name) {
            let mut sj = Json::obj();
            sj.set("n", Json::Num(s.n as f64));
            sj.set("mean", Json::Num(s.mean));
            sj.set("p50", Json::Num(s.p50));
            sj.set("p95", Json::Num(s.p95));
            sj.set("p99", Json::Num(s.p99));
            sj.set("max", Json::Num(s.max));
            j.set(&name, sj);
        }
    }
    j
}

/// v2 stats: one v1-shaped object per engine under `engines`, plus a
/// `server` object for registry-level facts.
fn stats_json(registry: &EngineRegistry, pending: usize, started: Instant) -> Json {
    let mut j = Json::obj();
    let mut engines = Json::obj();
    for e in registry.engines() {
        engines.set(e.name(), engine_stats_json(e));
    }
    j.set("engines", engines);
    let mut srv = Json::obj();
    srv.set("models", Json::Num(registry.len() as f64));
    srv.set("routing", Json::Str(registry.route_policy().name()));
    srv.set("pending", Json::Num(pending as f64));
    srv.set("uptime_s", Json::Num(started.elapsed().as_secs_f64()));
    j.set("server", srv);
    j
}

/// `{"cmd":"models"}`: every hosted engine with its serving spec, plus
/// the routing policy. `default` marks the engine unrouted requests go
/// to under a `default:<name>` policy.
fn models_json(registry: &EngineRegistry) -> Json {
    let default = match registry.route_policy() {
        RoutePolicy::Default(name) => Some(name.clone()),
        _ => None,
    };
    let mut entries = Vec::new();
    for e in registry.engines() {
        let spec = e.spec();
        let mut m = Json::obj();
        m.set("name", Json::Str(e.name().to_string()));
        m.set("backend", Json::Str(spec.name.clone()));
        match spec.arch {
            Arch::Gqa => {
                m.set("arch", Json::Str("gqa".to_string()));
            }
            Arch::Mla { rank } => {
                m.set("arch", Json::Str("mla".to_string()));
                m.set("rank", Json::Num(rank as f64));
            }
        }
        m.set("policy", Json::Str(e.policy_name().to_string()));
        m.set("cache", Json::Str(e.cache.kind_name().to_string()));
        m.set("batch", Json::Num(spec.batch as f64));
        m.set("capacity", Json::Num(spec.capacity as f64));
        m.set("max_prompt", Json::Num(spec.max_prompt() as f64));
        m.set("default", Json::Bool(default.as_deref() == Some(e.name())));
        entries.push(m);
    }
    let mut j = Json::obj();
    j.set("models", Json::Arr(entries));
    j.set("routing", Json::Str(registry.route_policy().name()));
    j
}

fn completion_json(c: &crate::coordinator::Completion) -> Json {
    let mut j = Json::obj();
    j.set("id", Json::Num(c.id as f64));
    j.set("model", Json::Str(c.model.clone()));
    j.set("text", Json::Str(c.text()));
    j.set("prompt_len", Json::Num(c.prompt_len as f64));
    j.set("max_new", Json::Num(c.max_new as f64));
    j.set("latency_s", Json::Num(c.latency_s));
    j.set("queue_s", Json::Num(c.queue_s));
    j.set("prefill_s", Json::Num(c.prefill_s));
    j.set("ttft_s", Json::Num(c.ttft_s));
    j.set("tpot_s", Json::Num(c.tpot_s));
    j
}

/// Run the serving loop over a registry of named engines: accepts
/// connections on `addr`, routes each request to an engine (explicit
/// `model` field, else the registry's [`RoutePolicy`]), steps every
/// non-idle engine each iteration, and replies per request. Returns once
/// a `shutdown` command arrives and all in-flight work is drained.
pub fn serve(registry: &mut EngineRegistry, addr: &str) -> Result<()> {
    registry.validate()?;
    let listener = TcpListener::bind(addr)
        .with_context(|| format!("bind {addr}"))?;
    listener.set_nonblocking(true)?;
    eprintln!(
        "[server] listening on {addr} ({} model(s): {}; routing `{}`)",
        registry.len(),
        registry.names().join(", "),
        registry.route_policy().name()
    );
    let started = Instant::now();
    let state = ServerState::new();
    // Reply channels by request id — O(1) completion delivery (the old
    // Vec scan was O(pending) per completion).
    let mut pending: HashMap<u64, Sender<Json>> = HashMap::new();

    loop {
        // Accept any waiting connections; each gets its own thread.
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    let st = state.clone();
                    std::thread::spawn(move || {
                        let _ = handle_conn(stream, st);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(e.into()),
            }
        }
        // Drain new work into the engines; answer stats/models
        // immediately. Routing runs here — on the engine thread — so
        // `least-loaded` sees live depths, and unknown models answer
        // in-band without ever touching an engine.
        for inc in state.incoming.lock().unwrap().drain(..) {
            match inc {
                Incoming::Req { mut req, model, reply } => {
                    match registry.route(model.as_deref()) {
                        Ok(idx) => {
                            let engine = registry.engine_at_mut(idx);
                            // Server-edge clamp: a hostile max_new cannot
                            // demand more than the engine's remaining
                            // capacity for this prompt. The completion
                            // echoes the effective budget.
                            let ceiling = engine.max_new_ceiling(req.prompt.len());
                            req.max_new_tokens = req.max_new_tokens.min(ceiling);
                            pending.insert(req.id, reply);
                            engine.submit(req);
                        }
                        Err(e) => {
                            let _ = reply.send(error_json(&format!("{e}")));
                        }
                    }
                }
                Incoming::Stats { reply } => {
                    let _ = reply.send(stats_json(registry, pending.len(), started));
                }
                Incoming::Models { reply } => {
                    let _ = reply.send(models_json(registry));
                }
            }
        }
        // Advance every non-idle engine one iteration (the fair sweep).
        if !registry.is_idle() {
            registry.step_non_idle()?;
        } else if state.is_shutdown() && pending.is_empty() {
            eprintln!("[server] shutdown");
            return Ok(());
        } else {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        // Deliver completions (drained every iteration so the history
        // cannot grow without bound in server mode). A send to a
        // disconnected client fails silently; the pending entry is gone
        // either way, so abandoned requests cannot leak.
        for c in registry.take_completions() {
            if let Some(tx) = pending.remove(&c.id) {
                let _ = tx.send(completion_json(&c));
            }
        }
    }
}

/// Minimal client helper (used by tests and examples).
pub fn client_request(addr: &str, prompt: &str, max_new: usize) -> Result<Json> {
    client_request_model(addr, prompt, max_new, None)
}

/// Like [`client_request`], targeting a named model (protocol v2).
pub fn client_request_model(
    addr: &str,
    prompt: &str,
    max_new: usize,
    model: Option<&str>,
) -> Result<Json> {
    let mut msg = Json::obj();
    msg.set("prompt", Json::Str(prompt.into()));
    msg.set("max_new", Json::Num(max_new as f64));
    if let Some(m) = model {
        msg.set("model", Json::Str(m.to_string()));
    }
    client_line(addr, &msg.to_string())
}

/// Send one raw protocol line and return the first reply line (exercises
/// error paths that a well-formed helper could never produce).
pub fn client_line(addr: &str, line: &str) -> Result<Json> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{line}")?;
    let mut reader = BufReader::new(stream);
    let mut out = String::new();
    reader.read_line(&mut out)?;
    Json::parse(out.trim())
}

/// Fetch the stats snapshot.
pub fn client_stats(addr: &str) -> Result<Json> {
    client_line(addr, "{\"cmd\":\"stats\"}")
}

/// Fetch the hosted-model listing.
pub fn client_models(addr: &str) -> Result<Json> {
    client_line(addr, "{\"cmd\":\"models\"}")
}

/// Send the shutdown command.
pub fn client_shutdown(addr: &str) -> Result<()> {
    let mut stream = TcpStream::connect(addr)?;
    writeln!(stream, "{{\"cmd\":\"shutdown\"}}")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let _ = reader.read_line(&mut line);
    Ok(())
}
