//! Serving-level quality harness (`transmla eval`).
//!
//! The paper's claim is two-sided: the serving speedup (measured by
//! [`crate::workload`] and the benches) *and* output quality recovered
//! after conversion. This subsystem measures the second side at the
//! level users experience it — completion text over the wire — by
//! fanning one dataset across N hosted models through protocol-v2
//! routing and reporting a per-model × per-scorer matrix:
//!
//!   * [`dataset`] — JSONL loader (`{id?, input, expected}` rows);
//!     malformed lines are in-band error entries, never a crash, and
//!     missing/duplicate ids are repaired with deterministic synthetic
//!     ids so the cross-model join can never drop or cross rows;
//!   * [`scorers`] — the pluggable [`Scorer`] family (exact, contains,
//!     case-folded contains, levenshtein-with-threshold, a bounded
//!     zero-dep regex engine, JSON validity), selected by repeatable
//!     CLI flags and composable per run;
//!   * [`driver`] — fans every row to every model against a live
//!     server (self-hosted registry or `--attach`) with bounded
//!     in-flight concurrency, transport retries, and per-row latency
//!     capture; results are row-aligned by construction;
//!   * [`report`] — the matrix (pass-rate, mean score, n, errors) with
//!     `metrics::summarize` latency percentiles and per-model deltas
//!     against a named `--baseline` model, emitted as deterministic
//!     JSONL + static HTML like the workload report.
//!
//! The relationship to [`crate::eval`]: that module is the *perplexity*
//! layer (logit-level loss over the artifact executables, feeding the
//! paper's tables); `qeval` is the *serving* layer the registry made
//! possible — same question, asked end-to-end. With `--baseline gqa`,
//! an MLA twin's row reads directly as quality-delta + latency-delta:
//! "did conversion hurt, and what did it buy".

pub mod dataset;
pub mod driver;
pub mod report;
pub mod scorers;

pub use dataset::{Dataset, Row};
pub use driver::{run_eval, EvalRun, ModelRun, RowOutcome};
pub use report::{EvalReport, ModelReport, ScorerCell};
pub use scorers::{Score, Scorer};

/// Minimal HTML escaping for report text cells.
pub(crate) fn html_escape(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}
