//! Per-model × per-scorer quality report with baseline deltas.
//!
//! [`EvalReport::build`] folds one [`EvalRun`] into a matrix: for each
//! model, each scorer's pass count / pass rate / mean value over the
//! completed rows, plus error counts and `metrics::summarize` latency
//! percentiles. With a named baseline model, every other model's
//! serialized row carries a `delta` object — per-scorer quality deltas
//! and latency-percentile deltas against it, which is the GQA↔MLA A/B
//! in one field ("did conversion hurt, and what did it buy").
//!
//! Determinism contract (mirrors the workload report): `build` is pure
//! in its inputs, and [`EvalReport::to_jsonl`] / [`EvalReport::render_html`]
//! serialize through the `BTreeMap`-backed [`Json`] writer and
//! fixed-precision formatting — identical runs produce identical bytes,
//! pinned by `integration_qeval.rs`.

use super::dataset::Dataset;
use super::driver::{EvalRun, RowOutcome};
use super::scorers::Scorer;
use crate::json::Json;
use crate::metrics::{summarize, Summary};
use anyhow::{bail, Context, Result};

/// One (model, scorer) matrix cell.
#[derive(Clone, Debug, PartialEq)]
pub struct ScorerCell {
    pub scorer: String,
    /// Completed rows this scorer graded (error rows are not scored).
    pub n: usize,
    pub passed: usize,
    /// Mean graded value over the `n` rows (0.0 when none completed).
    pub mean: f64,
}

impl ScorerCell {
    pub fn pass_rate(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.passed as f64 / self.n as f64
        }
    }
}

/// One model's report row.
#[derive(Clone, Debug)]
pub struct ModelReport {
    pub model: String,
    /// Rows attempted (= dataset rows).
    pub n: usize,
    pub completed: usize,
    pub errors: usize,
    /// One cell per scorer, in scorer-selection order.
    pub cells: Vec<ScorerCell>,
    /// Server-reported series over completed rows.
    pub ttft: Option<Summary>,
    pub latency: Option<Summary>,
}

/// The full eval report: dataset diagnostics + one row per model.
#[derive(Clone, Debug)]
pub struct EvalReport {
    pub label: String,
    pub baseline: Option<String>,
    pub n_rows: usize,
    /// Dataset lines that failed to parse (in-band, never fatal).
    pub malformed: usize,
    /// Rows whose id was repaired (missing or duplicate).
    pub synthetic_ids: usize,
    pub dup_ids: usize,
    pub wall_s: f64,
    pub models: Vec<ModelReport>,
}

impl EvalReport {
    /// Fold a run into the matrix. Pure in its inputs. Fails on
    /// structural problems only: no scorers, duplicate scorer names, a
    /// baseline that was not evaluated, or row-count drift between the
    /// dataset and a model run (the join invariant).
    pub fn build(
        label: &str,
        ds: &Dataset,
        scorers: &[Box<dyn Scorer>],
        run: &EvalRun,
        baseline: Option<&str>,
    ) -> Result<EvalReport> {
        if scorers.is_empty() {
            bail!("no scorers selected");
        }
        for (i, s) in scorers.iter().enumerate() {
            if scorers[..i].iter().any(|o| o.name() == s.name()) {
                bail!("duplicate scorer `{}`", s.name());
            }
        }
        if let Some(b) = baseline {
            if !run.models.iter().any(|m| m.model == b) {
                bail!("baseline `{b}` is not among the evaluated models");
            }
        }
        let mut models = Vec::new();
        for mr in &run.models {
            if mr.results.len() != ds.rows.len() {
                bail!(
                    "model `{}` returned {} results for {} dataset rows",
                    mr.model,
                    mr.results.len(),
                    ds.rows.len()
                );
            }
            let mut cells: Vec<ScorerCell> = scorers
                .iter()
                .map(|s| ScorerCell { scorer: s.name(), n: 0, passed: 0, mean: 0.0 })
                .collect();
            let (mut ttft, mut latency) = (Vec::new(), Vec::new());
            let mut errors = 0usize;
            for (row, res) in ds.rows.iter().zip(&mr.results) {
                match res {
                    RowOutcome::Done { output, ttft_s, latency_s, .. } => {
                        ttft.push(*ttft_s);
                        latency.push(*latency_s);
                        for (cell, s) in cells.iter_mut().zip(scorers) {
                            let sc = s.score(output, &row.expected);
                            cell.n += 1;
                            cell.passed += usize::from(sc.passed);
                            cell.mean += sc.value;
                        }
                    }
                    RowOutcome::Error { .. } => errors += 1,
                }
            }
            for cell in &mut cells {
                if cell.n > 0 {
                    cell.mean /= cell.n as f64;
                }
            }
            models.push(ModelReport {
                model: mr.model.clone(),
                n: mr.results.len(),
                completed: mr.results.len() - errors,
                errors,
                cells,
                ttft: summarize(&ttft),
                latency: summarize(&latency),
            });
        }
        Ok(EvalReport {
            label: label.to_string(),
            baseline: baseline.map(str::to_string),
            n_rows: ds.rows.len(),
            malformed: ds.errors.len(),
            synthetic_ids: ds.synthetic_ids,
            dup_ids: ds.dup_ids,
            wall_s: run.wall_s,
            models,
        })
    }

    fn baseline_model(&self) -> Option<&ModelReport> {
        self.baseline.as_deref().and_then(|b| self.models.iter().find(|m| m.model == b))
    }

    /// Serialize: one `eval-meta` line (label, dataset diagnostics,
    /// scorer and model listings), then one `eval-model` line per
    /// model; non-baseline rows carry the `delta` object. Deterministic
    /// key order via the `Json` writer.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        let mut meta = Json::obj();
        meta.set("kind", Json::Str("eval-meta".into()));
        meta.set("label", Json::Str(self.label.clone()));
        meta.set(
            "baseline",
            match &self.baseline {
                Some(b) => Json::Str(b.clone()),
                None => Json::Null,
            },
        );
        meta.set("n_rows", Json::Num(self.n_rows as f64));
        meta.set("malformed", Json::Num(self.malformed as f64));
        meta.set("synthetic_ids", Json::Num(self.synthetic_ids as f64));
        meta.set("dup_ids", Json::Num(self.dup_ids as f64));
        meta.set("wall_s", Json::Num(self.wall_s));
        meta.set(
            "scorers",
            Json::Arr(
                self.models
                    .first()
                    .map(|m| m.cells.iter().map(|c| Json::Str(c.scorer.clone())).collect())
                    .unwrap_or_default(),
            ),
        );
        meta.set(
            "models",
            Json::Arr(self.models.iter().map(|m| Json::Str(m.model.clone())).collect()),
        );
        out.push_str(&meta.to_string());
        out.push('\n');
        let base = self.baseline_model();
        for m in &self.models {
            let mut j = Json::obj();
            j.set("kind", Json::Str("eval-model".into()));
            j.set("model", Json::Str(m.model.clone()));
            j.set("n", Json::Num(m.n as f64));
            j.set("completed", Json::Num(m.completed as f64));
            j.set("errors", Json::Num(m.errors as f64));
            let mut scores = Json::obj();
            for c in &m.cells {
                let mut cj = Json::obj();
                cj.set("n", Json::Num(c.n as f64));
                cj.set("passed", Json::Num(c.passed as f64));
                cj.set("pass_rate", Json::Num(c.pass_rate()));
                cj.set("mean", Json::Num(c.mean));
                scores.set(&c.scorer, cj);
            }
            j.set("scores", scores);
            for (name, s) in [("ttft_s", &m.ttft), ("latency_s", &m.latency)] {
                if let Some(s) = s {
                    j.set(name, summary_json(s));
                }
            }
            if let Some(base) = base {
                if base.model != m.model {
                    j.set("delta", delta_json(base, m));
                }
            }
            out.push_str(&j.to_string());
            out.push('\n');
        }
        out
    }

    /// Parse a [`EvalReport::to_jsonl`] document back as
    /// `(meta, model rows)`, validating the keys the comparison
    /// tooling relies on.
    pub fn parse(text: &str) -> Result<(Json, Vec<Json>)> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let meta = Json::parse(lines.next().context("empty eval report")?)?;
        if meta.get("kind").and_then(Json::as_str) != Some("eval-meta") {
            bail!("not an eval report (missing `\"kind\":\"eval-meta\"` meta line)");
        }
        for k in ["label", "n_rows", "malformed", "synthetic_ids", "dup_ids", "scorers", "models"]
        {
            meta.get(k).with_context(|| format!("eval meta missing `{k}`"))?;
        }
        let mut rows = Vec::new();
        for line in lines {
            let j = Json::parse(line)?;
            if j.get("kind").and_then(Json::as_str) != Some("eval-model") {
                bail!("unexpected line kind in eval report (want `eval-model`)");
            }
            for k in ["model", "n", "completed", "errors", "scores"] {
                j.get(k).with_context(|| format!("eval model row missing `{k}`"))?;
            }
            rows.push(j);
        }
        Ok((meta, rows))
    }

    /// Console summary: one line per model, deltas inline.
    pub fn human(&self) -> String {
        let mut out = format!(
            "{}: {} rows ({} malformed, {} synthetic ids, {} duplicate ids), \
             {} models in {:.2}s",
            self.label,
            self.n_rows,
            self.malformed,
            self.synthetic_ids,
            self.dup_ids,
            self.models.len(),
            self.wall_s
        );
        let base = self.baseline_model();
        for m in &self.models {
            out.push_str(&format!(
                "\n  {}: {}/{} completed, {} errors",
                m.model, m.completed, m.n, m.errors
            ));
            for c in &m.cells {
                out.push_str(&format!(
                    " | {} {:.1}% (mean {:.3})",
                    c.scorer,
                    c.pass_rate() * 100.0,
                    c.mean
                ));
            }
            if let Some(s) = &m.latency {
                out.push_str(&format!(" | lat p50 {:.1}ms", s.p50 * 1e3));
            }
            if let Some(b) = base {
                if b.model != m.model {
                    for (c, bc) in m.cells.iter().zip(&b.cells) {
                        out.push_str(&format!(
                            " | Δ{} {:+.1}pp",
                            c.scorer,
                            (c.pass_rate() - bc.pass_rate()) * 100.0
                        ));
                    }
                }
            }
        }
        out
    }

    /// Static HTML: the same matrix, one row per model, per-scorer
    /// pass-rate cells annotated with the baseline delta. Fixed
    /// precision throughout — deterministic bytes.
    pub fn render_html(&self, title: &str) -> String {
        let esc = super::html_escape;
        let base = self.baseline_model();
        let mut h = String::new();
        h.push_str("<!doctype html>\n<html><head><meta charset=\"utf-8\">\n");
        h.push_str(&format!("<title>{}</title>\n", esc(title)));
        h.push_str(
            "<style>body{font:14px sans-serif;margin:2em}table{border-collapse:collapse}\n\
             th,td{border:1px solid #999;padding:4px 8px;text-align:right}\n\
             th{background:#eee}td.l,th.l{text-align:left}</style></head><body>\n",
        );
        h.push_str(&format!("<h1>{}</h1>\n", esc(title)));
        h.push_str(&format!(
            "<p>label <b>{}</b> — {} rows, {} malformed lines, {} synthetic ids \
             ({} duplicates), wall {:.2}s</p>\n<table>\n<tr><th class=\"l\">model</th>\
             <th>n</th><th>completed</th><th>errors</th>",
            esc(&self.label),
            self.n_rows,
            self.malformed,
            self.synthetic_ids,
            self.dup_ids,
            self.wall_s
        ));
        let scorer_names: Vec<&str> = self
            .models
            .first()
            .map(|m| m.cells.iter().map(|c| c.scorer.as_str()).collect())
            .unwrap_or_default();
        for name in &scorer_names {
            h.push_str(&format!("<th>{} pass</th><th>{} mean</th>", esc(name), esc(name)));
        }
        h.push_str("<th>ttft p50 (ms)</th><th>lat p50 (ms)</th><th>lat p95 (ms)</th></tr>\n");
        let ms = |s: &Option<Summary>, f: fn(&Summary) -> f64| match s {
            Some(s) => format!("{:.2}", f(s) * 1e3),
            None => "–".to_string(),
        };
        for m in &self.models {
            let is_base = base.map(|b| b.model == m.model).unwrap_or(false);
            h.push_str(&format!(
                "<tr><td class=\"l\">{}{}</td><td>{}</td><td>{}</td><td>{}</td>",
                esc(&m.model),
                if is_base { " (baseline)" } else { "" },
                m.n,
                m.completed,
                m.errors
            ));
            for c in &m.cells {
                let delta = match base {
                    Some(b) if !is_base => b
                        .cells
                        .iter()
                        .find(|bc| bc.scorer == c.scorer)
                        .map(|bc| {
                            format!(
                                " ({:+.1}pp)",
                                (c.pass_rate() - bc.pass_rate()) * 100.0
                            )
                        })
                        .unwrap_or_default(),
                    _ => String::new(),
                };
                h.push_str(&format!(
                    "<td>{:.1}%{}</td><td>{:.3}</td>",
                    c.pass_rate() * 100.0,
                    delta,
                    c.mean
                ));
            }
            h.push_str(&format!(
                "<td>{}</td><td>{}</td><td>{}</td></tr>\n",
                ms(&m.ttft, |s| s.p50),
                ms(&m.latency, |s| s.p50),
                ms(&m.latency, |s| s.p95)
            ));
        }
        h.push_str("</table></body></html>\n");
        h
    }
}

fn summary_json(s: &Summary) -> Json {
    let mut j = Json::obj();
    j.set("n", Json::Num(s.n as f64));
    j.set("mean", Json::Num(s.mean));
    j.set("p50", Json::Num(s.p50));
    j.set("p95", Json::Num(s.p95));
    j.set("p99", Json::Num(s.p99));
    j.set("max", Json::Num(s.max));
    j
}

/// Per-scorer quality deltas and latency-percentile deltas vs the
/// baseline (positive = this model higher than baseline).
fn delta_json(base: &ModelReport, m: &ModelReport) -> Json {
    let mut d = Json::obj();
    let mut scores = Json::obj();
    for c in &m.cells {
        if let Some(bc) = base.cells.iter().find(|b| b.scorer == c.scorer) {
            let mut cj = Json::obj();
            cj.set("pass_rate", Json::Num(c.pass_rate() - bc.pass_rate()));
            cj.set("mean", Json::Num(c.mean - bc.mean));
            scores.set(&c.scorer, cj);
        }
    }
    d.set("scores", scores);
    let p50 = |s: &Option<Summary>| s.as_ref().map(|s| s.p50);
    if let (Some(a), Some(b)) = (p50(&m.latency), p50(&base.latency)) {
        d.set("latency_p50_s", Json::Num(a - b));
    }
    if let (Some(a), Some(b)) = (p50(&m.ttft), p50(&base.ttft)) {
        d.set("ttft_p50_s", Json::Num(a - b));
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qeval::driver::ModelRun;
    use crate::qeval::scorers;

    /// Deterministic synthetic run: model 0 echoes every expected
    /// value, model 1 misses odd rows, timings are index-derived —
    /// no server, no clock, byte-stable.
    fn synthetic(rows: usize) -> (Dataset, EvalRun, Vec<Box<dyn Scorer>>) {
        let pairs: Vec<(String, String)> = (0..rows)
            .map(|i| (format!("in-{i}"), format!("out-{i}")))
            .collect();
        let refs: Vec<(&str, &str)> =
            pairs.iter().map(|(a, b)| (a.as_str(), b.as_str())).collect();
        let ds = Dataset::from_pairs(&refs);
        let outcome = |model: usize, i: usize| {
            if model == 1 && i == rows - 1 {
                return RowOutcome::Error { msg: "overloaded".into() };
            }
            let output = if model == 0 || i % 2 == 0 {
                format!("out-{i}")
            } else {
                format!("out-{i}X")
            };
            RowOutcome::Done {
                output,
                ttft_s: 0.010 + i as f64 * 0.001 + model as f64 * 0.002,
                tpot_s: 0.002,
                latency_s: 0.050 + i as f64 * 0.001,
                client_s: 0.055,
            }
        };
        let run = EvalRun {
            models: vec![
                ModelRun {
                    model: "gqa".into(),
                    results: (0..rows).map(|i| outcome(0, i)).collect(),
                },
                ModelRun {
                    model: "mla".into(),
                    results: (0..rows).map(|i| outcome(1, i)).collect(),
                },
            ],
            wall_s: 1.25,
        };
        let scorers = scorers::from_flags(&[
            ("exact".to_string(), "true".to_string()),
            ("levenshtein".to_string(), "0.8".to_string()),
        ])
        .unwrap();
        (ds, run, scorers)
    }

    #[test]
    fn matrix_counts_and_deltas() {
        let (ds, run, sc) = synthetic(6);
        let rep = EvalReport::build("t", &ds, &sc, &run, Some("gqa")).unwrap();
        assert_eq!(rep.models.len(), 2);
        let gqa = &rep.models[0];
        assert_eq!((gqa.n, gqa.completed, gqa.errors), (6, 6, 0));
        assert_eq!(gqa.cells[0].pass_rate(), 1.0, "baseline echoes expected");
        let mla = &rep.models[1];
        assert_eq!((mla.n, mla.completed, mla.errors), (6, 5, 1));
        // 5 completed rows 0..=4; odd rows 1,3 mismatch -> 3/5 exact.
        assert_eq!(mla.cells[0].passed, 3);
        assert!((mla.cells[0].pass_rate() - 0.6).abs() < 1e-12);
        // levenshtein similarity of "out-1X" vs "out-1": 1 - 1/6.
        assert!(mla.cells[1].mean > 0.9 && mla.cells[1].mean < 1.0);
        assert_eq!(gqa.latency.as_ref().unwrap().n, 6);
    }

    #[test]
    fn jsonl_roundtrip_deltas_and_validation() {
        let (ds, run, sc) = synthetic(6);
        let rep = EvalReport::build("t", &ds, &sc, &run, Some("gqa")).unwrap();
        let text = rep.to_jsonl();
        let (meta, rows) = EvalReport::parse(&text).unwrap();
        assert_eq!(meta.get("baseline").and_then(Json::as_str), Some("gqa"));
        assert_eq!(meta.get("scorers").and_then(Json::as_arr).unwrap().len(), 2);
        assert_eq!(rows.len(), 2);
        assert!(rows[0].get("delta").is_none(), "baseline row carries no delta");
        let delta = rows[1].get("delta").expect("non-baseline row carries delta");
        let d_exact = delta
            .get("scores")
            .and_then(|s| s.get("exact"))
            .and_then(|e| e.get("pass_rate"))
            .and_then(Json::as_f64)
            .unwrap();
        assert!((d_exact - (0.6 - 1.0)).abs() < 1e-12);
        assert!(delta.get("latency_p50_s").is_some());
        // Validation: truncated or mislabeled documents are rejected.
        assert!(EvalReport::parse("").is_err());
        assert!(EvalReport::parse("{\"kind\":\"workload\"}").is_err());
    }

    #[test]
    fn bytes_reproducible_and_input_sensitive() {
        let (ds, run, sc) = synthetic(5);
        let a = EvalReport::build("t", &ds, &sc, &run, Some("gqa")).unwrap();
        let b = EvalReport::build("t", &ds, &sc, &run, Some("gqa")).unwrap();
        assert_eq!(a.to_jsonl(), b.to_jsonl(), "JSONL byte-stable");
        assert_eq!(a.render_html("x"), b.render_html("x"), "HTML byte-stable");
        let (ds2, run2, sc2) = synthetic(4);
        let c = EvalReport::build("t", &ds2, &sc2, &run2, Some("gqa")).unwrap();
        assert_ne!(a.to_jsonl(), c.to_jsonl(), "different inputs, different bytes");
        let html = a.render_html("transmla eval report");
        assert!(html.contains("(baseline)"));
        assert!(html.contains("pp)"), "delta annotation present");
    }

    #[test]
    fn structural_errors_bail() {
        let (ds, run, sc) = synthetic(3);
        assert!(EvalReport::build("t", &ds, &sc, &run, Some("nope")).is_err());
        assert!(EvalReport::build("t", &ds, &[], &run, None).is_err());
        let mut short = run.clone();
        short.models[0].results.pop();
        assert!(EvalReport::build("t", &ds, &sc, &short, None).is_err());
    }

    #[test]
    fn error_only_model_reports_empty_cells() {
        let ds = Dataset::from_pairs(&[("p", "e")]);
        let run = EvalRun {
            models: vec![ModelRun {
                model: "m".into(),
                results: vec![RowOutcome::Error { msg: "nope".into() }],
            }],
            wall_s: 0.1,
        };
        let sc = scorers::from_flags(&[("exact".to_string(), "true".to_string())]).unwrap();
        let rep = EvalReport::build("t", &ds, &sc, &run, None).unwrap();
        let m = &rep.models[0];
        assert_eq!((m.completed, m.errors), (0, 1));
        assert_eq!(m.cells[0].n, 0);
        assert_eq!(m.cells[0].pass_rate(), 0.0);
        assert!(m.latency.is_none());
        assert!(rep.render_html("t").contains("–"), "missing summaries render as dashes");
    }
}
