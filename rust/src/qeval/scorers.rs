//! Pluggable output scorers for the quality harness.
//!
//! A [`Scorer`] grades one `(output, expected)` string pair into a
//! [`Score`] — a binary pass plus a graded value in `[0, 1]` — and the
//! harness runs every selected scorer over every completed row, so one
//! eval produces a per-model × per-scorer matrix. Scorers are selected
//! by repeatable CLI flags ([`from_flags`]) and composable per run:
//!
//! | flag               | scorer                                        |
//! |--------------------|-----------------------------------------------|
//! | `--exact`          | output equals expected, byte for byte         |
//! | `--contains`       | output contains expected as a substring       |
//! | `--contains-i`     | same, case-folded                             |
//! | `--levenshtein M`  | normalized edit similarity ≥ M (graded value) |
//! | `--regex PATTERN`  | output matches PATTERN                        |
//! | `--json`           | output parses as JSON                         |
//!
//! The regex scorer runs a deliberately small engine written here
//! (zero-dep repo): literals, `.`, postfix `* + ?`, classes with
//! ranges and negation, `\d \w \s` (and negations), anchors `^`/`$`,
//! and top-level alternation — no groups. Compilation never panics
//! (errors are `Err`), and matching carries a hard step budget so a
//! pathological pattern reports "no match" instead of hanging; both
//! are pinned by the property tests below.

use crate::json::Json;
use anyhow::{bail, Context, Result};

/// One scorer's verdict on one `(output, expected)` pair.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Score {
    /// Pass/fail under the scorer's own criterion.
    pub passed: bool,
    /// Graded value in `[0, 1]` (binary scorers report 1.0 or 0.0).
    pub value: f64,
}

impl Score {
    fn binary(passed: bool) -> Score {
        Score { passed, value: if passed { 1.0 } else { 0.0 } }
    }
}

/// A pluggable output scorer (`Send + Sync` so one set can be shared
/// across driver threads).
pub trait Scorer: Send + Sync {
    /// Stable name — the report's column key, unique per run.
    fn name(&self) -> String;
    fn score(&self, output: &str, expected: &str) -> Score;
}

/// `--exact`: output equals expected, byte for byte.
pub struct Exact;

impl Scorer for Exact {
    fn name(&self) -> String {
        "exact".into()
    }

    fn score(&self, output: &str, expected: &str) -> Score {
        Score::binary(output == expected)
    }
}

/// `--contains` / `--contains-i`: output contains expected as a
/// substring (optionally case-folded).
pub struct Contains {
    pub case_insensitive: bool,
}

impl Scorer for Contains {
    fn name(&self) -> String {
        if self.case_insensitive { "contains-i".into() } else { "contains".into() }
    }

    fn score(&self, output: &str, expected: &str) -> Score {
        let hit = if self.case_insensitive {
            output.to_lowercase().contains(&expected.to_lowercase())
        } else {
            output.contains(expected)
        };
        Score::binary(hit)
    }
}

/// `--json`: output parses as JSON (expected is ignored — validity is
/// the criterion, useful for tool-call style outputs).
pub struct JsonValidity;

impl Scorer for JsonValidity {
    fn name(&self) -> String {
        "json".into()
    }

    fn score(&self, output: &str, _expected: &str) -> Score {
        Score::binary(Json::parse(output.trim()).is_ok())
    }
}

/// `--levenshtein M`: normalized edit similarity, the one graded
/// scorer — `value` is the similarity itself, `passed` is `value >= M`.
pub struct Levenshtein {
    pub min_sim: f64,
}

impl Levenshtein {
    pub fn new(min_sim: f64) -> Result<Levenshtein> {
        if !min_sim.is_finite() || !(0.0..=1.0).contains(&min_sim) {
            bail!("levenshtein threshold `{min_sim}` out of range (want [0, 1])");
        }
        Ok(Levenshtein { min_sim })
    }
}

impl Scorer for Levenshtein {
    fn name(&self) -> String {
        "levenshtein".into()
    }

    fn score(&self, output: &str, expected: &str) -> Score {
        let sim = similarity(output, expected);
        Score { passed: sim >= self.min_sim, value: sim }
    }
}

/// Levenshtein edit distance over chars (two-row DP: O(|a|·|b|) time,
/// O(min) memory would need the shorter row — |b|+1 is small enough).
pub fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    if a.is_empty() {
        return b.len();
    }
    if b.is_empty() {
        return a.len();
    }
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Normalized similarity: `1 - dist / max(len)`, in `[0, 1]`; two empty
/// strings are identical (1.0).
pub fn similarity(a: &str, b: &str) -> f64 {
    let m = a.chars().count().max(b.chars().count());
    if m == 0 {
        return 1.0;
    }
    1.0 - levenshtein(a, b) as f64 / m as f64
}

/// `--regex PATTERN`: output matches the pattern (expected ignored).
pub struct RegexScorer {
    re: Regex,
}

impl RegexScorer {
    pub fn new(pattern: &str) -> Result<RegexScorer> {
        Ok(RegexScorer { re: Regex::compile(pattern)? })
    }
}

impl Scorer for RegexScorer {
    fn name(&self) -> String {
        "regex".into()
    }

    fn score(&self, output: &str, _expected: &str) -> Score {
        Score::binary(self.re.is_match(output))
    }
}

// ---------------------------------------------------------------------
// The bounded regex engine.

const MAX_PIECES: usize = 256;
const MAX_ALTS: usize = 64;
/// Hard cap on matcher recursion steps per `is_match` call; exhaustion
/// reports "no match" rather than hanging on pathological backtracking.
const STEP_BUDGET: usize = 1 << 20;

/// Compiled pattern: top-level alternatives, each a piece sequence with
/// optional `^`/`$` anchors. Recursion depth is bounded by the piece
/// count (≤ [`MAX_PIECES`]), total work by [`STEP_BUDGET`].
pub struct Regex {
    alts: Vec<Alt>,
}

#[derive(Clone, Debug)]
struct Alt {
    anchor_start: bool,
    anchor_end: bool,
    pieces: Vec<Piece>,
}

#[derive(Clone, Debug)]
struct Piece {
    atom: Atom,
    rep: Rep,
}

#[derive(Clone, Debug)]
enum Atom {
    Lit(char),
    Any,
    /// Inclusive char ranges (a single char is a degenerate range).
    Class { neg: bool, items: Vec<(char, char)> },
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum Rep {
    One,
    Star,
    Plus,
    Opt,
}

impl Regex {
    /// Compile, never panic: syntax problems (dangling repetition,
    /// unclosed class, trailing escape, unsupported escape, inverted
    /// range, oversize pattern) are all `Err`.
    pub fn compile(pattern: &str) -> Result<Regex> {
        let chars: Vec<char> = pattern.chars().collect();
        let mut alts = Vec::new();
        let (mut start, mut i) = (0usize, 0usize);
        let mut in_class = false;
        // Split on top-level `|` (escapes and classes shield the bar).
        while i < chars.len() {
            match chars[i] {
                '\\' => i += 1,
                '[' if !in_class => in_class = true,
                ']' if in_class => in_class = false,
                '|' if !in_class => {
                    alts.push(parse_alt(&chars[start..i])?);
                    start = i + 1;
                }
                _ => {}
            }
            i += 1;
        }
        alts.push(parse_alt(&chars[start..])?);
        if alts.len() > MAX_ALTS {
            bail!("regex: more than {MAX_ALTS} alternatives");
        }
        Ok(Regex { alts })
    }

    /// Unanchored match (unless the pattern anchors itself). Budget
    /// exhaustion returns `false` — deterministic for a given
    /// (pattern, text) pair, never a hang.
    pub fn is_match(&self, text: &str) -> bool {
        let chars: Vec<char> = text.chars().collect();
        let mut budget = STEP_BUDGET;
        for alt in &self.alts {
            let last_start = if alt.anchor_start { 0 } else { chars.len() };
            for s in 0..=last_start {
                if match_at(&alt.pieces, &chars, s, alt.anchor_end, &mut budget) {
                    return true;
                }
                if budget == 0 {
                    return false;
                }
            }
        }
        false
    }
}

fn parse_alt(chars: &[char]) -> Result<Alt> {
    let mut i = 0usize;
    let anchor_start = chars.first() == Some(&'^');
    if anchor_start {
        i = 1;
    }
    let mut anchor_end = false;
    let mut pieces: Vec<Piece> = Vec::new();
    while i < chars.len() {
        let c = chars[i];
        // `$` in final position is the end anchor; elsewhere a literal.
        if c == '$' && i + 1 == chars.len() {
            anchor_end = true;
            break;
        }
        let atom = match c {
            '.' => {
                i += 1;
                Atom::Any
            }
            '\\' => {
                let e = *chars.get(i + 1).context("regex: trailing `\\`")?;
                i += 2;
                escape_atom(e)?
            }
            '[' => {
                let (cls, next) = parse_class(chars, i)?;
                i = next;
                cls
            }
            '*' | '+' | '?' => bail!("regex: repetition `{c}` with nothing to repeat"),
            other => {
                i += 1;
                Atom::Lit(other)
            }
        };
        let rep = match chars.get(i) {
            Some('*') => {
                i += 1;
                Rep::Star
            }
            Some('+') => {
                i += 1;
                Rep::Plus
            }
            Some('?') => {
                i += 1;
                Rep::Opt
            }
            _ => Rep::One,
        };
        pieces.push(Piece { atom, rep });
        if pieces.len() > MAX_PIECES {
            bail!("regex: more than {MAX_PIECES} pieces in one alternative");
        }
    }
    Ok(Alt { anchor_start, anchor_end, pieces })
}

fn word_items() -> Vec<(char, char)> {
    vec![('0', '9'), ('A', 'Z'), ('_', '_'), ('a', 'z')]
}

fn space_items() -> Vec<(char, char)> {
    vec![('\t', '\t'), ('\n', '\n'), ('\r', '\r'), (' ', ' ')]
}

fn escape_atom(e: char) -> Result<Atom> {
    Ok(match e {
        'd' => Atom::Class { neg: false, items: vec![('0', '9')] },
        'D' => Atom::Class { neg: true, items: vec![('0', '9')] },
        'w' => Atom::Class { neg: false, items: word_items() },
        'W' => Atom::Class { neg: true, items: word_items() },
        's' => Atom::Class { neg: false, items: space_items() },
        'S' => Atom::Class { neg: true, items: space_items() },
        'n' => Atom::Lit('\n'),
        't' => Atom::Lit('\t'),
        'r' => Atom::Lit('\r'),
        c if c.is_ascii_alphanumeric() => bail!("regex: unsupported escape `\\{c}`"),
        c => Atom::Lit(c), // punctuation escapes: \. \* \[ \| \\ \$ ...
    })
}

/// Parse a `[...]` class starting at `chars[start] == '['`; returns the
/// atom and the index one past the closing `]`. A leading `]` and a
/// trailing `-` are literals, `[^...]` negates, `\d \w \s` expand.
fn parse_class(chars: &[char], start: usize) -> Result<(Atom, usize)> {
    let mut i = start + 1;
    let neg = chars.get(i) == Some(&'^');
    if neg {
        i += 1;
    }
    let mut items: Vec<(char, char)> = Vec::new();
    let mut first = true;
    loop {
        let &c = chars.get(i).context("regex: unclosed `[` class")?;
        if c == ']' && !first {
            return Ok((Atom::Class { neg, items }, i + 1));
        }
        first = false;
        let lo = if c == '\\' {
            let &e = chars.get(i + 1).context("regex: trailing `\\` in class")?;
            i += 1;
            match e {
                'd' => {
                    items.push(('0', '9'));
                    i += 1;
                    continue;
                }
                'w' => {
                    items.extend(word_items());
                    i += 1;
                    continue;
                }
                's' => {
                    items.extend(space_items());
                    i += 1;
                    continue;
                }
                other => class_escape(other)?,
            }
        } else {
            c
        };
        i += 1;
        // `lo-hi` range; a `-` followed by `]` is a literal dash.
        let ranged = chars.get(i) == Some(&'-') && chars.get(i + 1).is_some_and(|&c| c != ']');
        if ranged {
            i += 1;
            let mut hi = *chars.get(i).context("regex: unclosed `[` class")?;
            if hi == '\\' {
                let &e = chars.get(i + 1).context("regex: trailing `\\` in class")?;
                hi = class_escape(e)?;
                i += 1;
            }
            i += 1;
            if hi < lo {
                bail!("regex: inverted class range `{lo}-{hi}`");
            }
            items.push((lo, hi));
        } else {
            items.push((lo, lo));
        }
        if items.len() > MAX_PIECES {
            bail!("regex: class with more than {MAX_PIECES} items");
        }
    }
}

/// Single-char class escapes (`\d \w \s` are handled by the caller,
/// which splices their ranges in).
fn class_escape(e: char) -> Result<char> {
    Ok(match e {
        'n' => '\n',
        't' => '\t',
        'r' => '\r',
        c if c.is_ascii_alphanumeric() => bail!("regex: unsupported class escape `\\{c}`"),
        c => c,
    })
}

fn atom_matches(atom: &Atom, c: char) -> bool {
    match atom {
        Atom::Lit(l) => *l == c,
        Atom::Any => true,
        Atom::Class { neg, items } => {
            items.iter().any(|&(lo, hi)| (lo..=hi).contains(&c)) != *neg
        }
    }
}

/// Backtracking matcher for `pieces` at text position `pos`. Greedy
/// repetitions try their longest run first; every call burns one unit
/// of `budget`, and an exhausted budget fails the match. Recursion
/// depth is bounded by `pieces.len()` (each call drops one piece).
fn match_at(
    pieces: &[Piece],
    text: &[char],
    pos: usize,
    anchor_end: bool,
    budget: &mut usize,
) -> bool {
    if *budget == 0 {
        return false;
    }
    *budget -= 1;
    let Some(piece) = pieces.first() else {
        return !anchor_end || pos == text.len();
    };
    let rest = &pieces[1..];
    match piece.rep {
        Rep::One => {
            pos < text.len()
                && atom_matches(&piece.atom, text[pos])
                && match_at(rest, text, pos + 1, anchor_end, budget)
        }
        Rep::Opt => {
            (pos < text.len()
                && atom_matches(&piece.atom, text[pos])
                && match_at(rest, text, pos + 1, anchor_end, budget))
                || match_at(rest, text, pos, anchor_end, budget)
        }
        Rep::Star | Rep::Plus => {
            let mut end = pos;
            while end < text.len() && atom_matches(&piece.atom, text[end]) {
                end += 1;
            }
            let min = pos + usize::from(piece.rep == Rep::Plus);
            if end < min {
                return false;
            }
            let mut k = end;
            loop {
                if match_at(rest, text, k, anchor_end, budget) {
                    return true;
                }
                if k == min || *budget == 0 {
                    return false;
                }
                k -= 1;
            }
        }
    }
}

// ---------------------------------------------------------------------
// CLI flag surface.

/// Build the scorer set from `(flag, value)` pairs in command-line
/// order (the CLI's `all_flags`). Non-scorer flags are ignored, so the
/// whole flag list can be passed through. Boolean scorer flags accept
/// `true|on|1` (a bare `--exact` records `true`) and are skipped on
/// `false|off|0`; selecting the same scorer twice is an error (one
/// configuration per column per run).
pub fn from_flags(pairs: &[(String, String)]) -> Result<Vec<Box<dyn Scorer>>> {
    let mut out: Vec<Box<dyn Scorer>> = Vec::new();
    for (k, v) in pairs {
        let scorer: Option<Box<dyn Scorer>> = match k.as_str() {
            "exact" => bool_flag(k, v)?.then(|| Box::new(Exact) as Box<dyn Scorer>),
            "contains" => bool_flag(k, v)?
                .then(|| Box::new(Contains { case_insensitive: false }) as Box<dyn Scorer>),
            "contains-i" => bool_flag(k, v)?
                .then(|| Box::new(Contains { case_insensitive: true }) as Box<dyn Scorer>),
            "json" => bool_flag(k, v)?.then(|| Box::new(JsonValidity) as Box<dyn Scorer>),
            "levenshtein" => {
                let min = v.parse::<f64>().ok().with_context(|| {
                    format!("bad --levenshtein `{v}` (min similarity in [0, 1])")
                })?;
                Some(Box::new(Levenshtein::new(min)?))
            }
            "regex" => Some(Box::new(RegexScorer::new(v)?)),
            _ => None,
        };
        if let Some(s) = scorer {
            if out.iter().any(|o| o.name() == s.name()) {
                bail!("scorer `{}` selected twice (each scorer may appear once per run)", s.name());
            }
            out.push(s);
        }
    }
    Ok(out)
}

fn bool_flag(k: &str, v: &str) -> Result<bool> {
    match v {
        "true" | "on" | "1" => Ok(true),
        "false" | "off" | "0" => Ok(false),
        other => bail!("bad --{k} `{other}` (boolean flag)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop::{check, PropConfig};

    #[test]
    fn exact_contains_json_basics() {
        assert!(Exact.score("abc", "abc").passed);
        assert!(!Exact.score("abc", "abc ").passed);
        assert!(Contains { case_insensitive: false }.score("xx abc yy", "abc").passed);
        assert!(!Contains { case_insensitive: false }.score("xx ABC yy", "abc").passed);
        assert!(Contains { case_insensitive: true }.score("xx ABC yy", "abc").passed);
        assert!(JsonValidity.score(" {\"a\": [1, 2]} ", "").passed);
        assert!(!JsonValidity.score("{nope", "").passed);
    }

    #[test]
    fn levenshtein_known_distances() {
        assert_eq!(levenshtein("kitten", "sitting"), 3);
        assert_eq!(levenshtein("", "abc"), 3);
        assert_eq!(levenshtein("abc", "abc"), 0);
        assert!((similarity("", "") - 1.0).abs() < 1e-12);
        let s = Levenshtein::new(0.5).unwrap().score("abcd", "abxd");
        assert!(s.passed);
        assert!((s.value - 0.75).abs() < 1e-12);
        assert!(Levenshtein::new(1.5).is_err());
        assert!(Levenshtein::new(f64::NAN).is_err());
    }

    #[test]
    fn regex_feature_matrix() {
        let m = |p: &str, t: &str| Regex::compile(p).unwrap().is_match(t);
        assert!(m("abc", "xxabcyy"), "unanchored substring");
        assert!(m("^ab?c$", "ac"));
        assert!(m("^ab?c$", "abc"));
        assert!(!m("^ab?c$", "abbc"));
        assert!(m("[a-c]+", "zzba"));
        assert!(!m("^[a-c]+$", "zzba"));
        assert!(m("[^0-9]", "a1"));
        assert!(!m("^[^0-9]+$", "a1"));
        assert!(m("\\d+\\.\\d+", "pi is 3.14 ok"));
        assert!(m("cat|dog", "hotdog"));
        assert!(!m("^cat|^dog$", "hotdog"));
        assert!(m("a.*z", "a---z"));
        assert!(m("^$", ""));
        assert!(!m("^$", "x"));
        assert!(m("^\\w+\\s\\w+$", "two words"));
        assert!(m("[]a]", "]"), "leading ] is a literal");
        assert!(m("[a-]", "-"), "trailing - is a literal");
        assert!(m("x\\$", "x$"), "escaped dollar is a literal");
        assert!(m("a$", "ba"), "end anchor");
        assert!(!m("a$", "ab"));
    }

    #[test]
    fn regex_compile_errors_not_panics() {
        for bad in ["*a", "+", "?x", "[abc", "a\\", "[z-a]", "[\\", "\\q", "[\\q]"] {
            assert!(Regex::compile(bad).is_err(), "`{bad}` must fail to compile");
        }
    }

    #[test]
    fn regex_pathological_pattern_terminates() {
        // Classic catastrophic-backtracking shape: the step budget turns
        // the exponential search into a deterministic "no match".
        let re = Regex::compile("a*a*a*a*a*a*a*a*a*a*b$").unwrap();
        let text = "a".repeat(120) + "c";
        assert!(!re.is_match(&text));
    }

    fn rand_string(r: &mut Rng, alphabet: &[char], max_len: usize) -> String {
        let len = r.below(max_len + 1);
        (0..len).map(|_| alphabet[r.below(alphabet.len())]).collect()
    }

    #[test]
    fn prop_levenshtein_bounds_and_symmetry() {
        let alpha: Vec<char> = "abcx".chars().collect();
        check(
            "levenshtein_bounds",
            PropConfig { cases: 128, seed: 11 },
            |r| (rand_string(r, &alpha, 12), rand_string(r, &alpha, 12)),
            |(a, b)| {
                let d = levenshtein(a, b);
                let (la, lb) = (a.chars().count(), b.chars().count());
                if d != levenshtein(b, a) {
                    return Err("not symmetric".into());
                }
                if d < la.abs_diff(lb) || d > la.max(lb) {
                    let (lo, hi) = (la.abs_diff(lb), la.max(lb));
                    return Err(format!("distance {d} outside [{lo}, {hi}]"));
                }
                if levenshtein(a, a) != 0 {
                    return Err("identity has nonzero distance".into());
                }
                let s = similarity(a, b);
                if !(0.0..=1.0).contains(&s) {
                    return Err(format!("similarity {s} outside [0, 1]"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_threshold_monotonicity() {
        // A stricter threshold can only revoke passes, never grant them.
        let alpha: Vec<char> = "abz".chars().collect();
        check(
            "threshold_monotone",
            PropConfig { cases: 128, seed: 12 },
            |r| {
                let t1 = r.below(101) as f64 / 100.0;
                let t2 = r.below(101) as f64 / 100.0;
                (rand_string(r, &alpha, 10), rand_string(r, &alpha, 10), t1.min(t2), t1.max(t2))
            },
            |(a, b, lo, hi)| {
                let pass_hi = Levenshtein::new(*hi).unwrap().score(a, b).passed;
                let pass_lo = Levenshtein::new(*lo).unwrap().score(a, b).passed;
                if pass_hi && !pass_lo {
                    return Err(format!("passed at {hi} but not at {lo}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_regex_never_panics_on_adversarial_input() {
        // Random patterns over a metachar-heavy alphabet: compile either
        // errs or yields a matcher that terminates on random text. The
        // property is "no panic, no hang" — the assertion is reaching
        // Ok at all.
        let pat_alpha: Vec<char> = "ab*+?.[]^$|\\d-()".chars().collect();
        let txt_alpha: Vec<char> = "ab01 .$".chars().collect();
        check(
            "regex_no_panic",
            PropConfig { cases: 256, seed: 13 },
            |r| (rand_string(r, &pat_alpha, 16), rand_string(r, &txt_alpha, 24)),
            |(pat, text)| {
                if let Ok(re) = Regex::compile(pat) {
                    let _ = re.is_match(text);
                    let _ = re.is_match("");
                }
                Ok(())
            },
        );
    }

    #[test]
    fn from_flags_builds_in_order_and_rejects_dups() {
        let pairs = |kv: &[(&str, &str)]| {
            kv.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect::<Vec<_>>()
        };
        let s = from_flags(&pairs(&[
            ("exact", "true"),
            ("data", "d.jsonl"), // non-scorer flags pass through
            ("levenshtein", "0.8"),
            ("regex", "^a+$"),
            ("json", "true"),
        ]))
        .unwrap();
        let names: Vec<String> = s.iter().map(|s| s.name()).collect();
        assert_eq!(names, ["exact", "levenshtein", "regex", "json"]);
        assert!(from_flags(&pairs(&[("exact", "true"), ("exact", "on")])).is_err());
        assert!(from_flags(&pairs(&[("levenshtein", "puppies")])).is_err());
        assert!(from_flags(&pairs(&[("levenshtein", "2.0")])).is_err());
        assert!(from_flags(&pairs(&[("regex", "*bad")])).is_err());
        assert!(from_flags(&pairs(&[("exact", "maybe")])).is_err());
        // `--exact false` deselects rather than erroring.
        assert!(from_flags(&pairs(&[("exact", "false")])).unwrap().is_empty());
    }
}
