//! JSONL dataset loader for the quality harness.
//!
//! One row per line: `{"id": "...", "input": "...", "expected": "..."}`
//! with `id` and `expected` optional. Loading **never fails on row
//! content**: malformed lines (bad JSON, missing/non-string `input`)
//! become in-band error entries with their 1-based line number, so a
//! half-broken dataset still evaluates its good rows and the report can
//! say exactly what was skipped.
//!
//! Row identity is what the A/B join keys on, so it is made safe here
//! once rather than in every consumer: a row with a missing `id` — or
//! one that duplicates an earlier id — gets a deterministic synthetic
//! id (`row-<n>`, `n` = its 1-based position among the parsed rows),
//! and the dataset counts both repairs ([`Dataset::synthetic_ids`],
//! [`Dataset::dup_ids`]) for the report's warning column. After
//! parsing, ids are unique by construction: a cross-model join can
//! never silently drop or cross rows.

use crate::json::Json;
use anyhow::{Context, Result};
use std::collections::HashSet;
use std::path::Path;

/// One evaluable row.
#[derive(Clone, Debug, PartialEq)]
pub struct Row {
    /// Unique within the dataset (possibly synthetic — see module docs).
    pub id: String,
    /// The prompt sent to every model.
    pub input: String,
    /// Reference answer for the scorers (`""` when the row omits it —
    /// fine for reference-free scorers like `--regex` / `--json`).
    pub expected: String,
}

/// A parsed dataset plus its parse diagnostics.
#[derive(Clone, Debug, Default)]
pub struct Dataset {
    pub rows: Vec<Row>,
    /// `(1-based line number, message)` per malformed line.
    pub errors: Vec<(usize, String)>,
    /// Rows that received a synthetic id (missing or duplicate).
    pub synthetic_ids: usize,
    /// The subset of those that *duplicated* an earlier id.
    pub dup_ids: usize,
}

impl Dataset {
    /// Parse JSONL text. Infallible by design: every problem lands in
    /// [`Dataset::errors`] instead of aborting the load.
    pub fn parse(text: &str) -> Dataset {
        let mut ds = Dataset::default();
        let mut seen: HashSet<String> = HashSet::new();
        for (idx, line) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let j = match Json::parse(line) {
                Ok(j) => j,
                Err(e) => {
                    ds.errors.push((lineno, format!("bad JSON: {e:#}")));
                    continue;
                }
            };
            let Some(input) = j.get("input").and_then(Json::as_str) else {
                ds.errors.push((lineno, "missing string field `input`".into()));
                continue;
            };
            let expected =
                j.get("expected").and_then(Json::as_str).unwrap_or("").to_string();
            let n = ds.rows.len() + 1;
            let id = match j.get("id").and_then(Json::as_str) {
                Some(id) if seen.insert(id.to_string()) => id.to_string(),
                Some(_) => {
                    ds.dup_ids += 1;
                    synth_id(&mut seen, &mut ds.synthetic_ids, n)
                }
                None => synth_id(&mut seen, &mut ds.synthetic_ids, n),
            };
            ds.rows.push(Row { id, input: input.to_string(), expected });
        }
        ds
    }

    /// Load and parse a JSONL file (IO errors are still hard errors —
    /// only row *content* is forgiven).
    pub fn load(path: &Path) -> Result<Dataset> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading dataset {}", path.display()))?;
        Ok(Dataset::parse(&text))
    }

    /// Programmatic dataset from `(input, expected)` pairs — ids are
    /// positional and not counted as repairs (examples and tests).
    pub fn from_pairs(pairs: &[(&str, &str)]) -> Dataset {
        Dataset {
            rows: pairs
                .iter()
                .enumerate()
                .map(|(i, (input, expected))| Row {
                    id: format!("row-{}", i + 1),
                    input: (*input).to_string(),
                    expected: (*expected).to_string(),
                })
                .collect(),
            ..Dataset::default()
        }
    }
}

/// Deterministic synthetic id for row `n` (1-based); `-dup` suffixes
/// resolve collisions with user-provided `row-<n>` ids.
fn synth_id(seen: &mut HashSet<String>, counter: &mut usize, n: usize) -> String {
    *counter += 1;
    let mut id = format!("row-{n}");
    while !seen.insert(id.clone()) {
        id.push_str("-dup");
    }
    id
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn happy_path_keeps_ids_and_fields() {
        let ds = Dataset::parse(
            "{\"id\": \"a\", \"input\": \"in-a\", \"expected\": \"out-a\"}\n\
             \n\
             {\"id\": \"b\", \"input\": \"in-b\"}\n",
        );
        assert_eq!(ds.errors, vec![]);
        assert_eq!((ds.synthetic_ids, ds.dup_ids), (0, 0));
        assert_eq!(ds.rows.len(), 2);
        let want = Row { id: "a".into(), input: "in-a".into(), expected: "out-a".into() };
        assert_eq!(ds.rows[0], want);
        assert_eq!(ds.rows[1].expected, "", "missing expected defaults to empty");
    }

    #[test]
    fn malformed_lines_are_in_band_errors_not_crashes() {
        let ds = Dataset::parse(
            "{\"input\": \"ok\"}\n\
             {this is not json\n\
             {\"expected\": \"no input here\"}\n\
             {\"input\": 42}\n\
             {\"input\": \"ok2\"}\n",
        );
        assert_eq!(ds.rows.len(), 2);
        assert_eq!(ds.errors.len(), 3);
        let lines: Vec<usize> = ds.errors.iter().map(|(l, _)| *l).collect();
        assert_eq!(lines, vec![2, 3, 4], "1-based line numbers");
        assert!(ds.errors[0].1.contains("bad JSON"));
        assert!(ds.errors[1].1.contains("input"));
    }

    #[test]
    fn missing_and_duplicate_ids_get_synthetics_and_counters() {
        let ds = Dataset::parse(
            "{\"id\": \"a\", \"input\": \"i1\"}\n\
             {\"input\": \"i2\"}\n\
             {\"id\": \"a\", \"input\": \"i3\"}\n",
        );
        assert_eq!(ds.rows.len(), 3);
        assert_eq!(ds.rows[0].id, "a");
        assert_eq!(ds.rows[1].id, "row-2", "missing id is positional");
        assert_eq!(ds.rows[2].id, "row-3", "duplicate id is replaced");
        assert_eq!(ds.synthetic_ids, 2);
        assert_eq!(ds.dup_ids, 1);
    }

    #[test]
    fn synthetic_ids_never_collide_with_user_ids() {
        // A user row literally named `row-2` occupies the synthetic slot
        // the second row would get; the repair must stay unique.
        let ds = Dataset::parse(
            "{\"id\": \"row-2\", \"input\": \"i1\"}\n\
             {\"input\": \"i2\"}\n",
        );
        assert_eq!(ds.rows[0].id, "row-2");
        assert_eq!(ds.rows[1].id, "row-2-dup");
        let mut ids: Vec<&str> = ds.rows.iter().map(|r| r.id.as_str()).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), ds.rows.len(), "ids unique after repair");
    }

    #[test]
    fn from_pairs_is_positional_and_clean() {
        let ds = Dataset::from_pairs(&[("p1", "e1"), ("p2", "e2")]);
        assert_eq!(ds.rows[1].id, "row-2");
        assert_eq!((ds.synthetic_ids, ds.dup_ids, ds.errors.len()), (0, 0, 0));
    }
}
