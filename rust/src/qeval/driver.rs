//! Cross-model eval driver: fan every dataset row to N hosted models.
//!
//! The driver speaks plain protocol v2 against any live server — the
//! CLI self-hosts a registry over loopback (hermetic on the sim
//! backend) or `--attach`es to a running one; the driver cannot tell
//! the difference. Every `(model, row)` pair becomes one job on a
//! shared queue drained by [`EvalOpts::concurrency`] worker threads
//! (bounded in-flight requests, the same shape as the workload
//! replayer but closed-loop: quality runs care about coverage, not
//! arrival realism). Each job routes by the protocol-v2 `model` field,
//! retries transport failures with backoff, and records per-row
//! latency; in-band `{"error": ...}` replies are authoritative and
//! never retried.
//!
//! Results land in per-`(model, row)` slots rather than a completion
//! stream, so [`ModelRun::results`] is row-aligned with the dataset by
//! construction — the report's A/B join needs no key matching.

use super::dataset::Dataset;
use crate::config::EvalOpts;
use crate::json::Json;
use crate::server;
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Instant;

/// One row's fate against one model.
#[derive(Clone, Debug, PartialEq)]
pub enum RowOutcome {
    Done {
        /// Completion text (what the scorers grade).
        output: String,
        /// Server-reported latency split, seconds.
        ttft_s: f64,
        tpot_s: f64,
        latency_s: f64,
        /// Client-observed round trip (includes transport + retries).
        client_s: f64,
    },
    /// Transport gave up, or the server answered in-band with an error.
    Error { msg: String },
}

/// All rows for one model, index-aligned with `Dataset::rows`.
#[derive(Clone, Debug)]
pub struct ModelRun {
    pub model: String,
    pub results: Vec<RowOutcome>,
}

/// One full eval: every model × every row, plus the run wall time.
#[derive(Clone, Debug)]
pub struct EvalRun {
    pub models: Vec<ModelRun>,
    pub wall_s: f64,
}

/// Score-fetch pass: send every dataset row to every named model at
/// `addr` with bounded concurrency. Infallible per row (failures are
/// [`RowOutcome::Error`] entries); `Err` is reserved for setup-level
/// problems, of which there are currently none — the signature leaves
/// room for them.
pub fn run_eval(
    ds: &Dataset,
    models: &[String],
    addr: &str,
    opts: &EvalOpts,
) -> Result<EvalRun> {
    let start = Instant::now();
    let jobs: Mutex<VecDeque<(usize, usize)>> = Mutex::new(
        (0..models.len())
            .flat_map(|m| (0..ds.rows.len()).map(move |r| (m, r)))
            .collect(),
    );
    let slots: Vec<Vec<Mutex<Option<RowOutcome>>>> = models
        .iter()
        .map(|_| ds.rows.iter().map(|_| Mutex::new(None)).collect())
        .collect();
    let n_jobs = models.len() * ds.rows.len();
    let workers = opts.concurrency.max(1).min(n_jobs);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let job = jobs.lock().unwrap().pop_front();
                let Some((m, r)) = job else { break };
                let out = send_row(addr, &models[m], &ds.rows[r].input, opts.max_new);
                *slots[m][r].lock().unwrap() = Some(out);
            });
        }
    });
    let models = models
        .iter()
        .zip(slots)
        .map(|(name, row_slots)| ModelRun {
            model: name.clone(),
            results: row_slots
                .into_iter()
                .map(|s| s.into_inner().unwrap().expect("worker filled every slot"))
                .collect(),
        })
        .collect();
    Ok(EvalRun { models, wall_s: start.elapsed().as_secs_f64() })
}

/// One row against one model. Transport failures retry (bounded,
/// backing off); an in-band error reply is the server's answer and is
/// reported as-is.
fn send_row(addr: &str, model: &str, input: &str, max_new: usize) -> RowOutcome {
    let t0 = Instant::now();
    let mut last_err = String::new();
    for attempt in 0..3u32 {
        if attempt > 0 {
            std::thread::sleep(std::time::Duration::from_millis(5 << attempt));
        }
        match server::client_request_model(addr, input, max_new, Some(model)) {
            Ok(reply) => return classify(&reply, t0.elapsed().as_secs_f64()),
            Err(e) => last_err = format!("{e:#}"),
        }
    }
    RowOutcome::Error { msg: format!("transport: {last_err}") }
}

fn classify(reply: &Json, client_s: f64) -> RowOutcome {
    if let Some(err) = reply.get("error").and_then(Json::as_str) {
        return RowOutcome::Error { msg: err.to_string() };
    }
    let Some(output) = reply.get("text").and_then(Json::as_str) else {
        return RowOutcome::Error { msg: format!("malformed reply: {}", reply.to_string()) };
    };
    let f = |k: &str| reply.get(k).and_then(Json::as_f64).unwrap_or(0.0);
    RowOutcome::Done {
        output: output.to_string(),
        ttft_s: f("ttft_s"),
        tpot_s: f("tpot_s"),
        latency_s: f("latency_s"),
        client_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_splits_done_error_malformed() {
        let done = Json::parse(
            "{\"text\": \"hi\", \"ttft_s\": 0.01, \"tpot_s\": 0.002, \"latency_s\": 0.05}",
        )
        .unwrap();
        match classify(&done, 0.06) {
            RowOutcome::Done { output, ttft_s, latency_s, client_s, .. } => {
                assert_eq!(output, "hi");
                assert!((ttft_s - 0.01).abs() < 1e-12);
                assert!((latency_s - 0.05).abs() < 1e-12);
                assert!((client_s - 0.06).abs() < 1e-12);
            }
            other => panic!("expected Done, got {other:?}"),
        }
        let err = Json::parse("{\"error\": \"unknown model `x`\"}").unwrap();
        assert_eq!(
            classify(&err, 0.0),
            RowOutcome::Error { msg: "unknown model `x`".into() }
        );
        let odd = Json::parse("{\"ok\": true}").unwrap();
        assert!(matches!(classify(&odd, 0.0), RowOutcome::Error { .. }));
    }

    #[test]
    fn unreachable_server_yields_error_rows_not_failures() {
        // Nothing listens here: every job must come back as a transport
        // error row, aligned with the dataset, and run_eval still Oks.
        let ds = Dataset::from_pairs(&[("p1", "e1"), ("p2", "e2")]);
        let models = vec!["gqa".to_string()];
        let opts = EvalOpts { concurrency: 4, max_new: 4, baseline: None };
        let run = run_eval(&ds, &models, "127.0.0.1:18499", &opts).unwrap();
        assert_eq!(run.models.len(), 1);
        assert_eq!(run.models[0].results.len(), 2);
        for r in &run.models[0].results {
            match r {
                RowOutcome::Error { msg } => assert!(msg.starts_with("transport:")),
                other => panic!("expected transport error, got {other:?}"),
            }
        }
    }

    #[test]
    fn empty_dataset_or_models_is_a_clean_noop() {
        let opts = EvalOpts::default();
        let run = run_eval(&Dataset::default(), &["m".into()], "127.0.0.1:18499", &opts).unwrap();
        assert_eq!(run.models.len(), 1);
        assert!(run.models[0].results.is_empty());
        let run = run_eval(&Dataset::from_pairs(&[("p", "e")]), &[], "127.0.0.1:18499", &opts)
            .unwrap();
        assert!(run.models.is_empty());
    }
}
